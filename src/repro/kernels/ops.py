"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels run with interpret=True; on TPU they
compile natively. ``INTERPRET`` flips automatically from the backend.

Every dispatch runs under a ``jax.named_scope("octopus/<op>")`` so
device traces (``jax.profiler``) attribute kernel time to the protocol
step that dispatched it. Scopes only label the jaxpr/HLO — numerics,
dispatch counts and compiled programs are bit-identical with or without
them (the flight-recorder neutrality suite pins this).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .decode_codes import decode_codes_pallas
from .encode_codes import encode_codes_pallas
from .flash_attention import flash_attention_pallas
from .pack_bits import code_bits, pack_codes_pallas, unpack_codes_pallas
from .rmsnorm import rmsnorm_pallas
from .selective_scan import selective_scan_pallas
from .vq_nn import vq_nearest_pallas

INTERPRET = jax.default_backend() != "tpu"


def vq_nearest(z, codebook, **kw):
    """(N, M), (K, M) -> (N,) int32 nearest codebook atom per row."""
    kw.setdefault("interpret", INTERPRET)
    if kw["interpret"]:
        # off-TPU there is no VMEM budget: fatter N blocks mean fewer
        # (traced) grid steps, which dominates interpret-mode runtime
        kw.setdefault("block_n", 4096)
    with jax.named_scope("octopus/vq_nearest"):
        return vq_nearest_pallas(z, codebook, **kw)


def pack_codes(codes, *, bits, **kw):
    """Flat/any-shape int codes -> (n_groups, W) uint32 dense bit-stream
    at ceil(log2 K) bits per code (see kernels/pack_bits.py layout)."""
    kw.setdefault("interpret", INTERPRET)
    with jax.named_scope("octopus/pack_codes"):
        return pack_codes_pallas(codes, bits=bits, **kw)


def unpack_codes(words, *, bits, count, **kw):
    """(n_groups, W) uint32 words -> (count,) int32 codes, bit-exact."""
    kw.setdefault("interpret", INTERPRET)
    with jax.named_scope("octopus/unpack_codes"):
        return unpack_codes_pallas(words, bits=bits, count=count, **kw)


def decode_codes(words, table, *, bits=None, count=None, n_slices=1,
                 phases=None, use_ref=False, **kw):
    """Fused packed-word -> feature decode: (n, W) uint32 words + a
    (n_slices*R, F) decode table -> (count, F) rows, without the int32
    index or gathered-atom tensors ever hitting HBM (see
    kernels/decode_codes.py for the layout and the GSVQ mean-table
    contract). ``use_ref=True`` falls back to the pure-jnp oracle
    (ref.decode_codes_ref) — same result, no Pallas dispatch.

    ``words`` may be a ``repro.wire.CodePayload`` directly — bits/count
    (and per-record slice phases) then come from the carrier, and the
    result is the payload's (count, F) real rows in stream order."""
    if hasattr(words, "unpack"):               # wire carrier
        if bits is not None or count is not None or phases is not None:
            raise TypeError(
                "decode_codes got a CodePayload AND explicit bits=/count=/"
                "phases= — the carrier's own fields are authoritative; "
                "drop the arguments (or pass the raw word stream)")
        from repro.wire.codec import decode_rows
        return decode_rows(words, table, n_slices=n_slices,
                           use_ref=use_ref, **kw)
    if bits is None or count is None:
        raise TypeError("decode_codes needs bits= and count= for a raw "
                        "word stream (or pass a CodePayload)")
    if use_ref:
        from .ref import decode_codes_ref
        with jax.named_scope("octopus/decode_codes_ref"):
            return decode_codes_ref(words, table, bits=bits, count=count,
                                    n_slices=n_slices, phases=phases)
    kw.setdefault("interpret", INTERPRET)
    with jax.named_scope("octopus/decode_codes"):
        return decode_codes_pallas(words, table, bits=bits, count=count,
                                   n_slices=n_slices, phases=phases, **kw)


def encode_codes(z, codebooks, *, bits, n_groups=1, n_slices=1,
                 use_ref=None, **kw):
    """Fused latent -> packed-code encode with on-chip EMA statistics:
    (R, P, M) latents + (R, K, M) per-record codebooks -> (words
    (R*nW, W) uint32, counts (R, K), sums (R, K, M)) in ONE pass — the
    (N, K) distance matrix and the int32 index tensor never hit HBM (see
    kernels/encode_codes.py for modes and the record/packing layout).

    ``use_ref``: None (default) runs the Pallas kernel on TPU and the
    pure-jnp oracle (ref.encode_codes_ref) elsewhere — the oracle emits
    bit-identical words, and unlike the other wrappers' interpret
    fallback it keeps CPU CI fast (the XLA-fused oracle beats the
    interpreted grid). True/False force the oracle/kernel; off-TPU the
    forced kernel runs with interpret=True."""
    if use_ref or (use_ref is None and INTERPRET):
        from .ref import encode_codes_ref
        with jax.named_scope("octopus/encode_codes_ref"):
            return encode_codes_ref(z, codebooks, bits=bits,
                                    n_groups=n_groups, n_slices=n_slices)
    kw.setdefault("interpret", INTERPRET)
    if kw["interpret"]:
        # off-TPU there is no VMEM budget: fatter N blocks mean fewer
        # (traced) grid steps, which dominates interpret-mode runtime
        kw.setdefault("block_n", 4096)
    with jax.named_scope("octopus/encode_codes"):
        return encode_codes_pallas(z, codebooks, bits=bits,
                                   n_groups=n_groups, n_slices=n_slices,
                                   **kw)


def encode_payload(z, codebooks, *, bits, shape, n_groups=1, n_slices=1,
                   version=0, labels=None, n_samples=None, **kw):
    """``encode_codes`` speaking the wire natively: same fused dispatch,
    but the words come back wrapped as a ``repro.wire.CodePayload`` —
    one per-record stream per codebook record (``n_records ==
    z.shape[0]``), stamped with ``version``/``labels``/``privatized``.
    ``shape`` is the transmitted index shape (R, P[, n_c]). Returns
    (payload, counts, sums)."""
    from repro.wire.payload import CodePayload
    words, counts, sums = encode_codes(z, codebooks, bits=bits,
                                       n_groups=n_groups,
                                       n_slices=n_slices, **kw)
    payload = CodePayload.from_words(
        words, bits=bits, shape=shape, n_records=int(z.shape[0]),
        version=version, labels=labels, n_samples=n_samples,
        privatized=True)
    return payload, counts, sums


def flash_attention(q, k, v, *, causal=True, window=0, **kw):
    """(B,T,Hq,D) with GQA k/v (B,T,Hkv,D): repeat kv then run the kernel."""
    kw.setdefault("interpret", INTERPRET)
    q_per_kv = q.shape[2] // k.shape[2]
    if q_per_kv > 1:
        k = jnp.repeat(k, q_per_kv, axis=2)
        v = jnp.repeat(v, q_per_kv, axis=2)
    return flash_attention_pallas(q, k, v, causal=causal, window=window, **kw)


def rmsnorm(x, scale, *, eps=1e-6, **kw):
    kw.setdefault("interpret", INTERPRET)
    return rmsnorm_pallas(x, scale, eps=eps, **kw)


def selective_scan(decay, inp, c, h0, **kw):
    """Fused Mamba recurrence + output contraction (see selective_scan.py)."""
    kw.setdefault("interpret", INTERPRET)
    return selective_scan_pallas(decay, inp, c, h0, **kw)

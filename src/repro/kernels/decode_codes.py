"""Pallas TPU kernel: fused packed-code -> feature decode (Step 6 hot path).

The server's Step 6 front door used to decode uplinks in three
materialized hops: packed uint32 words -> int32 indices (HBM) -> gathered
atom rows (HBM, (N, N_g, m) for GSVQ) -> feature rows. This kernel goes
straight from the dense bit-stream to feature rows in ONE pass: per
(BLOCK_G, W) tile it unpacks the ``b``-bit codes with the same
constant-shift super-group layout as ``pack_bits.py`` and immediately
gathers the decode-table row on-chip via a one-hot MXU matmul, so the
intermediate index and atom tensors never touch HBM.

The decode table unifies both quantizer paths:

  * plain VQ  — the codebook itself, ``(K, M)``; a code gathers its atom.
  * GSVQ      — the precomputed per-slice group-mean table
    ``(n_slices * n_groups, m)`` (``gsvq_group_mean_table``): gathering
    row ``s * n_groups + g`` is mathematically identical to
    ``gsvq_dequantize_indices``'s uniform group average, but costs one
    row instead of an ``(N, N_g, m)`` gather + mean.

Slice bookkeeping: a flat GSVQ code stream interleaves slices — code
``j`` of a record belongs to slice ``j % n_slices``. Because streams are
padded to whole super-groups (and several records may be concatenated
into one dispatch), the kernel takes a per-group ``phase`` vector: the
slice id of the group's first code. Within a group, column ``j`` is
slice ``(phase + j) % n_slices`` — a per-row add + mod, no cross-lane
work. One-hot gather keeps everything on the MXU (the same trick the
roofline favours over dynamic row gathers on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pack_bits import packing_dims

BLOCK_G = 256          # stream super-groups per grid step


def stream_phases(n_stream_groups: int, bits: int, n_slices: int):
    """Slice id of each super-group's first code for a contiguous record.

    Group ``g`` starts at flat code offset ``g * G``, so its phase is
    ``(g * G) % n_slices``. Concatenated multi-record streams build their
    phase vector per record (each record's slice phase restarts at 0).
    """
    G, _ = packing_dims(bits)
    return (jnp.arange(n_stream_groups, dtype=jnp.int32) * G) % n_slices


def _decode_kernel(words_ref, phase_ref, table_ref, out_ref, *, bits, G, W,
                   n_slices, rows):
    """One (BG, W) word tile -> (BG, G, F) feature tile.

    Unrolls the G-column loop with constant shifts (same layout as
    ``_unpack_kernel``); each column's codes gather their table row via a
    one-hot (BG, rows*n_slices) @ (rows*n_slices, F) MXU matmul.
    """
    words = words_ref[...]                                 # (BG, W) uint32
    table = table_ref[...].astype(jnp.float32)             # (S*rows, F)
    mask = jnp.uint32((1 << bits) - 1)
    n_tab = table.shape[0]
    tab_iota = jax.lax.broadcasted_iota(jnp.int32, (1, n_tab), 1)
    for j in range(G):
        o = j * bits
        w0, s = divmod(o, 32)
        v = words[:, w0:w0 + 1] >> s
        if s + bits > 32:                                  # straddles a word
            v = v | (words[:, w0 + 1:w0 + 2] << (32 - s))
        code = (v & mask).astype(jnp.int32)                # (BG, 1)
        if n_slices > 1:
            sl = jax.lax.rem(phase_ref[...] + j, n_slices)
            code = sl * rows + code                        # row in stacked table
        onehot = (code == tab_iota).astype(jnp.float32)    # (BG, n_tab)
        feat = jax.lax.dot_general(                        # MXU gather
            onehot, table, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        out_ref[:, j, :] = feat.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "count", "n_slices",
                                             "block_g", "interpret"))
def decode_codes_pallas(words, table, *, bits: int, count: int,
                        n_slices: int = 1, phases=None,
                        block_g: int = BLOCK_G, interpret: bool = False):
    """(n_groups, W) uint32 words + (n_slices*R, F) table -> (count, F).

    Row ``i`` is the decode-table row of packed code ``i`` (pad codes
    beyond ``count`` are dropped). ``phases``: per-group slice id of the
    group's first code (default: a single contiguous record starting at
    slice 0 — see :func:`stream_phases`).
    """
    G, W = packing_dims(bits)
    n = words.shape[0]
    n_tab, F = table.shape
    assert n_tab % n_slices == 0, (n_tab, n_slices)
    rows = n_tab // n_slices
    if phases is None:
        phases = stream_phases(n, bits, n_slices)
    phases = jnp.asarray(phases, jnp.int32).reshape(-1, 1)
    block_g = min(block_g, max(8, n))
    pad = (-n) % block_g
    if pad:
        words = jnp.pad(words, ((0, pad), (0, 0)))
        phases = jnp.pad(phases, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_decode_kernel, bits=bits, G=G, W=W,
                          n_slices=n_slices, rows=rows),
        grid=((n + pad) // block_g,),
        in_specs=[
            pl.BlockSpec((block_g, W), lambda g: (g, 0)),
            pl.BlockSpec((block_g, 1), lambda g: (g, 0)),
            pl.BlockSpec((n_tab, F), lambda g: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_g, G, F), lambda g: (g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n + pad, G, F), table.dtype),
        interpret=interpret,
    )(words, phases, table)
    return out.reshape(-1, F)[:count]

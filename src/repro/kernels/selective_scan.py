"""Pallas TPU kernel: fused selective scan (Mamba recurrence + output).

    h_t = decay_t * h_{t-1} + inp_t          (B, T, di, N)
    y_t = <h_t, C_t>_N                        -> (B, T, di)

§Perf iteration 4 showed the JAX chunked formulation still writes one
(B, chunk, di, N) block per scan step to HBM (plus associative-scan
internals). This kernel keeps the running state h (BLOCK_DI, N) entirely
in VMEM scratch and streams decay/inp/C chunks through, writing ONLY the
(chunk, BLOCK_DI) y output — HBM traffic drops from O(T*di*N) state
blocks to the O(T*(2*di*N)) input reads + O(T*di) output writes that are
information-theoretically required.

Grid: (B, di/BLOCK_DI, T/CHUNK) with time minor (sequential carry in
scratch). Within a chunk the recurrence is a fori_loop over time steps —
the (BLOCK_DI, N) elementwise update maps onto the VPU; N=16 and
BLOCK_DI=512 give (512,16) VREG-aligned tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_DI = 512
CHUNK_T = 128


def _selscan_kernel(decay_ref, inp_ref, c_ref, h0_ref, y_ref, hlast_ref,
                    h_scr, *, chunk_t, seq_len):
    """One (batch, di-block, t-chunk) tile.

    decay/inp: (1, chunk_t, BLOCK_DI, N); c: (1, chunk_t, N);
    h0: (1, BLOCK_DI, N); y: (1, chunk_t, BLOCK_DI);
    hlast: (1, BLOCK_DI, N); h_scr: VMEM (BLOCK_DI, N) carry.
    """
    tstep = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(tstep == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    d = decay_ref[0].astype(jnp.float32)      # (chunk, di_blk, N)
    i = inp_ref[0].astype(jnp.float32)
    c = c_ref[0].astype(jnp.float32)          # (chunk, N)

    def step(t, carry):
        h = carry
        h = d[t] * h + i[t]                   # (di_blk, N)
        y_ref[0, t, :] = jnp.sum(h * c[t][None, :], axis=-1).astype(
            y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk_t, step, h_scr[...])
    h_scr[...] = h

    @pl.when(tstep == nt - 1)
    def _done():
        hlast_ref[0] = h.astype(hlast_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_di", "chunk_t",
                                             "interpret"))
def selective_scan_pallas(decay, inp, c, h0, *, block_di: int = BLOCK_DI,
                          chunk_t: int = CHUNK_T, interpret: bool = False):
    """decay/inp: (B, T, di, N); c: (B, T, N); h0: (B, di, N).

    Returns (y (B, T, di) float32, h_last (B, di, N) float32).
    """
    B, T, di, N = decay.shape
    block_di = min(block_di, di)
    chunk_t = min(chunk_t, T)
    pad_di = (-di) % block_di
    pad_t = (-T) % chunk_t
    if pad_di:
        decay = jnp.pad(decay, ((0, 0), (0, 0), (0, pad_di), (0, 0)),
                        constant_values=1.0)
        inp = jnp.pad(inp, ((0, 0), (0, 0), (0, pad_di), (0, 0)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad_di), (0, 0)))
    if pad_t:
        decay = jnp.pad(decay, ((0, 0), (0, pad_t), (0, 0), (0, 0)),
                        constant_values=1.0)
        inp = jnp.pad(inp, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad_t), (0, 0)))
    Tp, dip = T + pad_t, di + pad_di

    grid = (B, dip // block_di, Tp // chunk_t)
    y, hlast = pl.pallas_call(
        functools.partial(_selscan_kernel, chunk_t=chunk_t, seq_len=T),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk_t, block_di, N),
                         lambda b, di_, t: (b, t, di_, 0)),
            pl.BlockSpec((1, chunk_t, block_di, N),
                         lambda b, di_, t: (b, t, di_, 0)),
            pl.BlockSpec((1, chunk_t, N), lambda b, di_, t: (b, t, 0)),
            pl.BlockSpec((1, block_di, N), lambda b, di_, t: (b, di_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk_t, block_di),
                         lambda b, di_, t: (b, t, di_)),
            pl.BlockSpec((1, block_di, N), lambda b, di_, t: (b, di_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Tp, dip), jnp.float32),
            jax.ShapeDtypeStruct((B, dip, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_di, N), jnp.float32)],
        interpret=interpret,
    )(decay, inp, c, h0)
    return y[:, :T, :di], hlast[:, :di]

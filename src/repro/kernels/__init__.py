"""Pallas TPU kernels for the compute hot spots.

  vq_nn           MXU-tiled codebook nearest-neighbour (OCTOPUS hot spot)
  flash_attention online-softmax attention for 32k prefill
  rmsnorm         fused norm
  selective_scan  fused Mamba recurrence + output (the §Perf-4 memory fix
                  taken to its VMEM-resident conclusion)

Use via ``repro.kernels.ops``; oracles in ``repro.kernels.ref``.
"""
from . import ops, ref

"""Pallas TPU kernel: causal flash attention (online softmax).

Schedule: grid (B*H, Tq/BLOCK_Q, Tk/BLOCK_K) with the KV axis minor; the
(m, l, acc) carry lives in VMEM scratch across KV steps. Causal/window
masking prunes nothing at grid level (simplicity > skipping) but masks in
VREGs; the matmuls (q k^T and p v) hit the MXU with (128, 128) tiles.

This kernel is the TPU twin of ``repro.nn.attention._attend_chunked`` (same
math, same masking semantics), which serves as its lowering-anywhere oracle
alongside ``ref.flash_attention_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_Q = 128
BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_q, block_k, causal, window, sm_scale, seq_k):
    qstep = pl.program_id(1)
    kstep = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kstep == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * sm_scale          # (Bq, D)
    k = k_ref[0].astype(jnp.float32)                     # (Bk, D)
    v = v_ref[0].astype(jnp.float32)                     # (Bk, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Bq, Bk)

    qpos = qstep * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kpos = kstep * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = kpos < seq_k
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = (acc_ref[...] * corr[:, None]
                    + jax.lax.dot_general(
                        p, v, (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kstep == nk - 1)
    def _done():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           block_q: int = BLOCK_Q, block_k: int = BLOCK_K,
                           interpret: bool = False):
    """q,k,v: (B, T, H, D) -> (B, T, H, D). GQA repeat happens in ops.py."""
    B, Tq, H, D = q.shape
    Tk = k.shape[1]
    sm_scale = 1.0 / (D ** 0.5)
    block_q = min(block_q, Tq)
    block_k = min(block_k, Tk)
    pad_q = (-Tq) % block_q
    pad_k = (-Tk) % block_k

    def bh(t):     # (B, T, H, D) -> (B*H, T, D)
        return t.transpose(0, 2, 1, 3).reshape(B * H, t.shape[1], D)

    qh, kh, vh = bh(q), bh(k), bh(v)
    if pad_q:
        qh = jnp.pad(qh, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kh = jnp.pad(kh, ((0, 0), (0, pad_k), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, pad_k), (0, 0)))
    Tqp, Tkp = Tq + pad_q, Tk + pad_k

    grid = (B * H, Tqp // block_q, Tkp // block_k)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, block_q=block_q, block_k=block_k,
                          causal=causal, window=window, sm_scale=sm_scale,
                          seq_k=Tk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, iq, ik: (b, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Tqp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    out = out[:, :Tq].reshape(B, H, Tq, D).transpose(0, 2, 1, 3)
    return out

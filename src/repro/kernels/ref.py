"""Pure-jnp oracles for every Pallas kernel. Tests assert_allclose against
these across shape/dtype sweeps."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def vq_nearest_ref(z, codebook):
    """(N, M), (K, M) -> (N,) int32. Brute-force pairwise L2 argmin."""
    z = z.astype(jnp.float32)
    e = codebook.astype(jnp.float32)
    d = (jnp.sum(z * z, -1, keepdims=True)
         - 2.0 * z @ e.T
         + jnp.sum(e * e, -1)[None, :])
    return jnp.argmin(d, axis=-1).astype(jnp.int32)


def flash_attention_ref(q, k, v, *, causal=True, window=0, scale=None):
    """(B, T, H, D) x3 -> (B, T, H, D). Materialised softmax attention."""
    B, Tq, H, D = q.shape
    Tk = k.shape[1]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.array(D, jnp.float32))
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(Tq)[:, None]
    kpos = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def rmsnorm_ref(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def pack_codes_ref(codes, *, bits: int):
    """Flat int codes -> (n_groups, W) uint32 dense bit-stream.

    Same super-group layout as kernels/pack_bits.py: code j of a group
    occupies bits [j*b, (j+1)*b) of the group's lcm(b, 32)-bit payload.
    """
    from .pack_bits import _group_codes, packing_dims
    G, W = packing_dims(bits)
    grp = _group_codes(codes, bits)                       # (n_groups, G)
    cols = [jnp.zeros_like(grp[:, 0]) for _ in range(W)]
    for j in range(G):
        w0, s = divmod(j * bits, 32)
        c = grp[:, j]
        cols[w0] = cols[w0] | (c << s)
        if s + bits > 32:
            cols[w0 + 1] = cols[w0 + 1] | (c >> (32 - s))
    return jnp.stack(cols, axis=1)


def unpack_codes_ref(words, *, bits: int, count: int):
    """(n_groups, W) uint32 -> (count,) int32 codes."""
    from .pack_bits import packing_dims
    G, W = packing_dims(bits)
    mask = jnp.uint32((1 << bits) - 1)
    cols = []
    for j in range(G):
        w0, s = divmod(j * bits, 32)
        v = words[:, w0] >> s
        if s + bits > 32:
            v = v | (words[:, w0 + 1] << (32 - s))
        cols.append(v & mask)
    return jnp.stack(cols, axis=1).reshape(-1)[:count].astype(jnp.int32)


def decode_codes_ref(words, table, *, bits: int, count: int,
                     n_slices: int = 1, phases=None):
    """(n_groups, W) uint32 + (n_slices*R, F) table -> (count, F) rows.

    Unpack-then-gather oracle for kernels/decode_codes.py: code ``j`` of
    stream group ``g`` belongs to slice ``(phases[g] + j) % n_slices``
    and gathers table row ``slice * R + code``.
    """
    from .pack_bits import packing_dims
    G, _ = packing_dims(bits)
    n = words.shape[0]
    codes = unpack_codes_ref(words, bits=bits, count=n * G)
    if n_slices > 1:
        pos = jnp.arange(n * G, dtype=jnp.int32)
        if phases is None:
            sl = pos % n_slices
        else:
            ph = jnp.asarray(phases, jnp.int32).reshape(-1)
            sl = (ph[pos // G] + pos % G) % n_slices
        codes = sl * (table.shape[0] // n_slices) + codes
    return table[codes[:count]]


def encode_codes_ref(z, codebooks, *, bits: int, n_groups: int = 1,
                     n_slices: int = 1):
    """(R, P, M) latents + (R, K, M) per-record codebooks ->
    (words (R*nW, W) uint32, counts (R, K), sums (R, K, M)).

    Unfused oracle for kernels/encode_codes.py: per record, quantize
    against that record's codebook (plain-VQ score ``||e||^2 - 2 z.e^T``
    or the GSVQ Eq. 2 group match), pack each record's codes into its own
    zero-padded word stream, and segment-sum the Eq. 7-8 EMA statistics
    onto representative atoms (``g*ng + ng//2``; plain VQ: the atom).
    """
    R, P, M = z.shape
    K = codebooks.shape[1]
    zf = z.astype(jnp.float32)
    cb = codebooks.astype(jnp.float32)
    if n_groups > 1 or n_slices > 1:
        m = M // n_slices
        ng = K // n_groups
        zsl = zf.reshape(R, P, n_slices, m)
        csl = cb.reshape(R, K, n_slices, m).transpose(0, 2, 1, 3)

        def per_slice(z_s, cb_s):                       # (P, m), (K, m)
            z2 = jnp.sum(z_s * z_s, -1, keepdims=True)
            e2 = jnp.sum(cb_s * cb_s, -1)[None, :]
            d2 = jnp.maximum(z2 - 2.0 * (z_s @ cb_s.T) + e2, 0.0)
            d = jnp.sqrt(d2 + 1e-12)
            gd = jnp.mean(d.reshape(-1, n_groups, ng), axis=-1)
            return jnp.argmin(gd, axis=-1).astype(jnp.int32)

        idx = jax.vmap(jax.vmap(per_slice, in_axes=(1, 0), out_axes=1))(
            zsl, csl)                                   # (R, P, S)
        rep = idx * ng + ng // 2
        votes = jnp.broadcast_to(zf[:, :, None, :], idx.shape + (M,))
    else:
        e2 = jnp.sum(cb * cb, -1)                       # (R, K)
        cross = jnp.einsum("rpm,rkm->rpk", zf, cb)
        idx = jnp.argmin(e2[:, None, :] - 2.0 * cross,
                         axis=-1).astype(jnp.int32)     # (R, P)
        rep = idx
        votes = zf
    counts = jax.vmap(lambda r: jax.ops.segment_sum(
        jnp.ones_like(r.reshape(-1), jnp.float32), r.reshape(-1), K))(rep)
    sums = jax.vmap(lambda v, r: jax.ops.segment_sum(
        v.reshape(-1, M), r.reshape(-1), K))(votes, rep)
    # per-record pack, vectorized: every record zero-pads to whole
    # super-groups, so padding each record's flat codes to nW*G and
    # flattening IS the concatenation of the per-record streams
    from .pack_bits import packing_dims
    G, _ = packing_dims(bits)
    flat = idx.reshape(R, -1)
    pad = (-flat.shape[1]) % G
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    words = pack_codes_ref(flat, bits=bits)
    return words, counts, sums


def selective_scan_ref(decay, inp, c, h0):
    """Naive sequential reference: h_t = d_t h_{t-1} + i_t; y_t = <h_t, c_t>.

    decay/inp (B,T,di,N); c (B,T,N); h0 (B,di,N) -> (y (B,T,di), h_last).
    """
    def step(h, xs):
        d, i, ct = xs
        h = d * h + i
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    d = jnp.moveaxis(decay.astype(jnp.float32), 1, 0)
    i = jnp.moveaxis(inp.astype(jnp.float32), 1, 0)
    ct = jnp.moveaxis(c.astype(jnp.float32), 1, 0)
    h_last, ys = jax.lax.scan(step, h0.astype(jnp.float32), (d, i, ct))
    return jnp.moveaxis(ys, 0, 1), h_last

"""Pure-jnp oracles for every Pallas kernel. Tests assert_allclose against
these across shape/dtype sweeps."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def vq_nearest_ref(z, codebook):
    """(N, M), (K, M) -> (N,) int32. Brute-force pairwise L2 argmin."""
    z = z.astype(jnp.float32)
    e = codebook.astype(jnp.float32)
    d = (jnp.sum(z * z, -1, keepdims=True)
         - 2.0 * z @ e.T
         + jnp.sum(e * e, -1)[None, :])
    return jnp.argmin(d, axis=-1).astype(jnp.int32)


def flash_attention_ref(q, k, v, *, causal=True, window=0, scale=None):
    """(B, T, H, D) x3 -> (B, T, H, D). Materialised softmax attention."""
    B, Tq, H, D = q.shape
    Tk = k.shape[1]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.array(D, jnp.float32))
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(Tq)[:, None]
    kpos = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def rmsnorm_ref(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def pack_codes_ref(codes, *, bits: int):
    """Flat int codes -> (n_groups, W) uint32 dense bit-stream.

    Same super-group layout as kernels/pack_bits.py: code j of a group
    occupies bits [j*b, (j+1)*b) of the group's lcm(b, 32)-bit payload.
    """
    from .pack_bits import _group_codes, packing_dims
    G, W = packing_dims(bits)
    grp = _group_codes(codes, bits)                       # (n_groups, G)
    cols = [jnp.zeros_like(grp[:, 0]) for _ in range(W)]
    for j in range(G):
        w0, s = divmod(j * bits, 32)
        c = grp[:, j]
        cols[w0] = cols[w0] | (c << s)
        if s + bits > 32:
            cols[w0 + 1] = cols[w0 + 1] | (c >> (32 - s))
    return jnp.stack(cols, axis=1)


def unpack_codes_ref(words, *, bits: int, count: int):
    """(n_groups, W) uint32 -> (count,) int32 codes."""
    from .pack_bits import packing_dims
    G, W = packing_dims(bits)
    mask = jnp.uint32((1 << bits) - 1)
    cols = []
    for j in range(G):
        w0, s = divmod(j * bits, 32)
        v = words[:, w0] >> s
        if s + bits > 32:
            v = v | (words[:, w0 + 1] << (32 - s))
        cols.append(v & mask)
    return jnp.stack(cols, axis=1).reshape(-1)[:count].astype(jnp.int32)


def decode_codes_ref(words, table, *, bits: int, count: int,
                     n_slices: int = 1, phases=None):
    """(n_groups, W) uint32 + (n_slices*R, F) table -> (count, F) rows.

    Unpack-then-gather oracle for kernels/decode_codes.py: code ``j`` of
    stream group ``g`` belongs to slice ``(phases[g] + j) % n_slices``
    and gathers table row ``slice * R + code``.
    """
    from .pack_bits import packing_dims
    G, _ = packing_dims(bits)
    n = words.shape[0]
    codes = unpack_codes_ref(words, bits=bits, count=n * G)
    if n_slices > 1:
        pos = jnp.arange(n * G, dtype=jnp.int32)
        if phases is None:
            sl = pos % n_slices
        else:
            ph = jnp.asarray(phases, jnp.int32).reshape(-1)
            sl = (ph[pos // G] + pos % G) % n_slices
        codes = sl * (table.shape[0] // n_slices) + codes
    return table[codes[:count]]


def selective_scan_ref(decay, inp, c, h0):
    """Naive sequential reference: h_t = d_t h_{t-1} + i_t; y_t = <h_t, c_t>.

    decay/inp (B,T,di,N); c (B,T,N); h0 (B,di,N) -> (y (B,T,di), h_last).
    """
    def step(h, xs):
        d, i, ct = xs
        h = d * h + i
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    d = jnp.moveaxis(decay.astype(jnp.float32), 1, 0)
    i = jnp.moveaxis(inp.astype(jnp.float32), 1, 0)
    ct = jnp.moveaxis(c.astype(jnp.float32), 1, 0)
    h_last, ys = jax.lax.scan(step, h0.astype(jnp.float32), (d, i, ct))
    return jnp.moveaxis(ys, 0, 1), h_last

"""Pallas TPU kernel: fused latent -> packed-code + EMA-stats encode.

The client uplink hot path (§2.2 Steps 3-5, §3.8 encode latency), the
mirror image of ``decode_codes.py``: where the server fuses packed words
-> features, the client must fuse latents -> packed words. The unfused
path materialized the (N, K) distance matrix in HBM (``vq.nearest_atom``),
wrote the int32 index tensor back to HBM, re-read it for ``pack_codes``,
and re-ran the encoder to rebuild the very same latents for the EMA
refresh. This kernel does the whole quantize-pack-stats tail in ONE pass:

  * **streaming argmin** — distances are computed per (BLOCK_N, BLOCK_K)
    tile on the MXU with ``vq_nn.py``'s flash-style carry (running best
    distance + code in VMEM scratch), so the (N, K) matrix never exists;
  * **in-kernel packing** — on the last K step each N block's codes are
    OR-folded into the dense ``ceil(log2 K)``-bit uint32 word stream with
    ``pack_bits.py``'s constant-shift super-group layout; the int32 index
    tensor never touches HBM;
  * **on-chip EMA statistics** — the same codes drive a one-hot
    (BLOCK_N, K) @ (BLOCK_N, M) MXU matmul accumulating the per-atom
    counts and latent sums of Eq. 7-8, so the Step 5 refresh needs no
    second encoder pass (``ema.ema_update_from_stats`` consumes them).

Quantizer modes share one kernel:

  * plain VQ — score ``||e||^2 - 2 z.e^T`` per atom (row-constant
    ``||z||^2`` dropped), bit-identical to ``vq_nn.py``;
  * GSVQ — per-slice group match (Eq. 2): the per-record table is the
    slice-stacked codebook ``(n_slices * K, m)`` (slice ``s`` owns rows
    ``[s*K, (s+1)*K)``, the same layout family as the decode kernel's
    group-mean table), per-atom sqrt distances are mean-pooled over each
    group's ``ng`` rows, and a slice mask keeps row ``t`` (slice
    ``t % n_slices``) matching only its own slice's groups. Emitted
    codes are the within-slice group indices — exactly the transmitted
    alphabet — and EMA mass lands on each group's representative atom
    (``g * ng + ng//2``), matching ``octopus.client_codebook_refresh``.

Records: the leading axis of ``z``/``codebooks`` is a record (client)
axis — every record is quantized against ITS OWN codebook and packed
into its own zero-padded word stream, so one dispatch encodes a whole
simulated population (per-record streams concatenate exactly like the
multi-record streams ``decode_codes`` already consumes, slice phase
restarting at 0 per record).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pack_bits import packing_dims

BLOCK_N = 256          # flat codes per grid step
BLOCK_K = 512          # stacked-table rows per grid step


def stacked_slice_table(codebooks, *, n_slices: int):
    """(R, K, M) codebooks -> (R, n_slices * K, m) slice-stacked tables.

    Slice ``s`` of record ``r`` owns rows ``[s*K, (s+1)*K)``; group ``g``
    of slice ``s`` is the ``ng`` consecutive rows at ``s*K + g*ng``.
    """
    R, K, M = codebooks.shape
    m = M // n_slices
    return codebooks.reshape(R, K, n_slices, m).transpose(0, 2, 1, 3) \
        .reshape(R, n_slices * K, m)


def _encode_kernel(zs_ref, zf_ref, tab_ref, words_ref, counts_ref, sums_ref,
                   best_ref, code_ref, *, bits, G, W, n_slices, n_groups, ng,
                   n_atoms, count, block_k, vq_mode):
    """One (record, N block, K block) tile.

    zs_ref:  (1, BN, m)   slice-view latents            [VMEM]
    zf_ref:  (1, BN/S, M) full latents (stats values)   [VMEM]
    tab_ref: (1, BK, m)   stacked-table tile            [VMEM]
    words_ref:  (1, BN/G, W) packed words (last K step)
    counts_ref: (1, K)       per-atom counts  (accumulated over N blocks)
    sums_ref:   (1, K, M)    per-atom sums    (accumulated over N blocks)
    best_ref/code_ref: VMEM scratch carries across the K grid axis.
    """
    nstep = pl.program_id(1)
    kstep = pl.program_id(2)
    nk = pl.num_programs(2)
    BN = zs_ref.shape[1]

    @pl.when(kstep == 0)
    def _init():
        best_ref[...] = jnp.full_like(best_ref, jnp.inf)
        code_ref[...] = jnp.zeros_like(code_ref)

    zs = zs_ref[0].astype(jnp.float32)                     # (BN, m)
    e = tab_ref[0].astype(jnp.float32)                     # (BK, m)
    e2 = jnp.sum(e * e, axis=-1)[None, :]                  # (1, BK)
    cross = jax.lax.dot_general(                           # MXU matmul
        zs, e, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                # (BN, BK)

    if vq_mode:
        # same score as vq_nn.py: ||e||^2 - 2 z.e^T, pad atoms masked out
        d = e2 - 2.0 * cross
        gid = kstep * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        d = jnp.where(gid < n_atoms, d, jnp.inf)
        local_best = jnp.min(d, axis=-1)                   # (BN,)
        local_code = (jnp.argmin(d, axis=-1).astype(jnp.int32)
                      + kstep * block_k)
    else:
        # Eq. 2: sqrt per-atom distance, mean-pooled over each group
        gb = block_k // ng                                 # groups per tile
        z2 = jnp.sum(zs * zs, axis=-1, keepdims=True)      # (BN, 1)
        d2 = jnp.maximum(z2 - 2.0 * cross + e2, 0.0)
        d = jnp.sqrt(d2 + 1e-12)
        gd = jnp.mean(d.reshape(BN, gb, ng), axis=-1)      # (BN, gb)
        g0 = kstep * gb
        g_gid = g0 + jax.lax.broadcasted_iota(jnp.int32, (1, gb), 1)
        row_slice = jax.lax.broadcasted_iota(
            jnp.int32, (BN, 1), 0) % n_slices              # BN % S == 0
        gd = jnp.where(g_gid // n_groups == row_slice, gd, jnp.inf)
        local_best = jnp.min(gd, axis=-1)
        # carried code is the WITHIN-SLICE group index (the transmitted
        # alphabet); masking guarantees the winner is in the row's slice
        local_code = (jnp.argmin(gd, axis=-1).astype(jnp.int32) + g0
                      - row_slice[:, 0] * n_groups)

    prev_best = best_ref[...]
    take_new = local_best < prev_best                      # ties keep first
    best_ref[...] = jnp.where(take_new, local_best, prev_best)
    code_ref[...] = jnp.where(take_new, local_code, code_ref[...])

    @pl.when(kstep == nk - 1)
    def _emit():
        iota_n = jax.lax.broadcasted_iota(jnp.int32, (BN, 1), 0)[:, 0]
        valid = (nstep * BN + iota_n) < count
        codes = jnp.where(valid, code_ref[...], 0)         # pad packs as 0

        # ---- pack: (BN,) codes -> (BN/G, W) words, pack_bits.py layout
        grp = codes.reshape(BN // G, G).astype(jnp.uint32)
        cols = [jnp.zeros_like(grp[:, :1]) for _ in range(W)]
        for j in range(G):
            w0, s = divmod(j * bits, 32)
            c = grp[:, j:j + 1]
            cols[w0] = cols[w0] | (c << s)
            if s + bits > 32:                              # straddles a word
                cols[w0 + 1] = cols[w0 + 1] | (c >> (32 - s))
        words_ref[0] = jnp.concatenate(cols, axis=1)

        # ---- EMA statistics: one-hot MXU matmul onto representative atoms
        rep = codes * ng + (ng // 2)                       # vq: ng == 1
        kiota = jax.lax.broadcasted_iota(jnp.int32, (1, n_atoms), 1)
        onehot = ((rep[:, None] == kiota)
                  & valid[:, None]).astype(jnp.float32)    # (BN, K)
        cnt = jnp.sum(onehot, axis=0)                      # (K,)
        if n_slices > 1:
            # every slice votes its position's FULL latent (Eq. 7-8 via
            # client_codebook_refresh's broadcast), so fold slices first
            onehot = jnp.sum(
                onehot.reshape(BN // n_slices, n_slices, n_atoms), axis=1)
        zf = zf_ref[0].astype(jnp.float32)                 # (BN/S, M)
        sm = jax.lax.dot_general(                          # MXU scatter
            onehot, zf, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)            # (K, M)

        @pl.when(nstep == 0)
        def _first():
            counts_ref[0] = cnt
            sums_ref[0] = sm

        @pl.when(nstep != 0)
        def _acc():
            counts_ref[0] += cnt
            sums_ref[0] += sm


@functools.partial(jax.jit, static_argnames=("bits", "n_groups", "n_slices",
                                             "block_n", "block_k",
                                             "interpret"))
def encode_codes_pallas(z, codebooks, *, bits: int, n_groups: int = 1,
                        n_slices: int = 1, block_n: int = BLOCK_N,
                        block_k: int = BLOCK_K, interpret: bool = False):
    """z: (R, P, M) latents + (R, K, M) per-record codebooks ->
    (words (R * ceil(P*S/G), W) uint32, counts (R, K), sums (R, K, M)).

    Record ``r``'s codes are packed into rows ``[r*nW, (r+1)*nW)`` of the
    word stream (each record zero-padded to whole super-groups, exactly
    like ``pack_codes`` on that record alone); counts/sums are its Eq. 7-8
    EMA sufficient statistics. ``n_groups``/``n_slices`` > 1 selects the
    GSVQ mode (codes are within-slice group indices).
    """
    R, P, M = z.shape
    Rc, K, M2 = codebooks.shape
    assert M == M2 and R == Rc, (z.shape, codebooks.shape)
    gsvq = n_groups > 1 or n_slices > 1
    G, W = packing_dims(bits)
    if gsvq:
        assert M % n_slices == 0 and K % n_groups == 0, (M, K, n_groups,
                                                         n_slices)
        m = M // n_slices
        ng = K // n_groups
        table = stacked_slice_table(codebooks, n_slices=n_slices)
        S = n_slices
    else:
        m, ng, table, S = M, 1, codebooks, 1

    Pn = P * S                            # flat codes per record
    nW = -(-Pn // G)                      # payload rows per record
    unit = (G * S) // math.gcd(G, S)      # lcm: pack + slice alignment
    bn = max(unit, unit * (min(block_n, Pn + unit - 1) // unit))
    NB = -(-Pn // bn)
    BNp = bn // S

    t_rows = table.shape[1]               # S * K (multiple of ng)
    bk = max(ng, ng * (block_k // ng))
    bk = min(bk, t_rows)
    KB = -(-t_rows // bk)

    zs = z.reshape(R, Pn, m)
    pad_n = NB * bn - Pn
    if pad_n:
        zs = jnp.pad(zs, ((0, 0), (0, pad_n), (0, 0)))
    zf = z
    pad_p = NB * BNp - P
    if pad_p:
        zf = jnp.pad(zf, ((0, 0), (0, pad_p), (0, 0)))
    pad_t = KB * bk - t_rows              # pad rows masked via atom/slice id
    if pad_t:
        table = jnp.pad(table, ((0, 0), (0, pad_t), (0, 0)))

    words, counts, sums = pl.pallas_call(
        functools.partial(_encode_kernel, bits=bits, G=G, W=W, n_slices=S,
                          n_groups=(n_groups if gsvq else K), ng=ng,
                          n_atoms=K, count=Pn, block_k=bk,
                          vq_mode=not gsvq),
        grid=(R, NB, KB),
        in_specs=[
            pl.BlockSpec((1, bn, m), lambda r, n, k: (r, n, 0)),
            pl.BlockSpec((1, BNp, M), lambda r, n, k: (r, n, 0)),
            pl.BlockSpec((1, bk, m), lambda r, n, k: (r, k, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bn // G, W), lambda r, n, k: (r, n, 0)),
            pl.BlockSpec((1, K), lambda r, n, k: (r, 0)),
            pl.BlockSpec((1, K, M), lambda r, n, k: (r, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, NB * (bn // G), W), jnp.uint32),
            jax.ShapeDtypeStruct((R, K), jnp.float32),
            jax.ShapeDtypeStruct((R, K, M), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bn,), jnp.float32),
            pltpu.VMEM((bn,), jnp.int32),
        ],
        interpret=interpret,
    )(zs, zf, table)
    return words[:, :nW].reshape(R * nW, W), counts, sums

"""Pallas TPU kernel: fused RMSNorm.

Row-blocked over tokens; the full feature dim sits in VMEM (d_model <= 8192
=> 32 KB/row fp32, well within the ~16 MB VMEM at our block sizes). Fusing
the mean-square reduction with the scale multiply keeps the activation from
round-tripping to HBM between the two passes XLA would otherwise emit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)                  # (rows, d)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * s_ref[...].astype(jnp.float32)[None, :]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_pallas(x, scale, *, eps: float = 1e-6,
                   block_rows: int = BLOCK_ROWS, interpret: bool = False):
    """x: (..., d); scale: (d,)."""
    orig_shape = x.shape
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    N = xf.shape[0]
    block_rows = min(block_rows, N)
    pad = (-N) % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    grid = ((N + pad) // block_rows,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N + pad, d), x.dtype),
        interpret=interpret,
    )(xf, scale)
    return out[:N].reshape(orig_shape)

"""Pallas TPU kernels: dense bit-packing of VQ code indices (§2.8).

OCTOPUS clients transmit int code indices; each index only needs
``b = ceil(log2 K)`` bits (5-10 in the paper), so sending int32 wastes
3-6x the uplink. These kernels pack a flat int32 code stream into a
dense uint32 word stream (and back), so the transmitted byte count is
*measured* from the packed buffer instead of computed from a formula.

Layout: codes are processed in super-groups of ``G = lcm(b, 32) / b``
codes spanning exactly ``W = lcm(b, 32) / 32`` words, so every group has
an identical, statically-known bit layout — code ``j`` of a group lives
at bit offset ``j*b``, possibly straddling two words. Both the pack and
unpack kernels unroll the G-column loop with constant shifts (no
cross-lane bit gymnastics), which keeps everything on the VPU; the grid
tiles the group axis like ``vq_nn.py`` tiles N.

The stream is padded with zero codes to a whole number of groups; the
word-stream therefore carries ``ceil(N / G) * W`` words, i.e. exactly
``b`` bits per code plus at most ``W*4 - 1`` trailing pad bytes.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_G = 512          # groups per grid step


def code_bits(n_atoms: int) -> int:
    """Bits per transmitted code index: ceil(log2 K) (§2.8)."""
    return max(1, math.ceil(math.log2(max(int(n_atoms), 2))))


def packing_dims(bits: int):
    """(G codes, W words) per super-group: lcm(bits, 32) bits of payload."""
    if not 1 <= bits <= 32:
        raise ValueError(f"bits must be in [1, 32], got {bits}")
    lcm = bits * 32 // math.gcd(bits, 32)
    return lcm // bits, lcm // 32


def _group_codes(codes, bits: int):
    """Flat int codes -> (n_groups, G) uint32, zero-padded to whole groups."""
    G, _ = packing_dims(bits)
    flat = codes.reshape(-1).astype(jnp.uint32)
    pad = (-flat.shape[0]) % G
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, G) & jnp.uint32((1 << bits) - 1)


# ------------------------------------------------------------------ kernels

def _pack_kernel(codes_ref, words_ref, *, bits, G, W):
    """One (BLOCK_G, G) -> (BLOCK_G, W) tile: OR constant-shifted columns."""
    grp = codes_ref[...]                                  # (BG, G) uint32
    cols = [jnp.zeros_like(grp[:, :1]) for _ in range(W)]
    for j in range(G):
        o = j * bits
        w0, s = divmod(o, 32)
        c = grp[:, j:j + 1]
        cols[w0] = cols[w0] | (c << s)                    # low 32 bits wrap
        if s + bits > 32:                                 # straddles a word
            cols[w0 + 1] = cols[w0 + 1] | (c >> (32 - s))
    words_ref[...] = jnp.concatenate(cols, axis=1)


def _unpack_kernel(words_ref, codes_ref, *, bits, G, W):
    """Inverse tile: rebuild each code from its (up to two) host words."""
    words = words_ref[...]                                # (BG, W) uint32
    mask = jnp.uint32((1 << bits) - 1)
    cols = []
    for j in range(G):
        o = j * bits
        w0, s = divmod(o, 32)
        v = words[:, w0:w0 + 1] >> s
        if s + bits > 32:
            v = v | (words[:, w0 + 1:w0 + 2] << (32 - s))
        cols.append(v & mask)
    codes_ref[...] = jnp.concatenate(cols, axis=1).astype(jnp.int32)


# ----------------------------------------------------------------- wrappers

@functools.partial(jax.jit,
                   static_argnames=("bits", "block_g", "interpret"))
def pack_codes_pallas(codes, *, bits: int, block_g: int = BLOCK_G,
                      interpret: bool = False):
    """codes: int (...,) -> (n_groups, W) uint32 dense bit-stream."""
    G, W = packing_dims(bits)
    grp = _group_codes(codes, bits)
    n = grp.shape[0]
    block_g = min(block_g, max(8, n))
    pad = (-n) % block_g
    if pad:
        grp = jnp.pad(grp, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_pack_kernel, bits=bits, G=G, W=W),
        grid=((n + pad) // block_g,),
        in_specs=[pl.BlockSpec((block_g, G), lambda g: (g, 0))],
        out_specs=pl.BlockSpec((block_g, W), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((n + pad, W), jnp.uint32),
        interpret=interpret,
    )(grp)
    return out[:n]


@functools.partial(jax.jit,
                   static_argnames=("bits", "count", "block_g", "interpret"))
def unpack_codes_pallas(words, *, bits: int, count: int,
                        block_g: int = BLOCK_G, interpret: bool = False):
    """(n_groups, W) uint32 -> (count,) int32 codes (pad codes dropped)."""
    G, W = packing_dims(bits)
    n = words.shape[0]
    block_g = min(block_g, max(8, n))
    pad = (-n) % block_g
    if pad:
        words = jnp.pad(words, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_unpack_kernel, bits=bits, G=G, W=W),
        grid=((n + pad) // block_g,),
        in_specs=[pl.BlockSpec((block_g, W), lambda g: (g, 0))],
        out_specs=pl.BlockSpec((block_g, G), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((n + pad, G), jnp.int32),
        interpret=interpret,
    )(words)
    return out[:n].reshape(-1)[:count]

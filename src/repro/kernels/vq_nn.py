"""Pallas TPU kernel: VQ nearest-neighbour codebook search.

The OCTOPUS per-sample hot spot: for N latent vectors z (N, M) find the
nearest of K codebook atoms e (K, M) under L2. GPU ports do a per-vector
scan; on TPU we use the expanded form

    ||z - e||^2 = ||z||^2 - 2 z.e^T + ||e||^2

so the dominant term is an (N_blk, M) x (M, K_blk) matmul that runs on the
MXU, with a *streaming argmin* across K blocks (flash-attention style: carry
the running best distance + index, never materialise the (N, K) matrix in
HBM). ||z||^2 is constant per row and dropped from the argmin.

Grid: (N // BLOCK_N, K // BLOCK_K); K is the minor (fastest) grid axis so
each N block sees K blocks in sequence and the carry lives in VMEM scratch.

Block shapes are (8,128)-aligned for VREG/MXU tiling. M is loaded whole
(codebook atom dims here are small: 64-256).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_N = 256
BLOCK_K = 512


def _vq_nn_kernel(z_ref, e_ref, idx_ref, best_ref, bestidx_ref, *, block_k):
    """One (n_block, k_block) tile.

    z_ref:   (BLOCK_N, M) queries            [VMEM]
    e_ref:   (BLOCK_K, M) codebook tile      [VMEM]
    idx_ref: (BLOCK_N,)   output indices     [VMEM] (written on last k step)
    best_ref/bestidx_ref: VMEM scratch carries across the K grid axis.
    """
    kstep = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(kstep == 0)
    def _init():
        best_ref[...] = jnp.full_like(best_ref, jnp.inf)
        bestidx_ref[...] = jnp.zeros_like(bestidx_ref)

    z = z_ref[...].astype(jnp.float32)                    # (N, M)
    e = e_ref[...].astype(jnp.float32)                    # (K_blk, M)
    # distance sans ||z||^2 (row-constant): ||e||^2 - 2 z e^T
    e2 = jnp.sum(e * e, axis=-1)[None, :]                 # (1, K_blk)
    cross = jax.lax.dot_general(                          # MXU matmul
        z, e, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)               # (N, K_blk)
    d = e2 - 2.0 * cross

    local_best = jnp.min(d, axis=-1)                      # (N,)
    local_arg = jnp.argmin(d, axis=-1).astype(jnp.int32) + kstep * block_k

    prev_best = best_ref[...]
    prev_idx = bestidx_ref[...]
    take_new = local_best < prev_best
    best_ref[...] = jnp.where(take_new, local_best, prev_best)
    bestidx_ref[...] = jnp.where(take_new, local_arg, prev_idx)

    @pl.when(kstep == nk - 1)
    def _done():
        idx_ref[...] = bestidx_ref[...]


@functools.partial(jax.jit, static_argnames=("block_n", "block_k", "interpret"))
def vq_nearest_pallas(z, codebook, *, block_n: int = BLOCK_N,
                      block_k: int = BLOCK_K, interpret: bool = False):
    """z: (N, M) float; codebook: (K, M) -> (N,) int32 nearest-atom indices.

    N and K are padded to block multiples; M loaded unblocked.
    """
    N, M = z.shape
    K, M2 = codebook.shape
    assert M == M2, (M, M2)
    block_n = min(block_n, max(8, N))
    block_k = min(block_k, max(128, K))
    pad_n = (-N) % block_n
    pad_k = (-K) % block_k
    zp = jnp.pad(z, ((0, pad_n), (0, 0))) if pad_n else z
    # pad codebook with +inf-distance atoms (huge norm keeps them unselected)
    ep = jnp.pad(codebook, ((0, pad_k), (0, 0)), constant_values=1e30) \
        if pad_k else codebook
    Np, Kp = N + pad_n, K + pad_k

    grid = (Np // block_n, Kp // block_k)
    out = pl.pallas_call(
        functools.partial(_vq_nn_kernel, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, M), lambda n, k: (n, 0)),
            pl.BlockSpec((block_k, M), lambda n, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda n, k: (n,)),
        out_shape=jax.ShapeDtypeStruct((Np,), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((block_n,), jnp.float32),
            pltpu.VMEM((block_n,), jnp.int32),
        ],
        interpret=interpret,
    )(zp, ep)
    return out[:N]

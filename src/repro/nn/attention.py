"""Attention: GQA/MQA/MHA with RoPE, qk-norm, sliding window, KV cache.

Two compute paths:
  * ``_attend_full``   — plain einsum softmax attention (short sequences).
  * ``_attend_chunked``— KV-blockwise online-softmax (flash-attention
    algorithm in pure JAX via ``lax.scan``), used when seq >= CHUNK_THRESHOLD
    so 32k-prefill never materialises an S×S score tensor. The Pallas TPU
    kernel (repro.kernels.flash_attention) implements the same schedule for
    the MXU; this is its lowering-anywhere twin and numeric oracle.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .layers import dense_init, init_rmsnorm, rmsnorm
from repro import hints

CHUNK_THRESHOLD = 8192
KV_CHUNK = 1024
NEG_INF = -1e30


# ---------------------------------------------------------------- RoPE

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta=10000.0):
    """x: (B, T, H, D); positions: (B, T) int32."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                       # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * inv   # (B, T, d/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- params

def init_attention(key, cfg, dtype=jnp.float32):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, nq * hd, dtype),
        "wk": dense_init(ks[1], d, nkv * hd, dtype),
        "wv": dense_init(ks[2], d, nkv * hd, dtype),
        "wo": dense_init(ks[3], nq * hd, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, dtype)
        p["k_norm"] = init_rmsnorm(hd, dtype)
    return p


# ---------------------------------------------------------------- cores

def _repeat_kv(k, q_per_kv):
    if q_per_kv == 1:
        return k
    return jnp.repeat(k, q_per_kv, axis=2)


def _attend_full(q, k, v, *, causal, q_offset, window, kv_len_mask=None):
    """q: (B,Tq,Hq,D) k,v: (B,Tk,Hkv,D) with Hq == Hkv (pre-repeated)."""
    B, Tq, H, D = q.shape
    Tk = k.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.array(D, jnp.float32))
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = q_offset + jnp.arange(Tq)[:, None]        # (Tq,1)
    kpos = jnp.arange(Tk)[None, :]                   # (1,Tk)
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    if kv_len_mask is not None:                      # (B, Tk) valid-cache mask
        scores = jnp.where(kv_len_mask[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def _attend_chunked(q, k, v, *, causal, q_offset, window, kv_chunk=KV_CHUNK):
    """Online-softmax over KV chunks; memory O(Tq * kv_chunk) not O(Tq*Tk).

    Same math as flash attention: carry running (max, denom, weighted sum).
    """
    B, Tq, H, D = q.shape
    Tk = k.shape[1]
    Dk, Dv = k.shape[-1], v.shape[-1]      # MLA: k/v head dims differ from q
    n_chunks = -(-Tk // kv_chunk)
    pad = n_chunks * kv_chunk - Tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, kv_chunk, H, Dk).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, kv_chunk, H, Dv).transpose(1, 0, 2, 3, 4)
    scale = 1.0 / jnp.sqrt(jnp.array(D, jnp.float32))
    qf = q.astype(jnp.float32) * scale
    qpos = q_offset + jnp.arange(Tq)[:, None]

    def step(carry, xs):
        m, l, acc = carry
        kblk, vblk, cidx = xs
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kblk.astype(jnp.float32))
        kpos = cidx * kv_chunk + jnp.arange(kv_chunk)[None, :]
        mask = kpos < Tk
        if causal:
            mask = mask & (kpos <= qpos)
        if window:
            mask = mask & (kpos > qpos - window)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vblk.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    Dv = v.shape[-1]
    m0 = jnp.full((B, H, Tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Tq), jnp.float32)
    a0 = jnp.zeros((B, H, Tq, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (kc, vc, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def attend(q, k, v, *, causal=True, q_offset=0, window=0, kv_len_mask=None,
           force_chunked: Optional[bool] = None):
    """Dispatch full vs chunked attention. Inputs already RoPE'd/normed.

    q: (B,Tq,Hq,D), k/v: (B,Tk,Hkv,D) — GQA repeat happens here.
    """
    q_per_kv = q.shape[2] // k.shape[2]
    k = _repeat_kv(k, q_per_kv)
    v = _repeat_kv(v, q_per_kv)
    use_chunked = (q.shape[1] * k.shape[1] > CHUNK_THRESHOLD ** 2
                   if force_chunked is None else force_chunked)
    if use_chunked and kv_len_mask is None and q.shape[1] > 1:
        return _attend_chunked(q, k, v, causal=causal, q_offset=q_offset,
                               window=window)
    return _attend_full(q, k, v, causal=causal, q_offset=q_offset,
                        window=window, kv_len_mask=kv_len_mask)


# ---------------------------------------------------------------- module

class KVCache(NamedTuple):
    k: jax.Array            # (B, S, n_kv, head_dim)
    v: jax.Array
    # position index is carried once per model, not per layer


def attention(params, cfg, x, positions, *, cache: Optional[KVCache] = None,
              cache_index=None, window_override: Optional[int] = None):
    """Self-attention forward.

    Train/prefill: ``cache is None`` -> returns (out, new_cache_or_None).
    Decode: ``cache`` given, x is (B, 1, d); returns (out, updated_cache).
    """
    B, T, _ = x.shape
    hd = cfg.resolved_head_dim
    window = cfg.sliding_window if window_override is None else window_override
    q = hints.heads((x @ params["wq"]).reshape(B, T, cfg.n_heads, hd))
    k = hints.kv_heads((x @ params["wk"]).reshape(B, T, cfg.n_kv_heads, hd))
    v = hints.kv_heads((x @ params["wv"]).reshape(B, T, cfg.n_kv_heads, hd))
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if cache is None:
        out = attend(q, k, v, causal=True, window=window)
        new_cache = KVCache(k=k, v=v)
    else:
        S = cache.k.shape[1]
        idx = cache_index
        ck = jax.lax.dynamic_update_slice(cache.k, k, (0, idx, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache.v, v, (0, idx, 0, 0))
        kpos = jnp.arange(S)[None, :]
        valid = kpos <= idx
        if window:
            valid &= kpos > idx - window
        valid = jnp.broadcast_to(valid, (B, S))
        out = attend(q, ck, cv, causal=False, kv_len_mask=valid,
                     force_chunked=False)
        new_cache = KVCache(k=ck, v=cv)
    out = out.reshape(B, T, cfg.n_heads * hd) @ params["wo"]
    return out, new_cache


def init_cache(cfg, batch, seq_len, dtype=jnp.float32):
    hd = cfg.resolved_head_dim
    shape = (batch, seq_len, cfg.n_kv_heads, hd)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


# -------------------------------------------------- cross attention (whisper)

def init_cross_attention(key, cfg, dtype=jnp.float32):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, cfg.n_heads * hd, dtype),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * hd, d, dtype),
    }


def cross_attention(params, cfg, x, enc_out):
    """x: (B, T, d) decoder states; enc_out: (B, Tsrc, d)."""
    B, T, _ = x.shape
    Ts = enc_out.shape[1]
    hd = cfg.resolved_head_dim
    q = (x @ params["wq"]).reshape(B, T, cfg.n_heads, hd)
    k = (enc_out @ params["wk"]).reshape(B, Ts, cfg.n_kv_heads, hd)
    v = (enc_out @ params["wv"]).reshape(B, Ts, cfg.n_kv_heads, hd)
    out = attend(q, k, v, causal=False, force_chunked=False)
    return out.reshape(B, T, cfg.n_heads * hd) @ params["wo"]

"""Mamba-style selective SSM (Jamba mixer layers).

TPU adaptation: the CUDA selective-scan kernel is replaced by a **chunked
first-order linear recurrence** — ``lax.scan`` over sequence chunks with a
``lax.associative_scan`` inside each chunk. This bounds the materialised
(T, d_inner, d_state) tensor to one chunk (VMEM-friendly) while keeping the
cross-chunk dependency exact, and it lowers on any backend.

Decode is the O(1) recurrent step on a carried (state, conv window) cache.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .layers import causal_conv1d, dense_init, init_causal_conv1d

SCAN_CHUNK = 128


class MambaCache(NamedTuple):
    h: jax.Array             # (B, d_inner, d_state)
    conv: jax.Array          # (B, d_conv-1, d_inner) trailing inputs


def dt_rank(cfg) -> int:
    return cfg.ssm.dt_rank if cfg.ssm.dt_rank else -(-cfg.d_model // 16)


def init_mamba(key, cfg, dtype=jnp.float32):
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    dtr = dt_rank(cfg)
    ks = jax.random.split(key, 6)
    # S4D-real initialisation for A
    A = jnp.tile(jnp.arange(1, s.d_state + 1, dtype=jnp.float32)[None, :],
                 (di, 1))
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype),
        "conv": init_causal_conv1d(ks[1], di, s.d_conv, dtype),
        "x_proj": dense_init(ks[2], di, dtr + 2 * s.d_state, dtype),
        "dt_proj": dense_init(ks[3], dtr, di, dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jnp.exp(jax.random.uniform(ks[4], (di,), jnp.float32)
                             * (math.log(0.1) - math.log(1e-3))
                             + math.log(1e-3)), 1e-4, None))).astype(jnp.float32),
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], di, d, dtype),
    }


def _linear_recurrence_chunked(decay, inp, h0, chunk=SCAN_CHUNK):
    """h_t = decay_t * h_{t-1} + inp_t, over axis 1 of (B, T, di, N).

    Returns (hs (B,T,di,N), h_last). Chunked: O(chunk) live memory.
    """
    B, T, di, N = decay.shape
    n_chunks = -(-T // chunk)
    pad = n_chunks * chunk - T
    if pad:
        decay = jnp.pad(decay, ((0, 0), (0, pad), (0, 0), (0, 0)),
                        constant_values=1.0)
        inp = jnp.pad(inp, ((0, 0), (0, pad), (0, 0), (0, 0)))
    dc = decay.reshape(B, n_chunks, chunk, di, N).transpose(1, 0, 2, 3, 4)
    ic = inp.reshape(B, n_chunks, chunk, di, N).transpose(1, 0, 2, 3, 4)

    def combine(a, b):
        (a1, b1), (a2, b2) = a, b
        return a1 * a2, b1 * a2 + b2

    def step(h, xs):
        d, i = xs                                     # (B, chunk, di, N)
        pa, pb = jax.lax.associative_scan(combine, (d, i), axis=1)
        hs = pa * h[:, None] + pb                     # (B, chunk, di, N)
        return hs[:, -1], hs

    h_last, hs = jax.lax.scan(step, h0, (dc, ic))
    hs = hs.transpose(1, 0, 2, 3, 4).reshape(B, n_chunks * chunk, di, N)
    return hs[:, :T], h_last


def _selective_scan_fused(decay, inp, Cmat, h0, chunk=SCAN_CHUNK):
    """Fused recurrence + output contraction (§Perf iteration 4).

    Emits y_t = <h_t, C_t> per chunk WITHOUT materializing the full
    (B, T, di, N) state history — only one (B, chunk, di, N) block is live
    per step, and the scan body is rematerialized in the backward pass.
    This is the memory-decisive formulation for Mamba training at 4k+
    sequence lengths (the naive version writes T/chunk x chunk x di x N
    floats to HBM per layer).

    decay/inp: (B, T, di, N); Cmat: (B, T, N). Returns (y (B,T,di), h_last).
    """
    B, T, di, N = decay.shape
    n_chunks = -(-T // chunk)
    pad = n_chunks * chunk - T
    if pad:
        decay = jnp.pad(decay, ((0, 0), (0, pad), (0, 0), (0, 0)),
                        constant_values=1.0)
        inp = jnp.pad(inp, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
    dc = decay.reshape(B, n_chunks, chunk, di, N).transpose(1, 0, 2, 3, 4)
    ic = inp.reshape(B, n_chunks, chunk, di, N).transpose(1, 0, 2, 3, 4)
    cc = Cmat.reshape(B, n_chunks, chunk, N).transpose(1, 0, 2, 3)

    def combine(a, b):
        (a1, b1), (a2, b2) = a, b
        return a1 * a2, b1 * a2 + b2

    @jax.checkpoint
    def step(h, xs):
        d, i, c = xs
        pa, pb = jax.lax.associative_scan(combine, (d, i), axis=1)
        hs = pa * h[:, None] + pb                     # (B, chunk, di, N)
        y = jnp.einsum("btdn,btn->btd", hs, c)        # fused contraction
        return hs[:, -1], y

    h_last, ys = jax.lax.scan(step, h0, (dc, ic, cc))
    y = ys.transpose(1, 0, 2, 3).reshape(B, n_chunks * chunk, di)
    return y[:, :T], h_last


def mamba(params, cfg, x, *, cache: Optional[MambaCache] = None,
          cache_index=None):
    """x: (B, T, d). Train/prefill when cache is None; decode step otherwise."""
    s = cfg.ssm
    B, T, d = x.shape
    di = s.expand * d
    dtr = dt_rank(cfg)

    xz = x @ params["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)                 # (B, T, di) each

    if cache is None:
        xc = causal_conv1d(params["conv"], xs)
        conv_tail = xs[:, -(s.d_conv - 1):, :] if T >= s.d_conv - 1 else jnp.pad(
            xs, ((0, 0), (s.d_conv - 1 - T, 0), (0, 0)))
    else:
        # decode: prepend cached window
        xfull = jnp.concatenate([cache.conv, xs], axis=1)
        k = params["conv"]["kernel"]                  # (K, di)
        xc = jnp.einsum("bkc,kc->bc", xfull[:, -s.d_conv:], k)[:, None, :]
        conv_tail = xfull[:, -(s.d_conv - 1):, :]
    xc = jax.nn.silu(xc)

    proj = xc @ params["x_proj"]                      # (B, T, dtr+2N)
    dt_in = proj[..., :dtr]
    Bmat = proj[..., dtr:dtr + s.d_state]
    Cmat = proj[..., dtr + s.d_state:]
    dt = jax.nn.softplus(dt_in @ params["dt_proj"]
                         + params["dt_bias"]).astype(jnp.float32)
    A = -jnp.exp(params["A_log"])                     # (di, N)

    decay = jnp.exp(dt[..., None] * A)                # (B, T, di, N)
    inp = (dt * xc.astype(jnp.float32))[..., None] * Bmat.astype(
        jnp.float32)[:, :, None, :]

    h0 = (jnp.zeros((B, di, s.d_state), jnp.float32) if cache is None
          else cache.h)
    if cache is None and T > 1:
        # fused scan: y emitted per chunk, full (B,T,di,N) state history
        # never materialized (§Perf iteration 4)
        y, h_last = _selective_scan_fused(decay, inp,
                                          Cmat.astype(jnp.float32), h0)
    else:
        h_last = decay[:, 0] * h0 + inp[:, 0]
        hs = h_last[:, None]
        y = jnp.einsum("btdn,btn->btd", hs, Cmat.astype(jnp.float32))
    y = y + params["D"] * xc.astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ params["out_proj"]
    new_cache = MambaCache(h=h_last, conv=conv_tail)
    return out, new_cache


def init_mamba_cache(cfg, batch, dtype=jnp.float32):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    return MambaCache(
        h=jnp.zeros((batch, di, s.d_state), jnp.float32),
        conv=jnp.zeros((batch, s.d_conv - 1, di), dtype))

"""Mixture-of-Experts: top-k router + sort-based capacity dispatch.

Dispatch strategy (TPU-adapted, GShard-capacity semantics without the
O(tokens x experts x capacity) one-hot):

  1. top-k routing -> (token, expert) assignment list of length N*k,
  2. position-in-expert via a single argsort over expert ids (O(Nk log Nk)
     instead of an (Nk, E) cumsum tensor),
  3. scatter tokens into a dense (E, C, d) buffer (capacity-dropped),
  4. batched expert matmul via einsum over the leading expert axis — this is
     the axis sharded over 'model' (expert parallelism); XLA SPMD turns the
     scatter/gather into the all-to-all,
  5. gather back and combine with gate weights.

Aux losses: switch-style load-balance + router z-loss.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import act_fn, dense_init


class MoEOut(NamedTuple):
    y: jax.Array
    aux_loss: jax.Array


def init_moe(key, cfg, dtype=jnp.float32):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {"router": dense_init(ks[0], d, m.n_experts, jnp.float32)}
    def expert_stack(k, d_in, d_out):
        return jax.random.uniform(
            k, (m.n_experts, d_in, d_out), dtype,
            -1.0 / jnp.sqrt(d_in), 1.0 / jnp.sqrt(d_in))
    p["experts"] = {
        "wi": expert_stack(ks[1], d, m.d_ff_expert),
        "wg": expert_stack(ks[2], d, m.d_ff_expert),
        "wo": expert_stack(ks[3], m.d_ff_expert, d),
    }
    if m.n_shared_experts:
        ff_sh = m.n_shared_experts * m.d_ff_expert
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wi": dense_init(k1, d, ff_sh, dtype),
            "wg": dense_init(k2, d, ff_sh, dtype),
            "wo": dense_init(k3, ff_sh, d, dtype),
        }
    return p


def router_topk(logits, k, scoring="softmax"):
    """logits (N, E) fp32 -> (gate (N,k), idx (N,k), probs (N,E))."""
    if scoring == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        gate, idx = jax.lax.top_k(scores, k)
        gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)
        probs = scores / jnp.maximum(jnp.sum(scores, -1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        gate, idx = jax.lax.top_k(probs, k)
        gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)
    return gate, idx, probs


def load_balance_loss(probs, idx, n_experts):
    """Switch-Transformer aux: E * sum_e f_e * P_e."""
    N, k = idx.shape
    # fraction of assignments to each expert (counts over N*k)
    counts = jnp.zeros((n_experts,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    f = counts / (N * k)
    P = jnp.mean(probs, axis=0)
    return n_experts * jnp.sum(f * P)


def positions_in_expert(expert_ids, n_experts):
    """Rank of each assignment within its expert, via one argsort.

    expert_ids: (A,) int32. Returns (A,) int32 positions.
    """
    A = expert_ids.shape[0]
    order = jnp.argsort(expert_ids)                    # stable
    sorted_ids = expert_ids[order]
    counts = jnp.zeros((n_experts,), jnp.int32).at[expert_ids].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    pos_sorted = jnp.arange(A, dtype=jnp.int32) - starts[sorted_ids]
    return jnp.zeros((A,), jnp.int32).at[order].set(pos_sorted)


def _moe_shardmap(params, cfg, x, mesh, dp_axes, activation) -> MoEOut:
    """Expert-parallel MoE via shard_map (§Perf iteration 2c).

    Key observation: the residual stream is sharded over the data axes and
    REPLICATED over 'model', while experts are sharded over 'model'. So no
    token ever needs to move: each model shard routes its (replicated)
    token block, keeps only assignments to its own E/TP experts, runs the
    expert matmuls locally, and the combine is ONE psum of (tokens, d)
    partial outputs over 'model'. Collective cost per layer = the psum
    (~tokens x d), versus the full dispatch-buffer all-reduce XLA emits
    for the scatter formulation (measured 18.8-37.6 GB/op on DeepSeek).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    B, T, d = x.shape
    tp = mesh.shape["model"]
    e_loc = m.n_experts // tp
    a = act_fn(activation)
    k = m.n_experts_per_tok

    def body(xb, router, wi, wg, wo):
        # xb: (B_loc, T, d) — this dp shard's tokens (same for all model j)
        n = xb.shape[0] * xb.shape[1]
        xf = xb.reshape(n, d)
        logits = (xf.astype(jnp.float32) @ router).astype(jnp.float32)
        gate, idx, probs = router_topk(logits, k, m.router_scoring)
        aux = m.router_aux_coef * load_balance_loss(probs, idx, m.n_experts)
        aux = aux + 1e-3 * jnp.mean(
            jnp.square(jax.nn.logsumexp(logits, axis=-1)))
        aux = jax.lax.pmean(aux, dp_axes)

        j = jax.lax.axis_index("model")
        e_lo = j * e_loc
        A = n * k
        expert_ids = idx.reshape(A)
        gates = gate.reshape(A)
        token_ids = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
        local_e = expert_ids - e_lo
        mine = (local_e >= 0) & (local_e < e_loc)
        if xb.shape[1] == 1:
            C = A
        else:
            C = max(k, int(round(A * m.capacity_factor / m.n_experts)))
        seg = jnp.where(mine, local_e, e_loc)        # e_loc = discard bucket
        pos = positions_in_expert(seg, e_loc + 1)
        keep = mine & (pos < C)
        slot = jnp.where(keep, seg * C + pos, e_loc * C)
        updates = xf[token_ids] * keep[:, None].astype(xf.dtype)
        buf = jnp.zeros((e_loc * C + 1, d), xf.dtype).at[slot].add(updates)
        bufe = buf[: e_loc * C].reshape(e_loc, C, d)
        h = a(jnp.einsum("ecd,edf->ecf", bufe, wi)) * jnp.einsum(
            "ecd,edf->ecf", bufe, wg)
        out_buf = jnp.einsum("ecf,efd->ecd", h, wo).reshape(e_loc * C, d)
        out_buf = jnp.concatenate(
            [out_buf, jnp.zeros((1, d), out_buf.dtype)])
        gathered = out_buf[slot] * (gates * keep).astype(xf.dtype)[:, None]
        y = jnp.sum(gathered.reshape(n, k, d), axis=1)
        y = jax.lax.psum(y, "model")                 # combine across experts
        return y.reshape(xb.shape), aux

    e = params["experts"]
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp_axes, None, None), P(), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=(P(dp_axes, None, None), P()),
        check_rep=False)
    y, aux = fn(x, params["router"], e["wi"], e["wg"], e["wo"])

    if "shared" in params:
        s = params["shared"]
        xf = x.reshape(-1, d)
        from repro import hints
        hdn = hints.ffn_hidden((a(xf @ s["wi"]) * (xf @ s["wg"])
                                ).reshape(B, T, -1)).reshape(B * T, -1)
        y = y + (hdn @ s["wo"]).reshape(B, T, d)
    return MoEOut(y=y, aux_loss=aux)


def moe_apply(params, cfg, x, *, activation="silu") -> MoEOut:
    """x: (B, T, d) -> (B, T, d), aux_loss scalar.

    Two dispatch layouts (cfg.moe.dispatch):
      * "flat"     — (E*C, d) buffer, E on 'model'. Simple; under SPMD the
        token->buffer scatter lowers to replicate+all-reduce of the whole
        buffer (expensive at DeepSeek scale).
      * "bucketed" — (S, E, C_loc, d) buffer with a leading source-data-
        shard dim. Tokens are contiguous per dp shard, so each shard's
        scatter is local; the dp->model exchange moves only real token
        payloads (all-to-all-sized). §Perf iteration 2b.
    """
    m = cfg.moe
    B, T, d = x.shape
    N = B * T
    xf = x.reshape(N, d)
    a = act_fn(activation)

    logits = (xf.astype(jnp.float32) @ params["router"]).astype(jnp.float32)
    gate, idx, probs = router_topk(logits, m.n_experts_per_tok, m.router_scoring)
    aux = m.router_aux_coef * load_balance_loss(probs, idx, m.n_experts)
    aux = aux + 1e-3 * jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))

    k = m.n_experts_per_tok
    A = N * k
    expert_ids = idx.reshape(A)
    gates = gate.reshape(A)
    token_ids = jnp.repeat(jnp.arange(N, dtype=jnp.int32), k)

    from repro import hints
    e = params["experts"]

    st = hints._state()
    if m.dispatch == "shardmap" and st is not None and T > 1:
        # T == 1 (decode) stays on the flat path: the shard_map in_specs
        # re-gather FSDP'd expert weights EVERY step, which dwarfs the
        # one-token dispatch it saves (measured 2.7x collective regression
        # on deepseek decode_32k).
        mesh, dp_axes = st
        tp = mesh.shape.get("model", 1)
        dpsz = hints.dp_size()
        if (tp > 1 and m.n_experts % tp == 0 and B % dpsz == 0):
            return _moe_shardmap(params, cfg, x, mesh, dp_axes, activation)

    if m.dispatch == "bucketed" and hints.dp_size() > 1 \
            and N % hints.dp_size() == 0:
        S = hints.dp_size()
        n_loc = N // S                       # tokens per data shard
        C = max(1, int(round(A * m.capacity_factor / (m.n_experts * S))))
        shard_of = token_ids // n_loc        # (A,) source shard
        # rank within the (shard, expert) segment
        seg = shard_of * m.n_experts + expert_ids
        pos = positions_in_expert(seg, S * m.n_experts)
        keep = pos < C
        slot = jnp.where(keep, seg * C + pos, 0)
        updates = xf[token_ids] * keep[:, None].astype(xf.dtype)
        buf = jnp.zeros((S * m.n_experts * C, d), xf.dtype
                        ).at[slot].add(updates)
        buf = hints.expert_buffer_bucketed(
            buf.reshape(S, m.n_experts, C, d))
        # expert-major view: the (S@data -> E@model) transpose is the a2a
        bufe = hints.expert_buffer(
            buf.transpose(1, 0, 2, 3).reshape(m.n_experts, S * C, d))
        h = a(jnp.einsum("ecd,edf->ecf", bufe, e["wi"])) * jnp.einsum(
            "ecd,edf->ecf", bufe, e["wg"])
        out_e = jnp.einsum("ecf,efd->ecd", h, e["wo"])
        out_buf = hints.expert_buffer_bucketed(
            out_e.reshape(m.n_experts, S, C, d).transpose(1, 0, 2, 3)
        ).reshape(S * m.n_experts * C, d)
    else:
        # floor at top-k so tiny batches keep all first choices; decode
        # (T == 1) runs DROPLESS so single-token outputs match the
        # teacher-forced path exactly (capacity drops are a train-time
        # throughput trade, not a serving semantic)
        if T == 1:
            C = A
        else:
            C = max(k, int(round(A * m.capacity_factor / m.n_experts)))
        pos = positions_in_expert(expert_ids, m.n_experts)
        keep = pos < C
        slot = jnp.where(keep, expert_ids * C + pos, 0)
        # dispatch: scatter token features into (E*C, d) expert buffers
        updates = xf[token_ids] * keep[:, None].astype(xf.dtype)
        buf = jnp.zeros((m.n_experts * C, d), xf.dtype).at[slot].add(updates)
        buf = hints.expert_buffer(buf.reshape(m.n_experts, C, d))
        # batched expert matmuls (expert axis -> 'model' sharding)
        h = a(jnp.einsum("ecd,edf->ecf", buf, e["wi"])) * jnp.einsum(
            "ecd,edf->ecf", buf, e["wg"])
        out_buf = jnp.einsum("ecf,efd->ecd", h,
                             e["wo"]).reshape(m.n_experts * C, d)

    # combine: gather back, gate, sum over k slots per token
    gathered = out_buf[slot] * (gates * keep).astype(xf.dtype)[:, None]
    y = jnp.sum(gathered.reshape(N, k, d), axis=1)

    if "shared" in params:
        s = params["shared"]
        y = y + (a(xf @ s["wi"]) * (xf @ s["wg"])) @ s["wo"]
    return MoEOut(y=y.reshape(B, T, d), aux_loss=aux)

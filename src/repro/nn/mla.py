"""Multi-head Latent Attention (DeepSeek-V2/V3, MiniCPM3).

KV is compressed into a per-token latent ``c_kv`` of rank ``kv_lora_rank``
plus a single shared RoPE key of ``qk_rope_head_dim``; the decode cache stores
only ``(c_kv, k_rope)`` — this is the paper-family's KV-cache compression and
maps naturally onto OCTOPUS-style latent transmission.

Two attention paths:
  * train/prefill — latents are expanded through ``wkv_b`` and fed to the
    shared chunked/full attention core.
  * decode — **absorbed** form: ``wkv_b`` is folded into the query/output
    projections so attention runs directly in the rank-``kv_lora`` latent
    space; the S-long cache is never expanded.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .attention import apply_rope, attend
from .layers import dense_init, init_rmsnorm, rmsnorm


class MLACache(NamedTuple):
    c_kv: jax.Array          # (B, S, kv_lora_rank)
    k_rope: jax.Array        # (B, S, qk_rope_head_dim)


def init_mla(key, cfg, dtype=jnp.float32):
    m = cfg.mla
    d, nq = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    p = {}
    if m.q_lora_rank:
        p["wq_a"] = dense_init(ks[0], d, m.q_lora_rank, dtype)
        p["q_norm"] = init_rmsnorm(m.q_lora_rank, dtype)
        p["wq_b"] = dense_init(ks[1], m.q_lora_rank, nq * m.qk_head_dim, dtype)
    else:
        p["wq"] = dense_init(ks[0], d, nq * m.qk_head_dim, dtype)
    p["wkv_a"] = dense_init(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim, dtype)
    p["kv_norm"] = init_rmsnorm(m.kv_lora_rank, dtype)
    p["wkv_b"] = dense_init(
        ks[3], m.kv_lora_rank, nq * (m.qk_nope_head_dim + m.v_head_dim), dtype)
    p["wo"] = dense_init(ks[4], nq * m.v_head_dim, d, dtype)
    return p


def _queries(params, cfg, x):
    m = cfg.mla
    B, T, _ = x.shape
    if m.q_lora_rank:
        q = rmsnorm(params["q_norm"], x @ params["wq_a"]) @ params["wq_b"]
    else:
        q = x @ params["wq"]
    from repro import hints
    q = hints.heads(q.reshape(B, T, cfg.n_heads, m.qk_head_dim))
    return q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]


def _latents(params, cfg, x, positions):
    m = cfg.mla
    ckr = x @ params["wkv_a"]
    c_kv = rmsnorm(params["kv_norm"], ckr[..., : m.kv_lora_rank])
    k_rope = ckr[..., m.kv_lora_rank:]
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_attention(params, cfg, x, positions, *, cache: Optional[MLACache] = None,
                  cache_index=None):
    m = cfg.mla
    B, T, _ = x.shape
    nq = cfg.n_heads
    q_nope, q_rope = _queries(params, cfg, x)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    c_kv, k_rope = _latents(params, cfg, x, positions)

    if cache is None:
        # expanded path: standard attention with qk_head_dim keys
        kv = (c_kv @ params["wkv_b"]).reshape(
            B, T, nq, m.qk_nope_head_dim + m.v_head_dim)
        k_nope, v = kv[..., : m.qk_nope_head_dim], kv[..., m.qk_nope_head_dim:]
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (B, T, nq, m.qk_rope_head_dim))], axis=-1)
        out = attend(q, k, v, causal=True)
        new_cache = MLACache(c_kv=c_kv, k_rope=k_rope)
    else:
        # absorbed decode: attention in latent space, cache never expanded
        S = cache.c_kv.shape[1]
        idx = cache_index
        cc = jax.lax.dynamic_update_slice(cache.c_kv, c_kv, (0, idx, 0))
        cr = jax.lax.dynamic_update_slice(cache.k_rope, k_rope, (0, idx, 0))
        w_b = params["wkv_b"].reshape(
            m.kv_lora_rank, nq, m.qk_nope_head_dim + m.v_head_dim)
        w_kb = w_b[..., : m.qk_nope_head_dim]      # (L, H, dn)
        w_vb = w_b[..., m.qk_nope_head_dim:]       # (L, H, dv)
        q_lat = jnp.einsum("bthn,lhn->bthl", q_nope.astype(jnp.float32),
                           w_kb.astype(jnp.float32))
        scores = (jnp.einsum("bthl,bsl->bhts", q_lat, cc.astype(jnp.float32))
                  + jnp.einsum("bthr,bsr->bhts", q_rope.astype(jnp.float32),
                               cr.astype(jnp.float32)))
        scores = scores / jnp.sqrt(jnp.array(m.qk_head_dim, jnp.float32))
        valid = jnp.arange(S) <= idx
        scores = jnp.where(valid[None, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        out_lat = jnp.einsum("bhts,bsl->bthl", probs, cc.astype(jnp.float32))
        out = jnp.einsum("bthl,lhv->bthv", out_lat,
                         w_vb.astype(jnp.float32)).astype(x.dtype)
        new_cache = MLACache(c_kv=cc, k_rope=cr)

    out = out.reshape(B, T, nq * m.v_head_dim) @ params["wo"]
    return out, new_cache


def init_mla_cache(cfg, batch, seq_len, dtype=jnp.float32):
    m = cfg.mla
    return MLACache(
        c_kv=jnp.zeros((batch, seq_len, m.kv_lora_rank), dtype),
        k_rope=jnp.zeros((batch, seq_len, m.qk_rope_head_dim), dtype))

"""Base layers: functional, pytree-parameterised.

Convention: every layer is an ``init_*(key, ...) -> params`` plus a pure
``apply`` function. Params are nested dicts of jnp arrays so they pjit/scan
cleanly; logical sharding is attached later by ``repro.distributed.sharding``
based on param-path names, so names here are part of the sharding contract:

  ``emb``      (vocab, d)          -> vocab-sharded
  ``wq|wk|wv|wi|wg|w_up``          -> column-parallel (last dim on 'model')
  ``wo|w_down``                    -> row-parallel (first dim on 'model')
  ``experts/*``                    -> expert axis on 'model'
  ``scale|bias``                   -> replicated
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def uniform_init(key, shape, scale, dtype):
    return jax.random.uniform(key, shape, dtype, -scale, scale)


def dense_init(key, d_in, d_out, dtype=jnp.float32, name_scale: float = 1.0):
    scale = name_scale / math.sqrt(d_in)
    return uniform_init(key, (d_in, d_out), scale, dtype)


def embed_init(key, vocab, d, dtype=jnp.float32):
    return jax.random.normal(key, (vocab, d), dtype) * 0.02


# ---------------------------------------------------------------- norms

def init_rmsnorm(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    out = out * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(dt)


def init_norm(kind, d, dtype=jnp.float32):
    return init_rmsnorm(d, dtype) if kind == "rmsnorm" else init_layernorm(d, dtype)


def apply_norm(kind, params, x, eps=1e-6):
    return rmsnorm(params, x, eps) if kind == "rmsnorm" else layernorm(params, x, eps)


def instance_norm_2d(x, gamma=None, beta=None, eps=1e-5):
    """InstanceNorm over spatial dims of NHWC input (OCTOPUS Eq. 4).

    Normalizes each (instance, channel) independently across H, W — the
    paper's style-normalization/disentanglement primitive.
    """
    mu = jnp.mean(x, axis=(1, 2), keepdims=True)
    sigma = jnp.sqrt(jnp.var(x, axis=(1, 2), keepdims=True) + eps)
    out = (x - mu) / sigma
    if gamma is not None:
        out = out * gamma
    if beta is not None:
        out = out + beta
    return out


def instance_norm_1d(x, gamma=None, beta=None, eps=1e-5):
    """InstanceNorm over the time dim of NTC input (speech path)."""
    mu = jnp.mean(x, axis=1, keepdims=True)
    sigma = jnp.sqrt(jnp.var(x, axis=1, keepdims=True) + eps)
    out = (x - mu) / sigma
    if gamma is not None:
        out = out * gamma
    if beta is not None:
        out = out + beta
    return out


# ---------------------------------------------------------------- activations

def act_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    if name == "relu":
        return jax.nn.relu
    raise ValueError(f"unknown activation {name}")


# ---------------------------------------------------------------- gated MLP

def init_mlp(key, d_model, d_ff, dtype=jnp.float32):
    """SwiGLU/GeGLU gated MLP: wi (gate), wg (up), wo (down)."""
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": dense_init(k1, d_model, d_ff, dtype),
        "wg": dense_init(k2, d_model, d_ff, dtype),
        "wo": dense_init(k3, d_ff, d_model, dtype),
    }


def mlp(params, x, activation="silu"):
    from repro import hints
    a = act_fn(activation)
    h = a(x @ params["wi"]) * (x @ params["wg"])
    h = hints.ffn_hidden(h)
    return h @ params["wo"]


# ---------------------------------------------------------------- conv (DVQ-AE / frontends)

def init_conv2d(key, c_in, c_out, ksize, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(c_in * ksize * ksize)
    k1, k2 = jax.random.split(key)
    return {
        "kernel": uniform_init(k1, (ksize, ksize, c_in, c_out), scale, dtype),
        "bias": jnp.zeros((c_out,), dtype),
    }


def conv2d(params, x, stride=1, padding="SAME"):
    """NHWC conv."""
    y = jax.lax.conv_general_dilated(
        x, params["kernel"], window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + params["bias"]


def init_conv2d_transpose(key, c_in, c_out, ksize, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(c_in * ksize * ksize)
    k1, k2 = jax.random.split(key)
    return {
        "kernel": uniform_init(k1, (ksize, ksize, c_in, c_out), scale, dtype),
        "bias": jnp.zeros((c_out,), dtype),
    }


def conv2d_transpose(params, x, stride=2, padding="SAME"):
    y = jax.lax.conv_transpose(
        x, params["kernel"], strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + params["bias"]


def init_conv1d(key, c_in, c_out, ksize, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(c_in * ksize)
    return {
        "kernel": uniform_init(key, (ksize, c_in, c_out), scale, dtype),
        "bias": jnp.zeros((c_out,), dtype),
    }


def conv1d(params, x, stride=1, padding="SAME"):
    """NTC conv."""
    y = jax.lax.conv_general_dilated(
        x, params["kernel"], window_strides=(stride,), padding=padding,
        dimension_numbers=("NHC", "HIO", "NHC"))
    return y + params["bias"]


def causal_conv1d(params, x):
    """Causal depthwise-ish conv used by Mamba/mLSTM blocks.

    x: (B, T, C); params['kernel']: (K, C) depthwise weights.
    """
    k = params["kernel"]          # (K, C)
    K = k.shape[0]
    xpad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    # depthwise conv as feature-group conv
    y = jax.lax.conv_general_dilated(
        xpad, k[:, None, :], window_strides=(1,), padding="VALID",
        dimension_numbers=("NHC", "HIO", "NHC"),
        feature_group_count=x.shape[-1])
    return y


def init_causal_conv1d(key, channels, ksize, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(ksize)
    return {"kernel": uniform_init(key, (ksize, channels), scale, dtype)}

"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, sequential) — arXiv:2405.04517.

TPU adaptation notes:
  * mLSTM training uses the **chunkwise-parallel** form (linear-attention
    style): ``lax.scan`` over chunks carrying (C, n, m) inter-chunk state,
    quadratic-but-tiny intra-chunk weights. Exact stabilised exponential
    gating (running max ``m``) as in the paper's Appendix.
  * sLSTM has a true sequential dependency (recurrent R weights); it runs as
    a ``lax.scan`` over time. The paper notes this is intentionally
    non-parallelisable; we keep it and bound its cost by placing sLSTM on
    every ``slstm_every``-th layer only.
  * Decode for both is an O(1) state update.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .layers import causal_conv1d, dense_init, init_causal_conv1d

MLSTM_CHUNK = 128
NH = 4                       # assigned config: 4 heads


class MLSTMCache(NamedTuple):
    C: jax.Array             # (B, NH, DH, DH)
    n: jax.Array             # (B, NH, DH)
    m: jax.Array             # (B, NH)
    conv: jax.Array          # (B, K-1, di)


class SLSTMCache(NamedTuple):
    c: jax.Array             # (B, d)
    n: jax.Array             # (B, d)
    h: jax.Array             # (B, d)
    m: jax.Array             # (B, d)


# ================================================================= mLSTM

def init_mlstm(key, cfg, dtype=jnp.float32):
    x = cfg.xlstm
    d = cfg.d_model
    di = int(x.proj_factor * d)
    ks = jax.random.split(key, 8)
    return {
        "up_proj": dense_init(ks[0], d, 2 * di, dtype),
        "conv": init_causal_conv1d(ks[1], di, x.conv_dim, dtype),
        "wq": dense_init(ks[2], di, di, dtype),
        "wk": dense_init(ks[3], di, di, dtype),
        "wv": dense_init(ks[4], di, di, dtype),
        "w_if": dense_init(ks[5], di, 2 * NH, jnp.float32),
        "skip_scale": jnp.ones((di,), dtype),
        "down_proj": dense_init(ks[6], di, d, dtype),
    }


def _mlstm_chunk(q, k, v, ig, lf, C_in, n_in, m_in):
    """One chunk of stabilised mLSTM.

    q,k,v: (B,NH,L,DH); ig: (B,NH,L) log input gate; lf: (B,NH,L) log forget.
    Carry: C (B,NH,DH,DH), n (B,NH,DH), m (B,NH).
    """
    B, H, L, DH = q.shape
    scale = 1.0 / jnp.sqrt(jnp.array(DH, jnp.float32))
    b = jnp.cumsum(lf, axis=-1)                        # (B,H,L) inclusive
    # intra-chunk log weights: g[t,s] = b_t - b_s + ig_s  (s <= t)
    g = b[..., :, None] - b[..., None, :] + ig[..., None, :]
    tri = jnp.tril(jnp.ones((L, L), bool))
    g = jnp.where(tri, g, -jnp.inf)
    # stabiliser per target step
    m_intra = jnp.max(g, axis=-1)                      # (B,H,L)
    m_t = jnp.maximum(m_in[..., None] + b, m_intra)    # (B,H,L)
    w = jnp.exp(g - m_t[..., None])                    # (B,H,L,L)
    qk = jnp.einsum("bhld,bhsd->bhls", q, k) * scale
    h_intra = jnp.einsum("bhls,bhsd->bhld", w * qk, v)
    denom_intra = jnp.einsum("bhls,bhsd->bhld", w, k)
    inter_scale = jnp.exp(m_in[..., None] + b - m_t)   # (B,H,L)
    h_inter = jnp.einsum("bhld,bhde->bhle", q * scale, C_in) \
        * inter_scale[..., None]
    denom = jnp.einsum("bhld,bhd->bhl", q * scale, n_in) * inter_scale \
        + jnp.einsum("bhld,bhld->bhl", q, denom_intra)
    h = (h_intra + h_inter) / jnp.maximum(
        jnp.abs(denom), jnp.exp(-m_t))[..., None]
    # chunk-end state
    bL = b[..., -1:]                                   # (B,H,1)
    m_out = jnp.maximum(m_in + bL[..., 0],
                        jnp.max(bL - b + ig, axis=-1))
    wk_end = jnp.exp(bL - b + ig - m_out[..., None])   # (B,H,L)
    C_out = (jnp.exp(m_in + bL[..., 0] - m_out)[..., None, None] * C_in
             + jnp.einsum("bhl,bhld,bhle->bhde", wk_end, k, v))
    n_out = (jnp.exp(m_in + bL[..., 0] - m_out)[..., None] * n_in
             + jnp.einsum("bhl,bhld->bhd", wk_end, k))
    return h, C_out, n_out, m_out


def mlstm(params, cfg, x, *, cache: Optional[MLSTMCache] = None,
          cache_index=None, chunk=MLSTM_CHUNK):
    xc_cfg = cfg.xlstm
    B, T, d = x.shape
    di = int(xc_cfg.proj_factor * d)
    DH = di // NH
    up = x @ params["up_proj"]
    xb, z = jnp.split(up, 2, axis=-1)                 # (B,T,di)

    if cache is None:
        xconv = jax.nn.silu(causal_conv1d(params["conv"], xb))
        K = xc_cfg.conv_dim
        conv_tail = xb[:, -(K - 1):, :] if T >= K - 1 else jnp.pad(
            xb, ((0, 0), (K - 1 - T, 0), (0, 0)))
    else:
        K = xc_cfg.conv_dim
        xfull = jnp.concatenate([cache.conv, xb], axis=1)
        kern = params["conv"]["kernel"]
        xconv = jax.nn.silu(
            jnp.einsum("bkc,kc->bc", xfull[:, -K:], kern)[:, None, :])
        conv_tail = xfull[:, -(K - 1):, :]

    def heads(t):
        return t.reshape(B, -1, NH, DH).transpose(0, 2, 1, 3)
    q = heads(xconv @ params["wq"]).astype(jnp.float32)
    k = heads(xconv @ params["wk"]).astype(jnp.float32)
    v = heads(xconv @ params["wv"]).astype(jnp.float32)
    gates = (xconv @ params["w_if"]).astype(jnp.float32)  # (B,T,2NH)
    ig = gates[..., :NH].transpose(0, 2, 1)               # (B,NH,T) log-i
    lf = jax.nn.log_sigmoid(gates[..., NH:]).transpose(0, 2, 1)

    C0 = (jnp.zeros((B, NH, DH, DH), jnp.float32) if cache is None else cache.C)
    n0 = (jnp.zeros((B, NH, DH), jnp.float32) if cache is None else cache.n)
    m0 = (jnp.full((B, NH), -1e30, jnp.float32) if cache is None else cache.m)

    if T == 1:
        h, C1, n1, m1 = _mlstm_chunk(q, k, v, ig, lf, C0, n0, m0)
    else:
        n_chunks = -(-T // chunk)
        pad = n_chunks * chunk - T
        if pad:
            q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
            k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
            ig = jnp.pad(ig, ((0, 0), (0, 0), (0, pad)), constant_values=-1e30)
            lf = jnp.pad(lf, ((0, 0), (0, 0), (0, pad)))
        def to_chunks(t, extra=()):
            return t.reshape(*t.shape[:2], n_chunks, chunk,
                             *t.shape[3:]).transpose(2, 0, 1, 3, *range(4, t.ndim + 1))
        qs = to_chunks(q); ks_ = to_chunks(k); vs = to_chunks(v)
        igs = ig.reshape(B, NH, n_chunks, chunk).transpose(2, 0, 1, 3)
        lfs = lf.reshape(B, NH, n_chunks, chunk).transpose(2, 0, 1, 3)

        def step(carry, xs):
            C, n, m = carry
            qc, kc, vc, igc, lfc = xs
            h, C, n, m = _mlstm_chunk(qc, kc, vc, igc, lfc, C, n, m)
            return (C, n, m), h
        (C1, n1, m1), hs = jax.lax.scan(step, (C0, n0, m0),
                                        (qs, ks_, vs, igs, lfs))
        h = hs.transpose(1, 2, 0, 3, 4).reshape(B, NH, n_chunks * chunk, DH)
        h = h[:, :, :T]

    h = h.transpose(0, 2, 1, 3).reshape(B, -1, di).astype(x.dtype)
    h = h + params["skip_scale"] * xconv
    out = (h * jax.nn.silu(z)) @ params["down_proj"]
    return out, MLSTMCache(C=C1, n=n1, m=m1, conv=conv_tail)


def init_mlstm_cache(cfg, batch, dtype=jnp.float32):
    x = cfg.xlstm
    di = int(x.proj_factor * cfg.d_model)
    DH = di // NH
    return MLSTMCache(
        C=jnp.zeros((batch, NH, DH, DH), jnp.float32),
        n=jnp.zeros((batch, NH, DH), jnp.float32),
        m=jnp.full((batch, NH), -1e30, jnp.float32),
        conv=jnp.zeros((batch, x.conv_dim - 1, di), dtype))


# ================================================================= sLSTM

def init_slstm(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    DH = d // NH
    ks = jax.random.split(key, 4)
    ffd = int(cfg.xlstm.slstm_proj_factor * d)
    # recurrent weights are block-diagonal over heads: (NH, DH, 4*DH)
    r_scale = 1.0 / jnp.sqrt(jnp.array(DH, jnp.float32))
    return {
        "w_in": dense_init(ks[0], d, 4 * d, dtype),       # z,i,f,o pre-acts
        "r": jax.random.uniform(ks[1], (NH, DH, 4 * DH), jnp.float32,
                                -r_scale, r_scale),
        "bias": jnp.zeros((4 * d,), jnp.float32),
        "ffn_up": dense_init(ks[2], d, 2 * ffd, dtype),
        "ffn_down": dense_init(ks[3], ffd, d, dtype),
    }


def _slstm_step(params, d, carry, x_t):
    """x_t: (B, 4d) input pre-activations. carry: SLSTMCache arrays."""
    c, n, h, m = carry
    B = c.shape[0]
    DH = d // NH
    hh = h.reshape(B, NH, DH)
    rec = jnp.einsum("bhd,hde->bhe", hh, params["r"]).reshape(B, 4 * d)
    pre = x_t + rec + params["bias"]
    zp, ip, fp, op = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(zp)
    o = jax.nn.sigmoid(op)
    log_f = jax.nn.log_sigmoid(fp)
    m_new = jnp.maximum(log_f + m, ip)
    i = jnp.exp(ip - m_new)
    f = jnp.exp(log_f + m - m_new)
    c_new = f * c + i * z
    n_new = f * n + i
    h_new = o * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
    return (c_new, n_new, h_new, m_new), h_new


def slstm(params, cfg, x, *, cache: Optional[SLSTMCache] = None,
          cache_index=None):
    B, T, d = x.shape
    pre = (x @ params["w_in"]).astype(jnp.float32)    # (B,T,4d)
    if cache is None:
        carry0 = (jnp.zeros((B, d), jnp.float32),) * 3 + (
            jnp.full((B, d), -1e30, jnp.float32),)
    else:
        carry0 = (cache.c, cache.n, cache.h, cache.m)
    if T == 1:
        carry, h = _slstm_step(params, d, carry0, pre[:, 0])
        hs = h[:, None]
    else:
        carry, hs = jax.lax.scan(
            lambda cy, xt: _slstm_step(params, d, cy, xt),
            carry0, pre.transpose(1, 0, 2))
        hs = hs.transpose(1, 0, 2)
    hs = hs.astype(x.dtype)
    # GeGLU post-up/down projection (paper's post-sLSTM FFN)
    up = hs @ params["ffn_up"]
    a, b = jnp.split(up, 2, axis=-1)
    out = (jax.nn.gelu(a, approximate=True) * b) @ params["ffn_down"]
    new_cache = SLSTMCache(c=carry[0], n=carry[1], h=carry[2], m=carry[3])
    return out, new_cache


def init_slstm_cache(cfg, batch, dtype=jnp.float32):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SLSTMCache(c=z, n=z, h=z, m=jnp.full((batch, d), -1e30, jnp.float32))

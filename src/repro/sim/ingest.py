"""Server-side ingestion of packed client transmissions (Steps 4 -> 6).

Clients stream bit-packed code indices at high frequency; the server
does NOT train on every packet as it lands. ``IngestBuffer`` is the
middle tier: it accumulates the packed payloads (cheap — they stay
packed until needed), tracks the measured uplink byte count, and
materializes decoded features in bulk when downstream training
(core.downstream) wants a dataset or minibatches.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import octopus as OC
from repro.core.dvqae import DVQAEConfig
from .engine import PackedCodes


class IngestBuffer:
    """Accumulates rounds of packed transmissions for Step 6 training."""

    def __init__(self, cfg: DVQAEConfig):
        self.cfg = cfg
        self._rounds: List[PackedCodes] = []
        self._labels: List[Optional[jax.Array]] = []

    def __len__(self) -> int:
        return len(self._rounds)

    def add(self, packed: PackedCodes, labels=None) -> None:
        """Ingest one round's uplink. ``labels``: (C, B) or (C*B,) task
        labels riding alongside the codes (benchmark harness only — the
        real protocol ships codes)."""
        self._rounds.append(packed)
        self._labels.append(None if labels is None
                            else jnp.reshape(labels, (-1,)))

    @property
    def total_bytes(self) -> int:
        """Measured uplink bytes accumulated so far (§2.8 accounting)."""
        return sum(p.nbytes for p in self._rounds)

    @property
    def n_samples(self) -> int:
        return sum(p.shape[0] * p.shape[1] for p in self._rounds)

    # ------------------------------------------------------------- decode

    def codes(self) -> jax.Array:
        """Unpack every buffered round -> (sum_r C_r*B_r, T[, n_c]) int32."""
        if not self._rounds:
            raise ValueError("empty ingest buffer")
        parts = []
        for p in self._rounds:
            idx = p.unpack()
            parts.append(idx.reshape((-1,) + idx.shape[2:]))
        return jnp.concatenate(parts, axis=0)

    def labels(self) -> Optional[jax.Array]:
        if any(l is None for l in self._labels):
            return None
        return jnp.concatenate(self._labels, axis=0)

    def dataset(self, server: OC.ServerState
                ) -> Tuple[jax.Array, Optional[jax.Array]]:
        """Decode the whole buffer against the CURRENT global codebook:
        -> (features, labels) ready for core.downstream training."""
        feats = OC.codes_to_features(server, self.cfg, self.codes())
        return feats, self.labels()

    def batches(self, server: OC.ServerState, batch_size: int, *,
                key, steps: int):
        """Minibatch stream over the decoded buffer (Step 6 training)."""
        feats, labels = self.dataset(server)
        n = feats.shape[0]
        for i in range(steps):
            sel = jax.random.randint(jax.random.fold_in(key, i),
                                     (min(batch_size, n),), 0, n)
            yield feats[sel], None if labels is None else labels[sel]

    def train_probe(self, key, server: OC.ServerState, *, n_classes: int,
                    steps: int = 200, lr: float = 1e-3, batch: int = 64,
                    dataset=None):
        """Step 6: fit the paper's 3-linear-layer probe on the buffer.

        Pass ``dataset=(feats, labels)`` from a prior ``self.dataset``
        call to skip re-decoding the buffer.
        """
        from repro.core import downstream as DS
        feats, labels = dataset if dataset is not None \
            else self.dataset(server)
        if labels is None:
            raise ValueError("buffer has no labels to train on")
        probe = DS.init_linear_probe(key, int(feats[0].size), n_classes)
        return DS.sgd_train(key, DS.linear_probe, probe, feats, labels,
                            steps=steps, lr=lr, batch=batch)

"""DEPRECATED: server-side ingestion buffer (Steps 4 -> 6).

``IngestBuffer`` was the passive PR-1 middle tier between packed client
uplinks and downstream training. It is superseded by the asynchronous
code-server runtime's ``repro.server.CodeStore`` — versioned,
capacity-bounded, bulk-decoding — and now lives on only as a thin
compatibility alias over it. New code should use::

    from repro.server import CodeStore

which adds (client, round, codebook-version) keying, FIFO/reservoir
eviction, registry-snapshot decoding, and per-task label channels.
"""
from __future__ import annotations

import warnings
from typing import Optional, Tuple

import jax

from repro.core import octopus as OC
from repro.core.dvqae import DVQAEConfig
from repro.wire.payload import CodePayload


class IngestBuffer:
    """Deprecated alias: single-label, unbounded view over a CodeStore.

    Shapes are validated at ``add()`` (a mismatched ``labels`` used to
    surface only rounds later, at decode time).
    """

    def __init__(self, cfg: DVQAEConfig):
        warnings.warn(
            "IngestBuffer is deprecated; use repro.server.CodeStore "
            "(versioned, capacity-bounded, multi-task)",
            DeprecationWarning, stacklevel=2)
        from repro.server.store import CodeStore
        self.cfg = cfg
        self._store = CodeStore(cfg)

    def __len__(self) -> int:
        return len(self._store)

    def add(self, packed: CodePayload, labels=None) -> None:
        """Ingest one round's uplink. ``labels``: (C, B) or (C*B,) task
        labels riding alongside the codes — shape-checked here."""
        self._store.add(packed, round=len(self._store), labels=labels)

    @property
    def total_bytes(self) -> int:
        """Measured uplink bytes accumulated so far (§2.8 accounting)."""
        return self._store.total_bytes

    @property
    def n_samples(self) -> int:
        return self._store.n_samples

    # ------------------------------------------------------------- decode

    def codes(self) -> jax.Array:
        """Unpack every buffered round -> (sum_r C_r*B_r, T[, n_c]) int32."""
        if not len(self._store):
            raise ValueError("empty ingest buffer")
        return self._store.codes()

    def labels(self) -> Optional[jax.Array]:
        return self._store.labels()

    def dataset(self, server: OC.ServerState
                ) -> Tuple[jax.Array, Optional[jax.Array]]:
        """Decode the whole buffer against the CURRENT global codebook:
        -> (features, labels) ready for core.downstream training."""
        feats, _ = self._store.dataset(server)
        return feats, self.labels()

    def batches(self, server: OC.ServerState, batch_size: int, *,
                key, steps: int):
        """Minibatch stream over the decoded buffer (Step 6 training)."""
        feats, labels = self.dataset(server)
        n = feats.shape[0]
        for i in range(steps):
            sel = jax.random.randint(jax.random.fold_in(key, i),
                                     (min(batch_size, n),), 0, n)
            yield feats[sel], None if labels is None else labels[sel]

    def train_probe(self, key, server: OC.ServerState, *, n_classes: int,
                    steps: int = 200, lr: float = 1e-3, batch: int = 64,
                    dataset=None):
        """Step 6: fit the paper's 3-linear-layer probe on the buffer.

        Pass ``dataset=(feats, labels)`` from a prior ``self.dataset``
        call to skip re-decoding the buffer.
        """
        from repro.core import downstream as DS
        feats, labels = dataset if dataset is not None \
            else self.dataset(server)
        if labels is None:
            raise ValueError("buffer has no labels to train on")
        probe = DS.init_linear_probe(key, int(feats[0].size), n_classes)
        return DS.sgd_train(key, DS.linear_probe, probe, feats, labels,
                            steps=steps, lr=lr, batch=batch)

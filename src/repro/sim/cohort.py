"""Cohort-streamed population rounds (OCTOPUS §2.2 at 100k+ clients).

``SimEngine`` advances a stacked population in ONE fused dispatch — but
stacking 100k clients' full DVQ-AE states (plus their latents and packed
uplinks) in a single round is exactly the whole-population
materialization the paper's cross-device regime forbids. This module
streams the round instead:

  * :class:`CohortPlan` partitions the participating slot ids into
    fixed-size cohorts. Each cohort flows through the SAME jitted
    ``SimEngine`` round (one vmapped encode + ONE fused
    quantize-pack-stats dispatch, ``shard_map`` over the mesh 'data'
    axis when a mesh is given) — so peak memory is one COHORT's state,
    never the population's.
  * Per-cohort Step-5 contributions are folded into an
    **exactly associative** accumulator (``repro.core.ema.MergeStats``,
    int64 fixed point): any cohort grouping or order of the same client
    set produces the bit-identical merged dictionary
    (``octopus.server_merge_stats``). Grouping is invisible — the
    correctness contract the property suite (tests/test_cohort.py) pins.
  * Per-cohort :class:`~repro.wire.CodePayload` uplinks stream into
    ``OctopusServer.ingest`` unchanged; because every client record is
    padded to whole super-groups INDIVIDUALLY, Σ cohort ``nbytes`` ==
    the whole-population round's measured bytes (§2.8 accounting is
    cohort-invariant), and concatenating cohort payloads
    (``wire.concat_payloads``) reproduces the population payload
    bit-for-bit.
  * :meth:`CohortEngine.run_traffic` drives rounds from a
    ``RoundScheduler`` — diurnal participation (``DiurnalProfile``)
    arrives in whole cohorts, stragglers/drops ride the shared
    ``UplinkQueue`` at cohort granularity (cohorts are carved WITHIN
    each (delay, dropped) delivery group, so every payload is uniform).

Clients deploy FRESH from the server each round (cross-device regime:
the population's per-slot state lives on the devices, not the server) —
the server never holds more than one cohort's state at a time.

Bit-invariance boundary: the engine-level guarantee covers cohorts of
>= 2 clients. XLA compiles the degenerate C == 1 vmap into a different
program (last-ulp drift in the conv stack), so ``CohortPlan.build``
never emits a singleton tail; the MERGE algebra itself
(``core.ema.MergeStats``) is exact for any grouping including
singletons, given per-client statistics.

Typical use::

    eng = CohortEngine(cfg, gamma=0.99, n_local_steps=0)
    plan = CohortPlan.build(np.arange(100_000), cohort_size=1024)
    out = eng.round(server, plan, data_fn)     # streams 98 cohorts
    server = OC.server_merge_stats(server, out.stats)
"""
from __future__ import annotations

import time
from typing import Callable, List, NamedTuple, Optional, Tuple

import jax
import numpy as np

from repro.core import octopus as OC
from repro.core.dvqae import DVQAEConfig
from repro.core.ema import (MergeStats, merge_stats, merge_stats_add,
                            merge_stats_zero)
from repro.obs import recorder as _obs
from repro.wire.payload import CodePayload

from .engine import SimEngine

DataFn = Callable[[np.ndarray], object]     # slot ids -> (len(ids), B, ...)


class CohortPlan(NamedTuple):
    """A partition of participating slot ids into cohorts."""
    cohorts: Tuple[np.ndarray, ...]

    @classmethod
    def build(cls, members, cohort_size: int) -> "CohortPlan":
        """Chop ``members`` (slot ids, kept in order) into consecutive
        cohorts of ``cohort_size`` (the tail cohort may be smaller).

        A size-1 tail is folded into the previous cohort instead: XLA
        specializes the degenerate single-client batch into a DIFFERENT
        program than any C>=2 vmap (last-ulp float drift in the conv
        stack), which would break the engine-level bit-invariance the
        property suite pins — and it would burn a compile on a shape
        used once.
        """
        m = np.asarray(members, dtype=int).reshape(-1)
        if m.size == 0:
            raise ValueError("CohortPlan needs at least one member")
        cs = int(cohort_size)
        if cs < 1:
            raise ValueError(f"cohort_size must be >= 1, got {cs}")
        cohorts = [m[i:i + cs] for i in range(0, m.size, cs)]
        if cs > 1 and len(cohorts) > 1 and cohorts[-1].size == 1:
            tail = cohorts.pop()
            cohorts[-1] = np.concatenate([cohorts[-1], tail])
        return cls(cohorts=tuple(cohorts))

    @classmethod
    def from_groups(cls, groups) -> "CohortPlan":
        """Arbitrary (possibly ragged) explicit grouping — the property
        suite uses this to assert grouping-invariance."""
        cohorts = tuple(np.asarray(g, dtype=int).reshape(-1)
                        for g in groups)
        if not cohorts or any(c.size == 0 for c in cohorts):
            raise ValueError("every cohort needs at least one member")
        return cls(cohorts=cohorts)

    @property
    def n_cohorts(self) -> int:
        return len(self.cohorts)

    @property
    def sizes(self) -> Tuple[int, ...]:
        return tuple(int(c.size) for c in self.cohorts)

    @property
    def members(self) -> np.ndarray:
        return np.concatenate(self.cohorts)

    @property
    def n_clients(self) -> int:
        return int(sum(self.sizes))


class CohortRound(NamedTuple):
    """One streamed population round."""
    payloads: Tuple[CodePayload, ...]   # one per cohort, ingest-ready
    stats: MergeStats                   # associative Step-5 accumulator
    n_clients: int
    nbytes: int                         # Σ measured cohort uplink bytes


class TrafficRound(NamedTuple):
    """Per-round ledger of a scheduler-driven traffic run."""
    round: int
    n_participants: int
    n_cohorts: int
    bytes_sent: int
    bytes_delivered: int
    merged_version: Optional[int]


class ContinuousTick(NamedTuple):
    """Per-tick ledger of an open-ended continuous-ingest run."""
    tick: int
    n_participants: int
    n_cohorts: int
    bytes_offered: int       # measured bytes at the door (incl. refusals)
    bytes_delivered: int     # landed in the store this tick
    n_rejected: int          # admission rejections this tick
    n_deferred: int          # admissions answered "back off"
    merged_version: Optional[int]


class CohortEngine:
    """Streams population rounds cohort-by-cohort through ONE SimEngine.

    The inner engine's jit cache keys on the cohort shape, so every
    same-size cohort reuses one compiled round; a ragged tail cohort
    costs exactly one extra compile.
    """

    def __init__(self, cfg: DVQAEConfig, *, lr: float = 1e-4,
                 gamma: float = 0.99, n_local_steps: int = 1, mesh=None):
        self.cfg = cfg
        self.engine = SimEngine(cfg, lr=lr, gamma=gamma,
                                n_local_steps=n_local_steps, mesh=mesh)
        self.bits = self.engine.bits

    # ------------------------------------------------------------- rounds

    def round(self, server: OC.ServerState, plan: CohortPlan,
              data_fn: DataFn, *, version: int = 0,
              labels_fn: Optional[DataFn] = None,
              round_idx: Optional[int] = None) -> CohortRound:
        """Steps 2-5 for ``plan``'s population, one cohort at a time.

        ``data_fn(slot_ids)`` returns the cohort's local batches
        ``(len(slot_ids), B, ...)`` — keyed by slot id, so the SAME
        client sees the SAME data under any cohort grouping (that is
        what makes grouping-invariance testable). Clients deploy fresh
        from ``server``; per-cohort payloads are stamped ``version``.
        ``round_idx`` only labels the flight recorder's per-cohort
        encode events (the computation never reads it).
        """
        K, M = server.params["codebook"].shape
        stats = merge_stats_zero(int(K), int(M))
        payloads: List[CodePayload] = []
        for cohort in plan.cohorts:
            rec = _obs.active()
            t0 = time.perf_counter() if rec is not None else 0.0
            clients = self.engine.init_clients(server, int(cohort.size))
            labels = labels_fn(cohort) if labels_fn is not None else None
            clients, payload = self.engine.round(
                clients, data_fn(cohort), version=version, labels=labels)
            # fold this cohort's Step-5 contribution in; per-client
            # fixed-point quantization is grouping-independent, so the
            # integer totals match the single-shot population merge
            stats = merge_stats_add(stats, merge_stats(
                np.asarray(clients.params["codebook"]),
                np.asarray(clients.ema.counts)))
            payloads.append(payload)
            if rec is not None:
                jax.block_until_ready(payload.payload)
                fields = {"cohort_size": int(cohort.size)}
                if round_idx is not None:
                    fields["round"] = int(round_idx)
                rec.event("encode",
                          dur_ms=(time.perf_counter() - t0) * 1e3,
                          **fields, **_obs.payload_meta(payload))
        return CohortRound(payloads=tuple(payloads), stats=stats,
                           n_clients=plan.n_clients,
                           nbytes=sum(p.nbytes for p in payloads))

    # ------------------------------------------------------------ traffic

    def run_traffic(self, wire, scheduler, data_fn: DataFn, *,
                    cohort_size: int, n_rounds: int, merge_every: int = 0,
                    labels_fn: Optional[DataFn] = None,
                    queue=None) -> List[TrafficRound]:
        """Scheduler-driven rounds streaming into ``wire`` (an
        ``OctopusServer``).

        Each round: one ``RoundScheduler.step()`` decides participation
        (diurnal profiles arrive in whole cohorts via the scheduler's
        ``quantum``); participants are carved into cohorts WITHIN each
        (straggler delay, dropped) delivery group so every cohort
        payload has a uniform fate on the shared :class:`UplinkQueue`;
        due payloads land through ``wire.ingest`` unchanged. Every
        ``merge_every`` rounds the accumulated associative stats finish
        the Step-5 merge (``wire.merge_stats``) and register a new
        codebook version — subsequent cohorts pack under it.
        """
        from repro.server.runtime import UplinkQueue
        if queue is None:
            queue = UplinkQueue()
        acc: Optional[MergeStats] = None
        history: List[TrafficRound] = []
        for _ in range(n_rounds):
            rec = _obs.active()
            t0 = time.perf_counter() if rec is not None else 0.0
            ev = scheduler.step()
            groups = {}
            for j, slot in enumerate(ev.participants):
                key = (int(ev.delays[j]), bool(ev.dropped[j]))
                groups.setdefault(key, []).append(int(slot))
            sent = n_cohorts = 0
            for (delay, dropped), slots in sorted(groups.items()):
                plan = CohortPlan.build(slots, cohort_size)
                out = self.round(wire.state, plan, data_fn,
                                 version=wire.version, labels_fn=labels_fn,
                                 round_idx=ev.round)
                for payload, cohort in zip(out.payloads, plan.cohorts):
                    sent += queue.send(payload, round=ev.round,
                                       delay=delay, dropped=dropped,
                                       client_ids=cohort)
                if not dropped:
                    # dropped uplinks burn bytes AND lose their Step-5
                    # contribution — the radio ate the whole packet
                    acc = out.stats if acc is None else \
                        merge_stats_add(acc, out.stats)
                n_cohorts += plan.n_cohorts
            delivered, _ = queue.deliver(wire, ev.round)
            merged_version = None
            if merge_every and (ev.round + 1) % merge_every == 0 \
                    and acc is not None:
                merged_version = wire.merge_stats(acc)
                acc = None
            history.append(TrafficRound(
                round=ev.round, n_participants=int(ev.participants.size),
                n_cohorts=n_cohorts, bytes_sent=sent,
                bytes_delivered=delivered, merged_version=merged_version))
            if rec is not None:
                dur_ms = (time.perf_counter() - t0) * 1e3
                rec.event("round", round=ev.round,
                          n_participants=int(ev.participants.size),
                          n_cohorts=n_cohorts, bytes_sent=sent,
                          bytes_delivered=delivered,
                          queue_depth=len(queue),
                          merged_version=merged_version, dur_ms=dur_ms)
                rec.metrics.observe("round_ms", dur_ms)
                rec.metrics.set_gauge("uplink_queue_depth", len(queue))
        return history

    def run_continuous(self, service, scheduler, data_fn: DataFn, *,
                       cohort_size: int, n_ticks: int, merge_every: int = 0,
                       labels_fn: Optional[DataFn] = None,
                       migration_policy: Optional[str] = None
                       ) -> List[ContinuousTick]:
        """Open-ended traffic into a ``ContinuousIngestService``.

        The round-quantized loop inverted: each tick the scheduler draws
        an arrival count (set ``SchedulerConfig.rate`` for Poisson
        arrivals — quiet ticks and bursts both happen), arrivals are
        carved into cohorts per (delay, dropped) fate and OFFERED to the
        service one cohort-payload at a time, and the service clock
        ticks once. Admission is the service's call: a cohort whose
        offer comes back ``rejected`` (full queue, radio drop, wire
        violation) loses its Step-5 contribution along with its payload
        — backpressure reaches the merge, not just the store.

        Every ``merge_every`` ticks the accumulated associative stats
        finish the Step-5 merge. With ``migration_policy`` set, each
        merge also runs a rolling codebook upgrade: any open migration
        window is completed (applying the policy to old-version
        records), then a fresh ``latest-1 -> latest`` window opens — so
        in-flight payloads packed under the previous dictionary ingest
        as ``migrated`` while new cohorts pack under the merged one.
        """
        wire = service.wire
        acc: Optional[MergeStats] = None
        history: List[ContinuousTick] = []
        for _ in range(n_ticks):
            ev = scheduler.step()
            groups = {}
            for j, slot in enumerate(ev.participants):
                key = (int(ev.delays[j]), bool(ev.dropped[j]))
                groups.setdefault(key, []).append(int(slot))
            offered = n_cohorts = n_rej = n_def = 0
            for (delay, dropped), slots in sorted(groups.items()):
                plan = CohortPlan.build(slots, cohort_size)
                for cohort in plan.cohorts:
                    out = self.round(wire.state,
                                     CohortPlan.from_groups([cohort]),
                                     data_fn, version=wire.version,
                                     labels_fn=labels_fn,
                                     round_idx=ev.round)
                    res = service.offer(out.payloads[0], client_ids=cohort,
                                        delay=delay, dropped=dropped)
                    offered += res.nbytes
                    if res.verdict == "rejected":
                        n_rej += 1
                    elif res.verdict == "duplicate":
                        pass    # a retransmit raced in; counted once already
                    else:
                        if res.verdict == "deferred":
                            n_def += 1
                        # only admitted cohorts reach the Step-5 merge
                        acc = out.stats if acc is None else \
                            merge_stats_add(acc, out.stats)
                n_cohorts += plan.n_cohorts
            merged_version = None
            if merge_every and (ev.round + 1) % merge_every == 0 \
                    and acc is not None:
                # merge + migration go through the SERVICE delegates so
                # they journal (crash consistency) and compose with a
                # FaultyChannel wrapping the service
                merged_version = service.merge_stats(acc)
                acc = None
                if migration_policy is not None:
                    if wire.registry.migration is not None:
                        service.complete_migration()
                    service.begin_migration(policy=migration_policy)
            ts = service.tick(
                merged_version=merged_version,
                extra_fields={"n_participants": int(ev.participants.size),
                              "n_cohorts": n_cohorts})
            history.append(ContinuousTick(
                tick=ts.tick, n_participants=int(ev.participants.size),
                n_cohorts=n_cohorts, bytes_offered=offered,
                bytes_delivered=ts.bytes_delivered, n_rejected=n_rej,
                n_deferred=n_def, merged_version=merged_version))
        return history

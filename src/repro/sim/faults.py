"""Fault-injected uplink channel: the chaos plane between client and
server.

OCTOPUS assumes flaky edge uplinks are the NORM (§2.7-§2.8, Step 6) —
PR 8's runtime labels failure (admission verdicts) but nothing ever
injects it. :class:`FaultyChannel` sits between the payload producer
(``OctopusClient`` / ``CohortEngine.run_continuous``) and
``ContinuousIngestService.offer`` and applies a deterministic
:class:`FaultPlan`:

  * ``drop``      — the payload vanishes in the channel (bytes burn on
                    the §2.8 ledger, verdict ``rejected/radio_drop``);
  * ``duplicate`` — the payload arrives twice; the second copy carries
                    the SAME ``(client_id, seq)`` envelope, so the
                    service's dedup window answers ``duplicate`` and
                    nothing double-counts;
  * ``reorder``   — the two most recently queued payloads swap delivery
                    order (arrival order != send order);
  * ``delay``     — extra channel latency in ``[1, max_delay]`` ticks;
  * ``corrupt``   — ONE word-level bit flip; the carrier's CRC32 no
                    longer matches → ``rejected/corrupt`` at admission;
  * ``truncate``  — trailing word rows cut mid-flight; the stream is
                    too short for its declared shape → ``corrupt``.

Every fault family draws from its OWN PRNG substream — the PR-6
scheduler pattern ``fold_in(fold_in(key, send_index), purpose)`` — so
toggling one knob perturbs neither the other families nor anybody
else's population/traffic draws (the channel owns its key).

With a ``repro.wire.RetryPolicy`` the channel also runs the client
retry loop: transient outcomes (``deferred``, ``queue_full``,
``radio_drop``, ``corrupt``) re-offer the ORIGINAL clean payload under
the SAME envelope after a capped exponential backoff — retries that
race a success come back ``duplicate`` instead of double-ingesting.

The channel duck-types the service interface ``run_continuous`` uses
(``wire`` / ``offer`` / ``tick`` / ``drain`` / merge + migration
delegates), so it composes with the cohort engine unchanged:

    chan = FaultyChannel(service, FaultPlan(drop=0.1, corrupt=0.05),
                         key=jax.random.PRNGKey(3))
    engine.run_continuous(chan, sched, data_fn, ...)
"""
from __future__ import annotations

from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import numpy as np

from repro.obs import recorder as _obs
from repro.wire.session import RetryPolicy

#: per-family PRNG purposes (fold_in(fold_in(key, send), PURPOSE) — the
#: PR-6 scheduler substream pattern)
_STREAM_DROP = 1
_STREAM_DUPLICATE = 2
_STREAM_REORDER = 3
_STREAM_DELAY = 4
_STREAM_CORRUPT = 5
_STREAM_TRUNCATE = 6

FAULT_KINDS = ("drop", "duplicate", "reorder", "delay", "corrupt",
               "truncate")


def _rng_from_key(key) -> np.random.Generator:
    """Deterministic numpy generator from a jax key (scheduler idiom)."""
    return np.random.default_rng(
        np.asarray(jax.random.key_data(key)).astype(np.uint32))


class FaultPlan(NamedTuple):
    """Per-uplink fault probabilities (all independent draws)."""
    drop: float = 0.0        # channel loss: bytes burn, payload vanishes
    duplicate: float = 0.0   # payload arrives twice (same envelope)
    reorder: float = 0.0     # swap delivery order with the previous uplink
    delay: float = 0.0       # extra channel latency ...
    max_delay: int = 3       # ... uniform in [1, max_delay] ticks
    corrupt: float = 0.0     # one word-level bit flip (CRC catches it)
    truncate: float = 0.0    # trailing word rows cut (short stream)

    @property
    def active(self) -> bool:
        return any(p > 0 for p in (self.drop, self.duplicate, self.reorder,
                                   self.delay, self.corrupt, self.truncate))


class FaultyChannel:
    """Deterministic chaos between the payload producer and the service.

    Duck-types :class:`repro.server.ContinuousIngestService`'s offer /
    tick / drain surface (plus the journaled merge + migration
    delegates), so anything that drives a service — including
    ``CohortEngine.run_continuous`` — drives a faulted one unchanged.
    Fault counts land in ``.faults`` (and stream out as ``fault`` trace
    events / ``fault_<kind>`` metrics).
    """

    def __init__(self, service, plan: FaultPlan = FaultPlan(), *,
                 key=None, retry: Optional[RetryPolicy] = None):
        self.service = service
        self.plan = plan
        self.key = key if key is not None else jax.random.PRNGKey(0)
        self.retry = retry
        self.faults: Dict[str, int] = {}
        self.retries = 0
        self._sends = 0                         # per-send substream index
        self._next_seq: Dict[int, int] = {}     # auto-envelope counters
        self._retry_due: Dict[int, List[tuple]] = {}

    # ------------------------------------------------- service delegation

    @property
    def wire(self):
        return self.service.wire

    @property
    def queue(self):
        return self.service.queue

    @property
    def tick_idx(self) -> int:
        return self.service.tick_idx

    @property
    def verdicts(self) -> Dict[str, int]:
        return self.service.verdicts

    @property
    def verdict_bytes(self) -> Dict[str, int]:
        return self.service.verdict_bytes

    @property
    def decode_amortization(self) -> float:
        return self.service.decode_amortization

    def merge_stats(self, stats) -> int:
        return self.service.merge_stats(stats)

    def begin_migration(self, **kw):
        return self.service.begin_migration(**kw)

    def complete_migration(self):
        return self.service.complete_migration()

    # ------------------------------------------------------------- faults

    def _rng(self, purpose: int, idx: int) -> np.random.Generator:
        return _rng_from_key(jax.random.fold_in(
            jax.random.fold_in(self.key, idx), purpose))

    def _fault(self, kind: str, p, uplink_id) -> None:
        self.faults[kind] = self.faults.get(kind, 0) + 1
        rec = _obs.active()
        if rec is not None:
            rec.metrics.inc(f"fault_{kind}")
            rec.event("fault", fault=kind, tick=self.service.tick_idx,
                      nbytes=p.nbytes,
                      client_id=(None if uplink_id is None
                                 else int(uplink_id[0])))

    @staticmethod
    def _flip_bit(p, g: np.random.Generator):
        """One word-level bit flip; the stale checksum convicts it."""
        import jax.numpy as jnp
        words = np.array(np.asarray(p.payload), dtype=np.uint32, copy=True)
        if words.size == 0:
            return p
        flat = words.reshape(-1)
        i = int(g.integers(0, flat.size))
        flat[i] ^= np.uint32(1) << np.uint32(int(g.integers(0, 32)))
        return p._replace(payload=jnp.asarray(words))

    @staticmethod
    def _truncate(p, g: np.random.Generator):
        """Cut trailing word rows (None if the stream is too short to
        cut) — the declared shape now needs more rows than arrived."""
        import jax.numpy as jnp
        words = np.asarray(p.payload)
        rows = int(words.shape[0])
        if rows < 2:
            return None
        cut = int(g.integers(1, rows))
        return p._replace(payload=jnp.asarray(words[:rows - cut]))

    # -------------------------------------------------------------- offer

    def offer(self, payload, *, client_ids=None, delay: int = 0,
              dropped: bool = False, uplink_id=None, _attempt: int = 0):
        """One uplink through the faulty channel -> admission verdict."""
        p = self.service.wire._coerce(payload)
        if uplink_id is None and client_ids is not None:
            ids = np.asarray(client_ids).reshape(-1)
            if ids.size:
                cid = int(ids[0])
                seq = self._next_seq.get(cid, 0)
                self._next_seq[cid] = seq + 1
                uplink_id = (cid, seq)
        if dropped:        # scheduler-level radio drop: not channel chaos
            return self.service.offer(p, client_ids=client_ids,
                                      delay=delay, dropped=True,
                                      uplink_id=uplink_id)
        plan, idx = self.plan, self._sends
        self._sends += 1

        if plan.drop and \
                self._rng(_STREAM_DROP, idx).random() < plan.drop:
            self._fault("drop", p, uplink_id)
            res = self.service.offer(p, client_ids=client_ids, delay=delay,
                                     dropped=True, uplink_id=uplink_id)
            self._maybe_retry(p, client_ids, uplink_id, res, _attempt)
            return res

        send = p
        g = self._rng(_STREAM_CORRUPT, idx)
        if plan.corrupt and g.random() < plan.corrupt:
            send = self._flip_bit(send, g)
            self._fault("corrupt", p, uplink_id)
        g = self._rng(_STREAM_TRUNCATE, idx)
        if plan.truncate and g.random() < plan.truncate:
            cut = self._truncate(send, g)
            if cut is not None:
                send = cut
                self._fault("truncate", p, uplink_id)
        extra = 0
        g = self._rng(_STREAM_DELAY, idx)
        if plan.delay and g.random() < plan.delay:
            extra = int(g.integers(1, plan.max_delay + 1))
            self._fault("delay", p, uplink_id)

        res = self.service.offer(send, client_ids=client_ids,
                                 delay=delay + extra, uplink_id=uplink_id)

        g = self._rng(_STREAM_REORDER, idx)
        if plan.reorder and res.ok and res.verdict != "duplicate" \
                and g.random() < plan.reorder:
            if self.service.queue.reorder_tail():
                self._fault("reorder", p, uplink_id)
        g = self._rng(_STREAM_DUPLICATE, idx)
        if plan.duplicate and g.random() < plan.duplicate:
            self._fault("duplicate", p, uplink_id)
            self.service.offer(send, client_ids=client_ids,
                               delay=delay + extra, uplink_id=uplink_id)

        self._maybe_retry(p, client_ids, uplink_id, res, _attempt)
        return res

    # -------------------------------------------------------------- retry

    def _maybe_retry(self, p, client_ids, uplink_id, res,
                     attempt: int) -> None:
        """Schedule a clean retransmit of the SAME envelope on transient
        outcomes — the exactly-once dedup window makes a retry that
        raced a success harmless (``duplicate``)."""
        if self.retry is None or uplink_id is None:
            return
        if not self.retry.retryable(res) \
                or attempt >= self.retry.max_attempts:
            return
        wait = max(1, self.retry.backoff(
            attempt, salt=f"{uplink_id[0]}.{uplink_id[1]}"))
        due = self.service.tick_idx + wait
        self._retry_due.setdefault(due, []).append(
            (p, client_ids, uplink_id, attempt + 1))
        self.retries += 1
        rec = _obs.active()
        if rec is not None:
            rec.metrics.inc("retries")
            rec.event("retry", client_id=int(uplink_id[0]),
                      seq=int(uplink_id[1]), attempt=attempt,
                      wait_ticks=wait, verdict=res.verdict,
                      reason=res.reason)

    def _flush_retries(self) -> None:
        now = self.service.tick_idx
        for due in sorted(d for d in self._retry_due if d <= now):
            for (p, cids, uid, attempt) in self._retry_due.pop(due):
                self.offer(p, client_ids=cids, uplink_id=uid,
                           _attempt=attempt)

    # -------------------------------------------------------------- clock

    def tick(self, **kw):
        """Re-offer due retransmits, then advance the service clock."""
        self._flush_retries()
        return self.service.tick(**kw)

    def drain(self, max_ticks: int = 1000) -> list:
        """Tick until queue and retries are dry, then let the service
        drain its own background-decode tail."""
        out = []
        while (self._retry_due or len(self.service.queue)) \
                and len(out) < max_ticks:
            out.append(self.tick())
        if len(out) < max_ticks:
            out.extend(self.service.drain(max_ticks - len(out)))
        return out

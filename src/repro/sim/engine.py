"""Batched multi-client simulation engine (OCTOPUS §2.2 at population scale).

``core.octopus`` models ONE client's transition functions. Serving the
ROADMAP's "heavy traffic from millions of users" needs the whole client
population to advance per device call, so this engine:

  * stacks ``ClientState`` pytrees along a leading client axis
    (``replicate_clients`` / ``stack_clients``),
  * runs the Steps 2-3 front half (fine-tune + the round's SINGLE
    encoder pass) for every client in ONE jitted ``jax.vmap`` call —
    hundreds of clients per dispatch instead of a Python loop,
  * optionally wraps the vmap in ``shard_map`` over the mesh 'data' axis
    so client shards advance on separate devices (the same mesh contract
    as repro.distributed.sharding),
  * finishes Steps 3-5 in ONE fused quantize-pack-stats dispatch
    (repro.kernels.encode_codes): every client's latents are matched
    against that client's OWN codebook, bit-packed into a per-client
    dense uint32 record stream, and reduced to the EMA statistics that
    complete the Step 5 refresh — the population's (N, K) distance
    matrix and int32 index tensor never exist, and the per-round uplink
    bytes are MEASURED from the buffers that would actually cross the
    network, per-client padding included (§2.8).

Typical use::

    eng = SimEngine(cfg, lr=1e-4, gamma=0.99)
    clients = eng.init_clients(server, n_clients=256)
    clients, packed = eng.round(clients, data)     # data: (C, B, ...)
    server = eng.merge_into_server(server, clients)   # Step 5 tail
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import octopus as OC
from repro.core.dvqae import DVQAEConfig
from repro.wire.payload import CodePayload, normalize_labels


def __getattr__(name):
    if name == "PackedCodes":
        raise ImportError(
            "sim.engine.PackedCodes was removed; use "
            "repro.wire.CodePayload (same carrier, versioned wire format)")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


# ----------------------------------------------------------- client batches

def replicate_clients(server: OC.ServerState, n_clients: int
                      ) -> OC.ClientState:
    """Step 2 deployment for a population: one ClientState pytree whose
    leaves carry a leading (n_clients, ...) axis."""
    client = OC.client_init(server)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_clients,) + x.shape), client)


def stack_clients(clients) -> OC.ClientState:
    """List of per-client states -> one stacked ClientState pytree."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *clients)


def unstack_clients(batch: OC.ClientState):
    """Stacked ClientState -> list of per-client states (debug/interop)."""
    n = client_batch_size(batch)
    return [jax.tree.map(lambda x: x[i], batch) for i in range(n)]


def client_batch_size(batch: OC.ClientState) -> int:
    return int(jax.tree.leaves(batch)[0].shape[0])


# ------------------------------------------------------------------ engine

class SimEngine:
    """Compiles one population round (Steps 2-5) and reuses it.

    mesh=None        — single host: plain jitted vmap.
    mesh=Mesh(...)   — shard_map over the mesh 'data' axis: the client
                       axis is sharded, each device group advances its
                       slice of the population (n_clients must divide by
                       the data-axis size).
    """

    def __init__(self, cfg: DVQAEConfig, *, lr: float = 1e-4,
                 gamma: float = 0.99, n_local_steps: int = 1,
                 mesh=None):
        self.cfg = cfg
        self.bits = OC.transmit_bits(cfg)
        self.mesh = mesh

        def one_client(client, batch):
            return OC.client_round(client, cfg, batch, lr=lr, gamma=gamma,
                                   n_local_steps=n_local_steps)

        def one_client_encode(client, batch):
            """Steps 2-3 front half (the same code path client_round
            runs), latents flattened to (P, M) for the fused dispatch."""
            client, z = OC.client_finetune_encode(
                client, cfg, batch, lr=lr, n_local_steps=n_local_steps)
            return client, z.reshape(-1, z.shape[-1])

        step = jax.vmap(one_client)
        bits = self.bits

        def _round(clients, data):
            """One vmapped encode + ONE fused quantize-pack-stats dispatch
            for the (per-shard) population: the kernel quantizes every
            client's latents against that client's own codebook, emits
            each client's packed uplink record, and hands back the
            per-client EMA statistics that complete Step 5 without a
            second network pass."""
            from repro.core.ema import ema_update_from_stats
            from repro.kernels.ops import encode_codes
            clients, z = jax.vmap(one_client_encode)(clients, data)
            payload, counts, sums = encode_codes(
                z, clients.params["codebook"], bits=bits,
                n_groups=cfg.n_groups, n_slices=cfg.n_slices)
            ema = ema_update_from_stats(clients.ema, counts, sums,
                                        gamma=gamma)
            params = {**clients.params, "codebook": ema.codebook}
            clients = OC.ClientState(params=params, ema=ema,
                                     step=clients.step)
            return clients, payload

        round_fn = _round
        if mesh is not None:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            spec = P("data")
            step = shard_map(step, mesh, in_specs=(spec, spec),
                             out_specs=(spec, spec), check_rep=False)
            # the WHOLE round — encode, fused dispatch, EMA — runs inside
            # the shard-mapped body, so the kernel sees only its shard's
            # clients; per-shard payloads are per-client record streams,
            # so concatenating them along rows IS the population payload
            round_fn = shard_map(_round, mesh, in_specs=(spec, spec),
                                 out_specs=(spec, spec), check_rep=False)

        self._step = step
        self._step_jit = jax.jit(step)
        self._round = jax.jit(round_fn)
        self._shape_cache = {}

    # ------------------------------------------------------------- rounds

    def init_clients(self, server: OC.ServerState, n_clients: int
                     ) -> OC.ClientState:
        return replicate_clients(server, n_clients)

    def round(self, clients: OC.ClientState, data, *, version: int = 0,
              labels=None) -> Tuple[OC.ClientState, CodePayload]:
        """Advance every client one full round (Steps 2-5).

        data: (C, B, ...) — one local batch per client, client axis
        matching the stacked state. Returns the new population state and
        the round's wire payload: one per-client record stream per
        client (``n_records == C``), straight from the fused encode
        kernel — the population's int32 index tensor never exists.

        ``version`` stamps the codebook version the codes were packed
        under; ``labels`` (per-task dict or bare (C, B) array) ride the
        payload into the server's CodeStore.
        """
        c = client_batch_size(clients)
        assert data.shape[0] == c, (data.shape, c)
        idx_shape = self._index_shape(clients, data)
        clients, payload = self._round(clients, data)
        return clients, CodePayload(
            payload=payload, bits=self.bits, shape=idx_shape, n_records=c,
            version=int(version),
            labels=normalize_labels(labels, c * int(data.shape[1])),
            privatized=True)

    def round_indices(self, clients: OC.ClientState, data
                      ) -> Tuple[OC.ClientState, jax.Array]:
        """Steps 2-5 for the (sub)population, returning the UNPACKED int32
        code indices (C, B, T[, n_c]).

        The async code server (repro.server) uses this instead of
        ``round`` because participants split into delivery groups —
        stragglers, drops, per-version lanes — and each group packs its
        own uplink buffer; one population-wide payload would glue them
        together.
        """
        c = client_batch_size(clients)
        assert data.shape[0] == c, (data.shape, c)
        return self._step_jit(clients, data)

    def _index_shape(self, clients, data) -> Tuple[int, ...]:
        cache_key = tuple(data.shape)
        if cache_key not in self._shape_cache:
            out = jax.eval_shape(lambda c, d: self._step(c, d)[1],
                                 clients, data)
            self._shape_cache[cache_key] = tuple(out.shape)
        return self._shape_cache[cache_key]

    # ------------------------------------------------------- server side

    def merge_into_server(self, server: OC.ServerState,
                          clients: OC.ClientState) -> OC.ServerState:
        """Step 5 tail: count-weighted merge of the population's synced
        codebooks into the global dictionary — one einsum, no loop."""
        return OC.server_merge_codebooks(server, clients.params["codebook"],
                                         clients.ema.counts)

    def dequantize(self, server: OC.ServerState, packed: CodePayload):
        """Step 6 entry: fused decode of a round's payload against the
        CURRENT global codebook — the packed word stream goes straight to
        feature rows (ops.decode_codes); the int32 index tensor is never
        materialised."""
        feats = OC.codes_to_features(server, self.cfg, packed)
        return feats.reshape((-1,) + feats.shape[2:])   # merge client axis

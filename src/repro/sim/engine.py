"""Batched multi-client simulation engine (OCTOPUS §2.2 at population scale).

``core.octopus`` models ONE client's transition functions. Serving the
ROADMAP's "heavy traffic from millions of users" needs the whole client
population to advance per device call, so this engine:

  * stacks ``ClientState`` pytrees along a leading client axis
    (``replicate_clients`` / ``stack_clients``),
  * runs Steps 2-5 (``octopus.client_round``) for every client in ONE
    jitted ``jax.vmap`` call — hundreds of clients per dispatch instead
    of a Python loop,
  * optionally wraps the vmap in ``shard_map`` over the mesh 'data' axis
    so client shards advance on separate devices (the same mesh contract
    as repro.distributed.sharding),
  * bit-packs the population's code indices into one dense uint32 stream
    (repro.kernels.pack_bits) so the per-round uplink bytes are MEASURED
    from the buffer that would actually cross the network (§2.8).

Typical use::

    eng = SimEngine(cfg, lr=1e-4, gamma=0.99)
    clients = eng.init_clients(server, n_clients=256)
    clients, packed = eng.round(clients, data)     # data: (C, B, ...)
    server = eng.merge_into_server(server, clients)   # Step 5 tail
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import octopus as OC
from repro.core.dvqae import DVQAEConfig


class PackedCodes(NamedTuple):
    """One round's packed uplink: the population's code indices as a
    dense ceil(log2 K)-bit word stream."""
    payload: jax.Array           # (n_groups, W) uint32
    bits: int                    # bits per code
    shape: Tuple[int, ...]       # original indices shape (C, B, T[, n_c])

    @property
    def nbytes(self) -> int:
        """Measured size of the buffer that crosses the network."""
        return int(self.payload.size) * self.payload.dtype.itemsize

    @property
    def count(self) -> int:
        return int(math.prod(self.shape))

    def unpack(self) -> jax.Array:
        """Bit-exact inverse: -> int32 indices of the original shape."""
        from repro.kernels.ops import unpack_codes
        flat = unpack_codes(self.payload, bits=self.bits, count=self.count)
        return flat.reshape(self.shape)


# ----------------------------------------------------------- client batches

def replicate_clients(server: OC.ServerState, n_clients: int
                      ) -> OC.ClientState:
    """Step 2 deployment for a population: one ClientState pytree whose
    leaves carry a leading (n_clients, ...) axis."""
    client = OC.client_init(server)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_clients,) + x.shape), client)


def stack_clients(clients) -> OC.ClientState:
    """List of per-client states -> one stacked ClientState pytree."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *clients)


def unstack_clients(batch: OC.ClientState):
    """Stacked ClientState -> list of per-client states (debug/interop)."""
    n = client_batch_size(batch)
    return [jax.tree.map(lambda x: x[i], batch) for i in range(n)]


def client_batch_size(batch: OC.ClientState) -> int:
    return int(jax.tree.leaves(batch)[0].shape[0])


# ------------------------------------------------------------------ engine

class SimEngine:
    """Compiles one population round (Steps 2-5) and reuses it.

    mesh=None        — single host: plain jitted vmap.
    mesh=Mesh(...)   — shard_map over the mesh 'data' axis: the client
                       axis is sharded, each device group advances its
                       slice of the population (n_clients must divide by
                       the data-axis size).
    """

    def __init__(self, cfg: DVQAEConfig, *, lr: float = 1e-4,
                 gamma: float = 0.99, n_local_steps: int = 1,
                 mesh=None):
        self.cfg = cfg
        self.bits = OC.transmit_bits(cfg)
        self.mesh = mesh

        def one_client(client, batch):
            return OC.client_round(client, cfg, batch, lr=lr, gamma=gamma,
                                   n_local_steps=n_local_steps)

        step = jax.vmap(one_client)
        if mesh is not None:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            spec = P("data")
            step = shard_map(step, mesh, in_specs=(spec, spec),
                             out_specs=(spec, spec), check_rep=False)

        bits = self.bits

        def _round(clients, data):
            clients, idx = step(clients, data)
            from repro.kernels.ops import pack_codes
            payload = pack_codes(idx, bits=bits)
            return clients, payload

        self._step = step
        self._step_jit = jax.jit(step)
        self._round = jax.jit(_round)
        self._shape_cache = {}

    # ------------------------------------------------------------- rounds

    def init_clients(self, server: OC.ServerState, n_clients: int
                     ) -> OC.ClientState:
        return replicate_clients(server, n_clients)

    def round(self, clients: OC.ClientState, data
              ) -> Tuple[OC.ClientState, PackedCodes]:
        """Advance every client one full round (Steps 2-5).

        data: (C, B, ...) — one local batch per client, client axis
        matching the stacked state. Returns the new population state and
        the round's packed uplink.
        """
        c = client_batch_size(clients)
        assert data.shape[0] == c, (data.shape, c)
        idx_shape = self._index_shape(clients, data)
        clients, payload = self._round(clients, data)
        return clients, PackedCodes(payload=payload, bits=self.bits,
                                    shape=idx_shape)

    def round_indices(self, clients: OC.ClientState, data
                      ) -> Tuple[OC.ClientState, jax.Array]:
        """Steps 2-5 for the (sub)population, returning the UNPACKED int32
        code indices (C, B, T[, n_c]).

        The async code server (repro.server) uses this instead of
        ``round`` because participants split into delivery groups —
        stragglers, drops, per-version lanes — and each group packs its
        own uplink buffer; one population-wide payload would glue them
        together.
        """
        c = client_batch_size(clients)
        assert data.shape[0] == c, (data.shape, c)
        return self._step_jit(clients, data)

    def _index_shape(self, clients, data) -> Tuple[int, ...]:
        cache_key = tuple(data.shape)
        if cache_key not in self._shape_cache:
            out = jax.eval_shape(lambda c, d: self._step(c, d)[1],
                                 clients, data)
            self._shape_cache[cache_key] = tuple(out.shape)
        return self._shape_cache[cache_key]

    # ------------------------------------------------------- server side

    def merge_into_server(self, server: OC.ServerState,
                          clients: OC.ClientState) -> OC.ServerState:
        """Step 5 tail: count-weighted merge of the population's synced
        codebooks into the global dictionary — one einsum, no loop."""
        return OC.server_merge_codebooks(server, clients.params["codebook"],
                                         clients.ema.counts)

    def dequantize(self, server: OC.ServerState, packed: PackedCodes):
        """Step 6 entry: fused decode of a round's payload against the
        CURRENT global codebook — the packed word stream goes straight to
        feature rows (ops.decode_codes); the int32 index tensor is never
        materialised."""
        feats = OC.codes_to_features(server, self.cfg, packed)
        return feats.reshape((-1,) + feats.shape[2:])   # merge client axis

"""Batched multi-client OCTOPUS simulation (ROADMAP: client populations
at scale, not one Python object per client).

  engine  — stacked ClientState pytrees + one jitted vmap/shard_map round;
            the round's uplink is a ``repro.wire.CodePayload``
  cohort  — cohort-streamed population rounds (100k+ clients): fixed-size
            cohorts through ONE compiled engine round, exactly
            associative Step-5 stats merge, scheduler-driven traffic +
            open-ended continuous-ingest traffic
  faults  — FaultPlan / FaultyChannel: deterministic chaos (drop /
            duplicate / reorder / delay / corrupt / truncate) between
            the cohort engine and the ingest service, plus the client
            retry loop over the exactly-once dedup window

The PR-1 ``IngestBuffer`` and the ``PackedCodes`` payload alias are
RETIRED: importing either raises with a pointer at the unified wire
layer (``repro.wire`` / ``repro.server``).
"""
from repro.wire.payload import CodePayload

from .cohort import (CohortEngine, CohortPlan, CohortRound, ContinuousTick,
                     TrafficRound)
from .engine import (SimEngine, client_batch_size, replicate_clients,
                     stack_clients, unstack_clients)
from .faults import FAULT_KINDS, FaultPlan, FaultyChannel

__all__ = ["CodePayload", "CohortEngine", "CohortPlan", "CohortRound",
           "ContinuousTick", "FAULT_KINDS", "FaultPlan", "FaultyChannel",
           "SimEngine", "TrafficRound", "client_batch_size",
           "replicate_clients", "stack_clients", "unstack_clients"]

_TOMBSTONES = {
    "IngestBuffer": "repro.server.CodeStore / repro.server.ShardedCodeStore",
    "PackedCodes": "repro.wire.CodePayload",
}


def __getattr__(name):
    if name in _TOMBSTONES:
        raise ImportError(
            f"repro.sim.{name} was removed; use {_TOMBSTONES[name]} "
            f"(the unified wire carrier/store — see repro.wire)")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

"""Batched multi-client OCTOPUS simulation (ROADMAP: client populations
at scale, not one Python object per client).

  engine  — stacked ClientState pytrees + one jitted vmap/shard_map round
  ingest  — DEPRECATED server-side buffer; superseded by the async
            code-server runtime (repro.server.CodeStore)
"""
from .engine import (PackedCodes, SimEngine, client_batch_size,
                     replicate_clients, stack_clients, unstack_clients)
from .ingest import IngestBuffer

__all__ = ["PackedCodes", "SimEngine", "IngestBuffer", "client_batch_size",
           "replicate_clients", "stack_clients", "unstack_clients"]

"""Batched multi-client OCTOPUS simulation (ROADMAP: client populations
at scale, not one Python object per client).

  engine  — stacked ClientState pytrees + one jitted vmap/shard_map round;
            the round's uplink is a ``repro.wire.CodePayload`` (the
            deprecated ``PackedCodes`` is an alias of it)
  cohort  — cohort-streamed population rounds (100k+ clients): fixed-size
            cohorts through ONE compiled engine round, exactly
            associative Step-5 stats merge, scheduler-driven traffic
  ingest  — DEPRECATED server-side buffer; superseded by the async
            code-server runtime (repro.server.CodeStore)
"""
from repro.wire.payload import CodePayload

from .cohort import CohortEngine, CohortPlan, CohortRound, TrafficRound
from .engine import (PackedCodes, SimEngine, client_batch_size,
                     replicate_clients, stack_clients, unstack_clients)
from .ingest import IngestBuffer

__all__ = ["CodePayload", "CohortEngine", "CohortPlan", "CohortRound",
           "PackedCodes", "SimEngine", "IngestBuffer", "TrafficRound",
           "client_batch_size", "replicate_clients", "stack_clients",
           "unstack_clients"]

"""Batched multi-client OCTOPUS simulation (ROADMAP: client populations
at scale, not one Python object per client).

  engine  — stacked ClientState pytrees + one jitted vmap/shard_map round;
            the round's uplink is a ``repro.wire.CodePayload`` (the
            deprecated ``PackedCodes`` is an alias of it)
  ingest  — DEPRECATED server-side buffer; superseded by the async
            code-server runtime (repro.server.CodeStore)
"""
from repro.wire.payload import CodePayload

from .engine import (PackedCodes, SimEngine, client_batch_size,
                     replicate_clients, stack_clients, unstack_clients)
from .ingest import IngestBuffer

__all__ = ["CodePayload", "PackedCodes", "SimEngine", "IngestBuffer",
           "client_batch_size", "replicate_clients", "stack_clients",
           "unstack_clients"]

"""Flight recorder: a structured JSONL event log of the OCTOPUS pipeline.

Every run so far computed its numbers AFTER the fact (benchmarks/run.py
re-deriving throughput from wall-clock deltas); the pipeline itself kept
no record of what happened. The recorder is that record: one JSON object
per line, one line per event, covering the whole uplink life cycle —

  ``round``    one scheduler/population round (dur_ms, participant and
               byte ledger, queue depth, merged version)
  ``encode``   one fused encode dispatch (a cohort's or a client's
               Steps 3-5 tail) with the emitted payload's metadata
  ``uplink``   one :class:`repro.wire.CodePayload` hitting the wire —
               version / nbytes / bits / n_records / privatized (+ the
               wire revision and delivery fate). This is the captured
               stream a membership-inference harness replays: metadata
               ONLY, never the packed words, labels, latents or raw
               data, so the observability plane itself honors §2.5.
  ``ingest``   one payload landing in the server's versioned store
  ``decode``   one fused decode dispatch (per codebook-version group)
  ``merge``    one Step-5 dictionary merge registering a new version
  ``admission`` one admission verdict at the continuous-ingest door
               (accepted / migrated / deferred / rejected + reason +
               queue depth) — refusals stay §2.8-witnessed
  ``migration`` a rolling codebook-upgrade window opening or closing
               (src / dst versions, policy, leftover src records)
  ``fault``    the chaos plane injecting one fault into one uplink
               (``fault`` = drop / duplicate / reorder / delay /
               corrupt / truncate, plus the victim's nbytes)
  ``retry``    a client scheduling a retransmit of a transient-refused
               envelope (client_id / seq / attempt / backoff ticks)
  ``recovery`` one crash recovery completing (snapshot tick, journal
               entries replayed, wall duration)
  ``tap``      a red-team :class:`repro.privacy.PayloadTap` capturing
               one payload off the wire (capture count + the payload's
               METADATA — the tap announces itself in the trace, but
               the captured words live only in the opted-in tap)
  ``attack``   one inference attack scored (attack name, accuracy,
               chance, advantage — scalar results, never features)

Zero-overhead default: no recorder is installed unless the process opts
in (:func:`install` / :func:`recording` / the ``OCTOPUS_TRACE`` env
var). Instrumented call sites guard on ``active() is None`` — one global
read per site, no event dict, no timestamp, no allocation on the
disabled path. Instrumentation never touches RNG streams and never
forces a different computation, so traced and untraced runs are
bit-identical (pinned by tests/test_obs.py).

Spans are plain events carrying ``dur_ms`` (and a ``span`` id when
nesting matters); :meth:`FlightRecorder.span` times a ``with`` block and
emits the event at exit.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import IO, Any, Dict, Optional, Union

from .metrics import MetricsRegistry

EVENT_KINDS = ("round", "encode", "uplink", "ingest", "decode", "merge",
               "admission", "migration", "fault", "retry", "recovery",
               "tap", "attack")

#: uplink/ingest events carry EXACTLY this payload metadata — the §2.5
#: boundary of the observability plane (no words, no labels, no latents)
PAYLOAD_META_FIELDS = ("version", "nbytes", "bits", "n_records",
                       "privatized", "wire", "count")


def payload_meta(payload) -> Dict[str, Any]:
    """A :class:`~repro.wire.CodePayload`'s wire METADATA as a flat dict.

    Reads shape/dtype bookkeeping only — the packed words never leave
    the carrier, and label channels are deliberately not captured.
    """
    return {
        "version": int(payload.version),
        "nbytes": int(payload.nbytes),
        "bits": int(payload.bits),
        "n_records": int(payload.n_records),
        "privatized": bool(payload.privatized),
        "wire": int(payload.wire),
        "count": int(payload.count),
    }


class _Span:
    """Times a ``with`` block; emits ONE event (kind + dur_ms) at exit."""

    __slots__ = ("_rec", "_kind", "_fields", "_t0")

    def __init__(self, rec: "FlightRecorder", kind: str, fields: dict):
        self._rec = rec
        self._kind = kind
        self._fields = fields

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self._rec.event(self._kind,
                        dur_ms=(time.perf_counter() - self._t0) * 1e3,
                        **self._fields)


class FlightRecorder:
    """Appends structured events to a JSONL file, one line per event.

    ``path`` may be a filesystem path or an open text handle. Each line
    is ``{"kind": ..., "ts": <wall seconds>, "seq": <monotonic event
    index>, ...fields}``. The writer flushes per event so a crashed or
    killed run keeps everything recorded up to the failure. A
    :class:`~repro.obs.metrics.MetricsRegistry` rides along
    (``.metrics``) for the counters/gauges/histograms the instrumented
    sites maintain while the recorder is active.
    """

    def __init__(self, path: Union[str, os.PathLike, IO[str]], *,
                 metrics: Optional[MetricsRegistry] = None):
        if hasattr(path, "write"):
            self._fh: IO[str] = path
            self._owns = False
            self.path = getattr(path, "name", "<stream>")
        else:
            self._fh = open(path, "a")
            self._owns = True
            self.path = os.fspath(path)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.n_events = 0
        self._lock = threading.Lock()

    # -------------------------------------------------------------- events

    def event(self, kind: str, **fields) -> Dict[str, Any]:
        """Emit one event; returns the dict that was written.

        Field values must be SCALARS (numbers / strings / bools / None):
        arrays and containers are refused outright, so no event kind —
        present or future — can smuggle packed words, label vectors or
        latents into a trace (§2.5 is enforced mechanically, not by
        call-site discipline).
        """
        for k, v in fields.items():
            if (isinstance(v, (list, tuple, set, dict, bytes, bytearray))
                    or getattr(v, "ndim", 0)):
                raise ValueError(
                    f"trace event {kind!r} field {k!r} carries a "
                    f"{type(v).__name__}; events are scalar-only — the "
                    f"observability plane never records words, labels or "
                    f"latents (§2.5)")
        ev = {"kind": kind, "ts": time.time()}
        ev.update(fields)
        with self._lock:
            ev["seq"] = self.n_events
            self.n_events += 1
            self._fh.write(json.dumps(ev, separators=(",", ":"),
                                      default=_jsonable) + "\n")
            self._fh.flush()
        return ev

    def span(self, kind: str, **fields) -> _Span:
        """``with rec.span("decode", version=3): ...`` — one event with
        the block's ``dur_ms`` at exit."""
        return _Span(self, kind, fields)

    def uplink(self, payload, **fields) -> Dict[str, Any]:
        """THE uplink event: one payload crossing the wire. Captures the
        carrier's metadata (:func:`payload_meta`) — never its words or
        label channels — plus caller context (round, delay, fate)."""
        meta = payload_meta(payload)
        self.metrics.inc("uplinks_sent")
        self.metrics.inc("wire_bytes", meta["nbytes"])
        return self.event("uplink", **meta, **fields)

    # ----------------------------------------------------------- lifecycle

    def flush(self) -> None:
        self._fh.flush()

    def close(self) -> None:
        if self._owns and not self._fh.closed:
            self._fh.close()

    def __enter__(self) -> "FlightRecorder":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def _jsonable(x):
    """Last-resort coercion for numpy scalars riding in event fields."""
    for attr in ("item",):
        if hasattr(x, attr):
            return x.item()
    return str(x)


# ------------------------------------------------------- process singleton

_ACTIVE: Optional[FlightRecorder] = None


def active() -> Optional[FlightRecorder]:
    """The installed recorder, or None (the zero-overhead default).

    Instrumented sites guard every event behind ``active() is not
    None`` — when disabled, the entire cost is this global read.
    """
    return _ACTIVE


def install(rec: FlightRecorder) -> FlightRecorder:
    """Make ``rec`` the process-wide recorder all hooks report to."""
    global _ACTIVE
    _ACTIVE = rec
    return rec


def uninstall() -> Optional[FlightRecorder]:
    """Remove (and return) the installed recorder; does NOT close it."""
    global _ACTIVE
    rec, _ACTIVE = _ACTIVE, None
    return rec


class _Recording:
    """Context manager: install a fresh recorder, uninstall + close."""

    def __init__(self, path, **kw):
        self._rec = FlightRecorder(path, **kw)

    def __enter__(self) -> FlightRecorder:
        return install(self._rec)

    def __exit__(self, *exc) -> None:
        if _ACTIVE is self._rec:
            uninstall()
        self._rec.close()


def recording(path, **kw) -> _Recording:
    """``with obs.recording("trace.jsonl") as rec: ...`` — scoped
    tracing: every instrumented layer reports to ``rec`` inside the
    block, and the default reverts to no-op outside it."""
    return _Recording(path, **kw)


ENV_VAR = "OCTOPUS_TRACE"


def install_from_env() -> Optional[FlightRecorder]:
    """Install a recorder writing to ``$OCTOPUS_TRACE`` if set (how CI
    traces an unmodified example end to end). No-op otherwise."""
    path = os.environ.get(ENV_VAR, "").strip()
    if not path or _ACTIVE is not None:
        return _ACTIVE
    return install(FlightRecorder(path))

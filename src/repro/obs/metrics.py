"""Metrics plane: counters / gauges / histograms + the dispatch monitor.

The registry is deliberately tiny — plain Python floats behind names —
because it runs INSIDE the serving path: ``repro.wire`` /
``repro.server`` / ``repro.sim.cohort`` update it per uplink and per
round while a flight recorder is active. Standard instruments:

  counter    monotonically increasing total (``uplinks_ingested``,
             ``wire_bytes``, ``merges``)
  gauge      last-written level (``uplink_queue_depth``,
             ``store_records``, ``store_bytes``)
  histogram  streaming count/total/min/max (+mean) of an observation
             (``round_ms``, ``decode_ms/v<version>``)

:func:`dispatch_monitor` promotes the dispatch-counting trick that
tests/test_encode.py and tests/test_wire.py (and the ``wire`` /
``encode`` benchmark sections) each hand-rolled — wrapping
``dvqae.encode`` and the fused kernel entries with counting shims — into
one supported API: COUNTED (not inferred) encoder passes and fused
encode/decode/pack dispatch numbers for any block of code, restored on
exit, optionally folded into a registry's counters.
"""
from __future__ import annotations

from typing import Any, Dict, Optional


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v


class Histogram:
    __slots__ = ("count", "total", "min", "max")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def as_dict(self) -> Dict[str, float]:
        return {"count": self.count, "total": self.total, "mean": self.mean,
                "min": self.min if self.count else 0.0,
                "max": self.max if self.count else 0.0}


class MetricsRegistry:
    """Name -> instrument, created on first touch."""

    def __init__(self):
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, Histogram] = {}

    # ---------------------------------------------------------- instruments

    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge()
        return g

    def histogram(self, name: str) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram()
        return h

    # ----------------------------------------------------------- shorthand

    def inc(self, name: str, v: float = 1.0) -> None:
        self.counter(name).inc(v)

    def set_gauge(self, name: str, v: float) -> None:
        self.gauge(name).set(v)

    def observe(self, name: str, v: float) -> None:
        self.histogram(name).observe(v)

    def snapshot(self) -> Dict[str, Any]:
        """Plain-dict view (what the report CLI embeds in its JSON)."""
        return {
            "counters": {k: c.value for k, c in sorted(self.counters.items())},
            "gauges": {k: g.value for k, g in sorted(self.gauges.items())},
            "histograms": {k: h.as_dict()
                           for k, h in sorted(self.histograms.items())},
        }


# ---------------------------------------------------------- dispatch counts

class DispatchCounts:
    """Counted dispatch numbers for one monitored block of code.

    ``encoder_passes`` counts ``repro.core.dvqae.encode`` invocations
    (the PR-4 "exactly one encoder pass per round" regression number);
    the ``*_dispatches`` fields count the fused kernel entries in
    ``repro.kernels.ops``. The PR-4/PR-5 baseline for one facade round
    is ``(encoder_passes, encode_dispatches) == (1, 1)``.
    """

    __slots__ = ("encoder_passes", "encode_dispatches", "decode_dispatches",
                 "pack_dispatches", "unpack_dispatches")

    def __init__(self):
        for f in self.__slots__:
            setattr(self, f, 0)

    def as_dict(self) -> Dict[str, int]:
        return {f: getattr(self, f) for f in self.__slots__}

    def __repr__(self) -> str:
        inner = ", ".join(f"{k}={v}" for k, v in self.as_dict().items())
        return f"DispatchCounts({inner})"


class _DispatchMonitor:
    """Wraps the encoder + fused kernel entries with counting shims.

    The shims delegate unchanged (same args, same result objects), so
    monitored code is bit-identical to unmonitored code; originals are
    restored on exit even if the block raises. Supports the same
    attribute-patching composition the tests use (a monitor installed
    inside another monitor counts for both).
    """

    def __init__(self, metrics: Optional[MetricsRegistry]):
        self._metrics = metrics
        self.counts = DispatchCounts()
        self._saved = None

    def __enter__(self) -> DispatchCounts:
        from repro.core import dvqae
        from repro.kernels import ops
        c = self.counts

        def counting(real, field):
            def shim(*a, **kw):
                setattr(c, field, getattr(c, field) + 1)
                return real(*a, **kw)
            return shim

        self._saved = (dvqae.encode, ops.encode_codes, ops.decode_codes,
                       ops.pack_codes, ops.unpack_codes)
        dvqae.encode = counting(dvqae.encode, "encoder_passes")
        ops.encode_codes = counting(ops.encode_codes, "encode_dispatches")
        ops.decode_codes = counting(ops.decode_codes, "decode_dispatches")
        ops.pack_codes = counting(ops.pack_codes, "pack_dispatches")
        ops.unpack_codes = counting(ops.unpack_codes, "unpack_dispatches")
        return c

    def __exit__(self, *exc) -> None:
        from repro.core import dvqae
        from repro.kernels import ops
        (dvqae.encode, ops.encode_codes, ops.decode_codes,
         ops.pack_codes, ops.unpack_codes) = self._saved
        metrics = self._metrics
        if metrics is None:
            from .recorder import active
            rec = active()
            metrics = rec.metrics if rec is not None else None
        if metrics is not None:
            for name, n in self.counts.as_dict().items():
                if n:
                    metrics.inc(name, n)


def dispatch_monitor(*, metrics: Optional[MetricsRegistry] = None
                     ) -> _DispatchMonitor:
    """Count encoder passes and fused kernel dispatches in a block::

        with obs.dispatch_monitor() as counts:
            payload = client.round(batch)
        assert (counts.encoder_passes, counts.encode_dispatches) == (1, 1)

    With ``metrics`` given (or a flight recorder active), non-zero
    counts fold into that registry's counters on exit — the supported
    home of the fused-dispatch regression numbers.
    """
    return _DispatchMonitor(metrics)

"""Render a flight-recorder trace into per-round summaries.

    PYTHONPATH=src python -m repro.obs.report trace.jsonl
    PYTHONPATH=src python -m repro.obs.report trace.jsonl --check \\
        --json OBS_report.json

Reads the JSONL event stream a :class:`repro.obs.FlightRecorder` wrote
and reconstructs the numbers the ROADMAP asks for MEASURED, not
computed: per-round uplinks/sec, bytes/round, per-codebook-version
decode latency, merge cadence, and the queue-depth profile. ``--json``
writes the summary as a BENCH-style section (``{"section": "obs",
"rows": [{name, value, extra}]}``) so trend tooling can diff traces the
same way it diffs ``BENCH_<section>.json`` artifacts.

``--check`` enforces the §2.8 accounting invariant INSIDE the trace:
for every round event, the sum of that round's ``uplink`` events'
measured ``nbytes`` must equal the round's ``bytes_sent`` ledger (which
the traffic drivers compute from ``CodePayload.nbytes`` as payloads hit
the queue) — byte-exact, or the exit code is non-zero. A trace with no
uplink events also fails the check: an empty recorder is not evidence.

Continuous-ingest traces (``admission`` events present, every round
event carrying ``bytes_in_flight``) additionally get the conservation
check: Σ uplink bytes == Σ ingested bytes + Σ admission-REJECTED bytes
+ Σ admission-DUPLICATE bytes + the final tick's bytes still in flight
— i.e. every refused, retransmitted-and-deduplicated, or deferred
payload stays on the ledger, backpressure, faults and migration
included. Chaos-plane traces (``fault`` / ``retry`` / ``recovery``
events) get their injected-fault histogram, retry count and recovery
drill summarized alongside.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from typing import Any, Dict, List, Optional, Sequence


def load_events(path: str) -> List[Dict[str, Any]]:
    """Parse one JSONL trace; blank lines are skipped."""
    events = []
    with open(path) as fh:
        for i, line in enumerate(fh):
            line = line.strip()
            if not line:
                continue
            try:
                events.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i + 1}: not a JSON event: "
                                 f"{e}") from e
    return events


def summarize(events: Sequence[Dict[str, Any]]) -> Dict[str, Any]:
    """Aggregate an event stream into the per-round / per-version views.

    Uplink/ingest totals come from the events' measured ``nbytes``;
    per-round throughput divides the round's uplink count by the round
    event's ``dur_ms``; decode latency groups ``decode`` events by the
    codebook version they dispatched against.
    """
    kinds: Dict[str, int] = defaultdict(int)
    up = {"n": 0, "bytes": 0, "dropped": 0, "dropped_bytes": 0}
    ingest = {"n": 0, "bytes": 0}
    per_round_up: Dict[int, Dict[str, int]] = defaultdict(
        lambda: {"n": 0, "bytes": 0})
    rounds: List[Dict[str, Any]] = []
    decode: Dict[Any, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "total_ms": 0.0, "n_samples": 0})
    merges: List[Any] = []
    admission = {"n": 0, "bytes": 0,
                 "verdicts": defaultdict(int),
                 "verdict_bytes": defaultdict(int),
                 "reasons": defaultdict(int)}
    migrations: List[Dict[str, Any]] = []
    faults: Dict[str, int] = defaultdict(int)
    retries = 0
    recoveries: List[Dict[str, Any]] = []
    for ev in events:
        kind = ev.get("kind", "?")
        kinds[kind] += 1
        if kind == "uplink":
            up["n"] += 1
            up["bytes"] += int(ev.get("nbytes", 0))
            if ev.get("dropped"):
                up["dropped"] += 1
                up["dropped_bytes"] += int(ev.get("nbytes", 0))
            if "round" in ev:
                r = per_round_up[int(ev["round"])]
                r["n"] += 1
                r["bytes"] += int(ev.get("nbytes", 0))
        elif kind == "ingest":
            ingest["n"] += 1
            ingest["bytes"] += int(ev.get("nbytes", 0))
        elif kind == "round":
            rounds.append(dict(ev))
        elif kind == "decode":
            d = decode[ev.get("version")]
            d["count"] += 1
            d["total_ms"] += float(ev.get("dur_ms", 0.0))
            d["n_samples"] += int(ev.get("n_samples", 0))
        elif kind == "merge":
            merges.append(ev.get("version"))
        elif kind == "admission":
            admission["n"] += 1
            nb = int(ev.get("nbytes", 0))
            admission["bytes"] += nb
            v = str(ev.get("verdict", "?"))
            admission["verdicts"][v] += 1
            admission["verdict_bytes"][v] += nb
            if ev.get("reason"):
                admission["reasons"][str(ev["reason"])] += 1
        elif kind == "migration":
            migrations.append({k: ev.get(k) for k in
                               ("phase", "src", "dst", "policy",
                                "src_records", "src_bytes", "n_reencoded")
                               if k in ev})
        elif kind == "fault":
            faults[str(ev.get("fault", "?"))] += 1
        elif kind == "retry":
            retries += 1
        elif kind == "recovery":
            recoveries.append({k: ev.get(k) for k in
                               ("tick", "snapshot_tick", "n_replayed",
                                "dur_ms", "queue_depth", "store_records")
                               if k in ev})

    # one row per round INDEX: a crash-recovered service re-emits ticks
    # of the indices the crashed instance already traced (recovery is a
    # point on the same timeline, not a fork), so counter fields SUM
    # across the event group while gauges (queue depth, in-flight) come
    # from the group's last event — the per-round §2.8 identity then
    # holds across the kill
    by_rid: Dict[Any, Dict[str, Any]] = {}
    order: List[Any] = []
    for ev in sorted(rounds, key=lambda e: e.get("round", -1)):
        rid = ev.get("round")
        u = per_round_up.get(int(rid), {"n": 0, "bytes": 0}) \
            if rid is not None else {"n": 0, "bytes": 0}
        dur_ms = float(ev.get("dur_ms", 0.0))
        row = by_rid.get(rid)
        if row is None:
            order.append(rid)
            by_rid[rid] = {
                "round": rid,
                "n_participants": ev.get("n_participants"),
                "n_cohorts": ev.get("n_cohorts"),
                "n_uplinks": u["n"],
                "uplink_bytes": u["bytes"],
                "bytes_sent": ev.get("bytes_sent"),
                "bytes_delivered": ev.get("bytes_delivered"),
                "queue_depth": ev.get("queue_depth"),
                "bytes_in_flight": ev.get("bytes_in_flight"),
                "merged_version": ev.get("merged_version"),
                "dur_ms": dur_ms,
            }
            continue
        for f in ("n_participants", "n_cohorts", "bytes_sent",
                  "bytes_delivered"):
            if ev.get(f) is not None:
                row[f] = (row[f] or 0) + ev[f]
        for f in ("queue_depth", "bytes_in_flight"):
            if ev.get(f) is not None:
                row[f] = ev[f]
        if ev.get("merged_version") is not None:
            row["merged_version"] = ev["merged_version"]
        row["dur_ms"] += dur_ms
    round_rows = []
    for rid in order:
        row = by_rid[rid]
        dur_ms = row["dur_ms"]
        row["uplinks_per_sec"] = (row["n_uplinks"] / (dur_ms / 1e3)) \
            if dur_ms else None
        round_rows.append(row)
    for d in decode.values():
        d["mean_ms"] = d["total_ms"] / d["count"] if d["count"] else 0.0
    return {"n_events": len(events), "kinds": dict(kinds), "uplinks": up,
            "ingest": ingest, "rounds": round_rows,
            "decode": {str(k): v for k, v in sorted(
                decode.items(), key=lambda kv: str(kv[0]))},
            "merges": merges,
            "admission": {"n": admission["n"], "bytes": admission["bytes"],
                          "verdicts": dict(admission["verdicts"]),
                          "verdict_bytes": dict(admission["verdict_bytes"]),
                          "reasons": dict(admission["reasons"])},
            "migrations": migrations, "faults": dict(faults),
            "retries": retries, "recoveries": recoveries}


def check_bytes(summary: Dict[str, Any]) -> List[str]:
    """§2.8 invariant: per round, Σ uplink-event ``nbytes`` (measured
    from each CodePayload) == the round ledger's ``bytes_sent``.
    Returns human-readable mismatch strings (empty == pass)."""
    problems = []
    if summary["uplinks"]["n"] == 0:
        problems.append("trace holds no uplink events — nothing recorded")
    for row in summary["rounds"]:
        sent = row.get("bytes_sent")
        if sent is None:
            continue
        if int(sent) != int(row["uplink_bytes"]):
            problems.append(
                f"round {row['round']}: uplink events sum to "
                f"{row['uplink_bytes']} B but the round ledger sent "
                f"{sent} B")
    # continuous-ingest conservation: every byte that hit the wire is
    # either in the store, refused-and-witnessed, a deduplicated
    # retransmit, or still in flight
    adm = summary.get("admission", {"n": 0})
    rows = summary["rounds"]
    if adm["n"] and rows and all(r.get("bytes_in_flight") is not None
                                 for r in rows):
        rejected = adm["verdict_bytes"].get("rejected", 0)
        duplicate = adm["verdict_bytes"].get("duplicate", 0)
        in_flight = int(rows[-1]["bytes_in_flight"])
        lhs = int(summary["uplinks"]["bytes"])
        rhs = int(summary["ingest"]["bytes"]) + int(rejected) \
            + int(duplicate) + in_flight
        if lhs != rhs:
            problems.append(
                f"conservation: {lhs} B uplinked != {summary['ingest']['bytes']} B "
                f"ingested + {rejected} B rejected + {duplicate} B "
                f"duplicate + {in_flight} B in flight (= {rhs} B)")
    return problems


def bench_rows(summary: Dict[str, Any]) -> List[Dict[str, Any]]:
    """BENCH-style rows (real JSON numbers; ``extra`` is the only
    string field) mirroring the benchmarks/run.py artifact schema."""
    rows = [
        {"name": "n_events", "value": summary["n_events"],
         "extra": "+".join(f"{k}:{v}"
                           for k, v in sorted(summary["kinds"].items()))},
        {"name": "uplinks", "value": summary["uplinks"]["n"],
         "extra": f"dropped={summary['uplinks']['dropped']}"},
        {"name": "uplink_bytes", "value": summary["uplinks"]["bytes"],
         "extra": "measured_sum_of_CodePayload_nbytes"},
        {"name": "ingested", "value": summary["ingest"]["n"], "extra": ""},
        {"name": "ingested_bytes", "value": summary["ingest"]["bytes"],
         "extra": ""},
        {"name": "rounds", "value": len(summary["rounds"]), "extra": ""},
        {"name": "merges", "value": len(summary["merges"]),
         "extra": "+".join(f"v{m}" for m in summary["merges"])},
    ]
    timed = [r for r in summary["rounds"] if r["dur_ms"]]
    if timed:
        n = len(timed)
        rows.append({"name": "round_ms_mean",
                     "value": sum(r["dur_ms"] for r in timed) / n,
                     "extra": f"{n}rounds"})
        ups = [r["uplinks_per_sec"] for r in timed
               if r["uplinks_per_sec"] is not None]
        if ups:
            rows.append({"name": "uplinks_per_sec_mean",
                         "value": sum(ups) / len(ups),
                         "extra": f"peak={max(ups):.1f}"})
        rows.append({"name": "bytes_per_round_mean",
                     "value": sum(r["uplink_bytes"] for r in timed) / n,
                     "extra": ""})
    for v, d in summary["decode"].items():
        rows.append({"name": f"decode_v{v}_ms_mean", "value": d["mean_ms"],
                     "extra": f"{d['count']}dispatches_"
                              f"{d['n_samples']}samples"})
    adm = summary.get("admission", {"n": 0})
    if adm["n"]:
        for v in sorted(adm["verdicts"]):
            rows.append({"name": f"admission_{v}",
                         "value": adm["verdicts"][v], "extra": ""})
            rows.append({"name": f"admission_{v}_bytes",
                         "value": adm["verdict_bytes"].get(v, 0),
                         "extra": "stays on the §2.8 ledger"})
        for k in sorted(adm["reasons"]):
            rows.append({"name": f"admission_reason_{k}",
                         "value": adm["reasons"][k], "extra": ""})
    if summary.get("migrations"):
        rows.append({"name": "migrations",
                     "value": len(summary["migrations"]),
                     "extra": "+".join(
                         f"{m.get('phase')}:{m.get('src')}->{m.get('dst')}"
                         for m in summary["migrations"])})
    if summary.get("faults"):
        rows.append({"name": "faults_injected",
                     "value": sum(summary["faults"].values()),
                     "extra": "+".join(f"{k}:{v}" for k, v in
                                       sorted(summary["faults"].items()))})
        for k in sorted(summary["faults"]):
            rows.append({"name": f"fault_{k}",
                         "value": summary["faults"][k], "extra": ""})
    if summary.get("retries"):
        rows.append({"name": "retries", "value": summary["retries"],
                     "extra": "transient-refused envelopes retransmitted"})
    for r in summary.get("recoveries", []):
        rows.append({"name": "recovery_ms",
                     "value": float(r.get("dur_ms", 0.0)),
                     "extra": f"snap_tick={r.get('snapshot_tick')}_"
                              f"replayed={r.get('n_replayed')}"})
    return rows


def render(summary: Dict[str, Any]) -> str:
    """Plain-text view of one trace."""
    out = [f"events: {summary['n_events']}  "
           + "  ".join(f"{k}={v}" for k, v in sorted(
               summary["kinds"].items()))]
    u = summary["uplinks"]
    out.append(f"uplinks: {u['n']} payloads, {u['bytes']} B measured "
               f"({u['dropped']} dropped, {u['dropped_bytes']} B burned)")
    i = summary["ingest"]
    out.append(f"ingested: {i['n']} payloads, {i['bytes']} B into the store")
    if summary["rounds"]:
        out.append(f"{'round':>5} {'parts':>6} {'uplinks':>7} "
                   f"{'bytes':>10} {'queue':>5} {'ms':>8} {'up/s':>8}")
        for r in summary["rounds"]:
            ups = (f"{r['uplinks_per_sec']:8.1f}"
                   if r["uplinks_per_sec"] is not None else "       -")
            out.append(
                f"{r['round']!s:>5} {r['n_participants']!s:>6} "
                f"{r['n_uplinks']:>7} {r['uplink_bytes']:>10} "
                f"{r['queue_depth']!s:>5} {r['dur_ms']:8.1f} {ups}"
                + (f"  merged->v{r['merged_version']}"
                   if r.get("merged_version") is not None else ""))
    for v, d in summary["decode"].items():
        out.append(f"decode v{v}: {d['count']} dispatches, "
                   f"{d['mean_ms']:.2f} ms mean, {d['n_samples']} samples")
    if summary["merges"]:
        out.append("merges: " + ", ".join(f"v{m}" for m in
                                          summary["merges"]))
    adm = summary.get("admission", {"n": 0})
    if adm["n"]:
        out.append("admission: " + "  ".join(
            f"{v}={adm['verdicts'][v]} ({adm['verdict_bytes'].get(v, 0)} B)"
            for v in sorted(adm["verdicts"])))
        if adm["reasons"]:
            out.append("  reasons: " + "  ".join(
                f"{k}={n}" for k, n in sorted(adm["reasons"].items())))
    for m in summary.get("migrations", []):
        line = (f"migration {m.get('phase')}: v{m.get('src')} -> "
                f"v{m.get('dst')} ({m.get('policy')})")
        if m.get("phase") == "complete":
            line += (f", {m.get('src_records')} src records "
                     f"{m.get('src_bytes')} B left, "
                     f"{m.get('n_reencoded')} re-encoded")
        out.append(line)
    if summary.get("faults"):
        out.append("faults injected: " + "  ".join(
            f"{k}={v}" for k, v in sorted(summary["faults"].items())))
    if summary.get("retries"):
        out.append(f"retries: {summary['retries']} envelopes retransmitted")
    for r in summary.get("recoveries", []):
        out.append(f"recovery: snapshot t={r.get('snapshot_tick')}, "
                   f"{r.get('n_replayed')} journal entries replayed in "
                   f"{r.get('dur_ms', 0.0):.1f} ms -> tick {r.get('tick')}, "
                   f"{r.get('store_records')} records")
    return "\n".join(out)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="summarize a FlightRecorder JSONL trace")
    ap.add_argument("trace", help="path to the .jsonl trace")
    ap.add_argument("--json", dest="json_out", default="",
                    help="also write a BENCH-style JSON section here")
    ap.add_argument("--check", action="store_true",
                    help="fail unless per-round trace Σ-bytes equal the "
                         "round ledgers' measured bytes_sent (§2.8)")
    args = ap.parse_args(argv)

    summary = summarize(load_events(args.trace))
    print(render(summary))
    problems = check_bytes(summary)
    if args.json_out:
        with open(args.json_out, "w") as fh:
            json.dump({"section": "obs", "trace": args.trace,
                       "bytes_check_ok": not problems,
                       "rows": bench_rows(summary)}, fh, indent=1)
        print(f"wrote {args.json_out}")
    if args.check:
        if problems:
            for p in problems:
                print(f"BYTES CHECK FAILED: {p}", file=sys.stderr)
            return 1
        print(f"bytes check OK: trace Σ-bytes == round ledgers across "
              f"{len(summary['rounds'])} rounds")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""repro.obs — flight recorder + metrics plane for the OCTOPUS pipeline.

Opt-in tracing of every uplink from encode dispatch to codebook merge:

    from repro import obs

    with obs.recording("trace.jsonl"):
        client.round(batch)            # every layer logs to the trace

    with obs.dispatch_monitor() as counts:
        client.round(batch)
    assert (counts.encoder_passes, counts.encode_dispatches) == (1, 1)

Default is a no-op: ``obs.active()`` returns None and instrumented call
sites skip all event work. Setting ``$OCTOPUS_TRACE=<path>`` before the
process imports ``repro.obs`` installs a recorder automatically (how CI
traces the unmodified examples). Summaries: ``python -m repro.obs.report
trace.jsonl``. See ``recorder.py`` for the event schema and the §2.5
metadata-only capture rule.
"""
from .metrics import (Counter, DispatchCounts, Gauge, Histogram,
                      MetricsRegistry, dispatch_monitor)
from .recorder import (ENV_VAR, EVENT_KINDS, PAYLOAD_META_FIELDS,
                       FlightRecorder, active, install, install_from_env,
                       payload_meta, recording, uninstall)

__all__ = [
    "Counter",
    "DispatchCounts",
    "ENV_VAR",
    "EVENT_KINDS",
    "FlightRecorder",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "PAYLOAD_META_FIELDS",
    "active",
    "dispatch_monitor",
    "install",
    "install_from_env",
    "payload_meta",
    "recording",
    "uninstall",
]

install_from_env()

"""Synthetic content/style factorized datasets.

The paper evaluates on MNIST / CelebA / Speech — none available offline, so
we generate procedural data with an explicit (content, style) factorization
that lets every paper claim be tested *mechanistically*:

  * images: content = shape class (which glyph is drawn), style = identity
    (per-identity color/offset/scale transform). The downstream task is
    shape classification; the private attribute is identity — exactly the
    MNIST circle/digit and CelebA smile/identity splits.
  * speech: content = phoneme sequence (each phoneme is a characteristic
    band-pattern over feature channels), style = speaker (per-speaker
    channel gain/bias). Downstream = phoneme recognition; private =
    speaker id.

Everything is pure JAX so the generators jit and run on-device.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class LabeledData(NamedTuple):
    x: jax.Array          # images (N,H,W,C) or speech (N,T,C)
    content: jax.Array    # public label (N,)
    style: jax.Array      # private label / identity (N,)


# ------------------------------------------------------------------ images

N_SHAPES = 8


def _shape_stencils(size: int):
    """(N_SHAPES, size, size) binary glyphs: circle, square, cross, ..."""
    r = jnp.linspace(-1.0, 1.0, size)
    yy, xx = jnp.meshgrid(r, r, indexing="ij")
    rad = jnp.sqrt(xx ** 2 + yy ** 2)
    circle = (jnp.abs(rad - 0.6) < 0.18).astype(jnp.float32)
    disk = (rad < 0.55).astype(jnp.float32)
    square = ((jnp.abs(xx) < 0.6) & (jnp.abs(yy) < 0.6)
              & ((jnp.abs(xx) > 0.35) | (jnp.abs(yy) > 0.35))).astype(jnp.float32)
    cross = ((jnp.abs(xx) < 0.18) | (jnp.abs(yy) < 0.18)).astype(jnp.float32)
    diag = (jnp.abs(xx - yy) < 0.22).astype(jnp.float32)
    anti = (jnp.abs(xx + yy) < 0.22).astype(jnp.float32)
    hbar = (jnp.abs(yy) < 0.25).astype(jnp.float32)
    vbar = (jnp.abs(xx) < 0.25).astype(jnp.float32)
    return jnp.stack([circle, disk, square, cross, diag, anti, hbar, vbar])


def make_images(key, n: int, *, size: int = 32, channels: int = 3,
                n_identities: int = 10) -> LabeledData:
    """Factorized images: x = style_transform(identity)(glyph(content))."""
    kc, ks, kn, kg, kb = jax.random.split(key, 5)
    content = jax.random.randint(kc, (n,), 0, N_SHAPES)
    style = jax.random.randint(ks, (n,), 0, n_identities)
    stencils = _shape_stencils(size)
    base = stencils[content][..., None]                       # (n, s, s, 1)

    # per-identity style: channel gains, bias, background tint
    ident_keys = jax.random.split(kg, 3)
    gains = 0.5 + jax.random.uniform(ident_keys[0], (n_identities, channels))
    bias = 0.3 * jax.random.normal(ident_keys[1], (n_identities, channels))
    tint = 0.2 * jax.random.uniform(ident_keys[2], (n_identities, channels))

    g = gains[style][:, None, None, :]
    b = bias[style][:, None, None, :]
    t = tint[style][:, None, None, :]
    noise = 0.05 * jax.random.normal(kn, (n, size, size, channels))
    x = base * g + (1.0 - base) * t + b + noise
    return LabeledData(x=x, content=content, style=style)


# ------------------------------------------------------------------ speech

N_PHONEMES = 16


def _phoneme_bank(channels: int):
    """(N_PHONEMES, channels) characteristic spectral patterns."""
    c = jnp.arange(channels, dtype=jnp.float32)
    pat = []
    for p in range(N_PHONEMES):
        centre = (p + 0.5) * channels / N_PHONEMES
        width = channels / (N_PHONEMES * 1.5)
        pat.append(jnp.exp(-0.5 * ((c - centre) / width) ** 2)
                   + 0.3 * jnp.sin(c * (p + 1) * 0.37))
    return jnp.stack(pat)


def make_speech(key, n: int, *, frames: int = 64, channels: int = 16,
                n_speakers: int = 10, phonemes_per_clip: int = 4
                ) -> LabeledData:
    """Speech-like clips: phoneme band patterns x speaker channel transform.

    content label = first phoneme (clip-level class for the classifier);
    full phoneme sequence is recoverable per frame.
    """
    kp, ks, kg, kb, kn = jax.random.split(key, 5)
    seq = jax.random.randint(kp, (n, phonemes_per_clip), 0, N_PHONEMES)
    style = jax.random.randint(ks, (n,), 0, n_speakers)
    bank = _phoneme_bank(channels)

    seg = frames // phonemes_per_clip
    per_frame = jnp.repeat(seq, seg, axis=1)[:, :frames]      # (n, frames)
    base = bank[per_frame]                                    # (n, frames, C)

    gains = 0.5 + jax.random.uniform(kg, (n_speakers, channels))
    bias = 0.3 * jax.random.normal(kb, (n_speakers, channels))
    x = base * gains[style][:, None, :] + bias[style][:, None, :]
    x = x + 0.05 * jax.random.normal(kn, (n, frames, channels))
    return LabeledData(x=x, content=seq[:, 0], style=style)


# ----------------------------------------------------------- LM token data

def make_tokens(key, n_seqs: int, seq_len: int, vocab: int):
    """Synthetic LM corpus: Zipf-ish marginals + local bigram structure so
    the loss actually decreases during example training runs."""
    k1, k2 = jax.random.split(key)
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    probs = 1.0 / ranks
    probs = probs / probs.sum()
    first = jax.random.categorical(k1, jnp.log(probs)[None, :],
                                   shape=(n_seqs, 1))

    def step(tok, k):
        # next token correlated with previous (shift + noise)
        nxt = jax.random.categorical(k, jnp.log(probs)[None, :],
                                     shape=(n_seqs,))
        mix = jax.random.bernoulli(jax.random.fold_in(k, 1), 0.5, (n_seqs,))
        out = jnp.where(mix, (tok + 1) % vocab, nxt)
        return out, out

    keys = jax.random.split(k2, seq_len - 1)
    _, rest = jax.lax.scan(step, first[:, 0], keys)
    return jnp.concatenate([first, rest.T], axis=1).astype(jnp.int32)

from .federated import (batches, holdout_atd, partition, partition_stacked,
                        stacked_batches, train_test_split)
from .synthetic import LabeledData, make_images, make_speech, make_tokens

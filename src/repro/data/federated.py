"""Non-IID federated partitioner (§3.1 settings).

Splits a labeled dataset across M clients under three regimes:
  * iid          — uniform random assignment (the paper's best case)
  * worst        — sorted by label, each client gets a single class
  * skewed(p)    — fraction p assigned by label, remainder uniform
                   (the paper's 20% moderate case)
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .synthetic import LabeledData


def _take(data: LabeledData, idx) -> LabeledData:
    return LabeledData(x=data.x[idx], content=data.content[idx],
                       style=data.style[idx])


def partition(data: LabeledData, n_clients: int, *, regime: str = "iid",
              skew: float = 0.2, seed: int = 0) -> List[LabeledData]:
    """Returns a list of per-client shards."""
    n = int(data.content.shape[0])
    rng = np.random.default_rng(seed)
    labels = np.asarray(data.content)

    if regime == "iid":
        perm = rng.permutation(n)
    elif regime == "worst":
        perm = np.argsort(labels, kind="stable")
    elif regime == "skewed":
        n_sorted = int(n * skew)
        sel = rng.permutation(n)
        sorted_part = sel[:n_sorted][np.argsort(labels[sel[:n_sorted]],
                                                kind="stable")]
        rest = rng.permutation(sel[n_sorted:])
        perm = np.concatenate([sorted_part, rest])
    else:
        raise ValueError(regime)

    shards = np.array_split(perm, n_clients)
    return [_take(data, jnp.asarray(s)) for s in shards]


def partition_stacked(data: LabeledData, n_clients: int, *,
                      regime: str = "iid", skew: float = 0.2,
                      seed: int = 0) -> LabeledData:
    """Equal-size client shards stacked on a leading client axis.

    Returns a LabeledData whose fields are (n_clients, n_per, ...) — the
    layout the batched sim engine (repro.sim) and fedavg_train_batched
    vmap over. Shards are truncated to the smallest shard size so they
    stack; with array_split that drops at most n_clients-1 samples.
    """
    shards = partition(data, n_clients, regime=regime, skew=skew, seed=seed)
    n_per = min(int(s.x.shape[0]) for s in shards)
    return LabeledData(
        x=jnp.stack([s.x[:n_per] for s in shards]),
        content=jnp.stack([s.content[:n_per] for s in shards]),
        style=jnp.stack([s.style[:n_per] for s in shards]))


def stacked_batches(stacked: LabeledData, batch_size: int, *, seed: int = 0,
                    epochs: int = 1):
    """Per-client shuffled minibatches over a partition_stacked layout.

    Yields LabeledData with (n_clients, batch_size, ...) fields — one
    round's worth of local data for every client at once.
    """
    C, n = stacked.x.shape[0], stacked.x.shape[1]
    rng = np.random.default_rng(seed)
    for _ in range(epochs):
        perms = np.stack([rng.permutation(n) for _ in range(C)])  # (C, n)
        for i in range(0, n - batch_size + 1, batch_size):
            sel = jnp.asarray(perms[:, i:i + batch_size])          # (C, B)
            yield LabeledData(
                x=jnp.take_along_axis(
                    stacked.x, sel.reshape(sel.shape + (1,) * (
                        stacked.x.ndim - 2)), axis=1),
                content=jnp.take_along_axis(stacked.content, sel, axis=1),
                style=jnp.take_along_axis(stacked.style, sel, axis=1))


def train_test_split(data: LabeledData, test_frac: float = 0.2, seed: int = 0):
    n = int(data.content.shape[0])
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    cut = int(n * (1 - test_frac))
    return _take(data, jnp.asarray(perm[:cut])), _take(data, jnp.asarray(perm[cut:]))


def holdout_atd(data: LabeledData, atd_frac: float = 0.15, seed: int = 1):
    """§3.1: 15% of Tr held out as the public ATD set for server pretraining."""
    n = int(data.content.shape[0])
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    cut = int(n * atd_frac)
    return _take(data, jnp.asarray(perm[cut:])), _take(data, jnp.asarray(perm[:cut]))


def batches(data: LabeledData, batch_size: int, *, seed: int = 0,
            epochs: int = 1):
    """Shuffled minibatch iterator (numpy-side, feeds jit'd steps)."""
    n = int(data.content.shape[0])
    rng = np.random.default_rng(seed)
    for _ in range(epochs):
        perm = rng.permutation(n)
        for i in range(0, n - batch_size + 1, batch_size):
            idx = jnp.asarray(perm[i:i + batch_size])
            yield _take(data, idx)

"""Non-IID federated partitioner (§3.1 settings).

Splits a labeled dataset across M clients under three regimes:
  * iid          — uniform random assignment (the paper's best case)
  * worst        — sorted by label, each client gets a single class
  * skewed(p)    — fraction p assigned by label, remainder uniform
                   (the paper's 20% moderate case)
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .synthetic import LabeledData


def _take(data: LabeledData, idx) -> LabeledData:
    return LabeledData(x=data.x[idx], content=data.content[idx],
                       style=data.style[idx])


def partition(data: LabeledData, n_clients: int, *, regime: str = "iid",
              skew: float = 0.2, seed: int = 0) -> List[LabeledData]:
    """Returns a list of per-client shards."""
    n = int(data.content.shape[0])
    rng = np.random.default_rng(seed)
    labels = np.asarray(data.content)

    if regime == "iid":
        perm = rng.permutation(n)
    elif regime == "worst":
        perm = np.argsort(labels, kind="stable")
    elif regime == "skewed":
        n_sorted = int(n * skew)
        sel = rng.permutation(n)
        sorted_part = sel[:n_sorted][np.argsort(labels[sel[:n_sorted]],
                                                kind="stable")]
        rest = rng.permutation(sel[n_sorted:])
        perm = np.concatenate([sorted_part, rest])
    else:
        raise ValueError(regime)

    shards = np.array_split(perm, n_clients)
    return [_take(data, jnp.asarray(s)) for s in shards]


def train_test_split(data: LabeledData, test_frac: float = 0.2, seed: int = 0):
    n = int(data.content.shape[0])
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    cut = int(n * (1 - test_frac))
    return _take(data, jnp.asarray(perm[:cut])), _take(data, jnp.asarray(perm[cut:]))


def holdout_atd(data: LabeledData, atd_frac: float = 0.15, seed: int = 1):
    """§3.1: 15% of Tr held out as the public ATD set for server pretraining."""
    n = int(data.content.shape[0])
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    cut = int(n * atd_frac)
    return _take(data, jnp.asarray(perm[cut:])), _take(data, jnp.asarray(perm[:cut]))


def batches(data: LabeledData, batch_size: int, *, seed: int = 0,
            epochs: int = 1):
    """Shuffled minibatch iterator (numpy-side, feeds jit'd steps)."""
    n = int(data.content.shape[0])
    rng = np.random.default_rng(seed)
    for _ in range(epochs):
        perm = rng.permutation(n)
        for i in range(0, n - batch_size + 1, batch_size):
            idx = jnp.asarray(perm[i:i + batch_size])
            yield _take(data, idx)

"""Whisper base — encoder-decoder audio backbone [arXiv:2212.04356].
The mel-spectrogram + conv frontend is a STUB by assignment: input_specs
provides (B, 1500, d_model) frame embeddings; this config is the
transformer that consumes them. Decode = decoder step against frozen
encoder output."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio", source="arXiv:2212.04356",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
    vocab_size=51865, norm="layernorm", activation="gelu",
    is_encoder_decoder=True, n_encoder_layers=6, n_audio_frames=1500,
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="audio", source="reduced",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, d_ff=512,
    vocab_size=512, norm="layernorm", activation="gelu",
    is_encoder_decoder=True, n_encoder_layers=2, n_audio_frames=64,
)

"""xLSTM 350M — mLSTM stack with interleaved sLSTM blocks
[arXiv:2405.04517]. Attention-free; natively O(T) so long_500k runs
without a window."""
from .base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm", source="arXiv:2405.04517",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=50304, norm="layernorm",
    xlstm=XLSTMConfig(slstm_every=6, conv_dim=4, proj_factor=2.0),
)

SMOKE = ModelConfig(
    name="xlstm-smoke", family="ssm", source="reduced",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=512, norm="layernorm",
    xlstm=XLSTMConfig(slstm_every=2, conv_dim=4, proj_factor=2.0),
)

"""Qwen3 0.6B — dense GQA with per-head qk RMSNorm [hf:Qwen/Qwen3-8B
family card]. head_dim fixed at 128 (> d_model/n_heads)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b", family="dense", source="hf:Qwen/Qwen3-8B",
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=3072, vocab_size=151936, qk_norm=True, rope_theta=1e6,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="qwen3-smoke", family="dense", source="reduced",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
    d_ff=512, vocab_size=512, qk_norm=True, rope_theta=1e6,
    tie_embeddings=True,
)

"""DeepSeek-V3 671B — MLA + 1 shared + 256 routed top-8 experts + MTP
[arXiv:2412.19437]. First 3 layers dense (d_ff 18432); MoE layers use
2048-wide experts with sigmoid routing. The assignment's d_ff=2048 is the
per-expert hidden size."""
from .base import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe", source="arXiv:2412.19437",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, d_ff=18432,
    vocab_size=129280, use_mla=True, use_mtp=True,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=256, n_experts_per_tok=8, n_shared_experts=1,
                  d_ff_expert=2048, first_dense_layers=3,
                  router_scoring="sigmoid"),
)

SMOKE = ModelConfig(
    name="deepseek-smoke", family="moe", source="reduced",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, d_ff=512,
    vocab_size=512, use_mla=True, use_mtp=True,
    mla=MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                  qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32),
    moe=MoEConfig(n_experts=4, n_experts_per_tok=2, n_shared_experts=1,
                  d_ff_expert=128, first_dense_layers=1,
                  router_scoring="sigmoid", capacity_factor=4.0),
)

"""Qwen3-MoE 30B-A3B — 128 experts top-8, every layer MoE, GQA kv=4,
qk-norm [hf:Qwen/Qwen3-30B-A3B]. 768-wide experts (the assignment's
d_ff); no shared expert."""
from .base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe", source="hf:Qwen/Qwen3-30B-A3B",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=768, vocab_size=151936, qk_norm=True, rope_theta=1e6,
    moe=MoEConfig(n_experts=128, n_experts_per_tok=8, d_ff_expert=768),
)

SMOKE = ModelConfig(
    name="qwen3-moe-smoke", family="moe", source="reduced",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
    d_ff=256, vocab_size=512, qk_norm=True,
    moe=MoEConfig(n_experts=4, n_experts_per_tok=2, d_ff_expert=256,
                  capacity_factor=4.0),
)

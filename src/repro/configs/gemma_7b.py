"""Gemma 7B — dense, GeGLU, head_dim=256 [arXiv:2403.08295]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b", family="dense", source="arXiv:2403.08295",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24576, vocab_size=256000, activation="gelu",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma-smoke", family="dense", source="reduced",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
    d_ff=512, vocab_size=512, activation="gelu", tie_embeddings=True,
)

"""Jamba v0.1 52B — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887]. Attention every 8th layer; MoE replaces the MLP on
every other layer (period 2, offset 1)."""
from .base import MoEConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid", source="arXiv:2403.19887",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=65536, rope_theta=10000.0,
    attn_layer_period=8, attn_layer_offset=4,
    moe=MoEConfig(n_experts=16, n_experts_per_tok=2, d_ff_expert=14336,
                  layer_period=2, layer_offset=1),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    # long_500k: attention layers drop to a sliding window (Mamba layers are
    # already O(T)); window set by the serve path for that shape only.
)

SMOKE = ModelConfig(
    name="jamba-smoke", family="hybrid", source="reduced",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
    vocab_size=512, attn_layer_period=2, attn_layer_offset=1,
    moe=MoEConfig(n_experts=4, n_experts_per_tok=2, d_ff_expert=512,
                  layer_period=2, layer_offset=0, capacity_factor=4.0),
    ssm=SSMConfig(d_state=8, d_conv=4, expand=2),
)

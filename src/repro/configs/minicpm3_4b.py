"""MiniCPM3 4B — dense with Multi-head Latent Attention
[hf:openbmb/MiniCPM3-4B]: q_lora 768, kv_lora 256, 40 heads."""
from .base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b", family="dense", source="hf:openbmb/MiniCPM3-4B",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, d_ff=6400,
    vocab_size=73448, use_mla=True,
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                  qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64),
)

SMOKE = ModelConfig(
    name="minicpm3-smoke", family="dense", source="reduced",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, d_ff=512,
    vocab_size=512, use_mla=True,
    mla=MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                  qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32),
)

"""Chameleon 34B — early-fusion VLM [arXiv:2405.09818]. Image VQ tokens
share the text vocabulary (the OCTOPUS-native case: VQ codes ARE the
transmitted representation); the vision tokenizer is a stub — input_specs
feeds mixed-modal token ids directly. qk-norm per the paper's stability fix."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm", source="arXiv:2405.09818",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016,
    vocab_size=65536, qk_norm=True, rope_theta=10000.0,
    is_early_fusion_vlm=True,
)

SMOKE = ModelConfig(
    name="chameleon-smoke", family="vlm", source="reduced",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
    vocab_size=512, qk_norm=True, is_early_fusion_vlm=True,
)

"""Model / run configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``. The config is a
plain frozen dataclass so it hashes into jit static args and prints cleanly
into EXPERIMENTS.md tables.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts sub-config (GShard/DeepSeek-style routed experts)."""
    n_experts: int = 0                 # routed experts
    n_experts_per_tok: int = 0         # top-k
    n_shared_experts: int = 0          # DeepSeek shared experts (always-on)
    d_ff_expert: int = 0               # per-expert hidden size
    layer_period: int = 1              # every `period`-th layer is MoE ...
    layer_offset: int = 0              # ... starting at this index
    first_dense_layers: int = 0        # DeepSeek-V3: first k layers stay dense
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    router_dtype: str = "float32"
    router_scoring: str = "softmax"    # softmax | sigmoid (DeepSeek-V3)
    dispatch: str = "shardmap"         # shardmap (local EP + one psum) |
                                       # flat (E*C buffer, SPMD-partitioned)
                                       # | bucketed (refuted, kept for
                                       #   comparison — see §Perf)

    @property
    def enabled(self) -> bool:
        return self.n_experts > 0


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2/V3, MiniCPM3)."""
    q_lora_rank: int = 0               # 0 = full-rank q projection
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_head_dim + self.qk_rope_head_dim


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-style selective SSM sub-config (Jamba mixer layers)."""
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                   # 0 -> ceil(d_model/16)


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block layout: sLSTM layers interleaved into an mLSTM stack."""
    slstm_every: int = 6               # layer i is sLSTM when (i+1) % every == 0
    conv_dim: int = 4                  # causal-conv width in mLSTM blocks
    proj_factor: float = 2.0           # up-projection factor in mLSTM
    slstm_proj_factor: float = 1.333   # ffn factor of sLSTM post-block


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"              # dense|moe|hybrid|ssm|vlm|audio
    source: str = ""                   # citation for the config numbers

    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0                  # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    max_seq_len: int = 8192

    norm: str = "rmsnorm"              # rmsnorm|layernorm
    norm_eps: float = 1e-6
    activation: str = "silu"           # silu (swiglu) | gelu (geglu)
    qk_norm: bool = False              # Qwen3-style per-head q/k RMSNorm
    rope_theta: float = 10000.0
    sliding_window: int = 0            # 0 = full attention
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    attn_layer_period: int = 1         # hybrid: every k-th layer is attention
    attn_layer_offset: int = 0
    mixer: str = "attention"           # attention|mamba|mlstm (default mixer)

    use_mla: bool = False
    mla: MLAConfig = field(default_factory=MLAConfig)
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    xlstm: XLSTMConfig = field(default_factory=XLSTMConfig)

    # multi-token prediction (DeepSeek-V3): one extra MTP transformer layer
    use_mtp: bool = False
    mtp_loss_weight: float = 0.3

    # encoder-decoder (Whisper backbone). Frontend (mel+conv) is a STUB: the
    # model consumes precomputed frame embeddings of shape (B, n_frames, d).
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    n_audio_frames: int = 1500

    # vlm (Chameleon): early fusion — image VQ tokens share the text vocab.
    # The vision tokenizer is a STUB; input_specs feeds token ids directly.
    is_early_fusion_vlm: bool = False

    dtype: str = "float32"             # compute dtype
    param_dtype: str = "float32"

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def layer_kinds(self) -> Tuple[Tuple[str, str], ...]:
        """(mixer_kind, ffn_kind) per layer.

        mixer_kind in {attn, mla, mamba, mlstm, slstm}
        ffn_kind   in {dense, moe, none}
        """
        kinds = []
        for i in range(self.n_layers):
            if self.family == "ssm":
                x = self.xlstm
                mixer = "slstm" if (i + 1) % x.slstm_every == 0 else "mlstm"
                ffn = "none"
            elif self.family == "hybrid":
                is_attn = (i % self.attn_layer_period) == self.attn_layer_offset
                mixer = "attn" if is_attn else "mamba"
                ffn = "dense"
            elif self.use_mla:
                mixer, ffn = "mla", "dense"
            else:
                mixer, ffn = "attn", "dense"
            if self.moe.enabled and ffn == "dense":
                m = self.moe
                if i >= m.first_dense_layers and (i % m.layer_period) == m.layer_offset:
                    ffn = "moe"
            kinds.append((mixer, ffn))
        return tuple(kinds)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, hd = self.d_model, self.resolved_head_dim
        nq, nkv = self.n_heads, self.n_kv_heads
        total = self.vocab_size * d                      # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d                 # lm head
        for mixer, ffn in self.layer_kinds():
            if mixer == "attn":
                total += d * nq * hd + 2 * d * nkv * hd + nq * hd * d
            elif mixer == "mla":
                m = self.mla
                qin = m.q_lora_rank if m.q_lora_rank else d
                if m.q_lora_rank:
                    total += d * m.q_lora_rank
                total += qin * nq * m.qk_head_dim
                total += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                total += m.kv_lora_rank * nq * (m.qk_nope_head_dim + m.v_head_dim)
                total += nq * m.v_head_dim * d
            elif mixer == "mamba":
                s = self.ssm
                di = s.expand * d
                dt = s.dt_rank if s.dt_rank else -(-d // 16)
                total += d * 2 * di + di * s.d_conv + di * (dt + 2 * s.d_state)
                total += dt * di + di * s.d_state + di + di * d
            elif mixer == "mlstm":
                x = self.xlstm
                di = int(x.proj_factor * d)
                total += 2 * d * di + di * x.conv_dim + 3 * di * di // 4 + di * d
            elif mixer == "slstm":
                total += 4 * d * d + int(2 * self.xlstm.slstm_proj_factor * d * d)
            if ffn == "dense":
                total += 3 * d * self.d_ff
            elif ffn == "moe":
                m = self.moe
                total += d * m.n_experts                                   # router
                total += m.n_experts * 3 * d * m.d_ff_expert               # routed
                total += m.n_shared_experts * 3 * d * m.d_ff_expert        # shared
            total += 2 * d                                                  # norms
        if self.is_encoder_decoder:
            for _ in range(self.n_encoder_layers):
                total += 4 * d * nq * hd + 3 * d * self.d_ff + 2 * d       # enc self+ffn
            for _ in range(self.n_layers):
                total += 2 * d * nq * hd + 2 * d * nkv * hd + d            # cross attn
        if self.use_mtp:
            total += d * nq * hd + 2 * d * nkv * hd + nq * hd * d + 3 * d * self.d_ff
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k + shared experts)."""
        if not self.moe.enabled:
            return self.param_count()
        m = self.moe
        inactive_per_moe_layer = (m.n_experts - m.n_experts_per_tok) * 3 * self.d_model * m.d_ff_expert
        n_moe_layers = sum(1 for _, f in self.layer_kinds() if f == "moe")
        return self.param_count() - n_moe_layers * inactive_per_moe_layer

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape."""
    name: str
    seq_len: int
    global_batch: int
    mode: str                          # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    grad_clip: float = 1.0
    seed: int = 0
    remat: bool = True

"""Architecture registry: the 10 assigned configs + the paper's own DVQ-AE.

``get_config(name)`` returns the FULL assigned config (dry-run only);
``smoke_config(name)`` returns the reduced same-family variant (<=2 layers,
d_model<=512, <=4 experts) used by CPU smoke tests.
"""
from __future__ import annotations

import importlib
from typing import Dict

from .base import ModelConfig

ARCH_IDS = (
    "jamba_v0_1_52b",
    "qwen3_0_6b",
    "chameleon_34b",
    "minicpm3_4b",
    "gemma_7b",
    "xlstm_350m",
    "starcoder2_3b",
    "whisper_base",
    "deepseek_v3_671b",
    "qwen3_moe_30b_a3b",
)

# CLI-facing aliases (match the assignment's hyphenated ids)
ALIASES = {
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "qwen3-0.6b": "qwen3_0_6b",
    "chameleon-34b": "chameleon_34b",
    "minicpm3-4b": "minicpm3_4b",
    "gemma-7b": "gemma_7b",
    "xlstm-350m": "xlstm_350m",
    "starcoder2-3b": "starcoder2_3b",
    "whisper-base": "whisper_base",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
}


def canonical(name: str) -> str:
    return ALIASES.get(name, name.replace("-", "_").replace(".", "_"))


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.CONFIG


def smoke_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.SMOKE


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}

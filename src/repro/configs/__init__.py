from .base import INPUT_SHAPES, MLAConfig, MoEConfig, ModelConfig, ShapeConfig, SSMConfig, TrainConfig, XLSTMConfig
from .registry import ALIASES, ARCH_IDS, all_configs, canonical, get_config, smoke_config

"""StarCoder2 3B — dense GQA kv=2, RoPE, 4k sliding window
[arXiv:2402.19173]. LayerNorm + non-gated-MLP in the original; we keep
LayerNorm and note the gated-MLP substitution in DESIGN.md. 24 heads do
not divide the 16-way model axis — the sharding layer falls back to
hidden-dim tensor parallelism for this arch."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", family="dense", source="arXiv:2402.19173",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, d_ff=12288,
    vocab_size=49152, norm="layernorm", activation="gelu",
    sliding_window=4096, rope_theta=1e5,
)

SMOKE = ModelConfig(
    name="starcoder2-smoke", family="dense", source="reduced",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
    vocab_size=512, norm="layernorm", activation="gelu",
    sliding_window=128, rope_theta=1e5,
)

"""Asynchronous code-server runtime (OCTOPUS Step 6 at production scale).

  store      — CodeStore: capacity-bounded, versioned, lazily-decoded
               store of packed transmissions (supersedes sim.IngestBuffer)
  registry   — CodebookRegistry: immutable per-merge dictionary snapshots
               + staleness-weighted Step 5 merge
  scheduler  — RoundScheduler: partial participation, stragglers, drops,
               client churn — deterministic under one PRNG key
  multitask  — MultiTaskTrainer: N downstream heads from ONE bulk decode
  runtime    — AsyncCodeServer: ties it all to sim.SimEngine per round,
               ingesting every uplink through the unified wire endpoint
               (repro.wire.OctopusServer / CodePayload)
"""
from repro.wire.payload import CodePayload
from repro.wire.session import OctopusServer

from .multitask import MultiTaskTrainer, TaskSpec
from .registry import CodebookRegistry
from .runtime import AsyncCodeServer, RoundStats, UplinkQueue
from .scheduler import (STANDARD_SCENARIOS, DiurnalProfile, RoundEvent,
                        RoundScheduler, Scenario, SchedulerConfig)
from .store import CodeStore, StoreRecord

__all__ = ["AsyncCodeServer", "CodePayload", "CodeStore",
           "CodebookRegistry", "DiurnalProfile", "MultiTaskTrainer",
           "OctopusServer", "RoundEvent", "RoundScheduler", "RoundStats",
           "STANDARD_SCENARIOS", "Scenario", "SchedulerConfig",
           "StoreRecord", "TaskSpec", "UplinkQueue"]

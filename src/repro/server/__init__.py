"""Continuous-ingest code-server runtime (OCTOPUS Step 6 at production
scale).

  store      — CodeStore: one capacity-bounded, versioned, lazily-decoded
               ring buffer of packed transmissions; ShardedCodeStore:
               independent ring buffers per (codebook version, client
               shard) partition
  registry   — CodebookRegistry: immutable per-merge dictionary snapshots
               + staleness-weighted Step 5 merge + rolling
               MigrationWindow (keep / retire / reencode policies)
  scheduler  — RoundScheduler: partial participation, stragglers, drops,
               client churn, open-ended Poisson arrivals — deterministic
               under one PRNG key
  multitask  — MultiTaskTrainer: N downstream heads from ONE bulk decode
  runtime    — ContinuousIngestService: clocked, admission-controlled
               ingest (backpressure verdicts, exactly-once dedup window,
               background bulk decode under a BulkDecodePolicy) with
               journaled crash recovery (``recover``); AsyncCodeServer
               remains the round-quantized shim over it
  persist    — ServerPersistence: append-only ingest journal + atomic
               periodic snapshots of the full durable state
"""
from repro.wire.payload import CodePayload
from repro.wire.session import AdmissionResult, OctopusServer

from .multitask import MultiTaskTrainer, TaskSpec
from .persist import ServerPersistence
from .registry import (MIGRATION_POLICIES, CodebookRegistry,
                       MigrationWindow)
from .runtime import (AsyncCodeServer, BulkDecodePolicy,
                      ContinuousIngestService, RoundStats, TickStats,
                      UplinkQueue)
from .scheduler import (STANDARD_SCENARIOS, DiurnalProfile, RoundEvent,
                        RoundScheduler, Scenario, SchedulerConfig)
from .store import CodeStore, ShardedCodeStore, StoreRecord

__all__ = ["AdmissionResult", "AsyncCodeServer", "BulkDecodePolicy",
           "CodePayload", "CodeStore", "CodebookRegistry",
           "ContinuousIngestService", "DiurnalProfile",
           "MIGRATION_POLICIES", "MigrationWindow", "MultiTaskTrainer",
           "OctopusServer", "RoundEvent", "RoundScheduler", "RoundStats",
           "STANDARD_SCENARIOS", "Scenario", "SchedulerConfig",
           "ServerPersistence", "ShardedCodeStore", "StoreRecord",
           "TaskSpec", "TickStats", "UplinkQueue"]

"""Crash-consistent persistence for the continuous-ingest service.

Two complementary planes, both under one directory:

  * an APPEND-ONLY JOURNAL (``journal.jsonl``) of every state-mutating
    operation — admitted offers (packed words + full carrier metadata +
    envelope), ticks, Step-5 merges (the post-merge dictionary), and
    migration begin/complete ops — flushed per entry like the flight
    recorder, so a kill loses at most a torn final line;
  * PERIODIC SNAPSHOTS of the full durable state: the (sharded) store's
    ring contents, per-version ledgers and reservoir RNG streams, every
    ``CodebookRegistry`` snapshot plus any OPEN migration window, the
    uplink queue (pending payloads + the §2.8 byte ledger), the
    exactly-once dedup window, the admission histograms, and the server
    pytree (via ``repro.checkpoint.save_pytree``). The JSON manifest is
    written LAST with an atomic rename — a snapshot either exists
    completely or not at all.

``ContinuousIngestService.recover`` = latest snapshot + journal tail
replayed through the normal offer/tick/merge/migration paths. Replay is
deterministic (reservoir eviction resumes from the snapshotted RNG
state, entries apply in journal order), so the recovered store decodes
bit-identically to an uninterrupted run over the same accepted records.

Journaling packed words is §2.5-consistent: the journal holds exactly
what the store itself holds — public Z• code indices — never latents,
labels excepted (they ride with the carrier, as in the store).
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Dict, Optional

import numpy as np

from repro.checkpoint.journal import Journal, decode_array, encode_array
from repro.checkpoint.npz import load_pytree, save_pytree
from repro.wire.payload import CodePayload


# ------------------------------------------------------ payload (de)coding

def _payload_manifest(p: CodePayload) -> dict:
    return {"bits": int(p.bits), "shape": list(p.shape),
            "n_records": int(p.n_records), "version": int(p.version),
            "privatized": bool(p.privatized), "wire": int(p.wire),
            "checksum": p.checksum if p.checksum is None
            else int(p.checksum),
            "tasks": sorted(p.labels) if p.labels else []}


def _payload_from(m: dict, get) -> CodePayload:
    """Rebuild a carrier from its manifest + an array getter
    (``get("words")`` / ``get("label.<task>")`` -> np array)."""
    import jax.numpy as jnp
    labels = {t: jnp.asarray(get(f"label.{t}")) for t in m["tasks"]} or None
    return CodePayload(
        payload=jnp.asarray(get("words")), bits=int(m["bits"]),
        shape=tuple(m["shape"]), n_records=int(m["n_records"]),
        version=int(m["version"]), labels=labels,
        privatized=bool(m["privatized"]), wire=int(m["wire"]),
        checksum=None if m["checksum"] is None else int(m["checksum"]))


def _ids_list(client_ids) -> Optional[list]:
    if client_ids is None:
        return None
    return [int(c) for c in np.asarray(client_ids).reshape(-1)]


class ServerPersistence:
    """Journal + snapshot plane for ONE service directory.

    ``snapshot_every`` = service ticks between snapshots (0 = only the
    construction-time snapshot 0); ``keep`` = snapshots retained (the
    journal is never pruned — it is the ground truth the snapshots
    accelerate). ``resume=True`` reopens an existing directory for
    appending (what :meth:`ContinuousIngestService.recover` does).
    """

    def __init__(self, root: str, *, snapshot_every: int = 0,
                 keep: int = 3, resume: bool = False):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.snapshot_every = int(snapshot_every)
        self.keep = int(keep)
        self.journal = Journal(os.path.join(root, "journal.jsonl"),
                               resume=resume)

    # ----------------------------------------------------- journal writers

    def log_offer(self, p: CodePayload, *, client_ids=None, delay: int = 0,
                  uplink_id=None) -> None:
        entry = {"kind": "offer", "delay": int(delay),
                 "uplink_id": (None if uplink_id is None
                               else [int(uplink_id[0]), int(uplink_id[1])]),
                 "client_ids": _ids_list(client_ids),
                 "payload": _payload_manifest(p),
                 "words": encode_array(p.payload)}
        if p.labels:
            entry["labels"] = {t: encode_array(y)
                               for t, y in p.labels.items()}
        self.journal.append(entry)

    def log_tick(self) -> None:
        self.journal.append({"kind": "tick"})

    def log_refusal(self, verdict: str, reason: str, nbytes: int) -> None:
        """A refused offer (rejected / radio-dropped / deduplicated
        duplicate): no payload to replay, but its ledger deltas and
        verdict must survive a crash — §2.8 counts refusals too."""
        self.journal.append({"kind": "refusal", "verdict": verdict,
                             "reason": reason, "nbytes": int(nbytes)})

    def log_merge(self, codebook, version: int) -> None:
        self.journal.append({"kind": "merge", "version": int(version),
                             "codebook": encode_array(codebook)})

    def log_migration(self, phase: str, *, src: Optional[int] = None,
                      dst: Optional[int] = None,
                      policy: Optional[str] = None) -> None:
        self.journal.append({"kind": "migration", "phase": phase,
                             "src": src, "dst": dst, "policy": policy})

    # ----------------------------------------------------- journal readers

    def decode_offer_payload(self, entry: dict) -> CodePayload:
        labels = entry.get("labels", {})
        def get(name):
            if name == "words":
                return decode_array(entry["words"])
            return decode_array(labels[name[len("label."):]])
        return _payload_from(entry["payload"], get)

    def decode_merge_codebook(self, entry: dict) -> np.ndarray:
        return decode_array(entry["codebook"])

    # ----------------------------------------------------------- snapshots

    def _snap_base(self, tick: int) -> str:
        return os.path.join(self.root, f"snap_{tick:08d}")

    def snapshot(self, service) -> str:
        """Write one complete snapshot of ``service``'s durable state.
        The manifest lands last (atomic rename): its presence is the
        commit point."""
        tick = int(service.tick_idx)
        base = self._snap_base(tick)
        arrays: Dict[str, np.ndarray] = {}

        store_man, store_arr = service.wire.store.snapshot_state()
        arrays.update({f"store.{k}": a for k, a in store_arr.items()})
        reg_man, reg_arr = service.wire.registry.snapshot_state()
        arrays.update({f"registry.{k}": a for k, a in reg_arr.items()})

        q = service.queue
        pending = []
        for i, pu in enumerate(q._pending):
            p = pu.packed
            arrays[f"q{i}.words"] = np.asarray(p.payload)
            if pu.client_ids is not None:
                arrays[f"q{i}.client_ids"] = np.asarray(pu.client_ids)
            if p.labels:
                for t, y in p.labels.items():
                    arrays[f"q{i}.label.{t}"] = np.asarray(y)
            pending.append({"arrival_round": int(pu.arrival_round),
                            "sent_round": int(pu.sent_round),
                            "has_client_ids": pu.client_ids is not None,
                            "payload": _payload_manifest(p)})

        manifest = {
            "tick": tick,
            "journal_pos": self.journal.position,
            "store": store_man,
            "registry": reg_man,
            "queue": {"bytes_sent": int(q.bytes_sent),
                      "bytes_delivered": int(q.bytes_delivered),
                      "bytes_dropped": int(q.bytes_dropped),
                      "bytes_rejected": int(q.bytes_rejected),
                      "bytes_duplicate": int(q.bytes_duplicate),
                      "pending": pending},
            "service": {"verdicts": dict(service.verdicts),
                        "verdict_bytes": dict(service.verdict_bytes),
                        "decoded_records": int(service.decoded_records),
                        "decode_dispatches": int(service.decode_dispatches),
                        "seen": [list(k) for k in service._seen]},
        }

        save_pytree(base + ".state.npz", service.wire.state)
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".npz")
        os.close(fd)
        with open(tmp, "wb") as fh:
            np.savez(fh, **arrays)
        os.replace(tmp, base + ".npz")
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".json")
        os.close(fd)
        with open(tmp, "w") as fh:
            json.dump(manifest, fh)
        os.replace(tmp, base + ".json")
        self._prune()
        return base + ".json"

    def _prune(self) -> None:
        for tick in self.snapshots[:-self.keep]:
            base = self._snap_base(tick)
            for suffix in (".json", ".npz", ".state.npz"):
                if os.path.exists(base + suffix):
                    os.remove(base + suffix)

    @property
    def snapshots(self) -> list:
        """Committed snapshot ticks, ascending (manifest + both array
        files present)."""
        out = []
        for f in sorted(os.listdir(self.root)):
            if f.startswith("snap_") and f.endswith(".json"):
                tick = int(f[len("snap_"):-len(".json")])
                base = self._snap_base(tick)
                if os.path.exists(base + ".npz") and \
                        os.path.exists(base + ".state.npz"):
                    out.append(tick)
        return out

    def load_snapshot(self, cfg, state_like, *, shard_fn=None) -> dict:
        """Load the latest committed snapshot -> the recovery dict
        ``ContinuousIngestService.recover`` consumes."""
        from collections import OrderedDict

        from repro.server.runtime import PendingUplink, UplinkQueue
        from repro.server.store import CodeStore, ShardedCodeStore
        from repro.server.registry import CodebookRegistry

        ticks = self.snapshots
        if not ticks:
            raise FileNotFoundError(
                f"no committed snapshot under {self.root!r} — the "
                f"crashed service was never constructed with persist")
        base = self._snap_base(ticks[-1])
        with open(base + ".json") as fh:
            manifest = json.load(fh)
        data = np.load(base + ".npz")
        arrays = {k: data[k] for k in data.files}

        state = load_pytree(base + ".state.npz", state_like)

        store_man = manifest["store"]
        if store_man["kind"] == "sharded":
            store = ShardedCodeStore(cfg, shard_fn=shard_fn)
        else:
            store = CodeStore(cfg)
        store.load_state(store_man,
                         {k[len("store."):]: a for k, a in arrays.items()
                          if k.startswith("store.")})

        registry = CodebookRegistry(state.params["codebook"])
        registry.load_state(manifest["registry"],
                            {k[len("registry."):]: a
                             for k, a in arrays.items()
                             if k.startswith("registry.")})

        qman = manifest["queue"]
        queue = UplinkQueue()
        queue.bytes_sent = int(qman["bytes_sent"])
        queue.bytes_delivered = int(qman["bytes_delivered"])
        queue.bytes_dropped = int(qman["bytes_dropped"])
        queue.bytes_rejected = int(qman["bytes_rejected"])
        queue.bytes_duplicate = int(qman["bytes_duplicate"])
        for i, pm in enumerate(qman["pending"]):
            packed = _payload_from(
                pm["payload"],
                lambda name, i=i: arrays[f"q{i}.{name}"])
            queue._pending.append(PendingUplink(
                arrival_round=int(pm["arrival_round"]), packed=packed,
                client_ids=(np.asarray(arrays[f"q{i}.client_ids"])
                            if pm["has_client_ids"] else None),
                sent_round=int(pm["sent_round"])))

        svc = manifest["service"]
        return {
            "snapshot_tick": int(manifest["tick"]),
            "journal_pos": int(manifest["journal_pos"]),
            "tick_idx": int(manifest["tick"]),
            "state": state, "store": store, "registry": registry,
            "queue": queue,
            "verdicts": {str(k): int(v)
                         for k, v in svc["verdicts"].items()},
            "verdict_bytes": {str(k): int(v)
                              for k, v in svc["verdict_bytes"].items()},
            "decoded_records": int(svc["decoded_records"]),
            "decode_dispatches": int(svc["decode_dispatches"]),
            "seen": OrderedDict(((int(c), int(s)), True)
                                for c, s in svc["seen"]),
        }

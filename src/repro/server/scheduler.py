"""Production-traffic round scheduler: participation, stragglers, churn.

The batched sim engine advances whichever clients you hand it; real
deployments decide that set adversarially — a fraction of the population
participates per round, some uplinks arrive rounds late (stragglers),
some never arrive (radio loss), and the population itself churns as
devices enroll and disappear. ``RoundScheduler`` turns those knobs into
a deterministic per-round event stream that the async code server
replays through ``SimEngine``, so every scenario — full participation,
25 % + stragglers, churn with codebook-version lag — runs through the
SAME jitted population round.

Determinism: the whole schedule is a pure function of the constructor
PRNG key. Every per-round draw gets its OWN substream
(``fold_in(fold_in(key, round), purpose)``): churn, participant choice,
straggler delays, drops, and diurnal cohort draws never share a
Generator, so toggling one traffic knob — or adding a traffic profile —
cannot perturb any other draw. (They used to share one per-round
stream, so e.g. enabling ``straggler_prob`` silently re-randomized the
drop pattern; a churn re-run is now bit-reproducible regardless of the
other knobs.) Two schedulers built from equal keys emit identical event
sequences, across processes.

Shapes stay static: exactly ``k = max(1, round(participation *
n_slots))`` participants are drawn per round (from the ACTIVE slots), so
the engine compiles one (k, B, ...) round and reuses it for the run.
Leaves are capped to keep at least ``k`` slots active. With a
:class:`DiurnalProfile` the per-round count still arrives in whole
``quantum``-sized blocks (cohorts), so each cohort keeps its compiled
shape and only the NUMBER of cohort dispatches breathes with traffic.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, NamedTuple, Optional

import jax
import numpy as np


@dataclass(frozen=True)
class SchedulerConfig:
    participation: float = 1.0   # fraction of slots drawn per round
    straggler_prob: float = 0.0  # P(an uplink is delayed >= 1 round)
    max_delay: int = 3           # truncated-geometric delay support
    delay_p: float = 0.5         # geometric continue-probability
    drop_prob: float = 0.0       # P(an uplink never arrives)
    leave_prob: float = 0.0      # per-active-slot P(depart) per round
    join_prob: float = 0.0       # per-inactive-slot P(enroll) per round
    rate: Optional[float] = None  # open-ended traffic: mean arrivals/tick
    #                               (Poisson; overrides `participation`'s
    #                               fixed per-round count, 0 ticks happen)


class RoundEvent(NamedTuple):
    """Everything that happens to the population in one round."""
    round: int
    participants: np.ndarray     # (k,) slot ids drawn this round
    delays: np.ndarray           # (k,) rounds until the uplink lands
    dropped: np.ndarray          # (k,) bool — uplink lost entirely
    joined: np.ndarray           # slot ids that (re-)enrolled this round
    left: np.ndarray             # slot ids that departed this round


def _rng_from_key(key) -> np.random.Generator:
    """Host-side Generator seeded from a jax PRNG key (old or new style)."""
    try:
        data = jax.random.key_data(key)
    except (TypeError, AttributeError):
        data = key
    return np.random.default_rng(np.asarray(data).astype(np.uint32))


# one substream per draw purpose: folding the purpose tag AFTER the round
# index gives every (round, purpose) pair an independent Generator, so no
# knob's draw can advance another's stream
_STREAM_CHURN = 1
_STREAM_PARTICIPANTS = 2
_STREAM_DELAYS = 3
_STREAM_DROPS = 4
_STREAM_COHORTS = 5
_STREAM_ARRIVALS = 6


@dataclass(frozen=True)
class DiurnalProfile:
    """Cosine day/night participation swing (§2.2 heavy-traffic realism).

    ``fraction(t)`` oscillates between ``trough`` (quietest round) and
    ``peak`` (busiest) with period ``period`` rounds, peaking at round
    ``phase``. The cohort engine multiplies the scheduler's base
    participation by it, in whole cohorts.
    """
    period: int = 24
    trough: float = 0.25
    peak: float = 1.0
    phase: int = 0

    def fraction(self, round_idx: int) -> float:
        c = math.cos(2.0 * math.pi * (round_idx - self.phase) / self.period)
        return self.trough + (self.peak - self.trough) * 0.5 * (1.0 + c)


class RoundScheduler:
    """Deterministic event stream over a fixed slot array.

    ``profile`` (optional :class:`DiurnalProfile`) modulates the
    per-round participant count; ``quantum`` keeps that count a whole
    multiple (the cohort size), so compiled per-cohort shapes are reused
    and only the dispatch count varies with traffic.
    """

    def __init__(self, n_slots: int, cfg: SchedulerConfig = SchedulerConfig(),
                 *, key, profile: Optional[DiurnalProfile] = None,
                 quantum: int = 1):
        self.n_slots = int(n_slots)
        self.cfg = cfg
        self._key = key
        self.round = 0
        self.active = np.ones(self.n_slots, dtype=bool)
        self.profile = profile
        self.quantum = int(quantum)
        self.k = max(1, int(round(cfg.participation * self.n_slots)))
        if self.quantum > 1:
            self.k = max(self.quantum,
                         (self.k // self.quantum) * self.quantum)
        if self.k > self.n_slots:
            raise ValueError(f"participation {cfg.participation} needs "
                             f"{self.k} > {self.n_slots} slots")

    def _rng(self, purpose: int) -> np.random.Generator:
        """Fresh Generator for one (round, purpose) draw."""
        return _rng_from_key(jax.random.fold_in(
            jax.random.fold_in(self._key, self.round), purpose))

    def round_k(self) -> int:
        """This round's participant count: base ``k`` scaled by the
        diurnal profile, in whole ``quantum`` blocks (>= one block).

        With ``cfg.rate`` set the count is instead an open-ended Poisson
        arrival draw (its own substream) — traffic is no longer
        round-quantized: quiet ticks (k = 0) and bursts both happen,
        which is what a continuous-ingest service must absorb.
        """
        if self.cfg.rate is not None:
            k = int(self._rng(_STREAM_ARRIVALS).poisson(self.cfg.rate))
            if self.quantum > 1:
                k = (k // self.quantum) * self.quantum
            return min(k, self.n_slots)
        if self.profile is None:
            return self.k
        want = self.profile.fraction(self.round) * self.k
        q = self.quantum
        return max(q, int(round(want / q)) * q)

    def step(self) -> RoundEvent:
        cfg = self.cfg

        # ---- churn first: the participant draw sees this round's roster
        joined = np.array([], dtype=int)
        left = np.array([], dtype=int)
        if cfg.join_prob > 0.0 or cfg.leave_prob > 0.0:
            rng = self._rng(_STREAM_CHURN)
            if cfg.join_prob > 0.0:
                idle = np.nonzero(~self.active)[0]
                joined = idle[rng.random(idle.size) < cfg.join_prob]
                self.active[joined] = True
            if cfg.leave_prob > 0.0:
                act = np.nonzero(self.active)[0]
                cand = act[rng.random(act.size) < cfg.leave_prob]
                # keep at least k slots active so the compiled shape
                # holds; the cap drops a RANDOM subset of the would-be
                # leavers so churn stays unbiased across slot ids
                n_spare = int(self.active.sum()) - self.k
                left = rng.permutation(cand)[:max(0, min(cand.size,
                                                         n_spare))]
                self.active[left] = False

        k = self.round_k()
        act = np.nonzero(self.active)[0]
        participants = self._rng(_STREAM_PARTICIPANTS).choice(
            act, size=min(k, act.size), replace=False)
        participants.sort()
        k = participants.size

        delays = np.zeros(k, dtype=int)
        if cfg.straggler_prob > 0.0:
            rng = self._rng(_STREAM_DELAYS)
            slow = rng.random(k) < cfg.straggler_prob
            # truncated geometric on {1..max_delay}
            d = rng.geometric(1.0 - cfg.delay_p, size=k)
            delays = np.where(slow, np.minimum(d, cfg.max_delay), 0)
        dropped = (self._rng(_STREAM_DROPS).random(k) < cfg.drop_prob
                   if cfg.drop_prob > 0.0 else np.zeros(k, dtype=bool))

        ev = RoundEvent(round=self.round, participants=participants,
                        delays=delays, dropped=dropped,
                        joined=np.sort(joined), left=np.sort(left))
        self.round += 1
        return ev

    def cohort_rng(self) -> np.random.Generator:
        """Substream reserved for cohort-level draws (e.g. shuffling
        cohort dispatch order). Isolated by construction: consuming it
        never advances the churn / participant / delay / drop streams,
        so a churn re-run with or without cohort draws is
        bit-reproducible."""
        return self._rng(_STREAM_COHORTS)


class Scenario(NamedTuple):
    """A named traffic profile: scheduler knobs + merge cadence."""
    sched: SchedulerConfig
    merge_every: int


STANDARD_SCENARIOS: Dict[str, Scenario] = {
    # every slot reports every round, no failures — the sync baseline
    "full": Scenario(SchedulerConfig(), merge_every=4),
    # the paper-realistic regime: 25 % participation, half the uplinks
    # straggle 1-2 rounds, 1-in-8 drops on the radio
    "partial": Scenario(SchedulerConfig(participation=0.25,
                                        straggler_prob=0.5, max_delay=2,
                                        drop_prob=0.125), merge_every=4),
    # device churn with frequent merges: stragglers and re-joiners carry
    # codebook-version lag into the store
    "churn": Scenario(SchedulerConfig(participation=0.5,
                                      straggler_prob=0.5, max_delay=3,
                                      leave_prob=0.2, join_prob=0.5),
                      merge_every=2),
    # the red-team regime (repro.privacy): an on-path adversary taps the
    # wire while the population churns — moderate participation so every
    # round leaves observable traffic, join churn so membership turnover
    # gives a membership-inference attacker something to chase
    "adversary": Scenario(SchedulerConfig(participation=0.5,
                                          straggler_prob=0.3, max_delay=2,
                                          drop_prob=0.1, leave_prob=0.1,
                                          join_prob=0.25), merge_every=2),
}

"""Codebook version registry (Step 5 bookkeeping for an async server).

The paper's Step 5 is low-frequency: clients refresh codebooks locally
and sync to the server, which merges them into the global dictionary.
In an asynchronous deployment the merge happens *while* code uplinks
packed under older dictionaries are still in flight (stragglers, churned
clients that never re-deployed). Decoding those codes against the
post-merge dictionary is silently wrong — the atom an index named at
pack time has moved.

``CodebookRegistry`` pins every merged dictionary as an immutable
snapshot keyed by a monotonically increasing version, so the code store
can decode each transmission against exactly the table it was packed
under, bit-for-bit, no matter how many merges happened since.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import octopus as OC


class CodebookRegistry:
    """Immutable (K, M) codebook snapshots, one per merge."""

    def __init__(self, codebook: jax.Array):
        self._versions: Dict[int, jax.Array] = {0: jnp.asarray(codebook)}
        self.latest = 0

    def __len__(self) -> int:
        return len(self._versions)

    def __contains__(self, version: int) -> bool:
        return int(version) in self._versions

    def get(self, version: int) -> jax.Array:
        """Snapshot for ``version``; KeyError if it was never registered."""
        return self._versions[int(version)]

    @property
    def current(self) -> jax.Array:
        return self._versions[self.latest]

    def register(self, codebook: jax.Array) -> int:
        """Pin a new global dictionary; returns its version number."""
        self.latest += 1
        self._versions[self.latest] = jnp.asarray(codebook)
        return self.latest

    def pin_current(self, codebook: jax.Array) -> int:
        """Replace the LATEST snapshot in place (no new version) — for
        Step 1 pretraining that moves the dictionary before any client
        deployed or any payload was packed under it."""
        self._versions[self.latest] = jnp.asarray(codebook)
        return self.latest

    # ----------------------------------------------------------- merging

    def merge(self, server: OC.ServerState, client_codebooks, client_counts,
              *, client_versions=None, staleness_decay: float = 1.0
              ) -> tuple[OC.ServerState, int]:
        """Staleness-weighted Step 5 merge + snapshot registration.

        ``client_versions`` (per-client int, same leading axis as the
        codebooks): the registry version each client last deployed from.
        Staleness is ``latest - version`` and discounts the client's
        count weight by ``staleness_decay ** staleness`` (see
        ``octopus.server_merge_codebooks``). Returns the merged server
        state and the freshly registered version.
        """
        staleness = None
        if client_versions is not None and staleness_decay != 1.0:
            staleness = jnp.maximum(
                self.latest - jnp.asarray(client_versions, jnp.int32), 0)
        merged = OC.server_merge_codebooks(
            server, client_codebooks, client_counts,
            staleness=staleness, staleness_decay=staleness_decay)
        version = self.register(merged.params["codebook"])
        return merged, version

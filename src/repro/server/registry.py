"""Codebook version registry (Step 5 bookkeeping for an async server).

The paper's Step 5 is low-frequency: clients refresh codebooks locally
and sync to the server, which merges them into the global dictionary.
In an asynchronous deployment the merge happens *while* code uplinks
packed under older dictionaries are still in flight (stragglers, churned
clients that never re-deployed). Decoding those codes against the
post-merge dictionary is silently wrong — the atom an index named at
pack time has moved.

``CodebookRegistry`` pins every merged dictionary as an immutable
snapshot keyed by a monotonically increasing version, so the code store
can decode each transmission against exactly the table it was packed
under, bit-for-bit, no matter how many merges happened since.

A rolling upgrade is modelled as a ``MigrationWindow``: while a
``v_src -> v_dst`` window is open, payloads of BOTH versions ingest
concurrently (src-version payloads get a ``migrated`` admission
verdict); when the window closes, src-version records are kept,
retired, or lazily re-encoded under the window's policy, and the src
version may be retired so new src-version uplinks are rejected at
admission. Snapshots are NEVER deleted — a retired version still
decodes bit-exactly for anything already stored under it.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Set

import jax
import jax.numpy as jnp

from repro.core import octopus as OC

#: how a closing migration window disposes of src-version records:
#:   keep     — records stay, still decoded against their pinned snapshot
#:   retire   — records evicted (ledgered), src version refused at the door
#:   reencode — records transcoded to the dst codebook, then src retired
MIGRATION_POLICIES = ("keep", "retire", "reencode")


class MigrationWindow(NamedTuple):
    """An open ``src -> dst`` rolling-upgrade window."""
    src: int
    dst: int
    policy: str


class CodebookRegistry:
    """Immutable (K, M) codebook snapshots, one per merge."""

    def __init__(self, codebook: jax.Array):
        self._versions: Dict[int, jax.Array] = {0: jnp.asarray(codebook)}
        self.latest = 0
        self.migration: Optional[MigrationWindow] = None
        self._retired: Set[int] = set()

    def __len__(self) -> int:
        return len(self._versions)

    def __contains__(self, version: int) -> bool:
        return int(version) in self._versions

    def get(self, version: int) -> jax.Array:
        """Snapshot for ``version``; KeyError if it was never registered."""
        return self._versions[int(version)]

    @property
    def current(self) -> jax.Array:
        return self._versions[self.latest]

    def register(self, codebook: jax.Array) -> int:
        """Pin a new global dictionary; returns its version number."""
        self.latest += 1
        self._versions[self.latest] = jnp.asarray(codebook)
        return self.latest

    def pin_current(self, codebook: jax.Array) -> int:
        """Replace the LATEST snapshot in place (no new version) — for
        Step 1 pretraining that moves the dictionary before any client
        deployed or any payload was packed under it."""
        self._versions[self.latest] = jnp.asarray(codebook)
        return self.latest

    # --------------------------------------------------------- migration

    @property
    def retired(self) -> tuple:
        return tuple(sorted(self._retired))

    def is_retired(self, version: int) -> bool:
        return int(version) in self._retired

    def begin_migration(self, *, src: Optional[int] = None,
                        dst: Optional[int] = None,
                        policy: str = "keep") -> MigrationWindow:
        """Open a rolling ``src -> dst`` upgrade window.

        ``dst`` defaults to the latest version, ``src`` to ``dst - 1``.
        While the window is open, src-version payloads still ingest
        (flagged ``migrated``); the window's ``policy`` decides what
        happens to them when the window closes.
        """
        if self.migration is not None:
            raise ValueError(
                f"migration window {self.migration.src}->"
                f"{self.migration.dst} is still open")
        if policy not in MIGRATION_POLICIES:
            raise ValueError(f"policy must be one of {MIGRATION_POLICIES}, "
                             f"got {policy!r}")
        dst = self.latest if dst is None else int(dst)
        src = dst - 1 if src is None else int(src)
        if src not in self._versions or dst not in self._versions:
            raise KeyError(f"migration {src}->{dst}: both versions must be "
                           f"registered (have {sorted(self._versions)})")
        if src == dst:
            raise ValueError(f"migration src and dst are both {src}")
        if self.is_retired(src):
            raise ValueError(f"version {src} is already retired")
        self.migration = MigrationWindow(src=src, dst=dst, policy=policy)
        return self.migration

    def close_migration(self) -> MigrationWindow:
        if self.migration is None:
            raise ValueError("no migration window is open")
        win, self.migration = self.migration, None
        return win

    def retire(self, version: int) -> None:
        """Refuse future uplinks packed under ``version``. The snapshot
        stays pinned — already-stored payloads keep decoding bit-exactly."""
        version = int(version)
        if version == self.latest:
            raise ValueError(f"cannot retire the latest version {version}")
        if version not in self._versions:
            raise KeyError(version)
        self._retired.add(version)

    # --------------------------------------------------------- durability

    def snapshot_state(self) -> tuple:
        """Durable state -> (JSON-able manifest, {key: np array}): every
        pinned snapshot, the retired set and any OPEN migration window —
        a crash mid-migration recovers back INTO the window."""
        import numpy as np
        arrays = {f"v{v}": np.asarray(cb)
                  for v, cb in self._versions.items()}
        manifest = {"latest": int(self.latest),
                    "retired": sorted(int(v) for v in self._retired),
                    "migration": (None if self.migration is None
                                  else [int(self.migration.src),
                                        int(self.migration.dst),
                                        self.migration.policy]),
                    "versions": sorted(int(v) for v in self._versions)}
        return manifest, arrays

    def load_state(self, manifest: dict, arrays) -> "CodebookRegistry":
        """Restore :meth:`snapshot_state` output into this registry."""
        self._versions = {int(v): jnp.asarray(arrays[f"v{v}"])
                          for v in manifest["versions"]}
        self.latest = int(manifest["latest"])
        self._retired = {int(v) for v in manifest["retired"]}
        mig = manifest["migration"]
        self.migration = None if mig is None else MigrationWindow(
            src=int(mig[0]), dst=int(mig[1]), policy=str(mig[2]))
        return self

    # ----------------------------------------------------------- merging

    def merge(self, server: OC.ServerState, client_codebooks, client_counts,
              *, client_versions=None, staleness_decay: float = 1.0
              ) -> tuple[OC.ServerState, int]:
        """Staleness-weighted Step 5 merge + snapshot registration.

        ``client_versions`` (per-client int, same leading axis as the
        codebooks): the registry version each client last deployed from.
        Staleness is ``latest - version`` and discounts the client's
        count weight by ``staleness_decay ** staleness`` (see
        ``octopus.server_merge_codebooks``). Returns the merged server
        state and the freshly registered version.
        """
        staleness = None
        if client_versions is not None and staleness_decay != 1.0:
            staleness = jnp.maximum(
                self.latest - jnp.asarray(client_versions, jnp.int32), 0)
        merged = OC.server_merge_codebooks(
            server, client_codebooks, client_counts,
            staleness=staleness, staleness_decay=staleness_decay)
        version = self.register(merged.params["codebook"])
        return merged, version

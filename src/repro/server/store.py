"""Versioned, capacity-bounded store of packed client payloads.

This is Step 6's front door. Clients stream bit-packed code indices at
high frequency; the server must absorb them under churn without either
unbounded memory or eager decoding. ``CodeStore`` supersedes the passive
``sim.IngestBuffer``:

  * entries stay PACKED until a trainer asks for features — storage cost
    is the measured uplink bytes, not the decoded float tensors;
  * every entry is a ``repro.wire.CodePayload`` keyed by the payload's
    OWN codebook version (plus ``client_ids`` / ``round`` provenance) so
    payloads that raced a Step 5 merge decode against the registry
    snapshot they were packed under (bit-exact), never the current table;
  * payloads not marked ``privatized`` are REFUSED at the door — the
    §2.5 invariant that only public Z• codes cross the wire is enforced
    where the wire terminates;
  * a sample-count capacity with FIFO or reservoir eviction bounds the
    store under "millions of users" traffic — FIFO keeps the freshest
    window, reservoir keeps an (approximately) uniform sample of history;
  * decoding is BULK: records are grouped by version and each group is
    dequantized in one ``repro.wire.codec`` dispatch, so a multi-task
    trainer pays one decode for the whole store regardless of how many
    heads consume it.

Labels ride along per task — either inside the payload
(``CodePayload.labels``) or as ``add(..., labels={"content": y1})`` —
shape-validated against the packed payload at add() time, not at decode
time three rounds later.
"""
from __future__ import annotations

import time
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import octopus as OC
from repro.core.dvqae import DVQAEConfig
from repro.obs import recorder as _obs
from repro.wire.payload import (DEFAULT_TASK, CodePayload, LabelsLike,
                                normalize_labels)


class StoreRecord(NamedTuple):
    """One buffered uplink: a wire payload plus its provenance."""
    packed: CodePayload
    client_ids: np.ndarray              # (C,) who sent these codes
    round: int                          # scheduler round it was SENT
    version: int                        # codebook version it was packed under
    labels: Optional[Dict[str, jax.Array]]   # task -> (C*B,) labels

    @property
    def n_samples(self) -> int:
        return int(self.packed.shape[0]) * int(self.packed.shape[1])


class CodeStore:
    """Capacity-bounded, lazily-decoded store of packed transmissions."""

    def __init__(self, cfg: DVQAEConfig, *,
                 capacity_samples: Optional[int] = None,
                 policy: str = "fifo", seed: int = 0):
        if policy not in ("fifo", "reservoir"):
            raise ValueError(f"policy must be fifo|reservoir, got {policy!r}")
        self.cfg = cfg
        self.capacity_samples = capacity_samples
        self.policy = policy
        self._rng = np.random.default_rng(seed)
        self._records: List[StoreRecord] = []
        self._seen_records = 0            # total ever added (reservoir stats)
        self.evicted_samples = 0
        self.evicted_records = 0

    # ----------------------------------------------------------- metadata

    def __len__(self) -> int:
        return len(self._records)

    @property
    def records(self) -> Tuple[StoreRecord, ...]:
        return tuple(self._records)

    @property
    def n_samples(self) -> int:
        return sum(r.n_samples for r in self._records)

    @property
    def total_bytes(self) -> int:
        """Measured packed bytes currently held (§2.8 accounting)."""
        return sum(r.packed.nbytes for r in self._records)

    @property
    def versions(self) -> Tuple[int, ...]:
        return tuple(sorted({r.version for r in self._records}))

    @property
    def tasks(self) -> Tuple[str, ...]:
        names: Dict[str, None] = {}
        for r in self._records:
            if r.labels:
                for t in r.labels:
                    names[t] = None
        return tuple(names)

    # ---------------------------------------------------------------- add

    def add(self, packed: CodePayload, *, client_ids=None, round: int = 0,
            version: Optional[int] = None, labels: LabelsLike = None
            ) -> StoreRecord:
        """Ingest one wire payload.

        packed.shape is (C, B, T[, n_c]); ``client_ids`` (C,) defaults to
        0..C-1. ``version`` defaults to the payload's OWN codebook
        version; ``labels`` default to the payload's own label channels
        (per-task (C, B)/(C*B,) arrays, or one bare array stored under
        task name ``"label"``) — validated HERE. Payloads whose producer
        cleared the ``privatized`` flag are refused (§2.5).
        """
        if getattr(packed, "privatized", True) is False:
            raise ValueError(
                "refusing a payload not marked privatized: only public Z• "
                "code indices may enter the store (§2.5)")
        if len(packed.shape) < 2:
            raise ValueError(f"packed payload must carry a (clients, batch) "
                             f"leading layout, got shape {packed.shape}")
        C, B = int(packed.shape[0]), int(packed.shape[1])
        if client_ids is None:
            client_ids = np.arange(C)
        client_ids = np.asarray(client_ids).reshape(-1)
        if client_ids.shape[0] != C:
            raise ValueError(f"client_ids has {client_ids.shape[0]} entries "
                             f"for {C} client rows in the payload")
        if version is None:
            version = int(getattr(packed, "version", 0))
        if labels is None:
            labels = getattr(packed, "labels", None)
        rec = StoreRecord(packed=packed, client_ids=client_ids,
                          round=int(round), version=int(version),
                          labels=normalize_labels(labels, C * B))
        self._records.append(rec)
        self._seen_records += 1
        self._evict()
        ob = _obs.active()
        if ob is not None:
            ob.metrics.set_gauge("store_records", len(self._records))
            ob.metrics.set_gauge("store_samples", self.n_samples)
            ob.metrics.set_gauge("store_bytes", self.total_bytes)
        return rec

    def _evict(self) -> None:
        if self.capacity_samples is None:
            return
        while self.n_samples > self.capacity_samples and len(self._records) > 1:
            if self.policy == "fifo":
                victim = 0
            else:
                # Algorithm-R reservoir over records: the INCOMING record
                # is kept with prob slots/seen (replacing a uniform old
                # record), else rejected — survivors stay an approximately
                # uniform sample of everything ever added
                slots = len(self._records) - 1
                if self._rng.random() < slots / self._seen_records:
                    victim = int(self._rng.integers(0, slots))
                else:
                    victim = len(self._records) - 1
            rec = self._records.pop(victim)
            self.evicted_samples += rec.n_samples
            self.evicted_records += 1

    # ------------------------------------------------------------- lookup

    def get(self, client_id: int, round: int) -> Tuple[jax.Array, int]:
        """Decode ONE client's codes from the (client_id, round) key:
        -> ((B, T[, n_c]) int32 indices, codebook version)."""
        for rec in self._records:
            if rec.round != round:
                continue
            pos = np.nonzero(rec.client_ids == client_id)[0]
            if pos.size:
                idx = rec.packed.unpack()
                return idx[int(pos[0])], rec.version
        raise KeyError((client_id, round))

    # ------------------------------------------------------------- decode

    def codes(self, version: Optional[int] = None) -> jax.Array:
        """Unpack buffered records -> (N, T[, n_c]) int32, record order.
        ``version`` filters to codes packed under that codebook version."""
        recs = [r for r in self._records
                if version is None or r.version == version]
        if not recs:
            raise ValueError("empty code store"
                             + (f" for version {version}" if version
                                is not None else ""))
        parts = []
        for r in recs:
            idx = r.packed.unpack()
            parts.append(idx.reshape((-1,) + idx.shape[2:]))
        return jnp.concatenate(parts, axis=0)

    def labels(self, task: Optional[str] = None, *, records=None
               ) -> Optional[jax.Array]:
        """Concatenated labels for ``task`` (record order), or None if any
        record lacks them. ``records`` restricts to a subset (e.g. one
        codebook version's)."""
        if task is None:
            task = DEFAULT_TASK
        parts = []
        for r in (self._records if records is None else records):
            if not r.labels or task not in r.labels:
                return None
            parts.append(r.labels[task])
        return jnp.concatenate(parts, axis=0) if parts else None

    def label_dict(self, *, records=None) -> Dict[str, jax.Array]:
        """All tasks that every record carries -> {task: (N,) labels}."""
        recs = self._records if records is None else records
        names: Dict[str, None] = {}
        for r in recs:
            if r.labels:
                for t in r.labels:
                    names[t] = None
        out = {}
        for t in names:
            v = self.labels(t, records=recs)
            if v is not None:
                out[t] = v
        return out

    def _decode_group(self, recs: List[StoreRecord], server, codebook
                      ) -> List[jax.Array]:
        """ONE fused decode dispatch for records packed under one version.

        Delegates to ``repro.wire.codec.decode_payloads`` — the records'
        word streams are concatenated into a single ``ops.decode_codes``
        dispatch with per-record-restarting slice phases; the int32 index
        and gathered-atom tensors never materialise. A stored upload may
        itself be a MULTI-record stream (``CodePayload.n_records`` > 1,
        one sub-stream per client — what the fused encode kernel emits
        for a population round). Returns per-record (C*B, T..., M)
        feature blocks.
        """
        from repro.wire.codec import decode_payloads
        if codebook is None:
            if server is None:
                raise ValueError("CodeStore.dataset needs a ServerState or "
                                 "a registry to decode against")
            codebook = server.params["codebook"]
        blocks = decode_payloads([r.packed for r in recs], self.cfg,
                                 codebook)
        return [f.reshape((-1,) + f.shape[2:]) for f in blocks]

    def dataset(self, server: Optional[OC.ServerState], *, registry=None,
                version: Optional[int] = None
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Bulk decode: ONE fused decode dispatch per codebook version.

        With a ``registry`` (repro.server.CodebookRegistry) each version
        group decodes against its own snapshot; without one, everything
        decodes against the server's current table (the old IngestBuffer
        behaviour). ``version`` filters to payloads packed under that
        codebook version. Returns (features (N, ...), {task: (N,)
        labels}) in record order.
        """
        recs = [(i, r) for i, r in enumerate(self._records)
                if version is None or r.version == version]
        if not recs:
            raise ValueError("empty code store"
                             + (f" for version {version}" if version
                                is not None else ""))
        by_version: Dict[Tuple[int, int], List[int]] = {}
        for i, r in recs:
            by_version.setdefault((r.version, r.packed.bits), []).append(i)
        feats_parts: Dict[int, jax.Array] = {}
        ob = _obs.active()
        for (v, _), idxs in by_version.items():
            cb = registry.get(v) if registry is not None else None
            t0 = time.perf_counter() if ob is not None else 0.0
            blocks = self._decode_group([self._records[i] for i in idxs],
                                        server, cb)
            if ob is not None:
                jax.block_until_ready(blocks)
                dur_ms = (time.perf_counter() - t0) * 1e3
                ob.event("decode", version=int(v), dur_ms=dur_ms,
                         n_records=len(idxs),
                         n_samples=int(sum(b.shape[0] for b in blocks)))
                ob.metrics.observe(f"decode_ms/v{int(v)}", dur_ms)
            for i, f in zip(idxs, blocks):
                feats_parts[i] = f
        feats = jnp.concatenate([feats_parts[i] for i, _ in recs], axis=0)
        return feats, self.label_dict(records=[r for _, r in recs])

    def batches(self, server, batch_size: int, *, key, steps: int,
                registry=None):
        """Minibatch stream over the decoded store (decoded ONCE)."""
        feats, labels = self.dataset(server, registry=registry)
        n = feats.shape[0]
        for i in range(steps):
            sel = jax.random.randint(jax.random.fold_in(key, i),
                                     (min(batch_size, n),), 0, n)
            yield feats[sel], {t: y[sel] for t, y in labels.items()}

"""Versioned, capacity-bounded stores of packed client payloads.

This is Step 6's front door. Clients stream bit-packed code indices at
high frequency; the server must absorb them under churn without either
unbounded memory or eager decoding. ``CodeStore`` is one bounded ring
buffer; ``ShardedCodeStore`` partitions the traffic into independent
ring buffers keyed by ``(codebook version, client shard)`` so a
continuous-ingest service stays memory-capped per partition no matter
how the uplink mix skews. Both supersede the retired
``sim.IngestBuffer`` (see ``repro.wire``):

  * entries stay PACKED until a trainer asks for features — storage cost
    is the measured uplink bytes, not the decoded float tensors;
  * every entry is a ``repro.wire.CodePayload`` keyed by the payload's
    OWN codebook version (plus ``client_ids`` / ``round`` provenance) so
    payloads that raced a Step 5 merge decode against the registry
    snapshot they were packed under (bit-exact), never the current table;
  * payloads not marked ``privatized`` are REFUSED at the door — the
    §2.5 invariant that only public Z• codes cross the wire is enforced
    where the wire terminates;
  * a sample-count capacity with FIFO or reservoir eviction bounds the
    store under "millions of users" traffic — FIFO keeps the freshest
    window, reservoir keeps an (approximately) uniform sample of history;
    every ingested and evicted byte stays on a per-version ledger, so
    for each codebook version Σ stored + Σ evicted == Σ ingested bytes
    holds at all times (§2.8 accounting survives eviction);
  * decoding is BULK: records are grouped by version and each group is
    dequantized in one ``repro.wire.codec`` dispatch, so a multi-task
    trainer pays one decode for the whole store regardless of how many
    heads consume it.

Labels ride along per task — either inside the payload
(``CodePayload.labels``) or as ``add(..., labels={"content": y1})`` —
shape-validated against the packed payload at add() time, not at decode
time three rounds later.
"""
from __future__ import annotations

import time
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import octopus as OC
from repro.core.dvqae import DVQAEConfig
from repro.obs import recorder as _obs
from repro.wire.payload import (DEFAULT_TASK, CodePayload, LabelsLike,
                                normalize_labels)


class StoreRecord(NamedTuple):
    """One buffered uplink: a wire payload plus its provenance."""
    packed: CodePayload
    client_ids: np.ndarray              # (C,) who sent these codes
    round: int                          # scheduler round it was SENT
    version: int                        # codebook version it was packed under
    labels: Optional[Dict[str, jax.Array]]   # task -> (C*B,) labels

    @property
    def n_samples(self) -> int:
        return int(self.packed.shape[0]) * int(self.packed.shape[1])


class CodeStore:
    """Capacity-bounded, lazily-decoded store of packed transmissions."""

    def __init__(self, cfg: DVQAEConfig, *,
                 capacity_samples: Optional[int] = None,
                 policy: str = "fifo", seed: int = 0):
        if policy not in ("fifo", "reservoir"):
            raise ValueError(f"policy must be fifo|reservoir, got {policy!r}")
        self.cfg = cfg
        self.capacity_samples = capacity_samples
        self.policy = policy
        self._rng = np.random.default_rng(seed)
        self._records: List[StoreRecord] = []
        self._seen_records = 0            # total ever added (reservoir stats)
        self.evicted_samples = 0
        self.evicted_records = 0
        self.evicted_bytes = 0
        self.ingested_records = 0
        self.ingested_samples = 0
        self.ingested_bytes = 0
        # per-version byte ledgers: stored + evicted == ingested, always
        self._ingested_by_version: Dict[int, int] = {}
        self._evicted_by_version: Dict[int, int] = {}

    # ----------------------------------------------------------- metadata

    def __len__(self) -> int:
        return len(self._records)

    @property
    def records(self) -> Tuple[StoreRecord, ...]:
        return tuple(self._records)

    @property
    def n_samples(self) -> int:
        return sum(r.n_samples for r in self._records)

    @property
    def total_bytes(self) -> int:
        """Measured packed bytes currently held (§2.8 accounting)."""
        return sum(r.packed.nbytes for r in self._records)

    @property
    def versions(self) -> Tuple[int, ...]:
        return tuple(sorted({r.version for r in self._records}))

    @property
    def tasks(self) -> Tuple[str, ...]:
        names: Dict[str, None] = {}
        for r in self._records:
            if r.labels:
                for t in r.labels:
                    names[t] = None
        return tuple(names)

    # ---------------------------------------------------------------- add

    def add(self, packed: CodePayload, *, client_ids=None, round: int = 0,
            version: Optional[int] = None, labels: LabelsLike = None
            ) -> StoreRecord:
        """Ingest one wire payload.

        packed.shape is (C, B, T[, n_c]); ``client_ids`` (C,) defaults to
        0..C-1. ``version`` defaults to the payload's OWN codebook
        version; ``labels`` default to the payload's own label channels
        (per-task (C, B)/(C*B,) arrays, or one bare array stored under
        task name ``"label"``) — validated HERE. Payloads whose producer
        cleared the ``privatized`` flag are refused (§2.5).
        """
        if getattr(packed, "privatized", True) is False:
            raise ValueError(
                "refusing a payload not marked privatized: only public Z• "
                "code indices may enter the store (§2.5)")
        if len(packed.shape) < 2:
            raise ValueError(f"packed payload must carry a (clients, batch) "
                             f"leading layout, got shape {packed.shape}")
        C, B = int(packed.shape[0]), int(packed.shape[1])
        if client_ids is None:
            client_ids = np.arange(C)
        client_ids = np.asarray(client_ids).reshape(-1)
        if client_ids.shape[0] != C:
            raise ValueError(f"client_ids has {client_ids.shape[0]} entries "
                             f"for {C} client rows in the payload")
        if version is None:
            version = int(getattr(packed, "version", 0))
        if labels is None:
            labels = getattr(packed, "labels", None)
        rec = StoreRecord(packed=packed, client_ids=client_ids,
                          round=int(round), version=int(version),
                          labels=normalize_labels(labels, C * B))
        self._records.append(rec)
        self._seen_records += 1
        nb = rec.packed.nbytes
        self.ingested_records += 1
        self.ingested_samples += rec.n_samples
        self.ingested_bytes += nb
        v = rec.version
        self._ingested_by_version[v] = self._ingested_by_version.get(v, 0) + nb
        self._evict()
        self._set_gauges()
        return rec

    def _evict(self) -> None:
        if self.capacity_samples is None:
            return
        while self.n_samples > self.capacity_samples and len(self._records) > 1:
            if self.policy == "fifo":
                victim = 0
            else:
                # Algorithm-R reservoir over records: the INCOMING record
                # is kept with prob slots/seen (replacing a uniform old
                # record), else rejected — survivors stay an approximately
                # uniform sample of everything ever added
                slots = len(self._records) - 1
                if self._rng.random() < slots / self._seen_records:
                    victim = int(self._rng.integers(0, slots))
                else:
                    victim = len(self._records) - 1
            rec = self._records.pop(victim)
            self._charge_eviction(rec)

    def _charge_eviction(self, rec: StoreRecord) -> None:
        nb = rec.packed.nbytes
        self.evicted_samples += rec.n_samples
        self.evicted_records += 1
        self.evicted_bytes += nb
        v = rec.version
        self._evicted_by_version[v] = self._evicted_by_version.get(v, 0) + nb

    def _set_gauges(self) -> None:
        ob = _obs.active()
        if ob is not None:
            ob.metrics.set_gauge("store_records", len(self._records))
            ob.metrics.set_gauge("store_samples", self.n_samples)
            ob.metrics.set_gauge("store_bytes", self.total_bytes)

    # ------------------------------------------------------------- ledgers

    @property
    def ingested_bytes_by_version(self) -> Dict[int, int]:
        return dict(self._ingested_by_version)

    @property
    def evicted_bytes_by_version(self) -> Dict[int, int]:
        return dict(self._evicted_by_version)

    @property
    def stored_bytes_by_version(self) -> Dict[int, int]:
        out: Dict[int, int] = {}
        for r in self._records:
            out[r.version] = out.get(r.version, 0) + r.packed.nbytes
        return out

    def retire_version(self, version: int) -> Tuple[StoreRecord, ...]:
        """Evict EVERY record packed under ``version`` (migration retire /
        re-encode paths). The evicted bytes stay on the per-version
        ledger, so §2.8 accounting survives retirement; returns the
        retired records so a re-encode policy can transcode them."""
        version = int(version)
        keep, gone = [], []
        for r in self._records:
            (gone if r.version == version else keep).append(r)
        self._records = keep
        for r in gone:
            self._charge_eviction(r)
        self._set_gauges()
        return tuple(gone)

    # ---------------------------------------------------------- durability

    def snapshot_state(self) -> Tuple[dict, Dict[str, np.ndarray]]:
        """Durable state -> (JSON-able manifest, {key: np array}).

        Captures the ring contents (packed words + full carrier
        metadata + provenance), every ledger counter, AND the reservoir
        RNG state — replaying the same post-snapshot adds reproduces the
        same evictions, which is what makes journal replay bit-exact.
        """
        arrays: Dict[str, np.ndarray] = {}
        recs = []
        for i, r in enumerate(self._records):
            p = r.packed
            arrays[f"r{i}.words"] = np.asarray(p.payload)
            arrays[f"r{i}.client_ids"] = np.asarray(r.client_ids)
            tasks = sorted(r.labels) if r.labels else []
            for t in tasks:
                arrays[f"r{i}.label.{t}"] = np.asarray(r.labels[t])
            recs.append({
                "round": int(r.round), "version": int(r.version),
                "bits": int(p.bits), "shape": list(p.shape),
                "n_records": int(p.n_records),
                "payload_version": int(p.version),
                "privatized": bool(p.privatized), "wire": int(p.wire),
                "checksum": p.checksum if p.checksum is None
                else int(p.checksum),
                "tasks": tasks,
            })
        manifest = {
            "kind": "single",
            "policy": self.policy,
            "capacity_samples": self.capacity_samples,
            "seen_records": int(self._seen_records),
            "evicted": [int(self.evicted_samples),
                        int(self.evicted_records), int(self.evicted_bytes)],
            "ingested": [int(self.ingested_records),
                         int(self.ingested_samples),
                         int(self.ingested_bytes)],
            "ingested_by_version": {str(v): int(n) for v, n
                                    in self._ingested_by_version.items()},
            "evicted_by_version": {str(v): int(n) for v, n
                                   in self._evicted_by_version.items()},
            "rng_state": self._rng.bit_generator.state,
            "records": recs,
        }
        return manifest, arrays

    def load_state(self, manifest: dict, arrays: Dict[str, np.ndarray]
                   ) -> "CodeStore":
        """Restore :meth:`snapshot_state` output into this (fresh) store."""
        from repro.wire.payload import CodePayload as _CP
        self.policy = manifest["policy"]
        self.capacity_samples = manifest["capacity_samples"]
        self._seen_records = int(manifest["seen_records"])
        (self.evicted_samples, self.evicted_records,
         self.evicted_bytes) = [int(x) for x in manifest["evicted"]]
        (self.ingested_records, self.ingested_samples,
         self.ingested_bytes) = [int(x) for x in manifest["ingested"]]
        self._ingested_by_version = {
            int(v): int(n)
            for v, n in manifest["ingested_by_version"].items()}
        self._evicted_by_version = {
            int(v): int(n)
            for v, n in manifest["evicted_by_version"].items()}
        self._rng.bit_generator.state = manifest["rng_state"]
        self._records = []
        for i, m in enumerate(manifest["records"]):
            labels = {t: jnp.asarray(arrays[f"r{i}.label.{t}"])
                      for t in m["tasks"]} or None
            p = _CP(payload=jnp.asarray(arrays[f"r{i}.words"]),
                    bits=int(m["bits"]), shape=tuple(m["shape"]),
                    n_records=int(m["n_records"]),
                    version=int(m["payload_version"]), labels=labels,
                    privatized=bool(m["privatized"]), wire=int(m["wire"]),
                    checksum=(None if m["checksum"] is None
                              else int(m["checksum"])))
            self._records.append(StoreRecord(
                packed=p, client_ids=np.asarray(arrays[f"r{i}.client_ids"]),
                round=int(m["round"]), version=int(m["version"]),
                labels=labels))
        return self

    # ------------------------------------------------------------- lookup

    def get(self, client_id: int, round: int) -> Tuple[jax.Array, int]:
        """Decode ONE client's codes from the (client_id, round) key:
        -> ((B, T[, n_c]) int32 indices, codebook version)."""
        for rec in self._records:
            if rec.round != round:
                continue
            pos = np.nonzero(rec.client_ids == client_id)[0]
            if pos.size:
                idx = rec.packed.unpack()
                return idx[int(pos[0])], rec.version
        raise KeyError((client_id, round))

    # ------------------------------------------------------------- decode

    def codes(self, version: Optional[int] = None) -> jax.Array:
        """Unpack buffered records -> (N, T[, n_c]) int32, record order.
        ``version`` filters to codes packed under that codebook version."""
        recs = [r for r in self._records
                if version is None or r.version == version]
        if not recs:
            raise ValueError("empty code store"
                             + (f" for version {version}" if version
                                is not None else ""))
        parts = []
        for r in recs:
            idx = r.packed.unpack()
            parts.append(idx.reshape((-1,) + idx.shape[2:]))
        return jnp.concatenate(parts, axis=0)

    def labels(self, task: Optional[str] = None, *, records=None
               ) -> Optional[jax.Array]:
        """Concatenated labels for ``task`` (record order), or None if any
        record lacks them. ``records`` restricts to a subset (e.g. one
        codebook version's)."""
        return labels_for(self._records if records is None else records,
                          task)

    def label_dict(self, *, records=None) -> Dict[str, jax.Array]:
        """All tasks that every record carries -> {task: (N,) labels}."""
        return label_dict_for(self._records if records is None else records)

    def dataset(self, server: Optional[OC.ServerState], *, registry=None,
                version: Optional[int] = None
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Bulk decode: ONE fused decode dispatch per codebook version.

        With a ``registry`` (repro.server.CodebookRegistry) each version
        group decodes against its own snapshot; without one, everything
        decodes against the server's current table (the old IngestBuffer
        behaviour). ``version`` filters to payloads packed under that
        codebook version. Returns (features (N, ...), {task: (N,)
        labels}) in record order.
        """
        return decode_records(self._records, self.cfg, server,
                              registry=registry, version=version)

    def batches(self, server, batch_size: int, *, key, steps: int,
                registry=None):
        """Minibatch stream over the decoded store (decoded ONCE)."""
        feats, labels = self.dataset(server, registry=registry)
        n = feats.shape[0]
        for i in range(steps):
            sel = jax.random.randint(jax.random.fold_in(key, i),
                                     (min(batch_size, n),), 0, n)
            yield feats[sel], {t: y[sel] for t, y in labels.items()}


# ------------------------------------------------------- shared decode path

def labels_for(records, task: Optional[str] = None) -> Optional[jax.Array]:
    """Concatenated labels for ``task`` over ``records`` (record order),
    or None if any record lacks them."""
    if task is None:
        task = DEFAULT_TASK
    parts = []
    for r in records:
        if not r.labels or task not in r.labels:
            return None
        parts.append(r.labels[task])
    return jnp.concatenate(parts, axis=0) if parts else None


def label_dict_for(records) -> Dict[str, jax.Array]:
    """All tasks that every record carries -> {task: (N,) labels}."""
    names: Dict[str, None] = {}
    for r in records:
        if r.labels:
            for t in r.labels:
                names[t] = None
    out = {}
    for t in names:
        v = labels_for(records, t)
        if v is not None:
            out[t] = v
    return out


def decode_group(recs, cfg: DVQAEConfig, server, codebook
                 ) -> List[jax.Array]:
    """ONE fused decode dispatch for records packed under one version.

    Delegates to ``repro.wire.codec.decode_payloads`` — the records'
    word streams are concatenated into a single ``ops.decode_codes``
    dispatch with per-record-restarting slice phases; the int32 index
    and gathered-atom tensors never materialise. A stored upload may
    itself be a MULTI-record stream (``CodePayload.n_records`` > 1,
    one sub-stream per client — what the fused encode kernel emits
    for a population round). Returns per-record (C*B, T..., M)
    feature blocks.
    """
    from repro.wire.codec import decode_payloads
    if codebook is None:
        if server is None:
            raise ValueError("decode needs a ServerState or a registry "
                             "to decode against")
        codebook = server.params["codebook"]
    blocks = decode_payloads([r.packed for r in recs], cfg, codebook)
    return [f.reshape((-1,) + f.shape[2:]) for f in blocks]


def decode_records(records, cfg: DVQAEConfig,
                   server: Optional[OC.ServerState], *, registry=None,
                   version: Optional[int] = None
                   ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Bulk decode any record sequence: ONE fused dispatch per
    (codebook version, bit width) group, each against its pinned
    registry snapshot when a ``registry`` is given. Shared by
    ``CodeStore.dataset`` and ``ShardedCodeStore.dataset``."""
    records = list(records)
    recs = [(i, r) for i, r in enumerate(records)
            if version is None or r.version == version]
    if not recs:
        raise ValueError("empty code store"
                         + (f" for version {version}" if version
                            is not None else ""))
    by_version: Dict[Tuple[int, int], List[int]] = {}
    for i, r in recs:
        by_version.setdefault((r.version, r.packed.bits), []).append(i)
    feats_parts: Dict[int, jax.Array] = {}
    ob = _obs.active()
    for (v, _), idxs in by_version.items():
        cb = registry.get(v) if registry is not None else None
        t0 = time.perf_counter() if ob is not None else 0.0
        blocks = decode_group([records[i] for i in idxs], cfg, server, cb)
        if ob is not None:
            jax.block_until_ready(blocks)
            dur_ms = (time.perf_counter() - t0) * 1e3
            ob.event("decode", version=int(v), dur_ms=dur_ms,
                     n_records=len(idxs),
                     n_samples=int(sum(b.shape[0] for b in blocks)))
            ob.metrics.observe(f"decode_ms/v{int(v)}", dur_ms)
        for i, f in zip(idxs, blocks):
            feats_parts[i] = f
    feats = jnp.concatenate([feats_parts[i] for i, _ in recs], axis=0)
    return feats, label_dict_for([r for _, r in recs])


# ------------------------------------------------------------ sharded store

class ShardedCodeStore:
    """`(codebook version, client shard)`-partitioned ring buffers.

    Each partition is an independent ``CodeStore`` with its OWN
    ``capacity_samples`` bound and eviction policy, so memory stays
    capped per partition no matter how the uplink mix skews across
    versions or client populations — one hot shard cannot evict
    another shard's history. Partitions are created lazily on first
    traffic; their byte ledgers survive retirement so the §2.8
    invariant (per version: Σ stored + Σ evicted == Σ ingested bytes)
    holds across the whole store at all times.

    ``shard_fn`` maps a ``client_ids`` array to a shard index; the
    default hashes the first client id modulo ``n_shards`` (cohort
    uploads keep all their clients in one partition).
    """

    def __init__(self, cfg: DVQAEConfig, *, n_shards: int = 4,
                 capacity_samples: Optional[int] = None,
                 policy: str = "fifo", seed: int = 0, shard_fn=None):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        if policy not in ("fifo", "reservoir"):
            raise ValueError(f"policy must be fifo|reservoir, got {policy!r}")
        self.cfg = cfg
        self.n_shards = int(n_shards)
        self.capacity_samples = capacity_samples
        self.policy = policy
        self.seed = int(seed)
        self.shard_fn = shard_fn
        self._parts: Dict[Tuple[int, int], CodeStore] = {}

    # -------------------------------------------------------- partitioning

    def shard_of(self, client_ids) -> int:
        if self.shard_fn is not None:
            return int(self.shard_fn(client_ids)) % self.n_shards
        if client_ids is None:
            return 0
        ids = np.asarray(client_ids).reshape(-1)
        if ids.size == 0:
            return 0
        return int(ids[0]) % self.n_shards

    def partition(self, version: int, shard: int) -> CodeStore:
        k = (int(version), int(shard))
        part = self._parts.get(k)
        if part is None:
            # deterministic per-partition reservoir streams
            pseed = (self.seed * 1000003 + k[0] * 8191 + k[1]) & 0x7FFFFFFF
            part = CodeStore(self.cfg,
                             capacity_samples=self.capacity_samples,
                             policy=self.policy, seed=pseed)
            self._parts[k] = part
        return part

    @property
    def partitions(self) -> Dict[Tuple[int, int], CodeStore]:
        return dict(self._parts)

    def _ordered_parts(self) -> List[CodeStore]:
        return [self._parts[k] for k in sorted(self._parts)]

    # ---------------------------------------------------------------- add

    def add(self, packed: CodePayload, *, client_ids=None, round: int = 0,
            version: Optional[int] = None, labels: LabelsLike = None
            ) -> StoreRecord:
        if version is None:
            version = int(getattr(packed, "version", 0))
        shard = self.shard_of(client_ids)
        rec = self.partition(version, shard).add(
            packed, client_ids=client_ids, round=round, version=version,
            labels=labels)
        self._set_gauges()
        return rec

    def _set_gauges(self) -> None:
        ob = _obs.active()
        if ob is not None:
            ob.metrics.set_gauge("store_records", len(self))
            ob.metrics.set_gauge("store_samples", self.n_samples)
            ob.metrics.set_gauge("store_bytes", self.total_bytes)
            ob.metrics.set_gauge("store_partitions", len(self._parts))

    # ----------------------------------------------------------- metadata

    def __len__(self) -> int:
        return sum(len(p) for p in self._parts.values())

    @property
    def records(self) -> Tuple[StoreRecord, ...]:
        """All records, in sorted (version, shard) partition order."""
        out: List[StoreRecord] = []
        for p in self._ordered_parts():
            out.extend(p.records)
        return tuple(out)

    @property
    def n_samples(self) -> int:
        return sum(p.n_samples for p in self._parts.values())

    @property
    def total_bytes(self) -> int:
        return sum(p.total_bytes for p in self._parts.values())

    @property
    def versions(self) -> Tuple[int, ...]:
        return tuple(sorted({v for p in self._parts.values()
                             for v in p.versions}))

    @property
    def tasks(self) -> Tuple[str, ...]:
        names: Dict[str, None] = {}
        for p in self._ordered_parts():
            for t in p.tasks:
                names[t] = None
        return tuple(names)

    # ------------------------------------------------------------- ledgers

    @property
    def ingested_bytes(self) -> int:
        return sum(p.ingested_bytes for p in self._parts.values())

    @property
    def evicted_bytes(self) -> int:
        return sum(p.evicted_bytes for p in self._parts.values())

    @property
    def evicted_records(self) -> int:
        return sum(p.evicted_records for p in self._parts.values())

    @property
    def evicted_samples(self) -> int:
        return sum(p.evicted_samples for p in self._parts.values())

    def _sum_by_version(self, attr: str) -> Dict[int, int]:
        out: Dict[int, int] = {}
        for p in self._parts.values():
            for v, nb in getattr(p, attr).items():
                out[v] = out.get(v, 0) + nb
        return out

    @property
    def ingested_bytes_by_version(self) -> Dict[int, int]:
        return self._sum_by_version("ingested_bytes_by_version")

    @property
    def evicted_bytes_by_version(self) -> Dict[int, int]:
        return self._sum_by_version("evicted_bytes_by_version")

    @property
    def stored_bytes_by_version(self) -> Dict[int, int]:
        return self._sum_by_version("stored_bytes_by_version")

    def retire_version(self, version: int) -> Tuple[StoreRecord, ...]:
        """Evict every record of ``version`` across all shards. The
        emptied partitions stay registered so their ledgers keep
        witnessing the retired bytes."""
        gone: List[StoreRecord] = []
        for k in sorted(self._parts):
            if k[0] == int(version):
                gone.extend(self._parts[k].retire_version(version))
        self._set_gauges()
        return tuple(gone)

    # ---------------------------------------------------------- durability

    def snapshot_state(self) -> Tuple[dict, Dict[str, np.ndarray]]:
        """Durable state across ALL partitions (each ring's records,
        ledgers and reservoir RNG state) — see ``CodeStore
        .snapshot_state``. Array keys are prefixed ``p<version>.<shard>.``
        so one flat npz holds the whole sharded store."""
        arrays: Dict[str, np.ndarray] = {}
        parts = []
        for (v, s) in sorted(self._parts):
            man, arr = self._parts[(v, s)].snapshot_state()
            prefix = f"p{v}.{s}."
            arrays.update({prefix + k: a for k, a in arr.items()})
            parts.append({"version": int(v), "shard": int(s),
                          "manifest": man})
        manifest = {"kind": "sharded", "n_shards": int(self.n_shards),
                    "capacity_samples": self.capacity_samples,
                    "policy": self.policy, "seed": int(self.seed),
                    "partitions": parts}
        return manifest, arrays

    def load_state(self, manifest: dict, arrays: Dict[str, np.ndarray]
                   ) -> "ShardedCodeStore":
        """Restore :meth:`snapshot_state` output into this (fresh)
        sharded store. ``shard_fn`` is routing code, not state — pass it
        to the constructor as on the original deployment."""
        self.n_shards = int(manifest["n_shards"])
        self.capacity_samples = manifest["capacity_samples"]
        self.policy = manifest["policy"]
        self.seed = int(manifest["seed"])
        self._parts = {}
        for pm in manifest["partitions"]:
            v, s = int(pm["version"]), int(pm["shard"])
            prefix = f"p{v}.{s}."
            sub = {k[len(prefix):]: a for k, a in arrays.items()
                   if k.startswith(prefix)}
            self.partition(v, s).load_state(pm["manifest"], sub)
        return self

    # ------------------------------------------------------------- lookup

    def get(self, client_id: int, round: int) -> Tuple[jax.Array, int]:
        for p in self._ordered_parts():
            try:
                return p.get(client_id, round)
            except KeyError:
                continue
        raise KeyError((client_id, round))

    # ------------------------------------------------------------- decode

    def codes(self, version: Optional[int] = None) -> jax.Array:
        recs = [r for r in self.records
                if version is None or r.version == version]
        if not recs:
            raise ValueError("empty code store"
                             + (f" for version {version}" if version
                                is not None else ""))
        parts = []
        for r in recs:
            idx = r.packed.unpack()
            parts.append(idx.reshape((-1,) + idx.shape[2:]))
        return jnp.concatenate(parts, axis=0)

    def labels(self, task: Optional[str] = None, *, records=None
               ) -> Optional[jax.Array]:
        return labels_for(self.records if records is None else records,
                          task)

    def label_dict(self, *, records=None) -> Dict[str, jax.Array]:
        return label_dict_for(self.records if records is None else records)

    def dataset(self, server: Optional[OC.ServerState], *, registry=None,
                version: Optional[int] = None
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Bulk decode across all partitions: still ONE fused dispatch
        per (version, bits) group — sharding changes residency, not the
        decode batching."""
        return decode_records(self.records, self.cfg, server,
                              registry=registry, version=version)

    def batches(self, server, batch_size: int, *, key, steps: int,
                registry=None):
        feats, labels = self.dataset(server, registry=registry)
        n = feats.shape[0]
        for i in range(steps):
            sel = jax.random.randint(jax.random.fold_in(key, i),
                                     (min(batch_size, n),), 0, n)
            yield feats[sel], {t: y[sel] for t, y in labels.items()}

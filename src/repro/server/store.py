"""Versioned, capacity-bounded store of packed client transmissions.

This is Step 6's front door. Clients stream bit-packed code indices at
high frequency; the server must absorb them under churn without either
unbounded memory or eager decoding. ``CodeStore`` supersedes the passive
``sim.IngestBuffer``:

  * entries stay PACKED until a trainer asks for features — storage cost
    is the measured uplink bytes, not the decoded float tensors;
  * every entry is keyed by ``(client_ids, round, codebook_version)`` so
    transmissions that raced a Step 5 merge decode against the registry
    snapshot they were packed under (bit-exact), never the current table;
  * a sample-count capacity with FIFO or reservoir eviction bounds the
    store under "millions of users" traffic — FIFO keeps the freshest
    window, reservoir keeps an (approximately) uniform sample of history;
  * decoding is BULK: records are grouped by version and each group is
    dequantized in one call, so a multi-task trainer pays one decode for
    the whole store regardless of how many heads consume it.

Labels ride along per task: ``add(..., labels={"content": y1, "style":
y2})`` — shape-validated against the packed payload at add() time, not
at decode time three rounds later.
"""
from __future__ import annotations

from typing import Dict, List, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import octopus as OC
from repro.core.dvqae import DVQAEConfig
from repro.sim.engine import PackedCodes

LabelsLike = Union[None, jax.Array, np.ndarray, Dict[str, jax.Array]]

DEFAULT_TASK = "label"


class StoreRecord(NamedTuple):
    """One buffered uplink: a packed payload plus its provenance."""
    packed: PackedCodes
    client_ids: np.ndarray              # (C,) who sent these codes
    round: int                          # scheduler round it was SENT
    version: int                        # codebook version it was packed under
    labels: Optional[Dict[str, jax.Array]]   # task -> (C*B,) labels

    @property
    def n_samples(self) -> int:
        return int(self.packed.shape[0]) * int(self.packed.shape[1])


def _normalize_labels(labels: LabelsLike, n: int) -> Optional[Dict]:
    """dict/array/None -> {task: (n,) array} with add()-time validation."""
    if labels is None:
        return None
    if not isinstance(labels, dict):
        labels = {DEFAULT_TASK: labels}
    out = {}
    for task, arr in labels.items():
        arr = jnp.asarray(arr)
        if arr.size != n:
            raise ValueError(
                f"labels[{task!r}] has {arr.size} entries but the packed "
                f"payload carries {n} samples (shape mismatch caught at "
                f"add(), not decode)")
        out[task] = arr.reshape(-1)
    return out


class CodeStore:
    """Capacity-bounded, lazily-decoded store of packed transmissions."""

    def __init__(self, cfg: DVQAEConfig, *,
                 capacity_samples: Optional[int] = None,
                 policy: str = "fifo", seed: int = 0):
        if policy not in ("fifo", "reservoir"):
            raise ValueError(f"policy must be fifo|reservoir, got {policy!r}")
        self.cfg = cfg
        self.capacity_samples = capacity_samples
        self.policy = policy
        self._rng = np.random.default_rng(seed)
        self._records: List[StoreRecord] = []
        self._seen_records = 0            # total ever added (reservoir stats)
        self.evicted_samples = 0
        self.evicted_records = 0

    # ----------------------------------------------------------- metadata

    def __len__(self) -> int:
        return len(self._records)

    @property
    def records(self) -> Tuple[StoreRecord, ...]:
        return tuple(self._records)

    @property
    def n_samples(self) -> int:
        return sum(r.n_samples for r in self._records)

    @property
    def total_bytes(self) -> int:
        """Measured packed bytes currently held (§2.8 accounting)."""
        return sum(r.packed.nbytes for r in self._records)

    @property
    def versions(self) -> Tuple[int, ...]:
        return tuple(sorted({r.version for r in self._records}))

    @property
    def tasks(self) -> Tuple[str, ...]:
        names: Dict[str, None] = {}
        for r in self._records:
            if r.labels:
                for t in r.labels:
                    names[t] = None
        return tuple(names)

    # ---------------------------------------------------------------- add

    def add(self, packed: PackedCodes, *, client_ids=None, round: int = 0,
            version: int = 0, labels: LabelsLike = None) -> StoreRecord:
        """Ingest one packed uplink.

        packed.shape is (C, B, T[, n_c]); ``client_ids`` (C,) defaults to
        0..C-1. ``labels``: per-task (C, B)/(C*B,) arrays (or one bare
        array, stored under task name ``"label"``) — validated HERE.
        """
        if len(packed.shape) < 2:
            raise ValueError(f"packed payload must carry a (clients, batch) "
                             f"leading layout, got shape {packed.shape}")
        C, B = int(packed.shape[0]), int(packed.shape[1])
        if client_ids is None:
            client_ids = np.arange(C)
        client_ids = np.asarray(client_ids).reshape(-1)
        if client_ids.shape[0] != C:
            raise ValueError(f"client_ids has {client_ids.shape[0]} entries "
                             f"for {C} client rows in the payload")
        rec = StoreRecord(packed=packed, client_ids=client_ids,
                          round=int(round), version=int(version),
                          labels=_normalize_labels(labels, C * B))
        self._records.append(rec)
        self._seen_records += 1
        self._evict()
        return rec

    def _evict(self) -> None:
        if self.capacity_samples is None:
            return
        while self.n_samples > self.capacity_samples and len(self._records) > 1:
            if self.policy == "fifo":
                victim = 0
            else:
                # Algorithm-R reservoir over records: the INCOMING record
                # is kept with prob slots/seen (replacing a uniform old
                # record), else rejected — survivors stay an approximately
                # uniform sample of everything ever added
                slots = len(self._records) - 1
                if self._rng.random() < slots / self._seen_records:
                    victim = int(self._rng.integers(0, slots))
                else:
                    victim = len(self._records) - 1
            rec = self._records.pop(victim)
            self.evicted_samples += rec.n_samples
            self.evicted_records += 1

    # ------------------------------------------------------------- lookup

    def get(self, client_id: int, round: int) -> Tuple[jax.Array, int]:
        """Decode ONE client's codes from the (client_id, round) key:
        -> ((B, T[, n_c]) int32 indices, codebook version)."""
        for rec in self._records:
            if rec.round != round:
                continue
            pos = np.nonzero(rec.client_ids == client_id)[0]
            if pos.size:
                idx = rec.packed.unpack()
                return idx[int(pos[0])], rec.version
        raise KeyError((client_id, round))

    # ------------------------------------------------------------- decode

    def codes(self, version: Optional[int] = None) -> jax.Array:
        """Unpack buffered records -> (N, T[, n_c]) int32, record order.
        ``version`` filters to codes packed under that codebook version."""
        recs = [r for r in self._records
                if version is None or r.version == version]
        if not recs:
            raise ValueError("empty code store"
                             + (f" for version {version}" if version
                                is not None else ""))
        parts = []
        for r in recs:
            idx = r.packed.unpack()
            parts.append(idx.reshape((-1,) + idx.shape[2:]))
        return jnp.concatenate(parts, axis=0)

    def labels(self, task: Optional[str] = None) -> Optional[jax.Array]:
        """Concatenated labels for ``task`` (record order), or None if any
        record lacks them."""
        if task is None:
            task = DEFAULT_TASK
        parts = []
        for r in self._records:
            if not r.labels or task not in r.labels:
                return None
            parts.append(r.labels[task])
        return jnp.concatenate(parts, axis=0) if parts else None

    def label_dict(self) -> Dict[str, jax.Array]:
        """All tasks that every record carries -> {task: (N,) labels}."""
        out = {}
        for t in self.tasks:
            v = self.labels(t)
            if v is not None:
                out[t] = v
        return out

    def _decode_group(self, recs: List[StoreRecord], server, codebook
                      ) -> List[jax.Array]:
        """ONE fused decode dispatch for records packed under one version.

        The records' packed word streams are concatenated (each is padded
        to whole super-groups, so record boundaries sit on word rows) and
        handed to ops.decode_codes with a per-record-restarting slice
        phase vector; the int32 index and gathered-atom tensors never
        materialise. A stored upload may itself be a MULTI-record stream
        (``PackedCodes.n_records`` > 1, one sub-stream per client — what
        the fused encode kernel emits for a population round): its slice
        phases restart per sub-stream and each sub-stream's trailing pad
        rows are dropped. Returns per-record (C*B, T..., M) feature
        blocks.
        """
        from repro.core.octopus import packed_record_rows
        from repro.kernels.decode_codes import stream_phases
        from repro.kernels.ops import decode_codes
        from repro.kernels.pack_bits import packing_dims
        if codebook is None:
            if server is None:
                raise ValueError("CodeStore.dataset needs a ServerState or "
                                 "a registry to decode against")
            codebook = server.params["codebook"]
        table, n_slices = OC.decode_table(self.cfg, codebook)
        bits = recs[0].packed.bits
        G, _ = packing_dims(bits)
        payloads, phases, spans = [], [], []
        row_off = 0
        for r in recs:
            p = r.packed.payload
            nr = r.packed.n_records
            payloads.append(p)
            phases.append(jnp.tile(
                stream_phases(p.shape[0] // nr, bits, n_slices), nr))
            spans.append((row_off, int(p.shape[0])))
            row_off += p.shape[0]
        rows = decode_codes(jnp.concatenate(payloads, axis=0), table,
                            bits=bits, count=row_off * G, n_slices=n_slices,
                            phases=jnp.concatenate(phases))
        out = []
        F = int(table.shape[-1])
        for (start, n_rows), r in zip(spans, recs):
            f = packed_record_rows(n_rows, bits, r.packed.count,
                                   r.packed.n_records,
                                   rows[start * G:(start + n_rows) * G], F)
            shp = r.packed.shape                       # (C, B, T[, n_c])
            if self.cfg.n_groups > 1 or self.cfg.n_slices > 1:
                f = f.reshape(tuple(shp[:-1])
                              + (int(shp[-1]) * table.shape[-1],))
            else:
                f = f.reshape(tuple(shp) + (table.shape[-1],))
            out.append(f.reshape((-1,) + f.shape[2:]))  # merge client axis
        return out

    def dataset(self, server: Optional[OC.ServerState], *, registry=None
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Bulk decode: ONE fused decode dispatch per codebook version.

        With a ``registry`` (repro.server.CodebookRegistry) each version
        group decodes against its own snapshot; without one, everything
        decodes against the server's current table (the old IngestBuffer
        behaviour). Returns (features (N, ...), {task: (N,) labels}) in
        record order.
        """
        if not self._records:
            raise ValueError("empty code store")
        by_version: Dict[Tuple[int, int], List[int]] = {}
        for i, r in enumerate(self._records):
            by_version.setdefault((r.version, r.packed.bits), []).append(i)
        feats_parts: List[Optional[jax.Array]] = [None] * len(self._records)
        for (version, _), idxs in by_version.items():
            cb = registry.get(version) if registry is not None else None
            blocks = self._decode_group([self._records[i] for i in idxs],
                                        server, cb)
            for i, f in zip(idxs, blocks):
                feats_parts[i] = f
        return jnp.concatenate(feats_parts, axis=0), self.label_dict()

    def batches(self, server, batch_size: int, *, key, steps: int,
                registry=None):
        """Minibatch stream over the decoded store (decoded ONCE)."""
        feats, labels = self.dataset(server, registry=registry)
        n = feats.shape[0]
        for i in range(steps):
            sel = jax.random.randint(jax.random.fold_in(key, i),
                                     (min(batch_size, n),), 0, n)
            yield feats[sel], {t: y[sel] for t, y in labels.items()}

"""Asynchronous code-server runtime (Step 6 as a first-class subsystem).

``AsyncCodeServer`` owns the server side of the protocol under realistic
traffic: a fixed slot array of clients (stacked ``ClientState``), a
``RoundScheduler`` deciding who participates / straggles / churns, a
``CodebookRegistry`` pinning every merged dictionary, and a ``CodeStore``
absorbing the uplinks. Per round it

  1. applies churn — (re-)joining slots deploy fresh from the CURRENT
     server and adopt the latest codebook version; leavers go dark with
     whatever stale state they had,
  2. advances the participant subset through ONE jitted engine call
     (``SimEngine.round_indices``) and scatters the states back,
  3. splits the participants into delivery groups by (codebook version,
     straggler delay, dropped) and bit-packs each group's codes into its
     own measured ``repro.wire.CodePayload`` — version and label
     channels travel INSIDE the carrier, so stragglers' packets stay
     tagged with the dictionary they were packed under,
  4. delivers every in-flight payload whose arrival round has come
     through the single wire endpoint (``OctopusServer.ingest``, keyed
     on the payload's own version; dropped packets burn uplink bytes but
     never land),
  5. every ``merge_every`` rounds runs the staleness-weighted Step 5
     merge over the ACTIVE population — slots that never got sampled
     since their last deploy still sit on an older dictionary version,
     so their contribution is discounted by ``staleness_decay ** lag`` —
     registers the new dictionary version, and (optionally) re-deploys
     the slots that actually participated since the last merge (only
     they synced; everyone else keeps lagging until sampled or churned).

Downstream, ``MultiTaskTrainer`` trains any number of heads from one
bulk decode of the store — see repro.server.multitask.
"""
from __future__ import annotations

import time
from typing import Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import octopus as OC
from repro.obs import recorder as _obs
from repro.sim.engine import SimEngine
from repro.wire import CodePayload, OctopusServer

from .registry import CodebookRegistry
from .scheduler import RoundEvent, RoundScheduler
from .store import CodeStore


class PendingUplink(NamedTuple):
    """A wire payload still in flight (straggler delay). Codebook version
    and label channels ride INSIDE the payload — the carrier is the
    bookkeeping."""
    arrival_round: int
    packed: CodePayload
    client_ids: np.ndarray
    sent_round: int


class UplinkQueue:
    """In-flight uplink payloads + the measured byte ledger (§2.8).

    Shared by :class:`AsyncCodeServer` and the cohort traffic driver
    (``repro.sim.cohort.CohortEngine.run_traffic``): ``send`` charges
    every payload's MEASURED ``nbytes`` to the uplink (dropped packets
    burn bytes but never land); ``deliver`` pushes everything whose
    arrival round has come through the wire endpoint.
    """

    def __init__(self):
        self._pending: List[PendingUplink] = []
        self.bytes_sent = 0
        self.bytes_delivered = 0
        self.bytes_dropped = 0

    def send(self, packed: CodePayload, *, round: int, delay: int = 0,
             dropped: bool = False, client_ids=None) -> int:
        """Queue one payload; returns its measured nbytes."""
        n = packed.nbytes
        self.bytes_sent += n
        rec = _obs.active()
        if rec is not None:
            rec.uplink(packed, round=int(round), delay=int(delay),
                       dropped=bool(dropped),
                       n_clients=(len(client_ids)
                                  if client_ids is not None else None))
        if dropped:
            self.bytes_dropped += n
            return n
        self._pending.append(PendingUplink(
            arrival_round=int(round) + int(delay), packed=packed,
            client_ids=client_ids, sent_round=int(round)))
        if rec is not None:
            rec.metrics.set_gauge("uplink_queue_depth", len(self._pending))
        return n

    def deliver(self, wire: OctopusServer, round: int) -> tuple:
        """Ingest every due payload; returns (nbytes, n_payloads)."""
        delivered, n_del = 0, 0
        still: List[PendingUplink] = []
        for p in self._pending:
            if p.arrival_round <= round:
                wire.ingest(p.packed, client_ids=p.client_ids,
                            round=p.sent_round)
                delivered += p.packed.nbytes
                n_del += 1
            else:
                still.append(p)
        self._pending = still
        self.bytes_delivered += delivered
        rec = _obs.active()
        if rec is not None:
            rec.metrics.set_gauge("uplink_queue_depth", len(self._pending))
        return delivered, n_del

    @property
    def bytes_in_flight(self) -> int:
        return sum(p.packed.nbytes for p in self._pending)

    def __len__(self) -> int:
        return len(self._pending)


class RoundStats(NamedTuple):
    round: int
    n_participants: int
    n_joined: int
    n_left: int
    bytes_sent: int          # measured, incl. packets that will drop
    bytes_delivered: int     # measured, landed in the store this round
    n_delivered: int         # delivery groups landed this round
    merged_version: Optional[int]   # registry version if this round merged


class AsyncCodeServer:
    """Server runtime: scheduler-driven rounds over a versioned store."""

    def __init__(self, engine: SimEngine, server: OC.ServerState,
                 scheduler: RoundScheduler, *,
                 store: Optional[CodeStore] = None,
                 registry: Optional[CodebookRegistry] = None,
                 merge_every: int = 0, staleness_decay: float = 0.5,
                 redeploy_on_merge: bool = True):
        self.engine = engine
        self.scheduler = scheduler
        self.n_slots = scheduler.n_slots
        # ONE wire endpoint owns server state + registry + store: ingest
        # is keyed on each payload's own codebook version
        self.wire = OctopusServer(server, engine.cfg, store=store,
                                  registry=registry)
        self.merge_every = merge_every
        self.staleness_decay = staleness_decay
        self.redeploy_on_merge = redeploy_on_merge

        self.clients = engine.init_clients(server, self.n_slots)
        self.slot_versions = np.full(self.n_slots, self.registry.latest,
                                     dtype=int)
        self._participated = np.zeros(self.n_slots, dtype=bool)
        self.queue = UplinkQueue()
        self.round = 0
        self.n_merges = 0

    # --------------------------------------------- wire endpoint delegates

    @property
    def server(self) -> OC.ServerState:
        return self.wire.state

    @property
    def registry(self) -> CodebookRegistry:
        return self.wire.registry

    @property
    def store(self) -> CodeStore:
        return self.wire.store

    # byte ledger lives on the shared UplinkQueue

    @property
    def bytes_sent(self) -> int:
        return self.queue.bytes_sent

    @property
    def bytes_delivered(self) -> int:
        return self.queue.bytes_delivered

    @property
    def bytes_dropped(self) -> int:
        return self.queue.bytes_dropped

    # ------------------------------------------------------------ helpers

    def _set_slots(self, ids: np.ndarray, sub: OC.ClientState) -> None:
        self.clients = jax.tree.map(
            lambda full, part: full.at[jnp.asarray(ids)].set(part),
            self.clients, sub)

    def _deploy_fresh(self, ids: np.ndarray) -> None:
        """(Re-)deploy slots from the CURRENT server (Step 2 for joiners)."""
        if ids.size == 0:
            return
        fresh = OC.client_init(self.server)
        self.clients = jax.tree.map(
            lambda full, leaf: full.at[jnp.asarray(ids)].set(leaf),
            self.clients, fresh)
        self.slot_versions[ids] = self.registry.latest

    # -------------------------------------------------------------- round

    def run_round(self, data, labels=None) -> RoundStats:
        """One scheduler-driven round.

        data: (n_slots, B, ...) — every slot's would-be local batch (only
        participants' rows are touched). labels: optional per-task dict
        (or bare array) of (n_slots, B) arrays riding with the uplink.
        """
        assert data.shape[0] == self.n_slots, (data.shape, self.n_slots)
        rec = _obs.active()
        t0 = time.perf_counter() if rec is not None else 0.0
        ev: RoundEvent = self.scheduler.step()
        self._deploy_fresh(ev.joined)

        ids = ev.participants
        jids = jnp.asarray(ids)
        sub = jax.tree.map(lambda x: x[jids], self.clients)
        sub, idx = self.engine.round_indices(sub, data[jids])
        self._set_slots(ids, sub)
        self._participated[ids] = True

        label_dict = None
        if labels is not None:
            label_dict = labels if isinstance(labels, dict) \
                else {"label": labels}

        # ---- split into delivery groups: (version, delay, dropped); each
        # group's payload carries ITS version + label channels, so the
        # store keys ingestion off the carrier alone
        sent = 0
        versions = self.slot_versions[ids]
        groups: Dict[tuple, list] = {}
        for j in range(ids.size):
            k = (int(versions[j]), int(ev.delays[j]), bool(ev.dropped[j]))
            groups.setdefault(k, []).append(j)
        for (version, delay, dropped), pos in groups.items():
            pos = np.asarray(pos)
            gidx = idx[jnp.asarray(pos)]
            glabels = None
            if label_dict is not None:
                grows = jnp.asarray(ids[pos])
                glabels = {t: y[grows].reshape(-1)
                           for t, y in label_dict.items()}
            packed = CodePayload.pack(gidx, bits=self.engine.bits,
                                      version=version, labels=glabels)
            sent += self.queue.send(packed, round=self.round, delay=delay,
                                    dropped=dropped, client_ids=ids[pos])

        # ---- deliver everything whose arrival round has come through the
        # single wire endpoint (version/labels read from the payload)
        delivered, n_del = self.queue.deliver(self.wire, self.round)

        # ---- low-frequency Step 5 merge over the ACTIVE population
        merged_version = None
        if self.merge_every and (self.round + 1) % self.merge_every == 0:
            merged_version = self._merge()

        stats = RoundStats(round=self.round, n_participants=ids.size,
                           n_joined=ev.joined.size, n_left=ev.left.size,
                           bytes_sent=sent, bytes_delivered=delivered,
                           n_delivered=n_del, merged_version=merged_version)
        if rec is not None:
            dur_ms = (time.perf_counter() - t0) * 1e3
            rec.event("round", round=self.round,
                      n_participants=int(ids.size),
                      n_joined=int(ev.joined.size),
                      n_left=int(ev.left.size), bytes_sent=sent,
                      bytes_delivered=delivered,
                      queue_depth=len(self.queue),
                      merged_version=merged_version, dur_ms=dur_ms)
            rec.metrics.observe("round_ms", dur_ms)
        self.round += 1
        return stats

    def _merge(self) -> int:
        act = np.nonzero(self.scheduler.active)[0]
        jact = jnp.asarray(act)
        version = self.wire.merge(
            self.clients.params["codebook"][jact],
            self.clients.ema.counts[jact],
            client_versions=self.slot_versions[act],
            staleness_decay=self.staleness_decay)
        self.n_merges += 1
        if self.redeploy_on_merge:
            # only slots that participated since the last merge synced;
            # everyone else keeps their stale deployment (and version),
            # so the NEXT merge discounts them by staleness_decay ** lag
            self._deploy_fresh(np.nonzero(self._participated
                                          & self.scheduler.active)[0])
        self._participated[:] = False
        return version

    # ---------------------------------------------------------- downstream

    def dataset(self, version=None):
        """Version-correct bulk decode of everything delivered so far
        (``OctopusServer.features``)."""
        return self.wire.features(version=version)

    @property
    def in_flight(self) -> int:
        return len(self.queue)

"""Asynchronous code-server runtime (Step 6 as a first-class subsystem).

``AsyncCodeServer`` owns the server side of the protocol under realistic
traffic: a fixed slot array of clients (stacked ``ClientState``), a
``RoundScheduler`` deciding who participates / straggles / churns, a
``CodebookRegistry`` pinning every merged dictionary, and a ``CodeStore``
absorbing the uplinks. Per round it

  1. applies churn — (re-)joining slots deploy fresh from the CURRENT
     server and adopt the latest codebook version; leavers go dark with
     whatever stale state they had,
  2. advances the participant subset through ONE jitted engine call
     (``SimEngine.round_indices``) and scatters the states back,
  3. splits the participants into delivery groups by (codebook version,
     straggler delay, dropped) and bit-packs each group's codes into its
     own measured ``repro.wire.CodePayload`` — version and label
     channels travel INSIDE the carrier, so stragglers' packets stay
     tagged with the dictionary they were packed under,
  4. delivers every in-flight payload whose arrival round has come
     through the single wire endpoint (``OctopusServer.ingest``, keyed
     on the payload's own version; dropped packets burn uplink bytes but
     never land),
  5. every ``merge_every`` rounds runs the staleness-weighted Step 5
     merge over the ACTIVE population — slots that never got sampled
     since their last deploy still sit on an older dictionary version,
     so their contribution is discounted by ``staleness_decay ** lag`` —
     registers the new dictionary version, and (optionally) re-deploys
     the slots that actually participated since the last merge (only
     they synced; everyone else keeps lagging until sampled or churned).

Downstream, ``MultiTaskTrainer`` trains any number of heads from one
bulk decode of the store — see repro.server.multitask.
"""
from __future__ import annotations

import time
from typing import Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import octopus as OC
from repro.obs import recorder as _obs
from repro.sim.engine import SimEngine
from repro.wire import CodePayload, OctopusServer

from .registry import CodebookRegistry
from .scheduler import RoundEvent, RoundScheduler
from .store import CodeStore


class PendingUplink(NamedTuple):
    """A wire payload still in flight (straggler delay). Codebook version
    and label channels ride INSIDE the payload — the carrier is the
    bookkeeping."""
    arrival_round: int
    packed: CodePayload
    client_ids: np.ndarray
    sent_round: int


class UplinkQueue:
    """In-flight uplink payloads + the measured byte ledger (§2.8).

    Shared by :class:`AsyncCodeServer` and the cohort traffic driver
    (``repro.sim.cohort.CohortEngine.run_traffic``): ``send`` charges
    every payload's MEASURED ``nbytes`` to the uplink (dropped packets
    burn bytes but never land); ``deliver`` pushes everything whose
    arrival round has come through the wire endpoint.
    """

    def __init__(self):
        self._pending: List[PendingUplink] = []
        self.bytes_sent = 0
        self.bytes_delivered = 0
        self.bytes_dropped = 0
        self.bytes_rejected = 0
        self.bytes_duplicate = 0

    def send(self, packed: CodePayload, *, round: int, delay: int = 0,
             dropped: bool = False, client_ids=None) -> int:
        """Queue one payload; returns its measured nbytes."""
        n = packed.nbytes
        self.bytes_sent += n
        rec = _obs.active()
        if rec is not None:
            rec.uplink(packed, round=int(round), delay=int(delay),
                       dropped=bool(dropped),
                       n_clients=(len(client_ids)
                                  if client_ids is not None else None))
        if dropped:
            self.bytes_dropped += n
            return n
        self._pending.append(PendingUplink(
            arrival_round=int(round) + int(delay), packed=packed,
            client_ids=client_ids, sent_round=int(round)))
        if rec is not None:
            rec.metrics.set_gauge("uplink_queue_depth", len(self._pending))
        return n

    def charge(self, packed: CodePayload, *, round: int, reason: str = "",
               client_ids=None) -> int:
        """Ledger a REFUSED payload that never queues (§2.8: refusals
        still burned their uplink bytes). Returns its measured nbytes."""
        n = packed.nbytes
        self.bytes_sent += n
        self.bytes_rejected += n
        rec = _obs.active()
        if rec is not None:
            rec.uplink(packed, round=int(round), rejected=True,
                       reason=reason,
                       n_clients=(len(client_ids)
                                  if client_ids is not None else None))
        return n

    def charge_duplicate(self, packed: CodePayload, *, round: int,
                         client_ids=None) -> int:
        """Ledger a retransmit of an envelope the server already holds:
        the bytes crossed the uplink again (sent) but must never count
        delivered — exactly-once ingest is what keeps
        ``sent == delivered + dropped + rejected + duplicate + in-flight``
        an identity instead of an approximation."""
        n = packed.nbytes
        self.bytes_sent += n
        self.bytes_duplicate += n
        rec = _obs.active()
        if rec is not None:
            rec.uplink(packed, round=int(round), duplicate=True,
                       n_clients=(len(client_ids)
                                  if client_ids is not None else None))
        return n

    def reorder_tail(self) -> bool:
        """Swap the two most recently queued payloads (fault injection:
        the channel delivered them out of send order). Returns whether a
        swap happened — with fewer than two in flight there is nothing
        to reorder."""
        if len(self._pending) < 2:
            return False
        self._pending[-1], self._pending[-2] = \
            self._pending[-2], self._pending[-1]
        return True

    def deliver(self, wire: OctopusServer, round: int, *,
                results: Optional[list] = None) -> tuple:
        """Ingest every due payload; returns (nbytes, n_payloads).

        ``results`` (a list) collects one :class:`AdmissionResult` per
        delivery attempt; a payload the wire endpoint REJECTS (retired
        version, wire violation) moves its bytes to ``bytes_rejected``
        and is not counted delivered.
        """
        delivered, n_del = 0, 0
        still: List[PendingUplink] = []
        for p in self._pending:
            if p.arrival_round <= round:
                res = wire.ingest(p.packed, client_ids=p.client_ids,
                                  round=p.sent_round)
                if results is not None:
                    results.append(res)
                if res.ok:
                    delivered += p.packed.nbytes
                    n_del += 1
                else:
                    # admitted earlier, refused at the door now (e.g. its
                    # version was retired while in flight) — witness the
                    # late rejection so byte conservation stays checkable
                    self.bytes_rejected += p.packed.nbytes
                    late = _obs.active()
                    if late is not None:
                        late.metrics.inc("admission_rejected")
                        late.event("admission", round=int(round),
                                   verdict="rejected", reason=res.reason,
                                   queue_depth=len(self._pending),
                                   nbytes=p.packed.nbytes)
            else:
                still.append(p)
        self._pending = still
        self.bytes_delivered += delivered
        rec = _obs.active()
        if rec is not None:
            rec.metrics.set_gauge("uplink_queue_depth", len(self._pending))
        return delivered, n_del

    @property
    def bytes_in_flight(self) -> int:
        return sum(p.packed.nbytes for p in self._pending)

    def __len__(self) -> int:
        return len(self._pending)


class RoundStats(NamedTuple):
    round: int
    n_participants: int
    n_joined: int
    n_left: int
    bytes_sent: int          # measured, incl. packets that will drop
    bytes_delivered: int     # measured, landed in the store this round
    n_delivered: int         # delivery groups landed this round
    merged_version: Optional[int]   # registry version if this round merged


class BulkDecodePolicy(NamedTuple):
    """When the background bulk decoder fires and how much it batches.

    The PR-7 flight recorder measured ``decode_amortization = 1.32``
    records per dispatch for the round-driven runtime; this grows that
    seed into a tunable policy: every ``interval_ticks`` service ticks,
    if at least ``min_batch`` freshly-stored records are waiting, decode
    up to ``max_batch`` of them in as few fused dispatches as their
    (version, bits) grouping allows. ``interval_ticks=0`` disables the
    background decoder (decode happens only when a trainer asks).
    """
    min_batch: int = 1
    max_batch: int = 64
    interval_ticks: int = 1


class TickStats(NamedTuple):
    """What one ``ContinuousIngestService.tick`` did."""
    tick: int
    n_offered: int           # uplinks offered since the previous tick
    bytes_offered: int       # their measured bytes (incl. refusals)
    n_delivered: int         # payloads ingested into the store this tick
    bytes_delivered: int
    n_decoded: int           # records background-bulk-decoded this tick
    decode_dispatches: int   # fused dispatches those decodes cost
    queue_depth: int         # in-flight payloads after this tick
    bytes_in_flight: int
    merged_version: Optional[int] = None


class ContinuousIngestService:
    """Clocked, admission-controlled ingest over ONE wire endpoint.

    The round-driven loop inverted: clients ``offer`` uplinks whenever
    they like; a clock ``tick`` drains the due slice of the queue into
    the store and runs the background bulk decoder. Admission control
    happens AT OFFER TIME:

      * wire violations (§2.5 flag, wire revision, retired/unknown
        codebook version) are rejected at the door — bytes still burn
        on the §2.8 ledger, the payload never queues;
      * a full queue (``capacity``) rejects with ``queue_full`` —
        backpressure instead of unbounded growth;
      * a queue past ``defer_depth`` admits but answers ``deferred`` —
        the client's signal to back off while the service catches up;
      * payloads packed under the src version of an open migration
        window admit as ``migrated``;
      * an ``uplink_id`` of ``(client_id, seq)`` names the envelope: a
        retransmit of a key the service already ADMITTED (client retry,
        channel duplication) answers ``duplicate`` and is never stored
        twice — exactly-once ingest over an at-least-once channel. Only
        admitted keys register, so a retry of a refused or dropped
        envelope can still land.

    Every offer gets a structured :class:`AdmissionResult`; per-verdict
    count/byte histograms live on ``.verdicts`` / ``.verdict_bytes``
    (and stream out as ``admission`` trace events).

    With ``persist`` (a ``repro.server.ServerPersistence``) the service
    is CRASH-CONSISTENT: every admitted offer / tick / merge / migration
    op is journaled append-only before it mutates state, and periodic
    snapshots capture the full durable state (store rings, ledgers,
    registry snapshots, open migration window, queue, dedup window,
    server pytree). :meth:`recover` = load latest snapshot + replay the
    journal tail through the normal code paths — the recovered store
    decodes bit-identically to an uninterrupted run over the same
    accepted records, even when the kill landed mid-migration.
    """

    def __init__(self, wire: OctopusServer, *,
                 queue: Optional[UplinkQueue] = None,
                 capacity: Optional[int] = None,
                 defer_depth: Optional[int] = None,
                 decode_policy: BulkDecodePolicy = BulkDecodePolicy(),
                 dedup_window: int = 4096,
                 persist=None):
        from collections import OrderedDict
        self.wire = wire
        self.queue = queue if queue is not None else UplinkQueue()
        self.capacity = capacity
        if defer_depth is None and capacity is not None:
            defer_depth = max(1, (3 * capacity) // 4)
        self.defer_depth = defer_depth
        self.decode_policy = decode_policy
        self.dedup_window = int(dedup_window)
        self.tick_idx = 0
        self.verdicts: Dict[str, int] = {}
        self.verdict_bytes: Dict[str, int] = {}
        self.decoded_records = 0
        self.decode_dispatches = 0
        self._pending_decode: list = []
        self._tick_offered = 0
        self._tick_bytes = 0
        self._seen: "OrderedDict" = OrderedDict()   # admitted uplink_ids
        self._replaying = False
        self._persist = persist
        if persist is not None:
            # snapshot 0: recovery always has a floor to replay from
            persist.snapshot(self)

    # ------------------------------------------------------------- offers

    def _refuse(self, verdict: str, reason: str, nbytes: int) -> None:
        """Journal a refusal so the crash-recovered ledger and verdict
        histogram match the uninterrupted run exactly (the payload
        itself never lands, so only the deltas are journaled)."""
        if self._persist is not None and not self._replaying:
            self._persist.log_refusal(verdict, reason, nbytes)

    def _replay_refusal(self, verdict: str, reason: str,
                        nbytes: int) -> None:
        """Re-apply a journaled refusal's ledger + histogram deltas."""
        q = self.queue
        q.bytes_sent += nbytes
        if verdict == "duplicate":
            q.bytes_duplicate += nbytes
        elif reason == "radio_drop":
            q.bytes_dropped += nbytes
        else:
            q.bytes_rejected += nbytes
        self.verdicts[verdict] = self.verdicts.get(verdict, 0) + 1
        self.verdict_bytes[verdict] = \
            self.verdict_bytes.get(verdict, 0) + nbytes

    def _result(self, verdict: str, reason: str, nbytes: int
                ) -> "AdmissionResult":
        from repro.wire.session import AdmissionResult
        self._tick_offered += 1
        self._tick_bytes += nbytes
        self.verdicts[verdict] = self.verdicts.get(verdict, 0) + 1
        self.verdict_bytes[verdict] = \
            self.verdict_bytes.get(verdict, 0) + nbytes
        rec = _obs.active()
        if rec is not None:
            rec.metrics.inc(f"admission_{verdict}")
            rec.event("admission", round=self.tick_idx, verdict=verdict,
                      reason=reason, queue_depth=len(self.queue),
                      nbytes=nbytes)
        return AdmissionResult(verdict, reason, nbytes, None)

    def offer(self, payload, *, client_ids=None, delay: int = 0,
              dropped: bool = False, uplink_id=None) -> "AdmissionResult":
        """One uplink at the door -> admission verdict.

        ``dropped`` models a radio-layer loss: the bytes burn (§2.8)
        but the payload never lands — verdict ``rejected/radio_drop``.
        ``uplink_id`` is the ``(client_id, seq)`` idempotency envelope:
        a key the service already admitted answers ``duplicate``
        (bytes to the duplicate ledger bucket, nothing stored).
        Rejections (wire violations, full queue) are ledgered via
        ``UplinkQueue.charge``; admitted payloads queue via ``send``
        and land at the ``tick`` whose clock reaches their delay.
        """
        p = self.wire._coerce(payload)
        if dropped:
            self.queue.send(p, round=self.tick_idx, delay=int(delay),
                            dropped=True, client_ids=client_ids)
            self._refuse("rejected", "radio_drop", p.nbytes)
            return self._result("rejected", "radio_drop", p.nbytes)
        key = None if uplink_id is None else \
            (int(uplink_id[0]), int(uplink_id[1]))
        if key is not None and key in self._seen:
            self.queue.charge_duplicate(p, round=self.tick_idx,
                                        client_ids=client_ids)
            self._refuse("duplicate", "dedup_window", p.nbytes)
            return self._result("duplicate", "dedup_window", p.nbytes)
        verdict, reason = self.wire.precheck(p)
        if verdict == "rejected":
            self.queue.charge(p, round=self.tick_idx, reason=reason,
                              client_ids=client_ids)
            self._refuse(verdict, reason, p.nbytes)
            return self._result(verdict, reason, p.nbytes)
        if self.capacity is not None and len(self.queue) >= self.capacity:
            self.queue.charge(p, round=self.tick_idx, reason="queue_full",
                              client_ids=client_ids)
            self._refuse("rejected", "queue_full", p.nbytes)
            return self._result("rejected", "queue_full", p.nbytes)
        if key is not None:
            self._seen[key] = True
            while len(self._seen) > self.dedup_window:
                self._seen.popitem(last=False)
        if self._persist is not None and not self._replaying:
            self._persist.log_offer(p, client_ids=client_ids,
                                    delay=int(delay), uplink_id=key)
        self.queue.send(p, round=self.tick_idx, delay=int(delay),
                        client_ids=client_ids)
        if verdict == "accepted" and self.defer_depth is not None \
                and len(self.queue) > self.defer_depth:
            verdict, reason = "deferred", "queue_pressure"
        return self._result(verdict, reason, p.nbytes)

    # -------------------------------------------------------------- clock

    def tick(self, *, merged_version: Optional[int] = None,
             extra_fields: Optional[Dict] = None,
             emit_event: bool = True) -> TickStats:
        """Advance the service clock one step: deliver every due payload
        into the store, then (under ``decode_policy``) bulk-decode a
        batch of freshly-stored records in the background."""
        rec = _obs.active()
        t0 = time.perf_counter() if rec is not None else 0.0
        if self._persist is not None and not self._replaying:
            self._persist.log_tick()
        results: list = []
        delivered, n_del = self.queue.deliver(self.wire, self.tick_idx,
                                              results=results)
        for res in results:
            if res.ok and res.record is not None:
                self._pending_decode.append(res.record)

        n_decoded, n_disp = 0, 0
        pol = self.decode_policy
        if pol.interval_ticks and \
                (self.tick_idx + 1) % pol.interval_ticks == 0 and \
                len(self._pending_decode) >= pol.min_batch:
            batch = self._pending_decode[:pol.max_batch]
            self._pending_decode = self._pending_decode[pol.max_batch:]
            n_decoded, n_disp = self._bulk_decode(batch)

        stats = TickStats(
            tick=self.tick_idx, n_offered=self._tick_offered,
            bytes_offered=self._tick_bytes, n_delivered=n_del,
            bytes_delivered=delivered, n_decoded=n_decoded,
            decode_dispatches=n_disp, queue_depth=len(self.queue),
            bytes_in_flight=self.queue.bytes_in_flight,
            merged_version=merged_version)
        if rec is not None and emit_event:
            dur_ms = (time.perf_counter() - t0) * 1e3
            rec.event("round", round=self.tick_idx,
                      n_offered=self._tick_offered,
                      bytes_sent=self._tick_bytes,
                      bytes_delivered=delivered,
                      n_delivered=n_del, n_decoded=n_decoded,
                      queue_depth=len(self.queue),
                      bytes_in_flight=self.queue.bytes_in_flight,
                      merged_version=merged_version, dur_ms=dur_ms,
                      **(extra_fields or {}))
            rec.metrics.observe("tick_ms", dur_ms)
        self._tick_offered = 0
        self._tick_bytes = 0
        self.tick_idx += 1
        if self._persist is not None and not self._replaying \
                and self._persist.snapshot_every \
                and self.tick_idx % self._persist.snapshot_every == 0:
            self._persist.snapshot(self)
        return stats

    def _bulk_decode(self, records) -> tuple:
        """Background decode: ONE fused dispatch per (version, bits)
        group of the batch, each against its pinned registry snapshot."""
        from .store import decode_group
        by_key: Dict[tuple, list] = {}
        for r in records:
            by_key.setdefault((r.version, r.packed.bits), []).append(r)
        rec = _obs.active()
        n_decoded = 0
        for (v, _), recs in by_key.items():
            cb = self.wire.registry.get(v)
            t0 = time.perf_counter() if rec is not None else 0.0
            blocks = decode_group(recs, self.wire.cfg, self.wire.state, cb)
            if rec is not None:
                jax.block_until_ready(blocks)
                dur_ms = (time.perf_counter() - t0) * 1e3
                rec.event("decode", version=int(v), dur_ms=dur_ms,
                          n_records=len(recs),
                          n_samples=int(sum(b.shape[0] for b in blocks)))
                rec.metrics.observe(f"decode_ms/v{int(v)}", dur_ms)
            n_decoded += len(recs)
        self.decoded_records += n_decoded
        self.decode_dispatches += len(by_key)
        return n_decoded, len(by_key)

    def drain(self, max_ticks: int = 1000) -> List[TickStats]:
        """Tick until the queue is empty (or ``max_ticks``), then let
        the background decoder catch up. A tail batch the policy would
        never take on its own (fewer than ``min_batch`` records waiting,
        or the background decoder disabled) is flushed directly — a
        journaled service must not spin ``max_ticks`` of empty clock
        (and journal entries) over an undrainable remainder."""
        out = []
        while len(self.queue) and len(out) < max_ticks:
            out.append(self.tick())
        pol = self.decode_policy
        while self._pending_decode and len(out) < max_ticks:
            if not pol.interval_ticks \
                    or len(self._pending_decode) < pol.min_batch:
                batch = self._pending_decode[:pol.max_batch]
                self._pending_decode = self._pending_decode[pol.max_batch:]
                self._bulk_decode(batch)
            else:
                out.append(self.tick())
        return out

    # ------------------------------------------- journaled server-side ops

    def merge_stats(self, stats) -> int:
        """Step 5 merge through the service door (journaled): delegates
        to ``OctopusServer.merge_stats`` and journals the POST-merge
        dictionary + version, so replay re-registers the bit-identical
        snapshot without the client statistics."""
        version = self.wire.merge_stats(stats)
        if self._persist is not None and not self._replaying:
            self._persist.log_merge(
                self.wire.state.params["codebook"], version)
        return version

    def begin_migration(self, *, src: Optional[int] = None,
                        dst: Optional[int] = None, policy: str = "keep"):
        """Journaled ``OctopusServer.begin_migration`` — a kill with the
        window open replays back INTO the open window."""
        win = self.wire.begin_migration(src=src, dst=dst, policy=policy)
        if self._persist is not None and not self._replaying:
            self._persist.log_migration("begin", src=win.src, dst=win.dst,
                                        policy=win.policy)
        return win

    def complete_migration(self):
        """Journaled ``OctopusServer.complete_migration``."""
        progress = self.wire.complete_migration()
        if self._persist is not None and not self._replaying:
            self._persist.log_migration("complete")
        return progress

    def _replay_merge(self, codebook, version: int) -> None:
        """Re-apply a journaled merge: adopt the journaled post-merge
        dictionary (``server_merge_stats`` replaces ONLY the codebook
        param) and re-register it as the journaled version."""
        self.wire.state = self.wire.state._replace(
            params={**self.wire.state.params,
                    "codebook": jnp.asarray(codebook)})
        got = self.wire.registry.register(self.wire.state.params["codebook"])
        if got != int(version):
            raise RuntimeError(
                f"journal replay diverged: merge registered v{got}, "
                f"journal says v{version}")

    # ------------------------------------------------------------ recovery

    @classmethod
    def recover(cls, persist, cfg, state_like, *, shard_fn=None,
                **service_kw) -> "ContinuousIngestService":
        """Rebuild a crashed service: latest snapshot + journal replay.

        ``persist`` is a ``ServerPersistence`` rooted at the crashed
        service's directory (or the directory path itself); ``cfg`` /
        ``state_like`` are the deployment's DVQAEConfig and a template
        ``ServerState`` of the right pytree structure (e.g. a fresh
        ``octopus.server_init``) — checkpoint restore needs the shapes.
        Journal entries after the snapshot's high-water mark replay
        through the NORMAL offer/tick/merge/migration paths with the
        flight recorder detached (the pre-crash run already emitted
        those events); one ``recovery`` event summarizes the drill.
        Extra ``service_kw`` (capacity, defer_depth, decode_policy, ...)
        must match the crashed service's construction.
        """
        from repro.server.persist import ServerPersistence
        from repro.wire.session import OctopusServer as _Server
        if not isinstance(persist, ServerPersistence):
            persist = ServerPersistence(persist, resume=True)
        t0 = time.perf_counter()
        snap = persist.load_snapshot(cfg, state_like, shard_fn=shard_fn)
        wire = _Server(snap["state"], cfg, store=snap["store"],
                       registry=snap["registry"])
        service = cls(wire, **service_kw)
        service.queue = snap["queue"]
        service.tick_idx = snap["tick_idx"]
        service.verdicts = snap["verdicts"]
        service.verdict_bytes = snap["verdict_bytes"]
        service.decoded_records = snap["decoded_records"]
        service.decode_dispatches = snap["decode_dispatches"]
        service._seen = snap["seen"]

        # replay the journal tail with the recorder DETACHED: these
        # mutations already streamed their events before the crash
        rec = _obs.active()
        if rec is not None:
            _obs.uninstall()
        service._replaying = True
        n_replayed = 0
        try:
            for entry in persist.journal.entries(start=snap["journal_pos"]):
                kind = entry["kind"]
                if kind == "offer":
                    service.offer(persist.decode_offer_payload(entry),
                                  client_ids=entry.get("client_ids"),
                                  delay=entry.get("delay", 0),
                                  uplink_id=entry.get("uplink_id"))
                elif kind == "refusal":
                    service._replay_refusal(entry["verdict"],
                                            entry["reason"],
                                            entry["nbytes"])
                elif kind == "tick":
                    service.tick(emit_event=False)
                elif kind == "merge":
                    service._replay_merge(
                        persist.decode_merge_codebook(entry),
                        entry["version"])
                elif kind == "migration":
                    if entry["phase"] == "begin":
                        service.wire.begin_migration(
                            src=entry["src"], dst=entry["dst"],
                            policy=entry["policy"])
                    else:
                        service.wire.complete_migration()
                n_replayed += 1
        finally:
            service._replaying = False
            if rec is not None:
                _obs.install(rec)
        service._persist = persist
        dur_ms = (time.perf_counter() - t0) * 1e3
        rec = _obs.active()
        if rec is not None:
            rec.metrics.inc("recoveries")
            rec.event("recovery", tick=service.tick_idx,
                      snapshot_tick=snap["snapshot_tick"],
                      n_replayed=n_replayed, dur_ms=dur_ms,
                      queue_depth=len(service.queue),
                      store_records=len(service.wire.store))
        return service

    # ----------------------------------------------------------- metrics

    @property
    def decode_amortization(self) -> float:
        """Records decoded per fused dispatch (higher = better batching)."""
        return self.decoded_records / max(self.decode_dispatches, 1)

    @property
    def n_rejected(self) -> int:
        return self.verdicts.get("rejected", 0)

    @property
    def n_deferred(self) -> int:
        return self.verdicts.get("deferred", 0)


class AsyncCodeServer:
    """Server runtime: scheduler-driven rounds over a versioned store.

    Since the continuous-ingest refactor this is a thin round-quantized
    shim over :class:`ContinuousIngestService` — each ``run_round`` is
    exactly one service tick (offer the round's delivery groups, tick
    the clock, merge on schedule). The background bulk decoder is OFF
    here (``interval_ticks=0``): the round driver decodes when its
    trainer asks, like it always did.
    """

    def __init__(self, engine: SimEngine, server: OC.ServerState,
                 scheduler: RoundScheduler, *,
                 store: Optional[CodeStore] = None,
                 registry: Optional[CodebookRegistry] = None,
                 merge_every: int = 0, staleness_decay: float = 0.5,
                 redeploy_on_merge: bool = True):
        self.engine = engine
        self.scheduler = scheduler
        self.n_slots = scheduler.n_slots
        # ONE wire endpoint owns server state + registry + store: ingest
        # is keyed on each payload's own codebook version
        self.wire = OctopusServer(server, engine.cfg, store=store,
                                  registry=registry)
        self.merge_every = merge_every
        self.staleness_decay = staleness_decay
        self.redeploy_on_merge = redeploy_on_merge

        self.clients = engine.init_clients(server, self.n_slots)
        self.slot_versions = np.full(self.n_slots, self.registry.latest,
                                     dtype=int)
        self._participated = np.zeros(self.n_slots, dtype=bool)
        # the round loop is one service tick per round (no background
        # decode, no admission capacity — the legacy contract)
        self.service = ContinuousIngestService(
            self.wire, decode_policy=BulkDecodePolicy(interval_ticks=0))
        self.queue = self.service.queue
        self.n_merges = 0

    @property
    def round(self) -> int:
        return self.service.tick_idx

    # --------------------------------------------- wire endpoint delegates

    @property
    def server(self) -> OC.ServerState:
        return self.wire.state

    @property
    def registry(self) -> CodebookRegistry:
        return self.wire.registry

    @property
    def store(self) -> CodeStore:
        return self.wire.store

    # byte ledger lives on the shared UplinkQueue

    @property
    def bytes_sent(self) -> int:
        return self.queue.bytes_sent

    @property
    def bytes_delivered(self) -> int:
        return self.queue.bytes_delivered

    @property
    def bytes_dropped(self) -> int:
        return self.queue.bytes_dropped

    # ------------------------------------------------------------ helpers

    def _set_slots(self, ids: np.ndarray, sub: OC.ClientState) -> None:
        self.clients = jax.tree.map(
            lambda full, part: full.at[jnp.asarray(ids)].set(part),
            self.clients, sub)

    def _deploy_fresh(self, ids: np.ndarray) -> None:
        """(Re-)deploy slots from the CURRENT server (Step 2 for joiners)."""
        if ids.size == 0:
            return
        fresh = OC.client_init(self.server)
        self.clients = jax.tree.map(
            lambda full, leaf: full.at[jnp.asarray(ids)].set(leaf),
            self.clients, fresh)
        self.slot_versions[ids] = self.registry.latest

    # -------------------------------------------------------------- round

    def run_round(self, data, labels=None) -> RoundStats:
        """One scheduler-driven round.

        data: (n_slots, B, ...) — every slot's would-be local batch (only
        participants' rows are touched). labels: optional per-task dict
        (or bare array) of (n_slots, B) arrays riding with the uplink.
        """
        assert data.shape[0] == self.n_slots, (data.shape, self.n_slots)
        rec = _obs.active()
        t0 = time.perf_counter() if rec is not None else 0.0
        ev: RoundEvent = self.scheduler.step()
        self._deploy_fresh(ev.joined)

        ids = ev.participants
        jids = jnp.asarray(ids)
        sub = jax.tree.map(lambda x: x[jids], self.clients)
        sub, idx = self.engine.round_indices(sub, data[jids])
        self._set_slots(ids, sub)
        self._participated[ids] = True

        label_dict = None
        if labels is not None:
            label_dict = labels if isinstance(labels, dict) \
                else {"label": labels}

        # ---- split into delivery groups: (version, delay, dropped); each
        # group's payload carries ITS version + label channels, so the
        # store keys ingestion off the carrier alone
        sent = 0
        versions = self.slot_versions[ids]
        groups: Dict[tuple, list] = {}
        for j in range(ids.size):
            k = (int(versions[j]), int(ev.delays[j]), bool(ev.dropped[j]))
            groups.setdefault(k, []).append(j)
        for (version, delay, dropped), pos in groups.items():
            pos = np.asarray(pos)
            gidx = idx[jnp.asarray(pos)]
            glabels = None
            if label_dict is not None:
                grows = jnp.asarray(ids[pos])
                glabels = {t: y[grows].reshape(-1)
                           for t, y in label_dict.items()}
            packed = CodePayload.pack(gidx, bits=self.engine.bits,
                                      version=version, labels=glabels)
            res = self.service.offer(packed, client_ids=ids[pos],
                                     delay=delay, dropped=dropped)
            sent += res.nbytes

        # ---- low-frequency Step 5 merge over the ACTIVE population:
        # decided BEFORE the tick so the round event carries it
        this_round = self.round
        merged_version = None
        if self.merge_every and (this_round + 1) % self.merge_every == 0:
            merged_version = self._merge()

        # ---- one service tick: deliver everything whose arrival round
        # has come through the single wire endpoint (version/labels read
        # from the payload)
        ts = self.service.tick(merged_version=merged_version,
                               emit_event=False)
        delivered, n_del = ts.bytes_delivered, ts.n_delivered

        stats = RoundStats(round=this_round, n_participants=ids.size,
                           n_joined=ev.joined.size, n_left=ev.left.size,
                           bytes_sent=sent, bytes_delivered=delivered,
                           n_delivered=n_del, merged_version=merged_version)
        if rec is not None:
            dur_ms = (time.perf_counter() - t0) * 1e3
            rec.event("round", round=this_round,
                      n_participants=int(ids.size),
                      n_joined=int(ev.joined.size),
                      n_left=int(ev.left.size), bytes_sent=sent,
                      bytes_delivered=delivered,
                      queue_depth=len(self.queue),
                      bytes_in_flight=self.queue.bytes_in_flight,
                      merged_version=merged_version, dur_ms=dur_ms)
            rec.metrics.observe("round_ms", dur_ms)
        return stats

    def _merge(self) -> int:
        act = np.nonzero(self.scheduler.active)[0]
        jact = jnp.asarray(act)
        version = self.wire.merge(
            self.clients.params["codebook"][jact],
            self.clients.ema.counts[jact],
            client_versions=self.slot_versions[act],
            staleness_decay=self.staleness_decay)
        self.n_merges += 1
        if self.redeploy_on_merge:
            # only slots that participated since the last merge synced;
            # everyone else keeps their stale deployment (and version),
            # so the NEXT merge discounts them by staleness_decay ** lag
            self._deploy_fresh(np.nonzero(self._participated
                                          & self.scheduler.active)[0])
        self._participated[:] = False
        return version

    # ---------------------------------------------------------- downstream

    def dataset(self, version=None):
        """Version-correct bulk decode of everything delivered so far
        (``OctopusServer.features``)."""
        return self.wire.features(version=version)

    @property
    def in_flight(self) -> int:
        return len(self.queue)

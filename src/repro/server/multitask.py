"""Multi-task downstream training from ONE shared code store (Step 6).

The paper's central claim for Step 6 is the amortization: clients upload
codes ONCE and the server trains *any number* of downstream tasks on
them centrally — new task, zero extra uplink. This module realizes that
for the runtime: all task heads (the paper's 3-linear-layer probes, e.g.
a content classifier next to a sensitive-attribute adversary built on
``core.disentangle``'s public/private split) train from one bulk decode
of the CodeStore, and every SGD step updates EVERY head on the same
shared feature minibatch in one jitted call — features are read once,
not once per task.

Single-task parity: with one task, ``MultiTaskTrainer.fit`` performs
exactly the ``core.downstream.sgd_train`` computation (same batch
draws, same AdamW math), so the multi-head path is a strict
generalization — tested in tests/test_server.py.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Sequence

import jax

from repro.core import downstream as DS
from repro.optim.adamw import adamw_init, adamw_update


class TaskSpec(NamedTuple):
    name: str                 # label key in the store / labels dict
    n_classes: int


class MultiTaskTrainer:
    """N probe heads over shared features, one jitted step for all."""

    def __init__(self, key, tasks: Sequence[TaskSpec], in_dim: int, *,
                 hidden: int = 128, lr: float = 1e-3):
        if not tasks:
            raise ValueError("need at least one task")
        names = [t.name for t in tasks]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate task names: {names}")
        self.tasks = tuple(tasks)
        self.in_dim = int(in_dim)
        self.lr = lr
        self.params: Dict[str, dict] = {
            t.name: DS.init_linear_probe(jax.random.fold_in(key, i),
                                         self.in_dim, t.n_classes,
                                         hidden=hidden)
            for i, t in enumerate(tasks)}
        self._opt = adamw_init(self.params)
        task_names = tuple(names)

        @jax.jit
        def step(params, opt, xb, ys):
            def loss(p):
                # disjoint per-head params: the summed loss's gradient
                # w.r.t. head t is exactly head t's own gradient
                return sum(DS.xent_loss(DS.linear_probe, p[n], xb, ys[n])
                           for n in task_names)
            g = jax.grad(loss)(params)
            return adamw_update(params, g, opt, lr=lr)

        self._step = step

    # ------------------------------------------------------------- train

    def fit(self, key, feats, labels: Dict[str, jax.Array], *,
            steps: int = 200, batch: int = 64) -> Dict[str, dict]:
        """Train every head on the shared decoded features.

        Batch selection mirrors ``downstream.sgd_train`` (fold_in(key, i)
        + randint) so a one-task trainer reproduces it exactly.
        """
        missing = [t.name for t in self.tasks if t.name not in labels]
        if missing:
            raise ValueError(f"labels missing for tasks {missing}; "
                             f"store carries {sorted(labels)}")
        feats = feats.reshape(feats.shape[0], -1)
        ys = {t.name: labels[t.name] for t in self.tasks}
        n = feats.shape[0]
        for i in range(steps):
            sel = jax.random.randint(jax.random.fold_in(key, i),
                                     (min(batch, n),), 0, n)
            self.params, self._opt = self._step(
                self.params, self._opt, feats[sel],
                {k: y[sel] for k, y in ys.items()})
        return self.params

    def fit_from_store(self, key, store, server=None, *, registry=None,
                       version=None, steps: int = 200, batch: int = 64):
        """Decode the store ONCE, then train all heads from the shared
        features. ``store`` may be a ``CodeStore`` (+ ``server`` /
        ``registry``) or a ``repro.wire.OctopusServer`` wire endpoint —
        then the version-correct decode comes from ``features()`` and
        ``version=`` filters to one codebook version. Returns (params,
        feats, labels) so callers can evaluate without re-decoding."""
        if hasattr(store, "features"):          # wire endpoint
            feats, labels = store.features(version=version)
        else:
            feats, labels = store.dataset(server, registry=registry,
                                          version=version)
        self.fit(key, feats, labels, steps=steps, batch=batch)
        return self.params, feats, labels

    # -------------------------------------------------------------- eval

    def accuracy(self, feats, labels: Dict[str, jax.Array]
                 ) -> Dict[str, float]:
        feats = feats.reshape(feats.shape[0], -1)
        return {t.name: DS.accuracy(DS.linear_probe, self.params[t.name],
                                    feats, labels[t.name])
                for t in self.tasks}

"""Activation-sharding hints.

``with_sharding_constraint`` calls scattered through the model, active only
when a hint context is installed (by the step builders) — model code stays
mesh-agnostic and runs unsharded on CPU tests.

Hints pin the two decisions XLA's SPMD propagation most often gets wrong at
scale: (1) batch stays on the data axes through every residual-stream
tensor, (2) the head axis of q/k/v lands on 'model' (falling back to the
feature axis when heads don't divide it).
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX = threading.local()


@contextmanager
def activation_sharding(mesh: Mesh, dp_axes: Tuple[str, ...]):
    prev = getattr(_CTX, "state", None)
    _CTX.state = (mesh, tuple(dp_axes))
    try:
        yield
    finally:
        _CTX.state = prev


def _state():
    return getattr(_CTX, "state", None)


def _constrain(x, spec: P):
    st = _state()
    if st is None:
        return x
    mesh, _ = st
    try:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    except Exception:
        return x


def _dp_for(dim: int) -> Optional[Tuple[str, ...]]:
    st = _state()
    if st is None:
        return None
    mesh, dp = st
    size = 1
    for a in dp:
        size *= mesh.shape[a]
    return dp if dim % size == 0 and dim >= size else None


def _model_ok(dim: int) -> bool:
    st = _state()
    if st is None:
        return False
    mesh, _ = st
    m = mesh.shape.get("model", 1)
    return dim % m == 0 and dim >= m


def residual(x):
    """(B, T, d): batch on data axes, d replicated (residual stream)."""
    if _state() is None:
        return x
    dp = _dp_for(x.shape[0])
    return _constrain(x, P(dp, None, None))


def heads(x):
    """(B, T, H, D): batch on data, heads on model.

    Fallback when heads don't divide the model axis: shard the QUERY
    SEQUENCE dim (context parallelism), NOT head_dim — sharding D puts the
    score contraction across 'model' and forces an all-reduce of the full
    (B,H,Tq,chunk) score tensor on every KV chunk (measured 6.4 GB x 960
    on starcoder2 prefill_32k; see EXPERIMENTS.md §Perf iteration 1).
    Decode (T==1) keeps the D fallback — a one-token all-reduce is cheap
    and T cannot shard.
    """
    if _state() is None:
        return x
    dp = _dp_for(x.shape[0])
    if _model_ok(x.shape[2]):
        return _constrain(x, P(dp, None, "model", None))
    if x.shape[1] > 1 and _model_ok(x.shape[1]):
        return _constrain(x, P(dp, "model", None, None))
    # decode (T==1): D fallback. A/B'd against S-sharded cache +
    # replicated q — identical collective cost (XLA reshards to its
    # preferred H@8 partial sharding either way; eliminating the residual
    # 8x1.07 GB gathers needs an 8-way mesh axis or padded heads).
    if _model_ok(x.shape[3]):
        return _constrain(x, P(dp, None, None, "model"))
    return _constrain(x, P(dp, None, None, None))


def kv_heads(x):
    """(B, T, Hkv, D) keys/values: H on model if divisible, else REPLICATED.

    The q-side fallbacks don't transfer: T-sharding k/v under context-
    parallel q makes every q-chunk re-gather keys per scan step (measured
    2x train collectives on chameleon/qwen3 whose kv=8 < 16), and
    D-sharding puts the score contraction across 'model' (iteration 1).
    Replicated kv is cheap — GQA kv heads are small by design.
    """
    if _state() is None:
        return x
    dp = _dp_for(x.shape[0])
    if _model_ok(x.shape[2]):
        return _constrain(x, P(dp, None, "model", None))
    return _constrain(x, P(dp, None, None, None))


def ffn_hidden(x):
    """(B, T, d_ff): the column-parallel intermediate — d_ff on model."""
    if _state() is None:
        return x
    dp = _dp_for(x.shape[0])
    if _model_ok(x.shape[-1]):
        return _constrain(x, P(dp, None, "model"))
    return _constrain(x, P(dp, None, None))


def logits(x):
    """(B, T, V) or (B, V): vocab on model."""
    if _state() is None:
        return x
    dp = _dp_for(x.shape[0])
    spec = [dp] + [None] * (x.ndim - 1)
    if _model_ok(x.shape[-1]):
        spec[-1] = "model"
    return _constrain(x, P(*spec))


def expert_buffer(x):
    """(E, C, d): expert-parallel dispatch buffer — E on model.

    (Iteration-2 note: sharding C over the data axes was tried and
    REFUTED — XLA adds dp<->model reshards of the buffers, +20%
    collective bytes on deepseek train_4k. See §Perf.)
    """
    if _state() is None:
        return x
    if _model_ok(x.shape[0]):
        return _constrain(x, P("model", None, None))
    return x


def expert_buffer_bucketed(x):
    """(S_dp, E, C_loc, d): source-shard-major dispatch buffer.

    Dim 0 is the token's data shard (tokens are contiguous per dp shard
    under batch sharding), so the scatter that fills the buffer is LOCAL
    to each data shard; the subsequent (S_dp@data, E@model) -> expert-major
    exchange is the all-to-all, sized tokens*k*d instead of a full-buffer
    all-reduce.
    """
    if _state() is None:
        return x
    dp = _dp_for(x.shape[0])
    espec = "model" if _model_ok(x.shape[1]) else None
    return _constrain(x, P(dp, espec, None, None))


def dp_size() -> int:
    st = _state()
    if st is None:
        return 1
    mesh, dp = st
    size = 1
    for a in dp:
        size *= mesh.shape[a]
    return size

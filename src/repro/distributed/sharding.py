"""Sharding rules: param-path names -> PartitionSpecs, with a greedy
divisible-dim fallback so EVERY assigned architecture lowers on the
(data=16, model=16) production mesh.

Contract (names set in repro.nn.layers docstring):

  embed (V, d)                 vocab on 'model'  (fallback d)
  head  (d, V)                 V on 'model'
  column-parallel  (.., in, out)   out on 'model'   [wq wk wv wi wg up_proj
                                                     in_proj x_proj w_in
                                                     wq_a wq_b wkv_a wkv_b
                                                     ffn_up router]
  row-parallel     (.., in, out)   in on 'model'    [wo down_proj out_proj
                                                     dt_proj ffn_down]
  experts (.., E, in, out)     E on 'model' (expert parallelism)
  scale/bias/1-D               replicated

Stacked segments add a leading layer axis (never sharded). Models with
>= FSDP_THRESHOLD params additionally shard a second dim over the data
axes (ZeRO-3-style fully-sharded params; optimizer state inherits specs).

If a preferred dim is not divisible by the mesh axis, the rule walks the
remaining dims largest-first and shards the first divisible one; if none
divides, the axis is dropped (replicated) — this is what lets
starcoder2's 24 heads and minicpm3's 73448 vocab lower on a 16-way axis.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

FSDP_THRESHOLD = 10_000_000_000

COLUMN_NAMES = {"wq", "wk", "wv", "wi", "wg", "up_proj", "in_proj", "x_proj",
                "w_in", "wq_a", "wq_b", "wkv_a", "wkv_b", "ffn_up", "router",
                "w_if", "proj"}
ROW_NAMES = {"wo", "down_proj", "out_proj", "dt_proj", "ffn_down"}
EMBED_NAMES = {"embed"}
HEAD_NAMES = {"head"}


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def data_axis_size(mesh: Optional[Mesh]) -> int:
    """Size of the 'data' axis (1 without a mesh) — the population/cohort
    divisibility unit: a shard_mapped SimEngine round needs the cohort
    size to divide by it."""
    if mesh is None:
        return 1
    return _axis_size(mesh, _default_dp_axes(mesh))


def _place(spec: list, shape, dim: int, axes, size: int,
           taken: set) -> bool:
    """Try to put ``axes`` on ``dim``; greedy fallback over free dims."""
    order = [dim] + sorted((d for d in range(len(shape)) if d != dim),
                           key=lambda d: -shape[d])
    for d in order:
        if d in taken or spec[d] is not None:
            continue
        if shape[d] % size == 0 and shape[d] >= size:
            spec[d] = axes if isinstance(axes, str) else tuple(axes)
            taken.add(d)
            return True
    return False


def _leaf_spec(path_names: Tuple[str, ...], shape, mesh: Mesh, *,
               fsdp: bool, dp_axes, model_axis="model") -> P:
    ndim = len(shape)
    spec: list = [None] * ndim
    taken: set = set()
    msize = _axis_size(mesh, model_axis)
    dsize = _axis_size(mesh, dp_axes)
    name = path_names[-1] if path_names else ""
    in_experts = "experts" in path_names
    # stacked segments have a leading layer axis; skip it for rule dims
    lead = 1 if ("segments" in path_names and ndim >= 2) else 0
    if in_experts:
        lead += 1  # expert axis sits after the layer axis

    if ndim == 0 or ndim == 1 or name in {"scale", "bias", "dt_bias", "A_log",
                                          "D", "skip_scale"}:
        return P()

    if in_experts and ndim - lead >= 2:
        # expert-parallel: expert dim on model axis
        edim = lead - 1
        _place(spec, shape, edim, model_axis, msize, taken)
        if fsdp:
            _place(spec, shape, ndim - 1 if name != "wo" else ndim - 2,
                   dp_axes, dsize, taken)
        return P(*spec)

    if name in EMBED_NAMES:
        _place(spec, shape, 0, model_axis, msize, taken)
        if fsdp:
            _place(spec, shape, 1, dp_axes, dsize, taken)
        return P(*spec)
    if name in HEAD_NAMES:
        _place(spec, shape, ndim - 1, model_axis, msize, taken)
        if fsdp:
            _place(spec, shape, ndim - 2, dp_axes, dsize, taken)
        return P(*spec)
    if name in COLUMN_NAMES or (name == "kernel" and ndim >= 3):
        _place(spec, shape, ndim - 1, model_axis, msize, taken)
        if fsdp:
            _place(spec, shape, ndim - 2, dp_axes, dsize, taken)
        return P(*spec)
    if name in ROW_NAMES:
        _place(spec, shape, ndim - 2, model_axis, msize, taken)
        if fsdp:
            _place(spec, shape, ndim - 1, dp_axes, dsize, taken)
        return P(*spec)
    # unknown matrices: model on the last dim, fsdp on the second-to-last
    _place(spec, shape, ndim - 1, model_axis, msize, taken)
    if fsdp:
        _place(spec, shape, ndim - 2, dp_axes, dsize, taken)
    return P(*spec)


# TP-only param bytes above which inference keeps FSDP (v5e HBM budget:
# leave room for caches/activations).
INFER_TP_BYTES_LIMIT = 12e9


def param_specs(params_shape, cfg, mesh: Mesh, *, dp_axes=None,
                mode: str = "train"):
    """PartitionSpec pytree for a params (or ShapeDtypeStruct) pytree.

    mode="train": >=10B models FSDP over the data axes (grads/optimizer
    amortize the gathers). mode="infer": params stay TP-only whenever the
    per-device TP shard fits HBM — FSDP'd weights would be re-gathered on
    EVERY decode step (measured ~6.5 GB/step on jamba decode_32k, §Perf
    iteration 3); only models whose TP shard exceeds the budget (DeepSeek
    671B: 84 GB/dev) keep FSDP.
    """
    dp_axes = dp_axes or _default_dp_axes(mesh)
    fsdp = cfg.param_count() >= FSDP_THRESHOLD
    if mode == "infer" and fsdp:
        tp_bytes = cfg.param_count() * 2 / _axis_size(mesh, "model")
        if tp_bytes <= INFER_TP_BYTES_LIMIT:
            fsdp = False

    def spec_one(path, leaf):
        names = tuple(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in path)
        return _leaf_spec(names, leaf.shape, mesh, fsdp=fsdp,
                          dp_axes=dp_axes)

    return jax.tree_util.tree_map_with_path(spec_one, params_shape)


def _default_dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def batch_spec(mesh: Mesh) -> P:
    return P(_default_dp_axes(mesh))


# model-axis dim preference per cache field (dims indexed on the STACKED
# leaf: 0=segment-layer axis, 1=batch). Chosen so the decode contraction
# stays local or reduces to a tiny partial-sum all-reduce:
#   attn k/v (L,B,S,H,D): heads first (fully local attention); else S
#     (flash-decoding-style sequence parallelism: scores partial over S,
#     one small all-reduce) — NEVER D-first (D@model makes XLA re-gather
#     the whole cache when heads don't divide; measured 8 x 1.07 GB
#     all-gathers per jamba decode step, §Perf iteration 3).
#   mla c_kv (L,B,S,R): latent rank first (absorbed-decode contraction
#     partial-sums over R), else S.
#   mamba h (L,B,di,N): channel di (state update is elementwise in di).
#   mlstm C/n (L,B,NH,DH[,DH]): last DH.
_CACHE_MODEL_PREF = {
    "k": (3, 4, 2), "v": (3, 4, 2),          # KVCache
    "c_kv": (3, 2), "k_rope": (2,),          # MLACache
    "h": (2,), "conv": (3,),                 # MambaCache (+ sLSTM h)
    "C": (4, 3), "n": (3, 2), "m": (),       # MLSTMCache / SLSTMCache
    "c": (2,),
}


def cache_specs(caches_shape, cfg, mesh: Mesh, *, batch: int):
    """Field-name-aware cache sharding.

    Leaves are (L_seg, B, ...) stacked per segment. Batch goes on the data
    axes (global_batch=1 falls back to the longest dim, i.e. sequence);
    the model axis follows _CACHE_MODEL_PREF per cache field.
    """
    dp_axes = _default_dp_axes(mesh)
    dsize = _axis_size(mesh, dp_axes)
    msize = _axis_size(mesh, "model")

    def spec_one(path, leaf):
        shape = leaf.shape
        ndim = len(shape)
        field = ""
        for p in reversed(path):
            n = getattr(p, "name", getattr(p, "key", None))
            if isinstance(n, str):
                field = n
                break
        spec: list = [None] * ndim
        taken = {0}                          # stacked layer axis
        if ndim >= 2:
            if shape[1] % dsize == 0 and shape[1] >= dsize:
                spec[1] = dp_axes
                taken.add(1)
            elif ndim > 2:
                # batch too small: put data axes on the longest dim
                _place(spec, shape, int(max(range(2, ndim),
                                            key=lambda d: shape[d])),
                       dp_axes, dsize, taken)
        pref = _CACHE_MODEL_PREF.get(field)
        order = [d for d in (pref or ()) if d < ndim] + \
            [d for d in range(ndim - 1, 1, -1) if pref is None]
        for d in order:
            if d not in taken and spec[d] is None and shape[d] % msize == 0 \
                    and shape[d] >= msize:
                spec[d] = "model"
                break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec_one, caches_shape)


def to_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def activation_constraint(x, mesh: Mesh, spec: P):
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

"""Train / prefill / serve step builders.

Each builder returns (step_fn, in_specs, out_specs) ready for
``jax.jit(step_fn, in_shardings=..., out_shardings=...)`` under a mesh.
State pytrees are described with jax.eval_shape so the dry-run never
allocates full-size parameters.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import hints
from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.models import transformer as T
from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.schedules import warmup_cosine
from . import sharding as shd


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    step: jax.Array


def state_shape(cfg: ModelConfig, key=None):
    """ShapeDtypeStruct pytree of the full train state (no allocation)."""
    def init():
        params = T.init_lm(jax.random.PRNGKey(0), cfg)
        return TrainState(params=params, opt=adamw_init(params),
                          step=jnp.zeros((), jnp.int32))
    return jax.eval_shape(init)


def params_shape(cfg: ModelConfig):
    return jax.eval_shape(lambda: T.init_lm(jax.random.PRNGKey(0), cfg))


def state_specs(cfg: ModelConfig, mesh: Mesh):
    sshape = state_shape(cfg)
    pspec = shd.param_specs(sshape.params, cfg, mesh)
    return TrainState(
        params=pspec,
        opt=AdamWState(mu=pspec, nu=pspec, count=P()),
        step=P(),
    )


# -------------------------------------------------------------------- train

def build_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh: Mesh,
                     shape: ShapeConfig):
    """Returns (train_step, in_shardings, out_shardings, arg_shapes)."""
    bspec = shd.batch_spec(mesh)
    sspecs = state_specs(cfg, mesh)

    dp_axes = shd._default_dp_axes(mesh)

    def train_step(state: TrainState, batch):
      with hints.activation_sharding(mesh, dp_axes):
        def loss_fn(params):
            if cfg.is_encoder_decoder:
                enc = T.encode_audio(params, cfg, batch["frames"])
                return T.lm_loss(params, cfg, batch["tokens"], enc_out=enc,
                                 remat=tcfg.remat)
            return T.lm_loss(params, cfg, batch["tokens"], remat=tcfg.remat)

        loss, grads = jax.value_and_grad(loss_fn)(state.params)
        lr = warmup_cosine(state.step, base_lr=tcfg.learning_rate,
                           warmup_steps=tcfg.warmup_steps,
                           total_steps=tcfg.total_steps)
        params, opt = adamw_update(state.params, grads, state.opt, lr=lr,
                                   b1=tcfg.b1, b2=tcfg.b2,
                                   weight_decay=tcfg.weight_decay,
                                   grad_clip=tcfg.grad_clip)
        return TrainState(params=params, opt=opt, step=state.step + 1), loss

    batch_shapes = {"tokens": jax.ShapeDtypeStruct(
        (shape.global_batch, shape.seq_len), jnp.int32)}
    batch_specs = {"tokens": bspec}
    if cfg.is_encoder_decoder:
        batch_shapes["frames"] = jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.n_audio_frames, cfg.d_model),
            jnp.dtype(cfg.dtype))
        batch_specs["frames"] = P(bspec[0], None, None)

    in_specs = (sspecs, batch_specs)
    out_specs = (sspecs, P())
    arg_shapes = (state_shape(cfg), batch_shapes)
    return train_step, in_specs, out_specs, arg_shapes


# ------------------------------------------------------------------ prefill

def build_prefill_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig,
                       window_override: Optional[int] = None):
    """Prefill: full-sequence forward, emit ONLY last-position logits and
    the populated caches (realistic serving: logits (B, V), not (B, S, V))."""
    bspec = shd.batch_spec(mesh)
    pshape = params_shape(cfg)
    pspecs = shd.param_specs(pshape, cfg, mesh, mode="infer")

    def cache_shapes():
        return jax.eval_shape(
            lambda: T.init_caches(cfg, shape.global_batch, shape.seq_len))

    cspecs = shd.cache_specs(cache_shapes(), cfg, mesh,
                             batch=shape.global_batch)

    dp_axes = shd._default_dp_axes(mesh)

    def prefill_step(params, batch):
      with hints.activation_sharding(mesh, dp_axes):
        enc = None
        if cfg.is_encoder_decoder:
            enc = T.encode_audio(params, cfg, batch["frames"])
        out = T.prefill(params, cfg, batch["tokens"], enc_out=enc,
                        window_override=window_override)
        return out.logits[:, -1, :]

    batch_shapes = {"tokens": jax.ShapeDtypeStruct(
        (shape.global_batch, shape.seq_len), jnp.int32)}
    batch_specs = {"tokens": bspec}
    if cfg.is_encoder_decoder:
        batch_shapes["frames"] = jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.n_audio_frames, cfg.d_model),
            jnp.dtype(cfg.dtype))
        batch_specs["frames"] = P(bspec[0], None, None)

    in_specs = (pspecs, batch_specs)
    vocab_shardable = cfg.vocab_size % mesh.shape["model"] == 0
    out_specs = P(bspec[0], "model" if vocab_shardable else None)
    arg_shapes = (pshape, batch_shapes)
    return prefill_step, in_specs, out_specs, arg_shapes


# ------------------------------------------------------------------- serve

def build_serve_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig,
                     window_override: Optional[int] = None):
    """Single-token decode against a seq_len KV cache / recurrent state."""
    bspec = shd.batch_spec(mesh)
    pshape = params_shape(cfg)
    pspecs = shd.param_specs(pshape, cfg, mesh, mode="infer")
    cshape = jax.eval_shape(
        lambda: T.init_caches(cfg, shape.global_batch, shape.seq_len))
    cspecs = shd.cache_specs(cshape, cfg, mesh, batch=shape.global_batch)
    b_shardable = shape.global_batch % _dp_size(mesh) == 0
    tok_spec = bspec if b_shardable else P(None)

    dp_axes = shd._default_dp_axes(mesh)

    def serve_step(params, token, caches, index, enc_out=None):
      with hints.activation_sharding(mesh, dp_axes):
        logits, new_caches = T.decode_step(
            params, cfg, token, caches, index, enc_out=enc_out,
            window_override=window_override)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok[:, None], new_caches

    arg_shapes = {
        "params": pshape,
        "token": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
        "caches": cshape,
        "index": jax.ShapeDtypeStruct((), jnp.int32),
    }
    in_specs = {
        "params": pspecs, "token": tok_spec, "caches": cspecs, "index": P(),
    }
    out_specs = (tok_spec, cspecs)
    if cfg.is_encoder_decoder:
        arg_shapes["enc_out"] = jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.n_audio_frames, cfg.d_model),
            jnp.dtype(cfg.dtype))
        in_specs["enc_out"] = P(bspec[0] if b_shardable else None, None, None)
    return serve_step, in_specs, out_specs, arg_shapes


def shd_to(spec_tree, mesh: Mesh):
    """PartitionSpec pytree -> NamedSharding pytree."""
    from jax.sharding import NamedSharding
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _dp_size(mesh: Mesh) -> int:
    size = mesh.shape["data"]
    if "pod" in mesh.shape:
        size *= mesh.shape["pod"]
    return size


def decode_window(cfg: ModelConfig, shape: ShapeConfig) -> Optional[int]:
    """long_500k: full-attention archs run the sliding-window variant
    (window 4096); natively sub-quadratic mixers are untouched."""
    if shape.name == "long_500k" and cfg.family not in ("ssm",):
        if cfg.sliding_window:
            return cfg.sliding_window
        return 4096
    return None

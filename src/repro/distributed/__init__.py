from . import sharding, steps

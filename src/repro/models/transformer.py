"""Unified decoder-LM assembly for every assigned architecture.

A model is a sequence of blocks; each block = (mixer, ffn) picked per layer
by ``cfg.layer_kinds()`` (attn / mla / mamba / mlstm / slstm x dense / moe /
none). Layers are grouped into maximal *homogeneous segments*; each segment
stacks its params along a leading axis and runs under ``jax.lax.scan`` —
this keeps HLO size O(#segments), not O(#layers), which matters when
lowering 61-layer DeepSeek-V3 on a 512-device mesh. Training remats each
scanned block body.

Decode carries a per-segment stacked cache pytree; one ``decode_step`` is a
single-token pass updating every layer's cache functionally.

Encoder-decoder (Whisper backbone) adds a non-causal encoder over stub
frame embeddings and cross-attention in each decoder block. Early-fusion
VLM (Chameleon) is a plain decoder whose vocab already contains image VQ
codes — the modality frontend is a stub by assignment.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import hints
from repro.configs.base import ModelConfig
from repro.nn import attention as attn_mod
from repro.nn import mla as mla_mod
from repro.nn import moe as moe_mod
from repro.nn import ssm as ssm_mod
from repro.nn import xlstm as xlstm_mod
from repro.nn.attention import KVCache, attention, cross_attention, init_attention, init_cache, init_cross_attention
from repro.nn.layers import apply_norm, embed_init, init_mlp, init_norm, mlp


# ----------------------------------------------------------------- segments

def segment_plan(cfg: ModelConfig) -> Tuple[Tuple[str, str, int], ...]:
    """Maximal runs of identical (mixer, ffn) layer signatures."""
    runs = []
    for mixer, ffn in cfg.layer_kinds():
        if runs and runs[-1][0] == mixer and runs[-1][1] == ffn:
            runs[-1][2] += 1
        else:
            runs.append([mixer, ffn, 1])
    return tuple((m, f, n) for m, f, n in runs)


def _init_mixer(key, cfg, mixer, dtype):
    if mixer == "attn":
        return init_attention(key, cfg, dtype)
    if mixer == "mla":
        return mla_mod.init_mla(key, cfg, dtype)
    if mixer == "mamba":
        return ssm_mod.init_mamba(key, cfg, dtype)
    if mixer == "mlstm":
        return xlstm_mod.init_mlstm(key, cfg, dtype)
    if mixer == "slstm":
        return xlstm_mod.init_slstm(key, cfg, dtype)
    raise ValueError(mixer)


def _init_ffn(key, cfg, ffn, dtype):
    if ffn == "dense":
        return init_mlp(key, cfg.d_model, cfg.d_ff, dtype)
    if ffn == "moe":
        return moe_mod.init_moe(key, cfg, dtype)
    if ffn == "none":
        return {}
    raise ValueError(ffn)


def _init_block(key, cfg, mixer, ffn, dtype):
    k1, k2 = jax.random.split(key)
    p = {
        "pre_norm": init_norm(cfg.norm, cfg.d_model, dtype),
        "mixer": _init_mixer(k1, cfg, mixer, dtype),
    }
    if ffn != "none":
        p["post_norm"] = init_norm(cfg.norm, cfg.d_model, dtype)
        p["ffn"] = _init_ffn(k2, cfg, ffn, dtype)
    if cfg.is_encoder_decoder:
        k3, k4 = jax.random.split(jax.random.fold_in(key, 7))
        p["cross_norm"] = init_norm(cfg.norm, cfg.d_model, dtype)
        p["cross"] = init_cross_attention(k3, cfg, dtype)
    return p


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_lm(key, cfg: ModelConfig, dtype=None):
    """Full parameter pytree. Segments hold layer-stacked params."""
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, cfg.n_layers + 8)
    params: dict = {
        "embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": init_norm(cfg.norm, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = embed_init(keys[1], cfg.vocab_size, cfg.d_model,
                                    dtype).T
    li = 0
    segments = []
    for mixer, ffn, n in segment_plan(cfg):
        blocks = [_init_block(keys[2 + li + j], cfg, mixer, ffn, dtype)
                  for j in range(n)]
        segments.append(_stack(blocks))
        li += n
    params["segments"] = segments

    if cfg.is_encoder_decoder:
        enc_keys = jax.random.split(keys[-1], cfg.n_encoder_layers + 1)
        enc_blocks = []
        for j in range(cfg.n_encoder_layers):
            k1, k2 = jax.random.split(enc_keys[j])
            enc_blocks.append({
                "pre_norm": init_norm(cfg.norm, cfg.d_model, dtype),
                "mixer": init_attention(k1, cfg, dtype),
                "post_norm": init_norm(cfg.norm, cfg.d_model, dtype),
                "ffn": init_mlp(k2, cfg.d_model, cfg.d_ff, dtype),
            })
        params["encoder"] = _stack(enc_blocks)
        params["enc_final_norm"] = init_norm(cfg.norm, cfg.d_model, dtype)

    if cfg.use_mtp:
        km = jax.random.fold_in(keys[-1], 99)
        k1, k2 = jax.random.split(km)
        params["mtp"] = {
            "proj": embed_init(k1, 2 * cfg.d_model, cfg.d_model, dtype),
            "block": _init_block(k2, cfg, "attn", "dense", dtype),
            "norm": init_norm(cfg.norm, cfg.d_model, dtype),
        }
    return params


# ------------------------------------------------------------------- blocks

def _apply_mixer(bp, cfg, mixer, x, positions, cache, cache_index,
                 window_override):
    if mixer == "attn":
        return attention(bp["mixer"], cfg, x, positions, cache=cache,
                         cache_index=cache_index,
                         window_override=window_override)
    if mixer == "mla":
        return mla_mod.mla_attention(bp["mixer"], cfg, x, positions,
                                     cache=cache, cache_index=cache_index)
    if mixer == "mamba":
        return ssm_mod.mamba(bp["mixer"], cfg, x, cache=cache,
                             cache_index=cache_index)
    if mixer == "mlstm":
        return xlstm_mod.mlstm(bp["mixer"], cfg, x, cache=cache,
                               cache_index=cache_index)
    if mixer == "slstm":
        return xlstm_mod.slstm(bp["mixer"], cfg, x, cache=cache,
                               cache_index=cache_index)
    raise ValueError(mixer)


def _apply_block(bp, cfg, mixer, ffn, x, positions, *, cache=None,
                 cache_index=None, enc_out=None, window_override=None):
    """Pre-norm residual block. Returns (x, new_cache, aux_loss)."""
    h = apply_norm(cfg.norm, bp["pre_norm"], x, cfg.norm_eps)
    mix, new_cache = _apply_mixer(bp, cfg, mixer, h, positions, cache,
                                  cache_index, window_override)
    x = x + mix
    if cfg.is_encoder_decoder and enc_out is not None:
        h = apply_norm(cfg.norm, bp["cross_norm"], x, cfg.norm_eps)
        x = x + cross_attention(bp["cross"], cfg, h, enc_out)
    aux = jnp.zeros((), jnp.float32)
    if ffn == "dense":
        h = apply_norm(cfg.norm, bp["post_norm"], x, cfg.norm_eps)
        x = x + mlp(bp["ffn"], h, cfg.activation)
    elif ffn == "moe":
        h = apply_norm(cfg.norm, bp["post_norm"], x, cfg.norm_eps)
        out = moe_mod.moe_apply(bp["ffn"], cfg, h, activation=cfg.activation)
        x = x + out.y
        aux = out.aux_loss
    return x, new_cache, aux


# ------------------------------------------------------------------ forward

class LMOut(NamedTuple):
    logits: jax.Array
    aux_loss: jax.Array
    hidden: jax.Array


def _run_segments(params, cfg, x, positions, *, caches=None, cache_index=None,
                  enc_out=None, remat=False, window_override=None):
    """Scan each homogeneous segment. caches: per-segment stacked pytrees."""
    plan = segment_plan(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = []
    for si, (mixer, ffn, n) in enumerate(plan):
        seg_params = params["segments"][si]
        seg_cache = None if caches is None else caches[si]

        def body(carry, layer_in):
            xc, aux = carry
            bp, lc = layer_in
            xc, nc, a = _apply_block(
                bp, cfg, mixer, ffn, xc, positions, cache=lc,
                cache_index=cache_index, enc_out=enc_out,
                window_override=window_override)
            return (hints.residual(xc), aux + a), nc

        body_fn = jax.checkpoint(body) if remat else body
        (x, aux_total), seg_new_cache = jax.lax.scan(
            body_fn, (x, aux_total), (seg_params, seg_cache))
        new_caches.append(seg_new_cache)
    return x, aux_total, (new_caches if caches is not None else None)


def encode_audio(params, cfg: ModelConfig, frames):
    """Whisper encoder over stub frame embeddings (B, n_frames, d)."""
    x = frames
    pos = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])

    # encoder is non-causal: full attention without a mask
    def body_noncausal(xc, bp):
        h = apply_norm(cfg.norm, bp["pre_norm"], xc, cfg.norm_eps)
        B, T, _ = h.shape
        hd = cfg.resolved_head_dim
        q = (h @ bp["mixer"]["wq"]).reshape(B, T, cfg.n_heads, hd)
        k = (h @ bp["mixer"]["wk"]).reshape(B, T, cfg.n_kv_heads, hd)
        v = (h @ bp["mixer"]["wv"]).reshape(B, T, cfg.n_kv_heads, hd)
        q = attn_mod.apply_rope(q, pos, cfg.rope_theta)
        k = attn_mod.apply_rope(k, pos, cfg.rope_theta)
        o = attn_mod.attend(q, k, v, causal=False, force_chunked=False)
        xc = xc + o.reshape(B, T, cfg.n_heads * hd) @ bp["mixer"]["wo"]
        hh = apply_norm(cfg.norm, bp["post_norm"], xc, cfg.norm_eps)
        return xc + mlp(bp["ffn"], hh, cfg.activation), None

    x, _ = jax.lax.scan(body_noncausal, x, params["encoder"])
    return apply_norm(cfg.norm, params["enc_final_norm"], x, cfg.norm_eps)


def forward(params, cfg: ModelConfig, tokens, *, enc_out=None, remat=False,
            window_override=None) -> LMOut:
    """Teacher-forced forward. tokens: (B, T) int32 -> logits (B, T, V)."""
    B, T = tokens.shape
    x = hints.residual(params["embed"][tokens].astype(jnp.dtype(cfg.dtype)))
    positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    x, aux, _ = _run_segments(params, cfg, x, positions, enc_out=enc_out,
                              remat=remat, window_override=window_override)
    hidden = apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
    logits = _lm_head(params, cfg, hidden)
    return LMOut(logits=logits, aux_loss=aux, hidden=hidden)


def _lm_head(params, cfg, hidden):
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = hints.logits(hidden @ w)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


def lm_loss(params, cfg: ModelConfig, tokens, *, enc_out=None, remat=True,
            window_override=None):
    """Next-token cross-entropy (+ MoE aux + optional MTP)."""
    out = forward(params, cfg, tokens, enc_out=enc_out, remat=remat,
                  window_override=window_override)
    logits = out.logits[:, :-1].astype(jnp.float32)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll) + out.aux_loss

    if cfg.use_mtp:
        # DeepSeek-V3 multi-token prediction: predict t+2 from (h_t, emb_{t+1})
        h = out.hidden[:, :-2]
        nxt = params["embed"][tokens[:, 1:-1]].astype(h.dtype)
        z = jnp.concatenate([h, nxt], axis=-1) @ params["mtp"]["proj"]
        pos = jnp.broadcast_to(jnp.arange(z.shape[1])[None], z.shape[:2])
        z = _apply_block(params["mtp"]["block"], cfg, "attn", "dense",
                         z, pos)[0]
        z = apply_norm(cfg.norm, params["mtp"]["norm"], z, cfg.norm_eps)
        mtp_logits = _lm_head(params, cfg, z).astype(jnp.float32)
        t2 = tokens[:, 2:]
        logp2 = jax.nn.log_softmax(mtp_logits)
        nll2 = -jnp.take_along_axis(logp2, t2[..., None], axis=-1)[..., 0]
        loss = loss + cfg.mtp_loss_weight * jnp.mean(nll2)
    return loss


# ------------------------------------------------------------------- decode

def init_caches(cfg: ModelConfig, batch: int, seq_len: int, dtype=None):
    """Per-segment stacked caches for decode."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    caches = []
    for mixer, ffn, n in segment_plan(cfg):
        if mixer == "attn":
            one = init_cache(cfg, batch, seq_len, dtype)
        elif mixer == "mla":
            one = mla_mod.init_mla_cache(cfg, batch, seq_len, dtype)
        elif mixer == "mamba":
            one = ssm_mod.init_mamba_cache(cfg, batch, dtype)
        elif mixer == "mlstm":
            one = xlstm_mod.init_mlstm_cache(cfg, batch, dtype)
        elif mixer == "slstm":
            one = xlstm_mod.init_slstm_cache(cfg, batch, dtype)
        else:
            raise ValueError(mixer)
        caches.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), one))
    return caches


def decode_step(params, cfg: ModelConfig, token, caches, index, *,
                enc_out=None, window_override=None):
    """One-token decode. token: (B, 1) int32; index: scalar int32 position.

    Returns (logits (B, 1, V), new_caches).
    """
    B = token.shape[0]
    x = params["embed"][token].astype(jnp.dtype(cfg.dtype))
    positions = jnp.broadcast_to(index[None, None], (B, 1)).astype(jnp.int32)
    x, _, new_caches = _run_segments(
        params, cfg, x, positions, caches=caches, cache_index=index,
        enc_out=enc_out, window_override=window_override)
    hidden = apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
    return _lm_head(params, cfg, hidden), new_caches


def prefill(params, cfg: ModelConfig, tokens, *, enc_out=None,
            window_override=None) -> LMOut:
    """Prefill = teacher-forced forward without remat (inference)."""
    return forward(params, cfg, tokens, enc_out=enc_out, remat=False,
                   window_override=window_override)

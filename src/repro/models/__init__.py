from . import transformer
from .transformer import (decode_step, encode_audio, forward, init_caches,
                          init_lm, lm_loss, prefill, segment_plan)

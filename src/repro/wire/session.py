"""Session facades over the wire protocol: one client entry, one server
entry.

After PRs 1-4 the client↔server interface was a function zoo
(``client_transmit`` / ``client_round`` / ``client_round_fused`` /
``client_finetune_encode`` on one side; ``gather_codes`` /
``unpack_transmission`` / hand-wired CodeStore+Registry on the other).
These two classes subsume it:

  * :class:`OctopusClient` — ``round(batch)`` is THE uplink: Steps 2-5
    through the fused Pallas encode path (ONE encoder pass feeding ONE
    ``ops.encode_codes`` dispatch that quantizes, bit-packs and
    accumulates the EMA statistics on-chip), returning a
    :class:`CodePayload`. Policy flags pick the protocol profile —
    ``finetune=0`` skips Step 2, ``refresh=False`` skips Step 5;
    ``transmit(batch)`` is the encode-only profile (the old
    ``client_transmit``).
  * :class:`OctopusServer` — ``ingest(payload)`` / ``features()`` is THE
    downlink: payloads land in a versioned CodeStore keyed on the
    payload's OWN codebook version and decode against the registry
    snapshot they were packed under. ``ingest`` returns a structured
    :class:`AdmissionResult` verdict (accepted / migrated / deferred /
    rejected) instead of raising — payloads that are not marked
    ``privatized``, speak a different wire revision, or name a retired
    codebook version are REJECTED with a reason, and their measured
    bytes stay on the §2.8 ledger. Rolling ``v_n -> v_{n+1}`` codebook
    upgrades run through ``begin_migration`` / ``complete_migration``.

The pure, jittable round core is :func:`round_words` — bit-identical to
the PR-4 ``client_round_fused`` tail (same calls, same dispatch count);
``SimEngine`` remains the batched population driver for the same wire.
"""
from __future__ import annotations

import time
import zlib
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import octopus as OC
from repro.core.dvqae import DVQAEConfig
from repro.obs import recorder as _obs

from .payload import (SUPPORTED_WIRE_VERSIONS, WIRE_VERSION, CodePayload,
                      as_payload)

#: admission verdicts an ingest path can return (§2.8: ALL of them keep
#: the payload's measured bytes on the ledger, accepted or not)
ADMISSION_VERDICTS = ("accepted", "migrated", "deferred", "rejected",
                      "duplicate")

#: rejection reasons worth retrying: the condition is transient (load or
#: channel noise), so the SAME envelope re-sent later can land. The
#: other reasons (wire_revision, unprivatized, retired/unknown version)
#: are protocol facts a retransmit cannot fix.
TRANSIENT_REASONS = ("queue_full", "radio_drop", "corrupt")


class RetryPolicy(NamedTuple):
    """Capped exponential backoff for transient uplink failures.

    Attempt ``a`` waits ``min(base_ticks * 2**a, cap_ticks)`` service
    ticks plus a deterministic jitter in ``[0, jitter_ticks]`` hashed
    from (salt, attempt) — retries de-synchronize across clients without
    consuming anybody's PRNG stream (toggling retry must not perturb
    population or traffic draws).
    """
    max_attempts: int = 4
    base_ticks: int = 1
    cap_ticks: int = 8
    jitter_ticks: int = 1

    def backoff(self, attempt: int, *, salt="") -> int:
        wait = min(self.base_ticks * (2 ** int(attempt)), self.cap_ticks)
        if self.jitter_ticks:
            h = zlib.crc32(f"retry|{salt}|{int(attempt)}".encode())
            wait += h % (self.jitter_ticks + 1)
        return int(wait)

    def retryable(self, result: "AdmissionResult") -> bool:
        """deferred and transient rejections retry; accepted / migrated /
        duplicate (the server already holds this envelope) stop."""
        return (result.verdict == "deferred"
                or (result.verdict == "rejected"
                    and result.reason in TRANSIENT_REASONS))


class AdmissionResult(NamedTuple):
    """Structured verdict for one uplink payload at the server door.

    ``verdict``:
      accepted — stored (or queued) on the current codebook version
      migrated — stored, but packed under the src version of an OPEN
                 migration window (will be kept/retired/re-encoded when
                 the window closes)
      deferred — queued under backpressure; will be decoded, later
      rejected — refused (``reason`` says why); bytes still ledgered
      duplicate — this ``(client_id, seq)`` envelope was already
                 admitted; the retransmit is acknowledged but NOT
                 stored again (exactly-once ingest)
    ``nbytes`` is the payload's measured wire size; ``record`` is the
    StoreRecord for verdicts that stored the payload, else None.
    """
    verdict: str
    reason: str = ""
    nbytes: int = 0
    record: Optional[object] = None

    @property
    def ok(self) -> bool:
        return self.verdict != "rejected"


# --------------------------------------------------------- pure round core

def _round_core(client: OC.ClientState, cfg: DVQAEConfig, batch, *,
                lr: float = 1e-4, gamma: float = 0.99,
                n_local_steps: int = 1, refresh: bool = True):
    """Steps 2-5 with the fused uplink tail -> (client, z, words).

    Exactly the ``client_round_fused`` computation: ``n_local_steps`` of
    frozen-codebook fine-tuning, ONE encoder pass, ONE
    ``ops.encode_codes`` dispatch (quantize + pack + EMA stats on-chip),
    optional Step 5 refresh from the precomputed statistics. Neither the
    (N, K) distance matrix nor the int32 index tensor ever materializes.
    """
    from repro.kernels.ops import encode_codes
    client, z = OC.client_finetune_encode(client, cfg, batch, lr=lr,
                                          n_local_steps=n_local_steps)
    zf = z.reshape(1, -1, z.shape[-1])
    words, counts, sums = encode_codes(
        zf, client.params["codebook"][None], bits=OC.transmit_bits(cfg),
        n_groups=cfg.n_groups, n_slices=cfg.n_slices)
    if refresh:
        client = OC.client_codebook_refresh(client, cfg, None, gamma=gamma,
                                            stats=(counts[0], sums[0]))
    return client, z, words


def round_words(client: OC.ClientState, cfg: DVQAEConfig, batch, *,
                lr: float = 1e-4, gamma: float = 0.99,
                n_local_steps: int = 1, refresh: bool = True
                ) -> Tuple[OC.ClientState, jax.Array]:
    """Pure jittable round: (client, batch) -> (client, uint32 words).

    The words are exactly ``pack_codes(indices, transmit_bits(cfg))`` for
    the round's indices — wrap in ``jax.jit`` (or drive populations via
    ``SimEngine``) and build the :class:`CodePayload` outside the trace.
    """
    client, _, words = _round_core(client, cfg, batch, lr=lr, gamma=gamma,
                                   n_local_steps=n_local_steps,
                                   refresh=refresh)
    return client, words


def index_shape(cfg: DVQAEConfig, z_shape) -> Tuple[int, ...]:
    """Transmitted index shape for latents of shape (..., M): GSVQ sends
    one group index per slice per position."""
    base = tuple(int(d) for d in z_shape[:-1])
    if cfg.n_groups > 1 or cfg.n_slices > 1:
        return base + (cfg.n_slices,)
    return base


def fused_round(client: OC.ClientState, cfg: DVQAEConfig, batch, *,
                lr: float = 1e-4, gamma: float = 0.99,
                n_local_steps: int = 1, refresh: bool = True,
                version: int = 0, labels=None
                ) -> Tuple[OC.ClientState, CodePayload]:
    """One client round -> (client, CodePayload). The payload carries the
    wire's (C, B, ...) leading layout with C == 1 — one record stream,
    ready for ``OctopusServer.ingest`` / ``CodeStore.add``."""
    client, z, words = _round_core(client, cfg, batch, lr=lr, gamma=gamma,
                                   n_local_steps=n_local_steps,
                                   refresh=refresh)
    shape = (1,) + index_shape(cfg, z.shape)
    return client, CodePayload.from_words(
        words, bits=OC.transmit_bits(cfg), shape=shape, n_records=1,
        version=version, labels=labels, n_samples=int(z.shape[0]),
        privatized=True)


# ----------------------------------------------------------------- client

class OctopusClient:
    """One client device's session: local DVQ-AE state + uplink policy.

    ``server`` is an :class:`OctopusServer` (deploys from its current
    state and codebook version) or a bare ``octopus.ServerState``.
    """

    def __init__(self, server, cfg: Optional[DVQAEConfig] = None, *,
                 lr: float = 1e-4, gamma: float = 0.99,
                 n_local_steps: int = 1, client_id: int = 0):
        if isinstance(server, OctopusServer):
            cfg = cfg or server.cfg
            state, version = server.state, server.version
        else:
            if cfg is None:
                raise ValueError("OctopusClient(ServerState, ...) needs an "
                                 "explicit cfg")
            state, version = server, 0
        self.cfg = cfg
        self.lr = lr
        self.gamma = gamma
        self.n_local_steps = n_local_steps
        self.client_id = int(client_id)
        self.state = OC.client_init(state)
        self.version = int(version)
        self._seq = 0                    # next uplink envelope sequence no.

    # -------------------------------------------------------------- steps

    @property
    def codebook(self) -> jax.Array:
        return self.state.params["codebook"]

    def finetune(self, batch, *, steps: int = 1, lr: Optional[float] = None
                 ) -> None:
        """Explicit Step 2: frozen-codebook local fine-tuning."""
        opt = None
        for _ in range(steps):
            self.state, opt, _ = OC.client_finetune_step(
                self.state, self.cfg, batch,
                lr=self.lr if lr is None else lr, opt=opt)

    def round(self, batch, *, labels=None, finetune: Optional[int] = None,
              refresh: bool = True) -> CodePayload:
        """THE uplink entry: Steps 2-5 through the fused encode path.

        ``finetune`` overrides the session's ``n_local_steps`` for this
        round (0 skips Step 2); ``refresh=False`` skips the Step 5 EMA
        refresh. Returns the round's :class:`CodePayload`, stamped with
        the codebook version this client deployed from.
        """
        n_local = self.n_local_steps if finetune is None else int(finetune)
        rec = _obs.active()
        t0 = time.perf_counter() if rec is not None else 0.0
        self.state, payload = fused_round(
            self.state, self.cfg, batch, lr=self.lr, gamma=self.gamma,
            n_local_steps=n_local, refresh=refresh, version=self.version,
            labels=labels)
        if rec is not None:
            jax.block_until_ready(payload.payload)
            rec.event("encode", dur_ms=(time.perf_counter() - t0) * 1e3,
                      client_id=self.client_id, n_local_steps=n_local,
                      refresh=bool(refresh), **_obs.payload_meta(payload))
            rec.uplink(payload, client_id=self.client_id)
        return payload

    def transmit(self, batch, *, labels=None) -> CodePayload:
        """Encode-only uplink (Steps 3-4): no fine-tuning, no refresh —
        the old ``client_transmit``, minus the materialized index tensor."""
        return self.round(batch, labels=labels, finetune=0, refresh=False)

    # ---------------------------------------------------- exactly-once send

    def next_seq(self) -> int:
        """Mint the next envelope sequence number: ``(client_id, seq)``
        is the idempotency key the server dedups retransmits on."""
        seq, self._seq = self._seq, self._seq + 1
        return seq

    def send(self, target, payload: CodePayload, *,
             retry: Optional[RetryPolicy] = None,
             clock=None) -> AdmissionResult:
        """Offer ONE payload under a fresh ``(client_id, seq)`` envelope,
        retrying transient verdicts with capped exponential backoff.

        ``target`` is anything with the continuous ``offer`` door (a
        ``ContinuousIngestService`` or a ``FaultyChannel`` in front of
        one). Between attempts the client waits ``retry.backoff`` ticks
        by calling ``clock()`` (default: ``target.tick``) — the envelope
        key stays FIXED across attempts, so a retransmit of a payload
        the server already admitted comes back ``duplicate`` and is
        never double-counted.
        """
        seq = self.next_seq()
        step = clock if clock is not None else getattr(target, "tick", None)
        rec = _obs.active()
        attempt = 0
        while True:
            res = target.offer(payload, client_ids=[self.client_id],
                               uplink_id=(self.client_id, seq))
            if (retry is None or not retry.retryable(res)
                    or attempt >= retry.max_attempts):
                return res
            wait = retry.backoff(attempt,
                                 salt=f"{self.client_id}.{seq}")
            if rec is not None:
                rec.metrics.inc("retries")
                rec.event("retry", client_id=self.client_id, seq=seq,
                          attempt=attempt, wait_ticks=wait,
                          verdict=res.verdict, reason=res.reason)
            if step is not None:
                for _ in range(wait):
                    step()
            attempt += 1

    def uplink(self, target, batch, *, labels=None,
               retry: Optional[RetryPolicy] = None,
               clock=None) -> AdmissionResult:
        """``round`` + exactly-once ``send`` in one call: encode the
        batch ONCE, then (re)transmit the same payload under one
        idempotency key until the server holds it or retries exhaust."""
        return self.send(target, self.round(batch, labels=labels),
                         retry=retry, clock=clock)

    def sync(self, server: "OctopusServer") -> None:
        """Adopt the server's latest merged dictionary (Step 5 tail on
        the client side) and its codebook version; the local EMA restarts
        from the adopted atoms, fine-tuned encoder/decoder stay."""
        from repro.core.ema import init_ema
        cb = server.registry.current
        self.state = OC.ClientState(
            params={**self.state.params, "codebook": cb},
            ema=init_ema(cb), step=self.state.step)
        self.version = server.version


# ----------------------------------------------------------------- server

class OctopusServer:
    """Server session: versioned registry + code store behind ONE door.

    ``ingest`` keys every payload on its own ``version`` field (the
    per-delivery-group bookkeeping structs of the async runtime collapse
    into the carrier); ``features`` bulk-decodes version-correctly.
    """

    def __init__(self, server, cfg: Optional[DVQAEConfig] = None, *,
                 store=None, registry=None, require_privatized: bool = True):
        from repro.server.registry import CodebookRegistry
        from repro.server.store import CodeStore
        if not isinstance(server, OC.ServerState):
            raise TypeError("OctopusServer wraps an octopus.ServerState; "
                            "build one with octopus.server_init(key, cfg)")
        if cfg is None:
            raise ValueError("OctopusServer needs the DVQAEConfig")
        self.cfg = cfg
        self.state = server
        self.registry = registry if registry is not None else \
            CodebookRegistry(server.params["codebook"])
        self.store = store if store is not None else CodeStore(cfg)
        self.require_privatized = require_privatized

    @classmethod
    def init(cls, key, cfg: DVQAEConfig, *, lr: float = 1e-3, **kw
             ) -> "OctopusServer":
        return cls(OC.server_init(key, cfg, lr=lr), cfg, **kw)

    # ------------------------------------------------------------ protocol

    @property
    def version(self) -> int:
        """Current (latest merged) codebook version."""
        return self.registry.latest

    def pretrain(self, key, x, *, steps: int, batch: int = 32,
                 lr: float = 1e-3):
        """Step 1: ATD pretraining of the global DVQ-AE. Re-pins the
        pretrained dictionary as the current registry snapshot — only
        legal before any payload landed, or already-stored codes would
        silently decode against a dictionary they were not packed under.
        """
        if len(self.store):
            raise RuntimeError(
                f"pretrain would move codebook version "
                f"{self.registry.latest} under {len(self.store)} stored "
                f"payload(s); pretrain before ingesting (Step 1 precedes "
                f"Step 4)")
        self.state, out = OC.server_pretrain(key, self.state, self.cfg, x,
                                             steps=steps, batch=batch, lr=lr)
        self.registry.pin_current(self.state.params["codebook"])
        return out

    def deploy(self, **client_kw) -> OctopusClient:
        """Step 2: hand a client a session on the current global model."""
        return OctopusClient(self, **client_kw)

    def _coerce(self, payload) -> CodePayload:
        """Any carrier -> a CodePayload in the wire's (C, B, ...) leading
        layout. Legacy packed Transmissions ((B, T[, n_c]) indices with
        per-sample labels) are lifted to a single-client record."""
        p = as_payload(payload)
        if p is None:
            raise TypeError(f"the wire endpoint wants a CodePayload (or a "
                            f"packed legacy carrier), got "
                            f"{type(payload).__name__}")
        if hasattr(payload, "indices"):
            # the checksum covers the shape — restamp after the lift
            p = p._replace(shape=(1,) + p.shape).stamped()
        return p

    def precheck(self, p: CodePayload) -> Tuple[str, str]:
        """Wire-invariant admission check -> (verdict, reason), without
        touching the store. Rejections: unknown wire revision, missing
        §2.5 privatized flag, retired or never-registered codebook
        version, or a failed integrity check (short word stream, CRC
        mismatch) -> ``corrupt``. A payload packed under the src version
        of an OPEN migration window admits as ``migrated``."""
        if p.wire not in SUPPORTED_WIRE_VERSIONS:
            return "rejected", "wire_revision"
        if self.require_privatized and not p.privatized:
            return "rejected", "unprivatized"
        if self.registry.is_retired(p.version):
            return "rejected", "retired_version"
        if p.version not in self.registry:
            return "rejected", "unknown_version"
        if not p.verify():
            return "rejected", "corrupt"
        win = self.registry.migration
        if win is not None and int(p.version) == win.src:
            return "migrated", "migration_window"
        return "accepted", ""

    def ingest(self, payload, *, client_ids=None, round: int = 0
               ) -> AdmissionResult:
        """THE downlink entry: one payload into the versioned store.

        Coerces legacy carriers (packed ``Transmission``) — a carrier
        that is not a payload at all still raises ``TypeError`` — then
        runs :meth:`precheck` and returns a structured
        :class:`AdmissionResult` instead of raising on wire violations.
        Rejected payloads do NOT enter the store, but their measured
        bytes are counted (§2.8 accounting includes refusals).
        """
        p = self._coerce(payload)
        verdict, reason = self.precheck(p)
        rec = _obs.active()
        if verdict == "rejected":
            if rec is not None:
                rec.metrics.inc("uplinks_rejected")
                rec.metrics.inc("bytes_rejected", p.nbytes)
            return AdmissionResult(verdict, reason, p.nbytes, None)
        out = self.store.add(p, client_ids=client_ids, round=round)
        if rec is not None:
            rec.metrics.inc("uplinks_ingested")
            rec.metrics.inc("bytes_ingested", p.nbytes)
            if verdict == "migrated":
                rec.metrics.inc("uplinks_migrated")
            rec.event("ingest", round=int(round), verdict=verdict,
                      **_obs.payload_meta(p))
        return AdmissionResult(verdict, reason, p.nbytes, out)

    def features(self, *, version: Optional[int] = None):
        """Bulk decode of everything ingested, each version group against
        its own registry snapshot, ONE fused dispatch per version.
        ``version`` filters to payloads packed under that version.
        Returns (features (N, ...), {task: (N,) labels})."""
        return self.store.dataset(self.state, registry=self.registry,
                                  version=version)

    def decode(self, payload) -> jax.Array:
        """Directly decode ONE payload (store bypass) against the
        snapshot it was packed under; merges the client axis. Legacy
        Transmissions are lifted to (C=1, ...) like ``ingest`` does."""
        p = self._coerce(payload)
        rec = _obs.active()
        t0 = time.perf_counter() if rec is not None else 0.0
        feats = OC.codes_to_features(None, self.cfg, p,
                                     codebook=self.registry.get(p.version))
        out = feats.reshape((-1,) + feats.shape[2:])
        if rec is not None:
            jax.block_until_ready(out)
            dur_ms = (time.perf_counter() - t0) * 1e3
            rec.event("decode", version=int(p.version), dur_ms=dur_ms,
                      n_samples=int(out.shape[0]))
            rec.metrics.observe(f"decode_ms/v{int(p.version)}", dur_ms)
        return out

    # ----------------------------------------------------------- migration

    def begin_migration(self, *, src: Optional[int] = None,
                        dst: Optional[int] = None, policy: str = "keep"):
        """Open a rolling ``src -> dst`` codebook upgrade window (defaults:
        latest-1 -> latest). While open, payloads of BOTH versions ingest
        concurrently — src-version ones get ``migrated`` verdicts."""
        win = self.registry.begin_migration(src=src, dst=dst, policy=policy)
        rec = _obs.active()
        if rec is not None:
            rec.metrics.set_gauge("migration_open", 1)
            rec.event("migration", phase="begin", src=win.src, dst=win.dst,
                      policy=win.policy)
        return win

    def migration_progress(self) -> Dict[str, int]:
        """Record/byte counts for the open window's src and dst versions —
        how much of the store still speaks the old dictionary."""
        win = self.registry.migration
        if win is None:
            raise ValueError("no migration window is open")
        by_v = self.store.stored_bytes_by_version
        recs = self.store.records
        return {
            "src": win.src, "dst": win.dst,
            "src_records": sum(1 for r in recs if r.version == win.src),
            "dst_records": sum(1 for r in recs if r.version == win.dst),
            "src_bytes": by_v.get(win.src, 0),
            "dst_bytes": by_v.get(win.dst, 0),
        }

    def complete_migration(self) -> Dict[str, int]:
        """Close the window and apply its policy to src-version records:
        ``keep`` leaves them decoding against their pinned snapshot;
        ``retire`` evicts them (bytes stay ledgered) and refuses future
        src uplinks; ``reencode`` transcodes them to the dst codebook
        before retiring src. Returns the final progress summary."""
        progress = self.migration_progress()
        win = self.registry.close_migration()
        n_reencoded = 0
        if win.policy in ("retire", "reencode"):
            gone = self.store.retire_version(win.src)
            if win.policy == "reencode":
                for r in gone:
                    p = self._reencode_payload(r.packed, win.dst)
                    self.store.add(p, client_ids=r.client_ids,
                                   round=r.round, labels=r.labels)
                    n_reencoded += 1
            self.registry.retire(win.src)
        progress["n_reencoded"] = n_reencoded
        rec = _obs.active()
        if rec is not None:
            rec.metrics.set_gauge("migration_open", 0)
            rec.event("migration", phase="complete", src=win.src,
                      dst=win.dst, policy=win.policy,
                      src_records=progress["src_records"],
                      src_bytes=progress["src_bytes"],
                      n_reencoded=n_reencoded)
        return progress

    def _reencode_payload(self, packed: CodePayload, dst: int
                          ) -> CodePayload:
        """Transcode one payload to the ``dst`` codebook: decode against
        the snapshot it was packed under, re-quantize each feature to its
        nearest dst atom, re-pack under ``dst``. Plain-VQ only — a GSVQ
        index names a (group, slice) product atom, so transcoding it
        needs the full encoder path, not a nearest-atom lookup."""
        if self.cfg.n_groups > 1 or self.cfg.n_slices > 1:
            raise ValueError("reencode migration supports plain VQ only "
                             f"(cfg has n_groups={self.cfg.n_groups}, "
                             f"n_slices={self.cfg.n_slices})")
        feats = OC.codes_to_features(
            None, self.cfg, packed,
            codebook=self.registry.get(packed.version))  # (C, B, ..., M)
        cb = self.registry.get(dst)                      # (K, M)
        d = jnp.sum((feats[..., None, :] - cb) ** 2, axis=-1)
        idx = jnp.argmin(d, axis=-1).astype(jnp.int32)
        return CodePayload.pack(idx, bits=packed.bits, version=int(dst),
                                privatized=True)

    # --------------------------------------------------------- Step 5 tail

    def merge(self, client_codebooks, client_counts, *, client_versions=None,
              staleness_decay: float = 1.0) -> int:
        """Staleness-weighted Step 5 merge; registers and returns the new
        codebook version."""
        self.state, version = self.registry.merge(
            self.state, client_codebooks, client_counts,
            client_versions=client_versions,
            staleness_decay=staleness_decay)
        rec = _obs.active()
        if rec is not None:
            rec.metrics.inc("merges")
            rec.event("merge", version=int(version),
                      n_clients=int(len(client_counts)))
        return version

    def merge_clients(self, clients: OC.ClientState, **kw) -> int:
        """Merge a stacked population (e.g. ``SimEngine`` client state)."""
        return self.merge(clients.params["codebook"], clients.ema.counts,
                          **kw)

    def merge_stats(self, stats) -> int:
        """Step 5 tail from ASSOCIATIVE cohort statistics
        (``repro.core.ema.MergeStats``): the cohort engine streams a
        round cohort-by-cohort and folds each cohort's fixed-point
        contribution into one accumulator; this finishes the merge and
        registers the new dictionary version. Bit-identical for any
        cohort partition/order of the same client set."""
        self.state = OC.server_merge_stats(self.state, stats)
        version = self.registry.register(self.state.params["codebook"])
        rec = _obs.active()
        if rec is not None:
            rec.metrics.inc("merges")
            rec.event("merge", version=int(version), source="stats")
        return version

"""The OCTOPUS wire format: ONE versioned carrier for the code stream.

OCTOPUS's premise is that the latent code stream IS the network
interface between clients and the server (§2.3-§2.6, §2.8). Everything
that crosses that boundary travels as a :class:`CodePayload`:

  * ``payload`` — the dense ceil(log2 K)-bit packed uint32 word stream
    (kernels/pack_bits.py layout), the bytes that actually hit the
    uplink. ``nbytes`` is MEASURED from it — the single §2.8 byte
    accounting for the whole repo, per-record padding included.
  * ``n_records`` — the payload rows may be several concatenated
    per-record (per-client) streams, each zero-padded to whole
    super-groups: exactly what each client's radio sends, and the layout
    the fused encode kernel (kernels/encode_codes.py) emits for a
    population round.
  * ``version`` — the codebook version the codes were packed under, so
    the server decodes against the registry snapshot, never the current
    table (Step 5 merges move atoms while packets are in flight).
  * ``labels`` — optional per-task label channels riding with the codes
    (normalized to ``{task: flat array}`` at pack time).
  * ``privatized`` — asserts only public Z• code indices are on the
    wire. §2.5's disentangled private residual Z∘ is *structurally*
    untransmittable: the carrier holds quantized integer codes only
    (``pack`` rejects float inputs), and the server side refuses
    payloads whose producer cleared the flag.
  * ``wire`` — the wire-format version (:data:`WIRE_VERSION`), so
    heterogeneous deployments can reject payloads from an incompatible
    protocol revision instead of mis-decoding them.
  * ``checksum`` — a CRC32 over the packed words AND the metadata that
    steers decoding (bits / shape / n_records / version), stamped at
    pack time (wire revision 2). A flipped bit or truncated word stream
    no longer decodes silently into garbage features: admission verifies
    the CRC and rejects with reason ``corrupt``, bytes staying on the
    §2.8 ledger. Revision-1 payloads (no checksum) remain decodable.

The packed half of ``repro.core.octopus.Transmission`` is a legacy view
over this carrier; :func:`as_payload` coerces it. (The old
``sim.engine.PackedCodes`` alias is retired — importing it raises.)
"""
from __future__ import annotations

import math
import zlib
from typing import Any, Dict, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

#: current wire revision: 2 added the CRC32 integrity checksum
WIRE_VERSION = 2

#: revisions the server side still admits; revision 1 (pre-checksum)
#: traces decode unchanged — the CRC is simply absent
SUPPORTED_WIRE_VERSIONS = (1, 2)


def payload_crc(words, *, bits: int, shape, n_records: int,
                version: int) -> Optional[int]:
    """CRC32 over the packed word bytes + the decode-steering metadata.

    The header folds in everything a corrupted field could silently
    mis-decode through: bits, index shape, record count and codebook
    version. Returns None when ``words`` is an abstract tracer (inside a
    jit trace there are no bytes to sum — stamp outside the trace).
    """
    if isinstance(words, jax.core.Tracer):
        return None
    header = (f"{int(bits)}|{tuple(int(d) for d in shape)}|"
              f"{int(n_records)}|{int(version)}").encode()
    body = np.ascontiguousarray(
        np.asarray(words, dtype=np.uint32)).tobytes()
    return zlib.crc32(body, zlib.crc32(header)) & 0xFFFFFFFF

DEFAULT_TASK = "label"

LabelsLike = Union[None, jax.Array, np.ndarray, Dict[str, Any]]


def normalize_labels(labels: LabelsLike, n: Optional[int] = None
                     ) -> Optional[Dict[str, jax.Array]]:
    """dict/array/None -> ``{task: flat (n,) array}``.

    A bare array lands under task name :data:`DEFAULT_TASK`. With ``n``
    given, every channel is validated against the payload's sample count
    HERE — at pack/add time, not at decode time three rounds later.
    """
    if labels is None:
        return None
    if not isinstance(labels, dict):
        labels = {DEFAULT_TASK: labels}
    out = {}
    for task, arr in labels.items():
        arr = jnp.asarray(arr)
        if n is not None and arr.size != n:
            raise ValueError(
                f"labels[{task!r}] has {arr.size} entries but the packed "
                f"payload carries {n} samples (shape mismatch caught at "
                f"pack/add, not decode)")
        out[task] = arr.reshape(-1)
    return out


class CodePayload(NamedTuple):
    """One uplink on the wire: packed public code indices + provenance."""
    payload: jax.Array           # (rows, W) uint32 packed word stream
    bits: int                    # bits per transmitted code
    shape: Tuple[int, ...]       # original index shape (C, B, T[, n_c])
    n_records: int = 1           # per-record streams concatenated in payload
    version: int = 0             # codebook version the codes were packed under
    labels: Optional[Dict[str, jax.Array]] = None   # task -> flat labels
    privatized: bool = True      # only public Z• indices on the wire (§2.5)
    wire: int = WIRE_VERSION     # wire-format revision
    checksum: Optional[int] = None   # CRC32 over words + metadata (rev 2)

    # ------------------------------------------------------------ metadata

    @property
    def nbytes(self) -> int:
        """MEASURED size of the buffer that crosses the network (§2.8) —
        the repo's single byte accounting, per-record padding included."""
        return int(self.payload.size) * self.payload.dtype.itemsize

    @property
    def count(self) -> int:
        """Number of real (non-padding) codes across all records."""
        return int(math.prod(self.shape))

    @property
    def expected_rows(self) -> int:
        """Minimum word rows the declared shape needs — each record is
        padded to whole super-groups, so fewer rows means the stream was
        cut mid-flight."""
        from repro.kernels.pack_bits import packing_dims
        G, _ = packing_dims(self.bits)
        if self.n_records == 1:
            return (self.count + G - 1) // G
        per = self.count // self.n_records
        return self.n_records * ((per + G - 1) // G)

    # ----------------------------------------------------------- integrity

    def stamped(self) -> "CodePayload":
        """Stamp (or refresh) the CRC32 integrity checksum from the
        current words + metadata. Inside a jit trace the words are
        abstract, so the checksum stays None — stamp outside the trace."""
        crc = payload_crc(self.payload, bits=self.bits, shape=self.shape,
                          n_records=self.n_records, version=self.version)
        return self if crc is None else self._replace(checksum=crc)

    def verify(self) -> bool:
        """Admission-door integrity check: the word stream must be long
        enough for the declared shape, and when a checksum rides along
        (wire revision 2) it must match a recomputation over the
        received bytes. Checksum-less carriers (revision-1 traces, local
        constructions) pass — the CRC is verified when present."""
        try:
            rows = int(self.payload.shape[0])
        except (TypeError, IndexError):
            return False
        if rows < self.expected_rows:
            return False
        if self.checksum is None:
            return True
        crc = payload_crc(self.payload, bits=self.bits, shape=self.shape,
                          n_records=self.n_records, version=self.version)
        return crc is None or crc == int(self.checksum)

    # ------------------------------------------------------------- codecs

    @classmethod
    def pack(cls, indices, *, bits: int, version: int = 0,
             labels: LabelsLike = None, n_samples: Optional[int] = None,
             privatized: bool = True) -> "CodePayload":
        """Pack an int32 code matrix into ONE contiguous word stream.

        Rejects non-integer inputs: the carrier holds quantized code
        indices only, which is what makes the private residual Z∘
        structurally untransmittable rather than merely unused.
        """
        from repro.kernels.ops import pack_codes
        idx = jnp.asarray(indices)
        if not jnp.issubdtype(idx.dtype, jnp.integer):
            raise TypeError(
                f"CodePayload carries quantized code indices, got dtype "
                f"{idx.dtype}; float latents (e.g. the private residual "
                f"Z∘) are structurally untransmittable (§2.5)")
        words = pack_codes(idx, bits=bits)
        return cls(payload=words, bits=int(bits), shape=tuple(idx.shape),
                   n_records=1, version=int(version),
                   labels=normalize_labels(labels, n_samples),
                   privatized=bool(privatized)).stamped()

    @classmethod
    def pack_records(cls, indices, *, bits: int, version: int = 0,
                     labels: LabelsLike = None,
                     n_samples: Optional[int] = None,
                     privatized: bool = True) -> "CodePayload":
        """Pack ``indices`` (R, ...) as R per-record streams, each padded
        to whole super-groups — what R client radios would send, and the
        layout the fused encode kernel emits for a population round.

        ONE dispatch: each record's flat codes are zero-padded to whole
        super-groups, and row-major flattening of the (R, padded) matrix
        IS the concatenation of the per-record streams (the same idiom
        as ``ref.encode_codes_ref``'s per-record pack).
        """
        from repro.kernels.ops import pack_codes
        from repro.kernels.pack_bits import packing_dims
        idx = jnp.asarray(indices)
        if not jnp.issubdtype(idx.dtype, jnp.integer):
            raise TypeError(
                f"CodePayload carries quantized code indices, got dtype "
                f"{idx.dtype}")
        G, _ = packing_dims(bits)
        flat = idx.reshape(idx.shape[0], -1)
        pad = (-flat.shape[1]) % G
        if pad:
            flat = jnp.pad(flat, ((0, 0), (0, pad)))
        words = pack_codes(flat, bits=bits)
        return cls(payload=words, bits=int(bits), shape=tuple(idx.shape),
                   n_records=int(idx.shape[0]), version=int(version),
                   labels=normalize_labels(labels, n_samples),
                   privatized=bool(privatized)).stamped()

    @classmethod
    def from_words(cls, words, *, bits: int, shape, n_records: int = 1,
                   version: int = 0, labels: LabelsLike = None,
                   n_samples: Optional[int] = None,
                   privatized: bool = True) -> "CodePayload":
        """Wrap an already-packed word stream (e.g. straight from
        ``ops.encode_codes``) without touching the bytes."""
        return cls(payload=words, bits=int(bits), shape=tuple(shape),
                   n_records=int(n_records), version=int(version),
                   labels=normalize_labels(labels, n_samples),
                   privatized=bool(privatized)).stamped()

    def unpack(self) -> jax.Array:
        """Bit-exact inverse: -> int32 indices of the original shape."""
        from repro.kernels.ops import unpack_codes
        from repro.kernels.pack_bits import packing_dims
        if self.n_records == 1:
            flat = unpack_codes(self.payload, bits=self.bits,
                                count=self.count)
            return flat.reshape(self.shape)
        G, _ = packing_dims(self.bits)
        rows = int(self.payload.shape[0])
        flat = unpack_codes(self.payload, bits=self.bits, count=rows * G)
        per = flat.reshape(self.n_records, (rows // self.n_records) * G)
        return per[:, :self.count // self.n_records].reshape(self.shape)

    def with_meta(self, *, version: Optional[int] = None,
                  labels: LabelsLike = None,
                  n_samples: Optional[int] = None) -> "CodePayload":
        """Same bytes, updated provenance (version / label channels).
        The checksum covers the version field, so a stamped carrier is
        re-stamped when its version moves."""
        out = self._replace(
            version=self.version if version is None else int(version),
            labels=self.labels if labels is None
            else normalize_labels(labels, n_samples))
        if self.checksum is not None and out.version != self.version:
            out = out.stamped()
        return out


def concat_payloads(payloads) -> CodePayload:
    """Concatenate per-record payloads into ONE carrier, byte-preserving.

    Because every record (client) stream is padded to whole super-groups
    INDIVIDUALLY, stacking the word rows of cohort payloads reproduces
    the single whole-population payload bit-for-bit — and therefore
    ``Σ cohort.nbytes == concat.nbytes`` (§2.8 accounting is invariant
    to how a round is cohorted). All inputs must agree on bits / wire
    revision / codebook version / privatized flag and on the per-record
    trailing index shape; labels concatenate per task and mismatched
    task channels (some records labeled and some not, or differing task
    sets) raise ``ValueError`` like any other metadata mismatch — a
    silent drop to None would lose Step-6 supervision mid-concat.
    """
    ps = list(payloads)
    if not ps:
        raise ValueError("concat_payloads needs at least one payload")
    head = ps[0]
    for p in ps[1:]:
        if (p.bits, p.wire, p.version, p.privatized) != (
                head.bits, head.wire, head.version, head.privatized):
            raise ValueError(
                f"payload metadata mismatch: {(p.bits, p.wire, p.version, p.privatized)} "
                f"vs {(head.bits, head.wire, head.version, head.privatized)}")
        if p.shape[1:] != head.shape[1:]:
            raise ValueError(f"per-record shape mismatch: {p.shape} vs "
                             f"{head.shape}")
    labeled = [p.labels is not None for p in ps]
    if any(labeled) and not all(labeled):
        raise ValueError(
            f"label channel mismatch: {sum(labeled)}/{len(ps)} payloads "
            f"carry labels — every record must be labeled, or none")
    labels = None
    if all(labeled):
        tasks = set(head.labels)
        for p in ps[1:]:
            if set(p.labels) != tasks:
                raise ValueError(
                    f"label task-channel mismatch: {sorted(p.labels)} vs "
                    f"{sorted(tasks)}")
        labels = {t: jnp.concatenate([p.labels[t] for p in ps])
                  for t in tasks}
    if len(ps) == 1:
        return head
    words = jnp.concatenate([p.payload for p in ps], axis=0)
    n_records = sum(p.n_records for p in ps)
    shape = (sum(p.shape[0] for p in ps),) + head.shape[1:]
    out = CodePayload(payload=words, bits=head.bits, shape=shape,
                      n_records=n_records, version=head.version,
                      labels=labels, privatized=head.privatized,
                      wire=head.wire)
    if all(p.checksum is not None for p in ps):
        out = out.stamped()
    return out


def as_payload(tx) -> Optional[CodePayload]:
    """Coerce any packed carrier to a :class:`CodePayload`.

    Accepts a CodePayload as-is and a packed
    ``core.octopus.Transmission`` by view.
    Returns None for plain index arrays and unpacked Transmissions —
    those take the index decode path.
    """
    if isinstance(tx, CodePayload):
        return tx
    payload = getattr(tx, "payload", None)
    if payload is None:
        return None
    if hasattr(tx, "indices"):                 # packed Transmission
        return CodePayload(payload=payload, bits=int(tx.bits),
                           shape=tuple(tx.indices.shape),
                           labels=normalize_labels(getattr(tx, "labels",
                                                           None))).stamped()
    return CodePayload(payload=payload, bits=int(tx.bits),
                       shape=tuple(tx.shape),
                       n_records=int(getattr(tx, "n_records", 1))).stamped()

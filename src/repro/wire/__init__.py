"""Unified OCTOPUS wire protocol (the client↔server interface).

  payload  — CodePayload: THE versioned carrier crossing the network —
             packed uint32 words, per-record streams, codebook version,
             measured nbytes (the single §2.8 accounting), optional
             label channels, and the §2.5 ``privatized`` invariant
  codec    — fused CodePayload -> feature decode (one dispatch per
             codebook-version group; record/phase bookkeeping lives here)
  session  — OctopusClient.round(batch) / OctopusServer.ingest(payload)
             + .features(): the session facades subsuming the PR-1..4
             function zoo (client_transmit, client_round_fused,
             unpack_transmission, hand-wired store/registry plumbing).
             ``ingest`` answers with a structured AdmissionResult
             verdict (accepted / migrated / deferred / rejected)
"""
from .codec import decode_payloads, decode_rows
from .payload import (DEFAULT_TASK, SUPPORTED_WIRE_VERSIONS, WIRE_VERSION,
                      CodePayload, as_payload, concat_payloads,
                      normalize_labels, payload_crc)
from .session import (ADMISSION_VERDICTS, TRANSIENT_REASONS,
                      AdmissionResult, OctopusClient, OctopusServer,
                      RetryPolicy, fused_round, round_words)

__all__ = ["ADMISSION_VERDICTS", "AdmissionResult", "CodePayload",
           "OctopusClient", "OctopusServer", "RetryPolicy",
           "SUPPORTED_WIRE_VERSIONS", "TRANSIENT_REASONS", "WIRE_VERSION",
           "DEFAULT_TASK", "as_payload", "concat_payloads",
           "decode_payloads", "decode_rows", "fused_round",
           "normalize_labels", "payload_crc", "round_words"]

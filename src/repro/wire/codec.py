"""Fused decode of :class:`CodePayload` word streams — the ONE place the
record/phase bookkeeping lives.

Every server-side consumer used to carry its own copy of the packed →
feature plumbing (``octopus.codes_to_features``'s packed branch,
``CodeStore._decode_group``). Both now route here:

  * :func:`decode_payloads` — N payloads (same bits, one codebook) in
    exactly ONE ``ops.decode_codes`` dispatch: the word streams are
    concatenated (every record is padded to whole super-groups, so
    record boundaries sit on word rows) with per-record-restarting slice
    phases, and each record's trailing pad rows are dropped afterwards.
  * :func:`decode_rows` — one payload to its flat ``(count, F)`` real
    feature rows (what ``ops.decode_codes`` returns when handed the
    carrier directly).

The int32 index tensor and the gathered-atom tensor never materialize on
either path (see kernels/decode_codes.py).
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax.numpy as jnp

from .payload import CodePayload


def packed_record_rows(payload_rows: int, bits: int, count: int,
                       n_records: int, rows, table_dim: int):
    """Per-record gather of fused-decoded rows.

    ``rows``: (payload_rows * G, F) decode of the FULL word stream (pad
    codes included). Each of the ``n_records`` record streams owns
    ``payload_rows / n_records`` word rows; its first ``count/n_records``
    decoded rows are real, the rest decode trailing zero-padding. Returns
    the (count, F) real rows in stream order.
    """
    from repro.kernels.pack_bits import packing_dims
    rpr = payload_rows // n_records
    G, _ = packing_dims(bits)
    per = rows.reshape(n_records, rpr * G, table_dim)
    return per[:, :count // n_records].reshape(count, table_dim)


def payload_phases(p: CodePayload, n_slices: int):
    """Per-super-group slice phases for a (possibly multi-record) stream:
    each record's slice phase restarts at 0."""
    from repro.kernels.decode_codes import stream_phases
    rows = int(p.payload.shape[0])
    return jnp.tile(stream_phases(rows // p.n_records, p.bits, n_slices),
                    p.n_records)


def feature_shape(cfg, shape: Tuple[int, ...], feat_dim: int
                  ) -> Tuple[int, ...]:
    """Decoded feature shape of an index array ``shape``. GSVQ shapes end
    with n_c; per-code rows are m-dim slice chunks whose row-major
    concatenation IS the (..., M) layout."""
    if cfg.n_groups > 1 or cfg.n_slices > 1:
        return tuple(shape[:-1]) + (int(shape[-1]) * int(feat_dim),)
    return tuple(shape) + (int(feat_dim),)


def decode_rows(p: CodePayload, table, *, n_slices: int = 1, **kw):
    """One payload -> its (count, F) real decoded rows, ONE dispatch."""
    from repro.kernels.ops import decode_codes
    from repro.kernels.pack_bits import packing_dims
    if p.n_records == 1:
        return decode_codes(p.payload, table, bits=p.bits, count=p.count,
                            n_slices=n_slices, **kw)
    G, _ = packing_dims(p.bits)
    n_rows = int(p.payload.shape[0])
    rows = decode_codes(p.payload, table, bits=p.bits, count=n_rows * G,
                        n_slices=n_slices,
                        phases=payload_phases(p, n_slices), **kw)
    return packed_record_rows(n_rows, p.bits, p.count, p.n_records, rows,
                              int(table.shape[-1]))


def decode_payloads(payloads: Sequence[CodePayload], cfg, codebook,
                    **kw) -> List[jnp.ndarray]:
    """Decode N same-bits payloads against ONE codebook in exactly ONE
    fused dispatch. Returns per-payload feature blocks in the payloads'
    own index shapes (``feature_shape``) — callers merge axes themselves.
    """
    from repro.core import octopus as OC
    from repro.kernels.ops import decode_codes
    from repro.kernels.pack_bits import packing_dims
    if not payloads:
        return []
    bits = payloads[0].bits
    if any(p.bits != bits for p in payloads):
        raise ValueError(
            f"one dispatch needs one packing width, got "
            f"{sorted({p.bits for p in payloads})} bits")
    table, n_slices = OC.decode_table(cfg, codebook)
    F = int(table.shape[-1])
    if len(payloads) == 1:
        p = payloads[0]
        return [decode_rows(p, table, n_slices=n_slices, **kw).reshape(
            feature_shape(cfg, p.shape, F))]
    G, _ = packing_dims(bits)
    spans, phases, row_off = [], [], 0
    for p in payloads:
        n_rows = int(p.payload.shape[0])
        phases.append(payload_phases(p, n_slices))
        spans.append((row_off, n_rows))
        row_off += n_rows
    rows = decode_codes(
        jnp.concatenate([p.payload for p in payloads], axis=0), table,
        bits=bits, count=row_off * G, n_slices=n_slices,
        phases=jnp.concatenate(phases), **kw)
    out = []
    for (start, n_rows), p in zip(spans, payloads):
        f = packed_record_rows(n_rows, bits, p.count, p.n_records,
                               rows[start * G:(start + n_rows) * G], F)
        out.append(f.reshape(feature_shape(cfg, p.shape, F)))
    return out

"""Roofline terms from a compiled dry-run artifact.

    compute    = FLOPs_per_device / PEAK_FLOPS
    memory     = HBM_bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / (ICI_BW * links)

The compiled module (`compiled.as_text()`) is the SPMD-partitioned
PER-DEVICE program, so all quantities parsed from it are per-device and
divide by per-chip rates directly.

Accounting comes from ``repro.roofline.hlo_analysis`` — a trip-count-aware
HLO walker — because XLA's ``cost_analysis()`` counts while-loop (lax.scan)
bodies once instead of x trip_count, undercounting scanned layer stacks by
the layer count (verified experimentally; see EXPERIMENTS.md §Method).
cost_analysis values are still recorded as a secondary diagnostic.

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI x 4 links (2-D torus).
"""
from __future__ import annotations

import math
import re
from dataclasses import asdict, dataclass, field
from typing import Dict, Optional

from .hlo_analysis import analyze_hlo

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
ICI_LINKS = 4                # 2-D torus


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device quantities (from the partitioned module)
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    collective_counts: Dict[str, float] = field(default_factory=dict)
    # secondary diagnostics
    xla_cost_flops: float = 0.0
    xla_cost_bytes: float = 0.0
    while_trip_counts: list = field(default_factory=list)
    model_flops: float = 0.0       # global analytic 6ND / 2ND
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    useful_ratio: float = 0.0      # model_flops / (hlo_flops * chips)
    per_device_hbm_bytes: float = 0.0

    def finalize(self):
        self.compute_s = self.hlo_flops / PEAK_FLOPS
        self.memory_s = self.hlo_bytes / HBM_BW
        self.collective_s = self.collective_bytes / (ICI_BW * ICI_LINKS)
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bottleneck = max(terms, key=terms.get)
        if self.hlo_flops:
            self.useful_ratio = self.model_flops / (self.hlo_flops
                                                    * self.chips)
        return self

    def to_dict(self):
        return asdict(self)


def model_flops_per_step(cfg, shape) -> float:
    """Global analytic step FLOPs: 6*N_active*D train, 2*N_active*D
    inference (D = tokens processed)."""
    n = cfg.active_param_count()
    if shape.mode == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.mode == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch      # decode: 1 token/seq


def analyze(compiled, lowered_text: Optional[str], *, arch: str, shape_name,
            mesh_name: str, chips: int, cfg=None, shape=None,
            hlo_text: Optional[str] = None) -> RooflineReport:
    text = hlo_text if hlo_text is not None else (
        lowered_text if lowered_text is not None else compiled.as_text())
    totals = analyze_hlo(text)

    cost_flops = cost_bytes = 0.0
    if compiled is not None:
        try:
            cost = compiled.cost_analysis()
            if isinstance(cost, list):
                cost = cost[0]
            cost_flops = float(cost.get("flops", 0.0))
            cost_bytes = float(cost.get("bytes accessed", 0.0))
        except Exception:
            pass

    rep = RooflineReport(
        arch=arch, shape=str(shape_name), mesh=mesh_name, chips=chips,
        hlo_flops=totals.flops,
        hlo_bytes=totals.hbm_bytes,
        collective_bytes=totals.collective_bytes,
        collective_counts=dict(totals.collective_counts),
        xla_cost_flops=cost_flops, xla_cost_bytes=cost_bytes,
        while_trip_counts=list(totals.while_trip_counts),
        model_flops=model_flops_per_step(cfg, shape) if cfg is not None
        else 0.0,
    )
    if compiled is not None:
        try:
            mem = compiled.memory_analysis()
            rep.per_device_hbm_bytes = float(
                getattr(mem, "temp_size_in_bytes", 0)
                + getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
                - getattr(mem, "alias_size_in_bytes", 0))
        except Exception:
            pass
    return rep.finalize()


def format_table(reports) -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'mesh':10s} "
           f"{'compute_s':>10s} {'memory_s':>10s} {'collect_s':>10s} "
           f"{'bottleneck':>10s} {'useful':>7s} {'HBM/dev(GB)':>12s}")
    lines = [hdr, "-" * len(hdr)]
    for r in reports:
        lines.append(
            f"{r.arch:22s} {r.shape:12s} {r.mesh:10s} "
            f"{r.compute_s:10.4g} {r.memory_s:10.4g} {r.collective_s:10.4g} "
            f"{r.bottleneck:>10s} {r.useful_ratio:7.3f} "
            f"{r.per_device_hbm_bytes/1e9:12.2f}")
    return "\n".join(lines)

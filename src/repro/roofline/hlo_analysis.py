"""Trip-count-aware HLO accounting.

``compiled.cost_analysis()`` counts each while-loop body ONCE, but our
models scan over layer segments — so FLOPs/bytes/collectives inside a
61-layer scan are undercounted 61x. This module parses the compiled HLO
text, builds the computation call graph with multiplicities (while bodies
x trip_count), and produces corrected totals:

  * flops            — dot ops: 2 * |result| * |contracting dims|
                       (matmul-dominated models; conv approximated the
                       same way from kernel size when present)
  * collective_bytes — result bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute,
                       each multiplied by its computation's multiplicity
  * hbm_bytes        — result bytes of top-level materializing ops
                       (fusion outputs, dots, copies, DUS, collectives),
                       x2 for read+write; fusion-internal ops excluded

Trip counts come from the while condition's compare-against-constant.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "all-gather-start", "all-reduce-start",
                "collective-permute-start"}

# ops whose results occupy HBM on TPU. Aliasing/fused-away ops (reshape,
# broadcast, elementwise — fused into consumers by the TPU backend) are
# excluded; this is an approximation of post-fusion HBM traffic.
_MATERIALIZING = {"fusion", "dot", "convolution", "copy",
                  "dynamic-update-slice", "dynamic-slice", "reduce",
                  "concatenate", "scatter", "gather",
                  "dot-general"} | _COLLECTIVES

# result shape at line head:  %name = f32[1,2,3]{2,1,0} opcode(
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\]"
    r"[^\s]*\s+([a-z0-9\-]+)\(")
# tuple results:  %name = (f32[..], f32[..]) opcode(
_OP_TUPLE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*\((.*?)\)\s+([a-z0-9\-]+)\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_COND_BODY_RE = re.compile(r"condition=%?([\w.\-]+).*body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"s(?:32|64)\[\]\s+constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\][^\s,]*\s+%")
_ARGS_RE = re.compile(r"\(([^)]*)\)")
_NAME_REF_RE = re.compile(r"%([\w.\-]+)")


@dataclass
class OpInfo:
    name: str
    opcode: str
    result_bytes: float
    flops: float = 0.0
    calls: Tuple[str, ...] = ()
    cond: Optional[str] = None
    body: Optional[str] = None


@dataclass
class Computation:
    name: str
    ops: List[OpInfo] = field(default_factory=list)
    max_const: int = 0           # largest small int constant (trip-count hint)
    shapes: Dict[str, Tuple[str, str]] = field(default_factory=dict)


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _shape_bytes(dtype: str, dims: str) -> float:
    return _DTYPE_BYTES.get(dtype, 4) * _shape_elems(dims)


def _operand_names(line: str) -> List[str]:
    """Names of the op's direct operands (inside the first paren group)."""
    start = line.index("(")
    depth = 0
    end = start
    for i, ch in enumerate(line[start:], start):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    inner = line[start + 1:end]
    return _NAME_REF_RE.findall(inner)


def _dot_flops(line: str, result_elems: int, comp: "Computation") -> float:
    """2 * |result| * prod(lhs contracting dim sizes).

    Operands are printed by name only; resolve via the computation's
    symbol table (covers params and prior ops)."""
    m = _CONTRACT_RE.search(line)
    if not m:
        return 2.0 * result_elems          # fallback
    cdims = [int(x) for x in m.group(1).split(",") if x]
    names = _operand_names(line)
    lhs_dims: List[int] = []
    if names and names[0] in comp.shapes:
        lhs_dims = [int(x) for x in comp.shapes[names[0]][1].split(",") if x]
    else:
        # older HLO prints operand shapes inline
        inner = line[line.index("("):]
        shapes = _OPERAND_SHAPE_RE.findall(inner)
        if shapes:
            lhs_dims = [int(x) for x in shapes[0][1].split(",") if x]
    k = 1
    for d in cdims:
        if d < len(lhs_dims):
            k *= lhs_dims[d]
    return 2.0 * result_elems * k


def _conv_flops(line: str, result_elems: int, comp: "Computation") -> float:
    """2 * |result| * kernel_spatial * C_in (approx from rhs shape)."""
    names = _operand_names(line)
    rhs: List[int] = []
    if len(names) >= 2 and names[1] in comp.shapes:
        rhs = [int(x) for x in comp.shapes[names[1]][1].split(",") if x]
    else:
        inner = line[line.index("("):]
        shapes = _OPERAND_SHAPE_RE.findall(inner)
        if len(shapes) >= 2:
            rhs = [int(x) for x in shapes[1][1].split(",") if x]
    if not rhs:
        return 2.0 * result_elems
    k = 1
    for d in rhs[:-1]:                    # all but output-feature dim
        k *= d
    return 2.0 * result_elems * k


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if not line.strip():
            continue
        hdr = _COMP_HDR_RE.match(line)
        if hdr and (line.startswith("%") or line.startswith("ENTRY")):
            cur = Computation(name=hdr.group(1))
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                comps["__entry__"] = cur
            continue
        if cur is None:
            continue
        for m in _CONST_RE.finditer(line):
            cur.max_const = max(cur.max_const, int(m.group(1)))
        om = _OP_RE.match(line)
        tuple_bytes = None
        if not om:
            tm = _OP_TUPLE_RE.match(line)
            if not tm:
                continue
            name, shapes_str, opcode = tm.group(1), tm.group(2), tm.group(3)
            tuple_bytes = sum(_shape_bytes(d, s)
                              for d, s in _SHAPE_RE.findall(shapes_str))
            dtype, dims = "f32", ""
        else:
            name, dtype, dims, opcode = om.groups()
        rbytes = tuple_bytes if tuple_bytes is not None else \
            _shape_bytes(dtype, dims)
        relems = _shape_elems(dims) if tuple_bytes is None else 0
        if tuple_bytes is None:
            cur.shapes[name] = (dtype, dims)
        op = OpInfo(name=name, opcode=opcode, result_bytes=rbytes)
        if opcode in ("dot", "dot-general"):
            op.flops = _dot_flops(line, relems, cur)
        elif opcode == "convolution":
            op.flops = _conv_flops(line, relems, cur)
        if opcode == "fusion":
            cm = _CALLS_RE.search(line)
            if cm:
                op.calls = (cm.group(1),)
        if opcode == "while":
            wb = _COND_BODY_RE.search(line)
            if wb:
                op.cond, op.body = wb.group(1), wb.group(2)
        if opcode in ("call", "conditional", "custom-call"):
            cm = _CALLS_RE.search(line)
            if cm:
                op.calls = (cm.group(1),)
        ta = _TO_APPLY_RE.search(line)
        if ta and not op.calls and opcode not in ("while",):
            op.calls = (ta.group(1),)
        cur.ops.append(op)
    return comps


@dataclass
class HLOTotals:
    flops: float = 0.0
    collective_bytes: float = 0.0
    hbm_bytes: float = 0.0
    collective_counts: Dict[str, float] = field(default_factory=dict)
    while_trip_counts: List[int] = field(default_factory=list)


def _fusion_called(comps: Dict[str, Computation]) -> set:
    called = set()
    for c in comps.values():
        for op in c.ops:
            if op.opcode == "fusion":
                called.update(op.calls)
    return called


def analyze_hlo(text: str, entry: Optional[str] = None) -> HLOTotals:
    comps = parse_hlo(text)
    if not comps:
        return HLOTotals()
    fusion_comps = _fusion_called(comps)
    if entry is None:
        if "__entry__" in comps:
            entry = comps["__entry__"].name
        else:
            called = set(fusion_comps)
            for c in comps.values():
                for op in c.ops:
                    called.update(op.calls)
                    if op.cond:
                        called.add(op.cond)
                    if op.body:
                        called.add(op.body)
            roots = [n for n in comps if n not in called]
            entry = max(roots, key=lambda n: len(comps[n].ops)) \
                if roots else next(iter(comps))

    totals = HLOTotals()
    seen_stack = []

    def visit(comp_name: str, mult: float, top_level: bool):
        comp = comps.get(comp_name)
        if comp is None or comp_name in seen_stack:
            return
        seen_stack.append(comp_name)
        for op in comp.ops:
            totals.flops += op.flops * mult
            base = op.opcode.replace("-start", "")
            if base in {"all-gather", "all-reduce", "reduce-scatter",
                        "all-to-all", "collective-permute"}:
                totals.collective_bytes += op.result_bytes * mult
                totals.collective_counts[base] = \
                    totals.collective_counts.get(base, 0) + mult
            if top_level and op.opcode in _MATERIALIZING:
                totals.hbm_bytes += 2.0 * op.result_bytes * mult
            if op.opcode == "while" and op.body:
                trips = max(comps.get(op.cond, Computation("")).max_const
                            if op.cond else 1, 1)
                totals.while_trip_counts.append(trips)
                visit(op.body, mult * trips, True)
                visit(op.cond, mult * trips, False)
            elif op.opcode == "fusion":
                for cal in op.calls:
                    visit(cal, mult, False)     # fused interiors: flops only
            elif op.calls:
                for cal in op.calls:
                    visit(cal, mult, True)
        seen_stack.pop()

    visit(entry, 1.0, True)
    return totals

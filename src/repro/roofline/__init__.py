from . import analysis

"""Append-only JSONL journal for crash-consistent replay.

The continuous-ingest service logs every state-mutating operation
(admitted offer, tick, merge, migration op) as one JSON line, flushed
per entry — the same crash-safety idiom as the flight recorder. A
recovery loads the latest snapshot and replays the journal tail through
the NORMAL code paths, so the rebuilt state is the product of the same
deterministic machinery that produced the original.

Arrays ride inline as base64 words (:func:`encode_array` /
:func:`decode_array`) — journal entries are small (one uplink's packed
words, one merged codebook); bulk state belongs in snapshots
(``repro.checkpoint.npz``).
"""
from __future__ import annotations

import base64
import json
import os
from typing import Iterator, Optional

import numpy as np


def encode_array(a) -> dict:
    """np/jax array -> JSON-able {b64, dtype, shape} triple."""
    a = np.ascontiguousarray(np.asarray(a))
    return {"b64": base64.b64encode(a.tobytes()).decode("ascii"),
            "dtype": str(a.dtype), "shape": list(a.shape)}


def decode_array(d: dict) -> np.ndarray:
    """Inverse of :func:`encode_array` (bit-exact)."""
    return np.frombuffer(base64.b64decode(d["b64"]),
                         dtype=np.dtype(d["dtype"])
                         ).reshape(d["shape"]).copy()


class Journal:
    """One append-only JSONL file of replayable operations.

    ``position`` counts entries ever appended (the snapshot high-water
    mark); ``resume=True`` reopens an existing journal for appending
    (recovery keeps journaling where the crashed process stopped).
    Every ``append`` flushes — a killed process loses at most the entry
    it was mid-writing, and :meth:`entries` skips a torn final line.
    """

    def __init__(self, path: str, *, resume: bool = False):
        self.path = path
        self.position = 0
        if resume and os.path.exists(path):
            self.position = sum(1 for _ in self._read())
            self._fh = open(path, "a")
        else:
            self._fh = open(path, "w")

    def append(self, entry: dict) -> int:
        """Write one entry; returns its index in the journal."""
        self._fh.write(json.dumps(entry) + "\n")
        self._fh.flush()
        idx, self.position = self.position, self.position + 1
        return idx

    def _read(self) -> Iterator[dict]:
        with open(self.path) as fh:
            for line in fh:
                line = line.strip()
                if not line:
                    continue
                try:
                    yield json.loads(line)
                except json.JSONDecodeError:
                    return          # torn tail from a mid-write kill

    def entries(self, start: int = 0) -> Iterator[dict]:
        """Yield entries from index ``start`` (the replay tail)."""
        for i, entry in enumerate(self._read()):
            if i >= start:
                yield entry

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

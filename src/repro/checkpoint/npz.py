"""Pytree checkpointing: flatten to path-keyed arrays in one .npz + a JSON
sidecar with step/config metadata. No orbax in the container; this is the
minimal deployable equivalent (atomic rename, versioned, restart-safe)."""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save_pytree(path: str, tree, *, metadata: Optional[dict] = None):
    """Atomic save: write temp file then rename."""
    arrays, _ = _flatten_with_paths(tree)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".npz.tmp")
    os.close(fd)
    np.savez(tmp, **arrays)
    saved = tmp if tmp.endswith(".npz") else tmp + ".npz"
    if saved != tmp and os.path.exists(tmp + ".npz"):
        tmp = tmp + ".npz"
    os.replace(tmp, path)
    if metadata is not None:
        with open(path + ".json", "w") as f:
            json.dump(metadata, f, indent=2, default=str)


def load_pytree(path: str, like) -> Any:
    """Restore into the structure of ``like`` (paths must match)."""
    data = np.load(path)
    arrays, _ = _flatten_with_paths(like)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for (p, leaf) in flat:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        arr = data[key]
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)


def save(ckpt_dir: str, step: int, state, *, keep: int = 3,
         metadata: Optional[dict] = None):
    """Versioned save: ckpt_dir/step_000042.npz, pruned to ``keep``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    md = dict(metadata or {})
    md["step"] = step
    save_pytree(path, state, metadata=md)
    ckpts = sorted(f for f in os.listdir(ckpt_dir)
                   if f.startswith("step_") and f.endswith(".npz"))
    for old in ckpts[:-keep]:
        os.remove(os.path.join(ckpt_dir, old))
        side = os.path.join(ckpt_dir, old + ".json")
        if os.path.exists(side):
            os.remove(side)
    return path


def restore(ckpt_dir: str, like) -> Tuple[Optional[Any], int]:
    """Latest checkpoint in dir, or (None, 0)."""
    if not os.path.isdir(ckpt_dir):
        return None, 0
    ckpts = sorted(f for f in os.listdir(ckpt_dir)
                   if f.startswith("step_") and f.endswith(".npz"))
    if not ckpts:
        return None, 0
    latest = ckpts[-1]
    step = int(latest[len("step_"):-len(".npz")])
    return load_pytree(os.path.join(ckpt_dir, latest), like), step

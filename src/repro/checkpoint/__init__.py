"""Pytree checkpointing to .npz (flat path-keyed arrays) + metadata json,
plus the append-only JSONL journal crash recovery replays from."""
from .journal import Journal, decode_array, encode_array
from .npz import load_pytree, restore, save, save_pytree

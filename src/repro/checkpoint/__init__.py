"""Pytree checkpointing to .npz (flat path-keyed arrays) + metadata json."""
from .npz import load_pytree, restore, save, save_pytree

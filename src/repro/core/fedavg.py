"""Federated baselines the paper compares against (§3.1): FedAvg
[McMahan'17], FedProx [Li'18], DP-FL [Geyer'17 style clip+noise], and the
data-sharing strategy [Zhao'18].

Implemented generically over (apply_fn, params) classifiers so the same
harness trains the raw-data baselines that OCTOPUS's latent-code probe is
compared with in Fig. 4/5.
"""
from __future__ import annotations

from typing import Callable, List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.data.synthetic import LabeledData
from repro.optim.adamw import adamw_init, adamw_update, clip_by_global_norm
from .downstream import xent_loss


class FedConfig(NamedTuple):
    rounds: int = 20
    local_epochs: int = 1
    local_batch: int = 32
    lr: float = 1e-3
    # FedProx proximal coefficient (0 = plain FedAvg)
    prox_mu: float = 0.0
    # client-level DP: clip + gaussian noise on the update
    dp_clip: float = 0.0
    dp_noise: float = 0.0


def _local_update(key, apply_fn, global_params, x, y, n_steps: int,
                  fc: FedConfig):
    """One client's local training pass; returns the delta.

    Pure scan over local SGD steps so the same function serves the
    sequential path AND vmaps across a stacked client population
    (fedavg_train_batched / the repro.sim engine style of execution).
    """
    opt = adamw_init(global_params)
    n = x.shape[0]
    bsz = min(fc.local_batch, n)

    def loss(p, xb, yb):
        l = xent_loss(apply_fn, p, xb, yb)
        if fc.prox_mu:
            sq = jax.tree.map(lambda a, b: jnp.sum(jnp.square(a - b)),
                              p, global_params)
            l = l + 0.5 * fc.prox_mu * jax.tree.reduce(jnp.add, sq)
        return l

    def body(carry, i):
        params, opt = carry
        sel = jax.random.randint(jax.random.fold_in(key, i), (bsz,), 0, n)
        g = jax.grad(loss)(params, x[sel], y[sel])
        return adamw_update(params, g, opt, lr=fc.lr), None

    (params, _), _ = jax.lax.scan(body, (global_params, opt),
                                  jnp.arange(n_steps))
    return jax.tree.map(lambda new, old: new - old, params, global_params)


def _privatize_delta(key, delta, fc: FedConfig):
    if not fc.dp_clip:
        return delta
    delta, _ = clip_by_global_norm(delta, fc.dp_clip)
    leaves, treedef = jax.tree.flatten(delta)
    keys = jax.random.split(key, len(leaves))
    noised = [l + fc.dp_noise * fc.dp_clip
              * jax.random.normal(k, l.shape, l.dtype)
              for l, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, noised)


def fedavg_train(key, apply_fn, init_params, shards: Sequence[LabeledData],
                 label_fn: Callable, fc: FedConfig = FedConfig(),
                 shared_data: Optional[LabeledData] = None):
    """Run federated rounds; returns the final global params.

    ``shared_data`` implements the Zhao'18 data-sharing mitigation: a small
    public set appended to every client shard.
    """
    if shared_data is not None:
        shards = [LabeledData(
            x=jnp.concatenate([s.x, shared_data.x]),
            content=jnp.concatenate([s.content, shared_data.content]),
            style=jnp.concatenate([s.style, shared_data.style]))
            for s in shards]

    global_params = init_params
    sizes = jnp.asarray([s.x.shape[0] for s in shards], jnp.float32)
    weights = sizes / jnp.sum(sizes)
    for r in range(fc.rounds):
        deltas = []
        for ci, shard in enumerate(shards):
            k = jax.random.fold_in(jax.random.fold_in(key, r), ci)
            n = shard.x.shape[0]
            steps = max(1, fc.local_epochs * n // fc.local_batch)
            d = _local_update(k, apply_fn, global_params, shard.x,
                              label_fn(shard), steps, fc)
            d = _privatize_delta(jax.random.fold_in(k, 999), d, fc)
            deltas.append(d)
        # weighted average of deltas (FedAvg aggregation)
        avg = jax.tree.map(
            lambda *ds: sum(w * d for w, d in zip(weights, ds)), *deltas)
        global_params = jax.tree.map(jnp.add, global_params, avg)
    return global_params


def fedavg_train_batched(key, apply_fn, init_params, xs, ys,
                         fc: FedConfig = FedConfig()):
    """Batched FedAvg: the whole client population's local passes run in
    ONE jitted vmap per round (repro.sim-engine-style execution).

    xs: (C, n, ...) / ys: (C, n) — equal-size client shards stacked on a
    leading client axis (see repro.data.federated.partition_stacked).
    Bit-for-bit the same per-client RNG stream as the sequential
    ``fedavg_train`` on equal-size shards, so the two paths agree.
    """
    C, n = xs.shape[0], xs.shape[1]
    steps = max(1, fc.local_epochs * n // fc.local_batch)

    @jax.jit
    def one_round(global_params, r):
        kr = jax.random.fold_in(key, r)
        keys = jax.vmap(lambda ci: jax.random.fold_in(kr, ci))(
            jnp.arange(C))
        local = lambda k, x, y: _local_update(k, apply_fn, global_params,
                                              x, y, steps, fc)
        deltas = jax.vmap(local)(keys, xs, ys)           # leaves (C, ...)
        noise_keys = jax.vmap(lambda k: jax.random.fold_in(k, 999))(keys)
        deltas = jax.vmap(lambda k, d: _privatize_delta(k, d, fc))(
            noise_keys, deltas)
        avg = jax.tree.map(lambda d: jnp.mean(d, axis=0), deltas)
        return jax.tree.map(jnp.add, global_params, avg)

    global_params = init_params
    for r in range(fc.rounds):
        global_params = one_round(global_params, r)
    return global_params

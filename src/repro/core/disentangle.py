"""Disentanglement for local privatization (OCTOPUS §2.5, Eq. 4-6).

Latent Z splits into:
  public  Z• = VQ(Z_e(x))                — codebook-carried content
  private Z∘ = E[Z_e(x) − Z•]            — per-group residual style

Two mechanisms, no adversarial training:
  1. codebook quantization — shared content clusters to shared atoms; what
     the discrete code cannot carry (the residual) is the style.
  2. instance normalization before VQ — removes per-instance channel
     statistics (mu, sigma), which are temporally-invariant style carriers.

The latent loss (Eq. 6 second term) pulls IN(Z_e) toward its quantization,
tightening the content bottleneck:  lambda * ||IN(Z_e(x)) − Z•||^2.

Group supervision: samples within a group share the sensitive attribute
(same speaker / same identity); Z∘ is averaged over the group axis, so only
attribute-consistent residual style survives.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .vq import VQOut, quantize
from .gsvq import GSVQOut, gsvq_quantize


class DisentangledLatent(NamedTuple):
    public: jax.Array        # Z• quantized content, (..., M) (STE)
    private: jax.Array       # Z∘ group-averaged residual, broadcastable
    indices: jax.Array       # transmitted codes
    codebook_loss: jax.Array
    commit_loss: jax.Array
    latent_loss: jax.Array   # ||IN(z_e) - Z•||^2 (Eq. 6)


def instance_norm_latent(z_e, gamma=None, beta=None, eps: float = 1e-5):
    """IN over the token/spatial axis of (B, T, M) latents (Eq. 4).

    Channel-wise mu/sigma are computed per instance across positions — these
    statistics ARE the style signal being normalized away.
    """
    mu = jnp.mean(z_e, axis=-2, keepdims=True)
    sigma = jnp.sqrt(jnp.var(z_e, axis=-2, keepdims=True) + eps)
    out = (z_e - mu) / sigma
    if gamma is not None:
        out = out * gamma
    if beta is not None:
        out = out + beta
    return out


def split_public_private(z_e, codebook, *, group_axis: int = 0,
                         apply_in: bool = True, n_groups: int = 1,
                         n_slices: int = 1, gamma=None, beta=None
                         ) -> DisentangledLatent:
    """Eq. 5: Z• = VQ(IN(z_e)), Z∘ = E_group[z_e − Z•].

    z_e: (G?, B, T, M) — ``group_axis`` indexes attribute-sharing groups when
    present; with no grouping pass group_axis=None and the residual average
    is per-instance over T (the paper's speech framing).
    """
    z_in = instance_norm_latent(z_e, gamma, beta) if apply_in else z_e
    if n_groups > 1 or n_slices > 1:
        q: GSVQOut = gsvq_quantize(z_in, codebook, n_groups=n_groups,
                                   n_slices=n_slices)
    else:
        q: VQOut = quantize(z_in, codebook)
    residual = z_e - jax.lax.stop_gradient(q.quantized)
    if group_axis is None:
        private = jnp.mean(residual, axis=-2, keepdims=True)     # E over T
    else:
        private = jnp.mean(residual, axis=group_axis, keepdims=True)
    latent_loss = jnp.mean(jnp.square(z_in - jax.lax.stop_gradient(q.quantized)))
    return DisentangledLatent(public=q.quantized, private=private,
                              indices=q.indices,
                              codebook_loss=q.codebook_loss,
                              commit_loss=q.commit_loss,
                              latent_loss=latent_loss)


def recombine(public, private):
    """Decoder input: Z• + Z∘ (Eq. 6 reconstruction path)."""
    return public + private


def perturb_private(key, private, scale: float = 1.0):
    """§3.3 style transformation (1): Z∘' = Z∘ + noise — anonymized copy."""
    return private + scale * jax.random.normal(key, private.shape,
                                               private.dtype)


def replace_private(private_src):
    """§3.3 style transformation (2): swap in a reference sample's Z∘.

    Trivial by construction — returned as-is; named for protocol clarity.
    """
    return private_src


def total_loss(x, x_rec, dis: DisentangledLatent, *, alpha: float = 1.0,
               beta: float = 0.25, lam: float = 0.01):
    """Eq. 6 total: recon + alpha*codebook + beta*commit + lambda*latent."""
    recon = jnp.mean(jnp.square(x - x_rec))
    return (recon + alpha * dis.codebook_loss + beta * dis.commit_loss
            + lam * dis.latent_loss), recon

"""OCTOPUS core: the paper's contribution as composable JAX modules.

  vq           basic VQ + straight-through estimator (Eq. 1)
  gsvq         Group & Sliced VQ (Eq. 2-3)
  disentangle  IN + public/private latent split (Eq. 4-6)
  ema          codebook EMA refresh (Eq. 7-9)
  dvqae        conv/sequence DVQ-AE models
  octopus      client/server protocol (Steps 1-6)
  privacy      TOMBSTONE — the Thm. 1 adversary moved to repro.privacy
  overheads    §2.8 communication byte models
"""
from . import disentangle, dvqae, ema, gsvq, octopus, overheads, privacy, vq

__all__ = ["vq", "gsvq", "disentangle", "ema", "dvqae", "octopus",
           "privacy", "overheads"]

"""Communication-overhead accounting (§2.8) — closed-form byte models for
ordinary FL, gradient-compressed FL, split learning, and OCTOPUS.

These are the formulas behind the paper's efficiency claims; the benchmark
harness evaluates them with the actual byte counts measured from the built
system (model param bytes, latent code bytes) so the comparison is grounded
in this repo's artifacts rather than copied constants.
"""
from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class CommModel:
    n_clients: int            # N_C
    model_bytes: int          # N_M (bytes of model params)
    n_samples: int            # N_D (dataset size, samples)
    n_epochs: int             # N_E (global rounds)
    code_bytes_per_sample: int  # N_Z (OCTOPUS latent bytes per sample)
    smashed_bytes_per_sample: int = 0   # N_S (split learning cut layer)
    client_frac_params: float = 1.0     # eta (split learning client share)
    codebook_bytes: int = 0             # N_B
    codebook_sync_rounds: int = 10      # pi (paper: 'generally less than 10')
    downstream_model_bytes: int = 0     # N_A (final model download)


def federated_bytes(c: CommModel) -> int:
    """Ordinary FL: 2 * N_C * N_M * N_E (upload + download per round)."""
    return 2 * c.n_clients * c.model_bytes * c.n_epochs


def gradient_compressed_fl_bytes(c: CommModel, *, up_compress: float = 0.01,
                                 selected_frac: float = 0.1,
                                 round_multiplier: float = 3.0) -> int:
    """(N_C^sel * N_M^up + N_C * N_M) * N_E'; compression inflates rounds
    (N_E' >> N_E) — the paper's convergence-distortion caveat."""
    n_e = int(c.n_epochs * round_multiplier)
    sel = int(c.n_clients * selected_frac)
    up = int(c.model_bytes * up_compress)
    return (sel * up + c.n_clients * c.model_bytes) * n_e


def split_learning_bytes(c: CommModel) -> int:
    """(2 * N_S * N_D + eta * N_C * N_M) * N_E."""
    return int((2 * c.smashed_bytes_per_sample * c.n_samples
                + c.client_frac_params * c.n_clients * c.model_bytes)
               * c.n_epochs)


def octopus_bytes(c: CommModel) -> int:
    """N_D * N_Z + N_M + pi * N_B + N_A: once-off code upload, once-off
    model download, few-shot codebook syncs."""
    return (c.n_samples * c.code_bytes_per_sample
            + c.model_bytes
            + c.codebook_sync_rounds * c.codebook_bytes
            + c.downstream_model_bytes)


def code_bytes(n_positions: int, codebook_size: int, n_slices: int = 1) -> int:
    """Packed bytes of one sample's index matrix."""
    bits = max(1, math.ceil(math.log2(max(codebook_size, 2))))
    return (n_positions * n_slices * bits + 7) // 8


def comparison_table(c: CommModel) -> dict:
    fl = federated_bytes(c)
    oct_ = octopus_bytes(c)
    rows = {
        "federated": fl,
        "fl_grad_compressed": gradient_compressed_fl_bytes(c),
        "split_learning": split_learning_bytes(c),
        "octopus": oct_,
    }
    rows["octopus_vs_fl_ratio"] = fl / max(oct_, 1)
    return rows


def multi_task_bytes(c: CommModel, n_tasks: int) -> dict:
    """§2.8 multi-task: FL reruns everything per task; OCTOPUS reuses the
    gathered codes and only downloads each trained model once."""
    return {
        "federated": n_tasks * federated_bytes(c),
        "octopus": (c.n_samples * c.code_bytes_per_sample
                    + c.model_bytes
                    + c.codebook_sync_rounds * c.codebook_bytes
                    + n_tasks * max(c.downstream_model_bytes, 1)),
    }

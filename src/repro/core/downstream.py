"""Downstream-task models (§3.1.1): a small conv classifier for raw
images/speech (the centralized/federated baseline) and a linear probe for
OCTOPUS latent codes (the paper's 3-linear-layer head)."""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.nn.layers import conv2d, conv1d, dense_init, init_conv1d, init_conv2d
from repro.optim.adamw import adamw_init, adamw_update


# ------------------------------------------------------------ conv baseline

def init_conv_classifier(key, *, in_channels: int, n_classes: int,
                         hidden: int = 32, kind: str = "image"):
    ks = jax.random.split(key, 4)
    if kind == "image":
        return {
            "c1": init_conv2d(ks[0], in_channels, hidden, 3),
            "c2": init_conv2d(ks[1], hidden, hidden * 2, 3),
            "w": dense_init(ks[2], hidden * 2, hidden * 2),
            "b": jnp.zeros((hidden * 2,)),
            "head": dense_init(ks[3], hidden * 2, n_classes),
            "hb": jnp.zeros((n_classes,)),
        }
    return {
        "c1": init_conv1d(ks[0], in_channels, hidden, 3),
        "c2": init_conv1d(ks[1], hidden, hidden * 2, 3),
        "w": dense_init(ks[2], hidden * 2, hidden * 2),
        "b": jnp.zeros((hidden * 2,)),
        "head": dense_init(ks[3], hidden * 2, n_classes),
        "hb": jnp.zeros((n_classes,)),
    }


def conv_classifier(params, x, kind: str = "image"):
    conv = conv2d if kind == "image" else conv1d
    h = jax.nn.relu(conv(params["c1"], x, stride=2))
    h = jax.nn.relu(conv(params["c2"], h, stride=2))
    h = jnp.mean(h, axis=tuple(range(1, h.ndim - 1)))     # GAP
    h = jax.nn.relu(h @ params["w"] + params["b"])
    return h @ params["head"] + params["hb"]


# ------------------------------------------------------------- linear probe

def init_linear_probe(key, in_dim: int, n_classes: int, hidden: int = 128):
    """The paper's latent-code head: three linear layers (§3.6)."""
    ks = jax.random.split(key, 3)
    return {
        "w1": dense_init(ks[0], in_dim, hidden), "b1": jnp.zeros((hidden,)),
        "w2": dense_init(ks[1], hidden, hidden), "b2": jnp.zeros((hidden,)),
        "w3": dense_init(ks[2], hidden, n_classes),
        "b3": jnp.zeros((n_classes,)),
    }


def linear_probe(params, z):
    z = z.reshape(z.shape[0], -1)
    h = jax.nn.relu(z @ params["w1"] + params["b1"])
    h = jax.nn.relu(h @ params["w2"] + params["b2"])
    return h @ params["w3"] + params["b3"]


# --------------------------------------------------------------- train/eval

def xent_loss(apply_fn: Callable, params, x, y):
    logits = apply_fn(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def sgd_train(key, apply_fn, params, x, y, *, steps: int = 200,
              lr: float = 1e-3, batch: int = 64):
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt, xb, yb):
        g = jax.grad(lambda p: xent_loss(apply_fn, p, xb, yb))(params)
        return adamw_update(params, g, opt, lr=lr)

    n = x.shape[0]
    for i in range(steps):
        sel = jax.random.randint(jax.random.fold_in(key, i),
                                 (min(batch, n),), 0, n)
        params, opt = step(params, opt, x[sel], y[sel])
    return params


def accuracy(apply_fn, params, x, y) -> float:
    logits = apply_fn(params, x)
    return float(jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32)))

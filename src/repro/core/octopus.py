"""The OCTOPUS distributed learning protocol (§2.2, Steps 1-6).

Server:  Step 1  train initial global DVQ-AE on public data (ATD)
Clients: Step 2  one-shot local fine-tune (encoder + joint decoder)
         Step 3  disentangle; only Z• (indices) are releasable
         Step 4  transmit code indices at high frequency
         Step 5  low-frequency codebook EMA refresh -> sync to server
Server:  Step 6  train downstream tasks on gathered codes

The implementation is functional: ``ClientState`` / ``ServerState`` pytrees
plus pure transition functions, so the whole protocol jits and the client
population maps onto the mesh 'data' axis (one client shard per device
group) — see repro.distributed for the sharded variant.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from .dvqae import DVQAEConfig, DVQAEOut, forward, init_dvqae
from .ema import EMAState, ema_update, init_ema


class ClientState(NamedTuple):
    params: dict              # local DVQ-AE (encoder fine-tuned, decoder joint)
    ema: EMAState             # local codebook EMA accumulator
    step: jax.Array


class ServerState(NamedTuple):
    params: dict              # global DVQ-AE
    opt: AdamWState
    step: jax.Array


class Transmission(NamedTuple):
    """What actually crosses the network, with its §2.8 byte accounting."""
    indices: jax.Array        # int32 code matrix (B, T[, n_c])
    nbytes: int               # ceil(log2 K)/8-packed size
    labels: Optional[jax.Array] = None


# --------------------------------------------------------------- Step 1

def server_init(key, cfg: DVQAEConfig, lr: float = 1e-3) -> ServerState:
    params = init_dvqae(key, cfg)
    return ServerState(params=params, opt=adamw_init(params),
                       step=jnp.zeros((), jnp.int32))


def server_pretrain_step(state: ServerState, cfg: DVQAEConfig, batch,
                         lr: float = 1e-3, group_axis=None
                         ) -> Tuple[ServerState, DVQAEOut]:
    """One ATD pretraining step of the global DVQ-AE (Step 1)."""
    def loss_fn(p):
        out = forward(p, cfg, batch, group_axis=group_axis)
        return out.loss, out

    grads, out = jax.grad(loss_fn, has_aux=True)(state.params)
    params, opt = adamw_update(state.params, grads, state.opt, lr=lr)
    return ServerState(params=params, opt=opt, step=state.step + 1), out


# --------------------------------------------------------------- Step 2

def client_init(server: ServerState) -> ClientState:
    """Deploy the global model to a client; codebook starts frozen."""
    return ClientState(params=jax.tree.map(lambda x: x, server.params),
                       ema=init_ema(server.params["codebook"]),
                       step=jnp.zeros((), jnp.int32))


def client_finetune_step(client: ClientState, cfg: DVQAEConfig, batch,
                         lr: float = 1e-4, opt: Optional[AdamWState] = None,
                         ) -> Tuple[ClientState, AdamWState, DVQAEOut]:
    """One-shot fine-tuning: encoder + decoder update, codebook FROZEN
    (§2.6 'initially, the codebook is frozen for local fine-tuning')."""
    if opt is None:
        opt = adamw_init({"encoder": client.params["encoder"],
                          "decoder": client.params["decoder"]})

    def loss_fn(enc_dec):
        p = {**enc_dec, "codebook": client.params["codebook"]}
        out = forward(p, cfg, batch)
        return out.loss, out

    trainable = {"encoder": client.params["encoder"],
                 "decoder": client.params["decoder"]}
    grads, out = jax.grad(loss_fn, has_aux=True)(trainable)
    new, opt = adamw_update(trainable, grads, opt, lr=lr)
    params = {**new, "codebook": client.params["codebook"]}
    return (ClientState(params=params, ema=client.ema, step=client.step + 1),
            opt, out)


# ----------------------------------------------------------- Steps 3 + 4

def client_transmit(client: ClientState, cfg: DVQAEConfig, batch,
                    labels=None) -> Transmission:
    """Encode a local batch, release ONLY the public code indices."""
    import math
    out = forward(client.params, cfg, batch)
    idx = out.latent.indices
    bits = max(1, math.ceil(math.log2(max(cfg.codebook_size, 2))))
    if cfg.n_groups > 1:
        bits = max(1, math.ceil(math.log2(max(cfg.n_groups, 2))))
    nbytes = (int(idx.size) * bits + 7) // 8
    return Transmission(indices=idx, nbytes=nbytes, labels=labels)


# --------------------------------------------------------------- Step 5

def client_codebook_refresh(client: ClientState, cfg: DVQAEConfig, batch,
                            gamma: float = 0.99) -> ClientState:
    """Low-frequency EMA refresh of the local codebook (Eq. 9).

    Atoms must be updated in the SAME space the quantizer matches in:
    when the IN disentanglement layer is on, that is IN(z_e), not raw z_e
    (EMA toward raw latents drags atoms out of the normalized manifold
    and makes reconstruction worse under drift).
    """
    from .disentangle import instance_norm_latent
    out = forward(client.params, cfg, batch)
    idx = out.latent.indices
    if cfg.n_groups > 1:
        # group indices -> representative atom index (group centre)
        ng = cfg.codebook_size // cfg.n_groups
        idx = idx[..., 0] * ng + ng // 2
    z_e, _ = _encode_only(client.params, cfg, batch)
    if cfg.apply_in:
        z_e = instance_norm_latent(z_e)
    ema = ema_update(client.ema, z_e, idx, gamma=gamma)
    params = {**client.params, "codebook": ema.codebook}
    return ClientState(params=params, ema=ema, step=client.step)


def _encode_only(params, cfg, x):
    from .dvqae import encode
    return encode(params, cfg, x)


def server_merge_codebooks(server: ServerState,
                           client_codebooks: Sequence[jax.Array],
                           client_counts: Sequence[jax.Array]) -> ServerState:
    """Count-weighted average of synced client codebooks (global dictionary
    update, Step 5 tail). counts: per-atom EMA N_i of each client."""
    cbs = jnp.stack(list(client_codebooks))          # (M_clients, K, M)
    cts = jnp.stack(list(client_counts))             # (M_clients, K)
    w = cts / jnp.maximum(jnp.sum(cts, axis=0, keepdims=True), 1e-9)
    merged = jnp.einsum("ck,ckm->km", w, cbs)
    params = {**server.params, "codebook": merged.astype(
        server.params["codebook"].dtype)}
    return ServerState(params=params, opt=server.opt, step=server.step)


# --------------------------------------------------------------- Step 6

def gather_codes(transmissions: Sequence[Transmission]):
    """Server-side dataset assembly from client uploads."""
    idx = jnp.concatenate([t.indices for t in transmissions], axis=0)
    labels = None
    if transmissions[0].labels is not None:
        labels = jnp.concatenate([t.labels for t in transmissions], axis=0)
    total_bytes = sum(t.nbytes for t in transmissions)
    return idx, labels, total_bytes


def codes_to_features(server: ServerState, cfg: DVQAEConfig, indices):
    """Dequantize gathered codes into downstream-task features."""
    from .gsvq import gsvq_dequantize_indices
    from .vq import dequantize
    cb = server.params["codebook"]
    if cfg.n_groups > 1 or cfg.n_slices > 1:
        return gsvq_dequantize_indices(indices, cb, n_groups=cfg.n_groups,
                                       n_slices=cfg.n_slices)
    return dequantize(indices, cb)

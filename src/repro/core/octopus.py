"""The OCTOPUS distributed learning protocol (§2.2, Steps 1-6).

Server:  Step 1  train initial global DVQ-AE on public data (ATD)
Clients: Step 2  one-shot local fine-tune (encoder + joint decoder)
         Step 3  disentangle; only Z• (indices) are releasable
         Step 4  transmit code indices at high frequency
         Step 5  low-frequency codebook EMA refresh -> sync to server
Server:  Step 6  train downstream tasks on gathered codes

The implementation is functional: ``ClientState`` / ``ServerState`` pytrees
plus pure transition functions, so the whole protocol jits and the client
population maps onto the mesh 'data' axis (one client shard per device
group) — see repro.distributed for the sharded variant.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from .dvqae import DVQAEConfig, DVQAEOut, forward, init_dvqae
from . import ema as ema_mod
from .ema import (EMAState, assignment_stats, ema_update_from_stats,
                  init_ema)


class ClientState(NamedTuple):
    params: dict              # local DVQ-AE (encoder fine-tuned, decoder joint)
    ema: EMAState             # local codebook EMA accumulator
    step: jax.Array


class ServerState(NamedTuple):
    params: dict              # global DVQ-AE
    opt: AdamWState
    step: jax.Array


class Transmission(NamedTuple):
    """LEGACY carrier: what crossed the network before the unified wire
    protocol. New code speaks ``repro.wire.CodePayload`` — the single
    versioned carrier — via the ``repro.wire`` session facades; packed
    Transmissions are coerced with ``repro.wire.as_payload``.

    ``payload`` is the dense ceil(log2 K)-bit packed word stream (see
    repro.kernels.pack_bits) — the bytes that would actually hit the
    uplink; ``nbytes`` is MEASURED from it (via ``CodePayload.nbytes``,
    the repo's one byte accounting), not computed from a formula.
    ``indices`` keeps the unpacked int32 view for local convenience.
    """
    indices: jax.Array        # int32 code matrix (B, T[, n_c])
    nbytes: int               # measured size of the packed payload
    labels: Optional[jax.Array] = None
    payload: Optional[jax.Array] = None   # (n_groups, W) uint32 bit-stream
    bits: int = 0             # bits per transmitted code


def transmit_bits(cfg: DVQAEConfig) -> int:
    """Bits per transmitted code index (§2.8: 5-10 bits in the paper).

    With GSVQ (any ``n_groups``/``n_slices`` > 1) clients transmit one
    *group* index per slice per position, so the per-code alphabet is
    n_groups — including sliced configs with n_groups == 1, whose codes
    are a single-symbol alphabet (1-bit floor), NOT K. Per position this
    is ``n_slices * transmit_bits == gsvq_bits_per_position``; the
    measured payload size (``repro.wire.CodePayload.nbytes``, the single
    §2.8 accounting) follows.
    """
    from repro.kernels.pack_bits import code_bits
    if cfg.n_groups > 1 or cfg.n_slices > 1:
        return code_bits(cfg.n_groups)
    return code_bits(cfg.codebook_size)


# --------------------------------------------------------------- Step 1

def server_init(key, cfg: DVQAEConfig, lr: float = 1e-3) -> ServerState:
    params = init_dvqae(key, cfg)
    return ServerState(params=params, opt=adamw_init(params),
                       step=jnp.zeros((), jnp.int32))


def server_pretrain_step(state: ServerState, cfg: DVQAEConfig, batch,
                         lr: float = 1e-3, group_axis=None
                         ) -> Tuple[ServerState, DVQAEOut]:
    """One ATD pretraining step of the global DVQ-AE (Step 1)."""
    def loss_fn(p):
        out = forward(p, cfg, batch, group_axis=group_axis)
        return out.loss, out

    grads, out = jax.grad(loss_fn, has_aux=True)(state.params)
    params, opt = adamw_update(state.params, grads, state.opt, lr=lr)
    return ServerState(params=params, opt=opt, step=state.step + 1), out


def server_pretrain(key, server: ServerState, cfg: DVQAEConfig, x, *,
                    steps: int, batch: int = 32, lr: float = 1e-3
                    ) -> Tuple[ServerState, Optional[DVQAEOut]]:
    """Step 1 driver: ``steps`` ATD pretraining steps over random
    minibatches of ``x``. Returns (server, last step's DVQAEOut — None
    when steps == 0). Shared by the launch entries and benchmarks so the
    fold_in/randint minibatch idiom lives in one place.
    """
    out = None
    for i in range(steps):
        sel = jax.random.randint(jax.random.fold_in(key, i), (batch,), 0,
                                 x.shape[0])
        server, out = server_pretrain_step(server, cfg, x[sel], lr=lr)
    return server, out


# --------------------------------------------------------------- Step 2

def client_init(server: ServerState) -> ClientState:
    """Deploy the global model to a client; codebook starts frozen."""
    return ClientState(params=jax.tree.map(lambda x: x, server.params),
                       ema=init_ema(server.params["codebook"]),
                       step=jnp.zeros((), jnp.int32))


def client_finetune_step(client: ClientState, cfg: DVQAEConfig, batch,
                         lr: float = 1e-4, opt: Optional[AdamWState] = None,
                         ) -> Tuple[ClientState, AdamWState, DVQAEOut]:
    """One-shot fine-tuning: encoder + decoder update, codebook FROZEN
    (§2.6 'initially, the codebook is frozen for local fine-tuning')."""
    if opt is None:
        opt = adamw_init({"encoder": client.params["encoder"],
                          "decoder": client.params["decoder"]})

    def loss_fn(enc_dec):
        p = {**enc_dec, "codebook": client.params["codebook"]}
        out = forward(p, cfg, batch)
        return out.loss, out

    trainable = {"encoder": client.params["encoder"],
                 "decoder": client.params["decoder"]}
    grads, out = jax.grad(loss_fn, has_aux=True)(trainable)
    new, opt = adamw_update(trainable, grads, opt, lr=lr)
    params = {**new, "codebook": client.params["codebook"]}
    return (ClientState(params=params, ema=client.ema, step=client.step + 1),
            opt, out)


# ----------------------------------------------------------- Steps 3 + 4
# (client_transmit / unpack_transmission are RETIRED — see _TOMBSTONES
# at the end of the module; the uplink is repro.wire.CodePayload now)


# --------------------------------------------------------------- Step 5

def client_encode(params, cfg: DVQAEConfig, batch):
    """ONE encoder pass into quantizer space: (z, spatial).

    z is IN(z_e) when the disentanglement layer is on — the space the
    quantizer matches in and the space EMA atoms must move in (EMA toward
    raw latents drags atoms off the normalized manifold). Every Step 3-5
    consumer (quantize, pack, refresh statistics) feeds off this single
    pass; see :func:`client_round`.
    """
    from .disentangle import instance_norm_latent
    from .dvqae import encode
    z_e, spatial = encode(params, cfg, batch)
    if cfg.apply_in:
        z_e = instance_norm_latent(z_e)
    return z_e, spatial


def quantize_indices(cfg: DVQAEConfig, z, codebook):
    """Transmitted codes of quantizer-space latents z (..., M):
    (...,) atom ids for plain VQ, (..., n_c) per-slice group indices for
    GSVQ — identical to ``forward(...).latent.indices`` without the
    decoder/loss work."""
    from .gsvq import gsvq_indices
    from .vq import kernel_nearest_atom
    if cfg.n_groups > 1 or cfg.n_slices > 1:
        return gsvq_indices(z, codebook, n_groups=cfg.n_groups,
                            n_slices=cfg.n_slices)
    return kernel_nearest_atom(z, codebook)


def refresh_stats(cfg: DVQAEConfig, z, indices):
    """Eq. 7-8 sufficient statistics (counts (K,), sums (K, M)) of one
    batch — the jnp twin of the fused encode kernel's stats outputs.

    GSVQ: indices is a (..., n_c) per-slice GROUP-index matrix, not flat
    atom ids — every slice's group index lands on its group's
    representative atom (group centre) and votes its position's FULL
    latent into that atom's EMA mass. (Feeding the raw matrix to the
    segment sum scattered onto wrong atoms; n_groups == 1 sliced configs
    used to skip the mapping entirely.)
    """
    if cfg.n_groups > 1 or cfg.n_slices > 1:
        ng = cfg.codebook_size // cfg.n_groups
        indices = indices * ng + ng // 2               # (..., n_c) atom ids
        z = jnp.broadcast_to(z[..., None, :], indices.shape + z.shape[-1:])
    return assignment_stats(z, indices, cfg.codebook_size)


def client_codebook_refresh(client: ClientState, cfg: DVQAEConfig, batch,
                            gamma: float = 0.99, *, stats=None
                            ) -> ClientState:
    """Low-frequency EMA refresh of the local codebook (Eq. 9).

    ``stats``: precomputed (counts, sums) — e.g. straight from the fused
    encode kernel (kernels/encode_codes.py) or :func:`refresh_stats` —
    in which case ``batch`` is ignored and NO network pass runs. Without
    it, one encoder pass derives the statistics (this entry used to run
    the full ``forward`` AND a second encode for the same refresh).
    """
    if stats is None:
        z, _ = client_encode(client.params, cfg, batch)
        idx = quantize_indices(cfg, z, client.params["codebook"])
        stats = refresh_stats(cfg, z, idx)
    ema = ema_update_from_stats(client.ema, *stats, gamma=gamma)
    params = {**client.params, "codebook": ema.codebook}
    return ClientState(params=params, ema=ema, step=client.step)


def server_merge_codebooks(server: ServerState,
                           client_codebooks,
                           client_counts,
                           *, staleness=None,
                           staleness_decay: float = 1.0) -> ServerState:
    """Count-weighted average of synced client codebooks (global dictionary
    update, Step 5 tail). counts: per-atom EMA N_i of each client.

    Accepts either sequences of per-client (K, M) / (K,) arrays or the
    already-stacked (M_clients, K, M) / (M_clients, K) arrays the batched
    sim engine carries.

    ``staleness`` (optional, (M_clients,) int): how many codebook versions
    behind the global dictionary each client's sync is — the async server
    runtime (repro.server) discounts stale contributions by
    ``staleness_decay ** staleness`` on top of the count weights, so a
    client that slept through two merges pulls the dictionary less than
    one that synced last round.
    """
    cbs = jnp.asarray(client_codebooks) if isinstance(
        client_codebooks, jax.Array) else jnp.stack(list(client_codebooks))
    cts = jnp.asarray(client_counts) if isinstance(
        client_counts, jax.Array) else jnp.stack(list(client_counts))
    w = cts
    if staleness is not None:
        decay = staleness_decay ** jnp.asarray(staleness, jnp.float32)
        w = w * decay[:, None]
    tot = jnp.sum(w, axis=0)                                  # (K,)
    merged = jnp.einsum("ck,ckm->km",
                        w / jnp.maximum(tot[None], 1e-9), cbs)
    # atoms with no effective contribution (e.g. every client fully
    # staleness-decayed) keep the current dictionary instead of
    # collapsing to zero
    cur = server.params["codebook"].astype(merged.dtype)
    merged = jnp.where(tot[:, None] > 1e-9, merged, cur)
    params = {**server.params, "codebook": merged.astype(
        server.params["codebook"].dtype)}
    return ServerState(params=params, opt=server.opt, step=server.step)


def server_merge_stats(server: ServerState,
                       stats: "ema_mod.MergeStats") -> ServerState:
    """Step-5 tail from ASSOCIATIVE merge statistics (cohort streaming).

    ``stats`` is the int64 fixed-point accumulator from
    :func:`repro.core.ema.merge_stats` / ``merge_stats_add`` — the cohort
    engine folds each cohort's contribution in as it streams, and this
    finishes the merge once. Because the accumulation is exact integer
    addition, the resulting dictionary is bit-identical for ANY cohort
    partition or order of the same client set (see
    ``ema.merge_codebook``). Atoms with zero accumulated weight keep the
    current dictionary, matching :func:`server_merge_codebooks`.
    """
    merged = ema_mod.merge_codebook(stats, server.params["codebook"])
    params = {**server.params,
              "codebook": jnp.asarray(merged)}
    return ServerState(params=params, opt=server.opt, step=server.step)


# ------------------------------------------------------- Steps 2-5 (round)

def client_finetune_encode(client: ClientState, cfg: DVQAEConfig, batch, *,
                           lr: float = 1e-4, n_local_steps: int = 1
                           ) -> Tuple[ClientState, jax.Array]:
    """The round's Steps 2-3 front half, shared by every round variant
    (and the engine's vmapped body — bit-parity between the population
    round and the single-client loop rests on this being ONE code path):
    ``n_local_steps`` of frozen-codebook fine-tuning, then the round's
    SINGLE encoder pass into quantizer space."""
    opt = None
    for _ in range(n_local_steps):
        client, opt, _ = client_finetune_step(client, cfg, batch, lr=lr,
                                              opt=opt)
    z, _ = client_encode(client.params, cfg, batch)
    return client, z


def client_round(client: ClientState, cfg: DVQAEConfig, batch, *,
                 lr: float = 1e-4, gamma: float = 0.99,
                 n_local_steps: int = 1
                 ) -> Tuple[ClientState, jax.Array]:
    """One full client round: Steps 2-5 for a single client, as a pure
    jittable function of (state, batch).

    Runs ``n_local_steps`` of frozen-codebook fine-tuning (Step 2), then
    ONE encoder pass feeds everything downstream: the releasable code
    indices (Steps 3-4) and the Eq. 7-8 statistics behind the EMA
    codebook refresh (Step 5). (This used to re-run the network three
    times — forward for the indices, then forward AND encode again
    inside the refresh — for the same latents.)

    Returns (new_client, int32 indices); packing the indices across the
    whole population at once is the engine's job (one big packed buffer
    beats per-client slivers). ``repro.wire.OctopusClient.round`` is the
    session entry whose uplink never materializes the index tensor at
    all and ships a ``CodePayload``.
    """
    client, z = client_finetune_encode(client, cfg, batch, lr=lr,
                                       n_local_steps=n_local_steps)
    idx = quantize_indices(cfg, z, client.params["codebook"])
    client = client_codebook_refresh(client, cfg, batch, gamma=gamma,
                                     stats=refresh_stats(cfg, z, idx))
    return client, idx


# --------------------------------------------------------------- Step 6

def gather_codes(transmissions: Sequence[Transmission], *,
                 fill_label: int = -1):
    """Server-side dataset assembly from client uploads.

    Labels: if every upload carries them they concatenate; if none do,
    ``labels`` is None. MIXED labeled/unlabeled uploads keep sample
    alignment with the gathered codes by filling the unlabeled uploads'
    slots with ``fill_label`` (default -1) — semi-supervised Step 6
    training masks those out. (Keying off ``transmissions[0]`` used to
    crash on [labeled, unlabeled] and silently drop [unlabeled, labeled].)
    """
    idx = jnp.concatenate([t.indices for t in transmissions], axis=0)
    have = [t.labels is not None for t in transmissions]
    if not any(have):
        labels = None
    elif all(have):
        labels = jnp.concatenate([jnp.asarray(t.labels)
                                  for t in transmissions], axis=0)
    else:
        ref = jnp.asarray(next(t.labels for t in transmissions
                               if t.labels is not None))
        dtype = ref.dtype
        if jnp.issubdtype(dtype, jnp.unsignedinteger):
            dtype = jnp.int32           # fill_label must stay negative
        labels = jnp.concatenate(
            [jnp.asarray(t.labels).astype(dtype) if t.labels is not None
             else jnp.full((int(t.indices.shape[0]),) + ref.shape[1:],
                           fill_label, dtype)
             for t in transmissions], axis=0)
    total_bytes = sum(t.nbytes for t in transmissions)
    return idx, labels, total_bytes


def decode_table(cfg: DVQAEConfig, codebook):
    """Decode-side lookup table for the fused kernel: ((rows, F), n_slices).

    Plain VQ: the codebook itself ((K, M), 1) — a code gathers its atom.
    GSVQ: the stacked per-slice group-mean table
    ((n_slices * n_groups, m), n_slices) — gathering row ``s*n_groups+g``
    is mathematically identical to ``gsvq_dequantize_indices``'s uniform
    group average (kernels/decode_codes.py consumes this layout).
    """
    from .gsvq import gsvq_group_mean_table
    if cfg.n_groups > 1 or cfg.n_slices > 1:
        t = gsvq_group_mean_table(codebook, n_groups=cfg.n_groups,
                                  n_slices=cfg.n_slices)
        return t.reshape(cfg.n_slices * cfg.n_groups, -1), cfg.n_slices
    return codebook, 1


def codes_to_features(server: Optional[ServerState], cfg: DVQAEConfig,
                      indices, *, codebook=None):
    """Dequantize gathered codes into downstream-task features.

    ``indices`` is either an int32 code array OR a packed carrier — a
    ``repro.wire.CodePayload`` (or a legacy packed ``Transmission``,
    coerced via ``repro.wire.as_payload``). The
    carrier takes the fused decode path (repro.wire.codec, ONE
    ops.decode_codes dispatch): straight from the uint32 word stream to
    feature rows, never materialising the index or gathered-atom
    tensors. Both paths agree bit-exactly for VQ and to fp32 tolerance
    for GSVQ means.

    ``codebook`` overrides the server's current dictionary — the versioned
    code store (repro.server) passes the registry snapshot the codes were
    packed under, so Step 5 lag never decodes against the wrong table.
    """
    from .gsvq import gsvq_dequantize_indices
    from .vq import dequantize
    if codebook is None:
        if server is None:
            raise ValueError("codes_to_features needs a ServerState or an "
                             "explicit codebook= to decode against")
        codebook = server.params["codebook"]
    cb = codebook
    from repro.wire.codec import decode_payloads
    from repro.wire.payload import as_payload
    p = as_payload(indices)
    if p is not None:
        return decode_payloads([p], cfg, cb)[0]
    if isinstance(indices, Transmission):       # unpacked legacy carrier
        indices = indices.indices
    if cfg.n_groups > 1 or cfg.n_slices > 1:
        return gsvq_dequantize_indices(indices, cb, n_groups=cfg.n_groups,
                                       n_slices=cfg.n_slices)
    return dequantize(indices, cb)


# ------------------------------------------------------------ tombstones
# The PR-5 wire shims finished their deprecation cycle: importing one now
# raises with a pointer at the unified wire layer, the same retirement
# pattern as repro.sim's IngestBuffer/PackedCodes.

_TOMBSTONES = {
    "client_transmit": "repro.wire.OctopusClient.transmit / .round "
                       "(CodePayload uplink)",
    "client_round_fused": "repro.wire.OctopusClient.round / "
                          "repro.wire.round_words",
    "unpack_transmission": "repro.wire.CodePayload.unpack (via "
                           "repro.wire.as_payload for legacy "
                           "Transmissions)",
}


def __getattr__(name):
    if name in _TOMBSTONES:
        raise ImportError(
            f"repro.core.octopus.{name} was removed; use "
            f"{_TOMBSTONES[name]} — the unified wire carrier, see "
            f"repro.wire")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

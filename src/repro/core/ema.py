"""Codebook EMA updates (OCTOPUS §2.6, Eq. 7-9).

Flexible & stabilized training: instead of the codebook loss term, atoms are
updated with exponential moving averages of their assigned encoder outputs:

    N_i <- gamma N_i + (1-gamma) n_i
    m_i <- gamma m_i + (1-gamma) sum_j z_{i,j}
    e_i <- m_i / N_i

This is the *non-training* update the paper uses for low-frequency local
codebook refresh (weekly samples, monthly sync). TPU adaptation: the
per-atom sums are a ``segment_sum`` over code assignments — one scatter-add,
sharded over the data axis with a single psum when distributed.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class EMAState(NamedTuple):
    counts: jax.Array      # N_i, (K,)
    sums: jax.Array        # m_i, (K, M)
    codebook: jax.Array    # e_i, (K, M)


def init_ema(codebook) -> EMAState:
    K, M = codebook.shape
    return EMAState(counts=jnp.ones((K,), jnp.float32),
                    sums=codebook.astype(jnp.float32),
                    codebook=codebook)


def ema_update_from_stats(state: EMAState, n, s, gamma: float = 0.99,
                          laplace_eps: float = 1e-5) -> EMAState:
    """One EMA step from precomputed sufficient statistics (Eq. 7-9).

    n: (..., K) per-atom assignment counts; s: (..., K, M) per-atom latent
    sums — exactly what the fused encode kernel (kernels/encode_codes.py)
    emits, so the Step 5 refresh never re-runs the encoder. Leading batch
    axes (e.g. a stacked client population) broadcast against an equally
    batched ``state``.
    """
    K = n.shape[-1]
    counts = gamma * state.counts + (1.0 - gamma) * n
    sums = gamma * state.sums + (1.0 - gamma) * s
    # Laplace smoothing keeps dead atoms from collapsing to 0/0
    total = jnp.sum(counts, axis=-1, keepdims=True)
    smoothed = ((counts + laplace_eps) / (total + K * laplace_eps)) * total
    codebook = (sums / smoothed[..., None]).astype(state.codebook.dtype)
    return EMAState(counts=counts, sums=sums, codebook=codebook)


def assignment_stats(z_e, indices, n_atoms: int):
    """Batch sufficient statistics: (counts (K,), sums (K, M)).

    z_e: (..., M); indices: z_e.shape[:-1] int codes.
    """
    M = z_e.shape[-1]
    zf = z_e.reshape(-1, M).astype(jnp.float32)
    idx = indices.reshape(-1)
    n = jax.ops.segment_sum(jnp.ones_like(idx, jnp.float32), idx, n_atoms)
    s = jax.ops.segment_sum(zf, idx, n_atoms)
    return n, s


def ema_update(state: EMAState, z_e, indices, gamma: float = 0.99,
               laplace_eps: float = 1e-5) -> EMAState:
    """One EMA step from a batch of encoder outputs and their codes.

    z_e: (..., M); indices: z_e.shape[:-1] int codes.
    """
    K, M = state.codebook.shape
    n, s = assignment_stats(z_e, indices, K)
    return ema_update_from_stats(state, n, s, gamma=gamma,
                                 laplace_eps=laplace_eps)


def ema_update_distributed(state: EMAState, z_e, indices, gamma: float = 0.99,
                           axis_name: str = "data") -> EMAState:
    """shard_map/pmap body: per-shard segment sums + one psum each.

    The paper's client-side weekly accumulation maps to per-shard sums; the
    monthly server sync is the psum.
    """
    K, _ = state.codebook.shape
    n, s = assignment_stats(z_e, indices, K)
    n = jax.lax.psum(n, axis_name)
    s = jax.lax.psum(s, axis_name)
    return ema_update_from_stats(state, n, s, gamma=gamma)


def batch_optimal_atoms(z_e, indices, n_atoms: int):
    """Eq. 8: per-atom mean of assigned outputs (the EMA fixed point)."""
    n, s = assignment_stats(z_e, indices, n_atoms)
    return s / jnp.maximum(n, 1.0)[:, None], n


# ---------------------------------------------------- associative Step-5 merge
#
# The Step-5 server merge is a count-weighted average over client
# codebooks. Averaging in floats is NOT associative, so a population
# merged cohort-by-cohort would drift (in the last bits) from the same
# population merged in one shot — and the cohort engine's whole contract
# is that grouping is invisible. MergeStats therefore accumulates in
# FIXED-POINT int64: each client's contribution is quantized ONCE
# (independently of its cohort) and summed with integer adds, which are
# exactly associative and commutative. The float division back to a
# codebook happens once, at the end, on the identical integer totals —
# so any cohort partition/order reproduces the single-shot merge
# bit-for-bit.

MERGE_FIXED_BITS = 24                     # fractional bits of the fixed point
_MERGE_SCALE = np.int64(1) << MERGE_FIXED_BITS


class MergeStats(NamedTuple):
    """Associative sufficient statistics for the Step-5 codebook merge.

    num: (K, M) int64 — Σ_clients round(count_k * codebook_km * 2^24)
    den: (K,)  int64 — Σ_clients round(count_k * 2^24)
    """
    num: np.ndarray
    den: np.ndarray


def merge_stats_zero(n_atoms: int, dim: int) -> MergeStats:
    """Identity element of ``merge_stats_add``."""
    return MergeStats(num=np.zeros((n_atoms, dim), np.int64),
                      den=np.zeros((n_atoms,), np.int64))


def merge_stats(codebooks, counts, *, staleness=None,
                staleness_decay: float = 0.5) -> MergeStats:
    """Fixed-point merge statistics for a cohort of clients.

    codebooks: (C, K, M); counts: (C, K); staleness: optional (C,) int
    rounds-behind-current, weighted ``staleness_decay ** staleness`` like
    ``server_merge_codebooks``. Each client is quantized independently,
    so statistics from ANY partition of the same clients sum to the same
    integers.
    """
    cbs = np.asarray(codebooks, np.float64)
    w = np.asarray(counts, np.float64)
    if cbs.ndim == 2:
        cbs, w = cbs[None], w[None]
    if staleness is not None:
        decay = np.power(float(staleness_decay),
                         np.asarray(staleness, np.float64))
        w = w * decay[:, None]
    den_f = w * np.float64(_MERGE_SCALE)                     # (C, K)
    num_f = den_f[..., None] * cbs                           # (C, K, M)
    return MergeStats(
        num=np.rint(num_f).astype(np.int64).sum(axis=0),
        den=np.rint(den_f).astype(np.int64).sum(axis=0))


def merge_stats_add(a: MergeStats, b: MergeStats) -> MergeStats:
    """Exactly associative/commutative combine (plain int64 adds)."""
    return MergeStats(num=a.num + b.num, den=a.den + b.den)


def merge_codebook(stats: MergeStats, current) -> np.ndarray:
    """Finish the merge: integer totals -> float32 codebook.

    Atoms with (near-)zero total weight keep the ``current`` dictionary
    row, matching ``server_merge_codebooks``'s behaviour for dead atoms.
    """
    cur = np.asarray(current)
    live = stats.den > 0
    den = np.where(live, stats.den, np.int64(1)).astype(np.float64)
    merged = stats.num.astype(np.float64) / den[:, None]
    out = np.where(live[:, None], merged, cur.astype(np.float64))
    return out.astype(cur.dtype)

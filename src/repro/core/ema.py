"""Codebook EMA updates (OCTOPUS §2.6, Eq. 7-9).

Flexible & stabilized training: instead of the codebook loss term, atoms are
updated with exponential moving averages of their assigned encoder outputs:

    N_i <- gamma N_i + (1-gamma) n_i
    m_i <- gamma m_i + (1-gamma) sum_j z_{i,j}
    e_i <- m_i / N_i

This is the *non-training* update the paper uses for low-frequency local
codebook refresh (weekly samples, monthly sync). TPU adaptation: the
per-atom sums are a ``segment_sum`` over code assignments — one scatter-add,
sharded over the data axis with a single psum when distributed.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class EMAState(NamedTuple):
    counts: jax.Array      # N_i, (K,)
    sums: jax.Array        # m_i, (K, M)
    codebook: jax.Array    # e_i, (K, M)


def init_ema(codebook) -> EMAState:
    K, M = codebook.shape
    return EMAState(counts=jnp.ones((K,), jnp.float32),
                    sums=codebook.astype(jnp.float32),
                    codebook=codebook)


def ema_update_from_stats(state: EMAState, n, s, gamma: float = 0.99,
                          laplace_eps: float = 1e-5) -> EMAState:
    """One EMA step from precomputed sufficient statistics (Eq. 7-9).

    n: (..., K) per-atom assignment counts; s: (..., K, M) per-atom latent
    sums — exactly what the fused encode kernel (kernels/encode_codes.py)
    emits, so the Step 5 refresh never re-runs the encoder. Leading batch
    axes (e.g. a stacked client population) broadcast against an equally
    batched ``state``.
    """
    K = n.shape[-1]
    counts = gamma * state.counts + (1.0 - gamma) * n
    sums = gamma * state.sums + (1.0 - gamma) * s
    # Laplace smoothing keeps dead atoms from collapsing to 0/0
    total = jnp.sum(counts, axis=-1, keepdims=True)
    smoothed = ((counts + laplace_eps) / (total + K * laplace_eps)) * total
    codebook = (sums / smoothed[..., None]).astype(state.codebook.dtype)
    return EMAState(counts=counts, sums=sums, codebook=codebook)


def assignment_stats(z_e, indices, n_atoms: int):
    """Batch sufficient statistics: (counts (K,), sums (K, M)).

    z_e: (..., M); indices: z_e.shape[:-1] int codes.
    """
    M = z_e.shape[-1]
    zf = z_e.reshape(-1, M).astype(jnp.float32)
    idx = indices.reshape(-1)
    n = jax.ops.segment_sum(jnp.ones_like(idx, jnp.float32), idx, n_atoms)
    s = jax.ops.segment_sum(zf, idx, n_atoms)
    return n, s


def ema_update(state: EMAState, z_e, indices, gamma: float = 0.99,
               laplace_eps: float = 1e-5) -> EMAState:
    """One EMA step from a batch of encoder outputs and their codes.

    z_e: (..., M); indices: z_e.shape[:-1] int codes.
    """
    K, M = state.codebook.shape
    n, s = assignment_stats(z_e, indices, K)
    return ema_update_from_stats(state, n, s, gamma=gamma,
                                 laplace_eps=laplace_eps)


def ema_update_distributed(state: EMAState, z_e, indices, gamma: float = 0.99,
                           axis_name: str = "data") -> EMAState:
    """shard_map/pmap body: per-shard segment sums + one psum each.

    The paper's client-side weekly accumulation maps to per-shard sums; the
    monthly server sync is the psum.
    """
    K, _ = state.codebook.shape
    n, s = assignment_stats(z_e, indices, K)
    n = jax.lax.psum(n, axis_name)
    s = jax.lax.psum(s, axis_name)
    return ema_update_from_stats(state, n, s, gamma=gamma)


def batch_optimal_atoms(z_e, indices, n_atoms: int):
    """Eq. 8: per-atom mean of assigned outputs (the EMA fixed point)."""
    n, s = assignment_stats(z_e, indices, n_atoms)
    return s / jnp.maximum(n, 1.0)[:, None], n

"""Group and Sliced Vector Quantization (OCTOPUS §2.4, Eq. 2-3).

GVQ: the codebook (K, M) is partitioned into G groups of N_g = K/G atoms.
A latent vector is matched to the *group* with the smallest mean atom
distance (Eq. 2), then quantized to the inverse-distance-weighted average of
that group's atoms (Eq. 3). This softens the hard-argmin mismatch under
non-IID drift: a slightly-off query still lands in the right neighbourhood.

SVQ: atoms and latents are sliced into n_c parts along M and VQ runs
independently per slice — effective codebook size K^{n_c} at K·M storage.

Transmission: GVQ sends the group index (log2 G bits) per position per
slice; the weighted combination is reconstructed server-side from the shared
codebook, so only indices travel (same contract as plain VQ).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class GSVQOut(NamedTuple):
    quantized: jax.Array       # STE-passthrough quantized latents (..., M)
    indices: jax.Array         # (..., n_c) int32 group indices per slice
    codebook_loss: jax.Array
    commit_loss: jax.Array


def _group_distances(z, codebook, n_groups: int):
    """Mean per-group L2 distance (Eq. 2).

    z: (N, m); codebook: (K, m) -> (N, G).
    """
    K = codebook.shape[0]
    ng = K // n_groups
    # full pairwise distance then mean-pool over groups; the Pallas kernel
    # streams this without materialising (N, K) when K is large.
    z2 = jnp.sum(jnp.square(z), axis=-1, keepdims=True)
    e2 = jnp.sum(jnp.square(codebook), axis=-1)[None, :]
    d2 = jnp.maximum(z2 - 2.0 * (z @ codebook.T) + e2, 0.0)      # (N, K)
    d = jnp.sqrt(d2 + 1e-12)
    return jnp.mean(d.reshape(-1, n_groups, ng), axis=-1)        # (N, G)


def _group_weighted_average(z, group_atoms):
    """Inverse-distance-weighted atom average (Eq. 3).

    z: (N, m); group_atoms: (N, N_g, m) atoms of each row's matched group.
    """
    d = jnp.sqrt(jnp.sum(jnp.square(z[:, None, :] - group_atoms), axis=-1)
                 + 1e-12)                                        # (N, N_g)
    w = 1.0 / (d + 1e-8)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return jnp.einsum("ng,ngm->nm", w, group_atoms)


def gsvq_quantize(z_e, codebook, *, n_groups: int = 1, n_slices: int = 1) -> GSVQOut:
    """Group + sliced quantization with STE.

    z_e: (..., M); codebook: (K, M). M must divide by n_slices, K by n_groups.
    """
    *lead, M = z_e.shape
    K = codebook.shape[0]
    assert M % n_slices == 0, (M, n_slices)
    assert K % n_groups == 0, (K, n_groups)
    m = M // n_slices
    ng = K // n_groups

    zf = z_e.reshape(-1, n_slices, m)                            # (N, n_c, m)
    cb = codebook.reshape(K, n_slices, m).transpose(1, 0, 2)     # (n_c, K, m)

    def per_slice(z_s, cb_s):
        gd = _group_distances(z_s, cb_s, n_groups)               # (N, G)
        gidx = jnp.argmin(gd, axis=-1).astype(jnp.int32)         # (N,)
        groups = cb_s.reshape(n_groups, ng, m)
        atoms = groups[gidx]                                     # (N, N_g, m)
        zq = _group_weighted_average(z_s, atoms)
        return zq, gidx

    zq, gidx = jax.vmap(per_slice, in_axes=(1, 0), out_axes=(1, 1))(zf, cb)
    zq = zq.reshape(*lead, M)
    gidx = gidx.reshape(*lead, n_slices)

    codebook_loss = jnp.mean(jnp.square(jax.lax.stop_gradient(z_e) - zq))
    commit_loss = jnp.mean(jnp.square(z_e - jax.lax.stop_gradient(zq)))
    z_st = z_e + jax.lax.stop_gradient(zq - z_e)
    return GSVQOut(quantized=z_st, indices=gidx,
                   codebook_loss=codebook_loss, commit_loss=commit_loss)


def gsvq_indices(z_e, codebook, *, n_groups: int = 1, n_slices: int = 1):
    """Index-only GSVQ match: (..., M) latents -> (..., n_c) int32 group
    indices per slice, identical to ``gsvq_quantize(...).indices`` (same
    Eq. 2 argmin) without building the Eq. 3 weighted average — the
    transmit/refresh path needs only the codes.
    """
    *lead, M = z_e.shape
    K = codebook.shape[0]
    m = M // n_slices
    zf = z_e.reshape(-1, n_slices, m)
    cb = codebook.reshape(K, n_slices, m).transpose(1, 0, 2)

    def per_slice(z_s, cb_s):
        gd = _group_distances(z_s, cb_s, n_groups)
        return jnp.argmin(gd, axis=-1).astype(jnp.int32)

    gidx = jax.vmap(per_slice, in_axes=(1, 0), out_axes=1)(zf, cb)
    return gidx.reshape(*lead, n_slices)


def gsvq_dequantize_indices(indices, codebook, z_hint=None, *, n_groups: int,
                            n_slices: int):
    """Server-side reconstruction from group indices.

    Without the original z the exact Eq. 3 weights are unknown; the paper
    transmits indices only, so the server reconstructs with the *uniform*
    group average (the weights' expectation), or — when the client also
    ships a low-rate z hint — the weighted version. indices: (..., n_c).
    """
    *lead, n_c = indices.shape
    K, M = codebook.shape
    m = M // n_slices
    ng = K // n_groups
    cb = codebook.reshape(K, n_slices, m).transpose(1, 0, 2)     # (n_c, K, m)
    groups = cb.reshape(n_slices, n_groups, ng, m)
    flat_idx = indices.reshape(-1, n_c)

    def per_slice(idx_s, groups_s):
        atoms = groups_s[idx_s]                                  # (N, N_g, m)
        return jnp.mean(atoms, axis=1)

    out = jax.vmap(per_slice, in_axes=(1, 0), out_axes=1)(flat_idx, groups)
    return out.reshape(*lead, M)


def gsvq_group_mean_table(codebook, *, n_groups: int, n_slices: int):
    """Precomputed uniform group means: (n_slices, n_groups, m).

    Row ``(s, g)`` is the mean of group ``g``'s atoms restricted to slice
    ``s`` — exactly what :func:`gsvq_dequantize_indices` computes per
    index, hoisted out so the server's fused decode kernel
    (kernels/decode_codes.py) can gather one m-dim row per code instead
    of materialising the (N, N_g, m) atom tensor.
    """
    K, M = codebook.shape
    m = M // n_slices
    ng = K // n_groups
    cb = codebook.reshape(K, n_slices, m).transpose(1, 0, 2)     # (n_c, K, m)
    return jnp.mean(cb.reshape(n_slices, n_groups, ng, m), axis=2)


def gsvq_bits_per_position(n_groups: int, n_slices: int) -> int:
    """Uplink bits per latent position (§2.8): ``n_slices`` group indices
    of ``ceil(log2 n_groups)`` bits each (1-bit floor; the alphabet is
    the group id even when n_groups == 1)."""
    import math
    return n_slices * max(1, math.ceil(math.log2(max(n_groups, 2))))

"""Vector quantization with straight-through estimator (OCTOPUS Eq. 1).

The basic DVQ-AE quantizer: map each M-dim latent vector to the nearest
codebook atom, transmit only the int index. Loss terms:

    L = ||x - D(z_q)||^2  +  alpha * ||sg[z_e] - e||^2  +  beta * ||z_e - sg[e]||^2

The nearest-neighbour search is the per-sample hot spot; the Pallas kernel
``repro.kernels.vq_nn`` implements the MXU-tiled version of
:func:`nearest_atom` and is the DEFAULT :func:`quantize` path (same
interpret-on-CPU fallback convention as the pack/decode kernels, picked
by ``repro.kernels.ops``). ``use_kernel=False`` forces the pure-jnp
reference; both tie-break to the first minimal atom.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class VQOut(NamedTuple):
    quantized: jax.Array      # z_q, same shape as z_e (STE-passthrough)
    indices: jax.Array        # int32 codes, shape z_e.shape[:-1]
    codebook_loss: jax.Array  # ||sg[z_e] - e||^2
    commit_loss: jax.Array    # ||z_e - sg[e]||^2


def squared_distances(z, codebook):
    """Pairwise ||z - e||^2 via the expanded form (MXU-friendly).

    z: (N, M); codebook: (K, M) -> (N, K).
    """
    z2 = jnp.sum(jnp.square(z), axis=-1, keepdims=True)          # (N, 1)
    e2 = jnp.sum(jnp.square(codebook), axis=-1)[None, :]         # (1, K)
    cross = z @ codebook.T                                        # (N, K)
    return z2 - 2.0 * cross + e2


def nearest_atom(z, codebook):
    """Indices of nearest codebook atoms. z: (..., M) -> (...,) int32."""
    flat = z.reshape(-1, z.shape[-1])
    idx = jnp.argmin(squared_distances(flat, codebook), axis=-1)
    return idx.reshape(z.shape[:-1]).astype(jnp.int32)


def kernel_nearest_atom(z, codebook):
    """:func:`nearest_atom` via the MXU-tiled Pallas kernel (streaming
    argmin, no (N, K) matrix in HBM). Inputs are stop-gradiented — the
    argmin is non-differentiable, and severing the tangents lets the
    kernel sit inside ``jax.grad``-traced training steps."""
    from repro.kernels.ops import vq_nearest
    idx = vq_nearest(jax.lax.stop_gradient(z.reshape(-1, z.shape[-1])),
                     jax.lax.stop_gradient(codebook))
    return idx.reshape(z.shape[:-1])


def quantize(z_e, codebook, *, use_kernel: Optional[bool] = None) -> VQOut:
    """Quantize latents against the codebook with STE.

    z_e: (..., M) continuous encoder output.
    codebook: (K, M).
    use_kernel: None (default) picks the Pallas nearest-neighbour kernel
    via ``repro.kernels.ops`` (interpret fallback off-TPU); False forces
    the pure-jnp :func:`nearest_atom` reference.
    """
    if use_kernel or use_kernel is None:
        idx = kernel_nearest_atom(z_e, codebook)
    else:
        idx = nearest_atom(z_e, codebook)
    z_q = codebook[idx]                                           # (..., M)
    codebook_loss = jnp.mean(jnp.square(jax.lax.stop_gradient(z_e) - z_q))
    commit_loss = jnp.mean(jnp.square(z_e - jax.lax.stop_gradient(z_q)))
    # straight-through: forward z_q, backward identity to z_e
    z_st = z_e + jax.lax.stop_gradient(z_q - z_e)
    return VQOut(quantized=z_st, indices=idx,
                 codebook_loss=codebook_loss, commit_loss=commit_loss)


def dequantize(indices, codebook):
    """Server-side lookup: int codes -> latent embeddings."""
    return codebook[indices]


def init_codebook(key, n_atoms: int, dim: int, dtype=jnp.float32):
    """Unit-scale init: the IN layer upstream normalizes latents to
    ~N(0,1) per channel, so atoms must start at the same scale.

    A tiny init (e.g. 1/K) is a classic VQ-VAE collapse mode: commitment
    pulls z_e toward the near-zero codebook, the encoder output flattens,
    and reconstruction degenerates to the batch mean.
    """
    return jax.random.normal(key, (n_atoms, dim), dtype)


def vq_loss_terms(out: VQOut, alpha: float = 1.0, beta: float = 0.25):
    """alpha * codebook + beta * commitment (Eq. 1, second + third term)."""
    return alpha * out.codebook_loss + beta * out.commit_loss


def codes_nbits(indices, n_atoms: int) -> int:
    """Transmission cost of an index matrix in bits (§2.8: 5-10 bits/code)."""
    import math
    return int(indices.size) * max(1, math.ceil(math.log2(n_atoms)))


def perplexity(indices, n_atoms: int):
    """Codebook usage perplexity — exp(H(code distribution)).

    Low perplexity = codebook collapse; useful training diagnostic.
    Histogrammed with ``bincount`` — the (N, K) one-hot this used to
    materialize was K times the memory for the same counts.
    """
    flat = indices.reshape(-1)
    counts = jnp.bincount(flat, length=n_atoms).astype(jnp.float32)
    probs = counts / jnp.maximum(flat.size, 1)
    ent = -jnp.sum(jnp.where(probs > 0, probs * jnp.log(probs), 0.0))
    return jnp.exp(ent)

"""Distributed Vector-Quantized Autoencoder (OCTOPUS §2.3).

Three variants share the VQ/GSVQ/disentangle core:

  * ``image``  — Conv2D encoder (stride-2 downsampling + resnet blocks) to a
    (H/4, W/4, M) latent grid; ConvTranspose decoder. The paper's
    MNIST/CelebA path.
  * ``speech`` — Conv1D encoder over (B, T, C) frames to (B, T/4, M);
    Conv1D + upsample decoder. The paper's Speech path.
  * ``sequence`` — embedding-space encoder for token sequences: this is the
    bridge that feeds OCTOPUS codes into the assigned LM-scale backbones
    (a VQ tokenizer over d_model-dim hidden states).

All apply an IN layer before VQ (the disentanglement strategy) and return
both components so the client can transmit Z• only.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.nn.layers import (conv1d, conv2d, conv2d_transpose, dense_init,
                             init_conv1d, init_conv2d, init_conv2d_transpose,
                             instance_norm_1d, instance_norm_2d)
from .disentangle import DisentangledLatent, recombine, split_public_private
from .vq import init_codebook


@dataclass(frozen=True)
class DVQAEConfig:
    kind: str = "image"            # image | speech | sequence
    in_channels: int = 3           # image channels / speech feature dim
    hidden: int = 128              # conv channel width
    n_res_blocks: int = 2
    latent_dim: int = 64           # M, codebook atom dim
    codebook_size: int = 256       # K
    n_groups: int = 1              # GSVQ groups (1 = plain VQ)
    n_slices: int = 1              # GSVQ slices
    apply_in: bool = True          # InstanceNorm disentanglement on/off
    encoder_in: bool = True        # IN inside encoder convs (paper's encoder-
                                   # block IN; the stronger style filter)
    alpha: float = 1.0             # codebook loss weight
    beta: float = 0.25             # commitment weight
    lam: float = 0.01              # latent (IN-pull) weight, paper lambda

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


class DVQAEOut(NamedTuple):
    recon: jax.Array
    latent: DisentangledLatent
    loss: jax.Array
    recon_loss: jax.Array


# ------------------------------------------------------------- resnet block

def _init_resblock(key, c, dtype):
    k1, k2 = jax.random.split(key)
    return {"c1": init_conv2d(k1, c, c, 3, dtype),
            "c2": init_conv2d(k2, c, c, 1, dtype)}


def _resblock(p, x):
    h = conv2d(p["c1"], jax.nn.relu(x))
    h = conv2d(p["c2"], jax.nn.relu(h))
    return x + h


def _init_resblock1d(key, c, dtype):
    k1, k2 = jax.random.split(key)
    return {"c1": init_conv1d(k1, c, c, 3, dtype),
            "c2": init_conv1d(k2, c, c, 1, dtype)}


def _resblock1d(p, x):
    h = conv1d(p["c1"], jax.nn.relu(x))
    h = conv1d(p["c2"], jax.nn.relu(h))
    return x + h


# ------------------------------------------------------------------ image

def init_image_encoder(key, cfg: DVQAEConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 4 + cfg.n_res_blocks)
    p = {
        "down1": init_conv2d(ks[0], cfg.in_channels, cfg.hidden // 2, 4, dtype),
        "down2": init_conv2d(ks[1], cfg.hidden // 2, cfg.hidden, 4, dtype),
        "mid": init_conv2d(ks[2], cfg.hidden, cfg.hidden, 3, dtype),
        "to_latent": init_conv2d(ks[3], cfg.hidden, cfg.latent_dim, 1, dtype),
    }
    for i in range(cfg.n_res_blocks):
        p[f"res{i}"] = _init_resblock(ks[4 + i], cfg.hidden, dtype)
    return p


def image_encode(p, cfg: DVQAEConfig, x):
    """x: (B, H, W, C) -> (B, H/4, W/4, M)."""
    h = jax.nn.relu(conv2d(p["down1"], x, stride=2))
    if cfg.encoder_in:
        h = instance_norm_2d(h)
    h = jax.nn.relu(conv2d(p["down2"], h, stride=2))
    if cfg.encoder_in:
        h = instance_norm_2d(h)
    h = conv2d(p["mid"], h)
    for i in range(cfg.n_res_blocks):
        h = _resblock(p[f"res{i}"], h)
    return conv2d(p["to_latent"], jax.nn.relu(h))


def init_image_decoder(key, cfg: DVQAEConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 4 + cfg.n_res_blocks)
    p = {
        "from_latent": init_conv2d(ks[0], cfg.latent_dim, cfg.hidden, 3, dtype),
        "up1": init_conv2d_transpose(ks[1], cfg.hidden, cfg.hidden // 2, 4, dtype),
        "up2": init_conv2d_transpose(ks[2], cfg.hidden // 2, cfg.in_channels, 4, dtype),
    }
    for i in range(cfg.n_res_blocks):
        p[f"res{i}"] = _init_resblock(ks[3 + i], cfg.hidden, dtype)
    return p


def image_decode(p, cfg: DVQAEConfig, z):
    h = conv2d(p["from_latent"], z)
    for i in range(cfg.n_res_blocks):
        h = _resblock(p[f"res{i}"], h)
    h = jax.nn.relu(conv2d_transpose(p["up1"], jax.nn.relu(h), stride=2))
    return conv2d_transpose(p["up2"], h, stride=2)


# ------------------------------------------------------------------ speech

def init_speech_encoder(key, cfg: DVQAEConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 4 + cfg.n_res_blocks)
    p = {
        "down1": init_conv1d(ks[0], cfg.in_channels, cfg.hidden // 2, 4, dtype),
        "down2": init_conv1d(ks[1], cfg.hidden // 2, cfg.hidden, 4, dtype),
        "mid": init_conv1d(ks[2], cfg.hidden, cfg.hidden, 3, dtype),
        "to_latent": init_conv1d(ks[3], cfg.hidden, cfg.latent_dim, 1, dtype),
    }
    for i in range(cfg.n_res_blocks):
        p[f"res{i}"] = _init_resblock1d(ks[4 + i], cfg.hidden, dtype)
    return p


def speech_encode(p, cfg: DVQAEConfig, x):
    """x: (B, T, C) -> (B, T/4, M)."""
    h = jax.nn.relu(conv1d(p["down1"], x, stride=2))
    if cfg.encoder_in:
        h = instance_norm_1d(h)
    h = jax.nn.relu(conv1d(p["down2"], h, stride=2))
    if cfg.encoder_in:
        h = instance_norm_1d(h)
    h = conv1d(p["mid"], h)
    for i in range(cfg.n_res_blocks):
        h = _resblock1d(p[f"res{i}"], h)
    return conv1d(p["to_latent"], jax.nn.relu(h))


def init_speech_decoder(key, cfg: DVQAEConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 3 + cfg.n_res_blocks)
    p = {
        "from_latent": init_conv1d(ks[0], cfg.latent_dim, cfg.hidden, 3, dtype),
        "up1": init_conv1d(ks[1], cfg.hidden, cfg.hidden // 2, 3, dtype),
        "up2": init_conv1d(ks[2], cfg.hidden // 2, cfg.in_channels, 3, dtype),
    }
    for i in range(cfg.n_res_blocks):
        p[f"res{i}"] = _init_resblock1d(ks[3 + i], cfg.hidden, dtype)
    return p


def _upsample_1d(x, factor=2):
    B, T, C = x.shape
    return jnp.repeat(x, factor, axis=1)


def speech_decode(p, cfg: DVQAEConfig, z):
    h = conv1d(p["from_latent"], z)
    for i in range(cfg.n_res_blocks):
        h = _resblock1d(p[f"res{i}"], h)
    h = jax.nn.relu(conv1d(p["up1"], _upsample_1d(jax.nn.relu(h))))
    return conv1d(p["up2"], _upsample_1d(h))


# ---------------------------------------------------------------- sequence

def init_sequence_codec(key, cfg: DVQAEConfig, d_model: int, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {"enc": dense_init(k1, d_model, cfg.latent_dim, dtype),
            "dec": dense_init(k2, cfg.latent_dim, d_model, dtype)}


def sequence_encode(p, cfg: DVQAEConfig, h):
    """h: (B, T, d_model) backbone embeddings -> (B, T, M) latents."""
    return h @ p["enc"]


def sequence_decode(p, cfg: DVQAEConfig, z):
    return z @ p["dec"]


# ------------------------------------------------------------------- model

def init_dvqae(key, cfg: DVQAEConfig, d_model: Optional[int] = None,
               dtype=jnp.float32):
    ke, kd, kc = jax.random.split(key, 3)
    if cfg.kind == "image":
        enc = init_image_encoder(ke, cfg, dtype)
        dec = init_image_decoder(kd, cfg, dtype)
    elif cfg.kind == "speech":
        enc = init_speech_encoder(ke, cfg, dtype)
        dec = init_speech_decoder(kd, cfg, dtype)
    elif cfg.kind == "sequence":
        assert d_model is not None
        codec = init_sequence_codec(ke, cfg, d_model, dtype)
        enc, dec = {"proj": codec["enc"]}, {"proj": codec["dec"]}
    else:
        raise ValueError(cfg.kind)
    return {"encoder": enc, "decoder": dec,
            "codebook": init_codebook(kc, cfg.codebook_size, cfg.latent_dim,
                                      dtype)}


def encode(params, cfg: DVQAEConfig, x):
    if cfg.kind == "image":
        z = image_encode(params["encoder"], cfg, x)
        B, H, W, M = z.shape
        return z.reshape(B, H * W, M), (H, W)
    if cfg.kind == "speech":
        return speech_encode(params["encoder"], cfg, x), None
    return x @ params["encoder"]["proj"], None


def decode(params, cfg: DVQAEConfig, z, spatial=None):
    if cfg.kind == "image":
        H, W = spatial
        B = z.shape[0]
        return image_decode(params["decoder"], cfg,
                            z.reshape(B, H, W, cfg.latent_dim))
    if cfg.kind == "speech":
        return speech_decode(params["decoder"], cfg, z)
    return z @ params["decoder"]["proj"]


def forward(params, cfg: DVQAEConfig, x, *, group_axis=None) -> DVQAEOut:
    """Full autoencoding pass with disentanglement (Eq. 6 objective)."""
    z_e, spatial = encode(params, cfg, x)
    dis = split_public_private(
        z_e, params["codebook"], group_axis=group_axis,
        apply_in=cfg.apply_in, n_groups=cfg.n_groups, n_slices=cfg.n_slices)
    z = recombine(dis.public, dis.private)
    x_rec = decode(params, cfg, z, spatial)
    recon = jnp.mean(jnp.square(x - x_rec))
    loss = (recon + cfg.alpha * dis.codebook_loss + cfg.beta * dis.commit_loss
            + cfg.lam * dis.latent_loss)
    return DVQAEOut(recon=x_rec, latent=dis, loss=loss, recon_loss=recon)


def encode_public(params, cfg: DVQAEConfig, x):
    """Client transmit path: only the code indices leave the device."""
    out = forward(params, cfg, x)
    return out.latent.indices

"""TOMBSTONE: the privacy toolkit moved to ``repro.privacy``.

The Thm. 1 computational adversary (§2.7.2) now lives in
``repro.privacy.audit``, where it is the shared classifier core behind
both the paired :func:`repro.privacy.privacy_audit` and the wire-level
inference attacks (``repro.privacy.attacks``) that train the same probe
on captured CodePayload streams. This module only points there —
importing a moved name raises with the new location, same shim-hygiene
pattern as ``core.octopus`` / ``sim``.
"""
from __future__ import annotations

_TOMBSTONES = {
    "AdversaryMetrics": "repro.privacy.AdversaryMetrics",
    "init_adversary": "repro.privacy.init_adversary",
    "adversary_logits": "repro.privacy.adversary_logits",
    "xent": "repro.privacy.xent",
    "train_adversary": "repro.privacy.train_adversary",
    "evaluate_adversary": "repro.privacy.evaluate_adversary",
    "privacy_audit": "repro.privacy.privacy_audit",
}


def __getattr__(name):
    if name in _TOMBSTONES:
        raise ImportError(
            f"repro.core.privacy.{name} moved; use {_TOMBSTONES[name]} — "
            f"the red-team subsystem owns the Thm. 1 adversary now, see "
            f"repro.privacy")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

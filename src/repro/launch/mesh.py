"""Production meshes.

Target hardware: TPU v5e pods, 256 chips/pod.
  single-pod:  (data=16, model=16)
  multi-pod:   (pod=2, data=16, model=16) = 512 chips

Functions, never module-level constants — importing this module must not
touch jax device state (device count is locked at first jax init, and the
dry-run needs to set XLA_FLAGS before that).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, model_parallel: int = 1):
    """Mesh over whatever devices exist (CPU tests / local runs)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))

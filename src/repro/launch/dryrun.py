import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

The two lines above MUST run before any jax import (device count locks at
first init); that is why they precede the module docstring's imports.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
        --shape train_4k [--multi-pod] [--out experiments/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all

Outputs one JSON per combo with memory analysis, cost analysis, collective
byte counts, and the three roofline terms (single-pod numbers feed
EXPERIMENTS.md §Roofline).
"""
import argparse
import gzip
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import INPUT_SHAPES, ARCH_IDS, get_config
from repro.distributed import steps as S
from repro.launch.mesh import make_production_mesh
from repro.roofline import analysis as RA


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               dtype: str = "bfloat16", verbose: bool = True,
               hlo_out: str = ""):
    """Lower+compile one combo; returns (report_dict, compiled)."""
    from repro.configs.base import TrainConfig
    cfg = get_config(arch).replace(dtype=dtype, param_dtype=dtype)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256
    window = S.decode_window(cfg, shape)

    t0 = time.time()
    with mesh:
        if shape.mode == "train":
            fn, in_specs, out_specs, arg_shapes = S.build_train_step(
                cfg, TrainConfig(), mesh, shape)
            jfn = jax.jit(fn,
                          in_shardings=S.shd_to(in_specs, mesh),
                          out_shardings=S.shd_to(out_specs, mesh),
                          donate_argnums=(0,))
            lowered = jfn.lower(*arg_shapes)
        elif shape.mode == "prefill":
            fn, in_specs, out_specs, arg_shapes = S.build_prefill_step(
                cfg, mesh, shape, window_override=window)
            jfn = jax.jit(fn,
                          in_shardings=S.shd_to(in_specs, mesh),
                          out_shardings=S.shd_to(out_specs, mesh))
            lowered = jfn.lower(*arg_shapes)
        else:  # decode
            fn, in_specs, out_specs, arg_shapes = S.build_serve_step(
                cfg, mesh, shape, window_override=window)
            jfn = jax.jit(fn,
                          in_shardings=(S.shd_to(in_specs["params"], mesh),
                                        S.shd_to(in_specs["token"], mesh),
                                        S.shd_to(in_specs["caches"], mesh),
                                        S.shd_to(in_specs["index"], mesh))
                          + ((S.shd_to(in_specs["enc_out"], mesh),)
                             if "enc_out" in in_specs else ()),
                          out_shardings=S.shd_to(out_specs, mesh),
                          donate_argnums=(2,))
            args = [arg_shapes["params"], arg_shapes["token"],
                    arg_shapes["caches"], arg_shapes["index"]]
            if "enc_out" in arg_shapes:
                args.append(arg_shapes["enc_out"])
            lowered = jfn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    hlo_text = compiled.as_text()
    if hlo_out:
        with gzip.open(hlo_out, "wt") as f:
            f.write(hlo_text)
    rep = RA.analyze(compiled, None, arch=arch, shape_name=shape_name,
                     mesh_name=mesh_name, chips=chips, cfg=cfg, shape=shape,
                     hlo_text=hlo_text)
    d = rep.to_dict()
    d.update({
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "mode": shape.mode, "window_override": window,
        "memory_analysis": {
            k: int(getattr(mem, k, 0)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "alias_size_in_bytes",
             "generated_code_size_in_bytes")},
    })
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: OK "
              f"(lower {t_lower:.1f}s compile {t_compile:.1f}s, "
              f"bottleneck={rep.bottleneck}, "
              f"HBM/dev={rep.per_device_hbm_bytes/1e9:.2f} GB)")
        print("  memory_analysis:", d["memory_analysis"])
        print("  cost: flops=%.3e bytes=%.3e coll=%.3e" %
              (rep.hlo_flops, rep.hlo_bytes, rep.collective_bytes))
    return d, compiled


def main():
    ap = argparse.ArgumentParser()
    from repro.configs.registry import ALIASES
    ap.add_argument("--arch", choices=sorted(list(ARCH_IDS) + list(ALIASES)))
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="all 40 combos on the single-pod mesh")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--dtype", default="bfloat16")
    ap.add_argument("--reanalyze", action="store_true",
                    help="re-derive JSONs from cached .hlo.gz (no compile)")
    args = ap.parse_args()

    if args.reanalyze:
        from repro.configs import INPUT_SHAPES as SHAPES, get_config as gc
        mesh_tag = "2x16x16" if args.multi_pod else "16x16"
        chips = 512 if args.multi_pod else 256
        n = 0
        for arch in ARCH_IDS:
            for shape_name in SHAPES:
                hlo_path = os.path.join(
                    args.out, f"{arch}__{shape_name}__{mesh_tag}.hlo.gz")
                json_path = os.path.join(
                    args.out, f"{arch}__{shape_name}__{mesh_tag}.json")
                if not os.path.exists(hlo_path):
                    continue
                with gzip.open(hlo_path, "rt") as f:
                    text = f.read()
                cfg = gc(arch).replace(dtype=args.dtype,
                                       param_dtype=args.dtype)
                rep = RA.analyze(None, None, arch=arch,
                                 shape_name=shape_name, mesh_name=mesh_tag,
                                 chips=chips, cfg=cfg,
                                 shape=SHAPES[shape_name], hlo_text=text)
                d = rep.to_dict()
                if os.path.exists(json_path):
                    with open(json_path) as fj:
                        old = json.load(fj)
                    for k in ("lower_s", "compile_s", "mode",
                              "window_override", "memory_analysis"):
                        if k in old:
                            d[k] = old[k]
                    d["per_device_hbm_bytes"] = old.get(
                        "per_device_hbm_bytes", d["per_device_hbm_bytes"])
                with open(json_path, "w") as fj:
                    json.dump(d, fj, indent=2)
                n += 1
        print(f"reanalyzed {n} combos for mesh {mesh_tag}")
        return

    os.makedirs(args.out, exist_ok=True)
    combos = []
    if args.all:
        combos = [(a, s, args.multi_pod) for a in ARCH_IDS
                  for s in INPUT_SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape, args.multi_pod)]

    failures = []
    for arch, shape_name, mp in combos:
        mesh_tag = "2x16x16" if mp else "16x16"
        out_path = os.path.join(
            args.out, f"{arch}__{shape_name}__{mesh_tag}.json")
        hlo_path = os.path.join(
            args.out, f"{arch}__{shape_name}__{mesh_tag}.hlo.gz")
        try:
            d, _ = dryrun_one(arch, shape_name, multi_pod=mp,
                              dtype=args.dtype, hlo_out=hlo_path)
            with open(out_path, "w") as f:
                json.dump(d, f, indent=2)
        except Exception as e:
            failures.append((arch, shape_name, mesh_tag, repr(e)))
            print(f"[dryrun] FAIL {arch} x {shape_name} x {mesh_tag}: {e}")
            traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print(f"\nall {len(combos)} combos lowered+compiled OK")


if __name__ == "__main__":
    main()

"""Batched serving driver: prefill a prompt batch, then greedy-decode with
the production serve_step (KV caches / recurrent state).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_config
from repro.configs.base import ShapeConfig
from repro.data import make_tokens
from repro.distributed import steps as S
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    mesh = make_host_mesh()
    max_len = args.prompt_len + args.gen
    shape = ShapeConfig("serve", max_len, args.batch, "decode")
    fn, in_specs, out_specs, _ = S.build_serve_step(cfg, mesh, shape)

    with mesh:
        params = T.init_lm(key, cfg)
        prompts = make_tokens(key, args.batch, args.prompt_len,
                              cfg.vocab_size)
        enc = None
        if cfg.is_encoder_decoder:
            frames = jax.random.normal(
                key, (args.batch, cfg.n_audio_frames, cfg.d_model))
            enc = T.encode_audio(params, cfg, frames)

        caches = T.init_caches(cfg, args.batch, max_len)
        jstep = jax.jit(fn, donate_argnums=(2,))

        # prefill token-by-token through the serve step (exactly the decode
        # path the dry-run lowers; production prefill uses build_prefill_step)
        t0 = time.time()
        tok = prompts[:, :1]
        out_tokens = [tok]
        for t in range(max_len - 1):
            nxt, caches = jstep(params, tok, caches, jnp.int32(t),
                                *([] if enc is None else [enc]))
            tok = prompts[:, t + 1:t + 2] if t + 1 < args.prompt_len else nxt
            out_tokens.append(tok)
        jax.block_until_ready(tok)
        dt = time.time() - t0
        seqs = jnp.concatenate(out_tokens, axis=1)
        print(f"arch={cfg.name} generated {args.batch}x{args.gen} tokens "
              f"in {dt:.2f}s ({args.batch * max_len / dt:.1f} tok/s)")
        print("first sequence:", seqs[0, :48].tolist())


if __name__ == "__main__":
    main()

"""Async code-server launch entry: scheduler scenarios over the runtime.

Drives the repro.server subsystem end-to-end — pretrain a global DVQ-AE,
replay one (or every) STANDARD_SCENARIOS traffic profile through
``AsyncCodeServer``, then train the multi-task heads from one decode of
the versioned CodeStore. Prints per-scenario rounds/sec, measured uplink
bytes, store/version state and task accuracies.

    PYTHONPATH=src python -m repro.launch.octopus_server \
        [--scenario full|partial|churn|all] [--slots 8] [--rounds 8] \
        [--smoke]

``--smoke`` shrinks every knob to CI scale (a few seconds on CPU).
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.core import octopus as OC
from repro.core.dvqae import DVQAEConfig
from repro.data import make_images, partition_stacked, stacked_batches
from repro.server import (STANDARD_SCENARIOS, AsyncCodeServer,
                          MultiTaskTrainer, RoundScheduler, TaskSpec)
from repro.sim import SimEngine


def run_scenario(name, scenario, *, engine, server, stacked, slots, rounds,
                 local_batch, probe_steps, key, index: int = 0,
                 verbose: bool = True):
    """Drive one traffic scenario through the runtime, then train the
    two standard heads from one store decode. Shared by this CLI and
    ``benchmarks.run::bench_server`` — returns (srv, acc, rounds_per_sec).
    """
    if rounds < 2:
        raise ValueError("need rounds >= 2: round 0 is the compile warmup, "
                         "rounds/sec is timed over the rest")
    sched = RoundScheduler(slots, scenario.sched,
                           key=jax.random.fold_in(key, index))
    srv = AsyncCodeServer(engine, server, sched,
                          merge_every=scenario.merge_every,
                          staleness_decay=0.5)
    t0 = time.time()
    for r, b in zip(range(rounds),
                    stacked_batches(stacked, local_batch, epochs=rounds)):
        if r == 1:
            t0 = time.time()                    # round 0 pays compilation
        srv.run_round(b.x, labels={"content": b.content, "style": b.style})
    rps = (rounds - 1) / max(time.time() - t0, 1e-9)

    feats, labels = srv.dataset()
    tasks = [TaskSpec("content", int(stacked.content.max()) + 1),
             TaskSpec("style", int(stacked.style.max()) + 1)]
    trainer = MultiTaskTrainer(key, tasks, int(feats[0].size))
    trainer.fit(key, feats, labels, steps=probe_steps, batch=64)
    acc = trainer.accuracy(feats, labels)
    if verbose:
        print(f"[{name}] {rps:.2f} rounds/sec | bytes sent={srv.bytes_sent} "
              f"delivered={srv.bytes_delivered} "
              f"dropped={srv.bytes_dropped} | "
              f"store {len(srv.store)} recs v{list(srv.store.versions)} "
              f"({srv.n_merges} merges) | "
              + " ".join(f"{t}={a:.3f}" for t, a in acc.items()))
    return srv, acc, rps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="all",
                    choices=sorted(STANDARD_SCENARIOS) + ["all"])
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--local-batch", type=int, default=8)
    ap.add_argument("--codebook", type=int, default=64)
    ap.add_argument("--probe-steps", type=int, default=150)
    ap.add_argument("--pretrain-steps", type=int, default=80)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.smoke:
        args.slots, args.rounds, args.local_batch = 4, 4, 4
        args.probe_steps, args.pretrain_steps = 20, 20

    key = jax.random.PRNGKey(args.seed)
    cfg = DVQAEConfig(kind="image", in_channels=3, hidden=16, latent_dim=16,
                      codebook_size=args.codebook, n_res_blocks=1)
    data = make_images(key, max(args.slots * args.local_batch * args.rounds,
                                args.slots * 16), size=16, n_identities=4)
    server, out = OC.server_pretrain(key, OC.server_init(key, cfg), cfg,
                                     data.x, steps=args.pretrain_steps)
    if out is not None:
        print(f"pretrain recon loss: {float(out.recon_loss):.4f}")

    stacked = partition_stacked(data, args.slots, regime="skewed", skew=0.2)
    engine = SimEngine(cfg, lr=1e-4, gamma=0.95)
    names = sorted(STANDARD_SCENARIOS) if args.scenario == "all" \
        else [args.scenario]
    for i, name in enumerate(names):
        run_scenario(name, STANDARD_SCENARIOS[name], engine=engine,
                     server=server, stacked=stacked, slots=args.slots,
                     rounds=args.rounds, local_batch=args.local_batch,
                     probe_steps=args.probe_steps, key=key, index=i)


if __name__ == "__main__":
    main()

"""End-to-end training driver.

Runs on whatever devices exist (CPU in this container, TPU mesh in
production — same code path, the mesh adapts). Example:

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --smoke --steps 50 --batch 8 --seq 128

``--smoke`` uses the reduced config; omit it on real hardware to train the
full assigned config.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import restore, save
from repro.configs import INPUT_SHAPES, TrainConfig, get_config, smoke_config
from repro.configs.base import ShapeConfig
from repro.data import make_tokens
from repro.distributed import steps as S
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.optim.adamw import adamw_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    tcfg = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                       warmup_steps=max(1, args.steps // 10))
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    mesh = make_host_mesh()
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"mesh={dict(mesh.shape)}")

    step_fn, in_specs, out_specs, _ = S.build_train_step(cfg, tcfg, mesh,
                                                         shape)
    key = jax.random.PRNGKey(args.seed)
    with mesh:
        params = T.init_lm(key, cfg)
        state = S.TrainState(params=params, opt=adamw_init(params),
                             step=jnp.zeros((), jnp.int32))
        if args.ckpt_dir:
            restored, at = restore(args.ckpt_dir, state)
            if restored is not None:
                state = restored
                print(f"restored checkpoint at step {at}")

        jstep = jax.jit(step_fn, in_shardings=S.shd_to(in_specs, mesh),
                        out_shardings=S.shd_to(out_specs, mesh))

        data_key = jax.random.fold_in(key, 1)
        t0 = time.time()
        for i in range(args.steps):
            tokens = make_tokens(jax.random.fold_in(data_key, i),
                                 args.batch, args.seq, cfg.vocab_size)
            batch = {"tokens": tokens}
            if cfg.is_encoder_decoder:
                batch["frames"] = jax.random.normal(
                    jax.random.fold_in(data_key, 10_000 + i),
                    (args.batch, cfg.n_audio_frames, cfg.d_model),
                    jnp.dtype(cfg.dtype))
            state, loss = jstep(state, batch)
            if i % args.log_every == 0 or i == args.steps - 1:
                jax.block_until_ready(loss)
                dt = time.time() - t0
                tok_s = args.batch * args.seq * (i + 1) / dt
                print(f"step {i:5d} loss {float(loss):.4f} "
                      f"({tok_s:,.0f} tok/s)")
            if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
                save(args.ckpt_dir, i + 1, state,
                     metadata={"arch": cfg.name})
        print(f"done in {time.time()-t0:.1f}s; final loss {float(loss):.4f}")


if __name__ == "__main__":
    main()

"""repro.privacy — the privacy red team (attacks + defenses for §2.5).

The paper ASSERTS that transmitted codes carry no private component;
this package attacks that claim end-to-end and defends the server side:

  audit      the Thm. 1 computational adversary (moved from
             ``repro.core.privacy``): train q(Y|Z), read off H(Y|Z)
             bits and re-identification accuracy
  tap        ``PayloadTap`` — full-payload wire capture under the
             explicit ``$OCTOPUS_REDTEAM`` opt-in (normal traces stay
             metadata-only; the recorder enforces it)
  attacks    membership- and attribute-inference attackers over
             captured ``CodePayload`` streams (1912.04977's open
             problems, §2.5's adversary made concrete)
  sweep      deterministic attack-advantage-vs-knob curves (IN
             strength, K, GSVQ grouping) + the leaky-control teeth
             check -> ``BENCH_privacy.json``
  oblivious  ``ObliviousCodeStore`` — ORAM-style access-pattern hiding
             over the sharded store, bit-exact with the plain store,
             overhead measured OMLO-style

Run ``python -m benchmarks.run --section privacy`` for the sweep, or
``examples/privacy_redteam.py`` for the guided tour.
"""
from .audit import (AdversaryMetrics, adversary_logits, evaluate_adversary,
                    init_adversary, privacy_audit, train_adversary, xent)
from .tap import (ENV_VAR as REDTEAM_ENV_VAR, PayloadTap, RedTeamOptInError,
                  TapRecord, redteam_enabled)
from .attacks import (AttackReport, attribute_inference,
                      membership_inference, payload_histograms,
                      sample_labels, shadow_attack)
from .oblivious import ObliviousCodeStore
from .sweep import (attribute_point, encode_partial, harness_matches_wire,
                    make_codec, membership_point, oblivious_point, run_sweep)

__all__ = [
    "AdversaryMetrics", "adversary_logits", "evaluate_adversary",
    "init_adversary", "privacy_audit", "train_adversary", "xent",
    "REDTEAM_ENV_VAR", "PayloadTap", "RedTeamOptInError", "TapRecord",
    "redteam_enabled",
    "AttackReport", "attribute_inference", "membership_inference",
    "payload_histograms", "sample_labels", "shadow_attack",
    "ObliviousCodeStore",
    "attribute_point", "encode_partial", "harness_matches_wire",
    "make_codec", "membership_point", "oblivious_point", "run_sweep",
]

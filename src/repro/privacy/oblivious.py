"""Oblivious-access mode for the sharded code store (server defense).

1912.04977 §4.2.3 flags SERVER-side access-pattern leakage: even when
payload contents are privatized (§2.5), *which client's codes are
touched when* is itself a side channel — a storage observer watching
partition I/O learns participation schedules and client↔shard bindings.
The classic fix is ORAM-style access-pattern hiding; OMLO-style
evaluations report it as baseline-vs-oblivious overhead on identical
workloads, which is exactly how `BENCH_privacy.json` reports it here.

:class:`ObliviousCodeStore` wraps a
:class:`repro.server.store.ShardedCodeStore` and makes every operation's
*touch sequence* independent of its arguments:

  * every op touches EVERY partition of the live grid exactly once, in
    an order drawn from ``default_rng((seed, op_counter))`` — a schedule
    that is a pure function of (seed, op index, grid size), never of the
    client id, round, shard or payload being handled;
  * real work happens when the schedule reaches the relevant partition;
    every other touch is a dummy access of the same shape (a full
    partition scan for reads, a ledger probe for writes), so the
    observer sees a constant fan of partition touches per op;
  * ``open_version`` pre-creates a version's full shard grid so lazy
    partition creation cannot reveal which shard got first traffic.

Results are BIT-EXACT with the plain store: the plain ``get`` answers
from the minimum (version, shard) partition key holding a match, so the
oblivious scan collects per-partition candidates and answers from the
same minimum key — only the touch ORDER is randomized, never the
answer. Everything else (``dataset``, ``codes``, ledgers, snapshots)
delegates to the wrapped store unchanged; bulk decode already touches
every partition by construction.

The store keeps an ``access_log`` of (op, schedule) pairs and
touched/useful byte counters; :meth:`overhead` summarizes them as the
measured cost of obliviousness (the BENCH row).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.dvqae import DVQAEConfig
from repro.wire.payload import CodePayload, LabelsLike

from repro.server.store import ShardedCodeStore, StoreRecord


class ObliviousCodeStore:
    """Access-pattern-hiding facade over a ``ShardedCodeStore``.

    Same constructor surface as the plain sharded store plus
    ``oblivious_seed`` (the schedule stream — an observer who knows it
    still learns nothing, because schedules never depend on the query;
    it exists so runs are replayable).
    """

    def __init__(self, cfg: DVQAEConfig, *, n_shards: int = 4,
                 capacity_samples: Optional[int] = None,
                 policy: str = "fifo", seed: int = 0, shard_fn=None,
                 oblivious_seed: int = 0):
        self.inner = ShardedCodeStore(
            cfg, n_shards=n_shards, capacity_samples=capacity_samples,
            policy=policy, seed=seed, shard_fn=shard_fn)
        self.oblivious_seed = int(oblivious_seed)
        self._op_counter = 0
        #: (op name, partition-key schedule) per operation, for audit
        self.access_log: List[Tuple[str, Tuple[Tuple[int, int], ...]]] = []
        self.touched_partitions = 0
        self.useful_partitions = 0
        self.touched_bytes = 0
        self.useful_bytes = 0

    # ------------------------------------------------------------ schedule

    def open_version(self, version: int) -> None:
        """Pre-create the FULL shard grid for ``version`` so partition
        creation happens at version-open time (public knowledge — the
        registry announces versions) rather than on first traffic."""
        for s in range(self.inner.n_shards):
            self.inner.partition(int(version), s)

    def _schedule(self, op: str) -> List[Tuple[int, int]]:
        """All live partition keys, in an order drawn purely from
        (oblivious_seed, op counter) — provably query-independent."""
        keys = sorted(self.inner.partitions)
        rng = np.random.default_rng((self.oblivious_seed,
                                     self._op_counter))
        order = [keys[i] for i in rng.permutation(len(keys))]
        self._op_counter += 1
        self.access_log.append((op, tuple(order)))
        return order

    def _touch(self, key: Tuple[int, int], *, useful: bool) -> None:
        part = self.inner.partitions[key]
        self.touched_partitions += 1
        self.touched_bytes += part.total_bytes
        if useful:
            self.useful_partitions += 1
            self.useful_bytes += part.total_bytes

    # ----------------------------------------------------------------- add

    def add(self, packed: CodePayload, *, client_ids=None, round: int = 0,
            version: Optional[int] = None, labels: LabelsLike = None
            ) -> StoreRecord:
        """Ingest one payload obliviously: the full grid is touched in
        schedule order; the record lands in its real partition when the
        schedule reaches it, every other touch is a same-shape dummy
        (ledger probe). The stored result is identical to the plain
        store's — dummy touches mutate nothing."""
        if version is None:
            version = int(getattr(packed, "version", 0))
        self.open_version(version)
        shard = self.inner.shard_of(client_ids)
        target = (int(version), int(shard))
        rec: Optional[StoreRecord] = None
        for key in self._schedule("add"):
            self._touch(key, useful=key == target)
            if key == target:
                rec = self.inner.partition(*key).add(
                    packed, client_ids=client_ids, round=round,
                    version=version, labels=labels)
            else:
                # dummy write: probe the partition's ledger so the touch
                # has the same read shape as a real admission check
                _ = self.inner.partitions[key].n_samples
        self.inner._set_gauges()
        assert rec is not None
        return rec

    # ----------------------------------------------------------------- get

    def get(self, client_id: int, round: int):
        """Decode one client's codes without revealing which partition
        held them: EVERY partition is fully scanned in schedule order,
        hits are collected, and the answer is the hit from the minimum
        partition key — exactly what the plain store's sorted-order
        first-match scan returns."""
        hits: Dict[Tuple[int, int], tuple] = {}
        for key in self._schedule("get"):
            part = self.inner.partitions[key]
            try:
                found = part.get(client_id, round)
            except KeyError:
                found = None
            if found is not None:
                hits[key] = found
            self._touch(key, useful=found is not None)
        if not hits:
            raise KeyError((client_id, round))
        return hits[min(hits)]

    # ------------------------------------------------------------ overhead

    def overhead(self) -> Dict[str, float]:
        """Measured cost of obliviousness on the workload so far
        (OMLO-style baseline-vs-oblivious accounting): a plain store
        touches only the useful partitions/bytes, this one touches them
        all — the ratios ARE the overhead factor."""
        return {
            "ops": float(self._op_counter),
            "touched_partitions": float(self.touched_partitions),
            "useful_partitions": float(self.useful_partitions),
            "partition_touch_ratio": self.touched_partitions
            / max(1, self.useful_partitions),
            "touched_bytes": float(self.touched_bytes),
            "useful_bytes": float(self.useful_bytes),
            "byte_touch_ratio": self.touched_bytes
            / max(1, self.useful_bytes),
        }

    # --------------------------------------------------------- delegation

    def __len__(self) -> int:
        return len(self.inner)

    def __getattr__(self, name):
        # everything not overridden (dataset, codes, labels, ledgers,
        # snapshot/load, retire_version, partitions, ...) behaves exactly
        # as the wrapped store — bulk paths already touch every partition
        return getattr(self.__dict__["inner"], name)

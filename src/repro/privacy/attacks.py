"""Inference attacks on captured code streams (red team for §2.5).

"Advances and Open Problems in Federated Learning" (1912.04977) names
inference attacks on transmitted updates as a first-class open problem;
OCTOPUS's §2.5 claim is that its transmitted payloads don't give such an
attacker anything. These attackers test that claim from the attacker's
actual vantage point: NOT decoded latents (the ``privacy_audit`` view),
but the packed :class:`~repro.wire.CodePayload` streams a
:class:`~repro.privacy.tap.PayloadTap` records off the wire.

Both attacks are shadow-classifier attacks over per-sample code
histograms (order-free code usage — the strongest simple statistic of a
discrete stream):

  * ATTRIBUTE inference — predict a sensitive attribute (style /
    speaker / identity) of the sample behind a captured payload. The
    §2.5 mechanism under test is IN: a per-instance channel shift is
    exactly the style carrier Eq. 4 strips, so a privatized stream must
    score at chance while the leaky control (IN off) must not.
  * MEMBERSHIP inference — client-level membership under non-iid data:
    decide whether a captured payload came from a client whose traffic
    the attacker observed before (each client carries a persistent
    latent signature — the per-client shift — so re-identifying the
    signature IS membership, the 1912.04977 framing for non-iid
    populations).

``advantage = accuracy - chance`` where chance is the majority-class
rate of the held-out split (the no-information baseline), so "at
chance" means advantage ≈ 0 regardless of class balance. Every report
is deterministic in the provided PRNG key. With a flight recorder
installed, each attack emits an ``attack`` event (scalar results only).
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import recorder as _obs

from .audit import evaluate_adversary, train_adversary
from .tap import PayloadTap, TapRecord


class AttackReport(NamedTuple):
    """One attack's scorecard on a held-out split."""
    attack: str           # "attribute" | "membership" | caller-chosen
    accuracy: float       # held-out attack accuracy
    chance: float         # majority-class rate of the held-out split
    advantage: float      # accuracy - chance (≈0 == the attack failed)
    conditional_entropy_bits: float   # Thm. 1 H(Y|Z) estimate
    n_train: int
    n_test: int
    n_classes: int


def _records(source: Union[PayloadTap, Sequence[TapRecord]]
             ) -> List[TapRecord]:
    recs = list(source.records if isinstance(source, PayloadTap)
                else source)
    if not recs:
        raise ValueError("no captured payloads to attack")
    return recs


def payload_histograms(payloads, n_atoms: int) -> np.ndarray:
    """Captured payloads -> (N_samples, n_atoms) code-usage histograms.

    Each payload unpacks to (C, B, T[, S]) indices; every (client,
    sample) row becomes one normalized histogram over the transmitted
    alphabet. Works unchanged for GSVQ streams (alphabet = n_groups,
    n_slices codes per position) — the attacker needs only the alphabet
    size, which is wire metadata (``bits``).
    """
    rows = []
    for p in payloads:
        idx = np.asarray(p.unpack())
        flat = idx.reshape(idx.shape[0] * idx.shape[1], -1)
        onehot = flat[..., None] == np.arange(n_atoms)[None, None, :]
        rows.append(onehot.sum(axis=1) / flat.shape[1])
    return np.concatenate(rows, axis=0).astype(np.float32)


def sample_labels(records: Sequence[TapRecord], key: str) -> np.ndarray:
    """Per-SAMPLE int labels from per-record tap meta: a record's meta
    value may be a scalar (all its samples share it — the per-client
    case) or an array of one label per sample."""
    parts = []
    for r in records:
        n = int(r.payload.shape[0]) * int(r.payload.shape[1])
        v = r.meta.get(key)
        if v is None:
            raise KeyError(f"tap record lacks meta[{key!r}]")
        arr = np.asarray(v).reshape(-1)
        if arr.size == 1:
            arr = np.full((n,), int(arr[0]))
        if arr.size != n:
            raise ValueError(f"meta[{key!r}] has {arr.size} labels for "
                             f"{n} samples")
        parts.append(arr.astype(np.int32))
    return np.concatenate(parts, axis=0)


def shadow_attack(key, features, labels, n_classes: int, *,
                  attack: str = "attribute", steps: int = 200,
                  train_frac: float = 0.8,
                  test_features=None, test_labels=None) -> AttackReport:
    """Train the Thm. 1 probe as a shadow classifier and score it.

    Default: permute with ``key`` and split ``train_frac``/rest (the
    audit idiom — captured streams arrive client-sorted). Passing
    ``test_features``/``test_labels`` overrides the split with a
    disjoint evaluation capture (the membership setting, where train and
    test come from different rounds).
    """
    feats = jnp.asarray(features)
    y = jnp.asarray(labels).astype(jnp.int32)
    kp, kt = jax.random.split(key)
    if test_features is None:
        n = int(y.shape[0])
        perm = jax.random.permutation(kp, n)
        feats, y = feats[perm], y[perm]
        split = int(train_frac * n)
        tr_f, tr_y = feats[:split], y[:split]
        te_f, te_y = feats[split:], y[split:]
    else:
        tr_f, tr_y = feats, y
        te_f = jnp.asarray(test_features)
        te_y = jnp.asarray(test_labels).astype(jnp.int32)
    params = train_adversary(kt, tr_f, tr_y, n_classes, steps=steps)
    m = evaluate_adversary(params, te_f, te_y, n_classes)
    counts = np.bincount(np.asarray(te_y), minlength=n_classes)
    chance = float(counts.max() / max(1, counts.sum()))
    report = AttackReport(
        attack=attack, accuracy=m.accuracy, chance=chance,
        advantage=m.accuracy - chance,
        conditional_entropy_bits=m.conditional_entropy_bits,
        n_train=int(tr_y.shape[0]), n_test=int(te_y.shape[0]),
        n_classes=int(n_classes))
    rec = _obs.active()
    if rec is not None:
        rec.event("attack", attack=report.attack,
                  accuracy=report.accuracy, chance=report.chance,
                  advantage=report.advantage,
                  n_train=report.n_train, n_test=report.n_test,
                  n_classes=report.n_classes)
        rec.metrics.observe(f"attack_advantage/{report.attack}",
                            report.advantage)
    return report


def attribute_inference(key, source: Union[PayloadTap, Sequence[TapRecord]],
                        *, attribute: str, n_classes: int, n_atoms: int,
                        steps: int = 200) -> AttackReport:
    """Predict a sensitive per-sample attribute from captured payloads."""
    recs = _records(source)
    feats = payload_histograms([r.payload for r in recs], n_atoms)
    y = sample_labels(recs, attribute)
    return shadow_attack(key, feats, y, n_classes,
                         attack=f"attribute:{attribute}", steps=steps)


def membership_inference(key,
                         train: Union[PayloadTap, Sequence[TapRecord]],
                         test: Union[PayloadTap, Sequence[TapRecord]], *,
                         n_atoms: int, flag: str = "member",
                         steps: int = 200) -> AttackReport:
    """Decide whether a captured payload's client was previously
    observed. ``train`` is the attacker's shadow capture (its own
    member/non-member ground truth in ``meta[flag]``); ``test`` is a
    later, disjoint capture of the same population plus fresh clients.
    """
    tr = _records(train)
    te = _records(test)
    tr_f = payload_histograms([r.payload for r in tr], n_atoms)
    te_f = payload_histograms([r.payload for r in te], n_atoms)
    return shadow_attack(key, tr_f, sample_labels(tr, flag), 2,
                         attack="membership", steps=steps,
                         test_features=te_f,
                         test_labels=sample_labels(te, flag))

"""Deterministic leakage-vs-knob sweep: the `BENCH_privacy.json` rows.

Attack-advantage curves over the three §2.5-relevant knobs —
disentanglement strength, codebook size K, GSVQ grouping — plus the
oblivious-store overhead row, all on the PR-5 linear (``sequence``)
codec from ``test_wire.py``. That codec is the PROVABLY-leaky control:
with IN off, a per-instance channel shift (the style carrier Eq. 4
exists to strip) flows straight through the linear encoder into the
code stream, so the attribute attacker MUST score above chance there —
if it doesn't, the harness is broken, not the defense.

Everything is deterministic: population draws come from
``np.random.default_rng(seed)``, attacks from the provided JAX key, and
the oblivious store's schedules from its own seed — re-running a sweep
reproduces every row bit-for-bit.

Two encode paths feed the tap:

  * the FACADE path (``OctopusClient.transmit`` — the fused production
    wire) for the headline leaky-vs-privatized rows;
  * a partial-IN HARNESS encoder for the knob curves:
    ``z_s = (1-s)·z + s·IN(z)`` lets strength ``s`` move continuously,
    and at the endpoints (s=0, s=1) it is BIT-IDENTICAL to the facade
    with ``apply_in`` off/on — asserted every sweep as the
    ``harness_matches_wire`` row, so the curves are anchored to the
    real wire, not a look-alike.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import octopus as OC
from repro.core.disentangle import instance_norm_latent
from repro.core.dvqae import DVQAEConfig, init_dvqae
from repro.core.gsvq import gsvq_quantize
from repro.core.vq import quantize
from repro.optim.adamw import adamw_init
from repro.server.store import ShardedCodeStore
from repro.wire.payload import CodePayload
from repro.wire.session import OctopusServer

from .attacks import AttackReport, attribute_inference, membership_inference
from .oblivious import ObliviousCodeStore
from .tap import PayloadTap

#: the PR-5 linear codec's dimensions (test_wire.py's privacy regression)
D_MODEL = 12
M_LATENT = 8
T_SEQ = 10
N_CONTENT = 4
N_STYLES = 4
SHIFT_SCALE = 2.0      # style shift magnitude — IN-strippable by design


def make_codec(seed: int, *, K: int = 32, apply_in: bool = True,
               n_groups: int = 1, n_slices: int = 1):
    """(cfg, params, facade server) for one knob point. Params depend
    only on ``seed`` and the shape knobs, never on ``apply_in`` — the
    leaky and privatized variants share the exact same codec weights."""
    cfg = DVQAEConfig(kind="sequence", latent_dim=M_LATENT,
                      codebook_size=K, apply_in=apply_in,
                      n_groups=n_groups, n_slices=n_slices)
    params = init_dvqae(jax.random.PRNGKey(seed), cfg, d_model=D_MODEL)
    state = OC.ServerState(params=params, opt=adamw_init(params),
                           step=jnp.zeros((), jnp.int32))
    return cfg, params, OctopusServer(state, cfg)


def n_atoms(cfg: DVQAEConfig) -> int:
    """The transmitted alphabet the attacker histograms over."""
    if cfg.n_groups > 1 or cfg.n_slices > 1:
        return cfg.n_groups
    return cfg.codebook_size


def client_batch(rng, protos, shift, batch: int, noise: float = 0.05):
    """One client's local batch: time-varying content prototypes (IN
    cannot strip those) + a constant-over-T channel shift (IN strips
    exactly those) + sample noise."""
    content = rng.integers(0, protos.shape[0], size=batch)
    x = protos[content] + noise * rng.normal(
        size=(batch,) + protos.shape[1:])
    x = x + shift[None, None, :]
    return jnp.asarray(x, jnp.float32), content


def encode_partial(params, cfg: DVQAEConfig, x, strength: float
                   ) -> CodePayload:
    """Harness encoder with a CONTINUOUS disentanglement-strength knob.

    ``strength=0`` transmits VQ(z) (the leaky control), ``strength=1``
    transmits VQ(IN(z)) — both bit-identical to the facade wire with
    ``apply_in`` off/on (see :func:`harness_matches_wire`); intermediate
    values interpolate the pre-VQ latent, sweeping how much style
    survives quantization.
    """
    z = x @ params["encoder"]["proj"]
    s = float(strength)
    z_s = (1.0 - s) * z + s * instance_norm_latent(z)
    if cfg.n_groups > 1 or cfg.n_slices > 1:
        idx = gsvq_quantize(z_s, params["codebook"], n_groups=cfg.n_groups,
                            n_slices=cfg.n_slices).indices
    else:
        idx = quantize(z_s, params["codebook"]).indices
    return CodePayload.pack(idx[None], bits=OC.transmit_bits(cfg))


def harness_matches_wire(seed: int = 0, batch: int = 32) -> bool:
    """Anchor the harness to the production wire: at both endpoints the
    packed WORDS must equal a real ``OctopusClient.transmit``'s."""
    rng = np.random.default_rng(seed)
    protos = rng.normal(size=(N_CONTENT, T_SEQ, D_MODEL))
    shift = rng.normal(size=(D_MODEL,)) * SHIFT_SCALE
    x, _ = client_batch(rng, protos, shift, batch)
    ok = True
    for s, apply_in in ((0.0, False), (1.0, True)):
        cfg, params, srv = make_codec(seed, apply_in=apply_in)
        wire = srv.deploy().transmit(x)
        harness = encode_partial(params, cfg, x, s)
        ok = ok and np.array_equal(np.asarray(wire.payload),
                                   np.asarray(harness.payload))
    return ok


# ------------------------------------------------------------ attack points

def capture_population(params, cfg: DVQAEConfig, *, strength: float,
                       n_clients: int, batch: int, seed: int,
                       encode=None) -> PayloadTap:
    """Tap one round of a styled population: client ``c`` carries style
    ``c % N_STYLES``; the tap's meta holds the attacker-side ground
    truth. ``encode(x) -> CodePayload`` overrides the harness encoder
    (the facade rows pass a real client's ``transmit``)."""
    rng = np.random.default_rng(seed)
    protos = rng.normal(size=(N_CONTENT, T_SEQ, D_MODEL))
    shifts = rng.normal(size=(N_STYLES, D_MODEL)) * SHIFT_SCALE
    tap = PayloadTap(allow=True)
    for c in range(n_clients):
        sty = c % N_STYLES
        x, _ = client_batch(rng, protos, shifts[sty], batch)
        p = encode(x) if encode is not None else \
            encode_partial(params, cfg, x, strength)
        tap.capture(p, client=c, style=sty)
    return tap


def attribute_point(key, *, seed: int, K: int = 32, n_groups: int = 1,
                    n_slices: int = 1, strength: float = 1.0,
                    n_clients: int = 8, batch: int = 40,
                    steps: int = 150) -> AttackReport:
    """One knob point: build codec, capture a round, run the attribute
    attacker. Fully determined by (key, seed, knobs)."""
    cfg, params, _ = make_codec(seed, K=K, n_groups=n_groups,
                                n_slices=n_slices)
    tap = capture_population(params, cfg, strength=strength,
                             n_clients=n_clients, batch=batch,
                             seed=seed + 17)
    return attribute_inference(key, tap, attribute="style",
                               n_classes=N_STYLES, n_atoms=n_atoms(cfg),
                               steps=steps)


def membership_point(key, *, seed: int, strength: float,
                     n_members: int = 4, n_shadow: int = 12,
                     n_holdout: int = 8, batch: int = 24,
                     steps: int = 150) -> AttackReport:
    """One membership point: members carry persistent per-client
    signatures across rounds; the attacker trains on a round-1 capture
    of members + shadow non-members, and is tested on a LATER round of
    the members (fresh content, same signatures) plus never-seen
    holdout clients."""
    cfg, params, _ = make_codec(seed, K=32)
    rng = np.random.default_rng(seed + 53)
    protos = rng.normal(size=(N_CONTENT, T_SEQ, D_MODEL))
    member_sig = rng.normal(size=(n_members, D_MODEL)) * SHIFT_SCALE
    shadow_sig = rng.normal(size=(n_shadow, D_MODEL)) * SHIFT_SCALE
    holdout_sig = rng.normal(size=(n_holdout, D_MODEL)) * SHIFT_SCALE

    def rounds(tap, sigs, member):
        for i in range(sigs.shape[0]):
            x, _ = client_batch(rng, protos, sigs[i], batch)
            tap.capture(encode_partial(params, cfg, x, strength),
                        member=member)

    train = PayloadTap(allow=True)
    rounds(train, member_sig, 1)
    rounds(train, shadow_sig, 0)
    test = PayloadTap(allow=True)
    rounds(test, member_sig, 1)       # round 2: same members, new content
    rounds(test, holdout_sig, 0)      # fresh clients the attacker never saw
    return membership_inference(key, train, test, n_atoms=n_atoms(cfg),
                                steps=steps)


# --------------------------------------------------------- oblivious point

def oblivious_point(*, seed: int, n_clients: int = 8, rounds: int = 2,
                    batch: int = 16, n_shards: int = 4) -> Dict[str, float]:
    """OMLO-style baseline-vs-oblivious measurement on one identical
    workload: same ingest stream and same (client, round) query set
    against a plain ``ShardedCodeStore`` and an
    :class:`ObliviousCodeStore`; parity is checked bit-for-bit."""
    cfg, params, _ = make_codec(seed, K=32)
    rng = np.random.default_rng(seed + 99)
    protos = rng.normal(size=(N_CONTENT, T_SEQ, D_MODEL))
    sigs = rng.normal(size=(n_clients, D_MODEL)) * SHIFT_SCALE
    plain = ShardedCodeStore(cfg, n_shards=n_shards, seed=seed)
    obl = ObliviousCodeStore(cfg, n_shards=n_shards, seed=seed,
                             oblivious_seed=7)
    for r in range(rounds):
        for c in range(n_clients):
            x, _ = client_batch(rng, protos, sigs[c], batch)
            p = encode_partial(params, cfg, x, 1.0)
            plain.add(p, client_ids=[c], round=r)
            obl.add(p, client_ids=[c], round=r)
    queries = [(c, r) for r in range(rounds) for c in range(n_clients)]
    # warm both paths (unpack dispatch compilation) before timing
    plain.get(*queries[0]), obl.get(*queries[0])
    t0 = time.perf_counter()
    got_plain = [plain.get(c, r) for c, r in queries]
    t_plain = time.perf_counter() - t0
    t0 = time.perf_counter()
    got_obl = [obl.get(c, r) for c, r in queries]
    t_obl = time.perf_counter() - t0
    parity = np.array_equal(np.asarray(plain.codes()),
                            np.asarray(obl.codes()))
    for (ia, va), (ib, vb) in zip(got_plain, got_obl):
        parity = parity and va == vb and np.array_equal(np.asarray(ia),
                                                        np.asarray(ib))
    oh = obl.overhead()
    oh.update(parity_bitexact=float(parity),
              get_wall_ratio=t_obl / max(t_plain, 1e-9),
              n_queries=float(len(queries)))
    return oh


# ---------------------------------------------------------------- the sweep

def run_sweep(key, *, quick: bool = False, seed: int = 0
              ) -> List[Dict[str, object]]:
    """All `BENCH_privacy.json` rows: headline facade rows, the three
    knob curves, membership, and the oblivious-store overheads. Returns
    ``[{"name", "value", "extra"}, ...]`` for ``benchmarks.run`` to emit.
    """
    steps = 80 if quick else 150
    batch = 24 if quick else 40
    n_clients = 8
    rows: List[Dict[str, object]] = []

    def row(name, value, **extra):
        rows.append({"name": name, "value": float(value), "extra": extra})

    def attack_rows(name, rep: AttackReport, **extra):
        row(name, rep.advantage, accuracy=rep.accuracy, chance=rep.chance,
            h_bits=rep.conditional_entropy_bits, n_test=rep.n_test, **extra)

    # anchor: the harness encoder IS the wire at both endpoints
    row("harness_matches_wire", 1.0 if harness_matches_wire(seed) else 0.0)

    # headline: the REAL fused wire path, leaky control vs privatized.
    # The leaky row is the teeth check — the linear codec with IN off
    # provably forwards the style shift, so advantage must clear chance.
    ks = iter(jax.random.split(key, 64))
    for name, apply_in in (("leaky_control", False), ("privatized", True)):
        cfg, params, srv = make_codec(seed, K=32, apply_in=apply_in)
        tap = capture_population(
            params, cfg, strength=1.0, n_clients=n_clients, batch=batch,
            seed=seed + 17, encode=lambda x: srv.deploy().transmit(x))
        rep = attribute_inference(next(ks), tap, attribute="style",
                                  n_classes=N_STYLES,
                                  n_atoms=n_atoms(cfg), steps=steps)
        attack_rows(f"{name}_advantage", rep, knob="facade",
                    apply_in=apply_in, captured_bytes=tap.nbytes)

    # knob 1: disentanglement strength s in [0, 1]
    strengths = (0.0, 0.5, 1.0) if quick else (0.0, 0.25, 0.5, 0.75, 1.0)
    for s in strengths:
        rep = attribute_point(next(ks), seed=seed, strength=s,
                              n_clients=n_clients, batch=batch, steps=steps)
        attack_rows(f"attr_advantage/disent_s{s:.2f}", rep,
                    knob="disentanglement_strength", strength=s)

    # knob 2: codebook size K (leaky + privatized at each point)
    for K in ((16, 64) if quick else (16, 64, 256)):
        for tag, s in (("leaky", 0.0), ("priv", 1.0)):
            rep = attribute_point(next(ks), seed=seed, K=K, strength=s,
                                  n_clients=n_clients, batch=batch,
                                  steps=steps)
            attack_rows(f"attr_advantage/K{K}_{tag}", rep,
                        knob="codebook_size", K=K, strength=s)

    # knob 3: GSVQ grouping (G groups x S slices)
    gsvq = ((2, 1), (4, 2)) if quick else ((2, 1), (4, 1), (4, 2))
    for G, S in gsvq:
        for tag, s in (("leaky", 0.0), ("priv", 1.0)):
            rep = attribute_point(next(ks), seed=seed, n_groups=G,
                                  n_slices=S, strength=s,
                                  n_clients=n_clients, batch=batch,
                                  steps=steps)
            attack_rows(f"attr_advantage/gsvq_g{G}s{S}_{tag}", rep,
                        knob="gsvq_grouping", n_groups=G, n_slices=S,
                        strength=s)

    # membership (client re-identification), leaky vs privatized
    mem_kw = dict(n_members=3, n_shadow=8, n_holdout=5, batch=16) if quick \
        else dict(n_members=4, n_shadow=12, n_holdout=8, batch=24)
    for tag, s in (("leaky", 0.0), ("privatized", 1.0)):
        rep = membership_point(next(ks), seed=seed, strength=s,
                               steps=steps, **mem_kw)
        attack_rows(f"membership_{tag}_advantage", rep, knob="membership",
                    strength=s, **mem_kw)

    # oblivious store: bit-exact parity + measured overhead
    oh = oblivious_point(seed=seed, batch=8 if quick else 16)
    row("oblivious_parity_bitexact", oh["parity_bitexact"])
    row("oblivious_touch_ratio", oh["partition_touch_ratio"],
        byte_touch_ratio=oh["byte_touch_ratio"], ops=oh["ops"])
    row("oblivious_get_overhead", oh["get_wall_ratio"],
        n_queries=oh["n_queries"],
        touched_bytes=oh["touched_bytes"],
        useful_bytes=oh["useful_bytes"])
    return rows

"""PayloadTap: the red team's wire capture plane (FULL packed words).

The :class:`repro.obs.FlightRecorder` is metadata-only by design — §2.5
forbids words, labels or latents in a normal trace, and the recorder now
rejects array-shaped event fields outright. An inference attacker does
not play by that rule: it records every :class:`repro.wire.CodePayload`
that crosses the wire, packed words and all, and trains shadow
classifiers on the captured stream (see :mod:`repro.privacy.attacks`).

The tap is therefore a SEPARATE plane with an explicit opt-in: creating
one raises :class:`RedTeamOptInError` unless ``$OCTOPUS_REDTEAM`` is set
(or ``allow=True`` is passed by code that has already made the decision,
e.g. a test). Nothing in the pipeline constructs a tap implicitly, so
the metadata-only invariant of normal traces stays pinned — when a tap
IS active it announces itself with ``tap`` events that carry payload
metadata only, never the captured words.

Two ways to capture:

  * explicitly — ``tap.capture(payload, style=..., member=...)`` records
    the payload plus attacker-side ground truth (the labels a shadow
    population owner knows about its own traffic);
  * as a wiretap channel — ``PayloadTap(target=service)`` duck-types the
    continuous ``offer``/``tick``/``drain`` surface (same trick as
    ``sim.faults.FaultyChannel``), so any producer that can drive a
    ``ContinuousIngestService`` can be observed unmodified.
"""
from __future__ import annotations

import os
from typing import Any, Dict, List, NamedTuple, Optional

import numpy as np

from repro.obs import recorder as _obs
from repro.wire.payload import CodePayload

#: the explicit opt-in gate: set to 1/true/yes/on to allow payload taps
ENV_VAR = "OCTOPUS_REDTEAM"


class RedTeamOptInError(RuntimeError):
    """Raised when a PayloadTap is constructed without the explicit
    ``$OCTOPUS_REDTEAM`` opt-in — full-payload capture is never ambient."""


def redteam_enabled() -> bool:
    """True iff the process opted into red-team capture via the env."""
    return os.environ.get(ENV_VAR, "").strip().lower() in (
        "1", "true", "yes", "on")


class TapRecord(NamedTuple):
    """One captured uplink: the FULL payload + attacker-side context."""
    payload: CodePayload
    meta: Dict[str, Any]


class PayloadTap:
    """Records full payloads from the wire, under explicit opt-in.

    ``meta`` passed to :meth:`capture` is the attacker's OWN bookkeeping
    (shadow-population ground truth: style/client/membership labels) —
    it never touches the payload or the trace. With a flight recorder
    installed, each capture emits a ``tap`` event holding the §2.5
    payload METADATA only, so a trace shows *that* an adversary recorded
    the wire without the trace itself leaking what was recorded.
    """

    def __init__(self, *, allow: bool = False, target=None):
        if not (allow or redteam_enabled()):
            raise RedTeamOptInError(
                f"PayloadTap records FULL packed words off the wire; set "
                f"{ENV_VAR}=1 (or pass allow=True) to opt into red-team "
                f"capture — normal traces stay metadata-only (§2.5)")
        self.target = target
        self.records: List[TapRecord] = []

    # -------------------------------------------------------------- capture

    def capture(self, payload: CodePayload, **meta) -> CodePayload:
        """Record one payload (+ attacker ground truth); returns it so
        call sites can tap inline: ``srv.ingest(tap.capture(p))``."""
        self.records.append(TapRecord(payload=payload, meta=dict(meta)))
        rec = _obs.active()
        if rec is not None:
            rec.metrics.inc("tapped_payloads")
            rec.metrics.inc("tapped_bytes", payload.nbytes)
            rec.event("tap", n_captured=len(self.records),
                      **_obs.payload_meta(payload))
        return payload

    # ------------------------------------------- wiretap channel duck-typing

    def offer(self, payload, **kw):
        """Capture, then forward to the tapped service's admission door
        (requires ``target``). Client ids riding in the offer are wire
        metadata an on-path adversary sees anyway — they go in the
        capture's meta."""
        if self.target is None:
            raise ValueError("PayloadTap.offer needs a target service — "
                             "construct PayloadTap(target=service)")
        ids = kw.get("client_ids")
        self.capture(payload,
                     client_ids=None if ids is None else list(np.asarray(
                         ids).reshape(-1).tolist()),
                     uplink_id=kw.get("uplink_id"))
        return self.target.offer(payload, **kw)

    def tick(self, *a, **kw):
        return self.target.tick(*a, **kw)

    def drain(self, *a, **kw):
        return self.target.drain(*a, **kw)

    def __getattr__(self, name):
        if self.__dict__.get("target") is None:
            raise AttributeError(name)
        return getattr(self.target, name)

    # ------------------------------------------------------------- captured

    def __len__(self) -> int:
        return len(self.records)

    @property
    def payloads(self) -> List[CodePayload]:
        return [r.payload for r in self.records]

    @property
    def nbytes(self) -> int:
        """Measured bytes the adversary captured (§2.8 accounting)."""
        return sum(r.payload.nbytes for r in self.records)

    def metas(self, key: str) -> List[Any]:
        """One meta value per captured record (missing -> None)."""
        return [r.meta.get(key) for r in self.records]

    def codes(self) -> np.ndarray:
        """All captured code indices, unpacked -> (N_samples, T...) —
        the raw material the shadow classifiers train on."""
        parts = []
        for r in self.records:
            idx = np.asarray(r.payload.unpack())
            parts.append(idx.reshape((-1,) + idx.shape[2:]))
        if not parts:
            raise ValueError("empty tap")
        return np.concatenate(parts, axis=0)

"""Privacy evaluation: the computational adversary (§2.7.2, Theorem 1).

A neural classifier q(Y | Z) is trained post-hoc on released components;
its test cross-entropy is the (upper-bound estimate of) conditional
entropy H(Y | Z) in bits, and its test accuracy is the re-identification
rate. The adversary is NEVER part of OCTOPUS training — evaluation only.

This toolkit moved here from ``repro.core.privacy`` when the red-team
subsystem landed: the Thm. 1 probe is the shared classifier core behind
both the paired :func:`privacy_audit` (public Z• vs private Z∘) and the
wire-level inference attacks in :mod:`repro.privacy.attacks`, which
train the SAME probe on captured :class:`~repro.wire.CodePayload`
streams instead of decoded latents.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.nn.layers import dense_init
from repro.optim.adamw import adamw_init, adamw_update


class AdversaryMetrics(NamedTuple):
    accuracy: float                 # re-identification accuracy
    conditional_entropy_bits: float  # H(Y|Z) estimate via Thm. 1
    loss: float


def init_adversary(key, in_dim: int, n_classes: int, hidden: int = 256):
    """3-layer MLP probe (paper: 3 Conv1d + FC; features are already latent
    vectors here, so dense layers are the equivalent probe capacity)."""
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": dense_init(k1, in_dim, hidden), "b1": jnp.zeros((hidden,)),
        "w2": dense_init(k2, hidden, hidden), "b2": jnp.zeros((hidden,)),
        "w3": dense_init(k3, hidden, n_classes), "b3": jnp.zeros((n_classes,)),
    }


def adversary_logits(params, z):
    h = jax.nn.relu(z @ params["w1"] + params["b1"])
    h = jax.nn.relu(h @ params["w2"] + params["b2"])
    return h @ params["w3"] + params["b3"]


def xent(params, z, y):
    logits = adversary_logits(params, z)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def _flatten_features(z):
    return z.reshape(z.shape[0], -1).astype(jnp.float32)


def train_adversary(key, features, labels, n_classes: int, *,
                    steps: int = 300, lr: float = 1e-3, batch: int = 256):
    """Fit q(Y|Z) by SGD on cross-entropy (the Thm. 1 bound minimizer)."""
    z = _flatten_features(features)
    params = init_adversary(key, z.shape[-1], n_classes)
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt, zb, yb):
        g = jax.grad(xent)(params, zb, yb)
        return adamw_update(params, g, opt, lr=lr)

    n = z.shape[0]
    for i in range(steps):
        k = jax.random.fold_in(key, i)
        sel = jax.random.randint(k, (min(batch, n),), 0, n)
        params, opt = step(params, opt, z[sel], labels[sel])
    return params


def evaluate_adversary(params, features, labels, n_classes: int
                       ) -> AdversaryMetrics:
    """Test-set CE -> conditional entropy in bits (Thm. 1); accuracy."""
    z = _flatten_features(features)
    logits = adversary_logits(params, z)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return AdversaryMetrics(accuracy=float(acc),
                            conditional_entropy_bits=float(nll) / math.log(2),
                            loss=float(nll))


def privacy_audit(key, public_feats, private_feats, labels, n_classes: int,
                  steps: int = 300) -> Tuple[AdversaryMetrics, AdversaryMetrics]:
    """Paired audit: adversary on Z• (want: high H, low acc) vs on Z∘
    (expected: low H, high acc — the style really is there).

    Samples are permuted with the provided key before the 80/20 split:
    OCTOPUS features typically arrive label-sorted (the non-iid
    partitions of data.federated concatenate per-class shards), and an
    unshuffled head/tail split would evaluate the adversary on classes it
    never saw — degenerating the H(Y|Z) bound instead of measuring leakage.
    """
    n = labels.shape[0]
    # private component broadcasts over positions; tile to sample count
    pf = jnp.broadcast_to(private_feats,
                          (n,) + private_feats.shape[1:]) \
        if private_feats.shape[0] != n else private_feats
    kp, k1, k2 = jax.random.split(key, 3)
    perm = jax.random.permutation(kp, n)
    public_feats, pf, labels = public_feats[perm], pf[perm], labels[perm]
    split = int(0.8 * n)
    pub = train_adversary(k1, public_feats[:split], labels[:split], n_classes,
                          steps=steps)
    pub_m = evaluate_adversary(pub, public_feats[split:], labels[split:],
                               n_classes)
    prv = train_adversary(k2, pf[:split], labels[:split], n_classes,
                          steps=steps)
    prv_m = evaluate_adversary(prv, pf[split:], labels[split:], n_classes)
    return pub_m, prv_m

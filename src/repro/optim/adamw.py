"""AdamW over arbitrary param pytrees, with global-norm clipping.

Kept dependency-free (no optax in the container); state is a pytree of the
same structure as params so it shards with the params under pjit (optimizer
state inherits the param PartitionSpec -> ZeRO-style sharding for free when
params are FSDP-sharded).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    mu: dict
    nu: dict
    count: jax.Array


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(mu=zeros,
                      nu=jax.tree.map(jnp.zeros_like, zeros),
                      count=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(params, grads, state: AdamWState, *, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.0, grad_clip: float = 0.0):
    if grad_clip:
        grads, _ = clip_by_global_norm(grads, grad_clip)
    count = state.count + 1
    cf = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** cf
    bc2 = 1.0 - b2 ** cf

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        if weight_decay:
            step = step + weight_decay * p.astype(jnp.float32)
        return (p - lr * step.astype(p.dtype)).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(mu=new_m, nu=new_v, count=count)

"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig4,fig5,...]
    PYTHONPATH=src python -m benchmarks.run --section server --smoke

Emits ``section,name,value[,extra]`` CSV lines plus wall-time per section,
and writes each section's rows as a machine-readable ``BENCH_<section>.json``
artifact (``{"section", "rows": [{name, value, extra}], "wall_s"}``) in the
working directory so benchmark trajectories can be tracked across commits.
Paper targets:
  fig4     downstream accuracy: centralized vs FL variants vs OCTOPUS
  fig5     privatization: private-attribute accuracy + conditional entropy
  table1   disentanglement on/off across codebook sizes
  fig9     multi-task probes on latent codes vs raw baseline
  sec2_8   communication-overhead accounting (measured bytes)
  sec3_8   time overheads (encode latency, probe vs conv train time)
  kernels  Pallas kernel microbenchmarks vs jnp reference
  gsvq     GSVQ (groups x slices) accuracy vs bits-per-position
  sim      batched multi-client engine (repro.sim) throughput + uplink
  server   async code-server runtime (repro.server): rounds/sec, decode
           amortization, bytes-per-accuracy across traffic scenarios
  decode   fused packed-code->feature decode (kernels/decode_codes.py)
           vs the unpack-then-dequantize baseline
  encode   fused client uplink (kernels/encode_codes.py): single-encode
           + one quantize-pack-stats dispatch vs the seed pipeline that
           re-ran the network and materialized distances + indices
  wire     unified wire protocol (repro/wire): OctopusClient facade
           round vs the PR-4 fused round — bit-identical words,
           dispatch-count-neutral, plus the CodePayload->store roundtrip
  privacy  red-team sweep (repro/privacy): inference-attack advantage
           vs disentanglement strength / K / GSVQ grouping, the
           leaky-control teeth check, and oblivious-store overhead

``privacy`` CSV schema (rows ``privacy,<name>,<value>[,extra]``):
  harness_matches_wire      partial-IN harness encoder == facade wire
                            at both endpoints (packed words, bit-exact)
  leaky_control_advantage   attribute-attack advantage on the REAL
                            facade wire with IN off — MUST clear chance
                            (the harness-has-teeth gate)
  privatized_advantage      same attack, IN on — must sit ≈ chance
  attr_advantage/disent_s<s>   advantage at disentanglement strength s
  attr_advantage/K<K>_{leaky|priv}        advantage vs codebook size
  attr_advantage/gsvq_g<G>s<S>_{leaky|priv}  advantage vs GSVQ grouping
  membership_{leaky|privatized}_advantage  client re-identification
                            (round-2 members vs never-seen holdouts)
  oblivious_parity_bitexact oblivious store == plain sharded store
                            (codes + every (client, round) get)
  oblivious_touch_ratio     partitions touched per useful partition
                            (the access-pattern-hiding cost)
  oblivious_get_overhead    wall ratio oblivious/plain on one identical
                            query workload (OMLO methodology)

``wire`` CSV schema (rows ``wire,<name>,<value>[,extra]``):
  bit_identical_to_fused    facade payload words == pure round_words core
  facade_samples_per_sec    jitted facade round core (wire.round_words)
  facade_encoder_passes     COUNTED encoder invocations of one facade
                            round (extra: the pure core's count)
  facade_encode_dispatches  COUNTED ops.encode_codes dispatches (extra:
                            the pure core's count)
  payload_bytes             measured CodePayload.nbytes of one round
  store_bytes_match         store.total_bytes == payload.nbytes after
                            OctopusServer.ingest
  decoded_samples           rows decoded by OctopusServer.features()

``encode`` CSV schema (rows ``encode,<cfg>_<name>,<value>[,extra]``):
  fused_samples_per_sec     one uplink round (Steps 3-5 tail) as ONE
                            dispatch: single encoder pass feeding
                            ops.encode_codes (quantize + pack + EMA
                            stats fused)
  baseline_samples_per_sec  the same round through the seed entry
                            points: client_transmit (forward -> indices
                            -> pack) then client_codebook_refresh
                            (network pass again -> ema_update), each its
                            own dispatch with its own network pass
  fused_gbps / baseline_gbps   measured packed-uplink GB/s of each path
  speedup                   baseline time / fused time (same jit regime)
  encoder_passes_per_round  COUNTED encoder invocations of one
                            client_round (extra: the seed path's count)

``decode`` CSV schema (rows ``decode,<cfg>_<name>,<value>[,extra]``):
  fused_samples_per_sec     decoded samples/s straight from the packed
                            word stream (ops.decode_codes)
  baseline_samples_per_sec  same decode as unpack_codes -> dequantize
                            (two materialized hops)
  fused_gbps / baseline_gbps   measured packed-payload GB/s of each path
  speedup                   baseline time / fused time (same jit regime)

``server`` CSV schema (rows ``server,<scenario>_<name>,<value>[,extra]``):
  rounds_per_sec       scheduler-driven rounds/sec through the runtime
                       (post-compile)
  participants         scheduled participants per round
  bytes_delivered      MEASURED packed bytes landed in the CodeStore
  bytes_sent           measured bytes incl. dropped / in-flight
  store_records        records buffered (extra: codebook versions held)
  acc_<task>           multi-task head accuracy from ONE store decode
  bytes_per_point      delivered bytes per content-accuracy point
  decode_amortization  measured end-to-end: per-task pipeline time
                       (re-decode store + fit each head) / shared
                       pipeline time (one decode, one multi-head fit)
  decode_shared_pipeline_ms   wall ms of the shared pipeline leg
continuous-ingest soak rows (``server,continuous_*`` / ``admission_*``):
  continuous_uplinks_per_sec  HEADLINE: sustained uplinks/sec through
                       the clocked ContinuousIngestService under churn,
                       with backpressure and a rolling codebook
                       migration engaged inside the timed window
  continuous_ticks / continuous_participants   soak extent
  admission_<verdict> / admission_<verdict>_bytes   admission-control
                       histogram (accepted/migrated/deferred/rejected);
                       refused bytes stay on the §2.8 ledger
  continuous_bytes_delivered / continuous_bytes_refused   ledger split
  continuous_store_partitions   (version, shard) ring buffers in use
  continuous_migrations         rolling v_n -> v_{n+1} windows completed
  continuous_decode_amortization   records decoded per fused dispatch
                       by the background bulk-decode batches
chaos-plane rows (``server,goodput_under_faults`` etc.):
  goodput_under_faults  delivered B/s of the SAME soak run through a
                       journaled FaultyChannel (drop / duplicate /
                       reorder / delay / corrupt / truncate + retries)
                       — the §2.8 ledger stays conserved under chaos
  faults_injected / fault_retries   chaos extent (extra: per-kind)
  recovery_time_s      crash drill: snapshot + journal replay back to
                       the exact pre-kill tick/verdicts/ledger

``sim`` CSV schema (all rows ``sim,<name>,<value>[,<extra>]``):
  n_clients            population size advanced per jitted call
  round_ms             mean wall ms per engine round (Steps 2-5, jitted)
  clients_per_sec      n_clients * rounds / wall — the headline
                       scale metric (a Python client loop is the 1x
                       baseline)
  speedup_vs_loop      measured speedup over that Python client loop
  bytes_per_round      MEASURED size of the round's bit-packed uplink
                       payload
  bits_per_code        bits per packed code index
  bytes_per_round_int32  same indices as unpacked int32 (the naive
                       transmission the codec replaces)
  pack_ratio           bytes_per_round_int32 / bytes_per_round
  ingest_rounds        rounds accumulated in the server CodeStore
  ingest_total_bytes   measured bytes across the buffered rounds
  ingest_probe_acc     Step-6 probe accuracy trained from the store
  cohort_parity_bitexact   cohort-streamed round == single full-population
                       round (merge stats + payload words + bytes, ALL
                       array_equal)
  cohort_parity_pop    population size the parity gate checked
  cohort_size          clients per streamed cohort (the compiled unit)
  pop<N>_clients_per_sec   clients/sec of a cohort-streamed population
                       round at N simulated clients
  pop<N>_round_s       wall seconds of that streamed round
  pop<N>_bytes         Σ measured per-cohort uplink bytes of that round
  pop<N>_cohorts       cohorts dispatched in that round
  pop_max_clients      largest population in the scaling curve — the
                       ROADMAP 100k+ target rides here

Scaling-curve methodology: clients deploy fresh from the server each
round (cross-device regime), every cohort reuses ONE compiled engine
round (jit cache keyed on the cohort shape), per-cohort Step-5 stats
fold into the exactly-associative int64 fixed-point accumulator, and
clients/sec = N / wall(streamed round) AFTER a warm-up cohort compiles
the shape. Peak memory is one cohort's state — the population's stacked
state never exists, which is what lets N reach 100k+ on one host.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from benchmarks import common as C

_ROWS = []      # every _emit row, grouped into BENCH_<section>.json by main()


def _coerce(value):
    """BENCH artifacts carry real JSON values: numeric strings become
    numbers and True/False become booleans, so cross-PR trend tooling can
    diff rows without parsing. ``extra`` stays the only string field."""
    if isinstance(value, str):
        s = value.strip()
        if s in ("True", "False"):
            return s == "True"
        try:
            return int(s)
        except ValueError:
            pass
        try:
            return float(s)
        except ValueError:
            return value
    return value


def _emit(section, name, value, extra=""):
    _ROWS.append({"section": section, "name": name, "value": _coerce(value),
                  "extra": str(extra)})
    print(f"{section},{name},{value}{',' + str(extra) if extra else ''}",
          flush=True)


def _write_artifact(section, wall_s):
    """Dump one section's rows as machine-readable BENCH_<section>.json."""
    rows = [{k: r[k] for k in ("name", "value", "extra")}
            for r in _ROWS if r["section"] == section]
    with open(f"BENCH_{section}.json", "w") as f:
        json.dump({"section": section, "wall_s": round(wall_s, 1),
                   "rows": rows}, f, indent=1)


# ------------------------------------------------------------------- fig 4

def bench_fig4(key):
    """Downstream (content) accuracy across schemes (Fig. 4)."""
    from repro.core.downstream import conv_classifier, init_conv_classifier
    from repro.core.fedavg import FedConfig, fedavg_train
    from repro.core import downstream as DS

    pipe = C.build_pipeline(key, codebook_size=256)
    n_classes = 8
    y_tr, y_te = pipe.train.content, pipe.test.content

    # centralized on raw data (upper baseline)
    acc = C.train_conv_on_raw(key, pipe.train.x, y_tr, pipe.test.x, y_te)
    _emit("fig4", "centralized", f"{acc:.4f}")

    # centralized + DP (clip + noise during training)
    clf0 = init_conv_classifier(key, in_channels=3, n_classes=n_classes)
    dp = fedavg_train(key, conv_classifier, clf0, [pipe.train],
                      C.content_label,
                      FedConfig(rounds=C.FED_ROUNDS, dp_clip=1.0,
                                dp_noise=0.05, local_epochs=8))
    _emit("fig4", "centralized_dp",
          f"{DS.accuracy(conv_classifier, dp, pipe.test.x, y_te):.4f}")

    # federated variants
    def fed(shards, fc, shared=None, tag=""):
        p0 = init_conv_classifier(key, in_channels=3, n_classes=n_classes)
        p = fedavg_train(key, conv_classifier, p0, shards, C.content_label,
                         fc, shared_data=shared)
        a = DS.accuracy(conv_classifier, p, pipe.test.x, y_te)
        _emit("fig4", tag, f"{a:.4f}")
        return a

    base_fc = FedConfig(rounds=C.FED_ROUNDS, local_epochs=8)
    fed(pipe.shards_iid, base_fc, tag="fed_iid")
    fed(pipe.shards_worst, base_fc, tag="fed_noniid_worst")
    fed(pipe.shards_skew, base_fc, tag="fed_noniid_moderate")
    fed(pipe.shards_worst, FedConfig(rounds=C.FED_ROUNDS, prox_mu=0.1,
                                     local_epochs=8), tag="fedprox_worst")
    fed(pipe.shards_worst, base_fc, shared=pipe.atd, tag="fed_datashare")
    fed(pipe.shards_iid, FedConfig(rounds=C.FED_ROUNDS, dp_clip=1.0,
                                   dp_noise=0.05, local_epochs=8),
        tag="fed_iid_dp")

    # OCTOPUS across codebook sizes
    for B in (32, 64, 128, 256):
        p = pipe if B == 256 else C.build_pipeline(key, codebook_size=B)
        acc = C.train_probe_on_codes(key, p, p.train.content, p.test.content)
        _emit("fig4", f"octopus_B{B}", f"{acc:.4f}")


# ------------------------------------------------------------------- fig 5

def bench_fig5(key):
    """Privatization: identity (style) recognition accuracy on raw vs
    OCTOPUS public codes; conditional entropy per Thm. 1 (Fig. 5 + Fig. 7)."""
    from repro import privacy as PV
    pipe = C.build_pipeline(key, codebook_size=256)

    # adversary on RAW data (centralized leak baseline)
    acc_raw = C.train_conv_on_raw(key, pipe.train.x, pipe.train.style,
                                  pipe.test.x, pipe.test.style)
    _emit("fig5", "identity_acc_raw_centralized", f"{acc_raw:.4f}")

    # adversary on released public codes Z•
    adv = PV.train_adversary(key, pipe.train_codes, pipe.train.style,
                             C.N_IDENTITIES, steps=C.PROBE_STEPS)
    m_pub = PV.evaluate_adversary(adv, pipe.test_codes, pipe.test.style,
                                  C.N_IDENTITIES)
    _emit("fig5", "identity_acc_octopus_public", f"{m_pub.accuracy:.4f}")
    _emit("fig5", "cond_entropy_bits_public",
          f"{m_pub.conditional_entropy_bits:.4f}")

    # adversary on the private component Z∘ (should leak MORE)
    from repro.core.dvqae import forward as fwd
    out_tr = fwd(pipe.server.params, pipe.cfg, pipe.train.x)
    out_te = fwd(pipe.server.params, pipe.cfg, pipe.test.x)
    priv_tr = jnp.broadcast_to(out_tr.latent.private,
                               out_tr.latent.public.shape)
    priv_te = jnp.broadcast_to(out_te.latent.private,
                               out_te.latent.public.shape)
    adv2 = PV.train_adversary(key, priv_tr, pipe.train.style,
                              C.N_IDENTITIES, steps=C.PROBE_STEPS)
    m_prv = PV.evaluate_adversary(adv2, priv_te, pipe.test.style,
                                  C.N_IDENTITIES)
    _emit("fig5", "identity_acc_octopus_private", f"{m_prv.accuracy:.4f}")
    _emit("fig5", "cond_entropy_bits_private",
          f"{m_prv.conditional_entropy_bits:.4f}")

    _emit("fig5", "claim_public_much_lower",
          str(m_pub.accuracy < 0.6 * acc_raw))
    _emit("fig5", "claim_private_leaks_more",
          str(m_prv.accuracy > m_pub.accuracy))

    # utility retained on the same released codes
    util = C.train_probe_on_codes(key, pipe, pipe.train.content,
                                  pipe.test.content)
    _emit("fig5", "content_acc_on_public_codes", f"{util:.4f}")


# ------------------------------------------------------------------ table 1

def bench_table1(key):
    """Identity accuracy with/without disentanglement across codebook
    sizes (Table 1 / Fig. 8)."""
    from repro import privacy as PV
    for B in (32, 64, 128):
        row = []
        for apply_in in (True, False):
            pipe = C.build_pipeline(key, codebook_size=B, apply_in=apply_in)
            adv = PV.train_adversary(key, pipe.train_codes, pipe.train.style,
                                     C.N_IDENTITIES, steps=C.PROBE_STEPS)
            m = PV.evaluate_adversary(adv, pipe.test_codes, pipe.test.style,
                                      C.N_IDENTITIES)
            row.append(m.accuracy)
        _emit("table1", f"B{B}_with_disent", f"{row[0]:.4f}")
        _emit("table1", f"B{B}_without_disent", f"{row[1]:.4f}")
        _emit("table1", f"B{B}_disent_helps", str(row[0] <= row[1] + 0.05))


# ------------------------------------------------------------------- fig 9

def bench_fig9(key):
    """Multi-task: several binary attributes from ONE set of latent codes
    vs per-task conv baselines (Fig. 9)."""
    pipe = C.build_pipeline(key, codebook_size=256)
    tasks = {
        "is_round": lambda c: (c <= 1).astype(jnp.int32),
        "has_bar": lambda c: ((c == 6) | (c == 7)).astype(jnp.int32),
        "is_diag": lambda c: ((c == 4) | (c == 5)).astype(jnp.int32),
        "high_class": lambda c: (c >= 4).astype(jnp.int32),
    }
    t0 = time.time()
    for name, fn in tasks.items():
        acc = C.train_probe_on_codes(key, pipe, fn(pipe.train.content),
                                     fn(pipe.test.content))
        _emit("fig9", f"octopus_probe_{name}", f"{acc:.4f}")
    probe_t = time.time() - t0
    t0 = time.time()
    for name, fn in tasks.items():
        acc = C.train_conv_on_raw(key, pipe.train.x, fn(pipe.train.content),
                                  pipe.test.x, fn(pipe.test.content))
        _emit("fig9", f"conv_raw_{name}", f"{acc:.4f}")
    conv_t = time.time() - t0
    _emit("fig9", "probe_total_s", f"{probe_t:.2f}")
    _emit("fig9", "conv_total_s", f"{conv_t:.2f}")


# ------------------------------------------------------------------ §2.8

def bench_sec2_8(key):
    """Communication overheads with bytes measured from THIS system."""
    from repro.core.overheads import (CommModel, comparison_table,
                                      multi_task_bytes)
    from repro.core.downstream import init_conv_classifier
    pipe = C.build_pipeline(key, codebook_size=256)
    clf = init_conv_classifier(key, in_channels=3, n_classes=8)
    model_bytes = sum(l.size * 4 for l in jax.tree.leaves(clf))
    n_samples = pipe.train.x.shape[0]
    code_bytes = pipe.bytes_transmitted // max(n_samples, 1)
    cb = pipe.server.params["codebook"]
    c = CommModel(
        n_clients=C.N_CLIENTS, model_bytes=model_bytes,
        n_samples=n_samples, n_epochs=100,
        code_bytes_per_sample=code_bytes,
        smashed_bytes_per_sample=int(pipe.train_codes[0].size) * 4,
        codebook_bytes=cb.size * 4, downstream_model_bytes=model_bytes)
    for k, v in comparison_table(c).items():
        _emit("sec2_8", k, f"{v:.3e}" if isinstance(v, float) else v)
    mt = multi_task_bytes(c, 10)
    _emit("sec2_8", "multitask10_federated", mt["federated"])
    _emit("sec2_8", "multitask10_octopus", mt["octopus"])
    _emit("sec2_8", "raw_bytes_per_sample", pipe.train.x[0].size * 4)
    _emit("sec2_8", "code_bytes_per_sample", code_bytes)


# ------------------------------------------------------------------ §3.8

def bench_sec3_8(key):
    """Time overheads: per-sample encode latency; probe vs conv train."""
    from repro.wire import OctopusClient
    pipe = C.build_pipeline(key, codebook_size=256)
    client = OctopusClient(pipe.server, pipe.cfg)
    x1 = pipe.test.x[:1]
    payload = client.transmit(x1)                   # compile
    t0 = time.time()
    for _ in range(20):
        payload = client.transmit(x1)
    # the facade transmit IS the fused Steps 3-4 tail: quantize + bit-pack
    # in one dispatch, the payload is what hits the uplink
    jax.block_until_ready(payload.payload)
    _emit("sec3_8", "encode_ms_per_sample", f"{(time.time()-t0)/20*1e3:.2f}")

    t0 = time.time()
    C.train_probe_on_codes(key, pipe, pipe.train.content, pipe.test.content)
    _emit("sec3_8", "probe_train_s", f"{time.time()-t0:.2f}")
    t0 = time.time()
    C.train_conv_on_raw(key, pipe.train.x, pipe.train.content, pipe.test.x,
                        pipe.test.content)
    _emit("sec3_8", "conv_train_s", f"{time.time()-t0:.2f}")


# ---------------------------------------------------------------- kernels

def bench_kernels(key):
    """Microbenchmarks: Pallas (interpret on CPU) vs jnp reference."""
    from repro.kernels import ops, ref

    z = jax.random.normal(key, (2048, 64))
    cb = jax.random.normal(jax.random.PRNGKey(1), (256, 64))

    def timeit(fn, *args, n=5):
        out = fn(*args)
        jax.block_until_ready(out)
        t0 = time.time()
        for _ in range(n):
            out = fn(*args)
        jax.block_until_ready(out)
        return (time.time() - t0) / n * 1e6

    jref = jax.jit(ref.vq_nearest_ref)
    _emit("kernels", "vq_nn_ref_us", f"{timeit(jref, z, cb):.0f}")
    _emit("kernels", "vq_nn_pallas_interpret_us",
          f"{timeit(lambda a, b: ops.vq_nearest(a, b), z, cb):.0f}")

    q = jax.random.normal(key, (1, 512, 4, 64))
    jref2 = jax.jit(lambda q: ref.flash_attention_ref(q, q, q))
    _emit("kernels", "flash_ref_us", f"{timeit(jref2, q):.0f}")

    x = jax.random.normal(key, (4096, 1024))
    s = jnp.ones((1024,))
    jref3 = jax.jit(ref.rmsnorm_ref)
    _emit("kernels", "rmsnorm_ref_us", f"{timeit(jref3, x, s):.0f}")
    _emit("kernels", "interpret_mode", 1,
          extra="pallas timed in interpret mode on CPU; "
                "TPU timings require hardware")


def bench_gsvq(key):
    """§3.1 group setups: GSVQ (groups x slices) vs plain VQ — accuracy and
    bits-per-position trade-off."""
    from repro.core.gsvq import gsvq_bits_per_position
    for (g, sl) in ((1, 1), (4, 1), (8, 2), (16, 4)):
        pipe = C.build_pipeline(key, codebook_size=64, n_groups=g,
                                n_slices=sl)
        acc = C.train_probe_on_codes(key, pipe, pipe.train.content,
                                     pipe.test.content)
        bits = (gsvq_bits_per_position(g, sl) if g > 1
                else 6)                      # log2(64) plain VQ
        _emit("gsvq", f"G{g}_S{sl}_acc", f"{acc:.4f}")
        _emit("gsvq", f"G{g}_S{sl}_bits_per_pos", bits)


# ------------------------------------------------------------------- sim

def bench_sim(key):
    """Batched multi-client engine: clients/sec of one jitted population
    round (Steps 2-5) vs a Python client loop, plus the round's measured
    bit-packed uplink (schema in the module docstring)."""
    from repro.core import octopus as OC
    from repro.core.dvqae import DVQAEConfig
    from repro.data import make_images, partition_stacked, stacked_batches
    from repro.kernels.ops import pack_codes
    from repro.server import CodeStore
    from repro.sim import SimEngine

    n_clients = 16 if C.QUICK else 64
    local_batch = 8
    cfg = DVQAEConfig(kind="image", in_channels=3, hidden=16, latent_dim=16,
                      codebook_size=256, n_res_blocks=1)
    data = make_images(key, n_clients * local_batch, size=16,
                       n_identities=C.N_IDENTITIES)
    stacked = partition_stacked(data, n_clients, regime="iid")
    rounds = 3 if C.QUICK else 5

    # one (C, local_batch, ...) stacked batch per round, materialized up
    # front so the timed windows measure the round, not host-side slicing
    round_xs = [jax.block_until_ready(b.x) for b in
                stacked_batches(stacked, local_batch, epochs=rounds + 1)]

    server = OC.server_init(key, cfg)
    for i in range(20 if C.QUICK else 60):
        sel = jax.random.randint(jax.random.fold_in(key, i), (32,), 0,
                                 data.x.shape[0])
        server, _ = OC.server_pretrain_step(server, cfg, data.x[sel])

    engine = SimEngine(cfg, lr=1e-4, gamma=0.99)
    clients = engine.init_clients(server, n_clients)

    clients, packed = engine.round(clients, round_xs[0])       # compile
    jax.block_until_ready(packed.payload)
    t0 = time.time()
    for xb in round_xs[1:]:
        clients, packed = engine.round(clients, xb)
        jax.block_until_ready(packed.payload)   # await each round's uplink,
    dt = time.time() - t0                       # same sync as the baseline

    # 1x baseline: the SAME work as a Python loop over single clients —
    # identical per-round batches, including the per-round pack
    step = jax.jit(lambda c, xb: OC.client_round(c, cfg, xb, lr=1e-4,
                                                 gamma=0.99))
    loop_clients = [OC.client_init(server) for _ in range(n_clients)]
    step(loop_clients[0], round_xs[0][0])                      # compile
    t0 = time.time()
    for xb in round_xs[1:]:
        loop_out = [step(c, xb[i]) for i, c in enumerate(loop_clients)]
        loop_clients = [o[0] for o in loop_out]
        loop_packed = pack_codes(jnp.stack([o[1] for o in loop_out]),
                                 bits=engine.bits)
        jax.block_until_ready(loop_packed)
    loop_dt = time.time() - t0

    _emit("sim", "n_clients", n_clients)
    _emit("sim", "round_ms", f"{dt / rounds * 1e3:.1f}")
    _emit("sim", "clients_per_sec", f"{n_clients * rounds / dt:.1f}",
          extra="python client loop is the 1x baseline")
    _emit("sim", "speedup_vs_loop", f"{loop_dt / dt:.1f}")
    naive = packed.count * 4
    _emit("sim", "bytes_per_round", packed.nbytes)
    _emit("sim", "bits_per_code", packed.bits)
    _emit("sim", "bytes_per_round_int32", naive)
    _emit("sim", "pack_ratio", f"{naive / packed.nbytes:.2f}")

    # Step 6: accumulate rounds server-side and train from the store
    from repro.core import downstream as DS
    store = CodeStore(cfg)
    for r, b in enumerate(stacked_batches(stacked, local_batch, epochs=3,
                                          seed=1)):
        clients, packed = engine.round(clients, b.x)
        store.add(packed, round=r, labels=b.content)
    server = engine.merge_into_server(server, clients)
    feats, label_dict = store.dataset(server)         # decode ONCE
    labels = label_dict["label"]
    probe = DS.init_linear_probe(key, int(feats[0].size),
                                 int(stacked.content.max()) + 1)
    probe = DS.sgd_train(key, DS.linear_probe, probe, feats, labels,
                         steps=C.PROBE_STEPS)
    acc = DS.accuracy(DS.linear_probe, probe, feats, labels)
    _emit("sim", "ingest_rounds", len(store))
    _emit("sim", "ingest_total_bytes", store.total_bytes)
    _emit("sim", "ingest_probe_acc", f"{acc:.4f}")

    # ---- cohort-streamed population scaling curve (§2.2, ROADMAP item 1)
    import numpy as np

    from repro.sim import CohortEngine, CohortPlan

    pcfg = DVQAEConfig(kind="image", in_channels=3, hidden=8, latent_dim=8,
                       codebook_size=256, n_res_blocks=1)
    pserver = OC.server_init(key, pcfg)
    ceng = CohortEngine(pcfg, gamma=0.99, n_local_steps=0)
    cohort_size = 256 if C.QUICK else 1024
    # smoke runs the 1k rung + parity assert only — the 10k/100k rungs
    # burn ~85 s of wall clock that CI doesn't need
    pop_sizes = [1024] if C.QUICK else [1024, 10240, 102400]
    pool = jax.block_until_ready(
        jax.random.normal(key, (4096, 1, 8, 8, 3)))    # shared sample pool

    def data_fn(ids):
        # slot-id-keyed batches WITHOUT materializing population data:
        # each client reads its own pool row, so any cohort grouping
        # sees identical per-client batches (the parity invariant)
        return pool[np.asarray(ids) % pool.shape[0]]

    # parity gate: the streamed round must reproduce the one-shot
    # population round bit-for-bit before any throughput is reported
    n_par = pop_sizes[0]
    full = ceng.round(pserver, CohortPlan.from_groups([np.arange(n_par)]),
                      data_fn)
    parts = ceng.round(pserver, CohortPlan.build(np.arange(n_par),
                                                 cohort_size), data_fn)
    from repro.wire import concat_payloads
    cat = concat_payloads(parts.payloads)
    parity = (np.array_equal(parts.stats.num, full.stats.num)
              and np.array_equal(parts.stats.den, full.stats.den)
              and np.array_equal(np.asarray(cat.payload),
                                 np.asarray(full.payloads[0].payload)))
    bytes_match = parts.nbytes == full.nbytes
    _emit("sim", "cohort_parity_bitexact", int(parity and bytes_match),
          extra="streamed round vs one-shot population round")
    assert parity and bytes_match, "cohort parity broken — curve invalid"
    _emit("sim", "cohort_parity_pop", n_par)
    _emit("sim", "cohort_size", cohort_size)

    for n_pop in pop_sizes:
        plan = CohortPlan.build(np.arange(n_pop), cohort_size)
        warm = CohortPlan.from_groups([plan.cohorts[0]])
        ceng.round(pserver, warm, data_fn)              # compile the shape
        t0 = time.time()
        out = ceng.round(pserver, plan, data_fn)
        dt = time.time() - t0
        _emit("sim", f"pop{n_pop}_clients_per_sec", f"{n_pop / dt:.0f}")
        _emit("sim", f"pop{n_pop}_round_s", f"{dt:.2f}")
        _emit("sim", f"pop{n_pop}_bytes", out.nbytes)
        _emit("sim", f"pop{n_pop}_cohorts", plan.n_cohorts)
    _emit("sim", "pop_max_clients", pop_sizes[-1])


# ---------------------------------------------------------------- server

def bench_server(key):
    """Async code-server runtime across STANDARD_SCENARIOS: rounds/sec,
    measured uplink bytes, multi-task accuracy from one decode, and the
    decode amortization factor (schema in the module docstring)."""
    from repro.core import octopus as OC
    from repro.core.dvqae import DVQAEConfig
    from repro.data import make_images, partition_stacked
    from repro.launch.octopus_server import run_scenario
    from repro.server import STANDARD_SCENARIOS, MultiTaskTrainer, TaskSpec
    from repro.sim import SimEngine

    n_slots = 8 if C.QUICK else 16
    local_b, rounds = 8, (4 if C.QUICK else 8)
    cfg = DVQAEConfig(kind="image", in_channels=3, hidden=16, latent_dim=16,
                      codebook_size=64, n_res_blocks=1)
    data = make_images(key, n_slots * local_b * 4, size=16,
                       n_identities=C.N_IDENTITIES)
    server, _ = OC.server_pretrain(key, OC.server_init(key, cfg), cfg,
                                   data.x, steps=20 if C.QUICK else 60)
    stacked = partition_stacked(data, n_slots, regime="skewed", skew=0.2)
    engine = SimEngine(cfg, lr=1e-4, gamma=0.95)
    tasks = [TaskSpec("content", int(stacked.content.max()) + 1),
             TaskSpec("style", int(stacked.style.max()) + 1)]

    last_srv = None
    for i, (name, sc) in enumerate(STANDARD_SCENARIOS.items()):
        srv, acc, rps = run_scenario(
            name, sc, engine=engine, server=server, stacked=stacked,
            slots=n_slots, rounds=rounds, local_batch=local_b,
            probe_steps=C.PROBE_STEPS, key=key, index=i, verbose=False)
        _emit("server", f"{name}_rounds_per_sec", f"{rps:.2f}")
        _emit("server", f"{name}_participants", srv.scheduler.k)
        _emit("server", f"{name}_bytes_delivered", srv.bytes_delivered)
        _emit("server", f"{name}_bytes_sent", srv.bytes_sent,
              extra="incl. dropped / in-flight")
        _emit("server", f"{name}_store_records", len(srv.store),
              extra="v" + "+".join(map(str, srv.store.versions)))
        for t, a in acc.items():
            _emit("server", f"{name}_acc_{t}", f"{a:.4f}")
        _emit("server", f"{name}_bytes_per_point",
              f"{srv.bytes_delivered / max(acc['content'], 1e-3):.0f}")
        last_srv = srv

    # decode amortization, measured end-to-end: training every head from
    # ONE shared decode vs a per-task pipeline that re-decodes the store
    # for each head (what Step 6 without the shared store would do).
    # Every trainer's jitted step is warmed first so the ratio measures
    # decode + train work, not compile-count asymmetry.
    steps = max(C.PROBE_STEPS // 4, 10)
    feats, labels = last_srv.dataset()
    in_dim = int(feats[0].size)
    shared = MultiTaskTrainer(key, tasks, in_dim)
    singles = [MultiTaskTrainer(key, [t], in_dim) for t in tasks]
    for tr in [shared] + singles:
        tr.fit(key, feats, labels, steps=1, batch=64)      # compile warmup
    t0 = time.time()
    feats, labels = last_srv.dataset()
    shared.fit(key, feats, labels, steps=steps, batch=64)
    t_shared = max(time.time() - t0, 1e-9)
    t0 = time.time()
    for tr in singles:
        feats, labels = last_srv.dataset()                 # per-task decode
        tr.fit(key, feats, labels, steps=steps, batch=64)
    t_per_task = time.time() - t0
    _emit("server", "decode_amortization", f"{t_per_task / t_shared:.2f}",
          extra="per-task pipeline time / shared pipeline time")
    _emit("server", "decode_shared_pipeline_ms", f"{t_shared * 1e3:.0f}")

    # ---- continuous-ingest soak: the headline sustained-throughput row.
    # Open-ended Poisson traffic under churn drives the clocked service
    # through a sharded store with a deliberately tight admission window
    # (small queue capacity), so backpressure verdicts and a rolling
    # codebook migration are part of the measured steady state — the
    # uplinks/sec figure prices admission control in, not around.
    import numpy as np

    from repro.server import (BulkDecodePolicy, ContinuousIngestService,
                              RoundScheduler, SchedulerConfig,
                              ShardedCodeStore)
    from repro.sim import CohortEngine
    from repro.wire import OctopusServer

    n_ticks = 6 if C.QUICK else 20
    ccfg = DVQAEConfig(kind="image", in_channels=3, hidden=8, latent_dim=8,
                       codebook_size=64, n_res_blocks=1)
    cstate = OC.server_init(key, ccfg)
    srv = OctopusServer(cstate, ccfg,
                        store=ShardedCodeStore(ccfg, n_shards=4,
                                               capacity_samples=4096))
    svc = ContinuousIngestService(
        srv, capacity=2, defer_depth=1,
        decode_policy=BulkDecodePolicy(min_batch=1, max_batch=64))
    sched = RoundScheduler(
        n_slots * 2,
        SchedulerConfig(rate=float(n_slots), straggler_prob=0.4,
                        max_delay=2, drop_prob=0.1, leave_prob=0.2,
                        join_prob=0.5),
        key=jax.random.fold_in(key, 99))
    ceng = CohortEngine(ccfg, gamma=0.95, n_local_steps=0)
    pool = jax.block_until_ready(
        jax.random.normal(key, (256, 1, 8, 8, 3)))
    data_fn = lambda ids: pool[np.asarray(ids) % pool.shape[0]]

    # warm the per-cohort compile outside the timed window
    ceng.run_continuous(svc, sched, data_fn, cohort_size=4, n_ticks=1)
    t0 = time.time()
    hist = ceng.run_continuous(svc, sched, data_fn, cohort_size=4,
                               n_ticks=n_ticks, merge_every=3,
                               migration_policy="keep")
    svc.drain()
    dt = max(time.time() - t0, 1e-9)

    n_up = sum(svc.verdicts.values())
    _emit("server", "continuous_uplinks_per_sec", f"{n_up / dt:.1f}",
          extra="sustained, churn + backpressure + rolling migration")
    _emit("server", "continuous_ticks", n_ticks)
    _emit("server", "continuous_participants",
          sum(t.n_participants for t in hist))
    for v in ("accepted", "migrated", "deferred", "rejected"):
        _emit("server", f"admission_{v}", svc.verdicts.get(v, 0))
        _emit("server", f"admission_{v}_bytes", svc.verdict_bytes.get(v, 0))
    q = svc.queue
    assert q.bytes_sent == (q.bytes_delivered + q.bytes_dropped +
                            q.bytes_rejected + q.bytes_duplicate +
                            q.bytes_in_flight), \
        "uplink byte ledger leaked under backpressure"
    backpressured = (svc.verdicts.get("deferred", 0)
                     + svc.verdicts.get("rejected", 0))
    assert backpressured >= 1, \
        "soak never engaged backpressure — tighten capacity"
    _emit("server", "continuous_bytes_delivered", q.bytes_delivered)
    _emit("server", "continuous_bytes_refused",
          q.bytes_rejected + q.bytes_dropped,
          extra="still on the §2.8 ledger")
    _emit("server", "continuous_store_partitions",
          len(srv.store.partitions))
    _emit("server", "continuous_migrations", srv.registry.latest)
    _emit("server", "continuous_decode_amortization",
          f"{svc.decode_amortization:.2f}",
          extra="records decoded per fused dispatch")

    # ---- chaos plane: the same soak through a FaultyChannel, journaled.
    # goodput_under_faults prices retries, duplicates and CRC rejections
    # into the delivered-byte rate; recovery_time_s measures the crash
    # drill (snapshot + journal replay to the exact pre-kill state).
    import os
    import tempfile

    from repro.server import ServerPersistence
    from repro.sim import FaultPlan, FaultyChannel
    from repro.wire import RetryPolicy

    root = os.path.join(tempfile.mkdtemp(prefix="octopus_bench_"), "srv")
    fstate = OC.server_init(key, ccfg)
    fsrv = OctopusServer(fstate, ccfg,
                         store=ShardedCodeStore(ccfg, n_shards=4,
                                                capacity_samples=4096))
    fsvc = ContinuousIngestService(
        fsrv, capacity=4, defer_depth=3,
        decode_policy=BulkDecodePolicy(min_batch=1, max_batch=64),
        persist=ServerPersistence(root, snapshot_every=5))
    chan = FaultyChannel(
        fsvc,
        FaultPlan(drop=0.15, duplicate=0.15, reorder=0.2, delay=0.3,
                  corrupt=0.1, truncate=0.1),
        key=jax.random.fold_in(key, 123),
        retry=RetryPolicy(max_attempts=3))
    fsched = RoundScheduler(
        n_slots * 2,
        SchedulerConfig(rate=float(n_slots), straggler_prob=0.4,
                        max_delay=2, drop_prob=0.1),
        key=jax.random.fold_in(key, 124))
    ceng.run_continuous(chan, fsched, data_fn, cohort_size=4, n_ticks=1)
    t0 = time.time()
    ceng.run_continuous(chan, fsched, data_fn, cohort_size=4,
                        n_ticks=n_ticks, merge_every=3,
                        migration_policy="keep")
    chan.drain()
    dt = max(time.time() - t0, 1e-9)
    fq = fsvc.queue
    assert fq.bytes_sent == (fq.bytes_delivered + fq.bytes_dropped +
                             fq.bytes_rejected + fq.bytes_duplicate +
                             fq.bytes_in_flight), \
        "uplink byte ledger leaked under chaos"
    assert sum(chan.faults.values()) > 0, "fault plan never fired"
    _emit("server", "goodput_under_faults",
          f"{fq.bytes_delivered / dt:.0f}",
          extra=f"delivered B/s, {sum(chan.faults.values())} faults + "
                f"{chan.retries} retries priced in")
    _emit("server", "faults_injected", sum(chan.faults.values()),
          extra=", ".join(f"{k}={v}"
                          for k, v in sorted(chan.faults.items())))
    _emit("server", "fault_retries", chan.retries)

    t0 = time.time()
    recovered = ContinuousIngestService.recover(
        root, ccfg, OC.server_init(key, ccfg),
        capacity=4, defer_depth=3,
        decode_policy=BulkDecodePolicy(min_batch=1, max_batch=64))
    rec_s = time.time() - t0
    assert recovered.tick_idx == fsvc.tick_idx
    assert recovered.verdicts == fsvc.verdicts, \
        "recovered verdict histogram diverged"
    assert recovered.queue.bytes_sent == fq.bytes_sent
    _emit("server", "recovery_time_s", f"{rec_s:.3f}",
          extra=f"snapshot + journal replay to tick {recovered.tick_idx}")


# ---------------------------------------------------------------- decode

def bench_decode(key):
    """Step 6 ingest hot path: fused packed->feature decode
    (ops.decode_codes, one pass, no index/atom tensors in HBM) vs the
    unpack-then-dequantize baseline, both jitted (schema in the module
    docstring)."""
    import numpy as np
    from repro.core import octopus as OC
    from repro.core.dvqae import DVQAEConfig
    from repro.kernels import ops
    from repro.wire import CodePayload

    n_samples = 2_000 if C.QUICK else 20_000
    T = 64                                    # codes per sample
    cases = [
        ("vq_k256", DVQAEConfig(kind="image", latent_dim=16,
                                codebook_size=256)),
        ("gsvq_g16s4", DVQAEConfig(kind="image", latent_dim=16,
                                   codebook_size=64, n_groups=16,
                                   n_slices=4)),
    ]
    rng = np.random.default_rng(0)
    for name, cfg in cases:
        cb = jax.random.normal(key, (cfg.codebook_size, cfg.latent_dim))
        bits = OC.transmit_bits(cfg)
        gsvq = cfg.n_groups > 1 or cfg.n_slices > 1
        shape = (n_samples, T, cfg.n_slices) if gsvq else (n_samples, T)
        hi = cfg.n_groups if gsvq else cfg.codebook_size
        idx = jnp.asarray(rng.integers(0, hi, size=shape), jnp.int32)
        payload = jax.block_until_ready(ops.pack_codes(idx, bits=bits))
        packed = CodePayload(payload=payload, bits=bits, shape=shape)

        fused_fn = jax.jit(lambda w: OC.codes_to_features(
            None, cfg, CodePayload(payload=w, bits=bits, shape=shape),
            codebook=cb))
        base_fn = jax.jit(lambda w: OC.codes_to_features(
            None, cfg, ops.unpack_codes(w, bits=bits,
                                        count=packed.count).reshape(shape),
            codebook=cb))
        jax.block_until_ready(fused_fn(payload))          # compile
        jax.block_until_ready(base_fn(payload))

        def timeit(fn, n=3 if C.QUICK else 10):
            t0 = time.time()
            for _ in range(n):
                out = fn(payload)
            jax.block_until_ready(out)
            return (time.time() - t0) / n

        t_fused, t_base = timeit(fused_fn), timeit(base_fn)
        gb = packed.nbytes / 1e9
        _emit("decode", f"{name}_fused_samples_per_sec",
              f"{n_samples / t_fused:.0f}", extra=f"{bits}bits_per_code")
        _emit("decode", f"{name}_baseline_samples_per_sec",
              f"{n_samples / t_base:.0f}")
        _emit("decode", f"{name}_fused_gbps", f"{gb / t_fused:.4f}")
        _emit("decode", f"{name}_baseline_gbps", f"{gb / t_base:.4f}")
        _emit("decode", f"{name}_speedup", f"{t_base / t_fused:.2f}",
              extra=f"{t_fused * 1e3:.1f}ms_fused")
    _emit("decode", "interpret_mode", 1,
          extra="fused path timed in Pallas interpret mode on CPU; TPU "
                "timings require hardware (cf. kernels section)")


# ---------------------------------------------------------------- encode

def bench_encode(key):
    """Client uplink hot path (§2.2 Steps 3-5, §3.8 encode latency):
    single-encode round + fused quantize-pack-stats (ops.encode_codes)
    vs the seed pipeline — forward for the indices, forward + encode
    AGAIN for the EMA refresh, then separate quantize/pack/ema dispatches
    (schema in the module docstring)."""
    from repro.core import dvqae, ema as EMA, octopus as OC
    from repro.core.disentangle import instance_norm_latent
    from repro.core.dvqae import DVQAEConfig, forward
    from repro.kernels import ops
    from repro.wire import round_words

    B = 32 if C.QUICK else 128
    cases = [
        ("vq_k256", DVQAEConfig(kind="image", in_channels=3, hidden=32,
                                latent_dim=16, codebook_size=256,
                                n_res_blocks=1)),
        ("gsvq_g16s4", DVQAEConfig(kind="image", in_channels=3, hidden=32,
                                   latent_dim=16, codebook_size=64,
                                   n_groups=16, n_slices=4,
                                   n_res_blocks=1)),
    ]
    rounds = 3 if C.QUICK else 10
    for name, cfg in cases:
        bits = OC.transmit_bits(cfg)
        server = OC.server_init(key, cfg)
        client = OC.client_init(server)
        x = jax.random.normal(key, (B, 16, 16, 3))

        fused_fn = jax.jit(lambda c, x: round_words(
            c, cfg, x, n_local_steps=0))

        # the seed ran Steps 3-4 and Step 5 as separate entry points,
        # each re-deriving the same latents with its own network pass
        # (client_transmit: full forward; client_codebook_refresh:
        # forward + encode — XLA dedupes those two within the dispatch,
        # but not across the two dispatches)
        def legacy_transmit(client, x, cfg=cfg, bits=bits):
            idx = forward(client.params, cfg, x).latent.indices
            return ops.pack_codes(idx, bits=bits)

        def legacy_refresh(client, x, cfg=cfg):
            out = forward(client.params, cfg, x)
            z_e, _ = dvqae.encode(client.params, cfg, x)
            z = instance_norm_latent(z_e) if cfg.apply_in else z_e
            rep = out.latent.indices
            if cfg.n_groups > 1 or cfg.n_slices > 1:
                ng = cfg.codebook_size // cfg.n_groups
                rep = rep * ng + ng // 2
                z = jnp.broadcast_to(z[..., None, :],
                                     rep.shape + z.shape[-1:])
            return EMA.ema_update(client.ema, z, rep, gamma=0.99)

        t_jit, r_jit = jax.jit(legacy_transmit), jax.jit(legacy_refresh)

        def legacy_round(client, x):
            payload = t_jit(client, x)
            return r_jit(client, x).codebook, payload

        _, words = fused_fn(client, x)                         # compile
        jax.block_until_ready(words)
        _, payload = legacy_round(client, x)
        jax.block_until_ready(payload)
        assert words.nbytes == payload.nbytes                  # same uplink

        def timeit(fn):
            t0 = time.time()
            for _ in range(rounds):
                out = fn(client, x)
            jax.block_until_ready(out)   # BOTH outputs — the baseline's
            return (time.time() - t0) / rounds   # refresh is a 2nd dispatch

        # interleave and keep the min — single passes are noise-dominated
        # at smoke scale on a shared CPU
        t_fused = min(timeit(fused_fn) for _ in range(5))
        t_base = min(timeit(legacy_round) for _ in range(5))
        gb = words.size * words.dtype.itemsize / 1e9
        _emit("encode", f"{name}_fused_samples_per_sec",
              f"{B / t_fused:.0f}", extra=f"{bits}bits_per_code")
        _emit("encode", f"{name}_baseline_samples_per_sec",
              f"{B / t_base:.0f}")
        _emit("encode", f"{name}_fused_gbps", f"{gb / t_fused:.5f}")
        _emit("encode", f"{name}_baseline_gbps", f"{gb / t_base:.5f}")
        _emit("encode", f"{name}_speedup", f"{t_base / t_fused:.2f}",
              extra=f"{t_fused * 1e3:.1f}ms_fused")

    # acceptance: the round runs the encoder exactly ONCE (counted, not
    # inferred) — the seed path ran three network passes for the same z
    from repro.obs import dispatch_monitor
    cfg = cases[0][1]
    server = OC.server_init(key, cfg)
    client = OC.client_init(server)
    x = jax.random.normal(key, (4, 16, 16, 3))
    with dispatch_monitor() as counts:
        OC.client_round(client, cfg, x, n_local_steps=0)
    _emit("encode", "encoder_passes_per_round", counts.encoder_passes,
          extra="seed_path=3")
    _emit("encode", "oracle_fallback", 1,
          extra="off-TPU ops.encode_codes runs the jnp oracle "
                "(bit-identical words); Pallas-kernel timings require "
                "hardware")


# ------------------------------------------------------------------ wire

def bench_wire(key):
    """Unified wire protocol: the OctopusClient/OctopusServer facade
    round vs the pure ``round_words`` core it wraps — must be
    dispatch-count neutral and bit-identical (schema in the module
    docstring)."""
    import numpy as np

    from repro.core import octopus as OC
    from repro.core.dvqae import DVQAEConfig
    from repro.wire import OctopusServer, round_words

    B = 32 if C.QUICK else 128
    rounds = 3 if C.QUICK else 10
    cfg = DVQAEConfig(kind="image", in_channels=3, hidden=32, latent_dim=16,
                      codebook_size=256, n_res_blocks=1)
    server = OC.server_init(key, cfg)
    client0 = OC.client_init(server)
    x = jax.random.normal(key, (B, 16, 16, 3))

    facade_fn = jax.jit(lambda c, xb: round_words(c, cfg, xb,
                                                  n_local_steps=0))
    _, words = facade_fn(client0, x)                       # compile
    jax.block_until_ready(words)
    # the facade's CodePayload carries exactly the pure core's words
    srv = OctopusServer(server, cfg)
    cl = srv.deploy()
    payload = cl.round(x, finetune=0)
    assert np.array_equal(np.asarray(payload.payload), np.asarray(words))
    _emit("wire", "bit_identical_to_fused", "True")

    def timeit(fn):
        t0 = time.time()
        for _ in range(rounds):
            out = fn(client0, x)
        jax.block_until_ready(out)
        return (time.time() - t0) / rounds

    t_facade = min(timeit(facade_fn) for _ in range(5))
    _emit("wire", "facade_samples_per_sec", f"{B / t_facade:.0f}")

    # dispatch neutrality, COUNTED (not inferred): encoder passes and
    # fused encode dispatches of one un-jitted facade round vs the pure
    # core, through the supported monitor (obs.dispatch_monitor)
    from repro.obs import dispatch_monitor

    with dispatch_monitor() as fcounts:
        cl.round(x, finetune=0)
    fe, fk = fcounts.encoder_passes, fcounts.encode_dispatches
    with dispatch_monitor() as lcounts:
        round_words(client0, cfg, x, n_local_steps=0)
    le, lk = lcounts.encoder_passes, lcounts.encode_dispatches
    _emit("wire", "facade_encoder_passes", fe, extra=f"fused={le}")
    _emit("wire", "facade_encode_dispatches", fk, extra=f"fused={lk}")
    assert (fe, fk) == (le, lk) == (1, 1)

    # wire roundtrip: payload bytes are the single accounting end to end
    payload = cl.round(x, finetune=0)
    srv.ingest(payload)
    feats, _ = srv.features()
    _emit("wire", "payload_bytes", payload.nbytes,
          extra=f"{payload.bits}bits_per_code")
    _emit("wire", "store_bytes_match", str(srv.store.total_bytes
                                           == payload.nbytes))
    _emit("wire", "decoded_samples", feats.shape[0])


# ----------------------------------------------------------------- privacy

def bench_privacy(key):
    """Red-team sweep (repro.privacy): attack-advantage-vs-knob curves,
    the leaky-control teeth check, membership inference, and the
    oblivious-store parity + overhead rows. Deterministic in ``key``."""
    from repro import privacy as P
    for r in P.run_sweep(key, quick=C.QUICK):
        extra = " ".join(f"{k}={v}" for k, v in sorted(r["extra"].items())) \
            if r.get("extra") else ""
        _emit("privacy", r["name"], r["value"], extra)


SECTIONS = {
    "fig4": bench_fig4,
    "fig5": bench_fig5,
    "table1": bench_table1,
    "fig9": bench_fig9,
    "sec2_8": bench_sec2_8,
    "sec3_8": bench_sec3_8,
    "kernels": bench_kernels,
    "gsvq": bench_gsvq,
    "sim": bench_sim,
    "server": bench_server,
    "decode": bench_decode,
    "encode": bench_encode,
    "wire": bench_wire,
    "privacy": bench_privacy,
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", "--section", dest="only", default="",
                    help="comma-separated subset of sections")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke scale (same as OCTOPUS_BENCH_QUICK=1)")
    args = ap.parse_args()
    if args.smoke:
        C.set_quick()
    run = [s.strip() for s in args.only.split(",") if s.strip()] or \
        list(SECTIONS)
    key = jax.random.PRNGKey(0)
    print("section,name,value,extra")
    for name in run:
        t0 = time.time()
        SECTIONS[name](key)
        wall = time.time() - t0
        _emit(name, "_section_wall_s", f"{wall:.1f}")
        _write_artifact(name, wall)


if __name__ == "__main__":
    main()

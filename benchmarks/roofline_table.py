"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline table.

    PYTHONPATH=src python -m benchmarks.roofline_table \
        [--dir experiments/dryrun] [--mesh 16x16] [--markdown]
"""
from __future__ import annotations

import argparse
import json
import os

ARCH_ORDER = ["jamba_v0_1_52b", "qwen3_0_6b", "chameleon_34b", "minicpm3_4b",
              "gemma_7b", "xlstm_350m", "starcoder2_3b", "whisper_base",
              "deepseek_v3_671b", "qwen3_moe_30b_a3b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dir_, mesh):
    rows = []
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            p = os.path.join(dir_, f"{arch}__{shape}__{mesh}.json")
            if not os.path.exists(p):
                continue
            with open(p) as f:
                rows.append(json.load(f))
    return rows


def fmt(rows, markdown=False):
    cols = ["arch", "shape", "compute_s", "memory_s", "collective_s",
            "bottleneck", "useful", "HBM/dev GB", "flops", "coll GB"]
    lines = []
    if markdown:
        lines.append("| " + " | ".join(cols) + " |")
        lines.append("|" + "---|" * len(cols))
    else:
        lines.append(" ".join(f"{c:>12s}" for c in cols))
    for r in rows:
        vals = [r["arch"], r["shape"],
                f"{r['compute_s']:.3e}", f"{r['memory_s']:.3e}",
                f"{r['collective_s']:.3e}", r["bottleneck"],
                f"{r['useful_ratio']:.3f}",
                f"{r['per_device_hbm_bytes']/1e9:.1f}",
                f"{r['hlo_flops']:.2e}",
                f"{r['collective_bytes']/1e9:.1f}"]
        if markdown:
            lines.append("| " + " | ".join(vals) + " |")
        else:
            lines.append(" ".join(f"{v:>12s}" for v in vals))
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = load(args.dir, args.mesh)
    print(fmt(rows, args.markdown))
    print(f"\n{len(rows)} combos")


if __name__ == "__main__":
    main()

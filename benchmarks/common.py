"""Shared benchmark machinery: builds the OCTOPUS pipeline on the synthetic
factorized image set and returns everything the per-table benchmarks need.

Sizes are CPU-tuned: they preserve every *relationship* the paper claims
(ordering of accuracies, orders of magnitude in bytes) at laptop scale.
Set OCTOPUS_BENCH_QUICK=1 to shrink further (CI smoke).
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.core import downstream as DS
from repro.core import octopus as OC
from repro.core.dvqae import DVQAEConfig, forward as dvqae_forward
from repro.data import holdout_atd, make_images, partition, train_test_split

QUICK = bool(int(os.environ.get("OCTOPUS_BENCH_QUICK", "0")))

N_DATA = 400 if QUICK else 1200
IMG = 16 if QUICK else 32
N_CLIENTS = 4 if QUICK else 8
N_IDENTITIES = 8
PRETRAIN_STEPS = 60 if QUICK else 250
PROBE_STEPS = 80 if QUICK else 250
FED_ROUNDS = 3 if QUICK else 8


def set_quick():
    """Flip every size knob to the CI smoke scale after import — what
    OCTOPUS_BENCH_QUICK=1 does at import time, for ``run.py --smoke``."""
    global QUICK, N_DATA, IMG, N_CLIENTS, PRETRAIN_STEPS, PROBE_STEPS, \
        FED_ROUNDS
    QUICK = True
    N_DATA, IMG, N_CLIENTS = 400, 16, 4
    PRETRAIN_STEPS, PROBE_STEPS, FED_ROUNDS = 60, 80, 3


@dataclass
class Pipeline:
    cfg: DVQAEConfig
    server: OC.ServerState
    train: object          # LabeledData (client-held)
    test: object
    atd: object
    shards_iid: list
    shards_worst: list
    shards_skew: list
    train_codes: jax.Array      # gathered latent features (train)
    test_codes: jax.Array
    bytes_transmitted: int


def content_label(d):
    return d.content


def style_label(d):
    return d.style


def build_pipeline(key, *, codebook_size: int = 256, apply_in: bool = True,
                   n_groups: int = 1, n_slices: int = 1) -> Pipeline:
    cfg = DVQAEConfig(kind="image", in_channels=3, hidden=32, latent_dim=16,
                      codebook_size=codebook_size, n_res_blocks=1,
                      apply_in=apply_in, encoder_in=apply_in,
                      n_groups=n_groups, n_slices=n_slices)
    kd, ks, kt = jax.random.split(key, 3)
    data = make_images(kd, N_DATA, size=IMG, n_identities=N_IDENTITIES)
    tr, te = train_test_split(data, 0.2)
    tr, atd = holdout_atd(tr, 0.15)

    # Step 1: server pretrains the global DVQ-AE on public ATD
    server = OC.server_init(ks, cfg)
    atd_x = atd.x
    step = jax.jit(lambda s, x: OC.server_pretrain_step(s, cfg, x),
                   static_argnums=())
    for i in range(PRETRAIN_STEPS):
        sel = jax.random.randint(jax.random.fold_in(ks, i), (32,), 0,
                                 atd_x.shape[0])
        server, _ = OC.server_pretrain_step(server, cfg, atd_x[sel])

    shards_iid = partition(tr, N_CLIENTS, regime="iid")
    shards_worst = partition(tr, N_CLIENTS, regime="worst")
    shards_skew = partition(tr, N_CLIENTS, regime="skewed", skew=0.2)

    # Steps 2-4: each (worst-case) client fine-tunes once and ships ONE
    # CodePayload through the wire facades; the server bulk-decodes
    from repro.wire import OctopusServer
    wire_srv = OctopusServer(server, cfg)
    for ci, shard in enumerate(shards_worst):
        client = wire_srv.deploy(client_id=ci)
        client.finetune(shard.x[:32])
        wire_srv.ingest(client.transmit(shard.x, labels=shard.content),
                        client_ids=[ci])
    total_bytes = wire_srv.store.total_bytes
    train_codes, label_dict = wire_srv.features()
    labels = label_dict["label"]

    test_codes = wire_srv.decode(wire_srv.deploy().transmit(te.x))

    # reorder train labels to match gathered order
    gathered_train = type(tr)(x=jnp.concatenate([s.x for s in shards_worst]),
                              content=labels,
                              style=jnp.concatenate(
                                  [s.style for s in shards_worst]))
    return Pipeline(cfg=cfg, server=server, train=gathered_train, test=te,
                    atd=atd, shards_iid=shards_iid,
                    shards_worst=shards_worst, shards_skew=shards_skew,
                    train_codes=train_codes, test_codes=test_codes,
                    bytes_transmitted=total_bytes)


def train_probe_on_codes(key, pipe: Pipeline, labels_tr, labels_te):
    in_dim = int(pipe.train_codes[0].size)
    probe = DS.init_linear_probe(key, in_dim, int(labels_tr.max()) + 1)
    probe = DS.sgd_train(key, DS.linear_probe, probe, pipe.train_codes,
                         labels_tr, steps=PROBE_STEPS)
    return DS.accuracy(DS.linear_probe, probe, pipe.test_codes, labels_te)


def train_conv_on_raw(key, x_tr, y_tr, x_te, y_te, steps=None):
    clf = DS.init_conv_classifier(key, in_channels=3,
                                  n_classes=int(y_tr.max()) + 1)
    clf = DS.sgd_train(key, DS.conv_classifier, clf, x_tr, y_tr,
                       steps=steps or PROBE_STEPS)
    return DS.accuracy(DS.conv_classifier, clf, x_te, y_te)


class Timer:
    def __init__(self):
        self.t0 = time.time()

    def ms(self):
        return (time.time() - self.t0) * 1000.0

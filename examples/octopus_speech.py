"""Speech scenario (§2.1's motivating example): phoneme content vs speaker
style. Clients transmit phoneme-bearing codes; speaker identity is filtered
by IN + VQ disentanglement; a style-transfer reconstruction demo shows the
private-component replacement of §3.3.

    PYTHONPATH=src python examples/octopus_speech.py
"""
import jax
import jax.numpy as jnp

from repro.core import downstream as DS
from repro.core import octopus as OC
from repro import privacy as PV
from repro.core.disentangle import perturb_private, recombine
from repro.core.dvqae import DVQAEConfig, decode, forward
from repro.data import make_speech, train_test_split
from repro.wire import OctopusClient, OctopusServer

key = jax.random.PRNGKey(0)
cfg = DVQAEConfig(kind="speech", in_channels=16, hidden=32, latent_dim=16,
                  codebook_size=128, n_res_blocks=1,
                  n_groups=8, n_slices=2)       # GSVQ enabled
data = make_speech(key, 600, frames=64, channels=16, n_speakers=8)
train, test = train_test_split(data, 0.2)

# server pretrain (the paper notes speech codebooks align with phonemes)
server = OC.server_init(key, cfg)
for i in range(250):
    sel = jax.random.randint(jax.random.fold_in(key, i), (32,), 0,
                             train.x.shape[0])
    server, out = OC.server_pretrain_step(server, cfg, train.x[sel])
print(f"recon loss {float(out.recon_loss):.4f}")

# wire session: one CodePayload uplink, one server-side decode
srv = OctopusServer(server, cfg)
client = OctopusClient(srv)
payload = client.transmit(train.x, labels=train.content)
srv.ingest(payload)
raw = train.x.size * 4
print(f"GSVQ codes: {payload.shape}, {payload.nbytes:,} bytes "
      f"({raw/payload.nbytes:.0f}x smaller than raw)")

feats, label_dict = srv.features()
probe = DS.init_linear_probe(key, int(feats[0].size), 16)
probe = DS.sgd_train(key, DS.linear_probe, probe, feats,
                     label_dict["label"], steps=250)
te_feats = srv.decode(client.transmit(test.x))
print(f"phoneme accuracy on codes: "
      f"{DS.accuracy(DS.linear_probe, probe, te_feats, test.content):.3f}")

adv = PV.train_adversary(key, te_feats, test.style, 8, steps=200)
m = PV.evaluate_adversary(adv, te_feats, test.style, 8)
print(f"speaker re-identification: acc={m.accuracy:.3f} "
      f"H(Y|Z)={m.conditional_entropy_bits:.2f} bits")

# ---- §3.3 style transformation: reconstruct with perturbed private part
out = forward(server.params, cfg, test.x[:4])
z_anon = recombine(out.latent.public,
                   perturb_private(key, out.latent.private, scale=1.0))
recon_anon = decode(server.params, cfg, z_anon)
print(f"anonymized reconstruction shape: {recon_anon.shape}; "
      f"distortion vs original: "
      f"{float(jnp.mean(jnp.square(recon_anon - test.x[:4]))):.4f}")

"""Cohort-streamed population engine at 100k-client scale (§2.2).

Successor to examples/octopus_async.py on the POPULATION axis: the async
runtime stacks every slot's state, which caps it at a few hundred
clients; here a :class:`repro.sim.CohortEngine` streams a round through
fixed-size cohorts — one compiled engine round reused per cohort, peak
memory one cohort's state — so a single host simulates a 100k-client
round. The demo shows the three contracts the property suite
(tests/test_cohort.py) pins bit-exactly:

  1. grouping invariance — the cohort-streamed round reproduces the
     one-shot population round bit-for-bit (merge stats, payload words,
     Σ bytes), via the exactly-associative int64 fixed-point Step-5
     accumulator (repro.core.ema.MergeStats);
  2. §2.8 accounting — Σ per-cohort CodePayload.nbytes == the population
     round's measured bytes (per-client padding included);
  3. traffic realism — a diurnal RoundScheduler profile breathes the
     per-round cohort count day/night, payloads stream into
     ``OctopusServer.ingest`` unchanged, stragglers ride the shared
     UplinkQueue, and every merge registers a codebook version.

Set ``OCTOPUS_TRACE=trace.jsonl`` to flight-record the whole run (every
encode dispatch, uplink, ingest, decode and merge — summarize with
``python -m repro.obs.report trace.jsonl``); ``OCTOPUS_BENCH_QUICK=1``
shrinks the population round to CI smoke scale.

    PYTHONPATH=src python examples/population_engine.py
"""
import os
import time

import jax
import numpy as np

from repro import obs
from repro.core import octopus as OC
from repro.core.dvqae import DVQAEConfig
from repro.server import (DiurnalProfile, OctopusServer, RoundScheduler,
                          SchedulerConfig)
from repro.sim import CohortEngine, CohortPlan
from repro.wire import concat_payloads

QUICK = os.environ.get("OCTOPUS_BENCH_QUICK", "") == "1"
if obs.active() is not None:
    print(f"flight recorder active -> {obs.active().path}")

key = jax.random.PRNGKey(0)
cfg = DVQAEConfig(kind="image", in_channels=3, hidden=8, latent_dim=8,
                  codebook_size=256, n_res_blocks=1)
server = OC.server_init(key, cfg)

# slot-id-keyed data: every client reads its own row of a shared pool,
# so ANY cohort grouping sees identical per-client batches
pool = jax.random.normal(key, (4096, 1, 8, 8, 3))
data_fn = lambda ids: pool[np.asarray(ids) % pool.shape[0]]

engine = CohortEngine(cfg, gamma=0.99, n_local_steps=0)

# ---- 1+2: bit-exact cohort parity at 4096 clients, then scale to 100k
n = 4096
full = engine.round(server, CohortPlan.from_groups([np.arange(n)]), data_fn)
parts = engine.round(server, CohortPlan.build(np.arange(n), 512), data_fn)
cat = concat_payloads(parts.payloads)
assert np.array_equal(parts.stats.num, full.stats.num)
assert np.array_equal(np.asarray(cat.payload),
                      np.asarray(full.payloads[0].payload))
assert parts.nbytes == full.nbytes
print(f"parity @ {n} clients: streamed round bit-matches one-shot round "
      f"({parts.nbytes} uplink bytes either way)")

N = 8_192 if QUICK else 102_400
plan = CohortPlan.build(np.arange(N), 1024)
engine.round(server, CohortPlan.from_groups([plan.cohorts[0]]),
             data_fn)                                   # compile the shape
t0 = time.time()
out = engine.round(server, plan, data_fn)
dt = time.time() - t0
print(f"population round: {N} clients in {dt:.1f}s "
      f"({N / dt:,.0f} clients/sec, {plan.n_cohorts} cohorts, "
      f"{out.nbytes} uplink bytes)")
server = OC.server_merge_stats(server, out.stats)       # Step 5 tail

# ---- 3: diurnal traffic through the wire endpoint
wire = OctopusServer(server, cfg)
sched = RoundScheduler(
    8192, SchedulerConfig(participation=0.5, straggler_prob=0.3,
                          drop_prob=0.05),
    key=jax.random.PRNGKey(7),
    profile=DiurnalProfile(period=6, trough=0.25), quantum=512)
hist = engine.run_traffic(wire, sched, data_fn, cohort_size=512,
                          n_rounds=6, merge_every=3)
for h in hist:
    print(f"round {h.round}: {h.n_participants:5d} clients in "
          f"{h.n_cohorts} cohorts, sent {h.bytes_sent}B, "
          f"delivered {h.bytes_delivered}B"
          + (f", merged -> v{h.merged_version}" if h.merged_version
             else ""))
feats, _ = wire.features()
print(f"store: {len(wire.store)} payloads across codebook versions, "
      f"{feats.shape[0]} samples decoded version-correctly")

"""End-to-end driver: train a ~100M-parameter LM backbone on OCTOPUS codes.

This is the framework-scale integration: OCTOPUS's DVQ-AE acts as the
distributed tokenizer (clients transmit code indices); the server-side
backbone (any ``--arch``, here a deeper qwen3-family variant) trains on the
gathered code sequences with the production train_step under a host mesh.

    PYTHONPATH=src python examples/train_lm_on_codes.py --steps 200

(~100M params by default; use --small for a fast CI-sized run.)
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.configs.base import ShapeConfig, TrainConfig
from repro.core import octopus as OC
from repro.core.dvqae import DVQAEConfig
from repro.data import make_speech
from repro.distributed import steps as S
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.optim.adamw import adamw_init

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--small", action="store_true")
args = ap.parse_args()

key = jax.random.PRNGKey(0)

# ---------------------------------------------- OCTOPUS codes as LM tokens
K = 256
dvq = DVQAEConfig(kind="speech", in_channels=16, hidden=32, latent_dim=16,
                  codebook_size=K, n_res_blocks=1)
server = OC.server_init(key, dvq)
clips = make_speech(key, 256, frames=256, channels=16)
for i in range(100):
    sel = jax.random.randint(jax.random.fold_in(key, i), (16,), 0, 256)
    server, _ = OC.server_pretrain_step(server, dvq, clips.x[sel])
from repro.wire import OctopusClient
payload = OctopusClient(server, dvq).transmit(clips.x)   # CodePayload uplink
codes = payload.unpack()[0]              # (256, 64) int32 in [0, K)
print(f"gathered {codes.shape} code sequences "
      f"({payload.nbytes:,} bytes transmitted)")

# -------------------------------------------------- backbone on the codes
base = smoke_config("qwen3_0_6b")
if args.small:
    cfg = base.replace(vocab_size=K)
else:
    cfg = base.replace(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                       head_dim=64, d_ff=2048, vocab_size=K,
                       tie_embeddings=True)
print(f"backbone: {cfg.param_count()/1e6:.1f}M params")

mesh = make_host_mesh()
seq = codes.shape[1]
shape = ShapeConfig("codes", seq, args.batch, "train")
tcfg = TrainConfig(learning_rate=3e-4, total_steps=args.steps,
                   warmup_steps=max(1, args.steps // 10))
fn, in_specs, out_specs, _ = S.build_train_step(cfg, tcfg, mesh, shape)

with mesh:
    params = T.init_lm(key, cfg)
    state = S.TrainState(params=params, opt=adamw_init(params),
                         step=jnp.zeros((), jnp.int32))
    jstep = jax.jit(fn, in_shardings=S.shd_to(in_specs, mesh),
                    out_shardings=S.shd_to(out_specs, mesh),
                    donate_argnums=(0,))
    t0 = time.time()
    for i in range(args.steps):
        sel = jax.random.randint(jax.random.fold_in(key, 10_000 + i),
                                 (args.batch,), 0, codes.shape[0])
        state, loss = jstep(state, {"tokens": codes[sel]})
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {float(loss):.4f} "
                  f"({args.batch*seq*(i+1)/(time.time()-t0):,.0f} tok/s)")
print("LM-on-codes training done.")

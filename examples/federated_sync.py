"""Full OCTOPUS federation with temporal drift (§2.6 Flexible & Stabilized
Training): clients see a DISTRIBUTION SHIFT mid-stream; instead of
retraining, each client refreshes its codebook by EMA (Eq. 9) on new data
and syncs to the server, which merges the codebooks count-weighted
(Step 5). Shows recon quality recovering after the sync without touching
encoder/decoder weights.

    PYTHONPATH=src python examples/federated_sync.py
"""
import jax
import jax.numpy as jnp

from repro.core import octopus as OC
from repro.core.dvqae import DVQAEConfig, forward
from repro.data import make_images, partition

key = jax.random.PRNGKey(0)
cfg = DVQAEConfig(kind="image", in_channels=3, hidden=32, latent_dim=16,
                  codebook_size=128, n_res_blocks=1)

# phase-1 data and a drifted phase-2 (brighter, shifted styles)
d1 = make_images(key, 400, size=32, n_identities=8)
d2_raw = make_images(jax.random.PRNGKey(42), 400, size=32, n_identities=8)
d2 = type(d2_raw)(x=d2_raw.x * 1.6 + 0.8, content=d2_raw.content,
                  style=d2_raw.style)

server = OC.server_init(key, cfg)
for i in range(250):
    sel = jax.random.randint(jax.random.fold_in(key, i), (32,), 0, 400)
    server, out = OC.server_pretrain_step(server, cfg, d1.x[sel])
print(f"phase-1 recon loss: {float(out.recon_loss):.4f}")

clients = [OC.client_init(server) for _ in range(4)]
shards2 = partition(d2, 4, regime="worst")


def recon_loss(client, x):
    return float(forward(client.params, cfg, x).recon_loss)


drifted = sum(recon_loss(c, s.x[:64]) for c, s in zip(clients, shards2)) / 4
print(f"recon on drifted phase-2 data BEFORE codebook refresh: {drifted:.4f}")

# Step 5: low-frequency EMA refresh on each client, then server merge
for r in range(20):
    clients = [OC.client_codebook_refresh(c, cfg, s.x[:64], gamma=0.9)
               for c, s in zip(clients, shards2)]
after = sum(recon_loss(c, s.x[:64]) for c, s in zip(clients, shards2)) / 4
print(f"recon AFTER {20} EMA refreshes (no gradient training): {after:.4f}")

server = OC.server_merge_codebooks(
    server, [c.params["codebook"] for c in clients],
    [c.ema.counts for c in clients])
merged_client = OC.client_init(server)
merged = sum(recon_loss(merged_client, s.x[:64]) for s in shards2) / 4
print(f"recon with the MERGED global dictionary: {merged:.4f}")
print(f"improvement from pure codebook updates: "
      f"{(drifted - after) / drifted * 100:.1f}%")

"""Full OCTOPUS federation with temporal drift (§2.6 Flexible & Stabilized
Training), run on the batched sim engine (repro.sim): clients see a
DISTRIBUTION SHIFT mid-stream; instead of retraining, each client
refreshes its codebook by EMA (Eq. 9) on new data and syncs to the
server, which merges the codebooks count-weighted (Step 5). The whole
client population advances in ONE jitted vmap call per round, and every
round's uplink is the measured bit-packed payload (§2.8), not a formula.
Shows recon quality recovering after the sync without touching
encoder/decoder weights.

    PYTHONPATH=src python examples/federated_sync.py
"""
import jax
import jax.numpy as jnp

from repro.core import octopus as OC
from repro.core.dvqae import DVQAEConfig, forward
from repro.data import make_images, partition_stacked
from repro.sim import SimEngine

key = jax.random.PRNGKey(0)
cfg = DVQAEConfig(kind="image", in_channels=3, hidden=32, latent_dim=16,
                  codebook_size=128, n_res_blocks=1)

# phase-1 data and a drifted phase-2 (brighter, shifted styles)
d1 = make_images(key, 400, size=32, n_identities=8)
d2_raw = make_images(jax.random.PRNGKey(42), 400, size=32, n_identities=8)
d2 = type(d2_raw)(x=d2_raw.x * 1.6 + 0.8, content=d2_raw.content,
                  style=d2_raw.style)

server = OC.server_init(key, cfg)
for i in range(250):
    sel = jax.random.randint(jax.random.fold_in(key, i), (32,), 0, 400)
    server, out = OC.server_pretrain_step(server, cfg, d1.x[sel])
print(f"phase-1 recon loss: {float(out.recon_loss):.4f}")

# Step 2 deployment: 4 clients as ONE stacked pytree; phase-2 shards
# stacked (C, n, ...) so the population advances per engine call.
N_CLIENTS = 4
shards2 = partition_stacked(d2, N_CLIENTS, regime="worst")
x2 = shards2.x[:, :64]                                  # (C, 64, H, W, 3)

# n_local_steps=0: refresh-only rounds — the §2.6 story is that the
# codebook EMA alone absorbs the drift, with NO gradient training.
engine = SimEngine(cfg, gamma=0.9, n_local_steps=0)
clients = engine.init_clients(server, N_CLIENTS)


def mean_recon(clients, x):
    losses = jax.vmap(lambda p, xb: forward(p, cfg, xb).recon_loss)(
        clients.params, x)
    return float(jnp.mean(losses))


drifted = mean_recon(clients, x2)
print(f"recon on drifted phase-2 data BEFORE codebook refresh: {drifted:.4f}")

# Step 5: low-frequency EMA refresh, whole population per jitted call;
# Steps 3-4 ride along as a measured bit-packed repro.wire.CodePayload
# (one per-client record stream, the unified wire carrier).
uplink = 0
for r in range(20):
    clients, packed = engine.round(clients, x2)
    uplink += packed.nbytes
after = mean_recon(clients, x2)
print(f"recon AFTER 20 EMA refreshes (no gradient training): {after:.4f}")
print(f"measured uplink: {uplink} bytes over 20 rounds "
      f"({packed.bits} bits/code, raw would be {20 * x2.size * 4} bytes)")

server = engine.merge_into_server(server, clients)
merged = engine.init_clients(server, N_CLIENTS)
print(f"recon with the MERGED global dictionary: "
      f"{mean_recon(merged, x2):.4f}")
print(f"improvement from pure codebook updates: "
      f"{(drifted - after) / drifted * 100:.1f}%")

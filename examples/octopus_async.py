"""Continuous-ingest server runtime end-to-end (Step 6 as a service).

Successor to the round-quantized async example: the server side is now
the clocked ``ContinuousIngestService`` — a Poisson ``RoundScheduler``
emits open-ended client arrivals (stragglers, radio drops, join/leave
churn); every uplink is a ``repro.wire.CodePayload`` offered through
ADMISSION CONTROL, so each one gets a structured verdict (accepted /
migrated / deferred / rejected) instead of silently landing; admitted
payloads flow through a bounded UplinkQueue into a
``(codebook version, client shard)``-partitioned ShardedCodeStore with
ring-buffer eviction; Step 5 merges happen mid-stream and open ROLLING
MIGRATION windows (``v_n -> v_{n+1}``), so payloads of both versions
ingest concurrently while the CodebookRegistry keeps every snapshot
pinned for bit-exact decode; background bulk-decode batches amortize
the packed->feature kernel across records; and a MultiTaskTrainer fits
TWO downstream heads (content classifier + identity adversary, the
paper's Fig. 5 pairing) from ONE decode of the surviving store.

Set ``OCTOPUS_TRACE=trace.jsonl`` to flight-record the run, then audit
it (byte conservation across refusals included) with
``python -m repro.obs.report trace.jsonl --check``.

    PYTHONPATH=src python examples/octopus_async.py
"""
import time

import jax
import numpy as np

from repro import obs
from repro.core import octopus as OC
from repro.core.dvqae import DVQAEConfig
from repro.data import make_images, partition_stacked
from repro.server import (BulkDecodePolicy, ContinuousIngestService,
                          MultiTaskTrainer, RoundScheduler, SchedulerConfig,
                          ShardedCodeStore, TaskSpec)
from repro.sim import CohortEngine
from repro.wire import OctopusServer

rec = obs.install_from_env()                 # OCTOPUS_TRACE=... to record

key = jax.random.PRNGKey(0)
cfg = DVQAEConfig(kind="image", in_channels=3, hidden=16, latent_dim=16,
                  codebook_size=64, n_res_blocks=1)

N_SLOTS, COHORT, TICKS = 16, 4, 24
data = make_images(key, 640, size=16, n_identities=4)

# Step 1: pretrain the global DVQ-AE on (public) data
server0, out = OC.server_pretrain(key, OC.server_init(key, cfg), cfg,
                                  data.x, steps=80)
print(f"pretrain recon loss: {float(out.recon_loss):.4f}")

stacked = partition_stacked(data, N_SLOTS, regime="skewed", skew=0.2)


def data_fn(ids):
    return stacked.x[np.asarray(ids) % N_SLOTS, :COHORT]


def labels_fn(ids):
    sel = np.asarray(ids) % N_SLOTS
    return {"content": stacked.content[sel, :COHORT],
            "style": stacked.style[sel, :COHORT]}


# the service: a deliberately tight queue so churny bursts actually hit
# backpressure, a sharded store bounding memory per (version, shard),
# and a bulk-decode policy amortizing the fused decode kernel
srv = OctopusServer(server0, cfg,
                    store=ShardedCodeStore(cfg, n_shards=4,
                                           capacity_samples=2048))
service = ContinuousIngestService(
    srv, capacity=3, defer_depth=2,
    decode_policy=BulkDecodePolicy(min_batch=2, max_batch=64,
                                   interval_ticks=2))
sched = RoundScheduler(
    N_SLOTS,
    SchedulerConfig(rate=6.0, straggler_prob=0.4, max_delay=2,
                    drop_prob=0.1, leave_prob=0.2, join_prob=0.5),
    key=jax.random.PRNGKey(7))
engine = CohortEngine(cfg, gamma=0.95, n_local_steps=0)

# warm the per-cohort compile, then run the soak: merges every 6 ticks,
# each one opening a rolling keep-policy migration window
engine.run_continuous(service, sched, data_fn, cohort_size=COHORT,
                      n_ticks=1, labels_fn=labels_fn)
t0 = time.time()
hist = engine.run_continuous(service, sched, data_fn, cohort_size=COHORT,
                             n_ticks=TICKS, merge_every=6,
                             labels_fn=labels_fn, migration_policy="keep")
service.drain()
dt = max(time.time() - t0, 1e-9)

n_up = sum(service.verdicts.values())
print(f"\n{TICKS} ticks, {sum(t.n_participants for t in hist)} arrivals, "
      f"{n_up / dt:.1f} uplinks/sec sustained (post-compile)")
print("admission verdicts: "
      + ", ".join(f"{v}={service.verdicts.get(v, 0)}"
                  for v in ("accepted", "migrated", "deferred", "rejected")))

q = service.queue
print(f"uplink bytes: sent={q.bytes_sent} delivered={q.bytes_delivered} "
      f"dropped={q.bytes_dropped} rejected={q.bytes_rejected} "
      f"duplicate={q.bytes_duplicate} in_flight={q.bytes_in_flight}")
assert q.bytes_sent == (q.bytes_delivered + q.bytes_dropped
                        + q.bytes_rejected + q.bytes_duplicate
                        + q.bytes_in_flight)
print("byte ledger conserved across refusals: OK")

store = srv.store
print(f"store: {len(store)} records / {store.n_samples} samples across "
      f"{len(store.partitions)} (version, shard) partitions, "
      f"evicted={store.evicted_records} records "
      f"({store.evicted_bytes}B stay ledgered)")
print(f"registry: latest v{srv.registry.latest}, "
      f"{srv.registry.latest} rolling migrations completed, "
      f"decode amortization {service.decode_amortization:.2f} "
      f"records/dispatch")

# every surviving record still decodes against the snapshot it was
# packed under — bit-exact across all the mid-stream merges
for r in store.records:
    now = OC.codes_to_features(None, cfg, r.packed,
                               codebook=srv.registry.get(r.version))
    ref = srv.decode(r.packed)
    assert np.array_equal(np.asarray(now).reshape(np.asarray(ref).shape),
                          np.asarray(ref)), r.version
print(f"bit-exact decode for versions {store.versions}: OK")

# Step 6: TWO downstream heads from ONE decode of the shared store
feats, labels = srv.features()
tasks = [TaskSpec("content", int(stacked.content.max()) + 1),
         TaskSpec("style", int(stacked.style.max()) + 1)]
trainer = MultiTaskTrainer(key, tasks, int(feats[0].size))
trainer.fit(key, feats, labels, steps=150, batch=64)
acc = trainer.accuracy(feats, labels)
print("multi-task from one decode: "
      + ", ".join(f"{t}={a:.3f}" for t, a in acc.items()))

if rec is not None:
    obs.uninstall()
    rec.close()
    print(f"flight recording written to {rec.path}")

"""Asynchronous code-server runtime end-to-end (Step 6 as a subsystem).

Successor to examples/federated_sync.py: instead of a hand-rolled loop
over one engine call, the server side is the repro.server runtime — a
RoundScheduler decides who participates, straggles, drops out or churns;
every uplink is a ``repro.wire.CodePayload`` carrying its OWN codebook
version and label channels, delivered through the single wire endpoint
(``OctopusServer.ingest``) into a versioned CodeStore; the
CodebookRegistry pins every Step 5 merge so late payloads decode against
the dictionary they were packed under; and a MultiTaskTrainer fits TWO
downstream heads (content classifier + identity adversary, the paper's
Fig. 5 pairing) from ONE bulk decode of the store.

Three scheduler scenarios, same jitted population round:
  full     every slot participates, no failures
  partial  25 % participation + geometric stragglers + dropped uplinks
  churn    join/leave churn with merges every 2 rounds -> stragglers and
           re-joiners carry codebook-version lag into the store

    PYTHONPATH=src python examples/octopus_async.py
"""
import time

import jax
import numpy as np

from repro.core import octopus as OC
from repro.core.dvqae import DVQAEConfig
from repro.data import make_images, partition_stacked, stacked_batches
from repro.server import (STANDARD_SCENARIOS, AsyncCodeServer,
                          MultiTaskTrainer, RoundScheduler, TaskSpec)
from repro.sim import SimEngine

key = jax.random.PRNGKey(0)
cfg = DVQAEConfig(kind="image", in_channels=3, hidden=16, latent_dim=16,
                  codebook_size=64, n_res_blocks=1)

N_SLOTS, LOCAL_B, ROUNDS = 8, 8, 8
data = make_images(key, 640, size=16, n_identities=4)

# Step 1: pretrain the global DVQ-AE on (public) data
server0, out = OC.server_pretrain(key, OC.server_init(key, cfg), cfg,
                                  data.x, steps=80)
print(f"pretrain recon loss: {float(out.recon_loss):.4f}")

stacked = partition_stacked(data, N_SLOTS, regime="skewed", skew=0.2)
engine = SimEngine(cfg, lr=1e-4, gamma=0.95)          # shared jit cache

for name, sc in STANDARD_SCENARIOS.items():
    sched = RoundScheduler(N_SLOTS, sc.sched, key=jax.random.PRNGKey(7))
    srv = AsyncCodeServer(engine, server0, sched,
                          merge_every=sc.merge_every,
                          staleness_decay=0.5)
    batches = stacked_batches(stacked, LOCAL_B, epochs=ROUNDS, seed=3)

    # reference features captured the round each payload LANDS (fused
    # wire decode against its own version) — re-decoded at the end via
    # the index path to show the store stays bit-exact across merges
    refs = []
    t0, timed = time.time(), 0.0
    for r, b in zip(range(ROUNDS), batches):
        if r == 1:
            t0 = time.time()            # round 0 pays compilation
        stats = srv.run_round(b.x, labels={"content": b.content,
                                           "style": b.style})
        if r >= 1:
            timed = time.time() - t0
        for rec in srv.store.records[len(refs):]:
            refs.append((rec.version,
                         np.asarray(srv.wire.decode(rec.packed))))

    rps = (ROUNDS - 1) / max(timed, 1e-9)
    print(f"\n[{name}] {ROUNDS} rounds, {rps:.2f} rounds/sec (post-compile)")
    print(f"[{name}] uplink bytes: sent={srv.bytes_sent} "
          f"delivered={srv.bytes_delivered} dropped={srv.bytes_dropped} "
          f"in_flight={srv.in_flight}")
    print(f"[{name}] store: {len(srv.store)} records, "
          f"{srv.store.n_samples} samples, versions={srv.store.versions}, "
          f"merges={srv.n_merges} (registry latest v{srv.registry.latest})")

    # version-correct decode stays bit-exact after the run's merges
    for (version, ref), rec in zip(refs, srv.store.records):
        codes = rec.packed.unpack().reshape((-1,) + rec.packed.shape[2:])
        now = OC.codes_to_features(None, cfg, codes,
                                   codebook=srv.registry.get(version))
        assert np.array_equal(np.asarray(now), ref), (name, version)
    print(f"[{name}] bit-exact decode for versions "
          f"{sorted(set(v for v, _ in refs))} after {srv.n_merges} merges: OK")

    # Step 6: TWO downstream heads from ONE decode of the shared store
    feats, labels = srv.dataset()
    tasks = [TaskSpec("content", int(stacked.content.max()) + 1),
             TaskSpec("style", int(stacked.style.max()) + 1)]
    trainer = MultiTaskTrainer(key, tasks, int(feats[0].size))
    trainer.fit(key, feats, labels, steps=150, batch=64)
    acc = trainer.accuracy(feats, labels)
    print(f"[{name}] multi-task from one decode: "
          + ", ".join(f"{t}={a:.3f}" for t, a in acc.items()))

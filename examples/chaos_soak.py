"""Chaos soak + crash drill: faulted uplinks into a journaled service,
then a mid-stream kill and a bit-exact recovery.

The continuous-ingest runtime under the conditions OCTOPUS actually
assumes (§2.7-§2.8: flaky edge uplinks are the norm, not the
exception): every cohort payload crosses a ``FaultyChannel`` that
deterministically drops, duplicates, reorders, delays, corrupts and
truncates on its own PRNG substreams; clients retransmit transient
failures under ``(client_id, seq)`` idempotency envelopes
(``RetryPolicy`` backoff), so ingest stays exactly-once over an
at-least-once channel; every admitted offer / tick / merge / migration
is journaled through ``ServerPersistence`` with periodic snapshots.

Halfway through, the service is KILLED (abandoned mid-migration) and
``ContinuousIngestService.recover`` rebuilds it from snapshot + journal
replay — the drill asserts the recovered verdict histogram, byte
ledger and decoded features are EXACTLY the crashed service's, then
keeps serving traffic on the recovered instance.

Set ``OCTOPUS_TRACE=chaos.jsonl`` to flight-record the run, then audit
it (fault histogram + §2.8 conservation incl. duplicates) with
``python -m repro.obs.report chaos.jsonl --check``.

    PYTHONPATH=src python examples/chaos_soak.py
"""
import os
import tempfile
import time

import jax
import numpy as np

from repro import obs
from repro.core import octopus as OC
from repro.core.dvqae import DVQAEConfig
from repro.data import make_images, partition_stacked
from repro.server import (BulkDecodePolicy, ContinuousIngestService,
                          RoundScheduler, SchedulerConfig,
                          ServerPersistence, ShardedCodeStore)
from repro.sim import CohortEngine, FaultPlan, FaultyChannel
from repro.wire import OctopusServer, RetryPolicy

rec = obs.install_from_env()                 # OCTOPUS_TRACE=... to record

key = jax.random.PRNGKey(0)
cfg = DVQAEConfig(kind="image", in_channels=3, hidden=16, latent_dim=16,
                  codebook_size=64, n_res_blocks=1)

N_SLOTS, COHORT, TICKS = 16, 4, 12
data = make_images(key, 640, size=16, n_identities=4)

server0, out = OC.server_pretrain(key, OC.server_init(key, cfg), cfg,
                                  data.x, steps=40)
print(f"pretrain recon loss: {float(out.recon_loss):.4f}")

stacked = partition_stacked(data, N_SLOTS, regime="skewed", skew=0.2)


def data_fn(ids):
    return stacked.x[np.asarray(ids) % N_SLOTS, :COHORT]


root = os.path.join(tempfile.mkdtemp(prefix="octopus_chaos_"), "srv")


def build_service():
    srv = OctopusServer(server0, cfg,
                        store=ShardedCodeStore(cfg, n_shards=2,
                                               capacity_samples=4096))
    return ContinuousIngestService(
        srv, capacity=6, defer_depth=4,
        decode_policy=BulkDecodePolicy(min_batch=2, max_batch=64,
                                       interval_ticks=2),
        persist=ServerPersistence(root, snapshot_every=5))


PLAN = FaultPlan(drop=0.15, duplicate=0.15, reorder=0.2, delay=0.3,
                 corrupt=0.1, truncate=0.1)
service = build_service()
chan = FaultyChannel(service, PLAN, key=jax.random.PRNGKey(3),
                     retry=RetryPolicy(max_attempts=3))
sched = RoundScheduler(
    N_SLOTS,
    SchedulerConfig(rate=6.0, straggler_prob=0.4, max_delay=2,
                    drop_prob=0.1, leave_prob=0.2, join_prob=0.5),
    key=jax.random.PRNGKey(7))
engine = CohortEngine(cfg, gamma=0.95, n_local_steps=0)

# phase 1: chaos soak — merges every 4 ticks, each opening a rolling
# migration window, all of it journaled
t0 = time.time()
hist = engine.run_continuous(chan, sched, data_fn, cohort_size=COHORT,
                             n_ticks=TICKS, merge_every=4,
                             migration_policy="keep")
dt = max(time.time() - t0, 1e-9)
n_up = sum(service.verdicts.values())
print(f"\n{TICKS} faulted ticks, {n_up} offers, "
      f"{n_up / dt:.1f} uplinks/sec under chaos "
      f"({sum(chan.faults.values())} faults injected: "
      + ", ".join(f"{k}={v}" for k, v in sorted(chan.faults.items()))
      + f", {chan.retries} retries)")

q = service.queue
assert q.bytes_sent == (q.bytes_delivered + q.bytes_dropped
                        + q.bytes_rejected + q.bytes_duplicate
                        + q.bytes_in_flight)
print("byte ledger conserved under chaos: OK")

# phase 2: the CRASH — abandon the live service (in-flight queue, open
# migration window and all) and recover from snapshot + journal
crashed = service
assert crashed.wire.registry.migration is not None, \
    "kill was supposed to land mid-migration"
print(f"\nKILL at tick {crashed.tick_idx} (migration "
      f"v{crashed.wire.registry.migration.src}->"
      f"v{crashed.wire.registry.migration.dst} OPEN, "
      f"{len(crashed.queue)} payloads in flight)")

t0 = time.time()
recovered = ContinuousIngestService.recover(
    root, cfg, OC.server_init(key, cfg),
    capacity=6, defer_depth=4,
    decode_policy=BulkDecodePolicy(min_batch=2, max_batch=64,
                                   interval_ticks=2))
rec_s = time.time() - t0

assert recovered.tick_idx == crashed.tick_idx
assert recovered.verdicts == crashed.verdicts
assert recovered.verdict_bytes == crashed.verdict_bytes
for attr in ("bytes_sent", "bytes_delivered", "bytes_dropped",
             "bytes_rejected", "bytes_duplicate", "bytes_in_flight"):
    assert getattr(recovered.queue, attr) == getattr(crashed.queue, attr)
rw = recovered.wire.registry.migration
assert rw is not None and rw.dst == crashed.wire.registry.migration.dst
fa, _ = crashed.wire.features()
fb, _ = recovered.wire.features()
assert np.array_equal(np.asarray(fa), np.asarray(fb))
print(f"recovered in {rec_s:.2f}s: verdicts, ledger and decoded "
      f"features EXACT (tick {recovered.tick_idx}, migration window "
      f"still open, {len(recovered.wire.store)} records)")

# phase 3: the recovered service keeps serving the same chaos
chan2 = FaultyChannel(recovered, PLAN, key=jax.random.PRNGKey(4),
                      retry=RetryPolicy(max_attempts=3))
hist2 = engine.run_continuous(chan2, sched, data_fn, cohort_size=COHORT,
                              n_ticks=TICKS // 2, merge_every=4,
                              migration_policy="keep")
chan2.drain()
q = recovered.queue
assert q.bytes_sent == (q.bytes_delivered + q.bytes_dropped
                        + q.bytes_rejected + q.bytes_duplicate
                        + q.bytes_in_flight)
print(f"\npost-recovery: {TICKS // 2} more faulted ticks "
      f"({sum(chan2.faults.values())} faults), ledger still conserved, "
      f"registry at v{recovered.wire.registry.latest}")

store = recovered.wire.store
for r in store.records:
    now = OC.codes_to_features(None, cfg, r.packed,
                               codebook=recovered.wire.registry.get(
                                   r.version))
    ref = recovered.wire.decode(r.packed)
    assert np.array_equal(np.asarray(now).reshape(np.asarray(ref).shape),
                          np.asarray(ref)), r.version
print(f"bit-exact decode for versions {store.versions} after crash + "
      f"recovery: OK")

if rec is not None:
    obs.uninstall()
    print(f"\ntrace written: {rec.path}")

"""Privacy red team: attack the §2.5 claim, then hide the access pattern.

OCTOPUS claims transmitted codes carry no private component. This
example plays the adversary instead of trusting the claim:

  1. drive the ``adversary`` standing scenario's traffic through a
     ``PayloadTap`` — a wiretap that (under the explicit
     ``OCTOPUS_REDTEAM`` opt-in) records FULL packed payloads, unlike
     the metadata-only flight recorder;
  2. train attribute- and membership-inference attackers on the
     captured streams — against the privatized wire they score ≈
     chance, against the provably-leaky control codec (IN off) they
     must NOT (the harness has teeth);
  3. swap the server's sharded store for the ``ObliviousCodeStore``:
     same bits out (checked), but which client's codes are touched when
     leaks nothing — at a measured touch-ratio cost.

Set ``OCTOPUS_TRACE=redteam.jsonl`` to flight-record the run — the
trace shows ``tap``/``attack`` events (scalar results and payload
METADATA only; even a red-team run's trace honors §2.5).

    OCTOPUS_REDTEAM=1 PYTHONPATH=src python examples/privacy_redteam.py
"""
import os

os.environ.setdefault("OCTOPUS_REDTEAM", "1")    # the explicit opt-in

import jax
import numpy as np

from repro import obs, privacy as P
from repro.privacy import sweep as SW
from repro.server import STANDARD_SCENARIOS, RoundScheduler

rec = obs.install_from_env()                 # OCTOPUS_TRACE=... to record
key = jax.random.PRNGKey(0)

# ---- 1. tap the adversary scenario's traffic ---------------------------

scenario = STANDARD_SCENARIOS["adversary"]
sched = RoundScheduler(8, scenario.sched, key=jax.random.PRNGKey(42))
cfg, params, srv = P.make_codec(0, K=32)     # privatized wire (IN on)
cfg_leaky, params_leaky, srv_leaky = P.make_codec(0, K=32, apply_in=False)

rng = np.random.default_rng(0)
protos = rng.normal(size=(SW.N_CONTENT, SW.T_SEQ, SW.D_MODEL))
shifts = rng.normal(size=(SW.N_STYLES, SW.D_MODEL)) * SW.SHIFT_SCALE

tap, tap_leaky = P.PayloadTap(), P.PayloadTap()   # opt-in via env above
for _ in range(4):                                # 4 scheduled rounds
    ev = sched.step()
    for c in ev.participants.tolist():
        sty = c % SW.N_STYLES
        x, _ = SW.client_batch(rng, protos, shifts[sty], 24)
        tap.capture(srv.deploy(client_id=c).transmit(x),
                    client=c, style=sty)
        tap_leaky.capture(srv_leaky.deploy(client_id=c).transmit(x),
                          client=c, style=sty)
print(f"tapped {len(tap)} uplinks, {tap.nbytes} B of packed codes")

# ---- 2. attack the captured streams ------------------------------------

ka, kb = jax.random.split(key)
leaky = P.attribute_inference(ka, tap_leaky, attribute="style",
                              n_classes=SW.N_STYLES, n_atoms=32, steps=120)
priv = P.attribute_inference(kb, tap, attribute="style",
                             n_classes=SW.N_STYLES, n_atoms=32, steps=120)
print(f"attribute attack, leaky control:  acc {leaky.accuracy:.2f} "
      f"(chance {leaky.chance:.2f}) -> advantage {leaky.advantage:+.2f}")
print(f"attribute attack, privatized:     acc {priv.accuracy:.2f} "
      f"(chance {priv.chance:.2f}) -> advantage {priv.advantage:+.2f}")
assert leaky.advantage > 0.2, "the harness lost its teeth"
assert abs(priv.advantage) < 0.2, "the privatized wire leaked"

mem = P.membership_point(key, seed=0, strength=0.0, steps=120)
print(f"membership (leaky wire):          acc {mem.accuracy:.2f} "
      f"(chance {mem.chance:.2f}) -> advantage {mem.advantage:+.2f}")

# ---- 3. defend the server side: oblivious store ------------------------

oh = P.oblivious_point(seed=0)
assert oh["parity_bitexact"] == 1.0
print(f"oblivious store: bit-exact with plain store; "
      f"touch ratio {oh['partition_touch_ratio']:.1f}x, "
      f"get wall ratio {oh['get_wall_ratio']:.1f}x")

if rec is not None:
    print(f"trace: {rec.n_events} events -> {rec.path} "
          f"(tap/attack events are metadata-only)")

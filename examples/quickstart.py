"""Quickstart: the OCTOPUS protocol end-to-end in ~80 lines.

    PYTHONPATH=src python examples/quickstart.py

1. Server pretrains a DVQ-AE on public data (ATD).
2. Non-IID clients fine-tune encoders locally and transmit ONLY discrete
   latent codes (a few bytes per sample instead of the raw image).
3. The server trains a downstream classifier on the gathered codes.
4. A privacy audit shows identity (style) is filtered while content
   classification survives.
"""
import jax
import jax.numpy as jnp

from repro.core import downstream as DS
from repro.core import octopus as OC
from repro.core import privacy as PV
from repro.core.dvqae import DVQAEConfig
from repro.data import holdout_atd, make_images, partition, train_test_split

key = jax.random.PRNGKey(0)
cfg = DVQAEConfig(kind="image", in_channels=3, hidden=32, latent_dim=16,
                  codebook_size=256, n_res_blocks=1)

# ------------------------------------------------- data (content x style)
data = make_images(key, 800, size=32, n_identities=8)
train, test = train_test_split(data, 0.2)
train, atd = holdout_atd(train, 0.15)
clients = partition(train, 4, regime="worst")      # worst-case non-IID
print(f"{len(clients)} clients, {train.x.shape[0]} train samples, "
      f"{atd.x.shape[0]} public ATD samples")

# ------------------------------------------------- Step 1: server pretrain
server = OC.server_init(key, cfg)
for i in range(200):
    sel = jax.random.randint(jax.random.fold_in(key, i), (32,), 0,
                             atd.x.shape[0])
    server, out = OC.server_pretrain_step(server, cfg, atd.x[sel])
print(f"server DVQ-AE pretrained: recon loss {float(out.recon_loss):.4f}")

# ------------------------- Steps 2-4: clients fine-tune + transmit codes
txs = []
total_bytes = 0
for ci, shard in enumerate(clients):
    client = OC.client_init(server)
    client, _, _ = OC.client_finetune_step(client, cfg, shard.x[:32])
    tx = OC.client_transmit(client, cfg, shard.x, labels=shard.content)
    txs.append(tx)
    total_bytes += tx.nbytes
raw_bytes = sum(int(s.x.size) * 4 for s in clients)
print(f"transmitted {total_bytes:,} bytes of codes "
      f"(raw would be {raw_bytes:,}: {raw_bytes/total_bytes:.0f}x saving)")

# --------------------------------------- Step 6: downstream at the server
codes, labels, _ = OC.gather_codes(txs)
feats = OC.codes_to_features(server, cfg, codes)
probe = DS.init_linear_probe(key, int(feats[0].size), 8)
probe = DS.sgd_train(key, DS.linear_probe, probe, feats, labels, steps=200)

test_client = OC.client_init(server)
te_tx = OC.client_transmit(test_client, cfg, test.x)
te_feats = OC.codes_to_features(server, cfg, te_tx.indices)
acc = DS.accuracy(DS.linear_probe, probe, te_feats, test.content)
print(f"downstream content accuracy on codes: {acc:.3f}")

# ----------------------------------------------------------- privacy audit
adv = PV.train_adversary(key, te_feats, test.style, 8, steps=200)
m = PV.evaluate_adversary(adv, te_feats, test.style, 8)
print(f"identity re-identification from released codes: "
      f"acc={m.accuracy:.3f}, H(Y|Z)={m.conditional_entropy_bits:.2f} bits "
      f"(chance = {1/8:.3f}, max H = 3 bits)")

"""Quickstart: the OCTOPUS protocol end-to-end in ~80 lines.

    PYTHONPATH=src python examples/quickstart.py

1. Server pretrains a DVQ-AE on public data (ATD).
2. Non-IID clients fine-tune encoders locally and transmit ONLY discrete
   latent codes (a few bytes per sample instead of the raw image).
3. The server trains a downstream classifier on the gathered codes.
4. A privacy audit shows identity (style) is filtered while content
   classification survives.

Everything crossing the client→server boundary is a single carrier —
``repro.wire.CodePayload`` — through the session facades
``OctopusClient`` (uplink) / ``OctopusServer`` (ingest + decode).
"""
import jax

from repro.core import downstream as DS
from repro import privacy as PV
from repro.core.dvqae import DVQAEConfig
from repro.data import holdout_atd, make_images, partition, train_test_split
from repro.wire import OctopusServer

key = jax.random.PRNGKey(0)
cfg = DVQAEConfig(kind="image", in_channels=3, hidden=32, latent_dim=16,
                  codebook_size=256, n_res_blocks=1)

# ------------------------------------------------- data (content x style)
data = make_images(key, 800, size=32, n_identities=8)
train, test = train_test_split(data, 0.2)
train, atd = holdout_atd(train, 0.15)
clients = partition(train, 4, regime="worst")      # worst-case non-IID
print(f"{len(clients)} clients, {train.x.shape[0]} train samples, "
      f"{atd.x.shape[0]} public ATD samples")

# ------------------------------------------------- Step 1: server pretrain
srv = OctopusServer.init(key, cfg)
out = srv.pretrain(key, atd.x, steps=200)
print(f"server DVQ-AE pretrained: recon loss {float(out.recon_loss):.4f}")

# ---------------- Steps 2-4: clients fine-tune + transmit CodePayloads
for ci, shard in enumerate(clients):
    client = srv.deploy(client_id=ci)
    client.finetune(shard.x[:32])
    payload = client.transmit(shard.x, labels=shard.content)
    srv.ingest(payload, client_ids=[ci])
total_bytes = srv.store.total_bytes              # measured from the wire
raw_bytes = sum(int(s.x.size) * 4 for s in clients)
print(f"transmitted {total_bytes:,} bytes of codes "
      f"(raw would be {raw_bytes:,}: {raw_bytes/total_bytes:.0f}x saving)")

# --------------------------------------- Step 6: downstream at the server
feats, label_dict = srv.features()               # ONE bulk decode
labels = label_dict["label"]
probe = DS.init_linear_probe(key, int(feats[0].size), 8)
probe = DS.sgd_train(key, DS.linear_probe, probe, feats, labels, steps=200)

te_feats = srv.decode(srv.deploy().transmit(test.x))
acc = DS.accuracy(DS.linear_probe, probe, te_feats, test.content)
print(f"downstream content accuracy on codes: {acc:.3f}")

# ----------------------------------------------------------- privacy audit
adv = PV.train_adversary(key, te_feats, test.style, 8, steps=200)
m = PV.evaluate_adversary(adv, te_feats, test.style, 8)
print(f"identity re-identification from released codes: "
      f"acc={m.accuracy:.3f}, H(Y|Z)={m.conditional_entropy_bits:.2f} bits "
      f"(chance = {1/8:.3f}, max H = 3 bits)")

"""Unit tests for the NN substrate: attention, MoE, SSM, xLSTM, MLA."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import MLAConfig, MoEConfig, ModelConfig, SSMConfig, XLSTMConfig
from repro.nn import attention as A
from repro.nn import mla as MLA
from repro.nn import moe as MOE
from repro.nn import ssm as SSM
from repro.nn import xlstm as XL


# -------------------------------------------------------------- attention

def test_chunked_matches_full(key):
    cfg = ModelConfig(n_heads=4, n_kv_heads=2, d_model=64)
    q = jax.random.normal(key, (2, 96, 4, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 96, 4, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 96, 4, 16))
    full = A._attend_full(q, k, v, causal=True, q_offset=0, window=0)
    chunked = A._attend_chunked(q, k, v, causal=True, q_offset=0, window=0,
                                kv_chunk=32)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               atol=2e-5, rtol=2e-5)


def test_sliding_window_masks_distant(key):
    """With window w, token t must ignore keys < t-w+1: moving those keys
    must not change the output."""
    q = jax.random.normal(key, (1, 64, 2, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 64, 2, 16))
    out1 = A._attend_full(q, k, v, causal=True, q_offset=0, window=8)
    k2 = k.at[:, :40].set(999.0)
    v2 = v.at[:, :40].set(-999.0)
    out2 = A._attend_full(q, k2, v2, causal=True, q_offset=0, window=8)
    np.testing.assert_allclose(np.asarray(out1[:, 48:]),
                               np.asarray(out2[:, 48:]), atol=1e-5)


def test_gqa_repeat(key):
    cfg = ModelConfig(d_model=64, n_heads=4, n_kv_heads=2)
    params = A.init_attention(key, cfg)
    x = jax.random.normal(key, (2, 16, 64))
    pos = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))
    out, cache = A.attention(params, cfg, x, pos)
    assert out.shape == (2, 16, 64)
    assert cache.k.shape == (2, 16, 2, 16)


def test_rope_rotation_property(key):
    """RoPE: dot products depend only on relative position."""
    d = 32
    x = jax.random.normal(key, (1, 1, 1, d))
    y = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, d))
    def dot_at(p, q):
        xp = A.apply_rope(x, jnp.array([[p]]), 10000.0)
        yq = A.apply_rope(y, jnp.array([[q]]), 10000.0)
        return float(jnp.sum(xp * yq))
    assert dot_at(3, 7) == pytest.approx(dot_at(13, 17), abs=1e-4)
    assert dot_at(0, 5) == pytest.approx(dot_at(10, 15), abs=1e-4)


# -------------------------------------------------------------------- MoE

def _moe_cfg(E=4, k=2, shared=0):
    return ModelConfig(d_model=32, moe=MoEConfig(
        n_experts=E, n_experts_per_tok=k, n_shared_experts=shared,
        d_ff_expert=64, capacity_factor=2.0))


def test_moe_output_shape_and_aux(key):
    cfg = _moe_cfg()
    p = MOE.init_moe(key, cfg)
    x = jax.random.normal(key, (2, 8, 32))
    out = MOE.moe_apply(p, cfg, x)
    assert out.y.shape == x.shape
    assert float(out.aux_loss) > 0


def test_moe_positions_in_expert():
    ids = jnp.array([1, 0, 1, 1, 2, 0], jnp.int32)
    pos = MOE.positions_in_expert(ids, 4)
    # expert 0 sees items 1,5 -> pos 0,1; expert 1 sees 0,2,3 -> 0,1,2
    assert pos[1] == 0 and pos[5] == 1
    assert pos[0] == 0 and pos[2] == 1 and pos[3] == 2
    assert pos[4] == 0


def test_moe_capacity_drops(key):
    """With capacity_factor tiny, some tokens are dropped (output smaller
    norm) but nothing NaNs."""
    cfg = ModelConfig(d_model=32, moe=MoEConfig(
        n_experts=4, n_experts_per_tok=2, d_ff_expert=64,
        capacity_factor=0.25))
    p = MOE.init_moe(key, cfg)
    x = jax.random.normal(key, (2, 16, 32))
    out = MOE.moe_apply(p, cfg, x)
    assert bool(jnp.all(jnp.isfinite(out.y)))


def test_moe_load_balance_uniform_is_one():
    """Perfectly uniform routing gives aux = 1.0 (E * E * (1/E) * (1/E))."""
    E, N, k = 4, 64, 1
    probs = jnp.full((N, E), 1.0 / E)
    idx = (jnp.arange(N) % E)[:, None]
    lb = MOE.load_balance_loss(probs, idx, E)
    assert float(lb) == pytest.approx(1.0, rel=1e-5)


def test_moe_sigmoid_routing(key):
    cfg = ModelConfig(d_model=32, moe=MoEConfig(
        n_experts=4, n_experts_per_tok=2, d_ff_expert=64,
        router_scoring="sigmoid"))
    p = MOE.init_moe(key, cfg)
    x = jax.random.normal(key, (1, 8, 32))
    out = MOE.moe_apply(p, cfg, x)
    assert bool(jnp.all(jnp.isfinite(out.y)))


def test_moe_shared_expert_contributes(key):
    cfg = _moe_cfg(shared=1)
    p = MOE.init_moe(key, cfg)
    x = jax.random.normal(key, (1, 8, 32))
    with_shared = MOE.moe_apply(p, cfg, x).y
    p2 = dict(p)
    p2["shared"] = jax.tree.map(jnp.zeros_like, p["shared"])
    without = MOE.moe_apply(p2, cfg, x).y
    assert float(jnp.max(jnp.abs(with_shared - without))) > 1e-4


# -------------------------------------------------------------------- SSM

def _ssm_cfg():
    return ModelConfig(d_model=32, ssm=SSMConfig(d_state=8, d_conv=4, expand=2))


def test_mamba_prefill_decode_consistency(key):
    """Step-by-step decode must reproduce the full-sequence scan."""
    cfg = _ssm_cfg()
    p = SSM.init_mamba(key, cfg)
    x = jax.random.normal(key, (2, 12, 32))
    full, _ = SSM.mamba(p, cfg, x)
    cache = SSM.init_mamba_cache(cfg, 2)
    outs = []
    for t in range(12):
        o, cache = SSM.mamba(p, cfg, x[:, t:t + 1], cache=cache,
                             cache_index=jnp.int32(t))
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               atol=1e-4, rtol=1e-3)


def test_linear_recurrence_chunked_exact(key):
    decay = jax.nn.sigmoid(jax.random.normal(key, (2, 20, 4, 3)))
    inp = jax.random.normal(jax.random.PRNGKey(1), (2, 20, 4, 3))
    h0 = jnp.zeros((2, 4, 3))
    hs, hl = SSM._linear_recurrence_chunked(decay, inp, h0, chunk=7)
    # naive reference
    h = h0
    ref = []
    for t in range(20):
        h = decay[:, t] * h + inp[:, t]
        ref.append(h)
    ref = jnp.stack(ref, axis=1)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(ref[:, -1]),
                               atol=1e-5, rtol=1e-5)


# ------------------------------------------------------------------ xLSTM

def _xl_cfg():
    return ModelConfig(d_model=32, n_heads=4, n_kv_heads=4,
                       xlstm=XLSTMConfig(conv_dim=4, proj_factor=2.0))


def test_mlstm_prefill_decode_consistency(key):
    cfg = _xl_cfg()
    p = XL.init_mlstm(key, cfg)
    x = jax.random.normal(key, (2, 10, 32))
    full, _ = XL.mlstm(p, cfg, x, chunk=5)
    cache = XL.init_mlstm_cache(cfg, 2)
    outs = []
    for t in range(10):
        o, cache = XL.mlstm(p, cfg, x[:, t:t + 1], cache=cache)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               atol=2e-3, rtol=2e-2)


def test_mlstm_chunk_invariance(key):
    cfg = _xl_cfg()
    p = XL.init_mlstm(key, cfg)
    x = jax.random.normal(key, (1, 16, 32))
    a, _ = XL.mlstm(p, cfg, x, chunk=4)
    b, _ = XL.mlstm(p, cfg, x, chunk=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=1e-4, rtol=1e-3)


def test_slstm_prefill_decode_consistency(key):
    cfg = _xl_cfg()
    p = XL.init_slstm(key, cfg)
    x = jax.random.normal(key, (2, 10, 32))
    full, _ = XL.slstm(p, cfg, x)
    cache = XL.init_slstm_cache(cfg, 2)
    outs = []
    for t in range(10):
        o, cache = XL.slstm(p, cfg, x[:, t:t + 1], cache=cache)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               atol=1e-4, rtol=1e-3)


# -------------------------------------------------------------------- MLA

def _mla_cfg():
    return ModelConfig(d_model=64, n_heads=4, n_kv_heads=4, use_mla=True,
                       mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                     qk_nope_head_dim=16, qk_rope_head_dim=8,
                                     v_head_dim=16))


def test_mla_absorbed_decode_matches_expanded(key):
    """The absorbed decode path must equal the expanded teacher-forced path
    position by position — this is the correctness proof of the wkv_b
    folding."""
    cfg = _mla_cfg()
    p = MLA.init_mla(key, cfg)
    x = jax.random.normal(key, (2, 8, 64))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    full, _ = MLA.mla_attention(p, cfg, x, pos)
    cache = MLA.init_mla_cache(cfg, 2, 8)
    outs = []
    for t in range(8):
        o, cache = MLA.mla_attention(p, cfg, x[:, t:t + 1],
                                     pos[:, t:t + 1], cache=cache,
                                     cache_index=jnp.int32(t))
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               atol=1e-3, rtol=1e-2)


def test_mla_cache_is_compressed(key):
    """MLA cache stores rank-r latents, much smaller than full K/V."""
    cfg = _mla_cfg()
    cache = MLA.init_mla_cache(cfg, 2, 128)
    full_kv_floats = 2 * 128 * 4 * (16 + 8) * 2    # k+v per-head
    mla_floats = cache.c_kv.size + cache.k_rope.size
    assert mla_floats < full_kv_floats / 2

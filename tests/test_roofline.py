"""Roofline machinery tests: HLO analyzer correctness on known programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo_analysis import analyze_hlo, parse_hlo
from repro.roofline.analysis import RooflineReport, model_flops_per_step


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_trip_count_scaling():
    """Scanned matmuls must be counted x trip_count (the cost_analysis bug
    this module exists to fix)."""
    def body(x, w):
        return jnp.tanh(x @ w), None

    w = jnp.zeros((8, 64, 64))
    x = jnp.ones((4, 64))
    t = analyze_hlo(_compile_text(
        lambda x, w: jax.lax.scan(body, x, w)[0], x, w))
    assert t.flops == pytest.approx(8 * 2 * 4 * 64 * 64, rel=0.01)
    assert 8 in t.while_trip_counts


def test_unrolled_matches_scan():
    def body(x, w):
        return jnp.tanh(x @ w), None

    w = jnp.zeros((6, 32, 32))
    x = jnp.ones((4, 32))

    def unrolled(x, w):
        for i in range(6):
            x, _ = body(x, w[i])
        return x

    t_scan = analyze_hlo(_compile_text(
        lambda x, w: jax.lax.scan(body, x, w)[0], x, w))
    t_unroll = analyze_hlo(_compile_text(unrolled, x, w))
    assert t_scan.flops == pytest.approx(t_unroll.flops, rel=0.05)


def test_plain_matmul_flops():
    a = jnp.ones((128, 256))
    b = jnp.ones((256, 512))
    t = analyze_hlo(_compile_text(lambda a, b: a @ b, a, b))
    assert t.flops == pytest.approx(2 * 128 * 256 * 512, rel=0.01)


def test_train_flops_close_to_analytic(key):
    """Full model train step ~ 6ND (1.0-1.5x with attention + remat)."""
    from repro.configs import smoke_config
    from repro.models import transformer as T
    cfg = smoke_config("qwen3_0_6b")
    params = T.init_lm(key, cfg)
    tokens = jnp.zeros((4, 64), jnp.int32)
    text = _compile_text(
        lambda p, t: jax.grad(lambda p: T.lm_loss(p, cfg, t, remat=True))(p),
        params, tokens)
    t = analyze_hlo(text)
    est = 6 * cfg.param_count() * 4 * 64
    assert 0.8 < t.flops / est < 2.0, t.flops / est


def test_collective_bytes_under_mesh(key):
    """A sharded matmul with row-parallel weights must show an all-reduce
    of the output size."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("model",))
    # 1-device mesh generates no collectives; just assert parser stability
    a = jnp.ones((64, 64))
    with mesh:
        text = _compile_text(lambda a: a @ a, a)
    t = analyze_hlo(text)
    assert t.flops > 0


def test_report_bottleneck_selection():
    r = RooflineReport(arch="a", shape="s", mesh="m", chips=256,
                       hlo_flops=197e12, hlo_bytes=0.0,
                       collective_bytes=0.0, model_flops=197e12 * 256)
    r.finalize()
    assert r.bottleneck == "compute"
    assert r.compute_s == pytest.approx(1.0)
    assert r.useful_ratio == pytest.approx(1.0)


def test_model_flops_modes():
    from repro.configs import get_config
    from repro.configs.base import INPUT_SHAPES
    cfg = get_config("qwen3_0_6b")
    tr = model_flops_per_step(cfg, INPUT_SHAPES["train_4k"])
    pf = model_flops_per_step(cfg, INPUT_SHAPES["prefill_32k"])
    dc = model_flops_per_step(cfg, INPUT_SHAPES["decode_32k"])
    assert tr == 6 * cfg.active_param_count() * 256 * 4096
    assert pf == 2 * cfg.active_param_count() * 32 * 32768
    assert dc == 2 * cfg.active_param_count() * 128


def test_moe_active_params_used():
    from repro.configs import get_config
    from repro.configs.base import INPUT_SHAPES
    cfg = get_config("qwen3_moe_30b_a3b")
    tr = model_flops_per_step(cfg, INPUT_SHAPES["train_4k"])
    # active (3.4B), not total (30B)
    assert tr < 6 * cfg.param_count() * 256 * 4096 / 5


def test_parse_hlo_computation_count(key):
    text = _compile_text(lambda a: jnp.sum(a * a), jnp.ones((8, 8)))
    comps = parse_hlo(text)
    assert "__entry__" in comps

"""Distribution-layer tests: sharding specs, dry-run machinery on a small
host mesh (the 512-device production dry-run runs via launch/dryrun.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.configs.base import ShapeConfig, TrainConfig
from repro.distributed import sharding as shd
from repro.distributed import steps as S
from repro.models import transformer as T


def _fake_mesh_shape():
    """AbstractMesh lets us build specs without 256 devices."""
    from conftest import abstract_mesh
    return abstract_mesh((16, 16), ("data", "model"))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_divisible(arch):
    """Every sharded dim must be divisible by its mesh axes (the greedy
    fallback guarantee)."""
    cfg = get_config(arch)
    mesh = _fake_mesh_shape()
    pshape = S.params_shape(cfg)
    specs = shd.param_specs(pshape, cfg, mesh)

    def check(path, leaf, spec):
        for dim, axes in enumerate(spec):
            if axes is None:
                continue
            axes = (axes,) if isinstance(axes, str) else axes
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            assert leaf.shape[dim] % size == 0, (path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(check, pshape, specs)


@pytest.mark.parametrize("arch", ["deepseek_v3_671b", "chameleon_34b",
                                  "jamba_v0_1_52b", "qwen3_moe_30b_a3b"])
def test_fsdp_kicks_in_for_big_models(arch):
    """>=10B models must shard params over the data axis too."""
    cfg = get_config(arch)
    assert cfg.param_count() >= shd.FSDP_THRESHOLD
    mesh = _fake_mesh_shape()
    specs = shd.param_specs(S.params_shape(cfg), cfg, mesh)
    found_data = []
    jax.tree.map(
        lambda s: found_data.append(
            any(("data" in ((ax,) if isinstance(ax, str) else ax))
                for ax in s if ax is not None)),
        specs, is_leaf=lambda x: isinstance(x, P))
    assert any(found_data)


def test_small_models_not_fsdp():
    cfg = get_config("qwen3_0_6b")
    mesh = _fake_mesh_shape()
    specs = shd.param_specs(S.params_shape(cfg), cfg, mesh)
    leaves = [s for s in jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, P))]
    for s in leaves:
        for ax in s:
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            assert "data" not in axes


def test_expert_axis_sharded():
    cfg = get_config("deepseek_v3_671b")
    mesh = _fake_mesh_shape()
    pshape = S.params_shape(cfg)
    specs = shd.param_specs(pshape, cfg, mesh)
    # find an experts wi leaf: (L, E, d, ff)
    seg_specs = specs["segments"][1]["ffn"]["experts"]["wi"]
    assert seg_specs[1] == "model"          # expert dim on model axis


def test_starcoder2_heads_fallback():
    """24 heads don't divide 16 — wq must still shard (on the feature dim)."""
    cfg = get_config("starcoder2_3b")
    mesh = _fake_mesh_shape()
    pshape = S.params_shape(cfg)
    specs = shd.param_specs(pshape, cfg, mesh)
    wq_spec = specs["segments"][0]["mixer"]["wq"]
    wq_shape = pshape["segments"][0]["mixer"]["wq"].shape
    assert any(s is not None for s in wq_spec)
    for dim, axes in enumerate(wq_spec):
        if axes is not None:
            size = 16
            assert wq_shape[dim] % size == 0


def test_cache_specs_long_500k_batch1():
    """global_batch=1 cannot shard batch -> sequence must take the data
    axes for attention caches."""
    cfg = get_config("chameleon_34b")
    mesh = _fake_mesh_shape()
    cshape = jax.eval_shape(lambda: T.init_caches(cfg, 1, 524288))
    specs = shd.cache_specs(cshape, cfg, mesh, batch=1)
    k_spec = specs[0].k       # (L, B, S, H, D)
    k_shape = cshape[0].k.shape
    assert k_spec[1] is None                      # B=1 unshardable
    data_dims = [d for d, ax in enumerate(k_spec)
                 if ax is not None and "data" in (
                     (ax,) if isinstance(ax, str) else ax)]
    assert data_dims, f"no data-axis dim in {k_spec}"
    for d in data_dims:
        assert k_shape[d] % 16 == 0


def test_train_step_runs_on_host_mesh(key):
    """Full sharded train step executes on a 1-device host mesh."""
    from repro.launch.mesh import make_host_mesh
    from repro.optim.adamw import adamw_init
    cfg = smoke_config("qwen3_0_6b")
    mesh = make_host_mesh()
    shape = ShapeConfig("t", 32, 4, "train")
    fn, in_specs, out_specs, _ = S.build_train_step(cfg, TrainConfig(), mesh,
                                                    shape)
    with mesh:
        params = T.init_lm(key, cfg)
        state = S.TrainState(params=params, opt=adamw_init(params),
                             step=jnp.zeros((), jnp.int32))
        jfn = jax.jit(fn, in_shardings=S.shd_to(in_specs, mesh),
                      out_shardings=S.shd_to(out_specs, mesh))
        tokens = jax.random.randint(key, (4, 32), 0, cfg.vocab_size)
        state2, loss = jfn(state, {"tokens": tokens})
        assert bool(jnp.isfinite(loss))
        assert int(state2.step) == 1


def test_serve_step_runs_on_host_mesh(key):
    from repro.launch.mesh import make_host_mesh
    cfg = smoke_config("qwen3_0_6b")
    mesh = make_host_mesh()
    shape = ShapeConfig("d", 64, 2, "decode")
    fn, in_specs, out_specs, arg_shapes = S.build_serve_step(cfg, mesh, shape)
    with mesh:
        params = T.init_lm(key, cfg)
        caches = T.init_caches(cfg, 2, 64)
        token = jnp.zeros((2, 1), jnp.int32)
        nxt, caches = fn(params, token, caches, jnp.int32(0))
        assert nxt.shape == (2, 1)
        assert nxt.dtype == jnp.int32


def test_decode_window_rules():
    train = ShapeConfig("train_4k", 4096, 256, "train")
    long = ShapeConfig("long_500k", 524288, 1, "decode")
    assert S.decode_window(get_config("gemma_7b"), long) == 4096
    assert S.decode_window(get_config("xlstm_350m"), long) is None
    assert S.decode_window(get_config("starcoder2_3b"), long) == 4096
    assert S.decode_window(get_config("gemma_7b"), train) is None

"""hints module: constraint selection logic (no mesh = identity; divisible
dims get the expected axes)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import hints


def test_no_context_is_identity(key):
    x = jax.random.normal(key, (4, 8, 16))
    assert hints.residual(x) is x or bool(jnp.all(hints.residual(x) == x))
    q = jax.random.normal(key, (4, 8, 2, 16))
    out = hints.heads(q)
    assert out.shape == q.shape


def _mesh22():
    from conftest import abstract_mesh
    return abstract_mesh((2, 2), ("data", "model"))


def test_dp_divisibility_gate():
    mesh = _mesh22()
    with hints.activation_sharding(mesh, ("data",)):
        assert hints._dp_for(4) == ("data",)
        assert hints._dp_for(3) is None
        assert hints._model_ok(4)
        assert not hints._model_ok(3)
        assert hints.dp_size() == 2


def test_heads_prefers_head_axis():
    mesh = _mesh22()
    with hints.activation_sharding(mesh, ("data",)):
        # traced check: constraint must not error for divisible heads
        @jax.jit
        def f(x):
            return hints.heads(x)
        out = jax.eval_shape(f, jax.ShapeDtypeStruct((4, 8, 2, 16),
                                                     jnp.float32))
        assert out.shape == (4, 8, 2, 16)


def test_context_nests_and_restores():
    mesh = _mesh22()
    assert hints._state() is None
    with hints.activation_sharding(mesh, ("data",)):
        assert hints._state() is not None
        with hints.activation_sharding(mesh, ("data", "model")):
            assert hints.dp_size() == 4
        assert hints.dp_size() == 2
    assert hints._state() is None

"""Async code-server runtime (repro.server).

The contracts that make Step 6 a subsystem instead of a buffer:
  * CodeStore bounds memory (FIFO/reservoir eviction) and decodes each
    record bit-exactly against the codebook version it was packed under,
    no matter how many Step 5 merges happened since;
  * RoundScheduler is a pure function of its PRNG key (same key -> same
    participation/straggler/churn stream);
  * MultiTaskTrainer with one task IS core.downstream.sgd_train (exact
    same batch draws and AdamW math), so multi-head training is a strict
    generalization of the single-task path.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import downstream as DS
from repro.core import octopus as OC
from repro.core.dvqae import DVQAEConfig
from repro.kernels.pack_bits import code_bits
from repro.server import (STANDARD_SCENARIOS, AsyncCodeServer, CodeStore,
                          CodebookRegistry, MultiTaskTrainer, RoundScheduler,
                          SchedulerConfig, TaskSpec)
from repro.sim import SimEngine
from repro.wire import CodePayload


@pytest.fixture(scope="module")
def tiny_cfg():
    return DVQAEConfig(kind="image", in_channels=3, hidden=8, latent_dim=8,
                       codebook_size=16, n_res_blocks=1)


@pytest.fixture(scope="module")
def server(tiny_cfg):
    return OC.server_init(jax.random.PRNGKey(0), tiny_cfg)


def _pack(codes, version=0):
    """int32 (C, B, T) codes -> CodePayload like the engine emits."""
    return CodePayload.pack(jnp.asarray(codes, jnp.int32),
                            bits=code_bits(16), version=version)


def _codes(seed, c=2, b=3, t=4):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 16, size=(c, b, t))


# --------------------------------------------------------------- CodeStore

def test_store_add_validates_shapes(tiny_cfg):
    store = CodeStore(tiny_cfg)
    packed = _pack(_codes(0))
    with pytest.raises(ValueError, match="labels"):
        store.add(packed, labels=jnp.zeros((5,), jnp.int32))   # 5 != 2*3
    with pytest.raises(ValueError, match="client_ids"):
        store.add(packed, client_ids=np.arange(3))             # 3 != C=2
    store.add(packed, labels=jnp.zeros((2, 3), jnp.int32))     # (C, B) ok
    assert store.n_samples == 6


def test_store_fifo_eviction_keeps_freshest_window(tiny_cfg):
    store = CodeStore(tiny_cfg, capacity_samples=18, policy="fifo")
    for r in range(5):
        store.add(_pack(_codes(r)), round=r)
    assert store.n_samples <= 18
    assert [rec.round for rec in store.records] == [2, 3, 4]
    assert store.evicted_records == 2
    assert store.evicted_samples == 12


def test_store_reservoir_eviction_is_bounded_and_deterministic(tiny_cfg):
    def run(seed):
        store = CodeStore(tiny_cfg, capacity_samples=18, policy="reservoir",
                          seed=seed)
        for r in range(30):
            store.add(_pack(_codes(r)), round=r)
        return [rec.round for rec in store.records]

    kept = run(7)
    assert len(kept) == 3
    assert kept == run(7)                      # seeded -> deterministic
    # algorithm-R keeps an approx-uniform sample of history, not a FIFO
    # tail: across a few seeds, early records survive
    assert any(min(run(s)) < 20 for s in range(5))


def test_store_version_correct_decode_roundtrip(tiny_cfg, key):
    """Codes packed under version v decode bit-exactly against v's
    snapshot after later merges moved the registry on."""
    k1, k2 = jax.random.split(key)
    registry = CodebookRegistry(jax.random.normal(k1, (16, 8)))
    store = CodeStore(tiny_cfg)
    c0 = _codes(0)
    store.add(_pack(c0), round=0, version=0,
              labels={"content": jnp.zeros((2, 3), jnp.int32)})
    ref0 = np.asarray(registry.get(0))[np.asarray(c0).reshape(6, 4)]

    # two merges: the registry's latest table moves twice
    registry.register(jax.random.normal(k2, (16, 8)))
    registry.register(jax.random.normal(jax.random.fold_in(k2, 1), (16, 8)))
    c2 = _codes(2)
    store.add(_pack(c2), round=1, version=2,
              labels={"content": jnp.ones((2, 3), jnp.int32)})
    ref2 = np.asarray(registry.get(2))[np.asarray(c2).reshape(6, 4)]

    assert store.versions == (0, 2)
    feats, labels = store.dataset(None, registry=registry)
    np.testing.assert_array_equal(np.asarray(feats[:6]), ref0)   # NOT latest
    np.testing.assert_array_equal(np.asarray(feats[6:]), ref2)
    np.testing.assert_array_equal(np.asarray(labels["content"]),
                                  [0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 1, 1])
    # keyed lookup: (client_id, round) -> that client's codes + version
    idx, version = store.get(1, 0)
    np.testing.assert_array_equal(np.asarray(idx), c0[1])
    assert version == 0


def test_store_bulk_decode_matches_current_codebook_path(tiny_cfg, server,
                                                         key):
    """Without a registry, dataset() == decoding everything against the
    server's current table (the old IngestBuffer behaviour)."""
    store = CodeStore(tiny_cfg)
    for r in range(3):
        store.add(_pack(_codes(r)), round=r, version=r)   # versions differ
    feats, _ = store.dataset(server)
    ref = OC.codes_to_features(server, tiny_cfg, store.codes())
    np.testing.assert_array_equal(np.asarray(feats), np.asarray(ref))


# ---------------------------------------------------------- staleness merge

def test_staleness_weighted_merge_discounts_stale_clients(server):
    cbs = jnp.stack([jnp.ones((16, 8)), 3.0 * jnp.ones((16, 8))])
    cts = jnp.ones((2, 16))
    even = OC.server_merge_codebooks(server, cbs, cts)
    np.testing.assert_allclose(np.asarray(even.params["codebook"]), 2.0,
                               rtol=1e-6)
    # client 1 is two versions stale at decay 0.5 -> weight 1 vs 0.25
    m = OC.server_merge_codebooks(server, cbs, cts,
                                  staleness=jnp.array([0, 2]),
                                  staleness_decay=0.5)
    np.testing.assert_allclose(np.asarray(m.params["codebook"]),
                               (1.0 + 0.25 * 3.0) / 1.25, rtol=1e-6)
    # decay 0 silences stale clients entirely
    reg = CodebookRegistry(server.params["codebook"])
    reg.register(server.params["codebook"])
    merged, v = reg.merge(server, cbs, cts, client_versions=np.array([1, 0]),
                          staleness_decay=0.0)
    assert v == 2 and v == reg.latest
    np.testing.assert_allclose(np.asarray(merged.params["codebook"]), 1.0,
                               rtol=1e-6)


def test_merge_with_zero_total_weight_keeps_current_dictionary(server):
    """If every client's contribution decays to zero (all fully stale),
    the merge must keep the current dictionary, not zero it out."""
    cbs = jnp.stack([jnp.ones((16, 8)), 3.0 * jnp.ones((16, 8))])
    cts = jnp.ones((2, 16))
    m = OC.server_merge_codebooks(server, cbs, cts,
                                  staleness=jnp.array([1, 2]),
                                  staleness_decay=0.0)
    np.testing.assert_array_equal(np.asarray(m.params["codebook"]),
                                  np.asarray(server.params["codebook"]))


# --------------------------------------------------------------- scheduler

def test_scheduler_deterministic_under_fixed_key():
    cfg = SchedulerConfig(participation=0.5, straggler_prob=0.5, max_delay=3,
                          drop_prob=0.2, leave_prob=0.3, join_prob=0.4)
    def trace(key):
        s = RoundScheduler(16, cfg, key=key)
        return [s.step() for _ in range(12)]

    a, b = trace(jax.random.PRNGKey(5)), trace(jax.random.PRNGKey(5))
    for ea, eb in zip(a, b):
        for fa, fb in zip(ea, eb):
            np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))
    c = trace(jax.random.PRNGKey(6))
    assert any(not np.array_equal(ea.participants, ec.participants)
               for ea, ec in zip(a, c))


def test_scheduler_shapes_and_roster_invariants():
    cfg = SchedulerConfig(participation=0.25, straggler_prob=1.0,
                          max_delay=2, leave_prob=0.5, join_prob=0.1)
    s = RoundScheduler(8, cfg, key=jax.random.PRNGKey(1))
    assert s.k == 2
    for _ in range(20):
        ev = s.step()
        assert ev.participants.shape == (2,)              # static jit shape
        assert s.active[ev.participants].all()            # drawn from roster
        assert s.active.sum() >= s.k                      # leaves are capped
        assert ((1 <= ev.delays) & (ev.delays <= 2)).all()  # all straggle


def test_scheduler_streams_are_knob_isolated():
    """Each per-round draw owns a PRNG substream: toggling the straggler
    / drop knobs cannot perturb the participant or churn draws (they
    used to share ONE per-round Generator, so any knob re-randomized
    everything after it)."""
    base = SchedulerConfig(participation=0.5, leave_prob=0.3, join_prob=0.4)
    noisy = SchedulerConfig(participation=0.5, leave_prob=0.3, join_prob=0.4,
                            straggler_prob=0.9, max_delay=3, drop_prob=0.5)
    a = RoundScheduler(16, base, key=jax.random.PRNGKey(3))
    b = RoundScheduler(16, noisy, key=jax.random.PRNGKey(3))
    for _ in range(12):
        ea, eb = a.step(), b.step()
        np.testing.assert_array_equal(ea.participants, eb.participants)
        np.testing.assert_array_equal(ea.joined, eb.joined)
        np.testing.assert_array_equal(ea.left, eb.left)


def test_scheduler_cohort_rng_does_not_advance_population_streams():
    """Cohort-level draws live on a reserved substream: consuming it
    between steps leaves the churn/participant/delay/drop streams
    bit-identical (a churn re-run is reproducible with or without the
    cohort engine in the loop)."""
    cfg = SchedulerConfig(participation=0.5, straggler_prob=0.5,
                          drop_prob=0.2, leave_prob=0.3, join_prob=0.4)
    a = RoundScheduler(16, cfg, key=jax.random.PRNGKey(4))
    b = RoundScheduler(16, cfg, key=jax.random.PRNGKey(4))
    for _ in range(10):
        b.cohort_rng().random(100)          # cohort draws on b only
        ea, eb = a.step(), b.step()
        for fa, fb in zip(ea, eb):
            np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))


def test_scheduler_diurnal_profile_quantized_participation():
    """A diurnal profile breathes the per-round participant count
    between trough and peak, always in whole cohort quanta, without
    touching the event streams' determinism."""
    from repro.server import DiurnalProfile
    prof = DiurnalProfile(period=8, trough=0.25, peak=1.0)
    s = RoundScheduler(64, SchedulerConfig(participation=0.5),
                       key=jax.random.PRNGKey(5), profile=prof, quantum=8)
    assert s.k == 32
    counts = [s.step().participants.size for _ in range(8)]
    assert all(c % 8 == 0 for c in counts)
    assert max(counts) == 32                      # peak round = full k
    assert min(counts) == 8                       # trough = 0.25 * 32
    assert len(set(counts)) > 1                   # it actually breathes
    # replay determinism holds with the profile on
    s2 = RoundScheduler(64, SchedulerConfig(participation=0.5),
                        key=jax.random.PRNGKey(5), profile=prof, quantum=8)
    counts2 = [s2.step().participants.size for _ in range(8)]
    assert counts == counts2


# -------------------------------------------------------------- multi-task

def test_multitask_single_task_parity_with_downstream(key):
    """One-task MultiTaskTrainer == core.downstream.sgd_train exactly."""
    rng = np.random.default_rng(0)
    y = jnp.asarray(rng.integers(0, 4, size=96), jnp.int32)
    feats = jax.nn.one_hot(y, 4) + 0.1 * jnp.asarray(
        rng.normal(size=(96, 4)), jnp.float32)

    trainer = MultiTaskTrainer(key, [TaskSpec("label", 4)], 4, lr=1e-3)
    trainer.fit(key, feats, {"label": y}, steps=25, batch=32)

    probe = DS.init_linear_probe(jax.random.fold_in(key, 0), 4, 4)
    ref = DS.sgd_train(key, DS.linear_probe, probe, feats, y,
                       steps=25, lr=1e-3, batch=32)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6),
        trainer.params["label"], ref)
    acc = trainer.accuracy(feats, {"label": y})["label"]
    assert acc == pytest.approx(DS.accuracy(DS.linear_probe, ref, feats, y),
                                abs=0.05)


def test_multitask_trains_all_heads_from_shared_features(key):
    rng = np.random.default_rng(1)
    y1 = jnp.asarray(rng.integers(0, 3, size=120), jnp.int32)
    y2 = jnp.asarray(rng.integers(0, 2, size=120), jnp.int32)
    feats = jnp.concatenate([jax.nn.one_hot(y1, 3), jax.nn.one_hot(y2, 2)],
                            axis=-1)
    trainer = MultiTaskTrainer(key, [TaskSpec("a", 3), TaskSpec("b", 2)], 5)
    trainer.fit(key, feats, {"a": y1, "b": y2}, steps=120, batch=64)
    acc = trainer.accuracy(feats, {"a": y1, "b": y2})
    assert acc["a"] > 0.9 and acc["b"] > 0.9
    with pytest.raises(ValueError, match="missing"):
        trainer.fit(key, feats, {"a": y1}, steps=1)


# ----------------------------------------------------------------- runtime

def test_async_runtime_churn_versions_and_accounting(tiny_cfg, server, key):
    """End-to-end churn scenario: version lag lands in the store, byte
    accounting closes, and stored records re-decode bit-exactly against
    their own version after multiple merges."""
    n_slots, b, rounds = 8, 2, 8
    engine = SimEngine(tiny_cfg, gamma=0.9, n_local_steps=0)
    sched = RoundScheduler(n_slots, STANDARD_SCENARIOS["churn"].sched,
                           key=jax.random.PRNGKey(3))
    srv = AsyncCodeServer(engine, server, sched, merge_every=2,
                          staleness_decay=0.5)
    data = jax.random.normal(key, (n_slots, b, 8, 8, 3))
    labels = {"content": jnp.tile(jnp.arange(b), (n_slots, 1))}

    refs = []
    for r in range(rounds):
        srv.run_round(data, labels=labels)
        for rec in srv.store.records[len(refs):]:
            codes = rec.packed.unpack().reshape((-1,) + rec.packed.shape[2:])
            refs.append(np.asarray(OC.codes_to_features(
                None, tiny_cfg, codes,
                codebook=srv.registry.get(rec.version))))

    assert srv.n_merges == rounds // 2 >= 2
    assert srv.registry.latest == srv.n_merges
    assert srv.bytes_sent == (srv.bytes_delivered + srv.bytes_dropped
                              + srv.queue.bytes_in_flight)
    versions = {rec.version for rec in srv.store.records}
    assert len(versions) >= 2          # stragglers/re-joiners really lag

    # bit-exact per-version decode after all merges (tentpole acceptance)
    for rec, ref in zip(srv.store.records, refs):
        codes = rec.packed.unpack().reshape((-1,) + rec.packed.shape[2:])
        now = OC.codes_to_features(None, tiny_cfg, codes,
                                   codebook=srv.registry.get(rec.version))
        np.testing.assert_array_equal(np.asarray(now), ref)

    feats, got = srv.dataset()
    assert feats.shape[0] == srv.store.n_samples
    assert got["content"].shape[0] == srv.store.n_samples


def test_async_runtime_full_participation_matches_engine_round(tiny_cfg,
                                                               server, key):
    """With no churn/stragglers/merges, the runtime's round IS the plain
    engine round: same client states, same codes in the store."""
    n_slots, b = 4, 2
    data = jax.random.normal(key, (n_slots, b, 8, 8, 3))
    engine = SimEngine(tiny_cfg, gamma=0.9)
    sched = RoundScheduler(n_slots, SchedulerConfig(),
                           key=jax.random.PRNGKey(0))
    srv = AsyncCodeServer(engine, server, sched, merge_every=0)
    srv.run_round(data)

    clients, packed = engine.round(engine.init_clients(server, n_slots),
                                   data)
    np.testing.assert_array_equal(np.asarray(srv.store.codes()),
                                  np.asarray(packed.unpack()).reshape(
                                      (-1,) + packed.shape[2:]))
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6),
        srv.clients, clients)


# ------------------------------------------------------------- tombstones

def test_retired_shims_raise_with_pointer_to_wire():
    """The long-deprecated PR-1 shims are GONE, not warning: importing
    any of them raises ImportError pointing at the unified wire layer."""
    with pytest.raises(ImportError, match="repro.server.CodeStore"):
        from repro.sim import IngestBuffer  # noqa: F401
    with pytest.raises(ImportError, match="repro.wire.CodePayload"):
        from repro.sim import PackedCodes  # noqa: F401
    with pytest.raises(ImportError, match="repro.wire.CodePayload"):
        from repro.sim.engine import PackedCodes  # noqa: F401
    import repro.sim
    assert "IngestBuffer" not in repro.sim.__all__
    assert "PackedCodes" not in repro.sim.__all__

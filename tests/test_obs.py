"""Flight recorder + metrics plane (repro.obs).

The load-bearing contract is NEUTRALITY: tracing must observe the
pipeline without perturbing it. With a recorder installed, cohort round
words/features stay bit-identical, scheduler draws are unchanged (same
seeds as the determinism tests), and the counted fused-dispatch numbers
match the PR-4/PR-5 regression baselines. The recorder itself must obey
§2.5 — packed words, labels and latents never enter the trace, only
payload METADATA.
"""
import json

import jax
import numpy as np
import pytest

from repro import obs
from repro.core import octopus as OC
from repro.core.dvqae import DVQAEConfig
from repro.obs import report as obs_report
from repro.sim import CohortEngine, CohortPlan
from repro.wire import OctopusServer

N_CLIENTS = 12


@pytest.fixture(autouse=True)
def no_ambient_recorder():
    """Tests own the recorder lifecycle — drop any env-installed one."""
    obs.uninstall()
    yield
    obs.uninstall()


@pytest.fixture(scope="module")
def tiny_cfg():
    return DVQAEConfig(kind="image", in_channels=3, hidden=8, latent_dim=8,
                       codebook_size=16, n_res_blocks=1)


@pytest.fixture(scope="module")
def server(tiny_cfg):
    return OC.server_init(jax.random.PRNGKey(0), tiny_cfg)


@pytest.fixture(scope="module")
def data():
    return jax.random.normal(jax.random.PRNGKey(1),
                             (N_CLIENTS, 2, 8, 8, 3))


def _data_fn(data):
    return lambda ids: data[np.asarray(ids)]


# ------------------------------------------------------------ zero-overhead

def test_recorder_is_off_by_default():
    assert obs.active() is None


def test_recording_scopes_the_singleton(tmp_path):
    path = tmp_path / "t.jsonl"
    with obs.recording(path) as rec:
        assert obs.active() is rec
        rec.event("merge", version=1)
        with rec.span("decode", version=0):
            pass
    assert obs.active() is None
    events = obs_report.load_events(str(path))
    assert [e["kind"] for e in events] == ["merge", "decode"]
    assert events[1]["dur_ms"] >= 0.0
    assert [e["seq"] for e in events] == [0, 1]


def test_install_from_env(tmp_path, monkeypatch):
    path = tmp_path / "env.jsonl"
    monkeypatch.setenv(obs.ENV_VAR, str(path))
    rec = obs.install_from_env()
    try:
        assert obs.active() is rec and rec.path == str(path)
        # idempotent while one is installed
        assert obs.install_from_env() is rec
    finally:
        obs.uninstall()
        rec.close()


# ------------------------------------------------------- tracing neutrality

def test_facade_round_bit_identical_with_tracing(tiny_cfg, server, data,
                                                 tmp_path):
    srv = OctopusServer(server, tiny_cfg)
    batch = data[0]
    plain = srv.deploy().round(batch)
    with obs.recording(tmp_path / "t.jsonl"):
        traced = srv.deploy().round(batch)
    np.testing.assert_array_equal(np.asarray(plain.payload),
                                  np.asarray(traced.payload))
    assert plain.nbytes == traced.nbytes
    assert plain.shape == traced.shape


def test_cohort_round_bit_identical_with_tracing(tiny_cfg, server, data,
                                                 tmp_path):
    """Streamed round words + merged features are unchanged by tracing."""
    engine = CohortEngine(tiny_cfg, gamma=0.9, n_local_steps=0)
    plan = CohortPlan.build(np.arange(N_CLIENTS), 5)
    plain = engine.round(server, plan, _data_fn(data))
    with obs.recording(tmp_path / "t.jsonl") as rec:
        traced = engine.round(server, plan, _data_fn(data))
    np.testing.assert_array_equal(plain.stats.num, traced.stats.num)
    np.testing.assert_array_equal(plain.stats.den, traced.stats.den)
    for a, b in zip(plain.payloads, traced.payloads):
        np.testing.assert_array_equal(np.asarray(a.payload),
                                      np.asarray(b.payload))
    fa = OC.codes_to_features(server, tiny_cfg, plain.payloads[0])
    fb = OC.codes_to_features(server, tiny_cfg, traced.payloads[0])
    np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))
    # one encode event per cohort, metadata matching the payloads
    events = obs_report.load_events(str(tmp_path / "t.jsonl"))
    enc = [e for e in events if e["kind"] == "encode"]
    assert len(enc) == plan.n_cohorts
    assert [e["nbytes"] for e in enc] == [p.nbytes for p in traced.payloads]
    assert rec.n_events == len(events)


def test_scheduler_draws_unchanged_with_recorder(tmp_path):
    """Reuses the determinism test's seeds: a recorder must not touch the
    per-purpose RNG substreams."""
    from repro.server import RoundScheduler, SchedulerConfig
    cfg = SchedulerConfig(participation=0.5, straggler_prob=0.5, max_delay=3,
                          drop_prob=0.2, leave_prob=0.3, join_prob=0.4)

    def trace(key):
        s = RoundScheduler(16, cfg, key=key)
        return [s.step() for _ in range(12)]

    plain = trace(jax.random.PRNGKey(5))
    with obs.recording(tmp_path / "t.jsonl"):
        traced = trace(jax.random.PRNGKey(5))
    for ea, eb in zip(plain, traced):
        for fa, fb in zip(ea, eb):
            np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))


def test_traffic_run_identical_with_tracing(tiny_cfg, data, tmp_path):
    """The replay-determinism run (same seeds as tests/test_cohort.py)
    with tracing on: identical ledger/codebooks/features, and the trace's
    per-round Σ-bytes equal the §2.8 accounting bit-exactly."""
    from repro.server import RoundScheduler, SchedulerConfig

    def go():
        state = OC.server_init(jax.random.PRNGKey(0), tiny_cfg)
        wire = OctopusServer(state, tiny_cfg)
        sched = RoundScheduler(
            N_CLIENTS, SchedulerConfig(participation=0.5,
                                       straggler_prob=0.4, drop_prob=0.2),
            key=jax.random.PRNGKey(11))
        engine = CohortEngine(tiny_cfg, gamma=0.9, n_local_steps=0)
        hist = engine.run_traffic(wire, sched, _data_fn(data),
                                  cohort_size=3, n_rounds=4, merge_every=2)
        return wire, hist

    wa, ha = go()
    trace_path = tmp_path / "traffic.jsonl"
    with obs.recording(trace_path):
        wb, hb = go()
    assert ha == hb
    np.testing.assert_array_equal(np.asarray(wa.registry.current),
                                  np.asarray(wb.registry.current))
    assert wa.store.total_bytes == wb.store.total_bytes
    fa, _ = wa.features()
    fb, _ = wb.features()
    np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))

    # §2.8 accounting INSIDE the trace: per-round uplink-event Σ-nbytes
    # == the round ledger's bytes_sent == the TrafficRound ledger
    summary = obs_report.summarize(obs_report.load_events(str(trace_path)))
    assert obs_report.check_bytes(summary) == []
    by_round = {r["round"]: r for r in summary["rounds"]}
    for h in hb:
        assert by_round[h.round]["uplink_bytes"] == h.bytes_sent
        assert by_round[h.round]["bytes_sent"] == h.bytes_sent
    assert summary["uplinks"]["bytes"] == sum(h.bytes_sent for h in hb)
    assert summary["merges"] and len(summary["rounds"]) == 4


# ------------------------------------------------------- dispatch monitor

def test_dispatch_monitor_matches_regression_baselines(tiny_cfg, server,
                                                       data, tmp_path):
    """PR-4/PR-5 baseline: one facade round = exactly ONE encoder pass
    and ONE fused encode dispatch — with tracing on AND off."""
    srv = OctopusServer(server, tiny_cfg)
    batch = data[0]
    with obs.dispatch_monitor() as plain:
        srv.deploy().round(batch, finetune=0)
    with obs.recording(tmp_path / "t.jsonl") as rec:
        with obs.dispatch_monitor() as traced:
            srv.deploy().round(batch, finetune=0)
    for counts in (plain, traced):
        assert (counts.encoder_passes, counts.encode_dispatches) == (1, 1)
        assert counts.pack_dispatches == 0      # fused pack, no extra hop
    # non-zero counts folded into the active recorder's metrics
    snap = rec.metrics.snapshot()["counters"]
    assert snap["encoder_passes"] == 1 and snap["encode_dispatches"] == 1


def test_dispatch_monitor_restores_originals():
    from repro.core import dvqae
    from repro.kernels import ops
    before = (dvqae.encode, ops.encode_codes, ops.decode_codes,
              ops.pack_codes, ops.unpack_codes)
    with pytest.raises(RuntimeError):
        with obs.dispatch_monitor():
            raise RuntimeError("boom")
    assert (dvqae.encode, ops.encode_codes, ops.decode_codes,
            ops.pack_codes, ops.unpack_codes) == before


def test_dispatch_monitor_counts_decode_and_pack(tiny_cfg):
    import jax.numpy as jnp
    from repro.kernels import ops
    idx = jnp.arange(16, dtype=jnp.int32) % 4
    with obs.dispatch_monitor() as counts:
        words = ops.pack_codes(idx, bits=2)
        ops.unpack_codes(words, bits=2, count=16)
    assert counts.pack_dispatches == 1
    assert counts.unpack_dispatches == 1
    assert counts.encoder_passes == 0


# -------------------------------------------------------- §2.5 in the trace

def test_trace_never_carries_words_or_labels(tiny_cfg, server, data,
                                            tmp_path):
    """Metadata-only capture, enumerated over EVERY event kind: no event
    field holds the packed words, a label channel, or anything
    array-shaped. The kind list comes from ``obs.EVENT_KINDS`` at
    runtime, so a newly added event type lands in this scan the moment
    it exists — it cannot silently start carrying words or latents."""
    srv = OctopusServer(server, tiny_cfg)
    batch = data[0]
    labels = {"content": np.arange(batch.shape[0], dtype=np.int32)}
    with obs.recording(tmp_path / "t.jsonl") as rec:
        p = srv.deploy().round(batch, labels=labels)
        srv.ingest(p)
        srv.features()
        # synthesize one event of every registered kind with payload
        # metadata attached — the §2.5 scan below must hold for ALL of
        # them, including kinds no pipeline call emitted above
        for kind in obs.EVENT_KINDS:
            rec.event(kind, **obs.payload_meta(p))
    seen = set()
    for ev in obs_report.load_events(str(tmp_path / "t.jsonl")):
        seen.add(ev["kind"])
        assert "payload" not in ev and "words" not in ev
        assert "labels" not in ev and "content" not in ev
        for v in ev.values():
            assert isinstance(v, (int, float, bool, str, type(None)))
    assert seen >= set(obs.EVENT_KINDS)       # every kind was scanned
    meta = obs.payload_meta(p)
    assert set(meta) == set(obs.PAYLOAD_META_FIELDS)
    assert meta["nbytes"] == p.nbytes and meta["privatized"] is True


def test_event_refuses_arrays_and_containers(tmp_path):
    """The recorder enforces §2.5 mechanically: array- or
    container-valued event fields raise, for every event kind — new
    call sites cannot leak words/labels even by mistake."""
    with obs.recording(tmp_path / "t.jsonl") as rec:
        for kind in obs.EVENT_KINDS:
            for bad in (np.arange(4), [1, 2], (1, 2), {"y": 1}, b"words"):
                with pytest.raises(ValueError, match="scalar-only"):
                    rec.event(kind, leak=bad)
        ok = rec.event("tap", n=3, f=1.5, s="x", b=True, none=None,
                       np_scalar=np.float32(2.0))
        assert ok["n"] == 3
    events = obs_report.load_events(str(tmp_path / "t.jsonl"))
    assert [e["kind"] for e in events] == ["tap"]   # refused != written


# ----------------------------------------------------------- report CLI

def test_report_cli_check_and_json(tiny_cfg, data, tmp_path, capsys):
    from repro.server import RoundScheduler, SchedulerConfig
    state = OC.server_init(jax.random.PRNGKey(0), tiny_cfg)
    wire = OctopusServer(state, tiny_cfg)
    sched = RoundScheduler(
        N_CLIENTS, SchedulerConfig(participation=0.5, straggler_prob=0.4,
                                   drop_prob=0.2),
        key=jax.random.PRNGKey(11))
    engine = CohortEngine(tiny_cfg, gamma=0.9, n_local_steps=0)
    trace = tmp_path / "t.jsonl"
    with obs.recording(trace):
        hist = engine.run_traffic(wire, sched, _data_fn(data),
                                  cohort_size=3, n_rounds=4, merge_every=2)
        wire.features()

    out_json = tmp_path / "rep.json"
    rc = obs_report.main([str(trace), "--check", "--json", str(out_json)])
    assert rc == 0
    text = capsys.readouterr().out
    assert "bytes check OK" in text and "uplinks:" in text
    rep = json.loads(out_json.read_text())
    assert rep["section"] == "obs" and rep["bytes_check_ok"] is True
    rows = {r["name"]: r for r in rep["rows"]}
    # BENCH-style: real JSON numbers, extra the only string field
    for r in rep["rows"]:
        assert isinstance(r["value"], (int, float))
        assert isinstance(r["extra"], str)
    assert rows["rounds"]["value"] == 4
    # the report's measured Σ-bytes reproduce the traffic ledger
    assert rows["uplink_bytes"]["value"] == sum(h.bytes_sent for h in hist)
    assert any(n.startswith("decode_v") for n in rows)


def test_report_check_fails_on_tampered_ledger(tmp_path):
    trace = tmp_path / "bad.jsonl"
    events = [
        {"kind": "uplink", "round": 0, "nbytes": 8},
        {"kind": "round", "round": 0, "bytes_sent": 12, "dur_ms": 1.0},
    ]
    trace.write_text("\n".join(json.dumps(e) for e in events) + "\n")
    summary = obs_report.summarize(obs_report.load_events(str(trace)))
    assert obs_report.check_bytes(summary)
    assert obs_report.main([str(trace), "--check"]) == 1
    # an EMPTY trace is not evidence either
    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    assert obs_report.main([str(empty), "--check"]) == 1


# ----------------------------------------------------------- metrics plane

def test_metrics_registry_instruments():
    m = obs.MetricsRegistry()
    m.inc("uplinks", 3)
    m.inc("uplinks")
    m.set_gauge("depth", 7)
    for v in (2.0, 4.0, 6.0):
        m.observe("ms", v)
    snap = m.snapshot()
    assert snap["counters"]["uplinks"] == 4
    assert snap["gauges"]["depth"] == 7
    h = snap["histograms"]["ms"]
    assert (h["count"], h["min"], h["max"], h["mean"]) == (3, 2.0, 6.0, 4.0)


def test_queue_and_store_metrics(tiny_cfg, server, data, tmp_path):
    from repro.server.runtime import UplinkQueue
    srv = OctopusServer(server, tiny_cfg)
    with obs.recording(tmp_path / "t.jsonl") as rec:
        p = srv.deploy().round(data[0])
        q = UplinkQueue()
        q.send(p, round=0, delay=1)
        assert rec.metrics.gauge("uplink_queue_depth").value == 1
        q.deliver(srv, 1)
        assert rec.metrics.gauge("uplink_queue_depth").value == 0
        assert rec.metrics.gauge("store_records").value == 1
        assert rec.metrics.gauge("store_bytes").value == p.nbytes
    events = obs_report.load_events(str(tmp_path / "t.jsonl"))
    kinds = [e["kind"] for e in events]
    assert kinds.count("uplink") == 2     # facade round + queue.send
    assert "ingest" in kinds

"""Unit tests: Group & Sliced VQ (Eq. 2-3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gsvq


def test_reduces_to_group_of_all(key):
    """n_groups=1, n_slices=1 quantizes to the weighted average of ALL atoms
    (one big group) — sanity of the degenerate case."""
    z = jax.random.normal(key, (10, 8))
    cb = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
    out = gsvq.gsvq_quantize(z, cb, n_groups=1, n_slices=1)
    assert out.indices.shape == (10, 1)
    assert bool(jnp.all(out.indices == 0))


def test_group_index_picks_nearest_group(key):
    """Two well-separated groups: samples near group 1's atoms index group 1."""
    g0 = jnp.zeros((4, 8)) + jnp.array([10.0] * 8)
    g1 = jnp.zeros((4, 8)) - jnp.array([10.0] * 8)
    cb = jnp.concatenate([g0, g1]) + 0.1 * jax.random.normal(key, (8, 8))
    z = jnp.stack([jnp.full((8,), 9.5), jnp.full((8,), -9.5)])
    out = gsvq.gsvq_quantize(z, cb, n_groups=2)
    np.testing.assert_array_equal(np.asarray(out.indices[:, 0]), [0, 1])


def test_weighted_average_in_group_hull(key):
    """Eq. 3 output is a convex combination of the matched group's atoms."""
    z = jax.random.normal(key, (6, 4))
    cb = jax.random.normal(jax.random.PRNGKey(1), (8, 4))
    out = gsvq.gsvq_quantize(z, cb, n_groups=2)
    groups = cb.reshape(2, 4, 4)
    for i in range(6):
        g = np.asarray(groups[out.indices[i, 0]])
        q = np.asarray(out.quantized[i])
        assert q.min() >= g.min() - 1e-4 and q.max() <= g.max() + 1e-4


def test_sliced_indices_shape(key):
    z = jax.random.normal(key, (5, 3, 12))
    cb = jax.random.normal(jax.random.PRNGKey(1), (16, 12))
    out = gsvq.gsvq_quantize(z, cb, n_groups=4, n_slices=3)
    assert out.indices.shape == (5, 3, 3)
    assert int(out.indices.max()) < 4


def test_ste_gradient(key):
    z = jax.random.normal(key, (4, 8))
    cb = jax.random.normal(jax.random.PRNGKey(1), (8, 8))
    g = jax.grad(lambda z: jnp.sum(
        gsvq.gsvq_quantize(z, cb, n_groups=2).quantized))(z)
    np.testing.assert_allclose(np.asarray(g), np.ones_like(g), rtol=1e-6)


def test_dequantize_uniform_average(key):
    """Server reconstruction = uniform group mean of the indexed group."""
    cb = jax.random.normal(key, (8, 4))
    idx = jnp.array([[0], [1]])
    out = gsvq.gsvq_dequantize_indices(idx, cb, n_groups=2, n_slices=1)
    groups = cb.reshape(2, 4, 4)
    np.testing.assert_allclose(np.asarray(out[0]),
                               np.asarray(groups[0].mean(0)), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(out[1]),
                               np.asarray(groups[1].mean(0)), rtol=1e-5)


def test_bits_per_position():
    assert gsvq.gsvq_bits_per_position(16, 1) == 4
    assert gsvq.gsvq_bits_per_position(16, 4) == 16
    assert gsvq.gsvq_bits_per_position(2, 2) == 2


@pytest.mark.parametrize("n_groups,n_slices", [(2, 1), (4, 2), (8, 4)])
def test_shapes_roundtrip(key, n_groups, n_slices):
    z = jax.random.normal(key, (3, 7, 16))
    cb = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    out = gsvq.gsvq_quantize(z, cb, n_groups=n_groups, n_slices=n_slices)
    assert out.quantized.shape == z.shape
    rec = gsvq.gsvq_dequantize_indices(out.indices, cb, n_groups=n_groups,
                                       n_slices=n_slices)
    assert rec.shape == z.shape
    assert bool(jnp.all(jnp.isfinite(rec)))

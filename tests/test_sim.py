"""Batched multi-client sim engine (repro.sim) + bit-packing codec.

The two contracts that let the engine replace the Python client loop:
  * one jitted vmap round over N stacked clients == N single-client
    ``octopus.client_round`` calls (allclose; indices exactly equal),
  * pack -> unpack of code indices is bit-exact, with Pallas/jnp parity.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import octopus as OC
from repro.core.dvqae import DVQAEConfig
from repro.kernels import ops, ref
from repro.kernels.pack_bits import code_bits, packing_dims
from repro.server import CodeStore
from repro.sim import SimEngine, stack_clients


@pytest.fixture(scope="module")
def tiny_cfg():
    return DVQAEConfig(kind="image", in_channels=3, hidden=8, latent_dim=8,
                       codebook_size=16, n_res_blocks=1)


@pytest.fixture(scope="module")
def server(tiny_cfg):
    return OC.server_init(jax.random.PRNGKey(0), tiny_cfg)


def _assert_trees_close(a, b, **kw):
    jax.tree.map(lambda x, y: np.testing.assert_allclose(
        np.asarray(x), np.asarray(y), **kw), a, b)


# ------------------------------------------------------------------- codec

@pytest.mark.parametrize("n_atoms", [16, 256, 1024])
def test_pack_roundtrip_bitexact(n_atoms):
    bits = code_bits(n_atoms)
    rng = np.random.default_rng(n_atoms)
    for count in (1, 5, 257):
        codes = jnp.asarray(rng.integers(0, n_atoms, size=count), jnp.int32)
        packed_ref = ref.pack_codes_ref(codes, bits=bits)
        back = ref.unpack_codes_ref(packed_ref, bits=bits, count=count)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))
        # Pallas kernels produce the identical word stream and codes
        packed = ops.pack_codes(codes, bits=bits)
        np.testing.assert_array_equal(np.asarray(packed),
                                      np.asarray(packed_ref))
        back2 = ops.unpack_codes(packed, bits=bits, count=count)
        np.testing.assert_array_equal(np.asarray(back2), np.asarray(codes))


@pytest.mark.parametrize("n_atoms", [16, 256, 1024])
def test_packed_size_is_dense(n_atoms):
    """ceil(log2 K) bits per code, plus at most one group of padding."""
    bits = code_bits(n_atoms)
    G, W = packing_dims(bits)
    codes = jnp.zeros((1000,), jnp.int32)
    packed = ops.pack_codes(codes, bits=bits)
    nbytes = packed.size * packed.dtype.itemsize
    assert nbytes >= (1000 * bits + 7) // 8
    assert nbytes <= ((1000 + G - 1) // G) * W * 4


def test_transmission_measures_packed_bytes(tiny_cfg, server, key):
    """A legacy Transmission carries the packed payload; nbytes is
    measured from it (CodePayload.nbytes is the single source) and the
    payload unpacks bit-exactly to the indices via the wire coercion
    (the unpack_transmission shim is a tombstone now)."""
    from repro.core.dvqae import forward
    from repro.wire import CodePayload, as_payload
    client = OC.client_init(server)
    x = jax.random.normal(key, (4, 8, 8, 3))
    idx = forward(client.params, tiny_cfg, x).latent.indices
    p = CodePayload.pack(idx, bits=OC.transmit_bits(tiny_cfg))
    tx = OC.Transmission(indices=idx, nbytes=p.nbytes,
                         labels=jnp.arange(4),
                         payload=p.payload, bits=p.bits)
    assert tx.payload is not None
    assert tx.bits == code_bits(tiny_cfg.codebook_size)
    assert tx.nbytes == tx.payload.size * tx.payload.dtype.itemsize
    back = as_payload(tx).unpack()
    np.testing.assert_array_equal(np.asarray(back), np.asarray(tx.indices))


# ------------------------------------------------------------------ engine

def test_engine_round_matches_client_loop(tiny_cfg, server, key):
    """N=64 clients in one jitted vmap == 64 single-client rounds."""
    n_clients = 64
    data = jax.random.normal(key, (n_clients, 2, 8, 8, 3))
    engine = SimEngine(tiny_cfg, lr=1e-4, gamma=0.9)
    clients = engine.init_clients(server, n_clients)
    clients, packed = engine.round(clients, data)

    singles, idxs = [], []
    for i in range(n_clients):
        c = OC.client_init(server)
        c, idx = OC.client_round(c, tiny_cfg, data[i], lr=1e-4, gamma=0.9)
        singles.append(c)
        idxs.append(idx)

    np.testing.assert_array_equal(np.asarray(packed.unpack()),
                                  np.asarray(jnp.stack(idxs)))
    # atol covers AdamW's lr-sized (1e-4) normalized first-step updates,
    # whose direction is reduction-order-sensitive where gradients ~ 0
    _assert_trees_close(clients, stack_clients(singles),
                        rtol=1e-4, atol=3e-4)


def test_engine_sharded_matches_unsharded(tiny_cfg, server, key):
    """shard_map over the mesh 'data' axis == plain vmap."""
    from repro.launch.mesh import make_host_mesh
    n_clients = 8
    data = jax.random.normal(key, (n_clients, 2, 8, 8, 3))
    plain = SimEngine(tiny_cfg, gamma=0.9)
    sharded = SimEngine(tiny_cfg, gamma=0.9, mesh=make_host_mesh())
    c1, p1 = plain.round(plain.init_clients(server, n_clients), data)
    c2, p2 = sharded.round(sharded.init_clients(server, n_clients), data)
    np.testing.assert_array_equal(np.asarray(p1.unpack()),
                                  np.asarray(p2.unpack()))
    _assert_trees_close(c1, c2, rtol=1e-4, atol=5e-5)


def test_engine_merge_matches_sequence_merge(tiny_cfg, server, key):
    n_clients = 4
    data = jax.random.normal(key, (n_clients, 2, 8, 8, 3))
    engine = SimEngine(tiny_cfg, gamma=0.9)
    clients, _ = engine.round(engine.init_clients(server, n_clients), data)
    merged = engine.merge_into_server(server, clients)
    ref_merged = OC.server_merge_codebooks(
        server, [clients.params["codebook"][i] for i in range(n_clients)],
        [clients.ema.counts[i] for i in range(n_clients)])
    np.testing.assert_allclose(np.asarray(merged.params["codebook"]),
                               np.asarray(ref_merged.params["codebook"]),
                               rtol=1e-6)


# ------------------------------------------------------------------ ingest

def test_code_store_accumulates_engine_rounds(tiny_cfg, server, key):
    """Engine uplinks land in repro.server.CodeStore (the IngestBuffer
    successor): measured byte totals, lazily-decoded dataset, labels."""
    n_clients, b = 4, 2
    data = jax.random.normal(key, (n_clients, b, 8, 8, 3))
    engine = SimEngine(tiny_cfg, gamma=0.9)
    clients = engine.init_clients(server, n_clients)
    store = CodeStore(tiny_cfg)
    packeds = []
    for r in range(3):
        clients, packed = engine.round(clients, data)
        store.add(packed, labels=jnp.full((n_clients, b), r % 2, jnp.int32))
        packeds.append(packed)
    assert len(store) == 3
    assert store.total_bytes == sum(p.nbytes for p in packeds)
    assert store.ingested_bytes == store.total_bytes   # nothing evicted
    assert store.n_samples == 3 * n_clients * b
    codes = store.codes()
    assert codes.shape[0] == store.n_samples
    assert codes.dtype == jnp.int32
    feats, labels = store.dataset(server)
    assert feats.shape[0] == labels["label"].shape[0] == store.n_samples
    np.testing.assert_array_equal(
        np.asarray(labels["label"]),
        np.repeat([0, 1, 0], n_clients * b))


# -------------------------------------------------------------------- data

def test_stacked_batches_shapes_and_pool(key):
    """stacked_batches yields (C, B, ...) rounds drawn without
    replacement from each client's own shard."""
    from repro.data import make_images, partition_stacked, stacked_batches
    data = make_images(key, 48, size=8, n_identities=4)
    stacked = partition_stacked(data, 4, regime="iid")
    n_per = stacked.x.shape[1]
    seen = [[] for _ in range(4)]
    got = 0
    for b in stacked_batches(stacked, 4, epochs=2):
        assert b.x.shape == (4, 4, 8, 8, 3)
        assert b.content.shape == (4, 4)
        got += 1
        for c in range(4):
            seen[c].extend(np.asarray(b.content[c]).tolist())
    assert got == 2 * (n_per // 4)
    for c in range(4):
        own = np.asarray(stacked.content[c])
        # each epoch is a permutation of the client's shard labels
        assert sorted(seen[c][:n_per]) == sorted(own.tolist())


# ------------------------------------------------------------------ fedavg

def test_fedavg_batched_matches_sequential(key):
    from repro.core.downstream import conv_classifier, init_conv_classifier
    from repro.core.fedavg import (FedConfig, fedavg_train,
                                   fedavg_train_batched)
    from repro.data import make_images, partition_stacked

    data = make_images(key, 64, size=8, n_identities=4)
    stacked = partition_stacked(data, 4, regime="iid")
    shards = [type(data)(x=stacked.x[i], content=stacked.content[i],
                         style=stacked.style[i]) for i in range(4)]
    clf = init_conv_classifier(key, in_channels=3, n_classes=4)
    fc = FedConfig(rounds=2, local_epochs=1, local_batch=8,
                   dp_clip=0.5, dp_noise=0.01)
    p_seq = fedavg_train(key, conv_classifier, clf, shards,
                         lambda d: d.content, fc)
    p_bat = fedavg_train_batched(key, conv_classifier, clf, stacked.x,
                                 stacked.content, fc)
    _assert_trees_close(p_seq, p_bat, rtol=1e-4, atol=1e-5)

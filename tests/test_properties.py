"""Property-based tests (hypothesis) on system invariants.

hypothesis is a dev-only dependency (requirements-dev.txt); without it
the module skips instead of failing collection.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import ema, gsvq, vq
from repro.core.overheads import CommModel, federated_bytes, octopus_bytes
from repro.wire import CodePayload

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=25, deadline=None)


@given(n=st.integers(1, 64), k=st.integers(2, 64),
       m=st.sampled_from([4, 8, 16]), seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_vq_idempotent(n, k, m, seed):
    """Quantizing an already-quantized latent is a fixed point."""
    kz, kc = jax.random.split(jax.random.PRNGKey(seed))
    z = jax.random.normal(kz, (n, m))
    cb = jax.random.normal(kc, (k, m))
    out1 = vq.quantize(z, cb)
    out2 = vq.quantize(out1.quantized, cb)
    np.testing.assert_array_equal(np.asarray(out1.indices),
                                  np.asarray(out2.indices))
    assert float(out2.commit_loss) < 1e-9


@given(n=st.integers(1, 64), k=st.integers(2, 64),
       m=st.sampled_from([4, 8]), seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_vq_indices_in_range(n, k, m, seed):
    kz, kc = jax.random.split(jax.random.PRNGKey(seed))
    z = jax.random.normal(kz, (n, m)) * 10
    cb = jax.random.normal(kc, (k, m))
    idx = vq.nearest_atom(z, cb)
    assert int(idx.min()) >= 0 and int(idx.max()) < k


@given(n=st.integers(1, 32), seed=st.integers(0, 2**31 - 1),
       scale=st.floats(0.1, 10.0), shift=st.floats(-5.0, 5.0))
@settings(**SETTINGS)
def test_vq_translation_of_codebook_and_data(n, seed, scale, shift):
    """Nearest-neighbour structure is invariant to joint affine transforms
    of data and codebook (distances scale uniformly)."""
    kz, kc = jax.random.split(jax.random.PRNGKey(seed))
    z = jax.random.normal(kz, (n, 8))
    cb = jax.random.normal(kc, (16, 8))
    i1 = vq.nearest_atom(z, cb)
    i2 = vq.nearest_atom(z * scale + shift, cb * scale + shift)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


@given(g=st.sampled_from([1, 2, 4]), s=st.sampled_from([1, 2, 4]),
       seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_gsvq_ste_value_consistency(g, s, seed):
    """forward(quantized) == z + (q - z): STE value identity."""
    kz, kc = jax.random.split(jax.random.PRNGKey(seed))
    z = jax.random.normal(kz, (6, 16))
    cb = jax.random.normal(kc, (16, 16))
    out = gsvq.gsvq_quantize(z, cb, n_groups=g, n_slices=s)
    assert out.quantized.shape == z.shape
    assert bool(jnp.all(jnp.isfinite(out.quantized)))
    assert int(out.indices.max()) < max(g, 1)


@given(gamma=st.floats(0.5, 0.999), seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_ema_mass_conservation(gamma, seed):
    """Total EMA count mass after one update = gamma*old + (1-gamma)*N."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    cb = jax.random.normal(k1, (8, 4))
    st_ = ema.init_ema(cb)
    z = jax.random.normal(k2, (40, 4))
    idx = vq.nearest_atom(z, cb)
    s2 = ema.ema_update(st_, z, idx, gamma=gamma)
    np.testing.assert_allclose(float(jnp.sum(s2.counts)),
                               gamma * 8 + (1 - gamma) * 40, rtol=1e-4)


@given(nc=st.integers(1, 1000), nm=st.integers(1, 10**8),
       nd=st.integers(1, 10**6), ne=st.integers(1, 1000),
       nz=st.integers(1, 10**4))
@settings(**SETTINGS)
def test_overheads_positive_and_fl_grows_with_epochs(nc, nm, nd, ne, nz):
    c = CommModel(n_clients=nc, model_bytes=nm, n_samples=nd, n_epochs=ne,
                  code_bytes_per_sample=nz)
    fl = federated_bytes(c)
    oc = octopus_bytes(c)
    assert fl > 0 and oc > 0
    c2 = CommModel(n_clients=nc, model_bytes=nm, n_samples=nd,
                   n_epochs=ne + 1, code_bytes_per_sample=nz)
    assert federated_bytes(c2) > fl          # FL pays per round
    assert octopus_bytes(c2) == oc           # OCTOPUS is round-free


# ----------------------------------------------------- wire protocol

# shapes/bits drawn from small fixed sets so jit caches stay warm across
# hypothesis examples (fresh shapes would recompile every draw)
@given(bits=st.sampled_from([1, 2, 3, 5, 7, 8, 10, 12]),
       n=st.sampled_from([1, 37, 64]), records=st.sampled_from([1, 2, 3]),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_codepayload_roundtrip_bits_and_records(bits, n, records, seed):
    """CodePayload encode -> wire -> decode is bit-exact for every
    packing width 1-12 and multi-record (per-client) streams; nbytes is
    measured from the wire buffer, per-record padding included."""
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(rng.integers(0, 1 << bits, size=(records, n)),
                      jnp.int32)
    p = (CodePayload.pack_records(idx, bits=bits) if records > 1
         else CodePayload.pack(idx[0], bits=bits))
    got = p.unpack()
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(idx if records > 1
                                             else idx[0]))
    assert p.nbytes == int(p.payload.size) * p.payload.dtype.itemsize
    assert p.nbytes * 8 >= p.count * bits        # dense: >= b bits/code
    assert p.privatized and p.wire == 1


@given(case=st.sampled_from([(1, 1, 16), (4, 2, 64), (8, 1, 64),
                             (1, 2, 64)]),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_codepayload_decode_matches_index_path(case, seed):
    """Wire-carried codes decode to the same features as their unpacked
    indices, for VQ and grouped/sliced GSVQ configs."""
    from repro.core import octopus as OC
    from repro.core.dvqae import DVQAEConfig
    n_groups, n_slices, K = case
    cfg = DVQAEConfig(kind="image", latent_dim=16, codebook_size=K,
                      n_groups=n_groups, n_slices=n_slices)
    gsvq_on = n_groups > 1 or n_slices > 1
    rng = np.random.default_rng(seed)
    cb = jnp.asarray(rng.normal(size=(K, 16)), jnp.float32)
    shape = (2, 5, n_slices) if gsvq_on else (2, 5)
    idx = jnp.asarray(rng.integers(0, n_groups if gsvq_on else K,
                                   size=shape), jnp.int32)
    p = CodePayload.pack(idx, bits=OC.transmit_bits(cfg))
    got = OC.codes_to_features(None, cfg, p, codebook=cb)
    want = OC.codes_to_features(None, cfg, idx, codebook=cb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@given(seed=st.integers(0, 2**31 - 1), b=st.integers(1, 4),
       t=st.sampled_from([8, 16]), window=st.sampled_from([0, 4]))
@settings(max_examples=10, deadline=None)
def test_attention_causality(seed, b, t, window):
    """Changing future tokens never changes past outputs."""
    from repro.nn import attention as A
    k = jax.random.PRNGKey(seed)
    q = jax.random.normal(k, (b, t, 2, 8))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (b, t, 2, 8))
    v = jax.random.normal(jax.random.fold_in(k, 2), (b, t, 2, 8))
    out1 = A._attend_full(q, kk, v, causal=True, q_offset=0, window=window)
    kk2 = kk.at[:, t // 2:].add(100.0)
    v2 = v.at[:, t // 2:].add(-100.0)
    out2 = A._attend_full(q, kk2, v2, causal=True, q_offset=0, window=window)
    np.testing.assert_allclose(np.asarray(out1[:, :t // 2]),
                               np.asarray(out2[:, :t // 2]), atol=1e-5)


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_moe_positions_are_dense_ranks(seed):
    from repro.nn.moe import positions_in_expert
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(0, 8, size=64), jnp.int32)
    pos = np.asarray(positions_in_expert(ids, 8))
    for e in range(8):
        ranks = sorted(pos[np.asarray(ids) == e])
        assert ranks == list(range(len(ranks)))

"""Red-team subsystem tests (repro.privacy).

Covers the four §2.5-critical properties:
  * the tap is OPT-IN (no ambient full-payload capture) and, when
    active, announces itself in traces with metadata only;
  * the attack harness has teeth — the provably-leaky control codec
    (PR-5 linear codec, IN off) scores well above chance while the
    privatized wire sits at chance — and is deterministic per seed;
  * the oblivious store is bit-exact with the plain sharded store and
    its access schedules are provably query-independent, with byte
    ledgers conserved under arbitrary access streams (hypothesis
    property, fixed fallbacks without it);
  * the old ``core.privacy`` home is a tombstone pointing here.
"""
import jax
import numpy as np
import pytest

from repro import obs, privacy as P
from repro.core.dvqae import DVQAEConfig
from repro.kernels.pack_bits import code_bits, packing_dims
from repro.obs import report as obs_report
from repro.privacy import sweep as SW
from repro.server import STANDARD_SCENARIOS, ShardedCodeStore
from repro.wire import CodePayload

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:            # dev-only dependency; fixed cases still run
    HAVE_HYPOTHESIS = False

BITS = code_bits(16)


@pytest.fixture(scope="module")
def tiny_cfg():
    return DVQAEConfig(kind="image", in_channels=3, hidden=8, latent_dim=8,
                       codebook_size=16, n_res_blocks=1)


@pytest.fixture(autouse=True)
def no_ambient_redteam(monkeypatch):
    monkeypatch.delenv(P.REDTEAM_ENV_VAR, raising=False)


def _payload(n_samples, version=0, fill=0):
    """A 1-client, (1, n_samples, 3)-shaped payload from raw words —
    no kernels, ready for per-client store routing."""
    G, W = packing_dims(BITS)
    rows = (n_samples * 3 + G - 1) // G
    words = np.full((rows, W), fill, dtype=np.uint32)
    return CodePayload.from_words(words, bits=BITS,
                                  shape=(1, n_samples, 3),
                                  version=version)


# ------------------------------------------------------------------ the tap

def test_tap_requires_explicit_opt_in(monkeypatch):
    with pytest.raises(P.RedTeamOptInError, match="OCTOPUS_REDTEAM"):
        P.PayloadTap()
    assert not P.redteam_enabled()
    monkeypatch.setenv(P.REDTEAM_ENV_VAR, "1")
    assert P.redteam_enabled()
    P.PayloadTap()                               # env opt-in
    monkeypatch.delenv(P.REDTEAM_ENV_VAR)
    P.PayloadTap(allow=True)                     # code opt-in


def test_tap_captures_full_payload_but_traces_metadata_only(tmp_path):
    tap = P.PayloadTap(allow=True)
    p = _payload(4, fill=7)
    with obs.recording(tmp_path / "t.jsonl") as rec:
        out = tap.capture(p, style=2, member=1)
        assert rec.metrics.snapshot()["counters"]["tapped_bytes"] == p.nbytes
    assert out is p                              # inline-tap friendly
    assert len(tap) == 1 and tap.nbytes == p.nbytes
    assert tap.metas("style") == [2] and tap.metas("member") == [1]
    # the tap HOLDS the words (flattened to per-sample rows); the trace
    # does NOT
    np.testing.assert_array_equal(
        tap.codes(), np.asarray(p.unpack()).reshape(-1, 3))
    events = obs_report.load_events(str(tmp_path / "t.jsonl"))
    assert [e["kind"] for e in events] == ["tap"]
    assert "payload" not in events[0] and "words" not in events[0]
    for v in events[0].values():
        assert isinstance(v, (int, float, bool, str, type(None)))
    assert events[0]["nbytes"] == p.nbytes


def test_tap_as_wiretap_channel():
    class Sink:
        def __init__(self):
            self.offers, self.ticks = [], 0

        def offer(self, payload, **kw):
            self.offers.append((payload, kw))
            return "ok"

        def tick(self):
            self.ticks += 1

        def drain(self):
            return "drained"

    sink = Sink()
    tap = P.PayloadTap(allow=True, target=sink)
    p = _payload(2)
    assert tap.offer(p, client_ids=[5], uplink_id=(5, 0)) == "ok"
    assert sink.offers[0][0] is p                # forwarded unmodified
    tap.tick()
    assert sink.ticks == 1 and tap.drain() == "drained"
    assert tap.records[0].meta["client_ids"] == [5]
    assert tap.records[0].meta["uplink_id"] == (5, 0)
    # untargeted taps refuse channel duty instead of dropping traffic
    with pytest.raises(ValueError, match="target"):
        P.PayloadTap(allow=True).offer(p)


def test_wiring_registered():
    assert "adversary" in STANDARD_SCENARIOS
    assert STANDARD_SCENARIOS["adversary"].sched.join_prob > 0
    assert "tap" in obs.EVENT_KINDS and "attack" in obs.EVENT_KINDS


# ------------------------------------------------------------- the attacks

def test_attribute_attack_teeth_and_chance(key):
    """The §2.5 gate in miniature: leaky control well above chance,
    privatized wire at chance — same codec weights, same population."""
    leaky = P.attribute_point(key, seed=0, strength=0.0, n_clients=8,
                              batch=16, steps=60)
    priv = P.attribute_point(key, seed=0, strength=1.0, n_clients=8,
                             batch=16, steps=60)
    assert leaky.advantage > 0.2, leaky
    assert abs(priv.advantage) <= 0.2, priv
    assert leaky.conditional_entropy_bits < priv.conditional_entropy_bits


def test_attack_determinism_under_fixed_seed(key):
    """Same key + same captured stream -> the IDENTICAL AttackReport,
    field for field (the sweep's reproducibility contract)."""
    a = P.attribute_point(key, seed=3, strength=0.0, n_clients=8,
                          batch=12, steps=40)
    b = P.attribute_point(key, seed=3, strength=0.0, n_clients=8,
                          batch=12, steps=40)
    assert a == b
    c = P.membership_point(key, seed=3, strength=0.0, n_members=2,
                           n_shadow=4, n_holdout=3, batch=8, steps=40)
    d = P.membership_point(key, seed=3, strength=0.0, n_members=2,
                           n_shadow=4, n_holdout=3, batch=8, steps=40)
    assert c == d and c.attack == "membership"


def test_harness_is_bit_anchored_to_wire():
    """The partial-IN knob encoder equals the production facade at both
    endpoints — the sweep curves measure the real wire, not a model."""
    assert SW.harness_matches_wire(seed=0, batch=16)


def test_attack_emits_scalar_event(key, tmp_path):
    tap = P.PayloadTap(allow=True)
    tap.capture(_payload(40, fill=3), style=0)
    tap.capture(_payload(40, fill=9), style=1)
    with obs.recording(tmp_path / "t.jsonl"):
        P.attribute_inference(key, tap, attribute="style", n_classes=2,
                              n_atoms=16, steps=10)
    events = obs_report.load_events(str(tmp_path / "t.jsonl"))
    att = [e for e in events if e["kind"] == "attack"]
    assert len(att) == 1 and att[0]["attack"] == "attribute:style"
    for v in att[0].values():
        assert isinstance(v, (int, float, bool, str, type(None)))


# ------------------------------------------------------- oblivious store

def _mirror_stores(tiny_cfg, policy="fifo", capacity=8):
    plain = ShardedCodeStore(tiny_cfg, n_shards=3, seed=5, policy=policy,
                             capacity_samples=capacity)
    obl = P.ObliviousCodeStore(tiny_cfg, n_shards=3, seed=5, policy=policy,
                               capacity_samples=capacity, oblivious_seed=11)
    return plain, obl


def _run_parity_and_ledgers(tiny_cfg, policy, stream):
    """Feed one arbitrary (n, version, client) stream into both stores;
    check bit-exact feature parity and per-version byte conservation at
    EVERY step of the oblivious store's life."""
    plain, obl = _mirror_stores(tiny_cfg, policy=policy)
    for i, (n, version, client) in enumerate(stream):
        p = _payload(n, version, fill=i)
        plain.add(p, client_ids=[client], round=i)
        obl.add(p, client_ids=[client], round=i)
        ing = obl.ingested_bytes_by_version
        ev = obl.evicted_bytes_by_version
        st_ = obl.stored_bytes_by_version
        for v in ing:      # Σ stored + Σ evicted == Σ ingested, always
            assert st_.get(v, 0) + ev.get(v, 0) == ing[v]
    assert len(plain) == len(obl)
    assert plain.total_bytes == obl.total_bytes
    np.testing.assert_array_equal(np.asarray(plain.codes()),
                                  np.asarray(obl.codes()))
    for i, (_, _, client) in enumerate(stream):
        try:
            ia, va = plain.get(client, i)
        except KeyError:
            with pytest.raises(KeyError):
                obl.get(client, i)
            continue
        ib, vb = obl.get(client, i)
        assert va == vb
        np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))


FIXED_STREAMS = [
    [(2, 0, 0), (3, 0, 1), (2, 1, 0), (4, 0, 2), (1, 1, 3), (2, 0, 0)],
    [(4, 0, 0)] * 8,                        # one partition, heavy churn
    [(1, v, c) for v in (0, 1, 2) for c in range(6)],
]


@pytest.mark.parametrize("policy", ["fifo", "reservoir"])
@pytest.mark.parametrize("stream", FIXED_STREAMS)
def test_oblivious_parity_fixed(tiny_cfg, policy, stream):
    _run_parity_and_ledgers(tiny_cfg, policy, stream)


if HAVE_HYPOTHESIS:
    STEP = st.tuples(st.integers(1, 4), st.integers(0, 2),
                     st.integers(0, 7))

    @settings(max_examples=40, deadline=None)
    @given(stream=st.lists(STEP, min_size=1, max_size=25),
           policy=st.sampled_from(["fifo", "reservoir"]))
    def test_oblivious_parity_property(stream, policy):
        cfg = DVQAEConfig(kind="image", in_channels=3, hidden=8,
                          latent_dim=8, codebook_size=16, n_res_blocks=1)
        _run_parity_and_ledgers(cfg, policy, stream)


def test_oblivious_schedule_is_query_independent(tiny_cfg):
    """Two stores with the same oblivious seed and the same partition
    grid produce IDENTICAL touch schedules under completely different
    query streams — the observer learns op count and grid size, nothing
    else. Every schedule touches every partition exactly once."""
    a_plain, a = _mirror_stores(tiny_cfg)
    b_plain, b = _mirror_stores(tiny_cfg)
    for i in range(6):
        a.add(_payload(2, version=i % 2, fill=i), client_ids=[i], round=i)
        b.add(_payload(2, version=i % 2, fill=i + 40),
              client_ids=[5 - i], round=i)
    for i in range(6):                    # disjoint query targets
        a.get(i, i)
        b.get(5 - i, i)
    assert len(a.access_log) == len(b.access_log)
    for (op_a, sched_a), (op_b, sched_b) in zip(a.access_log, b.access_log):
        assert op_a == op_b
        # same schedule despite different clients/shards being useful...
        assert sched_a == sched_b
        # ...and full coverage: every live partition exactly once
        assert sorted(sched_a) == sorted(set(sched_a))
    oh = a.overhead()
    assert oh["touched_partitions"] > oh["useful_partitions"]
    assert oh["partition_touch_ratio"] > 1.0


def test_oblivious_open_version_pre_creates_grid(tiny_cfg):
    obl = P.ObliviousCodeStore(tiny_cfg, n_shards=4, oblivious_seed=2)
    obl.open_version(3)
    assert sorted(obl.partitions) == [(3, s) for s in range(4)]
    # a later add to ANY shard of v3 touches the whole pre-opened grid
    obl.add(_payload(2, version=3), client_ids=[1], round=0)
    op, sched = obl.access_log[-1]
    assert op == "add" and sorted(sched) == [(3, s) for s in range(4)]


# ------------------------------------------------------------- tombstone

def test_core_privacy_is_a_tombstone():
    from repro.core import privacy as old
    for name in ("privacy_audit", "train_adversary", "AdversaryMetrics"):
        with pytest.raises(ImportError, match="repro.privacy"):
            getattr(old, name)
    with pytest.raises(AttributeError):
        old.never_existed
    # the migrated toolkit is whole at the new home
    for name in ("privacy_audit", "train_adversary", "evaluate_adversary",
                 "init_adversary", "AdversaryMetrics"):
        assert callable(getattr(P, name)) or name == "AdversaryMetrics"

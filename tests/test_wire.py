"""Unified wire protocol (repro.wire): CodePayload + session facades.

The contracts that let ONE carrier and ONE session API replace the
PR-1..4 function zoo:
  * facade parity — ``OctopusClient.round`` is bit-identical to the PR-4
    ``client_round_fused`` words and adds ZERO extra dispatches (counted:
    one encoder pass, one ``ops.encode_codes`` dispatch — mirroring the
    PR-4 exactly-one-encoder-pass regression);
  * single byte accounting — ``CodePayload.nbytes`` is the only place
    payload bytes are computed: an engine round's bytes == the sum of
    the per-client payloads' bytes, and ``Transmission.nbytes`` comes
    from the same source;
  * tombstones — the PR-5 shims (``client_transmit`` /
    ``client_round_fused`` / ``unpack_transmission``) finished their
    deprecation cycle: importing one raises ImportError with a pointer
    at the wire layer (same retirement as ``sim.engine.PackedCodes``);
    legacy ``Transmission`` carriers still coerce via ``as_payload``;
  * integrity — every packed carrier is CRC-stamped
    (``payload_crc`` over header + words); a flipped bit or truncated
    stream fails ``verify()`` and is REJECTED ``corrupt`` at admission,
    bytes staying on the §2.8 ledger;
  * wire invariants — the server side REJECTS (structured
    ``AdmissionResult`` verdicts, §2.8-ledgered, not exceptions)
    unknown wire revisions, unknown/retired codebook versions, and
    payloads not marked ``privatized`` (§2.5: the private residual is
    structurally untransmittable — pack rejects floats outright);
  * privacy — a ``privatized=True`` payload decoded through the facade
    leaks no private-residual signal (the §2.7 audit shows the private
    component is strictly more identifying).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dvqae, octopus as OC
from repro.core.dvqae import DVQAEConfig
from repro.kernels import ops
from repro.kernels.pack_bits import code_bits
from repro.sim import SimEngine
from repro.wire import (SUPPORTED_WIRE_VERSIONS, WIRE_VERSION, CodePayload,
                        OctopusClient, OctopusServer, as_payload,
                        concat_payloads, payload_crc, round_words)


@pytest.fixture(scope="module")
def tiny_cfg():
    return DVQAEConfig(kind="image", in_channels=3, hidden=8, latent_dim=8,
                       codebook_size=16, n_res_blocks=1)


@pytest.fixture(scope="module")
def server(tiny_cfg):
    return OC.server_init(jax.random.PRNGKey(0), tiny_cfg)


def _legacy_tx(server, cfg, x, labels=None):
    """Hand-built legacy ``Transmission`` (the shim that minted these is
    a tombstone now): encode-only facade uplink, repacked WITHOUT the
    wire's leading client axis — the PR-4 layout."""
    payload = OctopusClient(server, cfg).transmit(x)
    idx = payload.unpack()[0]
    p = CodePayload.pack(idx, bits=payload.bits)
    return OC.Transmission(indices=idx, nbytes=p.nbytes, labels=labels,
                           payload=p.payload, bits=p.bits)


def _count_dispatches(fn):
    """(encoder passes, ops.encode_codes dispatches) of running ``fn`` —
    the PR-4 counting harness extended to the fused kernel entry."""
    enc, kern = [], []
    real_enc, real_kern = dvqae.encode, ops.encode_codes
    dvqae.encode = lambda *a: (enc.append(1), real_enc(*a))[1]
    ops.encode_codes = lambda *a, **k: (kern.append(1),
                                        real_kern(*a, **k))[1]
    try:
        fn()
    finally:
        dvqae.encode, ops.encode_codes = real_enc, real_kern
    return len(enc), len(kern)


# ------------------------------------------------------------- CodePayload

def test_payload_pack_unpack_multi_record():
    """pack_records concatenates per-record zero-padded streams — the
    engine/kernel layout — and unpacks bit-exactly."""
    rng = np.random.default_rng(0)
    idx = jnp.asarray(rng.integers(0, 32, size=(3, 45)), jnp.int32)
    p = CodePayload.pack_records(idx, bits=5)
    assert p.n_records == 3 and p.shape == (3, 45)
    np.testing.assert_array_equal(np.asarray(p.unpack()), np.asarray(idx))
    # per-record layout == each record packed alone, stacked
    singles = [ops.pack_codes(idx[r], bits=5) for r in range(3)]
    np.testing.assert_array_equal(np.asarray(p.payload),
                                  np.concatenate(singles, axis=0))
    assert p.nbytes == sum(int(w.size) * w.dtype.itemsize for w in singles)


def test_payload_rejects_float_latents():
    """§2.5 structural privatization: the carrier holds quantized integer
    codes only — a private float residual cannot even be packed."""
    with pytest.raises(TypeError, match="untransmittable"):
        CodePayload.pack(jnp.ones((4, 4), jnp.float32), bits=4)
    with pytest.raises(TypeError):
        CodePayload.pack_records(jnp.ones((2, 4)), bits=4)


def test_payload_label_validation():
    idx = jnp.zeros((2, 3, 4), jnp.int32)
    p = CodePayload.pack(idx, bits=4, labels=jnp.zeros((2, 3)), n_samples=6)
    assert set(p.labels) == {"label"} and p.labels["label"].shape == (6,)
    with pytest.raises(ValueError, match="labels"):
        CodePayload.pack(idx, bits=4, labels=jnp.zeros((5,)), n_samples=6)


# ------------------------------------------------------ payload integrity

def test_payload_crc_stamped_and_verifies():
    """Every packed carrier is wire-2 and CRC-stamped; verify() passes
    on the intact stream and pins the exact crc32 recomputation."""
    rng = np.random.default_rng(3)
    idx = jnp.asarray(rng.integers(0, 16, size=(2, 3, 4)), jnp.int32)
    p = CodePayload.pack(idx, bits=4)
    assert p.wire == WIRE_VERSION == 2
    assert p.wire in SUPPORTED_WIRE_VERSIONS
    assert p.checksum == payload_crc(p.payload, bits=p.bits, shape=p.shape,
                                     n_records=p.n_records,
                                     version=p.version)
    assert p.verify()
    # metadata is inside the CRC: the same words under a different
    # declared version must not validate against the old stamp
    assert not p._replace(version=p.version + 1).verify()


def test_payload_bit_flip_and_truncation_fail_verify():
    rng = np.random.default_rng(4)
    idx = jnp.asarray(rng.integers(0, 16, size=(2, 3, 4)), jnp.int32)
    p = CodePayload.pack(idx, bits=4)
    flipped = p._replace(
        payload=p.payload.at[0, 0].set(p.payload[0, 0] ^ np.uint32(1)))
    assert not flipped.verify()
    truncated = p._replace(payload=p.payload[:-1])
    assert not truncated.verify()
    # un-stamped carriers (hand-built, legacy wire-1) skip the check
    assert p._replace(checksum=None).verify()


def test_corrupt_payload_rejected_at_admission(tiny_cfg, server):
    """A flipped bit is caught AT THE DOOR: verdict rejected/corrupt,
    bytes §2.8-ledgered, nothing stored, nothing ever decoded."""
    from repro.server import ContinuousIngestService
    srv = OctopusServer(server, tiny_cfg)
    svc = ContinuousIngestService(srv)
    rng = np.random.default_rng(5)
    idx = jnp.asarray(rng.integers(0, 16, size=(2, 3, 4)), jnp.int32)
    p = CodePayload.pack(idx, bits=code_bits(16))
    bad = p._replace(
        payload=p.payload.at[0, 0].set(p.payload[0, 0] ^ np.uint32(1)))
    res = svc.offer(bad)
    assert res.verdict == "rejected" and res.reason == "corrupt"
    assert len(srv.store) == 0
    assert svc.queue.bytes_rejected == bad.nbytes
    # the intact twin still ingests
    assert svc.offer(p).ok


def test_unknown_wire_revision_rejected(tiny_cfg, server):
    srv = OctopusServer(server, tiny_cfg)
    p = CodePayload.pack(jnp.zeros((2, 3, 4), jnp.int32), bits=4)
    verdict, reason = srv.precheck(p._replace(wire=99))
    assert (verdict, reason) == ("rejected", "wire_revision")
    # wire-1 (pre-CRC) traces remain decodable: still a supported rev
    verdict, _ = srv.precheck(p._replace(wire=1, checksum=None))
    assert verdict == "accepted"


def test_concat_payloads_label_mismatch_raises():
    """Partial labeling or disagreeing task sets across concatenated
    payloads is an explicit ValueError, not silent label dropping."""
    rng = np.random.default_rng(6)
    idx = jnp.asarray(rng.integers(0, 16, size=(2, 3, 4)), jnp.int32)
    labeled = CodePayload.pack(idx, bits=4, labels=jnp.zeros((2, 3)))
    bare = CodePayload.pack(idx, bits=4)
    other = CodePayload.pack(idx, bits=4,
                             labels={"task2": jnp.zeros((2, 3))})
    with pytest.raises(ValueError, match="label channel mismatch"):
        concat_payloads([labeled, bare])
    with pytest.raises(ValueError, match="label task-channel mismatch"):
        concat_payloads([labeled, other])
    # the agreeing case concatenates and stays CRC-stamped
    both = concat_payloads([labeled, labeled])
    assert both.n_records == 2 and both.checksum is not None
    assert both.verify()


def test_engine_round_bytes_equal_sum_of_client_payload_bytes(tiny_cfg,
                                                              server, key):
    """Satellite: the sim-engine round's measured bytes == the sum of the
    per-client payloads' bytes (CodePayload.nbytes is the ONE source)."""
    n_clients = 3
    data = jax.random.normal(key, (n_clients, 2, 8, 8, 3))
    engine = SimEngine(tiny_cfg, gamma=0.9)
    clients, packed = engine.round(engine.init_clients(server, n_clients),
                                   data, version=0)
    assert isinstance(packed, CodePayload) and packed.n_records == n_clients
    idx = packed.unpack()
    per_client = [CodePayload.pack(idx[i], bits=packed.bits)
                  for i in range(n_clients)]
    assert packed.nbytes == sum(p.nbytes for p in per_client)
    # and the multi-record layout IS the per-client streams, stacked
    np.testing.assert_array_equal(
        np.asarray(packed.payload),
        np.concatenate([np.asarray(p.payload) for p in per_client]))


def test_transmission_nbytes_single_source(tiny_cfg, server, key):
    """Transmission.nbytes comes from CodePayload.nbytes."""
    x = jax.random.normal(key, (4, 8, 8, 3))
    tx = _legacy_tx(server, tiny_cfg, x)
    p = as_payload(tx)
    assert isinstance(p, CodePayload)
    assert tx.nbytes == p.nbytes \
        == int(tx.payload.size) * tx.payload.dtype.itemsize


# ---------------------------------------------------------- facade parity

def test_facade_round_bit_identical_to_fused(tiny_cfg, server, key):
    """Acceptance: OctopusClient.round == the pure ``round_words`` tail
    (words AND client state), and unpacks to client_round's indices."""
    x = jax.random.normal(key, (2, 8, 8, 3))
    srv = OctopusServer(server, tiny_cfg)
    cl = srv.deploy()
    payload = cl.round(x)
    ref_client, words = round_words(OC.client_init(server), tiny_cfg, x)
    np.testing.assert_array_equal(np.asarray(payload.payload),
                                  np.asarray(words))
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-6), cl.state, ref_client)
    _, idx = OC.client_round(OC.client_init(server), tiny_cfg, x)
    np.testing.assert_array_equal(np.asarray(payload.unpack()[0]),
                                  np.asarray(idx))
    assert payload.privatized and payload.version == 0
    assert payload.wire == WIRE_VERSION


def test_facade_round_dispatch_neutral(tiny_cfg, server, key):
    """Acceptance: the facade adds ZERO dispatches over the PR-4 fused
    round — exactly one encoder pass, one encode_codes dispatch."""
    x = jax.random.normal(key, (2, 8, 8, 3))
    cl = OctopusClient(server, tiny_cfg, n_local_steps=0)
    assert _count_dispatches(lambda: cl.round(x)) == (1, 1)
    ref = _count_dispatches(lambda: round_words(
        OC.client_init(server), tiny_cfg, x, n_local_steps=0))
    assert ref == (1, 1)
    # refresh/finetune policy flags stay single-dispatch too
    assert _count_dispatches(lambda: cl.transmit(x)) == (1, 1)
    assert _count_dispatches(lambda: cl.round(x, finetune=2))[1] == 1


def test_facade_transmit_is_encode_only(tiny_cfg, server, key):
    """Encode-only profile: packed words == pack(forward indices) with
    §2.8-measured bytes, and the client state is untouched."""
    from repro.core.dvqae import forward
    x = jax.random.normal(key, (4, 8, 8, 3))
    cl = OctopusClient(server, tiny_cfg)
    before = jax.tree.map(np.asarray, cl.state.params)
    payload = cl.transmit(x, labels=jnp.arange(4))
    idx = forward(OC.client_init(server).params, tiny_cfg, x).latent.indices
    ref = CodePayload.pack(idx, bits=OC.transmit_bits(tiny_cfg))
    np.testing.assert_array_equal(np.asarray(payload.payload),
                                  np.asarray(ref.payload))
    assert payload.nbytes == ref.nbytes
    np.testing.assert_array_equal(np.asarray(payload.unpack()[0]),
                                  np.asarray(idx))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        a, np.asarray(b)), before, cl.state.params)   # no refresh, no tune


def test_ingest_lifts_legacy_transmission(tiny_cfg, server, key):
    """A packed legacy Transmission ingests through the facade: lifted to
    the (C=1, B, ...) wire layout, labels stay per-sample aligned."""
    x = jax.random.normal(key, (4, 8, 8, 3))
    tx = _legacy_tx(server, tiny_cfg, x, labels=jnp.arange(4))
    srv = OctopusServer(server, tiny_cfg)
    res = srv.ingest(tx)
    assert res.verdict == "accepted" and res.ok
    assert res.record.packed.shape == (1,) + tuple(tx.indices.shape)
    feats, labels = srv.features()
    assert feats.shape[0] == 4
    np.testing.assert_array_equal(np.asarray(labels["label"]),
                                  np.arange(4))
    want = OC.codes_to_features(server, tiny_cfg, tx.indices)
    np.testing.assert_array_equal(np.asarray(feats), np.asarray(want))
    # direct decode lifts too: merges ONLY the client axis, so the
    # feature geometry matches the index path (was flattening B into T)
    np.testing.assert_array_equal(np.asarray(srv.decode(tx)),
                                  np.asarray(want))


def test_server_pretrain_refuses_to_move_versions_under_stored_payloads(
        tiny_cfg, server, key):
    """Step 1 must precede Step 4: re-pinning v0 after a payload landed
    would silently decode stored codes against the wrong dictionary."""
    srv = OctopusServer(server, tiny_cfg)
    srv.ingest(CodePayload.pack(jnp.zeros((2, 3, 4), jnp.int32), bits=4))
    with pytest.raises(RuntimeError, match="pretrain"):
        srv.pretrain(key, jax.random.normal(key, (8, 8, 8, 3)), steps=1)


def test_decode_codes_rejects_conflicting_carrier_args(key):
    """ops.decode_codes with a CodePayload refuses explicit bits=/count=
    instead of silently ignoring them."""
    p = CodePayload.pack(jnp.zeros((8,), jnp.int32), bits=4)
    table = jax.random.normal(key, (16, 8))
    rows = ops.decode_codes(p, table)
    assert rows.shape == (8, 8)
    with pytest.raises(TypeError, match="authoritative"):
        ops.decode_codes(p, table, bits=8, count=8)


def test_retired_shims_are_tombstones(tiny_cfg, server, key):
    """The PR-5 shims raise ImportError pointing at repro.wire — and the
    pointed-at path really does what the shim did (as_payload lift)."""
    for name in ("client_transmit", "client_round_fused",
                 "unpack_transmission"):
        with pytest.raises(ImportError, match="repro.wire"):
            getattr(OC, name)
    with pytest.raises(AttributeError):
        OC.never_existed
    x = jax.random.normal(key, (2, 8, 8, 3))
    tx = _legacy_tx(server, tiny_cfg, x)
    np.testing.assert_array_equal(np.asarray(as_payload(tx).unpack()),
                                  np.asarray(tx.indices))


# ----------------------------------------------------------- server facade

def test_server_facade_ingest_keys_on_payload_version(tiny_cfg, server):
    """ingest() keys the store off the payload's OWN version; features()
    decodes each version group against its snapshot and filters."""
    srv = OctopusServer(server, tiny_cfg)
    rng = np.random.default_rng(0)
    codes0 = jnp.asarray(rng.integers(0, 16, size=(2, 3, 4)), jnp.int32)
    srv.ingest(CodePayload.pack(codes0, bits=code_bits(16), version=0))
    # a merge moves the dictionary; new payloads carry version 1
    v1 = srv.merge(jnp.stack([jnp.ones((16, 8))]),
                   jnp.stack([jnp.ones((16,))]))
    assert v1 == 1 and srv.version == 1
    codes1 = jnp.asarray(rng.integers(0, 16, size=(2, 3, 4)), jnp.int32)
    srv.ingest(CodePayload.pack(codes1, bits=code_bits(16), version=1))

    feats, _ = srv.features()
    ref0 = np.asarray(srv.registry.get(0))[np.asarray(codes0).reshape(6, 4)]
    ref1 = np.asarray(srv.registry.get(1))[np.asarray(codes1).reshape(6, 4)]
    np.testing.assert_array_equal(np.asarray(feats[:6]), ref0)
    np.testing.assert_array_equal(np.asarray(feats[6:]), ref1)
    f0, _ = srv.features(version=0)                 # filtered view
    np.testing.assert_array_equal(np.asarray(f0), ref0)
    assert srv.store.records[0].version == 0
    assert srv.store.records[1].version == 1


def test_server_facade_rejects_wire_violations(tiny_cfg, server):
    """Wire violations come back as structured rejection verdicts — the
    payload never enters the store, but its measured bytes do reach the
    §2.8 ledger (AdmissionResult.nbytes)."""
    srv = OctopusServer(server, tiny_cfg)
    good = CodePayload.pack(jnp.zeros((2, 3, 4), jnp.int32), bits=4)
    for bad, reason in [
            (good._replace(wire=WIRE_VERSION + 1), "wire_revision"),
            (good._replace(privatized=False), "unprivatized"),
            (good._replace(version=7), "unknown_version")]:
        res = srv.ingest(bad)
        assert res.verdict == "rejected" and not res.ok
        assert res.reason == reason
        assert res.record is None
        assert res.nbytes == bad.nbytes > 0     # refusals stay ledgered
    with pytest.raises(TypeError):
        srv.ingest(jnp.zeros((2, 3, 4), jnp.int32))   # bare indices
    # the store itself also refuses non-privatized payloads (§2.5)
    with pytest.raises(ValueError, match="privatized"):
        srv.store.add(good._replace(privatized=False))
    assert len(srv.store) == 0                  # no rejection landed
    res = srv.ingest(good)
    assert res.verdict == "accepted" and res.ok
    assert srv.store.n_samples == 6


def test_engine_payload_carries_labels_into_store(tiny_cfg, server, key):
    """SimEngine.round(version=, labels=) -> the payload alone is enough
    for the store: no side-channel label/version arguments."""
    engine = SimEngine(tiny_cfg, gamma=0.9)
    data = jax.random.normal(key, (3, 2, 8, 8, 3))
    y = jnp.arange(6).reshape(3, 2)
    clients, packed = engine.round(engine.init_clients(server, 3), data,
                                   version=0, labels={"content": y})
    srv = OctopusServer(server, tiny_cfg)
    srv.ingest(packed)
    feats, labels = srv.features()
    assert feats.shape[0] == 6
    np.testing.assert_array_equal(np.asarray(labels["content"]),
                                  np.arange(6))


def test_multitask_trains_from_wire_endpoint(tiny_cfg, server, key):
    """MultiTaskTrainer.fit_from_store accepts the OctopusServer wire
    endpoint directly — one version-correct decode, no store/registry
    plumbing at the call site."""
    from repro.server import MultiTaskTrainer, TaskSpec
    srv = OctopusServer(server, tiny_cfg)
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(0, 16, size=(2, 8, 4)), jnp.int32)
    y = jnp.asarray(rng.integers(0, 2, size=(2, 8)), jnp.int32)
    srv.ingest(CodePayload.pack(codes, bits=code_bits(16),
                                labels={"content": y}, n_samples=16))
    trainer = MultiTaskTrainer(key, [TaskSpec("content", 2)], 4 * 8)
    params, feats, labels = trainer.fit_from_store(key, srv, steps=5)
    assert feats.shape[0] == 16 and set(labels) == {"content"}
    assert set(params) == {"content"}


def test_client_sync_adopts_merged_dictionary(tiny_cfg, server, key):
    srv = OctopusServer(server, tiny_cfg)
    cl = srv.deploy()
    assert cl.version == 0
    srv.merge(jnp.stack([jnp.ones((16, 8))]), jnp.stack([jnp.ones((16,))]))
    cl.sync(srv)
    assert cl.version == 1
    np.testing.assert_array_equal(np.asarray(cl.codebook),
                                  np.asarray(srv.registry.current))


# ---------------------------------------------------------------- privacy

def test_privatized_payload_leaks_no_private_residual(key):
    """Regression (§2.5/§2.7): a privatized=True payload leaks NO
    private-residual signal through the facade.

    Style is constructed as a per-instance channel shift — exactly the
    "temporally-invariant style carrier" IN strips (Eq. 4) — on a linear
    (sequence) codec, so the claim is mechanical: the wire bytes are
    BIT-IDENTICAL with style present or stripped, the audit adversary on
    wire-decoded features scores ~chance on style, and the private
    residual Z∘ (which the carrier structurally cannot hold) nails it.
    """
    from repro import privacy as PV
    from repro.core.dvqae import init_dvqae
    from repro.optim.adamw import adamw_init
    d_model, M, K = 12, 8, 32
    cfg = DVQAEConfig(kind="sequence", latent_dim=M, codebook_size=K)
    params = init_dvqae(key, cfg, d_model=d_model)
    server = OC.ServerState(params=params, opt=adamw_init(params),
                            step=jnp.zeros((), jnp.int32))

    n_cls, n_sty, B, T = 4, 4, 160, 10
    rng = np.random.default_rng(0)
    protos = rng.normal(size=(n_cls, T, d_model))
    content = rng.integers(0, n_cls, size=B)
    style = rng.integers(0, n_sty, size=B)
    shifts = rng.normal(size=(n_sty, d_model)) * 2.0   # style = IN-strippable
    x_base = jnp.asarray(protos[content]
                         + 0.05 * rng.normal(size=(B, T, d_model)),
                         jnp.float32)
    x = x_base + jnp.asarray(shifts[style], jnp.float32)[:, None, :]

    srv = OctopusServer(server, cfg)
    cl = srv.deploy()
    payload = cl.transmit(x)
    assert payload.privatized
    # structural: style-stripped inputs -> the IDENTICAL wire bytes
    np.testing.assert_array_equal(np.asarray(payload.payload),
                                  np.asarray(cl.transmit(x_base).payload))

    feats = srv.decode(payload)                     # what the wire carries
    out = dvqae.forward(server.params, cfg, x)
    priv = jnp.broadcast_to(out.latent.private, out.latent.public.shape)
    pub_m, prv_m = PV.privacy_audit(key, feats, priv,
                                    jnp.asarray(style), n_sty, steps=150)
    assert prv_m.accuracy > pub_m.accuracy + 0.2, (pub_m, prv_m)
    assert pub_m.conditional_entropy_bits > prv_m.conditional_entropy_bits, \
        (pub_m, prv_m)

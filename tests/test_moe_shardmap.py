"""shard_map MoE dispatch == flat dispatch (numerically, modulo capacity
ordering). Runs in a subprocess with 4 forced host devices so a real
(data=2, model=2) mesh exists."""
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro import hints
from repro.configs import MoEConfig, ModelConfig
from repro.nn import moe as MOE

key = jax.random.PRNGKey(0)
cfg_base = ModelConfig(d_model=32, moe=MoEConfig(
    n_experts=4, n_experts_per_tok=2, d_ff_expert=64,
    capacity_factor=8.0))            # capacity high enough: no drops
p = MOE.init_moe(key, cfg_base)
x = jax.random.normal(key, (4, 8, 32))

mesh = jax.make_mesh((2, 2), ("data", "model"))
flat = MOE.moe_apply(p, cfg_base.replace(
    moe=cfg_base.moe.__class__(**{**cfg_base.moe.__dict__,
                                  "dispatch": "flat"})), x)

cfg_sm = cfg_base.replace(moe=cfg_base.moe.__class__(
    **{**cfg_base.moe.__dict__, "dispatch": "shardmap"}))
with mesh:
    with hints.activation_sharding(mesh, ("data",)):
        xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
        sm = jax.jit(lambda p, x: MOE.moe_apply(p, cfg_sm, x))(p, xs)

import numpy as np
err = float(jnp.max(jnp.abs(flat.y - sm.y)))
print("MAXERR", err)
assert err < 1e-4, err
# aux losses agree approximately: shard_map computes load-balance stats
# per dp shard then pmeans (average of products != product of averages)
aerr = abs(float(flat.aux_loss) - float(sm.aux_loss))
print("AUXERR", aerr)
assert aerr < 1e-2, aerr
print("OK")
"""


def test_shardmap_matches_flat():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env={"PYTHONPATH": "src",
                                       "PATH": "/usr/bin:/bin"},
                       cwd="/root/repo", timeout=600)
    assert "OK" in r.stdout, f"stdout={r.stdout}\nstderr={r.stderr[-2000:]}"

"""Fused packed-code -> feature decode pipeline (kernels/decode_codes.py).

The contracts that let the fused path replace unpack-then-dequantize:
  * kernel parity — ops.decode_codes == table[unpack_codes(...)] bit-exact
    for every packing width the codec supports, incl. sliced streams with
    per-group phase vectors;
  * protocol parity — codes_to_features on a packed carrier (CodePayload /
    packed Transmission) == codes_to_features on the int32 indices, for
    VQ and GSVQ (grouped + sliced) configs;
  * store contract — CodeStore.dataset decodes each codebook-version
    group in exactly ONE fused dispatch, matching the per-record
    unpack-then-dequantize reference across versions.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import octopus as OC
from repro.core.dvqae import DVQAEConfig
from repro.core.gsvq import gsvq_bits_per_position
from repro.kernels import ops, ref
from repro.kernels.pack_bits import code_bits, packing_dims
from repro.server import CodebookRegistry, CodeStore
from repro.wire import CodePayload


def _pack(idx, bits):
    return CodePayload.pack(jnp.asarray(idx, jnp.int32), bits=bits)


# ------------------------------------------------------------------ kernel

@pytest.mark.parametrize("bits", [1, 3, 5, 8, 10, 12])
def test_decode_matches_unpack_then_gather(bits):
    """Fused kernel == table[unpack] bit-exact at every packing width."""
    K = 1 << bits
    rng = np.random.default_rng(bits)
    table = jnp.asarray(rng.normal(size=(K, 24)), jnp.float32)
    for count in (1, 257, 1000):
        codes = jnp.asarray(rng.integers(0, K, size=count), jnp.int32)
        words = ops.pack_codes(codes, bits=bits)
        fused = ops.decode_codes(words, table, bits=bits, count=count)
        want = table[ops.unpack_codes(words, bits=bits, count=count)]
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(want))
        np.testing.assert_array_equal(
            np.asarray(fused),
            np.asarray(ref.decode_codes_ref(words, table, bits=bits,
                                            count=count)))
        np.testing.assert_array_equal(
            np.asarray(fused),
            np.asarray(ops.decode_codes(words, table, bits=bits, count=count,
                                        use_ref=True)))


@pytest.mark.parametrize("n_slices", [2, 3, 4])
def test_decode_sliced_stream(n_slices):
    """Sliced streams gather row slice*R + code, slice = position % n_c."""
    R, m, count = 8, 4, 999
    bits = code_bits(R)
    rng = np.random.default_rng(n_slices)
    codes = jnp.asarray(rng.integers(0, R, size=count), jnp.int32)
    table = jnp.asarray(rng.normal(size=(n_slices * R, m)), jnp.float32)
    words = ops.pack_codes(codes, bits=bits)
    fused = ops.decode_codes(words, table, bits=bits, count=count,
                             n_slices=n_slices)
    sl = jnp.arange(count) % n_slices
    want = table[sl * R + ops.unpack_codes(words, bits=bits, count=count)]
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(want))


def test_decode_explicit_phases_restart_per_record():
    """A concatenated two-record stream with per-record phase vectors
    decodes each record as if it were dispatched alone."""
    from repro.kernels.decode_codes import stream_phases
    R, m, n_slices, bits = 4, 3, 3, code_bits(4)
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(n_slices * R, m)), jnp.float32)
    a = jnp.asarray(rng.integers(0, R, size=66), jnp.int32)
    b = jnp.asarray(rng.integers(0, R, size=130), jnp.int32)
    wa, wb = ops.pack_codes(a, bits=bits), ops.pack_codes(b, bits=bits)
    words = jnp.concatenate([wa, wb])
    phases = jnp.concatenate([stream_phases(wa.shape[0], bits, n_slices),
                              stream_phases(wb.shape[0], bits, n_slices)])
    G, _ = packing_dims(bits)
    rows = ops.decode_codes(words, table, bits=bits,
                            count=words.shape[0] * G, n_slices=n_slices,
                            phases=phases)
    for start, w, codes in ((0, wa, a), (wa.shape[0] * G, wb, b)):
        alone = ops.decode_codes(w, table, bits=bits, count=codes.shape[0],
                                 n_slices=n_slices)
        np.testing.assert_array_equal(
            np.asarray(rows[start:start + codes.shape[0]]),
            np.asarray(alone))


# ---------------------------------------------------------------- protocol

@pytest.mark.parametrize("n_groups,n_slices,K", [
    (1, 1, 256), (8, 1, 64), (4, 2, 64), (8, 4, 64), (1, 2, 64)])
def test_codes_to_features_packed_parity(key, n_groups, n_slices, K):
    """Fused packed path == index path for VQ and GSVQ configs."""
    cfg = DVQAEConfig(kind="image", latent_dim=16, codebook_size=K,
                      n_groups=n_groups, n_slices=n_slices)
    cb = jax.random.normal(key, (K, 16))
    bits = OC.transmit_bits(cfg)
    rng = np.random.default_rng(n_groups * 10 + n_slices)
    gsvq = n_groups > 1 or n_slices > 1
    shape = (3, 7, n_slices) if gsvq else (3, 7)
    hi = n_groups if gsvq else K
    idx = jnp.asarray(rng.integers(0, hi, size=shape), jnp.int32)
    fused = OC.codes_to_features(None, cfg, _pack(idx, bits), codebook=cb)
    want = OC.codes_to_features(None, cfg, idx, codebook=cb)
    assert fused.shape == want.shape
    np.testing.assert_allclose(np.asarray(fused), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    if not gsvq:
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(want))


def test_codes_to_features_accepts_transmission(key):
    """A packed legacy Transmission (hand-built — the minting shim is a
    tombstone now) takes the fused path and matches its own unpacked
    indices decoded the classic way."""
    from repro.core.dvqae import forward
    cfg = DVQAEConfig(kind="image", in_channels=3, hidden=8, latent_dim=8,
                      codebook_size=16, n_res_blocks=1)
    srv = OC.server_init(key, cfg)
    cl = OC.client_init(srv)
    x = jax.random.normal(key, (4, 8, 8, 3))
    idx = forward(cl.params, cfg, x).latent.indices
    p = CodePayload.pack(idx, bits=OC.transmit_bits(cfg))
    tx = OC.Transmission(indices=idx, nbytes=p.nbytes,
                         payload=p.payload, bits=p.bits)
    fused = OC.codes_to_features(srv, cfg, tx)
    want = OC.codes_to_features(srv, cfg, tx.indices)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(want))


def test_engine_dequantize_is_fused_and_exact(key):
    from repro.sim import SimEngine
    cfg = DVQAEConfig(kind="image", in_channels=3, hidden=8, latent_dim=8,
                      codebook_size=16, n_res_blocks=1)
    srv = OC.server_init(key, cfg)
    engine = SimEngine(cfg, gamma=0.9)
    clients = engine.init_clients(srv, 4)
    _, packed = engine.round(clients, jax.random.normal(key, (4, 2, 8, 8, 3)))
    got = engine.dequantize(srv, packed)
    idx = packed.unpack()
    want = OC.codes_to_features(srv, cfg, idx.reshape((-1,) + idx.shape[2:]))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------------------------------- store

@pytest.mark.parametrize("n_groups,n_slices,K", [(1, 1, 16), (4, 2, 64)])
def test_store_dataset_multiversion_fused_roundtrip(key, n_groups, n_slices,
                                                    K):
    """Multi-version stores decode per-version snapshots bit-exactly
    through the fused bulk path."""
    cfg = DVQAEConfig(kind="image", latent_dim=16, codebook_size=K,
                      n_groups=n_groups, n_slices=n_slices)
    bits = OC.transmit_bits(cfg)
    gsvq = n_groups > 1 or n_slices > 1
    registry = CodebookRegistry(jax.random.normal(key, (K, 16)))
    registry.register(jax.random.normal(jax.random.fold_in(key, 1), (K, 16)))
    store = CodeStore(cfg)
    rng = np.random.default_rng(0)
    want = []
    for version, rnd in ((0, 0), (1, 1), (0, 2)):
        shape = (2, 3, 4, n_slices) if gsvq else (2, 3, 4)
        idx = jnp.asarray(rng.integers(0, n_groups if gsvq else K,
                                       size=shape), jnp.int32)
        store.add(_pack(idx, bits), round=rnd, version=version)
        want.append(np.asarray(OC.codes_to_features(
            None, cfg, idx.reshape((6,) + idx.shape[2:]),
            codebook=registry.get(version))))
    feats, _ = store.dataset(None, registry=registry)
    np.testing.assert_allclose(np.asarray(feats), np.concatenate(want),
                               rtol=1e-6, atol=1e-6)


def test_store_dataset_one_dispatch_per_version(monkeypatch, key):
    """Acceptance: dataset() issues exactly one fused decode dispatch per
    codebook version, no matter how many records share it."""
    import repro.kernels.ops as ops_mod
    cfg = DVQAEConfig(kind="image", latent_dim=16, codebook_size=16)
    bits = OC.transmit_bits(cfg)
    registry = CodebookRegistry(jax.random.normal(key, (16, 16)))
    registry.register(jax.random.normal(jax.random.fold_in(key, 1),
                                        (16, 16)))
    store = CodeStore(cfg)
    rng = np.random.default_rng(1)
    for version, rnd in ((0, 0), (0, 1), (1, 2), (0, 3), (1, 4)):
        idx = rng.integers(0, 16, size=(2, 3, 4))
        store.add(_pack(idx, bits), round=rnd, version=version)

    calls = []
    real = ops_mod.decode_codes
    monkeypatch.setattr(ops_mod, "decode_codes",
                        lambda *a, **k: (calls.append(1), real(*a, **k))[1])
    feats, _ = store.dataset(None, registry=registry)
    assert len(calls) == 2                     # versions {0, 1}
    assert feats.shape[0] == store.n_samples


# ------------------------------------------------- §2.8 bits accounting

def test_transmit_bits_matches_transmitted_alphabet():
    """Satellite: bits/code is the per-slice group alphabet for EVERY
    GSVQ config (incl. n_groups == 1 sliced), aligned with
    gsvq_bits_per_position; plain VQ keeps ceil(log2 K)."""
    mk = lambda g, s, K=64: DVQAEConfig(latent_dim=16, codebook_size=K,
                                        n_groups=g, n_slices=s)
    assert OC.transmit_bits(mk(1, 1, 256)) == 8
    assert OC.transmit_bits(mk(16, 1)) == 4
    assert OC.transmit_bits(mk(4, 2)) == 2
    assert OC.transmit_bits(mk(1, 2)) == 1     # was 6 (= log2 K): overstated
    for g, s in ((16, 1), (4, 2), (1, 2), (8, 4)):
        assert OC.transmit_bits(mk(g, s)) * s == gsvq_bits_per_position(g, s)


def test_packed_nbytes_follow_sliced_alphabet():
    """A sliced n_groups == 1 uplink measures ~1 bit/code, not log2 K."""
    cfg = DVQAEConfig(latent_dim=16, codebook_size=64, n_groups=1,
                      n_slices=2)
    bits = OC.transmit_bits(cfg)
    idx = jnp.zeros((4, 8, 2), jnp.int32)      # the single-group alphabet
    packed = _pack(idx, bits)
    assert packed.nbytes <= (packed.count * 1 + 7) // 8 + 4 * 4  # ~1 bit/code

"""Data pipeline + checkpoint tests."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as C
from repro.data import (LabeledData, batches, holdout_atd, make_images,
                        make_speech, make_tokens, partition,
                        train_test_split)


def test_images_factorized(key):
    d = make_images(key, 128, size=32, n_identities=5)
    assert d.x.shape == (128, 32, 32, 3)
    assert int(d.content.max()) < 8 and int(d.style.max()) < 5
    # same content, different style -> different pixels (style matters)
    c0 = np.asarray(d.content)
    s = np.asarray(d.style)
    idx = np.where(c0 == c0[0])[0]
    diff_styles = [i for i in idx if s[i] != s[idx[0]]]
    if diff_styles:
        gap = float(jnp.mean(jnp.abs(d.x[idx[0]] - d.x[diff_styles[0]])))
        assert gap > 0.01


def test_speech_structure(key):
    d = make_speech(key, 64, frames=64, channels=16)
    assert d.x.shape == (64, 64, 16)
    assert bool(jnp.all(jnp.isfinite(d.x)))


def test_tokens_in_vocab(key):
    t = make_tokens(key, 8, 64, 100)
    assert t.shape == (8, 64)
    assert int(t.min()) >= 0 and int(t.max()) < 100


def test_partition_worst_case_single_class(key):
    d = make_images(key, 256, n_identities=4)
    shards = partition(d, 8, regime="worst")
    # worst case: each client sees very few classes
    for sh in shards:
        assert len(set(map(int, sh.content))) <= 3


def test_partition_iid_covers_classes(key):
    d = make_images(key, 512, n_identities=4)
    shards = partition(d, 4, regime="iid")
    for sh in shards:
        assert len(set(map(int, sh.content))) >= 6   # of 8 shapes


def test_partition_preserves_total(key):
    d = make_images(key, 100)
    for regime in ("iid", "worst", "skewed"):
        shards = partition(d, 7, regime=regime)
        assert sum(s.x.shape[0] for s in shards) == 100


def test_split_and_atd(key):
    d = make_images(key, 100)
    tr, te = train_test_split(d, 0.2)
    assert tr.x.shape[0] == 80 and te.x.shape[0] == 20
    rest, atd = holdout_atd(tr, 0.15)
    assert atd.x.shape[0] == 12


def test_batches_iterator(key):
    d = make_images(key, 50)
    bs = list(batches(d, 16))
    assert len(bs) == 3
    assert all(b.x.shape[0] == 16 for b in bs)


# ------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip_nested(key):
    tree = {"params": {"w": jax.random.normal(key, (4, 4)),
                       "layers": [jnp.ones(3), jnp.zeros(2)]},
            "step": jnp.int32(7)}
    with tempfile.TemporaryDirectory() as td:
        C.save(td, 1, tree, metadata={"arch": "test"})
        restored, step = C.restore(td, tree)
        assert step == 1
        np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                      np.asarray(tree["params"]["w"]))
        assert os.path.exists(os.path.join(td, "step_00000001.npz.json"))


def test_checkpoint_keeps_latest(key):
    tree = {"a": jnp.zeros(2)}
    with tempfile.TemporaryDirectory() as td:
        for s in range(6):
            C.save(td, s, {"a": jnp.full((2,), float(s))}, keep=3)
        files = sorted(f for f in os.listdir(td) if f.endswith(".npz"))
        assert len(files) == 3
        restored, step = C.restore(td, tree)
        assert step == 5
        assert float(restored["a"][0]) == 5.0


def test_checkpoint_restore_empty_dir():
    with tempfile.TemporaryDirectory() as td:
        restored, step = C.restore(td, {"a": jnp.zeros(1)})
        assert restored is None and step == 0


def test_checkpoint_model_state(key):
    from repro.configs import smoke_config
    from repro.models import transformer as T
    cfg = smoke_config("qwen3_0_6b")
    params = T.init_lm(key, cfg)
    with tempfile.TemporaryDirectory() as td:
        C.save(td, 0, params)
        restored, _ = C.restore(td, params)
        flat1 = jax.tree.leaves(params)
        flat2 = jax.tree.leaves(restored)
        for a, b in zip(flat1, flat2):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

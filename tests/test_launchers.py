"""Launcher smoke tests: train.py / serve.py / examples run end-to-end as
subprocesses (tiny settings)."""
import subprocess
import sys

ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}


def _run(args, timeout=600):
    return subprocess.run([sys.executable] + args, capture_output=True,
                          text=True, env=ENV, cwd="/root/repo",
                          timeout=timeout)


def test_train_cli_smoke():
    r = _run(["-m", "repro.launch.train", "--arch", "qwen3-0.6b", "--smoke",
              "--steps", "6", "--batch", "2", "--seq", "32",
              "--log-every", "5"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "loss" in r.stdout


def test_serve_cli_smoke():
    r = _run(["-m", "repro.launch.serve", "--arch", "xlstm-350m", "--smoke",
              "--batch", "2", "--prompt-len", "8", "--gen", "8"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "generated" in r.stdout


def test_train_loss_decreases():
    r = _run(["-m", "repro.launch.train", "--arch", "starcoder2-3b",
              "--smoke", "--steps", "30", "--batch", "4", "--seq", "64",
              "--log-every", "29"])
    assert r.returncode == 0, r.stderr[-2000:]
    lines = [l for l in r.stdout.splitlines() if l.startswith("step")]
    first = float(lines[0].split("loss")[1].split()[0])
    last = float(lines[-1].split("loss")[1].split()[0])
    assert last < first, (first, last)

"""Property tests: the chaos plane under ARBITRARY fault mixes.

The invariants that make fault injection safe to leave on everywhere:
  * BYTE CONSERVATION — at every point in an arbitrary offer/tick
    stream through a :class:`FaultyChannel` (any FaultPlan, retries on
    or off), Σ sent == Σ delivered + Σ dropped + Σ rejected +
    Σ duplicate + Σ in flight; §2.8 never loses a byte to chaos;
  * INTEGRITY — a payload the channel corrupted or truncated NEVER
    lands in the store: every stored record still verifies its CRC;
  * EXACTLY-ONCE — stored records == admitted verdicts; the dedup
    window keeps duplicated/retried envelopes from double-counting.

Payloads are built from raw numpy word streams via
``CodePayload.from_words`` so the properties run many cases without a
kernel dispatch. Hypothesis is a dev-only dependency; the fixed-case
fallbacks keep the invariants covered without it.
"""
import jax
import numpy as np
import pytest

from repro.core import octopus as OC
from repro.core.dvqae import DVQAEConfig
from repro.kernels.pack_bits import code_bits, packing_dims
from repro.server import ContinuousIngestService
from repro.sim import FaultPlan, FaultyChannel
from repro.wire import CodePayload, OctopusServer, RetryPolicy

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:            # dev-only dependency; fixed cases still run
    HAVE_HYPOTHESIS = False

BITS = code_bits(16)


@pytest.fixture(scope="module")
def tiny_cfg():
    return DVQAEConfig(kind="image", in_channels=3, hidden=8, latent_dim=8,
                       codebook_size=16, n_res_blocks=1)


@pytest.fixture(scope="module")
def state(tiny_cfg):
    return OC.server_init(jax.random.PRNGKey(0), tiny_cfg)


def _payload(n_samples, fill=0):
    """A (1, n_samples, 3)-shaped stamped payload from raw words."""
    G, W = packing_dims(BITS)
    count = n_samples * 3
    rows = max(2, (count + G - 1) // G)   # >= 2 rows so truncate can cut
    words = np.full((rows, W), fill, dtype=np.uint32)
    return CodePayload.from_words(words, bits=BITS,
                                  shape=(1, n_samples, 3))


# one plan knob set per case: probabilities coarse on purpose — the
# interesting transitions are off / sometimes / always
_P = [0.0, 0.4, 1.0]
if HAVE_HYPOTHESIS:
    PLAN = st.builds(FaultPlan,
                     drop=st.sampled_from(_P),
                     duplicate=st.sampled_from(_P),
                     reorder=st.sampled_from(_P),
                     delay=st.sampled_from(_P),
                     corrupt=st.sampled_from(_P),
                     truncate=st.sampled_from(_P))
    # (client_id 0..5, n_samples 1..4, tick-after?) per offer
    STEP = st.tuples(st.integers(0, 5), st.integers(1, 4), st.booleans())
    STREAM = st.lists(STEP, min_size=1, max_size=25)
    RETRY = st.sampled_from([None, RetryPolicy(max_attempts=2,
                                               base_ticks=1, cap_ticks=2)])

FIXED_CASES = [
    (FaultPlan(drop=1.0, duplicate=1.0), [(0, 2, True), (1, 3, False)],
     None),
    (FaultPlan(corrupt=1.0, truncate=0.4, delay=0.4),
     [(c, 2, c % 2 == 0) for c in range(6)], None),
    (FaultPlan(drop=0.4, duplicate=0.4, reorder=0.4, delay=0.4,
               corrupt=0.4, truncate=0.4),
     [(c % 4, 1 + c % 3, c % 2 == 0) for c in range(12)],
     RetryPolicy(max_attempts=2, base_ticks=1, cap_ticks=2)),
]


def _run(tiny_cfg, state, plan, stream, retry):
    svc = ContinuousIngestService(OctopusServer(state, tiny_cfg),
                                  capacity=8)
    chan = FaultyChannel(svc, plan, key=jax.random.PRNGKey(13),
                         retry=retry)
    for i, (cid, n, tick_after) in enumerate(stream):
        chan.offer(_payload(n, fill=i), client_ids=[cid])
        q = chan.queue
        assert q.bytes_sent == (q.bytes_delivered + q.bytes_dropped
                                + q.bytes_rejected + q.bytes_duplicate
                                + q.bytes_in_flight)
        if tick_after:
            chan.tick()
    chan.drain()
    return chan, svc


def _check(chan, svc):
    q = chan.queue
    # conservation, with everything landed (nothing left in flight)
    assert q.bytes_in_flight == 0
    assert q.bytes_sent == (q.bytes_delivered + q.bytes_dropped
                            + q.bytes_rejected + q.bytes_duplicate)
    # integrity: nothing corrupt ever landed
    for rec in svc.wire.store.records:
        assert rec.packed.verify()
    # exactly-once: one stored record per ADMITTED verdict
    admitted = sum(chan.verdicts.get(v, 0)
                   for v in ("accepted", "deferred", "migrated"))
    assert len(svc.wire.store) == admitted


if HAVE_HYPOTHESIS:
    _CFG = DVQAEConfig(kind="image", in_channels=3, hidden=8, latent_dim=8,
                       codebook_size=16, n_res_blocks=1)
    _STATE = OC.server_init(jax.random.PRNGKey(0), _CFG)

    @settings(max_examples=40, deadline=None)
    @given(plan=PLAN, stream=STREAM, retry=RETRY)
    def test_chaos_invariants_property(plan, stream, retry):
        _check(*_run(_CFG, _STATE, plan, stream, retry))


@pytest.mark.parametrize("plan,stream,retry", FIXED_CASES)
def test_chaos_invariants_fixed(tiny_cfg, state, plan, stream, retry):
    _check(*_run(tiny_cfg, state, plan, stream, retry))

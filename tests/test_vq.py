"""Unit tests: basic VQ (Eq. 1), codebook EMA (Eq. 7-9)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ema, vq


def test_nearest_atom_matches_bruteforce(key):
    z = jax.random.normal(key, (50, 16))
    cb = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    idx = vq.nearest_atom(z, cb)
    d = jnp.sum((z[:, None] - cb[None]) ** 2, -1)
    np.testing.assert_array_equal(np.asarray(idx), np.argmin(np.asarray(d), -1))


def test_quantize_forward_equals_codebook_rows(key):
    z = jax.random.normal(key, (4, 8, 16))
    cb = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    out = vq.quantize(z, cb)
    np.testing.assert_allclose(np.asarray(out.quantized),
                               np.asarray(cb[out.indices]),
                               rtol=1e-5, atol=1e-6)


def test_ste_gradient_passes_through(key):
    """d/dz of sum(quantize(z)) == ones (straight-through estimator)."""
    z = jax.random.normal(key, (8, 16))
    cb = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    g = jax.grad(lambda z: jnp.sum(vq.quantize(z, cb).quantized))(z)
    np.testing.assert_allclose(np.asarray(g), np.ones_like(g), rtol=1e-6)


def test_commit_loss_zero_when_z_on_codebook(key):
    cb = jax.random.normal(key, (32, 16))
    z = cb[:8]
    out = vq.quantize(z, cb)
    assert float(out.commit_loss) < 1e-10
    assert float(out.codebook_loss) < 1e-10


def test_vq_loss_terms_weights(key):
    z = jax.random.normal(key, (8, 16))
    cb = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    out = vq.quantize(z, cb)
    total = vq.vq_loss_terms(out, alpha=2.0, beta=0.5)
    np.testing.assert_allclose(
        float(total), 2.0 * float(out.codebook_loss) + 0.5 * float(out.commit_loss),
        rtol=1e-6)


def test_codes_nbits():
    idx = jnp.zeros((4, 16), jnp.int32)
    assert vq.codes_nbits(idx, 256) == 4 * 16 * 8
    assert vq.codes_nbits(idx, 512) == 4 * 16 * 9


def test_quantize_default_uses_kernel_and_matches_reference(key):
    """Satellite: quantize's DEFAULT path is the Pallas nearest-neighbour
    kernel (ops picks, interpret fallback off-TPU) and agrees with the
    pure-jnp reference — indices, losses and STE output."""
    z = jax.random.normal(key, (4, 50, 16))
    cb = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    auto = vq.quantize(z, cb)
    ref = vq.quantize(z, cb, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(auto.indices),
                                  np.asarray(ref.indices))
    np.testing.assert_allclose(np.asarray(auto.quantized),
                               np.asarray(ref.quantized), rtol=1e-6)
    assert float(auto.codebook_loss) == pytest.approx(
        float(ref.codebook_loss), rel=1e-6)
    # and it still sits inside grad-traced training steps (STE intact)
    g = jax.grad(lambda z: jnp.sum(vq.quantize(z, cb).quantized))(z)
    np.testing.assert_allclose(np.asarray(g), np.ones_like(g), rtol=1e-6)


def test_kernel_argmin_tiebreak_matches_nearest_atom(key):
    """Satellite: on exact ties (duplicated atoms) the kernel picks the
    FIRST minimal index, like jnp.argmin in nearest_atom — including
    duplicates that straddle the kernel's K-block boundary."""
    from repro.kernels.ops import vq_nearest
    cb = jax.random.normal(key, (640, 16))
    dup_pairs = [(3, 17), (40, 41), (100, 600)]   # 100/600 cross blocks
    for a, b in dup_pairs:
        cb = cb.at[b].set(cb[a])
    z = cb[jnp.array([a for a, _ in dup_pairs]
                     + [b for _, b in dup_pairs])] + 1e-8
    want = vq.nearest_atom(z, cb)
    got = vq_nearest(z, cb, block_k=128)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    firsts = np.array([a for a, _ in dup_pairs])
    np.testing.assert_array_equal(np.asarray(got).reshape(2, -1),
                                  np.stack([firsts, firsts]))


def test_perplexity_matches_onehot_reference(key):
    """Satellite regression: bincount histogram == the (N, K) one-hot
    mean it replaced, bit-for-bit on the resulting perplexity."""
    idx = jax.random.randint(key, (13, 37), 0, 29)
    onehot = jax.nn.one_hot(idx.reshape(-1), 32, dtype=jnp.float32)
    probs = jnp.mean(onehot, axis=0)
    ent = -jnp.sum(jnp.where(probs > 0, probs * jnp.log(probs), 0.0))
    np.testing.assert_allclose(float(vq.perplexity(idx, 32)),
                               float(jnp.exp(ent)), rtol=1e-6)
    # jit-compatible (length is static) and empty-safe
    assert float(jax.jit(vq.perplexity, static_argnums=1)(idx, 32)) > 0
    assert float(vq.perplexity(jnp.zeros((0,), jnp.int32), 8)) == \
        pytest.approx(1.0)


def test_perplexity_uniform_vs_collapsed():
    uniform = jnp.arange(64, dtype=jnp.int32) % 8
    collapsed = jnp.zeros((64,), jnp.int32)
    assert float(vq.perplexity(uniform, 8)) == pytest.approx(8.0, rel=1e-3)
    assert float(vq.perplexity(collapsed, 8)) == pytest.approx(1.0, rel=1e-3)


# ---------------------------------------------------------------- EMA

def test_ema_fixed_point_is_cluster_mean(key):
    """Repeated EMA updates on static data converge atoms to cluster means."""
    cb = jax.random.normal(key, (4, 8))
    centers = jnp.array([[5.0] * 8, [-5.0] * 8, [0.0] * 8, [9.0] * 8])
    z = jnp.repeat(centers, 16, axis=0) + 0.01 * jax.random.normal(
        jax.random.PRNGKey(1), (64, 8))
    state = ema.init_ema(centers + 0.5)   # near-correct init
    for _ in range(200):
        idx = jax.jit(lambda s, z: __import__("repro.core.vq", fromlist=["x"]
                                              ).nearest_atom(z, s.codebook))(state, z)
        state = ema.ema_update(state, z, idx, gamma=0.9)
    per_atom_mean, counts = ema.batch_optimal_atoms(z, idx, 4)
    live = counts > 0
    err = jnp.abs(state.codebook - per_atom_mean)[live]
    assert float(jnp.max(err)) < 0.1


def test_ema_counts_accumulate(key):
    cb = jax.random.normal(key, (8, 4))
    state = ema.init_ema(cb)
    z = jax.random.normal(jax.random.PRNGKey(1), (100, 4))
    from repro.core.vq import nearest_atom
    idx = nearest_atom(z, cb)
    s2 = ema.ema_update(state, z, idx, gamma=0.99)
    # total EMA mass: 0.99 * K * 1.0 + 0.01 * N
    np.testing.assert_allclose(float(jnp.sum(s2.counts)),
                               0.99 * 8 + 0.01 * 100, rtol=1e-5)


def test_batch_optimal_atoms_eq8(key):
    z = jnp.array([[1.0, 1.0], [3.0, 3.0], [10.0, 10.0]])
    idx = jnp.array([0, 0, 1])
    atoms, counts = ema.batch_optimal_atoms(z, idx, 3)
    np.testing.assert_allclose(np.asarray(atoms[0]), [2.0, 2.0])
    np.testing.assert_allclose(np.asarray(atoms[1]), [10.0, 10.0])
    assert counts[2] == 0


def test_codebook_init_unit_scale(key):
    """Regression: tiny codebook init (1/K) collapses the encoder — the
    commitment term drags z_e to ~0 and downstream accuracy falls to
    chance. Atoms must start at the unit scale of IN'd latents."""
    cb = __import__("repro.core.vq", fromlist=["x"]).init_codebook(key, 256, 16)
    import jax.numpy as jnp
    std = float(jnp.std(cb))
    assert 0.5 < std < 2.0, std

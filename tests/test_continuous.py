"""Continuous-ingest server runtime (the Step-6 refactor contracts).

What makes the clocked service a subsystem and not a queue wrapper:
  * admission control is STRUCTURED — every offer gets a verdict
    (accepted / migrated / deferred / rejected + reason), and the byte
    ledger stays conserved across all four: Σ sent == Σ delivered +
    Σ dropped + Σ rejected + Σ in flight (§2.8 includes refusals);
  * a rolling ``v_n -> v_{n+1}`` migration window ingests interleaved
    payloads of BOTH versions, and decode stays bit-identical to
    decoding each payload against its pinned registry snapshot — under
    every policy (keep / retire / reencode);
  * the round-driven ``AsyncCodeServer`` is a thin shim over the
    service (one tick per round) with unchanged behaviour;
  * open-ended Poisson traffic (``SchedulerConfig.rate``) is
    deterministic under one PRNG key, quiet ticks included.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import octopus as OC
from repro.core.dvqae import DVQAEConfig
from repro.kernels.pack_bits import code_bits
from repro.obs import report as obs_report
from repro.server import (BulkDecodePolicy, ContinuousIngestService,
                          RoundScheduler, SchedulerConfig, ShardedCodeStore)
from repro.sim import CohortEngine
from repro.wire import WIRE_VERSION, CodePayload, OctopusServer

N_CLIENTS = 12


@pytest.fixture(autouse=True)
def no_ambient_recorder():
    obs.uninstall()
    yield
    obs.uninstall()


@pytest.fixture(scope="module")
def tiny_cfg():
    return DVQAEConfig(kind="image", in_channels=3, hidden=8, latent_dim=8,
                       codebook_size=16, n_res_blocks=1)


@pytest.fixture(scope="module")
def state(tiny_cfg):
    return OC.server_init(jax.random.PRNGKey(0), tiny_cfg)


@pytest.fixture(scope="module")
def data():
    return jax.random.normal(jax.random.PRNGKey(1),
                             (N_CLIENTS, 2, 8, 8, 3))


def _data_fn(data):
    return lambda ids: data[np.asarray(ids)]


def _pack(seed, version=0, c=2, b=3, t=4):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 16, size=(c, b, t))
    return CodePayload.pack(jnp.asarray(codes, jnp.int32),
                            bits=code_bits(16), version=version)


def _service(tiny_cfg, state, **kw):
    srv = OctopusServer(state, tiny_cfg,
                        store=ShardedCodeStore(tiny_cfg, n_shards=2))
    return ContinuousIngestService(srv, **kw)


# ------------------------------------------------------- admission control

def test_backpressure_verdicts_and_byte_conservation(tiny_cfg, state):
    """A bounded queue rejects past capacity and defers past
    defer_depth; every byte that hit the door is conserved across
    delivered / dropped / rejected / in-flight (§2.8 incl. refusals)."""
    svc = _service(tiny_cfg, state, capacity=2, defer_depth=1)
    verdicts = [svc.offer(_pack(i), client_ids=np.array([2 * i, 2 * i + 1]))
                for i in range(5)]
    assert [v.verdict for v in verdicts] == \
        ["accepted", "deferred", "rejected", "rejected", "rejected"]
    assert all(v.reason == "queue_full" for v in verdicts[2:])
    assert svc.n_rejected == 3 and svc.n_deferred == 1
    q = svc.queue
    # rejected payloads never queue, but their measured bytes ledger
    assert len(q) == 2
    assert q.bytes_sent == sum(v.nbytes for v in verdicts)
    assert q.bytes_rejected == sum(v.nbytes for v in verdicts[2:])
    assert q.bytes_sent == q.bytes_delivered + q.bytes_dropped + \
        q.bytes_rejected + q.bytes_duplicate + q.bytes_in_flight
    ts = svc.tick()
    assert ts.n_delivered == 2 and ts.queue_depth == 0
    assert q.bytes_sent == q.bytes_delivered + q.bytes_dropped + \
        q.bytes_rejected + q.bytes_duplicate + q.bytes_in_flight
    # both admitted payloads landed (deferred is admitted, just slower)
    assert len(svc.wire.store) == 2


def test_wire_violations_reject_with_reason_at_the_door(tiny_cfg, state):
    svc = _service(tiny_cfg, state, capacity=8)
    good = _pack(0)
    for bad, reason in [
            (good._replace(wire=WIRE_VERSION + 1), "wire_revision"),
            (good._replace(privatized=False), "unprivatized"),
            (good._replace(version=9), "unknown_version")]:
        res = svc.offer(bad)
        assert res.verdict == "rejected" and res.reason == reason
    res = svc.offer(good, dropped=True)     # radio loss burns the bytes
    assert res.verdict == "rejected" and res.reason == "radio_drop"
    assert svc.queue.bytes_dropped == good.nbytes
    assert len(svc.queue) == 0 and len(svc.wire.store) == 0


def test_straggler_delay_holds_payloads_across_ticks(tiny_cfg, state):
    svc = _service(tiny_cfg, state)
    svc.offer(_pack(0), delay=2)
    assert svc.tick().n_delivered == 0
    assert svc.tick().n_delivered == 0
    assert svc.tick().n_delivered == 1      # arrival tick = offer + delay
    assert svc.queue.bytes_in_flight == 0


def test_bulk_decode_policy_amortizes_dispatches(tiny_cfg, state):
    """Background decode batches freshly-stored records: same-version
    records share ONE fused dispatch, so amortization grows past 1."""
    svc = _service(tiny_cfg, state,
                   decode_policy=BulkDecodePolicy(min_batch=1, max_batch=8,
                                                  interval_ticks=1))
    for i in range(4):
        svc.offer(_pack(i), client_ids=np.array([0, 1]))
    svc.tick()
    assert svc.decoded_records == 4
    assert svc.decode_dispatches == 1       # one (version, bits) group
    assert svc.decode_amortization == 4.0
    # interval_ticks=0 turns the background decoder off
    off = _service(tiny_cfg, state,
                   decode_policy=BulkDecodePolicy(interval_ticks=0))
    off.offer(_pack(0))
    off.tick()
    assert off.decoded_records == 0


# --------------------------------------------------------------- migration

def _merge_new_version(srv):
    return srv.merge(jnp.stack([jnp.ones((16, 8))]),
                     jnp.stack([jnp.ones((16,))]))


@pytest.mark.parametrize("policy", ["keep", "retire", "reencode"])
def test_live_migration_decode_bit_identical_to_pinned_snapshots(
        tiny_cfg, state, policy):
    """THE migration acceptance contract: interleaved payloads of both
    window versions ingest concurrently, and after the window closes
    every stored record still decodes bit-identically to decoding its
    payload against the registry snapshot it was packed under."""
    srv = OctopusServer(state, tiny_cfg,
                        store=ShardedCodeStore(tiny_cfg, n_shards=2))
    payloads = {0: [_pack(i, version=0) for i in range(2)],
                1: [_pack(10 + i, version=1) for i in range(2)]}
    v1 = _merge_new_version(srv)
    assert v1 == 1
    win = srv.begin_migration(policy=policy)
    assert (win.src, win.dst) == (0, 1)
    # interleave: v0, v1, v0, v1 — both dictionaries live on the wire
    verdicts = []
    for p0, p1 in zip(payloads[0], payloads[1]):
        verdicts.append(srv.ingest(p0, client_ids=np.array([0, 1])))
        verdicts.append(srv.ingest(p1, client_ids=np.array([2, 3])))
    assert [v.verdict for v in verdicts] == \
        ["migrated", "accepted"] * 2
    prog = srv.migration_progress()
    assert prog["src_records"] == 2 and prog["dst_records"] == 2

    # pin the per-payload reference features BEFORE the window closes
    ref = {}
    for v, ps in payloads.items():
        for p in ps:
            f = OC.codes_to_features(None, tiny_cfg, p,
                                     codebook=srv.registry.get(v))
            ref[(v, p.payload.tobytes())] = np.asarray(
                f.reshape((-1,) + f.shape[2:]))

    done = srv.complete_migration()
    assert srv.registry.migration is None
    if policy == "keep":
        assert srv.store.versions == (0, 1)
        assert done["n_reencoded"] == 0
    elif policy == "retire":
        # src records evicted, ledgered, src version refused at the door
        assert srv.store.versions == (1,)
        assert srv.registry.is_retired(0)
        assert srv.store.evicted_bytes_by_version[0] == \
            sum(p.nbytes for p in payloads[0])
        late = srv.ingest(_pack(99, version=0))
        assert late.verdict == "rejected" and late.reason == \
            "retired_version"
    else:
        assert srv.store.versions == (1,)
        assert done["n_reencoded"] == 2
        assert len(srv.store) == 4          # 2 kept + 2 transcoded

    # every SURVIVING record decodes bit-identically to its pinned
    # snapshot — migration never re-decodes against the wrong table
    for rec in srv.store.records:
        k = (rec.version, rec.packed.payload.tobytes())
        if k in ref:        # original records (re-encoded ones are new)
            np.testing.assert_array_equal(
                np.asarray(srv.decode(rec.packed)), ref[k])
    # and the registry still decodes RETIRED versions for anyone who
    # pinned them (snapshots are never deleted)
    for p in payloads[0]:
        np.testing.assert_array_equal(
            np.asarray(srv.decode(p)), ref[(0, p.payload.tobytes())])


def test_reencode_transcodes_to_nearest_dst_atoms(tiny_cfg, state):
    """Re-encoded records carry dst-version indices whose atoms are the
    nearest dst atoms to the src-decoded features."""
    srv = OctopusServer(state, tiny_cfg)
    p0 = _pack(3, version=0)
    v1 = _merge_new_version(srv)
    srv.begin_migration(policy="reencode")
    srv.ingest(p0)
    srv.complete_migration()
    (rec,) = srv.store.records
    assert rec.version == v1
    feats = OC.codes_to_features(None, tiny_cfg, p0,
                                 codebook=srv.registry.get(0))
    cb = np.asarray(srv.registry.get(v1))
    want = np.argmin(((np.asarray(feats)[..., None, :] - cb) ** 2
                      ).sum(-1), axis=-1)
    np.testing.assert_array_equal(np.asarray(rec.packed.unpack()), want)


def test_migration_window_guards(tiny_cfg, state):
    srv = OctopusServer(state, tiny_cfg)
    with pytest.raises(KeyError):
        srv.begin_migration()               # only v0 exists
    _merge_new_version(srv)
    srv.begin_migration(policy="keep")
    with pytest.raises(ValueError, match="still open"):
        srv.begin_migration(policy="keep")
    srv.complete_migration()
    with pytest.raises(ValueError, match="no migration window"):
        srv.complete_migration()
    with pytest.raises(ValueError, match="latest"):
        srv.registry.retire(srv.registry.latest)


# --------------------------------------------------- continuous traffic

def test_run_continuous_traced_conserves_bytes(tiny_cfg, data, tmp_path):
    """Open-ended churny traffic through the service, traced: the §2.8
    check (incl. the refused-payload conservation identity) passes, and
    backpressure actually engaged (>= 1 deferred/rejected verdict)."""
    state = OC.server_init(jax.random.PRNGKey(0), tiny_cfg)
    srv = OctopusServer(state, tiny_cfg,
                        store=ShardedCodeStore(tiny_cfg, n_shards=2))
    svc = ContinuousIngestService(srv, capacity=2, defer_depth=1)
    sched = RoundScheduler(
        N_CLIENTS,
        SchedulerConfig(rate=6.0, straggler_prob=0.5, max_delay=2,
                        drop_prob=0.2, leave_prob=0.2, join_prob=0.5),
        key=jax.random.PRNGKey(7))
    engine = CohortEngine(tiny_cfg, gamma=0.9, n_local_steps=0)
    trace = tmp_path / "cont.jsonl"
    with obs.recording(trace):
        hist = engine.run_continuous(svc, sched, _data_fn(data),
                                     cohort_size=3, n_ticks=5,
                                     merge_every=2,
                                     migration_policy="keep")
        svc.drain()
    assert len(hist) == 5
    assert sum(t.n_rejected for t in hist) + \
        sum(t.n_deferred for t in hist) >= 1
    q = svc.queue
    assert q.bytes_sent == q.bytes_delivered + q.bytes_dropped + \
        q.bytes_rejected + q.bytes_duplicate + q.bytes_in_flight
    # merges happened and opened rolling windows
    assert any(t.merged_version for t in hist)
    summary = obs_report.summarize(obs_report.load_events(str(trace)))
    assert obs_report.check_bytes(summary) == []
    assert summary["admission"]["verdicts"]     # non-empty histogram
    assert summary["kinds"].get("migration", 0) >= 1


def test_run_continuous_deterministic(tiny_cfg, data):
    """Same key -> same verdict stream, byte ledger and merged
    dictionary — open-ended traffic is replayable."""
    def go():
        state = OC.server_init(jax.random.PRNGKey(0), tiny_cfg)
        srv = OctopusServer(state, tiny_cfg)
        svc = ContinuousIngestService(srv, capacity=3)
        sched = RoundScheduler(
            N_CLIENTS, SchedulerConfig(rate=5.0, straggler_prob=0.4),
            key=jax.random.PRNGKey(3))
        engine = CohortEngine(tiny_cfg, gamma=0.9, n_local_steps=0)
        hist = engine.run_continuous(svc, sched, _data_fn(data),
                                     cohort_size=3, n_ticks=4,
                                     merge_every=2)
        return hist, svc
    ha, sa = go()
    hb, sb = go()
    assert ha == hb
    assert sa.verdicts == sb.verdicts
    assert sa.queue.bytes_sent == sb.queue.bytes_sent
    np.testing.assert_array_equal(
        np.asarray(sa.wire.registry.current),
        np.asarray(sb.wire.registry.current))


def test_poisson_arrivals_deterministic_and_bursty():
    """rate-driven scheduling: deterministic under the key, open-ended
    (variable counts, quiet ticks allowed), isolated substream."""
    cfg = SchedulerConfig(rate=2.0)
    a = RoundScheduler(16, cfg, key=jax.random.PRNGKey(2))
    b = RoundScheduler(16, cfg, key=jax.random.PRNGKey(2))
    ka = [a.step().participants.size for _ in range(20)]
    kb = [b.step().participants.size for _ in range(20)]
    assert ka == kb
    assert len(set(ka)) > 1                 # actually varies
    assert max(ka) <= 16
    # turning stragglers on must not change the arrival counts (each
    # draw purpose owns a substream)
    c = RoundScheduler(16, SchedulerConfig(rate=2.0, straggler_prob=0.9),
                       key=jax.random.PRNGKey(2))
    kc = [c.step().participants.size for _ in range(20)]
    assert kc == ka


# ---------------------------------------------------------- legacy shim

def test_async_server_is_a_thin_shim_over_the_service(tiny_cfg, data):
    """AsyncCodeServer.run_round == one service tick: the service's
    clock, queue and ledger ARE the legacy attributes."""
    from repro.server import AsyncCodeServer
    from repro.sim import SimEngine
    state = OC.server_init(jax.random.PRNGKey(0), tiny_cfg)
    sched = RoundScheduler(N_CLIENTS,
                           SchedulerConfig(participation=0.5,
                                           straggler_prob=0.4),
                           key=jax.random.PRNGKey(11))
    acs = AsyncCodeServer(SimEngine(tiny_cfg, gamma=0.9, n_local_steps=0),
                          state, sched, merge_every=2)
    assert acs.queue is acs.service.queue
    for r in range(3):
        assert acs.round == r == acs.service.tick_idx
        stats = acs.run_round(data)
        assert stats.round == r
    assert acs.bytes_sent == acs.service.queue.bytes_sent
    assert acs.bytes_sent == acs.bytes_delivered + acs.bytes_dropped + \
        acs.queue.bytes_rejected + acs.queue.bytes_duplicate + \
        acs.queue.bytes_in_flight

"""Integration: the OCTOPUS protocol end-to-end (Steps 1-6) on synthetic
factorized data, validating the paper's qualitative claims mechanically."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dvqae, octopus
from repro.core.dvqae import DVQAEConfig


@pytest.fixture(scope="module")
def image_cfg():
    return DVQAEConfig(kind="image", in_channels=3, hidden=32, latent_dim=16,
                       codebook_size=64, n_res_blocks=1)


def test_server_pretrain_reduces_loss(image_cfg):
    key = jax.random.PRNGKey(0)
    srv = octopus.server_init(key, image_cfg)
    x = jax.random.normal(key, (8, 16, 16, 3)) * 0.5

    @jax.jit
    def step(s, x):
        return octopus.server_pretrain_step(s, image_cfg, x)

    first = None
    for i in range(30):
        srv, out = step(srv, x)
        if first is None:
            first = float(out.loss)
    assert float(out.loss) < first


def test_client_roundtrip_codes_only(image_cfg):
    """Clients transmit int indices; server reconstructs features of the
    right shape; bytes transmitted << raw bytes."""
    from repro.wire import CodePayload
    key = jax.random.PRNGKey(0)
    srv = octopus.server_init(key, image_cfg)
    cl = octopus.client_init(srv)
    x = jax.random.normal(key, (4, 16, 16, 3))
    idx = dvqae.forward(cl.params, image_cfg, x).latent.indices
    p = CodePayload.pack(idx, bits=octopus.transmit_bits(image_cfg))
    tx = octopus.Transmission(indices=idx, nbytes=p.nbytes,
                              labels=jnp.arange(4),
                              payload=p.payload, bits=p.bits)
    assert tx.indices.dtype == jnp.int32
    raw_bytes = x.size * 4
    assert tx.nbytes < raw_bytes / 50
    idx, labels, total = octopus.gather_codes([tx, tx])
    feats = octopus.codes_to_features(srv, image_cfg, idx)
    assert feats.shape == (8, 16, image_cfg.latent_dim)   # 16x16 -> 4x4 grid
    assert labels.shape == (8,)


def test_codebook_refresh_changes_codebook(image_cfg):
    key = jax.random.PRNGKey(0)
    srv = octopus.server_init(key, image_cfg)
    cl = octopus.client_init(srv)
    x = jax.random.normal(key, (8, 16, 16, 3)) * 2.0
    before = cl.params["codebook"]
    cl2 = octopus.client_codebook_refresh(cl, image_cfg, x)
    assert float(jnp.max(jnp.abs(cl2.params["codebook"] - before))) > 0
    # EMA with gamma=0.99 moves slowly
    assert float(jnp.max(jnp.abs(cl2.params["codebook"] - before))) < \
        float(jnp.max(jnp.abs(before))) + 1.0


def test_server_merge_codebooks(image_cfg):
    key = jax.random.PRNGKey(0)
    srv = octopus.server_init(key, image_cfg)
    K, M = image_cfg.codebook_size, image_cfg.latent_dim
    cb1 = jnp.ones((K, M))
    cb2 = jnp.zeros((K, M))
    n1 = jnp.full((K,), 3.0)
    n2 = jnp.full((K,), 1.0)
    merged = octopus.server_merge_codebooks(srv, [cb1, cb2], [n1, n2])
    np.testing.assert_allclose(np.asarray(merged.params["codebook"]),
                               0.75, atol=1e-6)


def test_client_finetune_keeps_codebook_frozen(image_cfg):
    key = jax.random.PRNGKey(0)
    srv = octopus.server_init(key, image_cfg)
    cl = octopus.client_init(srv)
    x = jax.random.normal(key, (4, 16, 16, 3))
    cb_before = cl.params["codebook"]
    cl2, opt, out = octopus.client_finetune_step(cl, image_cfg, x)
    np.testing.assert_array_equal(np.asarray(cl2.params["codebook"]),
                                  np.asarray(cb_before))
    # but encoder moved
    diff = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.sum(jnp.abs(a - b))),
                     cl.params["encoder"], cl2.params["encoder"]))
    assert diff > 0


def test_speech_pipeline(key):
    cfg = DVQAEConfig(kind="speech", in_channels=8, hidden=32, latent_dim=16,
                      codebook_size=32, n_res_blocks=1)
    srv = octopus.server_init(key, cfg)
    x = jax.random.normal(key, (4, 32, 8))
    srv, out = octopus.server_pretrain_step(srv, cfg, x)
    assert out.recon.shape == x.shape
    cl = octopus.client_init(srv)
    idx = dvqae.forward(cl.params, cfg, x).latent.indices
    assert idx.shape == (4, 8)             # 32 frames -> 8 latent steps


@pytest.mark.parametrize("n_groups,n_slices", [(4, 2), (1, 2), (4, 1)])
def test_codebook_refresh_gsvq_maps_groups_to_representative_atoms(
        n_groups, n_slices):
    """Regression (§2.4/§2.6): sliced GSVQ refresh used to scatter EMA
    mass with raw (.., n_c) group indices as atom ids (and n_groups == 1
    sliced configs skipped the group->atom mapping entirely). Every
    slice's group index must land on its group's representative atom."""
    key = jax.random.PRNGKey(0)
    cfg = DVQAEConfig(kind="image", in_channels=3, hidden=8, latent_dim=16,
                      codebook_size=64, n_res_blocks=1,
                      n_groups=n_groups, n_slices=n_slices)
    srv = octopus.server_init(key, cfg)
    cl = octopus.client_init(srv)
    x = jax.random.normal(key, (4, 16, 16, 3))
    cl2 = octopus.client_codebook_refresh(cl, cfg, x, gamma=0.5)
    ng = cfg.codebook_size // cfg.n_groups
    representatives = {g * ng + ng // 2 for g in range(cfg.n_groups)}
    counts0, counts1 = np.asarray(cl.ema.counts), np.asarray(cl2.ema.counts)
    grew = set(np.nonzero(counts1 > 0.5 * counts0 + 1e-9)[0].tolist())
    assert grew, "refresh scattered no EMA mass"
    assert grew <= representatives, grew - representatives
    assert cl2.params["codebook"].shape == cl.params["codebook"].shape
    assert bool(jnp.all(jnp.isfinite(cl2.params["codebook"])))


def test_gather_codes_mixed_labels():
    """Regression: mixed labeled/unlabeled uploads keep sample alignment
    (fill -1) instead of crashing or silently dropping labels."""
    mk = lambda n, lab=None: octopus.Transmission(
        indices=jnp.zeros((n, 3), jnp.int32), nbytes=4, labels=lab)
    labeled, unlabeled = mk(2, jnp.array([5, 6])), mk(3)
    idx, lab, _ = octopus.gather_codes([labeled, unlabeled])
    assert idx.shape[0] == 5
    np.testing.assert_array_equal(np.asarray(lab), [5, 6, -1, -1, -1])
    _, lab, _ = octopus.gather_codes([unlabeled, labeled])   # used to drop
    np.testing.assert_array_equal(np.asarray(lab), [-1, -1, -1, 5, 6])
    _, lab, _ = octopus.gather_codes([unlabeled, unlabeled])
    assert lab is None
    _, lab, _ = octopus.gather_codes([labeled, labeled])
    np.testing.assert_array_equal(np.asarray(lab), [5, 6, 5, 6])
    # unsigned label dtypes must not wrap the -1 filler to a huge class id
    _, lab, _ = octopus.gather_codes(
        [mk(2, jnp.array([5, 6], jnp.uint32)), unlabeled])
    assert jnp.issubdtype(lab.dtype, jnp.signedinteger)
    np.testing.assert_array_equal(np.asarray(lab), [5, 6, -1, -1, -1])


def test_codebook_refresh_updates_in_normalized_space(image_cfg):
    """Regression: EMA must move atoms in IN-space when apply_in is on —
    atoms drifting toward raw z_e (different scale) worsen quantization."""
    key = jax.random.PRNGKey(0)
    srv = octopus.server_init(key, image_cfg)
    for i in range(60):
        x = jax.random.normal(jax.random.fold_in(key, i), (8, 16, 16, 3))
        srv, _ = octopus.server_pretrain_step(srv, image_cfg, x)
    cl = octopus.client_init(srv)
    # drifted inputs
    xd = jax.random.normal(jax.random.PRNGKey(7), (16, 16, 16, 3)) * 2 + 1
    from repro.core.dvqae import forward as fwd
    before = float(fwd(cl.params, image_cfg, xd).latent.commit_loss)
    for _ in range(15):
        cl = octopus.client_codebook_refresh(cl, image_cfg, xd, gamma=0.8)
    after = float(fwd(cl.params, image_cfg, xd).latent.commit_loss)
    assert after < before, (before, after)

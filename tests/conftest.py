import jax
import pytest

# Tests run on the single CPU device (dry-run owns the 512-device trick).
jax.config.update("jax_platform_name", "cpu")


def pytest_configure(config):
    # Escalate the repro deprecation shims (PackedCodes, client_transmit,
    # IngestBuffer, ...) to errors: no internal code path may silently
    # construct a deprecated carrier. Every shim's message says which
    # repro.* replacement to use, which is what the filter keys on.
    # (Tests that exercise the shims on purpose use pytest.warns, which
    # overrides these filters inside its block.)
    config.addinivalue_line(
        "filterwarnings", r"error:.*use repro\.:DeprecationWarning")


def abstract_mesh(sizes, names):
    """AbstractMesh across jax versions: new (sizes, names) signature vs
    the 0.4.x ((name, size), ...) pair tuple."""
    try:
        return jax.sharding.AbstractMesh(sizes, names)
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(names, sizes)))


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)

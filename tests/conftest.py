import jax
import pytest

# Tests run on the single CPU device (dry-run owns the 512-device trick).
jax.config.update("jax_platform_name", "cpu")


def abstract_mesh(sizes, names):
    """AbstractMesh across jax versions: new (sizes, names) signature vs
    the 0.4.x ((name, size), ...) pair tuple."""
    try:
        return jax.sharding.AbstractMesh(sizes, names)
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(names, sizes)))


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)

import jax
import pytest

# Tests run on the single CPU device (dry-run owns the 512-device trick).
jax.config.update("jax_platform_name", "cpu")


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)

"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train step + one decode step on CPU, asserting shapes and finiteness.
(The FULL assigned configs are exercised via the dry-run only.)"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config, smoke_config
from repro.models import transformer as T
from repro.optim.adamw import adamw_init, adamw_update

BATCH, SEQ = 2, 32


def _enc(cfg, params, key, batch=BATCH):
    if not cfg.is_encoder_decoder:
        return None
    frames = jax.random.normal(key, (batch, cfg.n_audio_frames, cfg.d_model))
    return T.encode_audio(params, cfg, frames)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_and_finite(arch, key):
    cfg = smoke_config(arch)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.moe.enabled:
        assert cfg.moe.n_experts <= 4
    params = T.init_lm(key, cfg)
    tokens = jax.random.randint(key, (BATCH, SEQ), 0, cfg.vocab_size)
    out = T.forward(params, cfg, tokens, enc_out=_enc(cfg, params, key))
    assert out.logits.shape == (BATCH, SEQ, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(out.logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step_reduces_loss(arch, key):
    cfg = smoke_config(arch)
    params = T.init_lm(key, cfg)
    opt = adamw_init(params)
    tokens = jax.random.randint(key, (BATCH, SEQ), 0, cfg.vocab_size)
    enc = _enc(cfg, params, key)

    def loss_fn(p):
        return T.lm_loss(p, cfg, tokens, enc_out=enc, remat=False)

    @jax.jit
    def step(params, opt):
        loss, g = jax.value_and_grad(loss_fn)(params)
        params, opt = adamw_update(params, g, opt, lr=1e-3)
        return params, opt, loss

    params, opt, l0 = step(params, opt)
    for _ in range(4):
        params, opt, loss = step(params, opt)
    assert bool(jnp.isfinite(loss))
    assert float(loss) < float(l0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_matches_prefill(arch, key):
    """Greedy decode logits at position t must match teacher-forced logits
    (cache correctness)."""
    cfg = smoke_config(arch)
    params = T.init_lm(key, cfg)
    S = 8
    tokens = jax.random.randint(key, (BATCH, S), 0, cfg.vocab_size)
    enc = _enc(cfg, params, key)
    full = T.forward(params, cfg, tokens, enc_out=enc)

    caches = T.init_caches(cfg, BATCH, S + 4)
    logits_steps = []
    for t in range(S):
        lg, caches = T.decode_step(params, cfg, tokens[:, t:t + 1], caches,
                                   jnp.int32(t), enc_out=enc)
        logits_steps.append(lg[:, 0])
    dec = jnp.stack(logits_steps, axis=1)
    err = jnp.max(jnp.abs(dec - full.logits))
    # recurrent paths accumulate small fp differences; attention is exact
    assert float(err) < (5e-2 if cfg.family in ("ssm", "hybrid") else 2e-3), \
        f"decode/prefill mismatch {float(err)}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The FULL config must carry the exact assigned hyperparameters."""
    spec = {
        "jamba_v0_1_52b": (32, 4096, 32, 8, 65536),
        "qwen3_0_6b": (28, 1024, 16, 8, 151936),
        "chameleon_34b": (48, 8192, 64, 8, 65536),
        "minicpm3_4b": (62, 2560, 40, 40, 73448),
        "gemma_7b": (28, 3072, 16, 16, 256000),
        "xlstm_350m": (24, 1024, 4, 4, 50304),
        "starcoder2_3b": (30, 3072, 24, 2, 49152),
        "whisper_base": (6, 512, 8, 8, 51865),
        "deepseek_v3_671b": (61, 7168, 128, 128, 129280),
        "qwen3_moe_30b_a3b": (48, 2048, 32, 4, 151936),
    }[arch]
    cfg = get_config(arch)
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.vocab_size) == spec
    assert cfg.source != ""


def test_moe_expert_counts():
    assert get_config("deepseek_v3_671b").moe.n_experts == 256
    assert get_config("deepseek_v3_671b").moe.n_experts_per_tok == 8
    assert get_config("qwen3_moe_30b_a3b").moe.n_experts == 128
    assert get_config("jamba_v0_1_52b").moe.n_experts == 16


def test_segment_plan_covers_all_layers():
    for a in ARCH_IDS:
        cfg = get_config(a)
        assert sum(n for _, _, n in T.segment_plan(cfg)) == cfg.n_layers

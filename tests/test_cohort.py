"""Cohort-sharded population engine: scale-invariance property suite.

The cohort engine's whole correctness story is algebraic, so these tests
pin it BIT-EXACTLY (``array_equal``, no tolerance anywhere):

  * grouping invariance — any partition/order of the same client set,
    merged cohort-by-cohort, bit-matches the single full-population
    merge (codebooks, EMA merge stats, decoded features);
  * §2.8 byte accounting — Σ per-cohort ``CodePayload.nbytes`` equals
    the whole-population round's measured bytes (per-client padding
    included), for VQ and GSVQ across packing widths 1-12;
  * payload concatenation — stacking cohort payload words IS the
    population payload.

hypothesis widens the fixed cases to arbitrary partitions when it is
installed (requirements-dev.txt); the deterministic cases always run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import octopus as OC
from repro.core.dvqae import DVQAEConfig
from repro.core.ema import (merge_codebook, merge_stats, merge_stats_add,
                            merge_stats_zero)
from repro.sim import CohortEngine, CohortPlan
from repro.wire import CodePayload, OctopusServer, concat_payloads

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:            # dev-only dependency; fixed cases still run
    HAVE_HYPOTHESIS = False

N_CLIENTS = 12


@pytest.fixture(scope="module")
def tiny_cfg():
    return DVQAEConfig(kind="image", in_channels=3, hidden=8, latent_dim=8,
                       codebook_size=16, n_res_blocks=1)


@pytest.fixture(scope="module")
def gsvq_cfg():
    return DVQAEConfig(kind="image", in_channels=3, hidden=8, latent_dim=8,
                       codebook_size=16, n_groups=4, n_slices=2,
                       n_res_blocks=1)


@pytest.fixture(scope="module")
def server(tiny_cfg):
    return OC.server_init(jax.random.PRNGKey(0), tiny_cfg)


@pytest.fixture(scope="module")
def gsvq_server(gsvq_cfg):
    return OC.server_init(jax.random.PRNGKey(0), gsvq_cfg)


@pytest.fixture(scope="module")
def data():
    return jax.random.normal(jax.random.PRNGKey(1),
                             (N_CLIENTS, 2, 8, 8, 3))


def _data_fn(data):
    return lambda ids: data[np.asarray(ids)]


def _partitions():
    """Order-preserving partitions of range(N_CLIENTS) into multi-client
    cohorts (the engine-level bit-invariance boundary — XLA specializes
    the degenerate C == 1 batch into a different program; singleton
    grouping is covered at the stats-algebra level, where the merge is
    exact for ANY grouping)."""
    ids = np.arange(N_CLIENTS)
    return [
        [ids],                                     # the population itself
        [ids[:5], ids[5:9], ids[9:]],              # ragged cohorts
        [ids[i:i + 2] for i in range(0, N_CLIENTS, 2)],   # minimal (C=2)
        [ids[:6], ids[6:]],                        # two halves
        [ids[:2], ids[2:5], ids[5:]],              # mixed 2/3/7
    ]


# -------------------------------------------------- grouping invariance

def _run(engine, server, groups, data):
    return engine.round(server, CohortPlan.from_groups(groups),
                        _data_fn(data))


@pytest.mark.parametrize("cfg_name", ["tiny_cfg", "gsvq_cfg"])
def test_cohort_grouping_invariance_bitexact(cfg_name, request, data):
    """Any order-preserving cohort partition reproduces the single
    full-population round bit-for-bit: merge stats, merged codebook,
    payload words, Σ bytes, decoded features."""
    cfg = request.getfixturevalue(cfg_name)
    srv = OC.server_init(jax.random.PRNGKey(0), cfg)
    engine = CohortEngine(cfg, gamma=0.9, n_local_steps=1)
    runs = [_run(engine, srv, g, data) for g in _partitions()]
    full = runs[0]
    full_payload = full.payloads[0]
    merged_full = OC.server_merge_stats(srv, full.stats)
    feats_full = OC.codes_to_features(srv, cfg, full_payload)
    for out in runs[1:]:
        np.testing.assert_array_equal(out.stats.num, full.stats.num)
        np.testing.assert_array_equal(out.stats.den, full.stats.den)
        merged = OC.server_merge_stats(srv, out.stats)
        np.testing.assert_array_equal(
            np.asarray(merged.params["codebook"]),
            np.asarray(merged_full.params["codebook"]))
        cat = concat_payloads(out.payloads)
        np.testing.assert_array_equal(np.asarray(cat.payload),
                                      np.asarray(full_payload.payload))
        assert cat.shape == full_payload.shape
        assert out.nbytes == full.nbytes == cat.nbytes
        feats = OC.codes_to_features(srv, cfg, cat)
        np.testing.assert_array_equal(np.asarray(feats),
                                      np.asarray(feats_full))


def test_cohort_order_invariance_of_merge(tiny_cfg, server, data):
    """Merge stats are COMMUTATIVE too: streaming the same cohorts in a
    different order bit-matches (payload order differs, the merge
    doesn't)."""
    engine = CohortEngine(tiny_cfg, gamma=0.9, n_local_steps=0)
    ids = np.arange(N_CLIENTS)
    fwd = _run(engine, server, [ids[:4], ids[4:8], ids[8:]], data)
    rev = _run(engine, server, [ids[8:], ids[4:8], ids[:4]], data)
    np.testing.assert_array_equal(fwd.stats.num, rev.stats.num)
    np.testing.assert_array_equal(fwd.stats.den, rev.stats.den)
    assert fwd.nbytes == rev.nbytes


if HAVE_HYPOTHESIS:
    @given(cuts=st.sets(st.integers(1, N_CLIENTS - 1), max_size=6),
           order_seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_cohort_merge_associativity_hypothesis(cuts, order_seed,
                                                   cached_round):
        """ARBITRARY partitions + cohort orders of one engine round
        bit-match the full-population merge. Per-client stats come from
        one cached engine round (cohorting is pure regrouping, as
        test_cohort_grouping_invariance_bitexact pins), so hypothesis
        explores partitions without recompiling the engine."""
        cbs, counts, full_stats = cached_round
        bounds = [0] + sorted(cuts) + [N_CLIENTS]
        groups = [np.arange(a, b) for a, b in zip(bounds, bounds[1:])]
        rng = np.random.default_rng(order_seed)
        acc = merge_stats_zero(*cbs.shape[1:])
        for g in rng.permutation(len(groups)):
            members = groups[g]
            acc = merge_stats_add(acc, merge_stats(cbs[members],
                                                   counts[members]))
        np.testing.assert_array_equal(acc.num, full_stats.num)
        np.testing.assert_array_equal(acc.den, full_stats.den)

    @pytest.fixture(scope="module")
    def cached_round(tiny_cfg, server, data):
        engine = CohortEngine(tiny_cfg, gamma=0.9, n_local_steps=0)
        out = _run(engine, server, [np.arange(N_CLIENTS)], data)
        eng = engine.engine
        clients, _ = eng.round(eng.init_clients(server, N_CLIENTS), data)
        cbs = np.asarray(clients.params["codebook"])
        counts = np.asarray(clients.ema.counts)
        return cbs, counts, out.stats


# ------------------------------------------------- merge-stats algebra

def test_cohort_plan_folds_singleton_tail():
    """13 members at cohort_size 4 -> (4, 4, 5), never a C=1 cohort
    (the degenerate vmap batch compiles into a different program)."""
    plan = CohortPlan.build(np.arange(13), 4)
    assert plan.sizes == (4, 4, 5)
    np.testing.assert_array_equal(plan.members, np.arange(13))
    assert CohortPlan.build(np.arange(1), 4).sizes == (1,)   # lone client
    assert CohortPlan.build(np.arange(12), 4).sizes == (4, 4, 4)


def test_merge_stats_singleton_grouping_is_exact(tiny_cfg, server, data):
    """At the stats level the merge IS exact for singleton grouping: one
    engine round's per-client stats, merged client-by-client, bit-match
    the full-population merge (the engine-level C >= 2 boundary is about
    XLA batch specialization, not the algebra)."""
    engine = CohortEngine(tiny_cfg, gamma=0.9, n_local_steps=0)
    eng = engine.engine
    clients, _ = eng.round(eng.init_clients(server, N_CLIENTS), data)
    cbs = np.asarray(clients.params["codebook"])
    counts = np.asarray(clients.ema.counts)
    full = merge_stats(cbs, counts)
    acc = merge_stats_zero(*cbs.shape[1:])
    for i in range(N_CLIENTS):
        acc = merge_stats_add(acc, merge_stats(cbs[i], counts[i]))
    np.testing.assert_array_equal(acc.num, full.num)
    np.testing.assert_array_equal(acc.den, full.den)


def test_merge_stats_zero_is_identity():
    s = merge_stats(np.random.default_rng(0).normal(size=(3, 8, 4)),
                    np.random.default_rng(1).random((3, 8)))
    z = merge_stats_zero(8, 4)
    np.testing.assert_array_equal(merge_stats_add(s, z).num, s.num)
    np.testing.assert_array_equal(merge_stats_add(z, s).den, s.den)


def test_merge_codebook_dead_atoms_keep_current():
    cur = np.arange(8, dtype=np.float32).reshape(4, 2)
    s = merge_stats(np.ones((1, 4, 2), np.float32),
                    np.array([[2.0, 0.0, 1.0, 0.0]]))
    out = merge_codebook(s, cur)
    np.testing.assert_array_equal(out[1], cur[1])
    np.testing.assert_array_equal(out[3], cur[3])
    np.testing.assert_array_equal(out[0], np.ones(2, np.float32))


def test_server_merge_stats_matches_weighted_average(tiny_cfg, server):
    """The fixed-point merge lands on the float count-weighted average
    (within fixed-point resolution) and keeps dtype/shape."""
    rng = np.random.default_rng(3)
    C, (K, M) = 5, server.params["codebook"].shape
    cbs = rng.normal(size=(C, K, M)).astype(np.float32)
    counts = rng.random((C, K)).astype(np.float32) + 0.1
    got = OC.server_merge_stats(
        server, merge_stats(cbs, counts)).params["codebook"]
    want = OC.server_merge_codebooks(server, cbs, counts).params["codebook"]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    assert got.dtype == server.params["codebook"].dtype


# -------------------------------------------- §2.8 byte accounting

@pytest.mark.parametrize("bits", list(range(1, 13)))
def test_byte_accounting_cohort_invariance_bits(bits):
    """Σ per-cohort nbytes == whole-population nbytes for every packing
    width 1-12, per-client padding included — and the concatenated
    cohort words ARE the population words."""
    rng = np.random.default_rng(bits)
    idx = jnp.asarray(rng.integers(0, 1 << bits, size=(N_CLIENTS, 7)),
                      jnp.int32)
    full = CodePayload.pack_records(idx, bits=bits)
    for groups in _partitions()[1:]:
        parts = [CodePayload.pack_records(idx[jnp.asarray(g)], bits=bits)
                 for g in groups]
        assert sum(p.nbytes for p in parts) == full.nbytes
        cat = concat_payloads(parts)
        np.testing.assert_array_equal(np.asarray(cat.payload),
                                      np.asarray(full.payload))
        np.testing.assert_array_equal(np.asarray(cat.unpack()),
                                      np.asarray(full.unpack()))


@pytest.mark.parametrize("cfg_name", ["tiny_cfg", "gsvq_cfg"])
def test_round_bytes_cohort_invariant(cfg_name, request, data):
    """Engine-level: a cohorted round charges exactly the bytes of the
    whole-population round, for VQ and GSVQ wire formats."""
    cfg = request.getfixturevalue(cfg_name)
    srv = OC.server_init(jax.random.PRNGKey(0), cfg)
    engine = CohortEngine(cfg, gamma=0.9, n_local_steps=0)
    full = _run(engine, srv, [np.arange(N_CLIENTS)], data)
    parts = _run(engine, srv,
                 [np.arange(0, 5), np.arange(5, 9), np.arange(9, 12)], data)
    assert parts.nbytes == full.nbytes
    assert sum(p.nbytes for p in parts.payloads) == full.payloads[0].nbytes


# ------------------------------------------------- wire integration

def test_cohort_payloads_ingest_and_decode_like_population(tiny_cfg, data):
    """Per-cohort payloads through OctopusServer.ingest decode to the
    SAME feature rows as the single population payload."""
    state = OC.server_init(jax.random.PRNGKey(0), tiny_cfg)
    engine = CohortEngine(tiny_cfg, gamma=0.9, n_local_steps=0)
    full = _run(engine, state, [np.arange(N_CLIENTS)], data)
    parts = _run(engine, state,
                 [np.arange(0, 3), np.arange(3, 10), np.arange(10, 12)],
                 data)
    wire_a = OctopusServer(state, tiny_cfg)
    wire_a.ingest(full.payloads[0])
    wire_b = OctopusServer(state, tiny_cfg)
    for p in parts.payloads:
        wire_b.ingest(p)
    fa, _ = wire_a.features()
    fb, _ = wire_b.features()
    np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))
    assert wire_b.store.total_bytes == wire_a.store.total_bytes


def test_traffic_run_is_replayable(tiny_cfg, data):
    """Two scheduler-driven traffic runs from the same key produce the
    identical byte ledger, store contents, and merged dictionaries."""
    from repro.server import RoundScheduler, SchedulerConfig

    def go():
        state = OC.server_init(jax.random.PRNGKey(0), tiny_cfg)
        wire = OctopusServer(state, tiny_cfg)
        sched = RoundScheduler(
            N_CLIENTS, SchedulerConfig(participation=0.5,
                                       straggler_prob=0.4, drop_prob=0.2),
            key=jax.random.PRNGKey(11))
        engine = CohortEngine(tiny_cfg, gamma=0.9, n_local_steps=0)
        hist = engine.run_traffic(wire, sched, _data_fn(data),
                                  cohort_size=3, n_rounds=4, merge_every=2)
        return wire, hist

    wa, ha = go()
    wb, hb = go()
    assert ha == hb
    np.testing.assert_array_equal(np.asarray(wa.registry.current),
                                  np.asarray(wb.registry.current))
    assert wa.store.total_bytes == wb.store.total_bytes
    fa, _ = wa.features()
    fb, _ = wb.features()
    np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))

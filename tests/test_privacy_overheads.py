"""Privacy evaluation (Thm. 1 adversary) + §2.8 overheads accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import overheads as OH
from repro import privacy as PV


def test_adversary_learns_separable_labels(key):
    """Features that encode the label -> high accuracy, low H(Y|Z)."""
    n, d, C = 512, 8, 4
    y = jax.random.randint(key, (n,), 0, C)
    z = jax.nn.one_hot(y, d) * 3.0 + 0.1 * jax.random.normal(
        jax.random.PRNGKey(1), (n, d))
    params = PV.train_adversary(key, z, y, C, steps=200)
    m = PV.evaluate_adversary(params, z, y, C)
    assert m.accuracy > 0.9
    assert m.conditional_entropy_bits < 0.5


def test_adversary_fails_on_random_features(key):
    n, d, C = 512, 8, 4
    y = jax.random.randint(key, (n,), 0, C)
    z = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    params = PV.train_adversary(key, z[:400], y[:400], C, steps=200)
    m = PV.evaluate_adversary(params, z[400:], y[400:], C)
    assert m.accuracy < 0.5
    assert m.conditional_entropy_bits > 1.0     # close to log2(4)=2 bits


def test_privacy_audit_ordering(key):
    """Audit must show: public carries less label info than private."""
    n, d, C = 400, 8, 4
    y = jax.random.randint(key, (n,), 0, C)
    private = jax.nn.one_hot(y, d) * 3.0
    public = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    pub_m, prv_m = PV.privacy_audit(key, public, private, y, C, steps=150)
    assert prv_m.accuracy > pub_m.accuracy
    assert prv_m.conditional_entropy_bits < pub_m.conditional_entropy_bits


def test_privacy_audit_shuffles_label_sorted_data(key):
    """Regression: the 80/20 split must permute first — on label-sorted
    inputs (what non-iid partitions produce) the old head/tail split
    evaluated the adversary on classes it never saw, so even a perfectly
    leaky private component scored ~0 and H(Y|Z) was degenerate."""
    n, C = 300, 5
    y = jnp.repeat(jnp.arange(C), n // C)              # label-sorted
    private = jax.nn.one_hot(y, 8) * 3.0               # fully leaky
    public = jax.random.normal(jax.random.PRNGKey(1), (n, 8))
    pub_m, prv_m = PV.privacy_audit(key, public, private, y, C, steps=150)
    assert prv_m.accuracy > 0.9                        # was ~0 unshuffled
    assert prv_m.accuracy > pub_m.accuracy
    assert prv_m.conditional_entropy_bits < pub_m.conditional_entropy_bits


# --------------------------------------------------------------- overheads

def _comm():
    return OH.CommModel(
        n_clients=100, model_bytes=10_000_000, n_samples=60_000,
        n_epochs=100, code_bytes_per_sample=64,
        smashed_bytes_per_sample=4096, client_frac_params=0.2,
        codebook_bytes=256 * 64 * 4, codebook_sync_rounds=10,
        downstream_model_bytes=1_000_000)


def test_fl_formula():
    c = _comm()
    assert OH.federated_bytes(c) == 2 * 100 * 10_000_000 * 100


def test_octopus_orders_of_magnitude_cheaper():
    c = _comm()
    table = OH.comparison_table(c)
    assert table["octopus"] < table["federated"] / 1000
    assert table["octopus"] < table["split_learning"] / 10
    assert table["octopus_vs_fl_ratio"] > 1000


def test_grad_compression_still_expensive():
    """§2.8: compressed FL still pays the uncompressed downlink x extra
    rounds — must stay well above OCTOPUS."""
    c = _comm()
    assert OH.gradient_compressed_fl_bytes(c) > OH.octopus_bytes(c) * 100


def test_multi_task_scaling():
    c = _comm()
    mt = OH.multi_task_bytes(c, n_tasks=10)
    # FL rerun 10x; octopus only re-downloads 10 small downstream models
    assert mt["federated"] == 10 * OH.federated_bytes(c)
    assert mt["octopus"] < OH.octopus_bytes(c) + 10 * c.downstream_model_bytes


def test_code_bytes_packing():
    assert OH.code_bytes(64, 256) == 64          # 8 bits/code
    assert OH.code_bytes(64, 16) == 32           # 4 bits/code
    assert OH.code_bytes(3, 256) == 3

"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.vq_nn import vq_nearest_pallas


# ------------------------------------------------------------------- vq_nn

@pytest.mark.parametrize("n,k,m", [(8, 16, 8), (100, 64, 32), (256, 256, 64),
                                   (300, 200, 64), (1000, 512, 128),
                                   (17, 33, 48)])
def test_vq_nn_matches_ref(key, n, k, m):
    z = jax.random.normal(key, (n, m))
    cb = jax.random.normal(jax.random.PRNGKey(1), (k, m))
    got = vq_nearest_pallas(z, cb, interpret=True)
    want = ref.vq_nearest_ref(z, cb)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_vq_nn_dtypes(key, dtype):
    z = jax.random.normal(key, (64, 32)).astype(dtype)
    cb = jax.random.normal(jax.random.PRNGKey(1), (48, 32)).astype(dtype)
    got = vq_nearest_pallas(z, cb, interpret=True)
    want = ref.vq_nearest_ref(z, cb)
    # bf16 rounding can flip argmin ties; allow tiny disagreement
    agree = float(jnp.mean((got == want).astype(jnp.float32)))
    assert agree > 0.98


def test_vq_nn_block_sweep(key):
    z = jax.random.normal(key, (500, 64))
    cb = jax.random.normal(jax.random.PRNGKey(1), (300, 64))
    want = ref.vq_nearest_ref(z, cb)
    for bn in (64, 128, 256):
        for bk in (128, 256):
            got = vq_nearest_pallas(z, cb, block_n=bn, block_k=bk,
                                    interpret=True)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_vq_nn_consistent_with_core_vq(key):
    from repro.core.vq import nearest_atom
    z = jax.random.normal(key, (128, 64))
    cb = jax.random.normal(jax.random.PRNGKey(1), (256, 64))
    np.testing.assert_array_equal(
        np.asarray(vq_nearest_pallas(z, cb, interpret=True)),
        np.asarray(nearest_atom(z, cb)))


# --------------------------------------------------------------- flash attn

@pytest.mark.parametrize("t,causal,window", [
    (64, True, 0), (128, True, 0), (200, True, 0), (128, False, 0),
    (256, True, 64), (300, True, 128),
])
def test_flash_matches_ref(key, t, causal, window):
    B, H, Dh = 2, 4, 32
    q = jax.random.normal(key, (B, t, H, Dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, t, H, Dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, t, H, Dh))
    got = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_gqa_via_ops(key):
    q = jax.random.normal(key, (2, 96, 8, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 96, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 96, 2, 16))
    got = ops.flash_attention(q, k, v, causal=True, interpret=True)
    kk, vv = jnp.repeat(k, 4, 2), jnp.repeat(v, 4, 2)
    want = ref.flash_attention_ref(q, kk, vv, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_matches_chunked_jax_twin(key):
    """Kernel vs the pure-JAX online-softmax twin in nn.attention."""
    from repro.nn.attention import _attend_chunked
    q = jax.random.normal(key, (1, 160, 2, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 160, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 160, 2, 16))
    got = flash_attention_pallas(q, k, v, causal=True, interpret=True)
    want = _attend_chunked(q, k, v, causal=True, q_offset=0, window=0,
                           kv_chunk=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtype(key, dtype):
    q = jax.random.normal(key, (1, 64, 2, 32)).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 2, 32)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 64, 2, 32)).astype(dtype)
    got = flash_attention_pallas(q, k, v, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


# ------------------------------------------------------------------ rmsnorm

@pytest.mark.parametrize("shape", [(8, 64), (3, 7, 128), (2, 5, 11, 256),
                                   (1, 512)])
def test_rmsnorm_matches_ref(key, shape):
    x = jax.random.normal(key, shape)
    s = jax.random.normal(jax.random.PRNGKey(1), (shape[-1],))
    got = rmsnorm_pallas(x, s, interpret=True)
    want = ref.rmsnorm_ref(x, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6, rtol=1e-6)


def test_rmsnorm_matches_layer(key):
    from repro.nn.layers import rmsnorm as layer_rmsnorm
    x = jax.random.normal(key, (4, 32, 64))
    s = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (64,)))
    got = rmsnorm_pallas(x, s, interpret=True)
    want = layer_rmsnorm({"scale": s}, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


# ----------------------------------------------------------- selective scan

@pytest.mark.parametrize("b,t,di,n", [(1, 16, 8, 4), (2, 40, 24, 8),
                                      (2, 128, 64, 16), (1, 200, 48, 16)])
def test_selective_scan_matches_ref(key, b, t, di, n):
    from repro.kernels.selective_scan import selective_scan_pallas
    decay = jax.nn.sigmoid(jax.random.normal(key, (b, t, di, n)))
    inp = jax.random.normal(jax.random.PRNGKey(1), (b, t, di, n))
    c = jax.random.normal(jax.random.PRNGKey(2), (b, t, n))
    h0 = jax.random.normal(jax.random.PRNGKey(3), (b, di, n))
    y, hl = selective_scan_pallas(decay, inp, c, h0, interpret=True)
    yr, hlr = ref.selective_scan_ref(decay, inp, c, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(hlr),
                               atol=1e-4, rtol=1e-4)


def test_selective_scan_block_sweep(key):
    from repro.kernels.selective_scan import selective_scan_pallas
    b, t, di, n = 1, 64, 32, 8
    decay = jax.nn.sigmoid(jax.random.normal(key, (b, t, di, n)))
    inp = jax.random.normal(jax.random.PRNGKey(1), (b, t, di, n))
    c = jax.random.normal(jax.random.PRNGKey(2), (b, t, n))
    h0 = jnp.zeros((b, di, n))
    yr, _ = ref.selective_scan_ref(decay, inp, c, h0)
    for bd in (16, 32):
        for ct in (16, 64):
            y, _ = selective_scan_pallas(decay, inp, c, h0, block_di=bd,
                                         chunk_t=ct, interpret=True)
            np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                       atol=1e-4, rtol=1e-4)


def test_selective_scan_matches_ssm_module(key):
    """Kernel agrees with the jnp fused scan used by the Mamba layer."""
    from repro.kernels.selective_scan import selective_scan_pallas
    from repro.nn.ssm import _selective_scan_fused
    b, t, di, n = 2, 50, 16, 8
    decay = jax.nn.sigmoid(jax.random.normal(key, (b, t, di, n)))
    inp = jax.random.normal(jax.random.PRNGKey(1), (b, t, di, n))
    c = jax.random.normal(jax.random.PRNGKey(2), (b, t, n))
    h0 = jnp.zeros((b, di, n))
    yk, hk = selective_scan_pallas(decay, inp, c, h0, interpret=True)
    yj, hj = _selective_scan_fused(decay, inp, c, h0, chunk=16)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yj),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hj),
                               atol=1e-4, rtol=1e-4)

"""Fused client uplink pipeline (kernels/encode_codes.py).

The contracts that let the fused encode replace quantize-then-pack-then-
re-encode:
  * kernel parity — ops.encode_codes words == quantize -> pack_codes
    bit-exact for every packing width (VQ and grouped/sliced GSVQ),
    matching the jnp oracle and the use_ref fallback;
  * stats parity — the kernel's (counts, sums) drive ema_update_from_stats
    to the same EMAState as the classic ema_update to fp32 tolerance;
  * roundtrip — kernel-packed words decode through ops.decode_codes back
    to the features of the original indices;
  * protocol — client_round runs the encoder EXACTLY once after local
    fine-tuning (the seed path ran it three times), and the engine's
    fused population round is bit-identical to the per-client loop;
  * store — multi-record (per-client) kernel-packed payloads ingest and
    bulk-decode against the right version snapshots.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dvqae, ema, octopus as OC
from repro.core.dvqae import DVQAEConfig
from repro.core.gsvq import gsvq_quantize
from repro.core.vq import nearest_atom, quantize
from repro.kernels import ops, ref
from repro.kernels.pack_bits import code_bits, packing_dims


def _rand(key, shape):
    return jax.random.normal(key, shape)


# ------------------------------------------------------------------ kernel

@pytest.mark.parametrize("bits", [1, 3, 5, 8, 10, 12])
def test_encode_words_bitexact_vq(key, bits):
    """Fused words == nearest-atom -> pack_codes at every packing width."""
    K = 1 << bits
    M = 16
    for count in (1, 37, 300):
        k1, k2 = jax.random.split(jax.random.fold_in(key, bits * 1000 + count))
        z = _rand(k1, (1, count, M))
        cb = _rand(k2, (1, K, M))
        words, counts, sums = ops.encode_codes(z, cb, bits=bits,
                                               use_ref=False)   # Pallas
        idx = nearest_atom(z[0], cb[0])
        want = ops.pack_codes(idx, bits=bits)
        np.testing.assert_array_equal(np.asarray(words), np.asarray(want))
        for alt in (ref.encode_codes_ref(z, cb, bits=bits)[0],
                    ops.encode_codes(z, cb, bits=bits)[0],      # default
                    ops.encode_codes(z, cb, bits=bits, use_ref=True)[0]):
            np.testing.assert_array_equal(np.asarray(words), np.asarray(alt))


@pytest.mark.parametrize("n_groups,n_slices,K,M", [
    (8, 1, 64, 16), (4, 2, 64, 16), (8, 4, 64, 32), (1, 2, 64, 16),
    (16, 3, 64, 24), (4096, 2, 4096, 8)])
def test_encode_words_bitexact_gsvq(key, n_groups, n_slices, K, M):
    """GSVQ fused words == gsvq_quantize -> pack_codes (group alphabet),
    incl. the 12-bit group alphabet and a 3-slice phase pattern."""
    bits = code_bits(n_groups)
    count = 23 if K > 1024 else 201
    k1, k2 = jax.random.split(jax.random.fold_in(key, n_groups + n_slices))
    z = _rand(k1, (1, count, M))
    cb = _rand(k2, (1, K, M))
    words, counts, sums = ops.encode_codes(z, cb, bits=bits,
                                           n_groups=n_groups,
                                           n_slices=n_slices,
                                           use_ref=False)       # Pallas
    idx = gsvq_quantize(z[0], cb[0], n_groups=n_groups,
                        n_slices=n_slices).indices
    want = ops.pack_codes(idx, bits=bits)
    np.testing.assert_array_equal(np.asarray(words), np.asarray(want))
    rw, _, _ = ref.encode_codes_ref(z, cb, bits=bits, n_groups=n_groups,
                                    n_slices=n_slices)
    np.testing.assert_array_equal(np.asarray(words), np.asarray(rw))
    dw, _, _ = ops.encode_codes(z, cb, bits=bits, n_groups=n_groups,
                                n_slices=n_slices)              # default
    np.testing.assert_array_equal(np.asarray(words), np.asarray(dw))


def test_encode_multi_record_streams_pack_per_record(key):
    """Each record packs into ITS OWN zero-padded stream against ITS OWN
    codebook — identical to pack_codes on every record alone."""
    bits, K, M, P, R = 5, 32, 16, 45, 3          # P*1 not a multiple of G
    G, W = packing_dims(bits)
    ks = jax.random.split(key, 2 * R)
    z = jnp.stack([_rand(ks[i], (P, M)) for i in range(R)])
    cbs = jnp.stack([_rand(ks[R + i], (K, M)) for i in range(R)])
    for use_ref in (False, True):
        words, counts, sums = ops.encode_codes(z, cbs, bits=bits,
                                               use_ref=use_ref)
        nW = -(-P // G)
        assert words.shape == (R * nW, W)
        for r in range(R):
            idx = nearest_atom(z[r], cbs[r])
            np.testing.assert_array_equal(
                np.asarray(words[r * nW:(r + 1) * nW]),
                np.asarray(ops.pack_codes(idx, bits=bits)))


@pytest.mark.parametrize("n_groups,n_slices,K", [(1, 1, 32), (4, 2, 64)])
def test_encode_stats_match_ema_update(key, n_groups, n_slices, K):
    """Kernel (counts, sums) -> ema_update_from_stats == classic
    ema_update on the broadcast representative-atom assignment."""
    M = 16
    cfg = DVQAEConfig(latent_dim=M, codebook_size=K, n_groups=n_groups,
                      n_slices=n_slices)
    bits = OC.transmit_bits(cfg)
    k1, k2 = jax.random.split(key)
    z = _rand(k1, (1, 97, M))
    cb = _rand(k2, (1, K, M))
    _, counts, sums = ops.encode_codes(z, cb, bits=bits, n_groups=n_groups,
                                       n_slices=n_slices, use_ref=False)
    _, rcounts, rsums = ops.encode_codes(z, cb, bits=bits,
                                         n_groups=n_groups,
                                         n_slices=n_slices)     # default
    np.testing.assert_allclose(np.asarray(counts), np.asarray(rcounts),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(sums), np.asarray(rsums),
                               rtol=1e-5, atol=1e-5)
    state = ema.init_ema(cb[0])
    got = ema.ema_update_from_stats(state, counts[0], sums[0], gamma=0.7)
    if n_groups > 1 or n_slices > 1:
        idx = gsvq_quantize(z[0], cb[0], n_groups=n_groups,
                            n_slices=n_slices).indices
        ng = K // n_groups
        rep = idx * ng + ng // 2
        zv = jnp.broadcast_to(z[0][..., None, :], rep.shape + (M,))
    else:
        rep = nearest_atom(z[0], cb[0])
        zv = z[0]
    want = ema.ema_update(state, zv, rep, gamma=0.7)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bits", [3, 8, 12])
def test_encode_roundtrips_through_fused_decode(key, bits):
    """encode_codes words -> ops.decode_codes == codebook[indices]."""
    K, M, count = 1 << bits, 8, 130
    k1, k2 = jax.random.split(jax.random.fold_in(key, bits))
    z = _rand(k1, (1, count, M))
    cb = _rand(k2, (1, K, M))
    words, _, _ = ops.encode_codes(z, cb, bits=bits, use_ref=False)
    rows = ops.decode_codes(words, cb[0], bits=bits, count=count)
    idx = nearest_atom(z[0], cb[0])
    np.testing.assert_array_equal(np.asarray(rows), np.asarray(cb[0][idx]))


def test_encode_kernel_block_sweep(key):
    """Words/stats invariant across block_n/block_k tilings."""
    bits, K, M, count = 6, 64, 16, 500
    k1, k2 = jax.random.split(key)
    z = _rand(k1, (1, count, M))
    cb = _rand(k2, (1, K, M))
    base = ops.encode_codes(z, cb, bits=bits, use_ref=False)
    for bn in (32, 96, 512):
        for bk in (16, 64, 512):
            got = ops.encode_codes(z, cb, bits=bits, block_n=bn, block_k=bk,
                                   use_ref=False)
            np.testing.assert_array_equal(np.asarray(got[0]),
                                          np.asarray(base[0]))
            np.testing.assert_allclose(np.asarray(got[1]),
                                       np.asarray(base[1]), rtol=1e-6)
            # sums reassociate across N-block accumulation order
            np.testing.assert_allclose(np.asarray(got[2]),
                                       np.asarray(base[2]), rtol=1e-5,
                                       atol=1e-5)


# ---------------------------------------------------------------- protocol

def _count_encoder_passes(fn):
    """Run ``fn`` with repro.core.dvqae.encode wrapped by a counter."""
    calls = []
    real = dvqae.encode

    def counting(params, cfg, x):
        calls.append(1)
        return real(params, cfg, x)

    dvqae.encode = counting
    try:
        fn()
    finally:
        dvqae.encode = real
    return len(calls)


def test_client_round_single_encoder_pass(key):
    """Acceptance: after local fine-tuning, client_round runs the encoder
    exactly ONCE (the seed path ran forward, then forward + encode again
    inside the refresh — three passes for one batch of latents)."""
    cfg = DVQAEConfig(kind="image", in_channels=3, hidden=8, latent_dim=8,
                      codebook_size=16, n_res_blocks=1)
    srv = OC.server_init(key, cfg)
    cl = OC.client_init(srv)
    x = jax.random.normal(key, (2, 8, 8, 3))
    n = _count_encoder_passes(
        lambda: OC.client_round(cl, cfg, x, n_local_steps=0))
    assert n == 1, f"client_round ran the encoder {n}x"
    from repro.wire import round_words
    n = _count_encoder_passes(
        lambda: round_words(cl, cfg, x, n_local_steps=0))
    assert n == 1, f"round_words ran the encoder {n}x"
    # each fine-tune step legitimately adds exactly one gradient pass
    n = _count_encoder_passes(
        lambda: OC.client_round(cl, cfg, x, n_local_steps=2))
    assert n == 3


def test_codebook_refresh_single_pass_and_stats_shortcut(key):
    """client_codebook_refresh runs ONE encoder pass (was two network
    passes), and zero when handed precomputed stats."""
    cfg = DVQAEConfig(kind="image", in_channels=3, hidden=8, latent_dim=8,
                      codebook_size=16, n_res_blocks=1)
    srv = OC.server_init(key, cfg)
    cl = OC.client_init(srv)
    x = jax.random.normal(key, (2, 8, 8, 3))
    assert _count_encoder_passes(
        lambda: OC.client_codebook_refresh(cl, cfg, x)) == 1
    z, _ = OC.client_encode(cl.params, cfg, x)
    idx = OC.quantize_indices(cfg, z, cl.params["codebook"])
    stats = OC.refresh_stats(cfg, z, idx)
    assert _count_encoder_passes(
        lambda: OC.client_codebook_refresh(cl, cfg, None, stats=stats)) == 0
    got = OC.client_codebook_refresh(cl, cfg, None, stats=stats)
    want = OC.client_codebook_refresh(cl, cfg, x)
    np.testing.assert_allclose(np.asarray(got.params["codebook"]),
                               np.asarray(want.params["codebook"]),
                               rtol=1e-6)


@pytest.mark.parametrize("n_groups,n_slices", [(1, 1), (4, 2)])
def test_engine_fused_round_matches_client_loop(key, n_groups, n_slices):
    """The population round (vmapped encode + ONE fused dispatch) equals
    N single-client rounds: per-client packed records unpack to the loop
    indices bit-exactly and client states agree."""
    from repro.sim import SimEngine, stack_clients
    cfg = DVQAEConfig(kind="image", in_channels=3, hidden=8,
                      latent_dim=16 if n_slices > 1 else 8,
                      codebook_size=64 if n_groups > 1 else 16,
                      n_res_blocks=1, n_groups=n_groups, n_slices=n_slices)
    srv = OC.server_init(key, cfg)
    n_clients = 5
    data = jax.random.normal(key, (n_clients, 2, 8, 8, 3))
    engine = SimEngine(cfg, lr=1e-4, gamma=0.9)
    clients, packed = engine.round(engine.init_clients(srv, n_clients), data)
    assert packed.n_records == n_clients

    singles, idxs = [], []
    for i in range(n_clients):
        c, idx = OC.client_round(OC.client_init(srv), cfg, data[i],
                                 lr=1e-4, gamma=0.9)
        singles.append(c)
        idxs.append(idx)
    np.testing.assert_array_equal(np.asarray(packed.unpack()),
                                  np.asarray(jnp.stack(idxs)))
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-4, atol=3e-4),
        clients, stack_clients(singles))
    # the uplink decodes against the post-merge dictionary as before
    merged = engine.merge_into_server(srv, clients)
    feats = engine.dequantize(merged, packed)
    idx = packed.unpack()
    want = OC.codes_to_features(merged, cfg,
                                idx.reshape((-1,) + idx.shape[2:]))
    np.testing.assert_allclose(np.asarray(feats), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_transmission_nbytes_counts_per_client_padding(key):
    """The engine payload is one stream PER CLIENT — measured bytes cover
    every client's own super-group padding (what each radio sends), so
    nbytes == n_clients * per-client packed bytes."""
    from repro.sim import SimEngine
    cfg = DVQAEConfig(kind="image", in_channels=3, hidden=8, latent_dim=8,
                      codebook_size=16, n_res_blocks=1)
    srv = OC.server_init(key, cfg)
    engine = SimEngine(cfg, gamma=0.9)
    n_clients = 3
    data = jax.random.normal(key, (n_clients, 2, 8, 8, 3))
    _, packed = engine.round(engine.init_clients(srv, n_clients), data)
    one = ops.pack_codes(packed.unpack()[0], bits=packed.bits)
    assert packed.nbytes == n_clients * one.size * one.dtype.itemsize


# ------------------------------------------------------------------- store

def test_store_ingests_kernel_packed_population_rounds(key):
    """Multi-record engine payloads land in the CodeStore and bulk-decode
    (one dispatch per version) to the same features as their unpacked
    indices, across codebook versions."""
    from repro.server import CodebookRegistry, CodeStore
    from repro.sim import SimEngine
    cfg = DVQAEConfig(kind="image", in_channels=3, hidden=8, latent_dim=16,
                      codebook_size=64, n_res_blocks=1, n_groups=4,
                      n_slices=2)
    srv = OC.server_init(key, cfg)
    registry = CodebookRegistry(srv.params["codebook"])
    engine = SimEngine(cfg, gamma=0.9)
    clients = engine.init_clients(srv, 4)
    store = CodeStore(cfg)
    want = []
    for rnd in range(2):
        data = jax.random.normal(jax.random.fold_in(key, rnd), (4, 2, 8, 8, 3))
        clients, packed = engine.round(clients, data)
        store.add(packed, round=rnd, version=0)
        idx = packed.unpack()
        want.append(np.asarray(OC.codes_to_features(
            None, cfg, idx.reshape((-1,) + idx.shape[2:]),
            codebook=registry.get(0))))
    feats, _ = store.dataset(None, registry=registry)
    np.testing.assert_allclose(np.asarray(feats), np.concatenate(want),
                               rtol=1e-6, atol=1e-6)

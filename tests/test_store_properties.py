"""Property tests: sharded code-store ledgers under churn.

The two invariants that make bounded ring buffers safe to run forever:
  * BYTE CONSERVATION — for every codebook version, at every point in
    an arbitrary ingest stream, Σ stored + Σ evicted == Σ ingested
    measured bytes (§2.8 never loses a byte to eviction), under both
    FIFO and reservoir policies;
  * PARTITION ISOLATION — a record lands in exactly the
    ``(version, shard)`` partition its payload routes to, and eviction
    in one partition never touches another (no cross-version or
    cross-client mixing).

Payloads are built from raw numpy word streams via
``CodePayload.from_words`` so the properties run hundreds of cases
without a single kernel dispatch.  Hypothesis is a dev-only dependency;
the fixed-case fallbacks keep the invariants covered without it.
"""
import numpy as np
import pytest

from repro.core.dvqae import DVQAEConfig
from repro.kernels.pack_bits import code_bits, packing_dims
from repro.server import CodeStore, ShardedCodeStore
from repro.wire import CodePayload

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:            # dev-only dependency; fixed cases still run
    HAVE_HYPOTHESIS = False

BITS = code_bits(16)


@pytest.fixture(scope="module")
def tiny_cfg():
    return DVQAEConfig(kind="image", in_channels=3, hidden=8, latent_dim=8,
                       codebook_size=16, n_res_blocks=1)


def _payload(n_samples, version, fill=0):
    """A (n_samples, 3)-shaped payload from raw words — no kernels."""
    G, W = packing_dims(BITS)
    count = n_samples * 3
    rows = (count + G - 1) // G
    words = np.full((rows, W), fill, dtype=np.uint32)
    return CodePayload.from_words(words, bits=BITS,
                                  shape=(n_samples, 3),
                                  version=version)


# (n_samples 1..4, version 0..2, client id 0..7) per ingest step
if HAVE_HYPOTHESIS:
    STEP = st.tuples(st.integers(1, 4), st.integers(0, 2),
                     st.integers(0, 7))
    STREAM = st.lists(STEP, min_size=1, max_size=40)
else:
    STREAM = None

FIXED_STREAMS = [
    [(2, 0, 0), (3, 0, 1), (2, 1, 0), (4, 0, 2), (1, 1, 3), (2, 0, 0)],
    [(4, 0, 0)] * 8,                        # one partition, heavy churn
    [(1, v, c) for v in (0, 1, 2) for c in range(6)],
]


def _run_byte_conservation(tiny_cfg, policy, stream):
    store = CodeStore(tiny_cfg, capacity_samples=8, policy=policy, seed=3)
    for i, (n, version, _) in enumerate(stream):
        store.add(_payload(n, version, fill=i))
        # the invariant holds at EVERY step, not just at the end
        stored = store.stored_bytes_by_version
        ing = store.ingested_bytes_by_version
        ev = store.evicted_bytes_by_version
        for v in ing:
            assert stored.get(v, 0) + ev.get(v, 0) == ing[v], \
                f"v{v} leak at step {i} under {policy}"
        # bounded: over capacity only when a single record alone is
        assert store.n_samples <= 8 or len(store.records) == 1
    assert store.total_bytes + store.evicted_bytes == store.ingested_bytes
    assert sum(ing.values()) == store.ingested_bytes


def _run_partition_isolation(tiny_cfg, stream):
    store = ShardedCodeStore(tiny_cfg, n_shards=4, capacity_samples=6,
                             seed=5)
    for i, (n, version, client) in enumerate(stream):
        ids = np.arange(client, client + n)
        store.add(_payload(n, version, fill=i), client_ids=ids)
        for (v, shard), part in store.partitions.items():
            for rec in part.records:
                assert rec.version == v, "version mixed across partitions"
                assert store.shard_of(rec.client_ids) == shard, \
                    "client shard mixed across partitions"
            assert part.n_samples <= 6 or len(part.records) == 1
    # aggregate ledgers == sum of partition ledgers, per version
    ing = store.ingested_bytes_by_version
    for v in ing:
        assert store.stored_bytes_by_version.get(v, 0) + \
            store.evicted_bytes_by_version.get(v, 0) == ing[v]
    assert store.total_bytes + store.evicted_bytes == store.ingested_bytes


if HAVE_HYPOTHESIS:

    @settings(max_examples=60, deadline=None)
    @given(stream=STREAM, policy=st.sampled_from(["fifo", "reservoir"]))
    def test_eviction_conserves_bytes_per_version(stream, policy):
        cfg = DVQAEConfig(kind="image", in_channels=3, hidden=8,
                          latent_dim=8, codebook_size=16, n_res_blocks=1)
        _run_byte_conservation(cfg, policy, stream)

    @settings(max_examples=60, deadline=None)
    @given(stream=STREAM)
    def test_partitions_never_mix_versions_or_shards(stream):
        cfg = DVQAEConfig(kind="image", in_channels=3, hidden=8,
                          latent_dim=8, codebook_size=16, n_res_blocks=1)
        _run_partition_isolation(cfg, stream)


@pytest.mark.parametrize("policy", ["fifo", "reservoir"])
@pytest.mark.parametrize("stream", FIXED_STREAMS)
def test_eviction_conserves_bytes_fixed_cases(tiny_cfg, policy, stream):
    _run_byte_conservation(tiny_cfg, policy, stream)


@pytest.mark.parametrize("stream", FIXED_STREAMS)
def test_partition_isolation_fixed_cases(tiny_cfg, stream):
    _run_partition_isolation(tiny_cfg, stream)


def test_retire_version_keeps_ledgers(tiny_cfg):
    """retire_version evicts every record of that version across all
    shards — the bytes move to the evicted ledger, never vanish."""
    store = ShardedCodeStore(tiny_cfg, n_shards=2, capacity_samples=32)
    for i, (n, v, c) in enumerate(FIXED_STREAMS[0]):
        store.add(_payload(n, v, fill=i),
                  client_ids=np.arange(c, c + n))
    ing = dict(store.ingested_bytes_by_version)
    gone = store.retire_version(0)
    assert all(r.version == 0 for r in gone)
    assert store.versions == (1,)
    assert store.evicted_bytes_by_version[0] == ing[0]
    assert store.stored_bytes_by_version.get(0, 0) == 0
    assert store.total_bytes + store.evicted_bytes == store.ingested_bytes

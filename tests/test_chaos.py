"""Chaos plane + crash-consistent server (the Step-6 robustness
contracts).

What makes the fault plane a subsystem and not a test helper:
  * every fault family (drop / duplicate / reorder / delay / corrupt /
    truncate) is DETERMINISTIC under one key and draws from its own
    PRNG substream — toggling one knob never perturbs another family's
    draws;
  * the §2.8 byte-conservation identity survives arbitrary chaos:
    Σ sent == Σ delivered + Σ dropped + Σ rejected + Σ duplicate +
    Σ in flight — corrupted, truncated, duplicated and retried bytes
    all stay on the ledger;
  * the ``(client_id, seq)`` idempotency envelope makes the channel
    exactly-once over at-least-once delivery: retries that race a
    success come back ``duplicate``, never double-stored;
  * a journaled service recovers from a kill at ANY tick — including
    mid-migration — to the exact verdict histogram, byte ledger and
    bit-identical decoded features of the uninterrupted run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import octopus as OC
from repro.core.dvqae import DVQAEConfig
from repro.kernels.pack_bits import code_bits
from repro.obs import report as obs_report
from repro.server import (ContinuousIngestService, RoundScheduler,
                          SchedulerConfig, ServerPersistence,
                          ShardedCodeStore)
from repro.sim import FAULT_KINDS, CohortEngine, FaultPlan, FaultyChannel
from repro.wire import CodePayload, OctopusServer, RetryPolicy

N_CLIENTS = 12


@pytest.fixture(autouse=True)
def no_ambient_recorder():
    obs.uninstall()
    yield
    obs.uninstall()


@pytest.fixture(scope="module")
def tiny_cfg():
    return DVQAEConfig(kind="image", in_channels=3, hidden=8, latent_dim=8,
                       codebook_size=16, n_res_blocks=1)


@pytest.fixture(scope="module")
def state(tiny_cfg):
    return OC.server_init(jax.random.PRNGKey(0), tiny_cfg)


@pytest.fixture(scope="module")
def data():
    return jax.random.normal(jax.random.PRNGKey(1),
                             (N_CLIENTS, 2, 8, 8, 3))


def _data_fn(data):
    return lambda ids: data[np.asarray(ids)]


def _pack(seed, version=0, c=1, b=3, t=4):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 16, size=(c, b, t))
    return CodePayload.pack(jnp.asarray(codes, jnp.int32),
                            bits=code_bits(16), version=version)


def _service(tiny_cfg, state, **kw):
    srv = OctopusServer(state, tiny_cfg,
                        store=ShardedCodeStore(tiny_cfg, n_shards=2))
    return ContinuousIngestService(srv, **kw)


def _conserved(q):
    return q.bytes_sent == (q.bytes_delivered + q.bytes_dropped
                            + q.bytes_rejected + q.bytes_duplicate
                            + q.bytes_in_flight)


# ------------------------------------------------------- fault families

def test_drop_burns_bytes_stores_nothing(tiny_cfg, state):
    chan = FaultyChannel(_service(tiny_cfg, state), FaultPlan(drop=1.0),
                         key=jax.random.PRNGKey(1))
    for i in range(4):
        res = chan.offer(_pack(i), client_ids=[i])
        assert (res.verdict, res.reason) == ("rejected", "radio_drop")
    chan.drain()
    assert chan.faults == {"drop": 4}
    assert len(chan.wire.store) == 0
    q = chan.queue
    assert q.bytes_dropped == q.bytes_sent > 0
    assert _conserved(q)


def test_duplicate_dedups_on_envelope(tiny_cfg, state):
    """The channel's duplicated copy carries the SAME (client_id, seq)
    envelope, so the service answers ``duplicate`` and stores once."""
    chan = FaultyChannel(_service(tiny_cfg, state),
                         FaultPlan(duplicate=1.0),
                         key=jax.random.PRNGKey(2))
    for i in range(3):
        res = chan.offer(_pack(i), client_ids=[i])
        assert res.verdict == "accepted"
    chan.drain()
    assert chan.faults == {"duplicate": 3}
    assert chan.verdicts["duplicate"] == 3
    assert len(chan.wire.store) == 3            # each payload held ONCE
    q = chan.queue
    assert q.bytes_duplicate > 0
    assert _conserved(q)


def test_corrupt_and_truncate_rejected_by_crc(tiny_cfg, state):
    """A word-level bit flip or a truncated stream no longer matches the
    carrier CRC -> rejected/corrupt at the door, bytes still ledgered."""
    for plan in (FaultPlan(corrupt=1.0), FaultPlan(truncate=1.0)):
        chan = FaultyChannel(_service(tiny_cfg, state), plan,
                             key=jax.random.PRNGKey(3))
        for i in range(3):
            res = chan.offer(_pack(i), client_ids=[i])
            assert (res.verdict, res.reason) == ("rejected", "corrupt")
        chan.drain()
        assert sum(chan.faults.values()) == 3
        assert len(chan.wire.store) == 0
        assert chan.queue.bytes_rejected == chan.queue.bytes_sent > 0
        assert _conserved(chan.queue)


def test_delay_holds_delivery_within_bound(tiny_cfg, state):
    chan = FaultyChannel(_service(tiny_cfg, state),
                         FaultPlan(delay=1.0, max_delay=3),
                         key=jax.random.PRNGKey(4))
    assert chan.offer(_pack(0), client_ids=[0]).verdict == "accepted"
    assert chan.faults == {"delay": 1}
    first = chan.tick()
    assert first.n_delivered == 0               # held back in the channel
    hist = [first] + chan.drain()
    assert sum(t.n_delivered for t in hist) == 1
    assert len(hist) <= 1 + 3                   # lands within max_delay
    assert len(chan.wire.store) == 1
    assert _conserved(chan.queue)


def test_reorder_swaps_arrival_order(tiny_cfg, state):
    """With reorder forced, the LAST two queued payloads swap: arrival
    order in the store differs from send order (plain CodeStore — a
    sharded store would itself scatter arrival order)."""
    svc = ContinuousIngestService(OctopusServer(state, tiny_cfg))
    chan = FaultyChannel(svc, FaultPlan(reorder=1.0),
                         key=jax.random.PRNGKey(5))
    a, b = _pack(10), _pack(11)
    chan.offer(a, client_ids=[0])               # alone: nothing to swap
    chan.offer(b, client_ids=[1])
    assert chan.faults == {"reorder": 1}
    chan.drain()
    recs = list(svc.wire.store.records)
    words = [np.asarray(r.packed.payload) for r in recs]
    np.testing.assert_array_equal(words[0], np.asarray(b.payload))
    np.testing.assert_array_equal(words[1], np.asarray(a.payload))
    assert _conserved(chan.queue)


def test_fault_families_draw_independent_substreams(tiny_cfg, state):
    """Enabling corruption must not change WHICH sends drop — each
    family folds its own purpose into the per-send substream."""
    def drops(plan):
        chan = FaultyChannel(_service(tiny_cfg, state), plan,
                             key=jax.random.PRNGKey(6))
        out = []
        for i in range(30):
            res = chan.offer(_pack(i), client_ids=[i])
            out.append(res.reason == "radio_drop")
        return out
    base = drops(FaultPlan(drop=0.3))
    assert 1 <= sum(base) <= 29                 # chaos actually mixed
    assert drops(FaultPlan(drop=0.3, corrupt=0.9, delay=0.5)) == base


def test_channel_is_deterministic_under_key(tiny_cfg, state):
    def go():
        chan = FaultyChannel(
            _service(tiny_cfg, state),
            FaultPlan(drop=0.2, duplicate=0.2, reorder=0.3, delay=0.3,
                      corrupt=0.15, truncate=0.1),
            key=jax.random.PRNGKey(7),
            retry=RetryPolicy(max_attempts=2))
        for i in range(25):
            chan.offer(_pack(i), client_ids=[i % 5])
            chan.tick()
        chan.drain()
        return chan
    a, b = go(), go()
    assert a.faults == b.faults and sum(a.faults.values()) > 0
    assert a.verdicts == b.verdicts
    assert a.retries == b.retries
    assert a.queue.bytes_sent == b.queue.bytes_sent
    assert len(a.wire.store) == len(b.wire.store)


# --------------------------------------------------------- exactly-once

def test_retry_loop_is_exactly_once(tiny_cfg, state):
    """Dropped sends retry under the SAME envelope until they land;
    retries that race a success answer ``duplicate``; every envelope is
    stored at most once and the ledger stays conserved."""
    chan = FaultyChannel(_service(tiny_cfg, state),
                         FaultPlan(drop=0.4, duplicate=0.3),
                         key=jax.random.PRNGKey(8),
                         retry=RetryPolicy(max_attempts=4, base_ticks=1,
                                           cap_ticks=4))
    n = 20
    for i in range(n):
        chan.offer(_pack(i, c=1), client_ids=[i])
        chan.tick()
    chan.drain()
    assert chan.retries > 0
    assert chan.faults.get("drop", 0) > 0
    # at-most-once per envelope: n distinct envelopes, so the store can
    # never exceed n records even though the channel re-sent many
    assert len(chan.wire.store) <= n
    admitted = sum(chan.verdicts.get(v, 0)
                   for v in ("accepted", "deferred", "migrated"))
    assert len(chan.wire.store) == admitted
    assert _conserved(chan.queue)


def test_client_send_retries_through_faulty_channel(tiny_cfg, state):
    """OctopusClient.send drives its own retry loop against the channel
    and lands exactly once even when the first attempts drop."""
    svc = _service(tiny_cfg, state)
    chan = FaultyChannel(svc, FaultPlan(drop=0.5),
                         key=jax.random.PRNGKey(9))
    srv = OctopusServer(state, tiny_cfg)
    cl = srv.deploy(client_id=3)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 3))
    results = [cl.uplink(chan, x, retry=RetryPolicy(max_attempts=6))
               for _ in range(6)]
    chan.drain()
    landed = sum(1 for r in results if r.ok and r.verdict != "duplicate")
    assert landed + sum(1 for r in results if r.verdict == "duplicate") \
        >= sum(1 for r in results if r.ok)
    assert len(svc.wire.store) == landed
    assert _conserved(svc.queue)


# ----------------------------------------------------- traced chaos run

def test_chaos_run_continuous_conserves_and_traces(tiny_cfg, data,
                                                   tmp_path):
    """The cohort engine drives a FAULTED service unchanged; the traced
    run passes the §2.8 report check with a non-empty fault histogram."""
    state = OC.server_init(jax.random.PRNGKey(0), tiny_cfg)
    svc = _service(tiny_cfg, state, capacity=4, defer_depth=3)
    chan = FaultyChannel(
        svc,
        FaultPlan(drop=0.15, duplicate=0.15, reorder=0.2, delay=0.3,
                  corrupt=0.1, truncate=0.1),
        key=jax.random.PRNGKey(11),
        retry=RetryPolicy(max_attempts=3))
    sched = RoundScheduler(
        N_CLIENTS,
        SchedulerConfig(rate=6.0, straggler_prob=0.4, max_delay=2,
                        drop_prob=0.1),
        key=jax.random.PRNGKey(12))
    engine = CohortEngine(tiny_cfg, gamma=0.9, n_local_steps=0)
    trace = tmp_path / "chaos.jsonl"
    with obs.recording(trace):
        hist = engine.run_continuous(chan, sched, _data_fn(data),
                                     cohort_size=3, n_ticks=8,
                                     merge_every=3,
                                     migration_policy="keep")
        chan.drain()
    assert len(hist) == 8
    assert sum(chan.faults.values()) > 0
    assert _conserved(svc.queue)
    summary = obs_report.summarize(obs_report.load_events(str(trace)))
    assert obs_report.check_bytes(summary) == []
    assert summary["faults"]                    # fault histogram streamed
    assert set(summary["faults"]) <= set(FAULT_KINDS)


# ------------------------------------------------------ crash recovery

def _chaos_run(tiny_cfg, data, root, *, n_ticks, snapshot_every=3):
    """One journaled faulted run; returns (channel, service)."""
    state = OC.server_init(jax.random.PRNGKey(0), tiny_cfg)
    persist = ServerPersistence(str(root), snapshot_every=snapshot_every)
    svc = _service(tiny_cfg, state, capacity=6, persist=persist)
    chan = FaultyChannel(
        svc, FaultPlan(drop=0.2, duplicate=0.2, delay=0.3, corrupt=0.1),
        key=jax.random.PRNGKey(21), retry=RetryPolicy(max_attempts=2))
    sched = RoundScheduler(
        N_CLIENTS, SchedulerConfig(rate=5.0, straggler_prob=0.3,
                                   max_delay=2),
        key=jax.random.PRNGKey(22))
    engine = CohortEngine(tiny_cfg, gamma=0.9, n_local_steps=0)
    engine.run_continuous(chan, sched, _data_fn(data),
                          cohort_size=3, n_ticks=n_ticks, merge_every=3,
                          migration_policy="keep")
    return chan, svc


def _assert_recovered_exact(crashed, recovered):
    assert recovered.tick_idx == crashed.tick_idx
    assert recovered.verdicts == crashed.verdicts
    assert recovered.verdict_bytes == crashed.verdict_bytes
    for attr in ("bytes_sent", "bytes_delivered", "bytes_dropped",
                 "bytes_rejected", "bytes_duplicate", "bytes_in_flight"):
        assert getattr(recovered.queue, attr) == \
            getattr(crashed.queue, attr), attr
    assert len(recovered.wire.store) == len(crashed.wire.store)
    assert recovered.wire.registry.latest == crashed.wire.registry.latest
    fa, _ = crashed.wire.features()
    fb, _ = recovered.wire.features()
    np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))


@pytest.mark.parametrize("n_ticks", [2, 5, 7])
def test_recover_from_kill_at_any_tick(tiny_cfg, data, tmp_path, n_ticks):
    """Kill the faulted, journaled service after n ticks (snapshots every
    3, so the journal tail length varies): recovery rebuilds the EXACT
    verdict histogram, byte ledger and bit-identical decoded features."""
    _, svc = _chaos_run(tiny_cfg, data, tmp_path / "srv", n_ticks=n_ticks)
    recovered = ContinuousIngestService.recover(
        str(tmp_path / "srv"), tiny_cfg,
        OC.server_init(jax.random.PRNGKey(0), tiny_cfg),
        shard_fn=None, capacity=6)
    _assert_recovered_exact(svc, recovered)


def test_recover_mid_migration_reopens_window(tiny_cfg, data, tmp_path):
    """A kill while a rolling migration window is OPEN replays back INTO
    the open window: same src/dst/policy, same latest version, and the
    recovered service can still complete the migration."""
    _, svc = _chaos_run(tiny_cfg, data, tmp_path / "srv", n_ticks=7)
    win = svc.wire.registry.migration
    assert win is not None                      # merge at tick 6 opened it
    recovered = ContinuousIngestService.recover(
        str(tmp_path / "srv"), tiny_cfg,
        OC.server_init(jax.random.PRNGKey(0), tiny_cfg),
        shard_fn=None, capacity=6)
    rwin = recovered.wire.registry.migration
    assert rwin is not None
    assert (rwin.src, rwin.dst, rwin.policy) == \
        (win.src, win.dst, win.policy)
    _assert_recovered_exact(svc, recovered)
    # the recovered service is LIVE: complete the window and keep going
    recovered.complete_migration()
    assert recovered.wire.registry.migration is None
    res = recovered.offer(_pack(99, version=recovered.wire.version),
                          client_ids=[0])
    assert res.ok
    recovered.drain()


def test_recovered_service_continues_identically(tiny_cfg, data, tmp_path):
    """Post-recovery traffic behaves exactly like the uninterrupted
    service fed the same offers — recovery is a point on the same
    timeline, not a fork."""
    _, svc = _chaos_run(tiny_cfg, data, tmp_path / "srv", n_ticks=5)
    recovered = ContinuousIngestService.recover(
        str(tmp_path / "srv"), tiny_cfg,
        OC.server_init(jax.random.PRNGKey(0), tiny_cfg),
        shard_fn=None, capacity=6)
    for s in (svc, recovered):
        for i in range(4):
            s.offer(_pack(100 + i, version=s.wire.version),
                    client_ids=[i], uplink_id=(i, 1000))
        s.drain()
    assert recovered.verdicts == svc.verdicts
    fa, _ = svc.wire.features()
    fb, _ = recovered.wire.features()
    np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))

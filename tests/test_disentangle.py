"""Unit tests: disentanglement (Eq. 4-6) — IN, public/private split."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import disentangle as D
from repro.core.vq import init_codebook


def test_instance_norm_removes_channel_stats(key):
    z = jax.random.normal(key, (4, 32, 8)) * 5.0 + 3.0
    out = D.instance_norm_latent(z)
    mu = jnp.mean(out, axis=-2)
    sd = jnp.std(out, axis=-2)
    np.testing.assert_allclose(np.asarray(mu), 0.0, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sd), 1.0, atol=1e-2)


def test_instance_norm_style_invariance(key):
    """Two 'speakers' = same content with different channel gain/bias must
    normalize to (nearly) the same representation — the paper's style-
    normalization claim."""
    content = jax.random.normal(key, (1, 32, 8))
    a = content * 2.0 + 1.0
    b = content * 0.5 - 3.0
    na, nb = D.instance_norm_latent(a), D.instance_norm_latent(b)
    np.testing.assert_allclose(np.asarray(na), np.asarray(nb), atol=1e-3)


def test_split_returns_additive_parts(key):
    z = jax.random.normal(key, (4, 16, 8))
    cb = init_codebook(jax.random.PRNGKey(1), 32, 8)
    dis = D.split_public_private(z, cb, group_axis=0)
    assert dis.public.shape == z.shape
    # private broadcasts over the group axis
    assert dis.private.shape[0] == 1
    rec_in = D.recombine(dis.public, dis.private)
    assert rec_in.shape == z.shape


def test_private_mean_residual(key):
    """Z∘ = E[z_e − Z•] over the group axis (Eq. 5)."""
    z = jax.random.normal(key, (4, 16, 8))
    cb = init_codebook(jax.random.PRNGKey(1), 32, 8)
    dis = D.split_public_private(z, cb, group_axis=0, apply_in=False)
    resid = z - dis.public
    np.testing.assert_allclose(np.asarray(dis.private),
                               np.asarray(jnp.mean(resid, 0, keepdims=True)),
                               atol=1e-5)


def test_perturb_private_changes_values(key):
    p = jnp.ones((1, 16, 8))
    p2 = D.perturb_private(key, p, scale=1.0)
    assert float(jnp.mean(jnp.abs(p2 - p))) > 0.1


def test_total_loss_components(key):
    z = jax.random.normal(key, (2, 8, 4))
    cb = init_codebook(jax.random.PRNGKey(1), 16, 4)
    dis = D.split_public_private(z, cb, group_axis=0)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 4))
    x_rec = x + 0.1
    total, recon = D.total_loss(x, x_rec, dis, lam=0.5)
    assert float(recon) > 0
    assert float(total) >= float(recon)


def test_in_reduces_style_leakage_in_public(key):
    """With IN, the public component of two styled copies of the same
    content is closer than without IN."""
    content = jax.random.normal(key, (1, 64, 8))
    a = content * 3.0 + 2.0
    b = content * 0.7 - 1.0
    z = jnp.concatenate([a, b], axis=0)
    cb = init_codebook(jax.random.PRNGKey(1), 64, 8)
    with_in = D.split_public_private(z, cb, group_axis=0, apply_in=True)
    without = D.split_public_private(z, cb, group_axis=0, apply_in=False)
    gap_with = float(jnp.mean(jnp.abs(with_in.public[0] - with_in.public[1])))
    gap_without = float(jnp.mean(jnp.abs(without.public[0] - without.public[1])))
    assert gap_with < gap_without
